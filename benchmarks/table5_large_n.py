"""Table V: scalability -- problem size n = 100, t_G = 20, t_C scaled."""

from benchmarks.common import algorithm_suite, csv_row, paper_problem, run_algo

NE = 5


def run(quick=True):
    rows = []
    seeds = (0, 1) if quick else tuple(range(10))
    prob = paper_problem(dim=100)
    suite = algorithm_suite(prob, n_epochs=NE)
    for t_C in (2.0, 20.0, 200.0, 2000.0):
        for name, algo in suite.items():
            n = 600 * NE if name == "tamuna" else 600
            res = run_algo(algo, n, seeds=seeds, t_G=20.0, t_C=t_C)
            rows.append(csv_row(f"table5_tc{t_C}", name, res))
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
