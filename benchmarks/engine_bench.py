"""Unified round-engine benchmark: fused vs unfused local epochs and
compressed vs uncompressed round wall-time at model scale.

Times one jitted Fed-PLT round of a reduced transformer through
``fed/runtime.py`` (i.e. through ``fed/engine.py``) for:

  * baseline           -- gd local epochs, exact z-exchange
  * pallas_fused       -- fedplt_update fused local step (NOTE: interpret
                          mode on this CPU container, so the fused number
                          is a correctness path, not TPU performance)
  * topk50 / int8      -- compressed z uplink (adds the per-agent
                          compressor to the round's critical path; the
                          quantity bought is uplink bytes, reported as
                          the compression ratio column)

Rows: ``engine,<name>,<ms/round>,<rel to baseline>,<uplink ratio>``.
"""

import time

import jax

from repro.configs import get_config
from repro.configs.base import InputShape
from repro.data.synthetic import make_batch_for
from repro.fed.api import CompressionSpec, FedSpec, build_trainer


def _bench_round(cfg, model, spec, iters):
    trainer = build_trainer(model, spec)
    state = trainer.init(jax.random.PRNGKey(0))
    shape = InputShape("bench", 32, 8, "train")
    batch = make_batch_for(cfg, shape, n_agents=spec.n_agents)
    key = jax.random.PRNGKey(1)
    state, _ = trainer.step(state, batch, key)  # compile + warm-up
    jax.block_until_ready(state.x)
    t0 = time.perf_counter()
    for i in range(iters):
        state, m = trainer.step(state, batch, jax.random.fold_in(key, i))
    jax.block_until_ready(state.x)
    return (time.perf_counter() - t0) / iters * 1e3  # ms


def run(quick=True):
    iters = 3 if quick else 10
    cfg = get_config("gemma2-2b").reduced()
    from repro.models.model import build_model
    model = build_model(cfg)
    base = dict(n_agents=2, n_epochs=2, gamma=0.1)

    cases = [
        ("baseline", dict(), 1.0),
        ("pallas_fused", dict(use_pallas=True), 1.0),
        ("topk50", dict(compression=CompressionSpec("topk", 0.5)), 2.0),
        ("topk25", dict(compression=CompressionSpec("topk", 0.25)), 4.0),
        ("int8", dict(compression=CompressionSpec("int8")), 4.0),
        ("adaptive", dict(compression=CompressionSpec(
            "adaptive_topk", ratio=0.25, energy=0.9)), 4.0),
        # same compressor through the packed fused-kernel path: one
        # launch for the whole pytree, one sort instead of two per leaf
        ("adaptive_pallas", dict(compression=CompressionSpec(
            "adaptive_topk", ratio=0.25, energy=0.9,
            backend="pallas")), 4.0),
        # heterogeneous groups: half the agents run AGD, half run one
        # cheap GD epoch -- measures the sequential group-dispatch cost
        ("hetero_gd_agd", dict(
            agent_groups="1*agd,1*gd:n_epochs=1"), 1.0),
    ]
    rows = []
    ms0 = None
    for name, kw, uplink in cases:
        spec = FedSpec(**base, **kw)
        ms = _bench_round(cfg, model, spec, iters)
        if ms0 is None:
            ms0 = ms
        rows.append(f"engine,{name},{ms:.1f},{ms / ms0:.2f}x,"
                    f"uplink/{uplink:.0f}")
    return rows
