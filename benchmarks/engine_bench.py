"""Unified round-engine benchmark: fused vs unfused local epochs and
compressed vs uncompressed round wall-time at model scale.

Times one jitted Fed-PLT round of a reduced transformer through
``fed/runtime.py`` (i.e. through ``fed/engine.py``) for:

  * baseline           -- gd local epochs, exact z-exchange
  * pallas_fused       -- fedplt_update fused local step (NOTE: interpret
                          mode on this CPU container, so the fused number
                          is a correctness path, not TPU performance)
  * topk50 / int8      -- compressed z uplink (adds the per-agent
                          compressor to the round's critical path; the
                          quantity bought is uplink bytes, reported as
                          the compression ratio column)

Rows: ``engine,<name>,<ms/round>,<rel to baseline>,<uplink ratio>``.
"""

import time

import jax

from repro.configs import get_config
from repro.configs.base import InputShape
from repro.data.synthetic import make_batch_for
from repro.fed import runtime


def _bench_round(cfg, model, fcfg, iters):
    state = runtime.init_state(model, jax.random.PRNGKey(0), fcfg)
    step = jax.jit(runtime.make_train_step(model, fcfg))
    shape = InputShape("bench", 32, 8, "train")
    batch = make_batch_for(cfg, shape, n_agents=fcfg.n_agents)
    key = jax.random.PRNGKey(1)
    state, _ = step(state, batch, key)         # compile + warm-up
    jax.block_until_ready(state.x)
    t0 = time.perf_counter()
    for i in range(iters):
        state, m = step(state, batch, jax.random.fold_in(key, i))
    jax.block_until_ready(state.x)
    return (time.perf_counter() - t0) / iters * 1e3  # ms


def run(quick=True):
    iters = 3 if quick else 10
    cfg = get_config("gemma2-2b").reduced()
    from repro.models.model import build_model
    model = build_model(cfg)
    base = dict(n_agents=2, n_epochs=2, gamma=0.1)

    cases = [
        ("baseline", dict(), 1.0),
        ("pallas_fused", dict(use_pallas_update=True), 1.0),
        ("topk50", dict(compression="topk", compress_ratio=0.5), 2.0),
        ("topk25", dict(compression="topk", compress_ratio=0.25), 4.0),
        ("int8", dict(compression="int8"), 4.0),
    ]
    rows = []
    ms0 = None
    for name, kw, uplink in cases:
        fcfg = runtime.FedConfig(**base, **kw)
        ms = _bench_round(cfg, model, fcfg, iters)
        if ms0 is None:
            ms0 = ms
        rows.append(f"engine,{name},{ms:.1f},{ms / ms0:.2f}x,"
                    f"uplink/{uplink:.0f}")
    return rows
