"""Unified round-engine benchmark: fused vs unfused local epochs,
compressed vs uncompressed rounds, and the fused round-edge kernels.

Part 1 (rounds): times one jitted Fed-PLT round of a reduced
transformer through ``fed/runtime.py`` (i.e. through
``fed/engine.py``) for:

  * baseline           -- gd local epochs, exact z-exchange
  * pallas_fused       -- fedplt_update fused local step (NOTE: interpret
                          mode on this CPU container, so the fused number
                          is a correctness path, not TPU performance)
  * topk50 / int8      -- compressed z uplink (adds the per-agent
                          compressor to the round's critical path; the
                          quantity bought is uplink bytes, reported as
                          the compression ratio column)
  * pallas_edges       -- the fused round-edge backend end to end
  * packed_xla/pallas  -- the packed-resident state layout (engine
                          layout contract): (x, z, t) stay one
                          (N, M_total) buffer across rounds, so the
                          round pays ZERO pack/unpack traffic on the
                          state path (asserted by the structure rows
                          below and the CI smoke)

Part 1b (round structure): state-path op counts of one round --
concatenate / gather / dynamic_update_slice per (layout x backend) at
engine scale with an elementwise oracle, so the counts measure the
STATE path, not the model's forward/backward.  The committed baseline
asserts the packed rounds contain zero concatenates and that the
packed pallas round's update-slice count collapses to the oracle's
single pack.

Part 2 (round edges): the coordinator edge (prox + reflect; z-update +
participation selects) at ENGINE SCALE -- N >= 32 agents on a ragged
multi-leaf tree -- measured three ways:

  * per-backend edge wall time through ``engine.coordinator_edge`` /
    ``engine.agent_edge`` (the shipped paths; on this CPU container the
    packed path pays pack/unpack concatenation and interpret-emulation
    overhead that a TPU does not, so treat these as correctness-path
    numbers, like the other interpret-mode rows);
  * STRUCTURE: jaxpr ops of the XLA edge vs pallas_call launches of the
    fused edge -- the committed baseline asserts the coordinator edge
    collapses to TWO kernel launches;
  * LAUNCH-GRANULAR speedup: the edge arithmetic executed as one
    jitted launch per op per leaf (the xla backend's own granularity --
    the HBM round-trips + dispatches an unfused schedule pays between
    launches) vs the two fused kernels -- a real measurement of what
    the fusion removes, CPU-measurable because each jitted call is a
    genuine executable with genuine memory round-trips.  A second
    bracket (per-op launches on the already-packed buffer) isolates
    how much of the win is packing vs fusing.

``run`` returns ``(rows, payload)``: CSV rows plus the JSON-able dict
``benchmarks.run --json`` writes (committed baseline:
``BENCH_engine.json``), so future PRs can regress per-case wall times,
launch counts, and the launch-granular speedup.
"""

import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.configs.base import InputShape
from repro.core import prox as prox_lib
from repro.data.synthetic import make_batch_for
from repro.fed import engine
from repro.fed.api import CompressionSpec, FedSpec, build_trainer
from repro.kernels.round_edge import ops as edge_ops

# engine-scale round-edge case: agents x ragged transformer-like leaves
EDGE_N_AGENTS = 64
EDGE_WIDTHS = (1024, 256, 256, 64, 512, 512, 64, 16) * 25   # 200 leaves


def _best_ms(fn, args, iters, reps=3):
    out = fn(*args)
    jax.block_until_ready(out)
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        for _ in range(iters):
            out = fn(*args)
        jax.block_until_ready(out)
        best = min(best, (time.perf_counter() - t0) / iters * 1e3)
    return best


def _count_prims(jaxpr, name):
    return engine.count_primitives(jaxpr, [name])[name]


def _bench_round(cfg, model, spec, iters):
    trainer = build_trainer(model, spec)
    state = trainer.init(jax.random.PRNGKey(0))
    shape = InputShape("bench", 32, 8, "train")
    batch = make_batch_for(cfg, shape, n_agents=spec.n_agents)
    key = jax.random.PRNGKey(1)
    state, _ = trainer.step(state, batch, key)  # compile + warm-up
    jax.block_until_ready(state.x)
    t0 = time.perf_counter()
    for i in range(iters):
        state, m = trainer.step(state, batch, jax.random.fold_in(key, i))
    jax.block_until_ready(state.x)
    return (time.perf_counter() - t0) / iters * 1e3  # ms


def _rounds(quick):
    iters = 3 if quick else 10
    cfg = get_config("gemma2-2b").reduced()
    from repro.models.model import build_model
    model = build_model(cfg)
    base = dict(n_agents=2, n_epochs=2, gamma=0.1)

    cases = [
        ("baseline", dict(), 1.0),
        ("pallas_fused", dict(use_pallas=True), 1.0),
        # compress backends pinned to "xla": the CompressionSpec default
        # is now "auto", which would fold adaptive into adaptive_pallas
        # and make int8 width-dependent -- these rows track the per-leaf
        # path
        ("topk50", dict(compression=CompressionSpec(
            "topk", 0.5, backend="xla")), 2.0),
        ("topk25", dict(compression=CompressionSpec(
            "topk", 0.25, backend="xla")), 4.0),
        ("int8", dict(compression=CompressionSpec(
            "int8", backend="xla")), 4.0),
        ("adaptive", dict(compression=CompressionSpec(
            "adaptive_topk", ratio=0.25, energy=0.9,
            backend="xla")), 4.0),
        # same compressor through the packed fused-kernel path: one
        # launch for the whole pytree, one sort instead of two per leaf
        ("adaptive_pallas", dict(compression=CompressionSpec(
            "adaptive_topk", ratio=0.25, energy=0.9,
            backend="pallas")), 4.0),
        # heterogeneous groups: half the agents run AGD, half run one
        # cheap GD epoch -- measures the sequential group-dispatch cost
        ("hetero_gd_agd", dict(
            agent_groups="1*agd,1*gd:n_epochs=1"), 1.0),
        # fused round-edge backend end to end (weight decay exercises
        # the in-kernel prox)
        ("pallas_edges", dict(engine_backend="pallas",
                              weight_decay=0.01), 1.0),
        # packed-resident state layout: same rounds with (x, z, t) kept
        # as one (N, M_total) buffer -- packed_pallas is pallas_edges
        # minus every per-edge pack/unpack copy
        ("packed_xla", dict(state_layout="packed"), 1.0),
        ("packed_pallas", dict(state_layout="packed",
                               engine_backend="pallas",
                               weight_decay=0.01), 1.0),
    ]
    rows, payload = [], []
    ms0 = None
    for name, kw, uplink in cases:
        spec = FedSpec(**base, **kw)
        ms = _bench_round(cfg, model, spec, iters)
        if ms0 is None:
            ms0 = ms
        rows.append(f"engine,{name},{ms:.1f},{ms / ms0:.2f}x,"
                    f"uplink/{uplink:.0f}")
        payload.append(dict(kind="round", case=name, ms_per_round=ms,
                            rel_to_baseline=ms / ms0,
                            uplink_ratio=uplink))
    return rows, payload


def _round_structure():
    """State-path op counts of one full round per (layout x backend).

    Uses the engine-scale ragged tree with an ELEMENTWISE gradient
    oracle, so concatenate / gather / dynamic_update_slice counts
    measure the state path only (a real model's forward/backward adds
    its own value-path ops, identical across layouts).  The packed
    rows' zero concatenate count is the layout contract's headline
    property; the CI engine smoke asserts it from the committed JSON.
    """
    from repro.core.solvers import SolverConfig
    from repro.fed import compress as compress_lib
    from repro.fed.solvers import make_packed_local_solver

    n = 8
    tree = {f"l{i}": jnp.ones((n, w))
            for i, w in enumerate(EDGE_WIDTHS[:16])}
    meta = compress_lib.packed_meta(tree)
    buf, _ = compress_lib.pack_leaves(tree)

    def fgrad(w, k):
        return jax.tree_util.tree_map(lambda l: 0.1 * l, w)

    scfg = SolverConfig(name="gd", n_epochs=2, step_size=0.1)
    rows, payload = [], []
    for layout in ("tree", "packed"):
        for backend in ("xla", "pallas"):
            cfg = engine.RoundConfig(n_agents=n, rho=1.0, damping=0.5,
                                     participation=0.9,
                                     engine_backend=backend,
                                     state_layout=layout)
            if layout == "packed":
                solver = make_packed_local_solver(
                    scfg, fgrad, cfg.rho, 0.1, 1.0, meta=meta)
                jaxpr = jax.make_jaxpr(
                    lambda x, z, t, k: engine.packed_round_step(
                        cfg, meta, x, z, t, k, solver))(
                    buf, buf, buf, jax.random.PRNGKey(0)).jaxpr
            else:
                solver = engine.make_local_solver(scfg, fgrad, cfg.rho,
                                                  0.1, 1.0)
                jaxpr = jax.make_jaxpr(
                    lambda x, z, t, k: engine.round_step(
                        cfg, x, z, t, k, solver))(
                    tree, tree, tree, jax.random.PRNGKey(0)).jaxpr
            counts = engine.count_primitives(
                jaxpr, ["concatenate", "gather", "dynamic_update_slice"])
            rows.append(
                f"engine,structure:{layout}_{backend},"
                f"concat={counts['concatenate']},"
                f"gather={counts['gather']},"
                f"dus={counts['dynamic_update_slice']}")
            payload.append(dict(
                kind="round_structure", layout=layout, backend=backend,
                concatenate=counts["concatenate"],
                gather=counts["gather"],
                dynamic_update_slice=counts["dynamic_update_slice"],
                n_agents=n, n_leaves=len(tree)))
    return rows, payload


def _async_rounds(quick):
    """Async (bounded-staleness) rounds vs the synchronous round at
    engine scale: N=64 agents on the packed layout with an elementwise
    oracle, staleness bounds 0 / 2 / 8.  The async round adds only
    per-agent select/counter arithmetic on top of the synchronous edges
    (the arrival mask streams through the same downlink path as the
    participation mask), so these rows bound the steady-state cost of
    the staleness machinery itself -- the broker's wall-clock win from
    not blocking on stragglers is a host-side property benchmarks on
    synthetic latencies would only restate."""
    from repro.core.solvers import SolverConfig
    from repro.fed import async_engine
    from repro.fed import compress as compress_lib
    from repro.fed.solvers import make_packed_local_solver

    iters = 5 if quick else 20
    n = EDGE_N_AGENTS
    tree = {f"l{i}": jnp.ones((n, w))
            for i, w in enumerate(EDGE_WIDTHS[:16])}
    meta = compress_lib.packed_meta(tree)
    buf, _ = compress_lib.pack_leaves(tree)

    def fgrad(w, k):
        return jax.tree_util.tree_map(lambda l: 0.1 * l, w)

    cfg0 = engine.RoundConfig(n_agents=n, participation=0.7,
                              damping=0.5, state_layout="packed")
    scfg = SolverConfig(name="gd", n_epochs=2, step_size=0.1)
    solver = make_packed_local_solver(scfg, fgrad, cfg0.rho, 0.1, 1.0,
                                      meta=meta)
    key = jax.random.PRNGKey(0)
    m_total = int(meta.m_total)
    shape_s = f"N={n};m={m_total};leaves={len(tree)}"
    rows, payload = [], []

    sync_f = jax.jit(lambda x, z, t, k: engine.packed_round_step(
        cfg0, meta, x, z, t, k, solver))
    ms0 = _best_ms(sync_f, (buf, buf, buf, key), iters)
    rows.append(f"engine,async:sync_ref,{ms0:.2f},1.00x,{shape_s}")
    payload.append(dict(kind="async_round", case="sync_ref",
                        max_staleness=None, ms_per_round=ms0,
                        rel_to_sync=1.0, n_agents=n, m_total=m_total))

    staleness0 = async_engine.init_staleness(n)
    y_tag0 = jnp.zeros_like(buf)
    for K in (0, 2, 8):
        cfg = engine.RoundConfig(
            n_agents=n, participation=0.7, damping=0.5,
            state_layout="packed",
            staleness=engine.StalenessConfig(mode="stale",
                                             max_staleness=K))
        f = jax.jit(lambda x, z, t, yt, st, k, cfg=cfg:
                    async_engine.packed_async_round_step(
                        cfg, meta, x, z, t, yt, st, k, solver))
        ms = _best_ms(f, (buf, buf, buf, y_tag0, staleness0, key),
                      iters)
        rows.append(f"engine,async:stale_K{K},{ms:.2f},"
                    f"{ms / ms0:.2f}x,{shape_s}")
        payload.append(dict(kind="async_round", case=f"stale_K{K}",
                            max_staleness=K, ms_per_round=ms,
                            rel_to_sync=ms / ms0, n_agents=n,
                            m_total=m_total))
    return rows, payload


def _sharded(quick):
    """Weak scaling of the mesh-sharded packed round (ROADMAP item 2).

    One engine-scale packed round (elementwise oracle, pallas edges)
    per (devices, N) point at a fixed 512 agents PER SHARD: N=512 on 1
    device up to N=4096 on 8, plus the N=64 single-device baseline.
    Points needing more devices than are visible are skipped (the
    committed rows come from an
    ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` run).  On
    this single-core CPU container the host devices time-share one
    core, so ms/round GROWS with N here -- the weak-scaling flatness
    claim is about real multi-chip meshes; these rows pin the
    correctness path and the per-shard launch structure (exactly TWO
    fused edge launches per shard, asserted by the CI sharded smoke
    from the ``launches_per_shard`` field)."""
    import numpy as np
    from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

    from repro.core.solvers import SolverConfig
    from repro.fed import compress as compress_lib
    from repro.fed.solvers import make_packed_local_solver

    iters = 2 if quick else 8
    widths = EDGE_WIDTHS[:16]

    def fgrad(w, k):
        return jax.tree_util.tree_map(lambda l: 0.1 * l, w)

    scfg = SolverConfig(name="gd", n_epochs=2, step_size=0.1)
    n_dev = len(jax.devices())
    rows, payload = [], []

    # per-shard launch structure: TPU-shaped (interpret=False) trace of
    # the sharded edges -- the partial-sum uplink + presummed downlink
    mesh1 = Mesh(np.asarray(jax.devices()[:1]).reshape(1, 1),
                 ("agent", "model"))
    zt = jnp.zeros((8, 1024))

    def tpu_sharded_edges(x_, w_, z_, u_):
        y, v = edge_ops.round_uplink_sharded(z_, mesh=mesh1, n_total=8,
                                             rho_eff=0.125,
                                             interpret=False)
        xn, zn = edge_ops.round_downlink_sharded(x_, w_, z_, y, u_,
                                                 mesh=mesh1, damping=0.5,
                                                 interpret=False)
        return v, xn, zn

    launches = _count_prims(
        jax.make_jaxpr(tpu_sharded_edges)(zt, zt, zt,
                                          jnp.zeros((8,))).jaxpr,
        "pallas_call")
    rows.append(f"engine,sharded:structure,launches_per_shard={launches}")
    payload.append(dict(kind="sharded_structure",
                        launches_per_shard=launches))

    cases = [(64, 1)] + [(512 * d, d) for d in (1, 2, 4, 8)]
    ms0 = None
    for n, d in cases:
        name = f"n{n}_d{d}"
        if d > n_dev:
            rows.append(f"engine,sharded:{name},skipped,needs {d} devices")
            continue
        mesh = Mesh(np.asarray(jax.devices()[:d]).reshape(d, 1),
                    ("agent", "model"))
        tree = {f"l{i}": jnp.ones((n, w)) for i, w in enumerate(widths)}
        meta = compress_lib.packed_meta(tree)
        buf = jax.device_put(
            compress_lib.pack_leaves(tree)[0],
            NamedSharding(mesh, P("agent", None)))
        del tree
        solver = make_packed_local_solver(scfg, fgrad, 1.0, 0.1, 1.0,
                                          meta=meta)
        cfg = engine.RoundConfig(n_agents=n, participation=0.9,
                                 damping=0.5, state_layout="packed",
                                 engine_backend="pallas", agent_shards=d)
        f = jax.jit(lambda x, z, t, k, cfg=cfg, meta=meta,
                    solver=solver, mesh=mesh:
                    engine.packed_round_step(cfg, meta, x, z, t, k,
                                             solver, mesh=mesh))
        ms = _best_ms(f, (buf, buf, buf, jax.random.PRNGKey(0)), iters,
                      reps=2)
        if ms0 is None:
            ms0 = ms
        rows.append(f"engine,sharded:{name},{ms:.2f},{ms / ms0:.2f}x,"
                    f"N={n};devices={d};m={int(meta.m_total)}")
        payload.append(dict(kind="sharded_round", case=name, n_agents=n,
                            devices=d, ms_per_round=ms,
                            rel_to_first=ms / ms0,
                            per_shard_rows=n // d,
                            launches_per_shard=launches,
                            m_total=int(meta.m_total)))
    return rows, payload


def _robust_agg(quick):
    """Robust-aggregation uplink statistics (byzantine-robust PR).

    One jitted aggregate over an (N, width) z stack per (stat, backend,
    N) point: the plain survivor mean (the historical reduce, the
    baseline row), trimmed_mean(f=2) and coord_median through the XLA
    registry path and through the robust_agg sort kernel (interpret
    mode on this CPU container -- a correctness path, not TPU
    performance, like every other interpret-mode row here).  The
    quantity bought is the robustness statistic itself; the cost is the
    per-column sort replacing the single row reduce, so the ratio
    column reports each stat against the mean at the same N."""
    from repro.fed import robust
    from repro.kernels.robust_agg import ops as robust_ops

    iters = 2 if quick else 8
    width = 2048 if quick else 8192
    rows, payload = [], []
    key = jax.random.PRNGKey(0)

    def registry(name, param):
        return jax.jit(lambda v: robust.aggregate_rows(
            v, None, name=name, param=param, backend="xla"))

    for n in (64, 256, 1024):
        x = jax.random.normal(jax.random.fold_in(key, n), (n, width))
        cases = [
            ("mean", "xla", registry("mean", 0.0)),
            ("trimmed_mean_f2", "xla", registry("trimmed_mean", 2.0)),
            ("trimmed_mean_f2", "pallas",
             jax.jit(lambda v: robust_ops.robust_aggregate(
                 v, stat="trimmed_mean", trim=2))),
            ("coord_median", "xla", registry("coord_median", 0.0)),
            ("coord_median", "pallas",
             jax.jit(lambda v: robust_ops.robust_aggregate(
                 v, stat="coord_median"))),
        ]
        ms_mean = None
        for stat, backend, f in cases:
            ms = _best_ms(f, (x,), iters, reps=2)
            if ms_mean is None:
                ms_mean = ms
            name = f"{stat}_{backend}_n{n}"
            rows.append(f"engine,robust_agg:{name},{ms:.3f},"
                        f"{ms / ms_mean:.2f}x,N={n};m={width}")
            payload.append(dict(kind="robust_agg", case=name, stat=stat,
                                backend=backend, n_agents=n,
                                width=width, ms_per_agg=ms,
                                rel_to_mean=ms / ms_mean))
    return rows, payload


def _edge_trees():
    key = jax.random.PRNGKey(0)
    tree = {f"l{i}": jax.random.normal(jax.random.fold_in(key, i),
                                       (EDGE_N_AGENTS, w))
            for i, w in enumerate(EDGE_WIDTHS)}
    x = tree
    w = {k: 0.9 * v for k, v in tree.items()}
    z = {k: 1.1 * v for k, v in tree.items()}
    u = jax.random.bernoulli(key, 0.7,
                             (EDGE_N_AGENTS,)).astype(jnp.float32)
    return x, w, z, u


def _edges(backend, prox):
    cfg = engine.RoundConfig(n_agents=EDGE_N_AGENTS, rho=1.0,
                             damping=0.5, engine_backend=backend)

    def f(x, w, z, u):
        y, v = engine.coordinator_edge(cfg, z, z, prox)
        xn, zn = engine.agent_edge(cfg, u, w, x, z, y, z, prox)
        return v, xn, zn

    return f


def _round_edge(quick):
    iters = 5 if quick else 20
    prox = prox_lib.make_prox("weight_decay", weight=0.1)
    x, w, z, u = _edge_trees()
    m_total = int(sum(EDGE_WIDTHS))
    shape_s = f"N={EDGE_N_AGENTS};m={m_total};leaves={len(EDGE_WIDTHS)}"
    rows, payload = [], []

    # -- per-backend edge wall time + structure -------------------------
    # launch counts come from the TPU-shaped (interpret=False) trace --
    # abstract eval only, safe on CPU; the CPU default executes the same
    # kernel bodies directly when the grid is one program
    width = -(-m_total // 128) * 128
    zt = jnp.zeros((EDGE_N_AGENTS, width))
    ut = jnp.zeros((EDGE_N_AGENTS,))

    def tpu_edges(x_, w_, z_, u_):
        _, v = edge_ops.round_uplink(z_, prox=prox,
                                     rho_eff=1.0 / EDGE_N_AGENTS,
                                     interpret=False)
        xn, zn = edge_ops.round_downlink(x_, w_, z_, u_, prox=prox,
                                         rho_eff=1.0 / EDGE_N_AGENTS,
                                         damping=0.5, interpret=False)
        return v, xn, zn

    fused_launches = _count_prims(
        jax.make_jaxpr(tpu_edges)(zt, zt, zt, ut).jaxpr, "pallas_call")

    ms = {}
    for backend in ("xla", "pallas"):
        f = _edges(backend, prox)
        ms[backend] = _best_ms(jax.jit(f), (x, w, z, u), iters)
        n_ops = len(jax.make_jaxpr(f)(x, w, z, u).jaxpr.eqns)
        launches = fused_launches if backend == "pallas" else 0
        # distinct labels: "launches=" is the TPU-schedule pallas_call
        # count (a 0 here is a regression, never substituted), "ops="
        # the per-leaf path's jaxpr equation count
        detail = (f"launches={launches}" if backend == "pallas"
                  else f"ops={n_ops}")
        rows.append(f"engine,edge:{backend},{ms[backend]:.2f},"
                    f"{detail},{shape_s}")
        payload.append(dict(
            kind="edge", backend=backend, ms_per_edge_pair=ms[backend],
            pallas_launches=launches, jaxpr_ops=n_ops,
            n_agents=EDGE_N_AGENTS, m_total=m_total,
            n_leaves=len(EDGE_WIDTHS)))

    # -- packed-resident edges: the same fused kernels with the state
    # ALREADY resident in one (N, width) buffer (the packed layout's
    # round-to-round steady state) -- what the tree-layout pallas row
    # pays on top of this is pure pack/unpack traffic
    from repro.fed import compress as compress_lib

    meta = compress_lib.packed_meta(z)
    xb = compress_lib.pack_leaves(x)[0]
    wb = compress_lib.pack_leaves(w)[0]
    zb = compress_lib.pack_leaves(z)[0]
    pcfg = engine.RoundConfig(n_agents=EDGE_N_AGENTS, rho=1.0,
                              damping=0.5, engine_backend="pallas",
                              state_layout="packed")

    def packed_edges(x_, w_, z_, u_):
        y, v = engine.coordinator_edge_packed(pcfg, z_, z_, meta, prox)
        xn, zn = engine.agent_edge_packed(pcfg, u_, w_, x_, z_, y, z_,
                                          prox)
        return v, xn, zn

    ms_packed_res = _best_ms(jax.jit(packed_edges), (xb, wb, zb, u),
                             iters)
    rows.append(f"engine,edge:packed_pallas,{ms_packed_res:.2f},"
                f"launches={fused_launches},{shape_s}")
    payload.append(dict(
        kind="edge", backend="packed_pallas",
        ms_per_edge_pair=ms_packed_res,
        pallas_launches=fused_launches, jaxpr_ops=None,
        n_agents=EDGE_N_AGENTS, m_total=m_total,
        n_leaves=len(EDGE_WIDTHS)))

    # -- launch-granular: the unfused schedule (one jitted executable
    # per op = one launch + HBM round-trip each) vs the two fused
    # kernels.  Two unfused brackets: per-leaf per-op launches (the xla
    # backend's own granularity -- ~7 launches x n_leaves) and per-op
    # launches on the already-packed buffer (the launch floor an
    # unfused schedule could reach with packing but no fusion).
    key = jax.random.PRNGKey(1)
    zb = jax.random.normal(key, (EDGE_N_AGENTS, width))
    xb, wb = 0.9 * zb, 1.1 * zb
    rho_eff, damping = 1.0 / EDGE_N_AGENTS, 0.5

    mean_f = jax.jit(lambda z: jnp.mean(z, axis=0))
    prox_f = jax.jit(lambda zb_: prox(zb_, rho_eff))
    refl_f = jax.jit(lambda y, z: 2.0 * y[None] - z)
    zupd_f = jax.jit(lambda z, w_, y: z + 2.0 * damping * (w_ - y[None]))
    sel_f = jax.jit(lambda u_, a, b: jnp.where(
        (u_ != 0).reshape(-1, 1), a, b))

    def unfused_ops(x_, w_, z_, u_):
        zbar = mean_f(z_)
        y = prox_f(zbar)
        v = refl_f(y, z_)
        zu = zupd_f(z_, w_, y)
        return v, sel_f(u_, w_, x_), sel_f(u_, zu, z_)

    def unfused_per_leaf(x_, w_, z_, u_):
        return [unfused_ops(x_[k], w_[k], z_[k], u_) for k in z_]

    def fused(x_, w_, z_, u_):
        _, v = edge_ops.round_uplink(z_, prox=prox, rho_eff=rho_eff)
        xn, zn = edge_ops.round_downlink(x_, w_, z_, u_, prox=prox,
                                         rho_eff=rho_eff,
                                         damping=damping)
        return v, xn, zn

    ms_leaf = _best_ms(unfused_per_leaf, (x, w, z, u), iters)
    ms_packed = _best_ms(unfused_ops, (xb, wb, zb, u), iters)
    ms_fused = _best_ms(fused, (xb, wb, zb, u), iters)
    speedup = ms_leaf / ms_fused
    rows.append(f"engine,edge:launch_granular,{ms_fused:.2f},"
                f"{speedup:.2f}x,{shape_s}")
    payload.append(dict(
        kind="edge_launch",
        ms_unfused_per_leaf_launches=ms_leaf,
        ms_unfused_packed_launches=ms_packed,
        ms_fused_kernels=ms_fused, speedup=speedup,
        unfused_launches=7 * len(EDGE_WIDTHS), fused_launches=2,
        n_agents=EDGE_N_AGENTS, m_total=m_total,
        n_leaves=len(EDGE_WIDTHS)))
    return rows, payload


def run(quick=True):
    round_rows, round_payload = _rounds(quick)
    struct_rows, struct_payload = _round_structure()
    async_rows, async_payload = _async_rounds(quick)
    sharded_rows, sharded_payload = _sharded(quick)
    robust_rows, robust_payload = _robust_agg(quick)
    edge_rows, edge_payload = _round_edge(quick)
    payload = {"cases": (round_payload + struct_payload + async_payload
                         + sharded_payload + robust_payload
                         + edge_payload),
               "quick": bool(quick)}
    return (round_rows + struct_rows + async_rows + sharded_rows
            + robust_rows + edge_rows, payload)


if __name__ == "__main__":
    print("\n".join(run()[0]))
