"""Shared benchmark infrastructure for the paper-table reproductions.

The paper's set-up (Sec. VII): logistic regression, N=100 agents, n=5
features, q_i=250 samples, eps=0.5; convergence metric = computational
time (t_G per local gradient, t_C per communication round) to reach
||sum_i grad f_i(x_bar)||^2 <= 1e-5; results averaged over Monte-Carlo
seeds (paper: 100; quick mode: 3).
"""

from __future__ import annotations

import functools

import jax
import numpy as np

from repro.core import baselines
from repro.core.metrics import hitting_round
from repro.core.problem import make_logreg_problem
from repro.fed.api import FedSpec, PrivacySpec, build_trainer

N_AGENTS, DIM, Q, EPS = 100, 5, 250, 0.5


@functools.lru_cache(maxsize=8)
def paper_problem(nonconvex: bool = False, dim: int = DIM):
    return make_logreg_problem(n_agents=N_AGENTS, q=Q, dim=dim, eps=EPS,
                               nonconvex=nonconvex, seed=0)


def fedplt_runner(problem, n_epochs=5, rho=1.0, solver="gd",
                  participation=1.0, tau=0.0, batch_size=None,
                  step_size=None):
    spec = FedSpec(
        rho=rho, participation=participation, batch_size=batch_size,
        solver=solver, n_epochs=n_epochs, gamma=step_size,
        privacy=PrivacySpec(tau=tau),
        mu=0.05 if problem.nonconvex else None,
        L=4.0 if problem.nonconvex else None)
    trainer = build_trainer(problem, spec)

    def run(key, n_rounds):
        _, crit = trainer.run(key, n_rounds)
        return crit

    time_fn = lambda tG, tC: (n_epochs * tG + tC) * \
        problem.n_agents * participation
    return baselines.Algorithm("fedplt", run, time_fn)


# hyperparameters tuned per problem family (grid-searched offline; the
# paper likewise tunes each algorithm "to achieve the best performance")
def algorithm_suite(problem, n_epochs=5, participation=1.0):
    nc = problem.nonconvex
    # step sizes scaled by the problem's smoothness (tuned at L~=6.4 on
    # the paper's n=5 problem, transferred by the 1/L rule elsewhere)
    L = 4.0 if nc else problem.smoothness()
    g = (0.32 if nc else 0.64) / L
    g_lin = (0.96 if nc else 1.9) / L  # FedLin/FedPD tolerate larger steps
    suite = {
        "fedpd": baselines.make_fedpd(problem, eta=1.0, gamma=g_lin,
                                      n_epochs=n_epochs),
        "fedlin": baselines.make_fedlin(problem, gamma=g_lin,
                                        n_epochs=n_epochs),
        "led": baselines.make_led(problem, gamma=g, n_epochs=n_epochs),
        "5gcs": baselines.make_5gcs(problem, alpha=1.0, eta=1.0,
                                    n_epochs=n_epochs,
                                    participation=participation),
        "fedplt": fedplt_runner(problem, n_epochs=n_epochs,
                                participation=participation),
    }
    if not nc:  # TAMUNA is str-convex only (Table I)
        suite["tamuna"] = baselines.make_tamuna(
            problem, gamma=1.27 / L, p_comm=1.0 / n_epochs,
            participation=participation)
    return suite


def run_algo(algo, n_rounds, seeds=(0, 1, 2), t_G=1.0, t_C=10.0,
             per_step: bool = False, n_epochs=5):
    """Monte-Carlo averaged time-to-threshold (paper's metric)."""
    times, finals = [], []
    for s in seeds:
        crit = np.asarray(algo.run(jax.random.PRNGKey(s), n_rounds))
        k = hitting_round(crit)
        finals.append(float(crit[-1]))
        if k is None:
            times.append(np.nan)
        else:
            times.append(k * algo.time_per_round(t_G, t_C))
    t = float(np.nanmean(times)) if not np.all(np.isnan(times)) else None
    return {"time": t, "final": float(np.mean(finals)),
            "hit_rate": float(np.mean(~np.isnan(times)))}


def csv_row(table, name, result, extra=""):
    t = "-" if result["time"] is None else f"{result['time']:.4g}"
    return (f"{table},{name},{t},{result['final']:.3e},"
            f"{result['hit_rate']:.2f}{extra}")
