"""Table II: convergence-speed comparison, convex and nonconvex,
t_G = 1, t_C = 10, N_e = 5."""

from benchmarks.common import algorithm_suite, csv_row, paper_problem, run_algo

NE = 5


def run(quick=True):
    rows = []
    seeds = (0, 1, 2) if quick else tuple(range(20))
    for setting, nonconvex, rounds in [("convex", False, 400),
                                       ("nonconvex", True, 600)]:
        prob = paper_problem(nonconvex=nonconvex)
        for name, algo in algorithm_suite(prob, n_epochs=NE).items():
            n = rounds * NE if name == "tamuna" else rounds
            res = run_algo(algo, n, seeds=seeds, t_G=1.0, t_C=10.0)
            rows.append(csv_row(f"table2_{setting}", name, res))
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
