"""Table VI: Fed-PLT convergence vs participation percentage."""

from benchmarks.common import csv_row, fedplt_runner, paper_problem, run_algo

NE = 5


def run(quick=True):
    rows = []
    seeds = (0, 1, 2) if quick else tuple(range(20))
    prob = paper_problem()
    for pct in (0.4, 0.5, 0.6, 0.7, 0.8, 0.9, 1.0):
        algo = fedplt_runner(prob, n_epochs=NE, participation=pct)
        res = run_algo(algo, 800, seeds=seeds, t_G=1.0, t_C=10.0)
        rows.append(csv_row("table6", f"active{int(pct*100)}", res))
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
