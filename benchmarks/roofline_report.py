"""Render the dry-run JSON results into the EXPERIMENTS.md roofline table."""

import json
import sys


def fmt(x):
    return f"{x:.3e}" if isinstance(x, float) else str(x)


def render(results):
    lines = [
        "| arch | shape | mesh | status | compute_s | memory_s | "
        "collective_s | bottleneck | useful_ratio | params |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in results:
        if r["status"] != "ok":
            lines.append(
                f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                f"{r['status']} | - | - | - | - | - | - |")
            continue
        rl = r["roofline"]
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | ok | "
            f"{rl['compute_s']:.3e} | {rl['memory_s']:.3e} | "
            f"{rl['collective_s']:.3e} | {rl['bottleneck']} | "
            f"{rl['useful_ratio']:.3f} | {r['params']/1e9:.2f}B |")
    return "\n".join(lines)


def run(quick=True, path="dryrun_results.json"):
    try:
        with open(path) as f:
            results = json.load(f)
    except FileNotFoundError:
        return [f"roofline,skipped,no {path} (run repro.launch.dryrun "
                f"--all first)"]
    ok = sum(r["status"] == "ok" for r in results)
    return [f"roofline,cases_ok,{ok},of,{len(results)}"]


if __name__ == "__main__":
    path = sys.argv[1] if len(sys.argv) > 1 else "dryrun_results.json"
    with open(path) as f:
        print(render(json.load(f)))
