"""Beyond-paper: compressed z-exchange -- rounds-to-threshold and uplink
bytes vs compressor, on the paper's problem (dim=20 variant so top-k has
room to sparsify).  Construction goes through the front door
(:class:`repro.fed.api.FedSpec`); see ``compress_bench`` for the
registry-driven sweep over every registered compressor."""

import jax
import numpy as np

from repro.core.metrics import hitting_round
from repro.core.problem import make_logreg_problem
from repro.fed.api import CompressionSpec, FedSpec, build_trainer


def run(quick=True):
    rows = []
    prob = make_logreg_problem(n_agents=100, q=250, dim=20, seed=0)
    cases = [
        ("exact", CompressionSpec(), 32),           # bits per coordinate
        ("int8", CompressionSpec(name="int8"), 8),
        ("topk50", CompressionSpec(name="topk", ratio=0.5), 16),
        ("topk25", CompressionSpec(name="topk", ratio=0.25), 8),
        ("topk10", CompressionSpec(name="topk", ratio=0.1), 3.2),
    ]
    k_exact = None
    for name, comp, bits in cases:
        spec = FedSpec(rho=1.0, n_epochs=5, compression=comp)
        _, crit = build_trainer(prob, spec).run(jax.random.PRNGKey(0),
                                                1000)
        k = hitting_round(np.asarray(crit))
        if k_exact is None:
            k_exact = k
        if k is None:
            rows.append(f"compression,{name},-,"
                        f"{np.asarray(crit)[-1]:.3e},")
            continue
        uplink = k * bits / (k_exact * 32.0)
        rows.append(f"compression,{name},{k},"
                    f"{np.asarray(crit)[-1]:.3e},rel_uplink={uplink:.2f}")
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
