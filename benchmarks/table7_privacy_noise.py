"""Table VII: asymptotic error of privacy-preserving Fed-PLT vs noise
variance tau, plus the Prop. 4 / Cor. 1 theoretical counterparts."""

import jax
import numpy as np

from benchmarks.common import fedplt_runner, paper_problem
from repro.core import privacy, theory


def run(quick=True):
    rows = []
    prob = paper_problem()
    mu, L = prob.strong_convexity(), prob.smoothness()
    # stabilized parameters so the Cor.-1 theoretical column is finite
    stab = theory.stabilize(mu, L, n_epochs_grid=(5,))
    rho, ne, K = stab.rho, stab.n_epochs, 300
    gamma = stab.gamma
    for tau in (1e-6, 1e-5, 1e-4, 1e-3, 1e-2, 1e-1, 1.0, 10.0):
        algo = fedplt_runner(prob, n_epochs=ne, rho=rho,
                             solver="noisy_gd", tau=tau, step_size=gamma)
        crit = np.asarray(algo.run(jax.random.PRNGKey(0), K))
        asym_emp = float(np.sqrt(np.mean(crit[-50:])))
        asym_thy = theory.asymptotic_error(mu, L, rho, gamma, ne, tau,
                                           prob.dim, prob.n_agents)
        eps, lam = privacy.adp_epsilon(1.0, mu, tau, prob.q, gamma, K, ne,
                                       delta=1e-5)
        rows.append(
            f"table7,tau{tau:g},{asym_emp:.4g},{asym_thy:.4g},{eps:.4g}")
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
