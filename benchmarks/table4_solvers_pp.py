"""Table IV: local solvers (GD / accelerated GD) x partial participation
(100% vs 50% of agents), t_G = 1, t_C = 10."""

from benchmarks.common import (csv_row, fedplt_runner, paper_problem,
                               run_algo)
from repro.core import baselines

NE = 5


def run(quick=True):
    rows = []
    seeds = (0, 1, 2) if quick else tuple(range(20))
    prob = paper_problem()
    cases = {
        "fedplt_gd": fedplt_runner(prob, solver="gd", n_epochs=NE),
        "fedplt_gd_pp": fedplt_runner(prob, solver="gd", n_epochs=NE,
                                      participation=0.5),
        "fedplt_agd": fedplt_runner(prob, solver="agd", n_epochs=NE),
        "fedplt_agd_pp": fedplt_runner(prob, solver="agd", n_epochs=NE,
                                       participation=0.5),
        "5gcs_gd": baselines.make_5gcs(prob, eta=1.0, n_epochs=NE,
                                       participation=1.0),
        "5gcs_gd_pp": baselines.make_5gcs(prob, eta=1.0, n_epochs=NE,
                                          participation=0.5),
        "5gcs_agd": baselines.make_5gcs(prob, eta=1.0, n_epochs=NE,
                                        participation=1.0, solver="agd"),
        "5gcs_agd_pp": baselines.make_5gcs(prob, eta=1.0, n_epochs=NE,
                                           participation=0.5,
                                           solver="agd"),
        "tamuna": baselines.make_tamuna(prob, gamma=0.2, p_comm=1.0 / NE),
        "tamuna_pp": baselines.make_tamuna(prob, gamma=0.2,
                                           p_comm=1.0 / NE,
                                           participation=0.5),
    }
    for name, algo in cases.items():
        n = 800 * NE if name.startswith("tamuna") else 800
        res = run_algo(algo, n, seeds=seeds, t_G=1.0, t_C=10.0)
        rows.append(csv_row("table4", name, res))
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
