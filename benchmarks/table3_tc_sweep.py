"""Table III: convergence speed vs communication cost t_C (convex)."""

from benchmarks.common import algorithm_suite, csv_row, paper_problem, run_algo

NE = 5


def run(quick=True):
    rows = []
    seeds = (0, 1, 2) if quick else tuple(range(20))
    prob = paper_problem()
    suite = algorithm_suite(prob, n_epochs=NE)
    for t_C in (0.1, 1.0, 10.0, 100.0):
        for name, algo in suite.items():
            n = 400 * NE if name == "tamuna" else 400
            res = run_algo(algo, n, seeds=seeds, t_G=1.0, t_C=t_C)
            rows.append(csv_row(f"table3_tc{t_C}", name, res))
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
