"""Table VIII: Fed-PLT performance vs penalty rho (non-monotone;
best near rho = 1)."""

from benchmarks.common import csv_row, fedplt_runner, paper_problem, run_algo


def run(quick=True):
    rows = []
    seeds = (0, 1, 2) if quick else tuple(range(20))
    prob = paper_problem()
    for rho in (0.1, 1.0, 10.0):
        algo = fedplt_runner(prob, n_epochs=5, rho=rho)
        res = run_algo(algo, 2000, seeds=seeds, t_G=1.0, t_C=10.0)
        rows.append(csv_row("table8", f"rho{rho:g}", res))
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
