"""Proposition 4 privacy curves: RDP/ADP epsilon vs K*N_e, showing the
bounded privacy-loss ceiling (the paper's headline result)."""

from benchmarks.common import paper_problem
from repro.core import privacy


def run(quick=True):
    rows = []
    prob = paper_problem()
    mu = prob.strong_convexity()
    gamma, tau = 0.1, 0.1
    lam = 8.0
    ceiling = privacy.rdp_to_adp(
        privacy.rdp_epsilon_limit(lam, 1.0, mu, tau, prob.q), lam, 1e-5)
    for k in (1, 10, 100, 1000, 10000):
        for ne in (1, 5, 20):
            eps, _ = privacy.adp_epsilon(1.0, mu, tau, prob.q, gamma, k,
                                         ne, 1e-5)
            rows.append(f"privacy,K{k}_Ne{ne},{eps:.5g},"
                        f"ceiling,{ceiling:.5g}")
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
