"""Benchmark driver: one harness per paper table + kernel microbench.

Prints ``table,name,value...`` CSV rows (time-to-threshold in the paper's
(t_G, t_C) units, final criterion, hit rate).

  PYTHONPATH=src python -m benchmarks.run [--full] [--only table2,...]
                                          [--json PATH]

``--json PATH`` additionally writes a machine-readable dict of every
module that returned a structured payload (``run`` returning
``(rows, payload)`` instead of bare rows) -- the committed
``BENCH_compress.json`` baseline is produced by
``--only compress_bench --json BENCH_compress.json`` so future PRs can
regress per-case wall times and speedups.
"""

import argparse
import json
import sys
import time

from benchmarks import (compress_bench, engine_bench, kernel_bench,
                        privacy_bounds, roofline_report, table2_comparison,
                        table3_tc_sweep, table4_solvers_pp, table5_large_n,
                        table6_participation, table7_privacy_noise,
                        table8_rho, table9_ne)

MODULES = {
    "table2": table2_comparison,
    "table3": table3_tc_sweep,
    "table4": table4_solvers_pp,
    "table5": table5_large_n,
    "table6": table6_participation,
    "table7": table7_privacy_noise,
    "table8": table8_rho,
    "table9": table9_ne,
    "privacy": privacy_bounds,
    "compress_bench": compress_bench,
    "engine": engine_bench,
    "kernel": kernel_bench,
    "roofline": roofline_report,
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="more Monte-Carlo seeds (slower)")
    ap.add_argument("--only", default=None)
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write structured per-case results (wall "
                         "times, speedups, shapes) as JSON")
    args = ap.parse_args()
    only = set(args.only.split(",")) if args.only else None

    print("table,name,time_or_value,final_or_aux,extra")
    failures = 0
    payloads = {}
    for name, mod in MODULES.items():
        if only and name not in only:
            continue
        t0 = time.time()
        try:
            result = mod.run(quick=not args.full)
            rows, payload = (result if isinstance(result, tuple)
                             else (result, None))
            for row in rows:
                print(row)
            if payload is not None:
                payloads[name] = payload
        except Exception as e:  # noqa: BLE001
            failures += 1
            print(f"{name},ERROR,{type(e).__name__}: {e}")
        print(f"# {name} done in {time.time() - t0:.1f}s", file=sys.stderr)
    if args.json is not None:
        with open(args.json, "w") as f:
            json.dump(payloads, f, indent=2, sort_keys=True)
        print(f"# wrote {args.json}", file=sys.stderr)
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
