"""Benchmark driver: one harness per paper table + kernel microbench.

Prints ``table,name,value...`` CSV rows (time-to-threshold in the paper's
(t_G, t_C) units, final criterion, hit rate).

  PYTHONPATH=src python -m benchmarks.run [--full] [--only table2,...]
"""

import argparse
import sys
import time

from benchmarks import (compress_bench, compression_bench, engine_bench,
                        kernel_bench, privacy_bounds, roofline_report,
                        table2_comparison, table3_tc_sweep,
                        table4_solvers_pp, table5_large_n,
                        table6_participation, table7_privacy_noise,
                        table8_rho, table9_ne)

MODULES = {
    "table2": table2_comparison,
    "table3": table3_tc_sweep,
    "table4": table4_solvers_pp,
    "table5": table5_large_n,
    "table6": table6_participation,
    "table7": table7_privacy_noise,
    "table8": table8_rho,
    "table9": table9_ne,
    "privacy": privacy_bounds,
    "compression": compression_bench,
    "compress_bench": compress_bench,
    "engine": engine_bench,
    "kernel": kernel_bench,
    "roofline": roofline_report,
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="more Monte-Carlo seeds (slower)")
    ap.add_argument("--only", default=None)
    args = ap.parse_args()
    only = set(args.only.split(",")) if args.only else None

    print("table,name,time_or_value,final_or_aux,extra")
    failures = 0
    for name, mod in MODULES.items():
        if only and name not in only:
            continue
        t0 = time.time()
        try:
            for row in mod.run(quick=not args.full):
                print(row)
        except Exception as e:  # noqa: BLE001
            failures += 1
            print(f"{name},ERROR,{type(e).__name__}: {e}")
        print(f"# {name} done in {time.time() - t0:.1f}s", file=sys.stderr)
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
