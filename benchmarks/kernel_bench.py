"""Kernel micro-benchmarks.

On this CPU container the Pallas kernels run in interpret mode (Python),
so wall-times are NOT TPU performance; we report the XLA-path reference
implementations' wall time (what the models actually execute here) plus
derived bytes/FLOPs so the numbers are meaningful.
"""

import time

import jax

from repro.kernels.fedplt_update.ref import fedplt_update_ref
from repro.kernels.flash_attention.ref import flash_attention_ref
from repro.kernels.lru_scan.ref import lru_scan_ref


def _bench(fn, *args, iters=20):
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6  # us


def run(quick=True):
    rows = []
    key = jax.random.PRNGKey(0)

    n = 1 << 20
    w, g, v = (jax.random.normal(jax.random.fold_in(key, i), (n,))
               for i in range(3))
    f = jax.jit(lambda w, g, v: fedplt_update_ref(w, g, v, gamma=0.1,
                                                  inv_rho=1.0))
    us = _bench(f, w, g, v)
    rows.append(f"kernel,fedplt_update_ref_1M,{us:.1f},"
                f"GBps={3 * 4 * n / us / 1e3:.2f}")

    B, S, H, D = 1, 1024, 8, 64
    q = jax.random.normal(key, (B, S, H, D))
    k = jax.random.normal(key, (B, S, H, D))
    vv = jax.random.normal(key, (B, S, H, D))
    f = jax.jit(lambda q, k, v: flash_attention_ref(q, k, v, causal=True))
    us = _bench(f, q, k, vv, iters=5)
    fl = 4 * B * H * S * S * D
    rows.append(f"kernel,attention_ref_1k,{us:.1f},"
                f"GFLOPs={fl / us / 1e3:.2f}")

    a = jax.nn.sigmoid(jax.random.normal(key, (4, 2048, 256)))
    b = jax.random.normal(key, (4, 2048, 256))
    f = jax.jit(lru_scan_ref)
    us = _bench(f, a, b, iters=5)
    rows.append(f"kernel,lru_scan_ref_2k,{us:.1f},"
                f"GBps={2 * 4 * a.size / us / 1e3:.2f}")
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
