"""Table IX: Fed-PLT vs number of local epochs N_e across t_C -- the
paper's key observation: optimal N_e is finite and grows with t_C."""

from benchmarks.common import csv_row, fedplt_runner, paper_problem, run_algo


def run(quick=True):
    rows = []
    seeds = (0, 1, 2) if quick else tuple(range(20))
    prob = paper_problem()
    for ne in (1, 2, 5, 8, 10, 20):
        algo = fedplt_runner(prob, n_epochs=ne)
        for t_C in (0.1, 1.0, 10.0, 100.0):
            res = run_algo(algo, 2000, seeds=seeds, t_G=1.0, t_C=t_C)
            rows.append(csv_row(f"table9_tc{t_C}", f"ne{ne}", res))
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
