"""Registry- and backend-driven uplink-compression sweep.

Two parts (this harness absorbed the PR-1-era ``compression_bench``):

* **Convergence**: every compressor registered in
  :mod:`repro.fed.compress` runs the paper's dim-20 logreg problem
  through the :class:`repro.fed.api.FedSpec` front door --
  rounds-to-threshold, final criterion, measured keep fraction, and the
  relative uplink bytes the compressor buys (keep * value bits vs 32-bit
  exact exchange).

* **Perf**: ``compress_increment`` wall time, backend x compressor x
  shape -- per-leaf XLA registry path vs the packed
  :mod:`repro.kernels.compress` Pallas path (interpret mode on this CPU
  container), including the engine-scale ragged pytree (the reduced
  gemma2-2b leaf layout ``engine_bench`` rounds flatten).  The
  ``speedup`` column is XLA time / Pallas time for the same case.

``run`` returns ``(rows, payload)``: CSV rows plus the JSON-able dict
``benchmarks.run --json`` writes (committed baseline:
``BENCH_compress.json``), so future PRs can regress against per-case
wall times and speedups.

Rows::

  compress_bench,conv:<name>,<rounds-to-threshold>,<final criterion>,
      keep=..;uplink=..;ms=..
  compress_bench,perf:<case>:<name>:<backend>,<ms/call>,<speedup vs
      xla>,N=..;m=..;leaves=..
"""

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.metrics import hitting_round
from repro.core.problem import make_logreg_problem
from repro.fed.api import CompressionSpec, FedSpec, build_trainer
from repro.fed.compress import (PALLAS_COMPRESSORS, available_compressors,
                                compress_increment, get_compressor)
from repro.fed.engine import RoundConfig

# bits per transmitted value on the wire (topk adds ~log2(m) index bits,
# folded into the measured keep fraction's 32-bit values below)
_VALUE_BITS = {"int8": 8}

# leaf widths of the reduced gemma2-2b parameter tree -- the exact
# ragged pytree one engine_bench round compresses (engine-scale case)
_GEMMA2R_LEAVES = (131072, 256, 65536, 65536, 65536, 65536, 256, 256,
                   262144, 131072, 65536, 65536, 65536, 65536, 256, 256,
                   262144, 131072)

# perf sweep: (case name, n_agents, per-leaf widths)
_PERF_CASES = (
    ("dense100x256", 100, (256,)),
    ("wide8x65536", 8, (65536,)),
    ("engine_gemma2r", 2, _GEMMA2R_LEAVES),
)


def _convergence(quick):
    rows, payload = [], []
    prob = make_logreg_problem(n_agents=100, q=250, dim=20, seed=0)
    rounds = 600 if quick else 1000
    # measured keep fraction on a fixed probe increment: the sparsity an
    # actual uplink would exploit (int8 keeps everything but sends 8
    # bits; the keep column tracks sparsity only)
    probe = jax.random.normal(jax.random.PRNGKey(1),
                              (prob.n_agents, 256))
    k_exact = None
    names = available_compressors()
    # the exact exchange runs first: it is the rounds-to-threshold
    # baseline the rel_uplink column normalizes against
    names = ["none"] + [n for n in names if n != "none"]
    for name in names:
        comp = CompressionSpec(name=name, ratio=0.25, energy=0.9)
        trainer = build_trainer(
            prob, FedSpec(rho=1.0, n_epochs=5, compression=comp))
        t0 = time.perf_counter()
        _, crit = trainer.run(jax.random.PRNGKey(0), rounds)
        crit = np.asarray(crit)          # blocks on the scan
        ms = (time.perf_counter() - t0) / rounds * 1e3
        k = hitting_round(crit)
        rc = trainer.spec.round_config()
        kept = float(jnp.mean(get_compressor(name)(probe, rc) != 0.0))
        if name == "none":
            k_exact = k
        bits = _VALUE_BITS.get(name, 32.0 * kept)
        uplink = (k * bits / (k_exact * 32.0)
                  if k is not None and k_exact else None)
        up_s = f"{uplink:.2f}" if uplink is not None else "-"
        rows.append(f"compress_bench,conv:{name},{k if k else '-'},"
                    f"{crit[-1]:.3e},keep={kept:.2f};"
                    f"uplink={up_s};ms={ms:.2f}")
        payload.append(dict(kind="convergence", compressor=name,
                            rounds_to_threshold=k,
                            final_criterion=float(crit[-1]),
                            keep_fraction=kept, rel_uplink=uplink,
                            ms_per_round=ms))
    return rows, payload


def _time_compress(tree, cfg, iters):
    f = jax.jit(lambda t: compress_increment(t, cfg))
    out = f(tree)
    jax.block_until_ready(out)           # compile + warm-up
    t0 = time.perf_counter()
    for _ in range(iters):
        out = f(tree)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e3


def _perf(quick):
    rows, payload = [], []
    iters = 3 if quick else 10
    key = jax.random.PRNGKey(0)
    for case, n_agents, widths in _PERF_CASES:
        tree = {f"l{i}": jax.random.normal(jax.random.fold_in(key, i),
                                           (n_agents, w))
                for i, w in enumerate(widths)}
        m_total = int(sum(widths))
        for name in sorted(PALLAS_COMPRESSORS):
            ms = {}
            for backend in ("xla", "pallas"):
                cfg = RoundConfig(
                    n_agents=n_agents, compression=name,
                    compress_ratio=0.25, compress_energy=0.9,
                    compress_backend=backend)
                ms[backend] = _time_compress(tree, cfg, iters)
            speedup = ms["xla"] / ms["pallas"]
            for backend in ("xla", "pallas"):
                rel = speedup if backend == "pallas" else 1.0
                rows.append(
                    f"compress_bench,perf:{case}:{name}:{backend},"
                    f"{ms[backend]:.2f},{rel:.2f}x,"
                    f"N={n_agents};m={m_total};leaves={len(widths)}")
                payload.append(dict(
                    kind="perf", case=case, compressor=name,
                    backend=backend, n_agents=n_agents,
                    m_total=m_total, n_leaves=len(widths),
                    ms_per_call=ms[backend], speedup_vs_xla=rel))
    return rows, payload


def run(quick=True):
    conv_rows, conv_payload = _convergence(quick)
    perf_rows, perf_payload = _perf(quick)
    payload = {"cases": conv_payload + perf_payload,
               "quick": bool(quick)}
    return conv_rows + perf_rows, payload


if __name__ == "__main__":
    print("\n".join(run()[0]))
