"""Registry-driven uplink-compression sweep.

Unlike ``compression_bench`` (a fixed case list), this harness walks
EVERY compressor registered in :mod:`repro.fed.compress` -- including
the per-agent adaptive one and anything registered after this file was
written -- through the :class:`repro.fed.api.FedSpec` front door, so
BENCH output tracks the per-round cost of each uplink compressor as the
registry grows.

Rows: ``compress_bench,<name>,<rounds-to-threshold>,<final criterion>,
keep=<measured kept fraction>;ms=<ms per round>``.
"""

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.metrics import hitting_round
from repro.core.problem import make_logreg_problem
from repro.fed.api import CompressionSpec, FedSpec, build_trainer
from repro.fed.compress import available_compressors, get_compressor


def run(quick=True):
    rows = []
    prob = make_logreg_problem(n_agents=100, q=250, dim=20, seed=0)
    rounds = 600 if quick else 1000
    # measured keep fraction on a fixed probe increment: the sparsity an
    # actual uplink would exploit (int8 keeps everything but sends 8
    # bits; the keep column tracks sparsity only)
    probe = jax.random.normal(jax.random.PRNGKey(1),
                              (prob.n_agents, 256))
    for name in available_compressors():
        comp = CompressionSpec(name=name, ratio=0.25, energy=0.9)
        trainer = build_trainer(
            prob, FedSpec(rho=1.0, n_epochs=5, compression=comp))
        t0 = time.perf_counter()
        _, crit = trainer.run(jax.random.PRNGKey(0), rounds)
        crit = np.asarray(crit)          # blocks on the scan
        ms = (time.perf_counter() - t0) / rounds * 1e3
        k = hitting_round(crit)
        rc = trainer.spec.round_config()
        kept = float(jnp.mean(get_compressor(name)(probe, rc) != 0.0))
        rows.append(f"compress_bench,{name},{k if k else '-'},"
                    f"{crit[-1]:.3e},keep={kept:.2f};ms={ms:.2f}")
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
