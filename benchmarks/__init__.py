"""Benchmark harnesses: one per paper table (II-IX) + privacy curves,
kernel microbench, and the dry-run roofline report."""
