"""Byzantine-robust aggregation suite (ISSUE 10).

Three tiers, mirroring the other kernel/engine contracts:

* KERNEL, bitwise: the robust_agg Pallas sort-and-trim kernel == the
  ref.py oracle across stats, trims, live masks, sort implementations,
  and block realizations (the parity contract).
* ENGINE, bitwise: ``aggregator="mean"`` -- and ``trimmed_mean`` at
  ``f = 0``, which IS the mean -- is a bitwise no-op vs the historical
  trajectories across state_layout x engine_backend x compressor (the
  8-combo assert), tree and packed robust trajectories agree bitwise
  on real columns, and a 1-device mesh reproduces the unsharded robust
  round bit-for-bit.
* BREAKDOWN, behavioral: under a persistent sign-flip attack on 25% of
  the agents the trimmed-mean trajectory stays within tolerance of the
  clean fixed point while the plain mean is steered several times
  further away; property tests pin permutation invariance and the
  honest-row envelope guarantee (``f < N/2``).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.fedplt import FedPLT, FedPLTConfig
from repro.core.problem import make_quadratic_problem
from repro.core.solvers import SolverConfig
from repro.fed import async_engine
from repro.fed import compress as compress_lib
from repro.fed import engine, robust
from repro.fed.api import FedSpec, spec_from_args
from repro.fed.broker import IncrementBroker, replay
from repro.fed.faults import FaultPlan
from repro.fed.solvers import make_packed_local_solver
from repro.kernels.robust_agg import ops
from repro.kernels.robust_agg.ref import robust_aggregate_ref

multi_device = pytest.mark.skipif(
    len(jax.devices()) < 8,
    reason="needs 8 devices (XLA_FLAGS=--xla_force_host_platform_"
           "device_count=8)")


def _stack(seed, n, m, scale=1.0):
    return scale * jax.random.normal(jax.random.PRNGKey(seed), (n, m))


def _mesh(agents=1, model=1):
    from jax.sharding import Mesh

    devs = np.asarray(jax.devices()[:agents * model]).reshape(
        agents, model)
    return Mesh(devs, ("agent", "model"))


# ---------------------------------------------------------------------------
# Kernel tier: pallas kernel vs ref oracle, bitwise
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n,m", [(1, 5), (4, 128), (8, 300), (17, 64),
                                 (64, 129)])
@pytest.mark.parametrize("stat,trim", [("trimmed_mean", 0),
                                       ("trimmed_mean", 2),
                                       ("coord_median", 0)])
def test_kernel_matches_ref_bitwise(n, m, stat, trim):
    if 2 * trim >= n:
        pytest.skip("trim larger than the stack")
    x = _stack(n * m + trim, n, m)
    live = None
    if n >= 4:   # evict some rows; order stats must skip them
        live = np.ones(n, np.float32)
        live[:: max(n // 3, 1)] = 0.0
    want = jax.jit(robust_aggregate_ref,
                   static_argnames=("stat", "trim"))(x, live, stat=stat,
                                                     trim=trim)
    for sort_impl in ("xla", "bitonic"):
        for bc in (16, 256):
            got = ops.robust_aggregate(x, live, stat=stat, trim=trim,
                                       sort_impl=sort_impl,
                                       block_cols=bc)
            np.testing.assert_array_equal(
                np.asarray(got), np.asarray(want),
                err_msg=f"{stat} trim={trim} {sort_impl} bc={bc}")


def test_kernel_semantics_vs_numpy():
    """The sorted-selection arithmetic against a plain numpy oracle
    (allclose: numpy reduces in a different association)."""
    rng = np.random.default_rng(0)
    x = rng.normal(size=(9, 37)).astype(np.float32)
    live = np.ones(9, np.float32)
    live[[2, 5]] = 0.0
    rows = x[live == 1.0]
    got_tm = np.asarray(ops.robust_aggregate(x, live, stat="trimmed_mean",
                                             trim=2))
    want_tm = np.sort(rows, axis=0)[2:-2].mean(axis=0, keepdims=True)
    np.testing.assert_allclose(got_tm, want_tm, rtol=1e-6, atol=1e-7)
    got_md = np.asarray(ops.robust_aggregate(x, live,
                                             stat="coord_median"))
    want_md = np.median(rows, axis=0, keepdims=True)
    np.testing.assert_allclose(got_md, want_md, rtol=1e-6, atol=1e-7)


def test_kernel_rejects_bad_inputs():
    x = _stack(0, 4, 8)
    with pytest.raises(ValueError, match="unknown robust stat"):
        ops.robust_aggregate(x, stat="mode")
    with pytest.raises(ValueError, match=r"\(N, M\) buffers"):
        ops.robust_aggregate(jnp.zeros((4,)), stat="coord_median")
    with pytest.raises(ValueError, match="unknown robust stat"):
        robust_aggregate_ref(x, stat="mode")


# ---------------------------------------------------------------------------
# Registry + validation
# ---------------------------------------------------------------------------

def test_registry_contents_and_errors():
    assert set(robust.available_aggregators()) >= {
        "mean", "trimmed_mean", "coord_median", "norm_clip_mean"}
    with pytest.raises(ValueError, match="unknown aggregator"):
        robust.get_aggregator("geometric_median")
    with pytest.raises(ValueError, match="non-negative integer"):
        robust.validate_aggregator("trimmed_mean", 1.5)
    with pytest.raises(ValueError, match="2f < N"):
        robust.validate_aggregator("trimmed_mean", 2, n_agents=4)
    with pytest.raises(ValueError, match="clip radius"):
        robust.validate_aggregator("norm_clip_mean", 0.0)
    with pytest.raises(ValueError, match="clip radius"):
        robust.validate_aggregator("norm_clip_mean", float("inf"))
    assert robust.validate_aggregator("trimmed_mean", 2,
                                      n_agents=8) == 2.0
    assert robust.validate_aggregator("mean", 0.0) == 0.0


def test_spec_and_config_threading():
    spec = spec_from_args(["--aggregator", "trimmed_mean",
                           "--aggregator-param", "2",
                           "--n-agents", "8"]).validate()
    cfg = spec.round_config()
    assert cfg.aggregator == "trimmed_mean"
    assert cfg.aggregator_param == 2.0
    assert cfg.robust_aggregator == "trimmed_mean"
    dense = spec.to_dense_config()
    assert dense.aggregator == "trimmed_mean"
    assert dense.to_spec(8).aggregator == "trimmed_mean"
    with pytest.raises(ValueError, match="2f < N"):
        FedSpec(n_agents=4, aggregator="trimmed_mean",
                aggregator_param=2).validate()
    with pytest.raises(ValueError, match="unknown aggregator"):
        engine.RoundConfig(n_agents=4, aggregator="nope")
    # f = 0 IS the mean: the dispatch must resolve to the historical path
    assert engine.RoundConfig(
        n_agents=4, aggregator="trimmed_mean",
        aggregator_param=0.0).robust_aggregator is None
    assert engine.RoundConfig(n_agents=4).robust_aggregator is None


def test_mean_keeps_object_identity():
    """The mean path must return z_seen ITSELF (live=None): downstream
    lagged-path dispatch keys on ``z_seen is z``."""
    cfg = engine.RoundConfig(n_agents=4)
    z = {"a": _stack(0, 4, 8)}
    assert engine.robust_seen(cfg, z, None) is z
    cfg0 = engine.RoundConfig(n_agents=4, aggregator="trimmed_mean",
                              aggregator_param=0.0)
    assert engine.robust_seen(cfg0, z, None) is z


# ---------------------------------------------------------------------------
# Engine tier: the 8-combo bitwise no-op + robust layout parity
# ---------------------------------------------------------------------------

def _tree_state(n=8):
    key = jax.random.PRNGKey(3)
    return {"a": jax.random.normal(key, (n, 5)),
            "b": jax.random.normal(jax.random.fold_in(key, 1), (n, 3, 3))}


def _fgrad(w, k):
    return jax.tree_util.tree_map(lambda l: 0.1 * l, w)


_SCFG = SolverConfig(name="gd", n_epochs=2, step_size=0.1)


def _tree_solver():
    return engine.make_local_solver(_SCFG, _fgrad, 1.0, 0.1, 1.0)


def _packed_solver(meta):
    return make_packed_local_solver(_SCFG, _fgrad, 1.0, 0.1, 1.0,
                                    meta=meta)


def _run_rounds(cfg, state, solver, rounds=3, meta=None):
    x = z = t = state
    key = jax.random.PRNGKey(7)
    for _ in range(rounds):
        if meta is None:
            res = engine.round_step(cfg, x, z, t, key, solver)
        else:
            res = engine.packed_round_step(cfg, meta, x, z, t, key,
                                           solver)
        x, z, t, key = res.x, res.z, res.t, res.next_key
    return res


COMBOS = [(layout, backend, compression)
          for layout in ("tree", "packed")
          for backend in ("xla", "pallas")
          for compression in ("none", "topk")]


@pytest.mark.parametrize("layout,backend,compression", COMBOS)
def test_mean_is_bitwise_noop_8_combos(layout, backend, compression):
    """trimmed_mean(f=0) resolves to the mean dispatch, so its
    trajectories must equal the default config BIT FOR BIT on every
    layout x backend x compressor combo -- the robust layer leaves the
    historical graph untouched unless a real statistic is selected."""
    kw = dict(n_agents=8, engine_backend=backend, state_layout=layout,
              compression=compression, compress_ratio=0.5)
    tree = _tree_state()
    if layout == "packed":
        buf, meta = compress_lib.pack_leaves(tree)
        base = _run_rounds(engine.RoundConfig(**kw), buf,
                           _packed_solver(meta), meta=meta)
        rob = _run_rounds(
            engine.RoundConfig(aggregator="trimmed_mean",
                               aggregator_param=0.0, **kw),
            buf, _packed_solver(meta), meta=meta)
    else:
        base = _run_rounds(engine.RoundConfig(**kw), tree,
                           _tree_solver())
        rob = _run_rounds(
            engine.RoundConfig(aggregator="trimmed_mean",
                               aggregator_param=0.0, **kw),
            tree, _tree_solver())
    for a, b in zip(jax.tree_util.tree_leaves(base._asdict()),
                    jax.tree_util.tree_leaves(rob._asdict())):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.parametrize("aggregator,param", [("trimmed_mean", 2),
                                              ("coord_median", 0),
                                              ("norm_clip_mean", 0.7)])
def test_robust_seen_tree_packed_aggregate_bitwise(aggregator, param):
    """The aggregated z_seen itself is BITWISE identical between the
    tree and packed entry points: both reduce per column through the
    same registry function on the same packed values."""
    tree = _tree_state()
    buf, meta = compress_lib.pack_leaves(tree)
    live = jnp.asarray([1, 1, 0, 1, 1, 1, 1, 1], jnp.float32)
    st = robust.robust_seen_tree(tree, live, name=aggregator,
                                 param=param, backend="xla")
    sp = robust.robust_seen_packed(buf, live, name=aggregator,
                                   param=param, meta=meta,
                                   backend="xla")
    zt = compress_lib.pack_leaves(st)[0]
    mask = np.zeros(meta.width, bool)
    for a, b in meta.segments:
        mask[a:b] = True
    np.testing.assert_array_equal(np.asarray(zt)[:, mask],
                                  np.asarray(sp)[:, mask])


@pytest.mark.parametrize("aggregator,param", [("trimmed_mean", 2),
                                              ("coord_median", 0),
                                              ("norm_clip_mean", 0.7)])
@pytest.mark.parametrize("backend", ["xla", "pallas"])
def test_robust_tree_packed_parity(aggregator, param, backend):
    """Tree- and packed-resident robust trajectories agree to f32
    rounding on the real (non-padding) columns, under both engine
    backends.  The aggregate is bitwise (previous test); the multi-round
    trajectories are only ulp-tight because the robust broadcast shifts
    XLA's fusion boundaries, and CPU instruction selection (FMA vs
    mul+add) may then differ between the two compiled layouts."""
    tree = _tree_state()
    buf, meta = compress_lib.pack_leaves(tree)
    kw = dict(n_agents=8, engine_backend=backend, aggregator=aggregator,
              aggregator_param=param)
    rt = _run_rounds(engine.RoundConfig(state_layout="tree", **kw),
                     tree, _tree_solver())
    rp = _run_rounds(engine.RoundConfig(state_layout="packed", **kw),
                     buf, _packed_solver(meta), meta=meta)
    mask = np.zeros(meta.width, bool)
    for a, b in meta.segments:
        mask[a:b] = True
    for field in ("x", "z", "t"):
        zt = compress_lib.pack_leaves(getattr(rt, field))[0]
        zp = getattr(rp, field)
        np.testing.assert_allclose(
            np.asarray(zt)[:, mask], np.asarray(zp)[:, mask],
            rtol=1e-6, atol=1e-7,
            err_msg=f"{field} {aggregator} {backend}")


@multi_device
@pytest.mark.parametrize("aggregator,param", [("trimmed_mean", 2),
                                              ("coord_median", 0)])
def test_robust_mesh_of_one_is_bitwise(aggregator, param):
    """A 1-device mesh runs the all-gather robust path, whose gather of
    one shard is the identity -- trajectories must equal the unsharded
    engine bit-for-bit (the degenerate-case contract)."""
    tree = _tree_state()
    buf, meta = compress_lib.pack_leaves(tree)
    kw = dict(n_agents=8, state_layout="packed", aggregator=aggregator,
              aggregator_param=param)
    base = _run_rounds(engine.RoundConfig(**kw), buf, _packed_solver(meta),
                       meta=meta)
    key = jax.random.PRNGKey(7)
    x = z = t = buf
    with _mesh(1, 1) as mesh:
        for _ in range(3):
            res = engine.packed_round_step(
                engine.RoundConfig(**kw), meta, x, z, t, key,
                _packed_solver(meta), mesh=mesh)
            x, z, t, key = res.x, res.z, res.t, res.next_key
    for field in ("x", "z", "t", "y"):
        np.testing.assert_array_equal(
            np.asarray(getattr(base, field)),
            np.asarray(getattr(res, field)), err_msg=field)


@multi_device
def test_robust_multi_device_mesh_close():
    """An 8-way agent mesh all-gathers real shards; the order statistic
    itself is deterministic, so trajectories match the unsharded run to
    f32 rounding (the downstream psum combine order is not bitwise)."""
    tree = _tree_state()
    buf, meta = compress_lib.pack_leaves(tree)
    kw = dict(n_agents=8, state_layout="packed",
              aggregator="trimmed_mean", aggregator_param=2)
    base = _run_rounds(engine.RoundConfig(**kw), buf, _packed_solver(meta),
                       meta=meta)
    key = jax.random.PRNGKey(7)
    x = z = t = buf
    with _mesh(8, 1) as mesh:
        for _ in range(3):
            res = engine.packed_round_step(
                engine.RoundConfig(agent_shards=8, **kw), meta, x, z, t,
                key, _packed_solver(meta), mesh=mesh)
            x, z, t, key = res.x, res.z, res.t, res.next_key
    for field in ("x", "z", "t", "y"):
        np.testing.assert_allclose(
            np.asarray(getattr(base, field)),
            np.asarray(getattr(res, field)), rtol=1e-5, atol=1e-6,
            err_msg=field)


def test_async_engine_takes_robust_aggregator():
    """The async round consumes the same robust z_seen transform, and
    at f=0 the trimmed dispatch resolves to the historical async round
    bitwise (same graph, not merely close)."""
    tree = _tree_state()
    stale = engine.StalenessConfig(mode="stale", max_staleness=2)
    y_tag = async_engine.init_y_tag(tree)
    s0 = async_engine.init_staleness(8)
    key = jax.random.PRNGKey(0)
    res = async_engine.async_round_step(
        engine.RoundConfig(n_agents=8, aggregator="coord_median",
                           staleness=stale),
        tree, tree, tree, y_tag, s0, key, _tree_solver())
    for l in jax.tree_util.tree_leaves(res.y):
        assert bool(jnp.isfinite(l).all())
    base = async_engine.async_round_step(
        engine.RoundConfig(n_agents=8, staleness=stale),
        tree, tree, tree, y_tag, s0, key, _tree_solver())
    trim0 = async_engine.async_round_step(
        engine.RoundConfig(n_agents=8, aggregator="trimmed_mean",
                           aggregator_param=0.0, staleness=stale),
        tree, tree, tree, y_tag, s0, key, _tree_solver())
    for a, b in zip(jax.tree_util.tree_leaves(base._asdict()),
                    jax.tree_util.tree_leaves(trim0._asdict())):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# Breakdown tier: the sign-flip attack (the acceptance scenario)
# ---------------------------------------------------------------------------

def _attack_run(aggregator, param, corrupt, rounds=60):
    quad = make_quadratic_problem(n_agents=8, dim=8, seed=3)
    algo = FedPLT(quad, FedPLTConfig(
        solver=SolverConfig(name="gd", n_epochs=2, step_size=0.05),
        damping=0.7, aggregator=aggregator, aggregator_param=param))
    s = algo.init(jax.random.PRNGKey(0))
    for _ in range(rounds):
        s, _ = algo.round_with_faults(s, None, corrupt, None)
    return np.asarray(s.y)


def test_sign_flip_attack_mean_diverges_trimmed_survives():
    """Sign-flip on 25% of the agents (2 of 8): finite and in-norm, so
    the guards cannot see it.  The plain mean is steered several times
    the clean scale away from the clean fixed point; trimmed_mean(f=2)
    stays within tolerance of it.  The acceptance scenario."""
    corrupt = np.zeros(8, np.float32)
    corrupt[:2] = -1.0                      # w -> -w for agents 0, 1
    corrupt = jnp.asarray(corrupt)
    y_clean = _attack_run("mean", 0.0, None)
    y_mean = _attack_run("mean", 0.0, corrupt)
    y_trim = _attack_run("trimmed_mean", 2, corrupt)
    scale = float(np.linalg.norm(y_clean))
    err_mean = float(np.linalg.norm(y_mean - y_clean))
    err_trim = float(np.linalg.norm(y_trim - y_clean))
    # trimmed-mean converges within tolerance of the clean run ...
    assert err_trim < 2.0 * scale, (err_trim, scale)
    # ... the mean does not (steered several times the clean scale) ...
    assert err_mean > 5.0 * scale, (err_mean, scale)
    # ... and the robust run is several times closer than the mean
    assert err_mean > 3.0 * err_trim, (err_mean, err_trim)


def test_byzantine_broker_end_to_end_with_replay():
    """FaultPlan byzantine events -> broker-realized (N, 2) rows ->
    robust survival, with the recording replaying bit-for-bit."""
    quad = make_quadratic_problem(n_agents=8, dim=8, seed=3)
    plan = FaultPlan.generate(5, 8, 40, n_byzantine=2,
                              byzantine_kind="sign_flip")
    assert plan.has_byzantine

    def build(aggregator, param):
        algo = FedPLT(quad, FedPLTConfig(
            solver=SolverConfig(name="gd", n_epochs=2, step_size=0.05),
            damping=0.7, async_mode="stale", max_staleness=0,
            aggregator=aggregator, aggregator_param=param))
        return algo, lambda s, u, c, l: algo.round_with_faults(
            s, u, c, l)[0]

    algo, step = build("trimmed_mean", 2)
    broker = IncrementBroker(8, max_staleness=0, seed=11)
    state0 = algo.init(jax.random.PRNGKey(0))
    s_rob, sched = broker.run(step, state0, n_rounds=40, faults=plan)
    rows = [broker.record.corrupt_row(r) for r in range(40)]
    assert all(r is not None and r.shape == (8, 2) for r in rows)

    # replay the recording: bit-for-bit
    s_replay = replay(step, state0, sched, record=broker.record)
    np.testing.assert_array_equal(np.asarray(s_rob.y),
                                  np.asarray(s_replay.y))

    # same attack through the plain mean: steered several times further
    algo_m, step_m = build("mean", 0.0)
    broker_m = IncrementBroker(8, max_staleness=0, seed=11)
    s_mean, _ = broker_m.run(step_m, algo_m.init(jax.random.PRNGKey(0)),
                             n_rounds=40, faults=plan)
    algo_c, step_c = build("mean", 0.0)
    broker_c = IncrementBroker(8, max_staleness=0, seed=11)
    s_clean, _ = broker_c.run(step_c,
                              algo_c.init(jax.random.PRNGKey(0)),
                              n_rounds=40)
    y_clean = np.asarray(s_clean.y)
    err_rob = np.linalg.norm(np.asarray(s_rob.y) - y_clean)
    err_mean = np.linalg.norm(np.asarray(s_mean.y) - y_clean)
    assert err_mean > 2.0 * err_rob, (err_mean, err_rob)


# ---------------------------------------------------------------------------
# Property tests (hypothesis; conftest ships a deterministic stub)
# ---------------------------------------------------------------------------

@settings(max_examples=12, deadline=None)
@given(seed=st.integers(min_value=0, max_value=10_000),
       n=st.sampled_from([4, 7, 8, 16]),
       stat=st.sampled_from(["trimmed_mean", "coord_median"]))
def test_order_stats_are_permutation_invariant(seed, n, stat):
    """Agent order cannot matter: the sort erases it EXACTLY (bitwise),
    live mask permuted along."""
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(n, 33)).astype(np.float32)
    live = (rng.random(n) > 0.25).astype(np.float32)
    if live.sum() == 0:
        live[0] = 1.0
    perm = rng.permutation(n)
    trim = 1 if (stat == "trimmed_mean" and n > 2) else 0
    a = robust_aggregate_ref(jnp.asarray(x), live, stat=stat, trim=trim)
    b = robust_aggregate_ref(jnp.asarray(x[perm]), live[perm],
                             stat=stat, trim=trim)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@settings(max_examples=12, deadline=None)
@given(seed=st.integers(min_value=0, max_value=10_000),
       n=st.sampled_from([4, 8, 16]))
def test_trimmed_f0_is_the_mean(seed, n):
    """trimmed_mean at f=0 averages every live row -- equal to the
    survivor mean to f32 rounding (BITWISE equality is guaranteed one
    level up: RoundConfig resolves f=0 to the exact mean dispatch,
    asserted in the 8-combo test)."""
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(n, 17)).astype(np.float32)
    a = np.asarray(robust.aggregate_rows(jnp.asarray(x), None,
                                         name="trimmed_mean", param=0.0))
    b = np.asarray(robust.aggregate_rows(jnp.asarray(x), None,
                                         name="mean", param=0.0))
    np.testing.assert_allclose(a, b, rtol=1e-6, atol=1e-7)


@settings(max_examples=12, deadline=None)
@given(seed=st.integers(min_value=0, max_value=10_000),
       n=st.sampled_from([5, 8, 16]),
       f=st.sampled_from([1, 2]),
       median=st.booleans())
def test_honest_envelope_breakdown_guarantee(seed, n, f, median):
    """With c corrupt rows, c <= f (trimmed) or c < N/2 (median), the
    aggregate of every column lies inside the honest rows' [min, max]
    envelope -- the breakdown guarantee that makes finite adversarial
    values harmless."""
    if 2 * f >= n:
        return
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(n, 21)).astype(np.float32)
    c = f if not median else max(1, (n - 1) // 2)
    corrupt_rows = rng.choice(n, size=c, replace=False)
    x[corrupt_rows] = rng.choice(
        [-1e6, 1e6, 3.0], size=(c, 21)).astype(np.float32)
    honest = np.delete(x, corrupt_rows, axis=0)
    lo = honest.min(axis=0) - 1e-4
    hi = honest.max(axis=0) + 1e-4
    if median:
        out = robust_aggregate_ref(jnp.asarray(x), None,
                                   stat="coord_median")
    else:
        out = robust_aggregate_ref(jnp.asarray(x), None,
                                   stat="trimmed_mean", trim=f)
    out = np.asarray(out)[0]
    assert np.all(out >= lo) and np.all(out <= hi), (
        out.min(), out.max(), lo.min(), hi.max())


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(min_value=0, max_value=10_000),
       attack=st.booleans())
def test_norm_clip_stays_within_radius_of_center(seed, attack):
    """norm_clip_mean = center + mean of per-row residuals clipped to
    l2 norm <= radius, so the aggregate can never leave the radius-ball
    around the coordinate-median center -- no matter how wild the
    corrupt rows are (the clipping bound an adversary cannot beat)."""
    rng = np.random.default_rng(seed)
    n, m, radius = 8, 13, 0.5
    x = rng.normal(size=(n, m)).astype(np.float32)
    if attack:
        x[:3] = rng.choice([-1e6, 1e6], size=(3, m)).astype(np.float32)
    center = np.asarray(robust.aggregate_rows(
        jnp.asarray(x), None, name="coord_median", param=0.0))
    out = np.asarray(robust.aggregate_rows(
        jnp.asarray(x), None, name="norm_clip_mean", param=radius))
    assert np.linalg.norm(out - center) <= radius * (1.0 + 1e-5), \
        np.linalg.norm(out - center)
