"""Per-architecture smoke tests (REDUCED configs: 2 layers, d_model<=512,
<=4 experts): one forward/train step + one decode step on CPU, asserting
output shapes and no NaNs.  Full configs are exercised only via dryrun."""

import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.models import frontends
from repro.models.model import build_model

KEY = jax.random.PRNGKey(0)


def make_batch(cfg, B=2, S=32, with_labels=True):
    batch = {"tokens": jax.random.randint(KEY, (B, S), 0, cfg.vocab)}
    if with_labels:
        batch["labels"] = jax.random.randint(
            jax.random.PRNGKey(7), (B, S), 0, cfg.vocab)
    if cfg.n_enc_layers:
        batch["enc_embeds"] = frontends.fake_audio_frames(KEY, cfg, B)
    if cfg.frontend == "vision":
        batch["patch_embeds"] = frontends.fake_patch_embeds(KEY, cfg, B)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_and_train_step(arch):
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(1))
    batch = make_batch(cfg)
    logits, aux = model.forward(params, batch=batch)
    S_total = 32 + (cfg.n_frontend_tokens if cfg.frontend == "vision"
                    else 0)
    assert logits.shape == (2, S_total, cfg.vocab)
    assert not bool(jnp.isnan(logits).any())

    loss, grads = jax.value_and_grad(
        lambda p: model.loss_fn(p, batch=batch))(params)
    assert jnp.isfinite(loss)
    gnorm = sum(jnp.sum(jnp.square(g)) for g in
                jax.tree_util.tree_leaves(grads))
    assert jnp.isfinite(gnorm) and gnorm > 0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_step(arch):
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(1))
    B = 2
    cache = model.init_cache(batch=B, cache_len=64)
    toks = jnp.array([1, 2], jnp.int32)
    step = jax.jit(lambda p, c, t: model.decode_step(p, cache=c, tokens=t))
    for _ in range(3):
        logits, cache = step(params, cache, toks)
    assert logits.shape == (B, cfg.vocab)
    assert not bool(jnp.isnan(logits).any())
    assert cache["pos"].shape == (B,)          # per-sequence positions
    assert int(cache["pos"][0]) == 3


@pytest.mark.parametrize("arch", [a for a in ARCH_IDS
                                  if get_config(a).supports_long_ctx])
def test_long_ctx_decode_path(arch):
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(1))
    cache = model.init_cache(batch=1, cache_len=128, long_ctx=True)
    toks = jnp.array([5], jnp.int32)
    logits, cache = model.decode_step(params, cache=cache, tokens=toks,
                                      long_ctx=True)
    assert not bool(jnp.isnan(logits).any())


@pytest.mark.parametrize("arch", ["phi4-mini-3.8b", "gemma2-2b",
                                  "falcon-mamba-7b", "recurrentgemma-2b",
                                  "gemma3-12b", "qwen2-moe-a2.7b",
                                  "nemotron-4-340b"])
def test_decode_matches_forward(arch):
    """Token-by-token decode reproduces the parallel forward logits --
    cross-validates caches (ring buffers, recurrent states) against the
    chunked/block-local attention and scan paths."""
    import dataclasses
    cfg = get_config(arch).reduced()
    if cfg.n_experts:
        # capacity drops are a train-time-only semantic (decode never
        # overflows); ample capacity makes the two paths comparable
        cfg = dataclasses.replace(cfg, capacity_factor=8.0)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(1))
    B, S = 2, 24
    toks = jax.random.randint(KEY, (B, S), 0, cfg.vocab, jnp.int32)
    logits_par, _ = model.forward(params, batch={"tokens": toks})

    cache = model.init_cache(batch=B, cache_len=S)
    outs = []
    for t in range(S):
        lg, cache = model.decode_step(params, cache=cache,
                                      tokens=toks[:, t])
        outs.append(lg)
    logits_seq = jnp.stack(outs, axis=1)
    diff = jnp.max(jnp.abs(logits_par - logits_seq))
    assert float(diff) < 2e-2, float(diff)


def test_whisper_decode_matches_forward():
    """Enc-dec path: decode with a filled cross-attention cache matches
    the parallel encoder+decoder forward."""
    from repro.models.decode import fill_cross_cache
    from repro.models.transformer import (_stage_forward, build_stages)
    from repro.models.layers import rms_norm

    cfg = get_config("whisper-small").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(1))
    B, S = 2, 12
    toks = jax.random.randint(KEY, (B, S), 0, cfg.vocab, jnp.int32)
    enc = frontends.fake_audio_frames(KEY, cfg, B)
    batch = {"tokens": toks, "enc_embeds": enc}
    logits_par, _ = model.forward(params, batch=batch)

    # run the encoder once (same computation forward() performs)
    stages = build_stages(cfg)
    aux = jnp.zeros((), jnp.float32)
    enc_pos = jnp.arange(enc.shape[1])
    enc_out, _ = _stage_forward(params["stages"][0], stages[0],
                                enc.astype(params["embed"].dtype), cfg,
                                enc_pos, aux)
    enc_out = rms_norm(enc_out, jnp.zeros_like(enc_out[0, 0]),
                       cfg.norm_eps)

    cache = model.init_cache(batch=B, cache_len=S)
    cache = fill_cross_cache(params, cfg, cache, enc_out)
    outs = []
    for t in range(S):
        lg, cache = model.decode_step(params, cache=cache,
                                      tokens=toks[:, t])
        outs.append(lg)
    diff = jnp.max(jnp.abs(logits_par - jnp.stack(outs, axis=1)))
    assert float(diff) < 2e-2, float(diff)
