"""Extensions: Remark-1 uncoordinated solvers, compressed z-exchange,
Krasnosel'skii damping."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.fedplt import FedPLT, FedPLTConfig
from repro.core.metrics import hitting_round
from repro.core.problem import LogRegProblem, make_logreg_problem
from repro.core.solvers import SolverConfig

GD5 = SolverConfig(name="gd", n_epochs=5)


@pytest.fixture(scope="module")
def prob():
    return make_logreg_problem(n_agents=20, q=50, dim=20, seed=0)


@pytest.fixture(scope="module")
def hetero_prob():
    p0 = make_logreg_problem(n_agents=20, q=50, dim=5, seed=0)
    scales = jnp.linspace(0.3, 3.0, 20)[:, None, None]
    return LogRegProblem(A=p0.A * scales, b=p0.b, eps=0.5)


def _run(p, cfg, rounds=500):
    _, crit = FedPLT(p, cfg).run(jax.random.PRNGKey(0), rounds)
    return np.asarray(crit)


def test_uncoordinated_solvers_converge(hetero_prob):
    """Remark 1: per-agent step sizes from local moduli still converge
    exactly on heterogeneous agents."""
    crit = _run(hetero_prob, FedPLTConfig(rho=1.0, uncoordinated=True,
                                          solver=GD5), 300)
    assert crit[-1] < 1e-9


def test_per_agent_moduli_vary(hetero_prob):
    L_i = hetero_prob.per_agent_smoothness()
    assert float(jnp.max(L_i) / jnp.min(L_i)) > 5.0


@pytest.mark.parametrize("comp,kw,rounds", [
    ("int8", {}, 300),
    ("topk", {"compress_ratio": 0.5}, 400),
    ("topk", {"compress_ratio": 0.1}, 800),
])
def test_compressed_exchange_converges_exactly(prob, comp, kw, rounds):
    """Beyond-paper: lag-based error feedback keeps exact convergence
    under int8 and top-k (down to 10%) z compression."""
    cfg = FedPLTConfig(rho=1.0, compression=comp, solver=GD5, **kw)
    crit = _run(prob, cfg, rounds)
    assert crit[-1] < 1e-9, crit[-1]


def test_compression_costs_rounds_not_accuracy(prob):
    hit_exact = hitting_round(_run(prob, FedPLTConfig(rho=1.0,
                                                      solver=GD5), 300))
    hit_topk = hitting_round(_run(prob, FedPLTConfig(
        rho=1.0, compression="topk", compress_ratio=0.1,
        solver=GD5), 800))
    assert hit_exact < hit_topk            # bandwidth traded for rounds
    assert hit_topk < 10 * hit_exact       # at sublinear cost


def test_damping_half_is_douglas_rachford(prob):
    """damping=1/2 (DRS) still converges exactly, slower than PRS."""
    crit = _run(prob, FedPLTConfig(rho=1.0, damping=0.5, solver=GD5), 400)
    assert crit[-1] < 1e-9


def test_compression_with_partial_participation(prob):
    cfg = FedPLTConfig(rho=1.0, compression="int8", participation=0.6,
                       solver=GD5)
    crit = _run(prob, cfg, 800)
    assert crit[-1] < 1e-8
