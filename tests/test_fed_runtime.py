"""Large-scale Fed-PLT runtime: training works, DP noise flows, the
runtime round is semantically the paper's Algorithm 1 on pytrees."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.base import InputShape
from repro.data.synthetic import make_batch_for
from repro.fed import runtime
from repro.models.model import build_model

SHAPE = InputShape("tiny", 32, 8, "train")


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("gemma2-2b").reduced()
    model = build_model(cfg)
    return cfg, model


def test_fed_training_reduces_loss(setup):
    cfg, model = setup
    fcfg = runtime.FedConfig(n_agents=4, n_epochs=2, gamma=0.1)
    state = runtime.init_state(model, jax.random.PRNGKey(0), fcfg)
    step = jax.jit(runtime.make_train_step(model, fcfg))
    batch = make_batch_for(cfg, SHAPE, n_agents=4)
    losses = []
    for i in range(6):
        state, m = step(state, batch, jax.random.PRNGKey(i))
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0]


def test_dp_noise_and_clipping_path(setup):
    cfg, model = setup
    fcfg = runtime.FedConfig(n_agents=2, n_epochs=2, tau=0.01, clip=1.0)
    state = runtime.init_state(model, jax.random.PRNGKey(0), fcfg)
    step = jax.jit(runtime.make_train_step(model, fcfg))
    batch = make_batch_for(cfg, SHAPE, n_agents=2)
    state, m = step(state, batch, jax.random.PRNGKey(0))
    assert np.isfinite(m["loss"])
    # agents received different noise: x_1 != x_2 even with same init/data
    diff = jax.tree_util.tree_reduce(
        lambda acc, x: acc + float(jnp.sum(jnp.abs(x[0] - x[1]))),
        state.x, 0.0)
    assert diff > 0


def test_inactive_agents_keep_state(setup):
    cfg, model = setup
    fcfg = runtime.FedConfig(n_agents=4, n_epochs=1,
                             participation=1e-7)  # nobody active
    state0 = runtime.init_state(model, jax.random.PRNGKey(0), fcfg)
    step = jax.jit(runtime.make_train_step(model, fcfg))
    batch = make_batch_for(cfg, SHAPE, n_agents=4)
    state1, m = step(state0, batch, jax.random.PRNGKey(3))
    same = jax.tree_util.tree_all(jax.tree_util.tree_map(
        lambda a, b: bool(jnp.array_equal(a, b)), state0.x, state1.x))
    assert same
    assert float(m["participation"]) == 0.0


def test_weight_decay_prox_shrinks(setup):
    cfg, model = setup
    fcfg = runtime.FedConfig(n_agents=2, weight_decay=0.1, rho=1.0)
    zbar = {"w": jnp.ones((3,))}
    y = runtime._coordinator_prox(zbar, fcfg)
    expect = 1.0 / (1.0 + 1.0 * 0.1 / 2)
    np.testing.assert_allclose(y["w"], expect, atol=1e-6)


def test_runtime_matches_core_fedplt_on_quadratic():
    """The pytree runtime round == the paper-faithful core round when the
    'model' is a bare quadratic loss (full participation, no noise)."""
    from repro.core.fedplt import FedPLT, FedPLTConfig
    from repro.core.problem import make_quadratic_problem
    from repro.core.solvers import SolverConfig

    prob = make_quadratic_problem(n_agents=3, dim=4, seed=0)

    class QuadModel:
        def init(self, key):
            return {"x": jnp.zeros(4)}

        def loss_fn(self, params, batch, remat=False):
            Q, c = batch["Q"], batch["c"]
            x = params["x"]
            return 0.5 * x @ Q @ x + c @ x

    gamma, rho, ne = 0.05, 1.0, 3
    fcfg = runtime.FedConfig(n_agents=3, rho=rho, gamma=gamma, n_epochs=ne)
    state = runtime.init_state(QuadModel(), jax.random.PRNGKey(0), fcfg)
    step = runtime.make_train_step(QuadModel(), fcfg)
    batch = {"Q": prob.Q, "c": prob.c}
    for i in range(50):
        state, _ = step(state, batch, jax.random.PRNGKey(i))

    core = FedPLT(prob, FedPLTConfig(
        rho=rho, solver=SolverConfig(name="gd", n_epochs=ne,
                                     step_size=gamma)))
    cstate, _ = core.run(jax.random.PRNGKey(0), 50)
    np.testing.assert_allclose(
        jnp.mean(state.x["x"], axis=0), core.x_bar(cstate), atol=1e-3)
