"""Per-kernel allclose sweeps vs the ref.py pure-jnp oracles
(interpret=True on CPU), plus hypothesis property tests."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels.fedplt_update.ops import fedplt_update, fedplt_update_tree
from repro.kernels.fedplt_update.ref import fedplt_update_ref
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import flash_attention_ref
from repro.kernels.lru_scan.ops import lru_scan
from repro.kernels.lru_scan.ref import lru_scan_ref

KEY = jax.random.PRNGKey(0)


# ---------------------------------------------------------------------------
# fedplt_update
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("shape", [(5,), (128,), (1000,), (77, 33),
                                   (4, 256, 512), (3, 7, 11, 13)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_fedplt_update_sweep(shape, dtype):
    ks = jax.random.split(KEY, 4)
    w, g, v, t = (jax.random.normal(k, shape, dtype) for k in ks)
    for noise in (None, t):
        out = fedplt_update(w, g, v, t=noise, gamma=0.07, inv_rho=1.3)
        ref = fedplt_update_ref(w, g, v, t=noise, gamma=0.07, inv_rho=1.3)
        tol = 1e-5 if dtype == jnp.float32 else 2e-2
        np.testing.assert_allclose(np.asarray(out, np.float32),
                                   np.asarray(ref, np.float32), atol=tol)


@given(st.integers(1, 2000), st.floats(1e-4, 1.0), st.floats(0.01, 10.0))
@settings(max_examples=25, deadline=None)
def test_fedplt_update_property(n, gamma, inv_rho):
    ks = jax.random.split(jax.random.PRNGKey(n), 3)
    w, g, v = (jax.random.normal(k, (n,)) for k in ks)
    out = fedplt_update(w, g, v, gamma=gamma, inv_rho=inv_rho)
    ref = fedplt_update_ref(w, g, v, gamma=gamma, inv_rho=inv_rho)
    np.testing.assert_allclose(out, ref, atol=1e-5)


def test_fedplt_update_tree():
    tree = {"a": jnp.ones((17, 5)), "b": {"c": jnp.full((300,), 2.0)}}
    zeros = jax.tree_util.tree_map(jnp.zeros_like, tree)
    out = fedplt_update_tree(tree, zeros, zeros, gamma=0.1, inv_rho=2.0)
    # w - 0.1*(0 + 2*(w-0)) = 0.8 w
    np.testing.assert_allclose(out["a"], 0.8 * tree["a"], atol=1e-6)
    np.testing.assert_allclose(out["b"]["c"], 0.8 * tree["b"]["c"],
                               atol=1e-6)


# ---------------------------------------------------------------------------
# flash_attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("S,H,Hkv,D", [(128, 4, 4, 32), (256, 4, 2, 64),
                                       (256, 8, 1, 32)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(S, H, Hkv, D, dtype):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (2, S, H, D), dtype)
    k = jax.random.normal(ks[1], (2, S, Hkv, D), dtype)
    v = jax.random.normal(ks[2], (2, S, Hkv, D), dtype)
    out = flash_attention(q, k, v, causal=True)
    ref = flash_attention_ref(q, k, v, causal=True)
    tol = 2e-3 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=tol)


@pytest.mark.parametrize("kwargs", [
    dict(causal=True, window=64),
    dict(causal=True, cap=30.0),
    dict(causal=False),
    dict(causal=True, window=32, cap=50.0),
])
def test_flash_attention_variants(kwargs):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (1, 256, 4, 32))
    k = jax.random.normal(ks[1], (1, 256, 2, 32))
    v = jax.random.normal(ks[2], (1, 256, 2, 32))
    out = flash_attention(q, k, v, **kwargs)
    ref = flash_attention_ref(q, k, v, **kwargs)
    np.testing.assert_allclose(out, ref, atol=2e-3)


def test_flash_attention_block_sizes():
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (1, 256, 2, 32))
    k = jax.random.normal(ks[1], (1, 256, 2, 32))
    v = jax.random.normal(ks[2], (1, 256, 2, 32))
    ref = flash_attention_ref(q, k, v, causal=True)
    for bq, bk in [(64, 64), (128, 64), (64, 128), (256, 256)]:
        out = flash_attention(q, k, v, causal=True, block_q=bq, block_k=bk)
        np.testing.assert_allclose(out, ref, atol=2e-3)


# ---------------------------------------------------------------------------
# lru_scan
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,S,W", [(1, 128, 16), (2, 256, 32), (3, 64, 8)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_lru_scan_sweep(B, S, W, dtype):
    ks = jax.random.split(KEY, 2)
    a = jax.nn.sigmoid(jax.random.normal(ks[0], (B, S, W))).astype(dtype)
    b = jax.random.normal(ks[1], (B, S, W), dtype)
    out = lru_scan(a, b)
    ref = lru_scan_ref(a, b)
    tol = 1e-4 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=tol)


def test_lru_scan_4d_state():
    ks = jax.random.split(KEY, 2)
    a = jax.nn.sigmoid(jax.random.normal(ks[0], (2, 128, 8, 4)))
    b = jax.random.normal(ks[1], (2, 128, 8, 4))
    np.testing.assert_allclose(lru_scan(a, b), lru_scan_ref(a, b),
                               atol=1e-4)


@given(st.integers(1, 6), st.integers(2, 5))
@settings(max_examples=15, deadline=None)
def test_lru_scan_chunk_invariance(b_seed, chunk_pow):
    """Result is independent of the chunking (cross-chunk carry exact)."""
    a = jax.nn.sigmoid(jax.random.normal(jax.random.PRNGKey(b_seed),
                                         (1, 64, 8)))
    b = jax.random.normal(jax.random.PRNGKey(b_seed + 99), (1, 64, 8))
    out1 = lru_scan(a, b, chunk=2 ** chunk_pow)
    out2 = lru_scan(a, b, chunk=64)
    np.testing.assert_allclose(out1, out2, atol=1e-5)
