"""Serving layer: per-sequence cache positions, continuous batching
isolation, slot reset."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models.decode import reset_slots
from repro.models.model import build_model


@pytest.fixture(scope="module", params=["gemma2-2b", "falcon-mamba-7b"])
def setup(request):
    cfg = get_config(request.param).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(1))
    return cfg, model, params


def _decode_seq(model, params, toks, B_pad=1, lane=0, other_toks=None,
                cache_len=32):
    """Decode `toks` in lane `lane` of a B_pad-slot batch; other lanes
    run `other_toks` (or idle)."""
    B = B_pad
    cache = model.init_cache(batch=B, cache_len=cache_len)
    outs = []
    for t in range(len(toks)):
        batch_toks = np.zeros(B, np.int32)
        batch_toks[lane] = toks[t]
        if other_toks is not None:
            for b in range(B):
                if b != lane:
                    batch_toks[b] = other_toks[(t + b) % len(other_toks)]
        logits, cache = model.decode_step(
            params, cache=cache, tokens=jnp.asarray(batch_toks))
        outs.append(np.asarray(logits[lane]))
    return np.stack(outs)


def test_slot_isolation(setup):
    """A sequence's logits are identical whether it runs alone or next to
    unrelated sequences in other slots (continuous-batching invariant)."""
    cfg, model, params = setup
    toks = [3, 17, 5, 9, 11]
    alone = _decode_seq(model, params, toks, B_pad=1, lane=0)
    crowd = _decode_seq(model, params, toks, B_pad=3, lane=1,
                        other_toks=[101, 55, 7, 42])
    np.testing.assert_allclose(alone, crowd, atol=2e-3)


def test_reset_slots_frees_state(setup):
    """After reset_slots, the freed lane reproduces a fresh sequence."""
    cfg, model, params = setup
    B, cache_len = 2, 32
    toks = [3, 17, 5]
    # fresh run
    fresh = _decode_seq(model, params, toks, B_pad=2, lane=0,
                        cache_len=cache_len)
    # dirty the cache in lane 0, then reset lane 0 only
    cache = model.init_cache(batch=B, cache_len=cache_len)
    for t in [9, 8, 7, 6]:
        _, cache = model.decode_step(
            params, cache=cache, tokens=jnp.asarray([t, t + 1]))
    cache = reset_slots(cache, jnp.asarray([True, False]))
    outs = []
    for t in range(len(toks)):
        logits, cache = model.decode_step(
            params, cache=cache, tokens=jnp.asarray([toks[t], 1]))
        outs.append(np.asarray(logits[0]))
    np.testing.assert_allclose(fresh, np.stack(outs), atol=2e-3)


def test_staggered_positions(setup):
    """Sequences at different depths coexist: positions advance per
    sequence independently after a reset."""
    cfg, model, params = setup
    cache = model.init_cache(batch=2, cache_len=16)
    for t in range(4):
        _, cache = model.decode_step(params, cache=cache,
                                     tokens=jnp.asarray([1, 2]))
    cache = reset_slots(cache, jnp.asarray([True, False]))
    _, cache = model.decode_step(params, cache=cache,
                                 tokens=jnp.asarray([1, 2]))
    assert int(cache["pos"][0]) == 1
    assert int(cache["pos"][1]) == 5
