"""The Fed-PLT front door: FedSpec validation, build_trainer equivalence
with the legacy front ends, the generated CLI, and the compressor
registry (including the per-agent adaptive compressor at model scale)."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.fedplt import FedPLT, FedPLTConfig
from repro.core.problem import make_quadratic_problem
from repro.core.prox import make_prox
from repro.core.solvers import SolverConfig
from repro.fed import runtime
from repro.fed.api import (CompressionSpec, FedSpec, PrivacySpec,
                           add_spec_args, build_trainer, spec_from_args)
from repro.fed.compress import (available_compressors, get_compressor,
                                register_compressor)


@pytest.fixture(scope="module")
def quad():
    return make_quadratic_problem(n_agents=5, dim=6, seed=3)


class QuadModel:
    """Minimal model-path object: a bare quadratic loss."""

    def init(self, key):
        return {"x": jnp.zeros(6)}

    def loss_fn(self, params, batch, remat=False):
        x = params["x"]
        return 0.5 * x @ batch["Q"] @ x + batch["c"] @ x


def _quad_batch(quad):
    return {"Q": quad.Q, "c": quad.c}


# ---------------------------------------------------------------------------
# Dense path: build_trainer == FedPLT, bit for bit
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("cfg_kw,solver_kw", [
    (dict(), dict(name="gd")),                                   # plain gd
    (dict(), dict(name="noisy_gd", tau=0.05)),                   # DP noise
    # legacy quirk: gd with tau set ran NOISELESS (tau read only by
    # noisy_gd) -- to_spec must not let the tau>0 upgrade change that
    (dict(), dict(name="gd", tau=0.1)),
    (dict(participation=0.6), dict(name="gd")),                  # partial
    (dict(participation=0.7, compression="topk", compress_ratio=0.5,
          damping=0.5), dict(name="gd")),                        # topk + pp
])
def test_build_trainer_matches_fedplt_bit_for_bit(quad, cfg_kw, solver_kw):
    """FedPLT(problem, cfg).run == build_trainer(problem,
    cfg.to_spec()).run -- same PRNG stream, same ops, same bits."""
    cfg = FedPLTConfig(rho=1.0,
                       solver=SolverConfig(n_epochs=3, **solver_kw),
                       **cfg_kw)
    key = jax.random.PRNGKey(11)
    s_ref, c_ref = FedPLT(quad, cfg).run(key, 25)
    trainer = build_trainer(quad, cfg.to_spec())
    s_new, c_new = trainer.run(key, 25)
    np.testing.assert_array_equal(np.asarray(c_ref), np.asarray(c_new))
    np.testing.assert_array_equal(np.asarray(s_ref.x), np.asarray(s_new.x))
    np.testing.assert_array_equal(np.asarray(s_ref.z), np.asarray(s_new.z))


def test_dense_config_roundtrip_is_identity(quad):
    for cfg in [
        FedPLTConfig(),
        FedPLTConfig(rho=0.5, prox_h="l1", batch_size=16,
                     solver=SolverConfig(name="sgd", n_epochs=7)),
        FedPLTConfig(mu=0.1, L=5.0, dp_init=True, uncoordinated=True,
                     solver=SolverConfig(name="noisy_gd", tau=0.2,
                                         step_size=0.03), damping=0.5,
                     compression="int8", participation=0.4),
    ]:
        assert cfg.to_spec().to_dense_config() == cfg


def test_dense_state_t_materialized_only_when_compressed(quad):
    uncompressed = build_trainer(quad, FedSpec(rho=1.0))
    assert uncompressed.init(jax.random.PRNGKey(0)).t is None
    compressed = build_trainer(quad, FedSpec(
        rho=1.0, compression=CompressionSpec(name="topk")))
    assert compressed.init(jax.random.PRNGKey(0)).t is not None
    # ... and running uncompressed still works (scan carries the None)
    state, crit = uncompressed.run(jax.random.PRNGKey(0), 5)
    assert state.t is None and np.isfinite(np.asarray(crit)).all()


def test_dense_trainer_consensus_and_report(quad):
    spec = FedSpec(rho=1.0, n_epochs=5,
                   privacy=PrivacySpec(tau=0.05, clip=1.0))
    trainer = build_trainer(quad, spec)
    state, _ = trainer.run(jax.random.PRNGKey(0), 30)
    np.testing.assert_allclose(trainer.consensus(state),
                               jnp.mean(state.x, axis=0))
    rep = trainer.privacy_report(30, local_dataset_size=100)
    assert np.isfinite(rep.adp_eps) and rep.adp_eps > 0


# ---------------------------------------------------------------------------
# Model path: FedConfig shim == FedSpec through make_train_step
# ---------------------------------------------------------------------------

def test_fedconfig_to_spec_train_step_equivalent(quad):
    fcfg = runtime.FedConfig(n_agents=5, gamma=0.05, n_epochs=3,
                             weight_decay=0.1, compression="topk",
                             compress_ratio=0.5)
    batch = _quad_batch(quad)

    def losses(cfg_like):
        state = runtime.init_state(QuadModel(), jax.random.PRNGKey(0),
                                   cfg_like)
        step = jax.jit(runtime.make_train_step(QuadModel(), cfg_like))
        out = []
        for i in range(4):
            state, m = step(state, batch, jax.random.PRNGKey(i))
            out.append(float(m["loss"]))
        return out

    np.testing.assert_array_equal(losses(fcfg), losses(fcfg.to_spec()))


def test_weight_decay_prox_shared_registry():
    """The model path's weight decay is the core/prox.py registry entry:
    one ProxH convention for both fronts."""
    y = jnp.array([2.0, -4.0])
    # reciprocal-multiply form (not division): see prox_l2sq's docstring
    np.testing.assert_array_equal(
        make_prox("weight_decay", weight=0.3)(y, 0.5),
        y * (1.0 / (1.0 + 0.3 * 0.5)))
    fcfg = runtime.FedConfig(n_agents=2, weight_decay=0.3, rho=1.0)
    np.testing.assert_array_equal(
        runtime._coordinator_prox({"w": y}, fcfg)["w"],
        y * (1.0 / (1.0 + 0.3 * (1.0 / 2))))


# ---------------------------------------------------------------------------
# Validation: one home, messages survive the dedup
# ---------------------------------------------------------------------------

def test_clip_validation_raised_once_from_spec():
    with pytest.raises(ValueError, match="clip must be positive"):
        FedSpec(n_agents=2, gamma=0.1,
                privacy=PrivacySpec(clip=0.0)).validate()
    # ... and still fails fast at the legacy call sites
    with pytest.raises(ValueError, match="clip must be positive"):
        runtime.make_train_step(QuadModel(),
                                runtime.FedConfig(n_agents=2, clip=0.0))
    with pytest.raises(ValueError, match="clip must be positive"):
        runtime.privacy_report(
            runtime.FedConfig(n_agents=2, tau=0.1, clip=-1.0), 10, 10)


def test_agd_moduli_validation_raised_once_from_spec():
    # gamma=2, rho=1 derives L = 1/2 - 1 < 0 <= mu
    with pytest.raises(ValueError, match="agd momentum needs L > mu"):
        FedSpec(n_agents=2, solver="agd", gamma=2.0).validate()
    with pytest.raises(ValueError, match="agd momentum needs L > mu"):
        runtime.make_train_step(
            QuadModel(), runtime.FedConfig(n_agents=2, solver="agd",
                                           gamma=2.0))
    with pytest.raises(ValueError, match="agd momentum needs L > mu"):
        FedSpec(n_agents=2, solver="agd", mu=2.0, L=1.0).validate()


def test_agd_with_dp_noise_rejected():
    with pytest.raises(ValueError, match="gd-type solver, not 'agd'"):
        FedSpec(n_agents=2, solver="agd",
                privacy=PrivacySpec(tau=0.1)).validate()


def test_privacy_report_requires_tau():
    with pytest.raises(ValueError, match="requires tau > 0"):
        runtime.privacy_report(runtime.FedConfig(n_agents=2), 10, 10)


def test_unknown_compressor_lists_registry():
    with pytest.raises(ValueError, match="registered:.*topk"):
        FedSpec(n_agents=2,
                compression=CompressionSpec(name="nope")).validate()


def test_unknown_prox_lists_registry():
    with pytest.raises(ValueError, match="unknown prox.*registered:"):
        FedSpec(n_agents=2, prox_h="nope").validate()


def test_compress_energy_threads_to_dense_engine(quad):
    """CompressionSpec.energy must reach the dense round engine (and
    round-trip through the legacy config), not silently reset to the
    default."""
    spec = FedSpec(rho=1.0, compression=CompressionSpec(
        name="adaptive_topk", ratio=0.25, energy=0.5))
    trainer = build_trainer(quad, spec)
    assert trainer.algo._ecfg.compress_energy == 0.5
    cfg = FedPLTConfig(compression="adaptive_topk", compress_energy=0.5)
    assert cfg.to_spec().compression.energy == 0.5
    assert cfg.to_spec().to_dense_config() == cfg


# ---------------------------------------------------------------------------
# Generated CLI round-trip
# ---------------------------------------------------------------------------

def test_spec_from_args_roundtrip(quad):
    spec = spec_from_args([
        "--n-agents", "5", "--rho", "0.5", "--gamma", "0.1",
        "--n-epochs", "2", "--participation", "0.8", "--tau", "0.01",
        "--clip", "1.0", "--weight-decay", "0.2",
        "--compression", "topk", "--compress-ratio", "0.5"])
    assert spec == FedSpec(
        n_agents=5, rho=0.5, gamma=0.1, n_epochs=2, participation=0.8,
        weight_decay=0.2, privacy=PrivacySpec(tau=0.01, clip=1.0),
        compression=CompressionSpec(name="topk", ratio=0.5))
    # the parsed spec drives a real fed train step
    spec.validate()
    step = jax.jit(runtime.make_train_step(QuadModel(), spec))
    state = runtime.init_state(QuadModel(), jax.random.PRNGKey(0), spec)
    state, m = step(state, _quad_batch(quad), jax.random.PRNGKey(0))
    assert np.isfinite(m["loss"])
    assert state.t is not None   # compressed exchange materializes t


def test_cli_defaults_match_spec_field_defaults():
    """A no-flag CLI run and FedSpec() must denote the SAME training
    trajectory: every exposed field whose dataclass default is concrete
    must generate a flag with exactly that default (the n_epochs 5-vs-3
    drift trained silently different models).  None-defaulted fields
    (n_agents, gamma) are the one sanctioned exception: the CLI has to
    pick a concrete value where the spec derives one."""
    from repro.fed import api

    classes = {"spec": FedSpec, "privacy": PrivacySpec,
               "compression": CompressionSpec}
    for owner, name, flag, _, kwargs in api._cli_entries():
        fields = {f.name: f for f in dataclasses.fields(classes[owner])}
        default = fields[name].default
        if default is None or default is dataclasses.MISSING:
            continue
        assert kwargs["default"] == default, (
            f"{flag} defaults to {kwargs['default']!r} but "
            f"{classes[owner].__name__}.{name} defaults to {default!r}")
    # the drift this guards against, end to end:
    assert spec_from_args([]).n_epochs == FedSpec().n_epochs


def test_cli_agd_with_tau_fails_fast():
    spec = spec_from_args(["--tau", "0.3", "--solver", "agd"])
    with pytest.raises(ValueError, match="gd-type solver, not 'agd'"):
        spec.validate()


def test_cli_flags_track_registered_compressors():
    """A compressor registered at runtime is immediately a legal
    --compression choice: the CLI is generated, not hand-mirrored."""
    import argparse

    @register_compressor("cli_probe_compressor")
    def probe(dz, cfg):   # pragma: no cover - never called
        return dz

    ap = argparse.ArgumentParser()
    add_spec_args(ap)
    spec = spec_from_args(
        ap.parse_args(["--compression", "cli_probe_compressor"]))
    assert spec.compression.name == "cli_probe_compressor"


# ---------------------------------------------------------------------------
# Compressor registry + the per-agent adaptive compressor
# ---------------------------------------------------------------------------

def test_registry_has_builtins():
    names = available_compressors()
    for expected in ("none", "topk", "int8", "adaptive_topk"):
        assert expected in names


def test_registered_compressor_usable_by_name(quad):
    """Extensibility proof at the dense front end: a compressor
    registered here runs through FedSpec without engine changes."""
    calls = []

    @register_compressor("mean_sign_test")
    def mean_sign(dz, cfg):
        calls.append(1)
        scale = jnp.mean(jnp.abs(dz), axis=-1, keepdims=True)
        return jnp.sign(dz) * scale

    trainer = build_trainer(quad, FedSpec(
        rho=1.0, damping=0.5,
        compression=CompressionSpec(name="mean_sign_test")))
    state, crit = trainer.run(jax.random.PRNGKey(0), 10)
    assert calls, "registered compressor was never dispatched"
    assert np.isfinite(np.asarray(crit)).all()
    assert state.t is not None


def test_topk_transmits_exactly_k_on_ties():
    """Magnitude ties must not inflate the uplink: a threshold-select
    keeps EVERY tied coordinate (an all-constant increment would
    transmit all m entries at a k/m bandwidth budget)."""
    cfg = type("C", (), {"compress_ratio": 0.25,
                         "compress_energy": 0.95})()
    m = 16
    k = int(0.25 * m)
    rows = jnp.stack([jnp.ones(m),                 # all-tied constants
                      jnp.zeros(m),                # all-zero increment
                      -3.0 * jnp.ones(m)])         # tied negatives
    out = get_compressor("topk")(rows, cfg)
    kept = np.asarray((out != 0).sum(axis=-1))
    assert kept[0] == k
    assert kept[1] == 0                             # zeros transmit zeros
    assert kept[2] == k
    # surviving entries are the original values, untouched
    np.testing.assert_array_equal(np.asarray(out[0][out[0] != 0]),
                                  np.ones(k))


def test_adaptive_topk_transmits_exactly_k_on_ties():
    """Same tie discipline for the adaptive compressor: an all-constant
    row at a 0.5 energy target needs ceil(m/2) coordinates -- the old
    threshold-select transmitted all m of them."""
    cfg = type("C", (), {"compress_ratio": 1.0 / 16.0,
                         "compress_energy": 0.5})()
    m = 16
    out = get_compressor("adaptive_topk")(jnp.stack([jnp.ones(m)]), cfg)
    kept = int(np.asarray((out != 0).sum(axis=-1))[0])
    assert kept == 8   # smallest prefix with >= 50% energy, not m


def test_topk_no_ties_keeps_top_magnitudes():
    cfg = type("C", (), {"compress_ratio": 0.5, "compress_energy": 0.95})()
    row = jnp.array([[0.1, -5.0, 2.0, 0.01, 3.0, -0.2]])
    out = get_compressor("topk")(row, cfg)
    np.testing.assert_array_equal(
        np.asarray(out[0]), [0.0, -5.0, 2.0, 0.0, 3.0, 0.0])


def test_adaptive_topk_ratio_is_per_agent():
    """A concentrated increment keeps fewer coordinates than a diffuse
    one -- the ratio adapts per agent instead of one global k."""
    cfg = type("C", (), {"compress_ratio": 1.0 / 16.0,
                         "compress_energy": 0.9})()
    concentrated = jnp.zeros(64).at[7].set(10.0).at[40].set(5.0)
    diffuse = jnp.ones(64)
    out = get_compressor("adaptive_topk")(
        jnp.stack([concentrated, diffuse]), cfg)
    kept = (out != 0).sum(axis=-1)
    assert int(kept[0]) <= 4            # hot coords only
    assert int(kept[1]) >= 32           # diffuse energy needs many
    # transmitted values are the original entries (no rescaling)
    np.testing.assert_array_equal(out[0][7], concentrated[7])


def test_adaptive_topk_at_model_scale_through_fedspec(quad):
    """Acceptance: the per-agent heterogeneous scenario the redesign
    enables -- an adaptive-ratio compressor from the registry, driven at
    model scale purely through FedSpec."""
    spec = FedSpec(n_agents=5, gamma=0.05, n_epochs=3, damping=0.5,
                   compression=CompressionSpec(name="adaptive_topk",
                                               ratio=0.25, energy=0.95))
    trainer = build_trainer(QuadModel(), spec)
    batch = _quad_batch(quad)
    state, _ = trainer.run(jax.random.PRNGKey(0), 50, lambda i: batch)
    # the consensus model reaches the closed-form optimum of sum_i f_i
    # despite every uplink being adaptively sparsified (error feedback)
    err = float(jnp.linalg.norm(trainer.consensus(state)["x"]
                                - quad.solve()))
    assert err < 1e-3
    assert state.t is not None
    # error feedback: the coordinator copy lags z under sparsification
    lag = jax.tree_util.tree_reduce(
        lambda a, l: a + float(jnp.sum(jnp.abs(l))),
        jax.tree_util.tree_map(lambda z, t: z - t, state.z, state.t), 0.0)
    assert lag > 0


def test_model_trainer_requires_resolved_spec():
    with pytest.raises(ValueError, match="n_agents"):
        build_trainer(QuadModel(), FedSpec(gamma=0.1))
    with pytest.raises(ValueError, match="gamma"):
        build_trainer(QuadModel(), FedSpec(n_agents=2))
    with pytest.raises(TypeError, match="cannot build a trainer"):
        build_trainer(object(), FedSpec(n_agents=2, gamma=0.1))


def test_spec_is_hashable_and_frozen():
    spec = FedSpec(n_agents=2)
    hash(spec)
    with pytest.raises(dataclasses.FrozenInstanceError):
        spec.rho = 2.0
