"""Shared fixtures.  NOTE: no XLA_FLAGS here -- smoke tests and benches
must see the single real CPU device; only dryrun.py forces 512.

Also installs a minimal fallback shim for ``hypothesis`` when the real
package is not available (the container images only guarantee jax +
numpy + pytest): the property tests then run a fixed-seed sweep of
random examples instead of being collection errors.  Install the real
``hypothesis`` (the ``test`` extra in pyproject.toml) to get shrinking
and the full example database.
"""

import random
import sys
import types

import jax
import pytest

try:
    import hypothesis  # noqa: F401
except ModuleNotFoundError:
    _MAX_EXAMPLES_CAP = 16  # keep interpret-mode kernel sweeps fast on CPU

    class _Strategy:
        """A draw function wrapper; only the strategies the suite uses."""

        def __init__(self, draw):
            self.draw = draw

    def _floats(min_value=0.0, max_value=1.0, **_kw):
        return _Strategy(lambda rng: rng.uniform(min_value, max_value))

    def _integers(min_value=0, max_value=100):
        return _Strategy(lambda rng: rng.randint(min_value, max_value))

    def _lists(elem, min_size=0, max_size=10, **_kw):
        def draw(rng):
            n = rng.randint(min_size, max_size)
            return [elem.draw(rng) for _ in range(n)]

        return _Strategy(draw)

    def _sampled_from(seq):
        seq = list(seq)
        return _Strategy(lambda rng: seq[rng.randrange(len(seq))])

    def _booleans():
        return _Strategy(lambda rng: rng.random() < 0.5)

    def _just(value):
        return _Strategy(lambda rng: value)

    _st = types.ModuleType("hypothesis.strategies")
    _st.floats = _floats
    _st.integers = _integers
    _st.lists = _lists
    _st.sampled_from = _sampled_from
    _st.booleans = _booleans
    _st.just = _just

    def _settings(max_examples=10, **_kw):
        def deco(fn):
            fn._stub_max_examples = max_examples
            return fn

        return deco

    def _given(*strategies, **kw_strategies):
        def deco(fn):
            n = min(getattr(fn, "_stub_max_examples", 10),
                    _MAX_EXAMPLES_CAP)

            def runner():
                rng = random.Random(0)  # deterministic across runs
                for _ in range(n):
                    args = [s.draw(rng) for s in strategies]
                    kwargs = {k: s.draw(rng)
                              for k, s in kw_strategies.items()}
                    fn(*args, **kwargs)

            # no functools.wraps: pytest would follow __wrapped__ and
            # mistake the example parameters for fixtures
            runner.__name__ = fn.__name__
            runner.__doc__ = fn.__doc__
            runner.__module__ = fn.__module__
            return runner

        return deco

    _hyp = types.ModuleType("hypothesis")
    _hyp.given = _given
    _hyp.settings = _settings
    _hyp.strategies = _st
    _hyp.__stub__ = True
    sys.modules["hypothesis"] = _hyp
    sys.modules["hypothesis.strategies"] = _st


@pytest.fixture(autouse=True, scope="module")
def _bounded_compile_cache():
    """Drop jit/pjit compile caches at module boundaries.

    A full tier-1 run compiles thousands of distinct programs in one
    process; on single-CPU containers the accumulated executables
    eventually segfault XLA:CPU inside a late ``backend_compile``
    (the failing test roams -- whichever module compiles next once
    the process is saturated).  Clearing at module boundaries keeps
    the footprint bounded; recompilation is deterministic, so
    numerics are unaffected.
    """
    yield
    jax.clear_caches()


@pytest.fixture(scope="session")
def key():
    return jax.random.PRNGKey(0)
