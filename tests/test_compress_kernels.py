"""Fused uplink-compression kernel suite: bit-exactness vs the ref.py
oracles AND the registry XLA compressors (tie-heavy / ragged /
non-block-aligned inputs, interpret mode), packed-path == per-leaf-path
identity, compressor invariants across the whole registry, and the
backend knob end to end."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.problem import make_logreg_problem
from repro.fed.api import CompressionSpec, FedSpec, build_trainer, spec_from_args
from repro.fed.compress import (PALLAS_COMPRESSORS, available_compressors,
                                compress_increment, compress_rows,
                                get_compressor, pack_leaves, unpack_leaves)
from repro.fed.engine import RoundConfig
from repro.kernels.compress import ops, ref


# tie-heavy / non-aligned row battery: every case is (N, m) plus a
# mutation planting adversarial structure
def _tie_heavy(n, m, seed=0):
    x = jax.random.normal(jax.random.PRNGKey(seed), (n, m))
    x = x.at[0].set(1.0)                   # all-tied row
    x = x.at[1 % n].set(0.0)               # all-zero row
    x = x.at[2 % n, ::3].set(-2.5)         # repeated magnitude, mixed sign
    return x


def _cfg(name, ratio=0.25, energy=0.9, backend="xla"):
    return RoundConfig(n_agents=1, compression=name,
                       compress_ratio=ratio, compress_energy=energy,
                       compress_backend=backend)


# ---------------------------------------------------------------------------
# Kernel vs ref.py vs registry XLA compressors (bit-exact)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n,m", [(3, 7), (5, 300), (8, 128), (2, 1000),
                                 (11, 33)])
@pytest.mark.parametrize("mode", ["topk", "adaptive_topk"])
def test_rank_select_matches_ref_and_registry(n, m, mode):
    x = _tie_heavy(n, m, seed=m)
    out = ops.rank_select(x, mode=mode, ratio=0.25, energy=0.9)
    oracle = ref.rank_select_ref(x, mode=mode, ratio=0.25, energy=0.9)
    registry = get_compressor(mode)(x, _cfg(mode))
    np.testing.assert_array_equal(np.asarray(out), np.asarray(oracle))
    np.testing.assert_array_equal(np.asarray(out), np.asarray(registry))


@pytest.mark.parametrize("n,m", [(3, 7), (5, 300), (2, 1000)])
def test_int8_matches_ref_and_registry(n, m):
    """Bit-exact under jit on both sides -- the engine always runs the
    compressors jitted, and eager XLA compiles the dequant scale's
    division one ULP differently on some shapes (fusion-dependent
    codegen), so jit-vs-eager is not the parity that matters."""
    x = _tie_heavy(n, m, seed=m)
    out = ops.int8_quantize(x)
    np.testing.assert_array_equal(
        np.asarray(out), np.asarray(jax.jit(ref.int8_ref)(x)))
    registry = jax.jit(lambda v: get_compressor("int8")(v, _cfg("int8")))
    np.testing.assert_array_equal(np.asarray(out),
                                  np.asarray(registry(x)))


@pytest.mark.parametrize("segments", [
    ((0, 20), (20, 277), (277, 300)),      # ragged, non-block-aligned
    ((0, 3), (3, 4), (4, 300)),            # tiny segments
    ((0, 150), (160, 300)),                # gap (padding columns)
])
@pytest.mark.parametrize("mode", ["topk", "adaptive_topk"])
def test_segmented_rank_select_matches_ref(segments, mode):
    x = _tie_heavy(5, 300)
    out = ops.rank_select(x, segments=segments, mode=mode, ratio=0.25,
                          energy=0.9)
    oracle = ref.rank_select_ref(x, segments, mode=mode, ratio=0.25,
                                 energy=0.9)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(oracle))


def test_segmented_int8_matches_ref():
    x = _tie_heavy(5, 300)
    segments = ((0, 20), (20, 277), (277, 300))
    oracle = jax.jit(lambda v: ref.int8_ref(v, segments))(x)
    np.testing.assert_array_equal(
        np.asarray(ops.int8_quantize(x, segments=segments)),
        np.asarray(oracle))


def test_segment_ranks_match_ref():
    x = _tie_heavy(4, 96)
    segments = ((0, 40), (40, 96))
    got = ops.segment_ranks(x, segments=segments)
    oracle = ref.segment_ranks_ref(x, segments)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(oracle))


@pytest.mark.parametrize("n,m,segments", [
    (3, 64, None), (4, 37, ((0, 10), (12, 37))), (2, 128, ((0, 128),)),
    (9, 5, None),
])
def test_bitonic_sort_impl_matches_xla(n, m, segments):
    """The explicit compare-exchange network (the Mosaic-lowerable form)
    realizes the identical permutation as the in-kernel lax.sort: the
    composite key is unique, so both equal the stable order."""
    x = _tie_heavy(n, m, seed=n * m)
    covered = ((0, m),) if segments is None else segments
    a = ops.segment_ranks(x, segments=segments, sort_impl="xla")
    b = ops.segment_ranks(x, segments=segments, sort_impl="bitonic")
    for s0, s1 in covered:
        np.testing.assert_array_equal(np.asarray(a[:, s0:s1]),
                                      np.asarray(b[:, s0:s1]))
    for mode in ("topk", "adaptive_topk"):
        sa = ops.rank_select(x, segments=segments, mode=mode, ratio=0.3,
                             energy=0.8, sort_impl="xla")
        sb = ops.rank_select(x, segments=segments, mode=mode, ratio=0.3,
                             energy=0.8, sort_impl="bitonic")
        np.testing.assert_array_equal(np.asarray(sa), np.asarray(sb))


def test_bf16_rank_select_matches_registry():
    x = _tie_heavy(4, 200).astype(jnp.bfloat16)
    for mode in ("topk", "adaptive_topk"):
        out = ops.rank_select(x, mode=mode, ratio=0.25, energy=0.9)
        registry = get_compressor(mode)(x, _cfg(mode))
        assert out.dtype == jnp.bfloat16
        np.testing.assert_array_equal(np.asarray(out, np.float32),
                                      np.asarray(registry, np.float32))


# ---------------------------------------------------------------------------
# Leaf packing + the packed pallas path == the per-leaf XLA path
# ---------------------------------------------------------------------------

def _ragged_tree(n=5, seed=3):
    key = jax.random.PRNGKey(seed)
    shapes = {"emb": (n, 37, 5), "w": {"a": (n, 130), "b": (n, 3)},
              "bias": (n, 1)}
    return jax.tree_util.tree_map(
        lambda s: jax.random.normal(jax.random.fold_in(key, s[-1]), s),
        shapes, is_leaf=lambda s: isinstance(s, tuple))


def test_pack_unpack_roundtrip():
    tree = _ragged_tree()
    buf, meta = pack_leaves(tree)
    assert buf.shape[1] % 128 == 0         # lane-aligned packed width
    back = unpack_leaves(buf, meta)
    jax.tree_util.tree_map(
        lambda a, b: np.testing.assert_array_equal(np.asarray(a),
                                                   np.asarray(b)),
        tree, back)


@pytest.mark.parametrize("name", sorted(PALLAS_COMPRESSORS))
def test_packed_path_bit_identical_to_per_leaf(name):
    """One packed kernel launch == the historical per-leaf registry
    dispatch, bitwise, on a ragged multi-leaf pytree (incl. an all-tied
    leaf)."""
    tree = _ragged_tree()
    tree["w"]["a"] = jnp.ones_like(tree["w"]["a"])   # all-tied leaf
    # jit both, as the engine does (eager XLA codegen differs by a ULP
    # in the int8 scale on some shapes; see test_int8_matches_* above)
    per_leaf = jax.jit(
        lambda t: compress_increment(t, _cfg(name, backend="xla")))(tree)
    packed = jax.jit(
        lambda t: compress_increment(t, _cfg(name, backend="pallas")))(tree)
    jax.tree_util.tree_map(
        lambda a, b: np.testing.assert_array_equal(np.asarray(a),
                                                   np.asarray(b)),
        per_leaf, packed)


def test_packed_path_under_jit():
    tree = _ragged_tree()
    cfg = _cfg("adaptive_topk", backend="pallas")
    eager = compress_increment(tree, cfg)
    jitted = jax.jit(lambda t: compress_increment(t, cfg))(tree)
    jax.tree_util.tree_map(
        lambda a, b: np.testing.assert_array_equal(np.asarray(a),
                                                   np.asarray(b)),
        eager, jitted)


def test_non_accelerated_compressor_falls_back():
    """backend="pallas" with a compressor that has no kernel silently
    uses the per-leaf XLA path (documented fallback)."""
    tree = _ragged_tree()
    out_x = compress_increment(tree, _cfg("none", backend="xla"))
    out_p = compress_increment(tree, _cfg("none", backend="pallas"))
    jax.tree_util.tree_map(
        lambda a, b: np.testing.assert_array_equal(np.asarray(a),
                                                   np.asarray(b)),
        out_x, out_p)


# ---------------------------------------------------------------------------
# Compressor invariants across the whole registry, both backends
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", sorted(available_compressors()))
@pytest.mark.parametrize("backend", ["xla", "pallas"])
def test_registry_preserves_shape_and_dtype(name, backend):
    x = _tie_heavy(6, 97)
    out = compress_rows(x, _cfg(name, backend=backend))
    assert out.shape == x.shape
    assert out.dtype == x.dtype


@pytest.mark.parametrize("backend", ["xla", "pallas"])
def test_exact_k_on_all_tied_rows(backend):
    """Adversarial all-tied input: EXACTLY k values survive per row --
    the tie discipline a threshold select would blow (it would transmit
    the whole row)."""
    m = 64
    x = jnp.ones((4, m))
    out = compress_rows(x, _cfg("topk", ratio=0.25, backend=backend))
    np.testing.assert_array_equal(
        np.asarray(jnp.sum(out != 0.0, axis=-1)), np.full(4, m // 4))
    out = compress_rows(
        x, _cfg("adaptive_topk", ratio=1.0 / 16.0, energy=0.5,
                backend=backend))
    # flat spectrum: the smallest prefix holding >= 50% energy is m/2
    np.testing.assert_array_equal(
        np.asarray(jnp.sum(out != 0.0, axis=-1)), np.full(4, m // 2))


# ---------------------------------------------------------------------------
# The backend knob end to end
# ---------------------------------------------------------------------------

def test_round_config_rejects_unknown_backend():
    with pytest.raises(ValueError, match="backend"):
        RoundConfig(n_agents=2, compress_backend="nope")


def test_spec_validates_backend():
    with pytest.raises(ValueError, match="backend"):
        FedSpec(n_agents=2, compression=CompressionSpec(
            backend="nope")).validate()


def test_cli_backend_roundtrip():
    spec = spec_from_args(["--compression", "adaptive_topk",
                           "--compress-backend", "pallas"])
    assert spec.compression.backend == "pallas"
    assert spec.validate().round_config().compress_backend == "pallas"


@pytest.mark.parametrize("name", sorted(PALLAS_COMPRESSORS))
def test_dense_trainer_backend_bit_identity(name):
    """Full Fed-PLT trajectories are bit-identical under either
    backend: the fused kernels change the schedule, not the numbers."""
    prob = make_logreg_problem(n_agents=6, q=30, dim=20, seed=0)
    runs = {}
    for backend in ("xla", "pallas"):
        spec = FedSpec(rho=1.0, n_epochs=2, compression=CompressionSpec(
            name=name, ratio=0.3, energy=0.9, backend=backend))
        state, crit = build_trainer(prob, spec).run(
            jax.random.PRNGKey(0), 6)
        runs[backend] = (np.asarray(state.x), np.asarray(state.z),
                         np.asarray(state.t), np.asarray(crit))
    for a, b in zip(runs["xla"], runs["pallas"]):
        np.testing.assert_array_equal(a, b)


def test_backend_threads_to_dense_engine():
    prob = make_logreg_problem(n_agents=4, q=20, dim=10, seed=0)
    spec = FedSpec(rho=1.0, compression=CompressionSpec(
        name="topk", backend="pallas"))
    trainer = build_trainer(prob, spec)
    assert trainer.algo._ecfg.compress_backend == "pallas"
    # legacy shim round-trips the knob too
    from repro.core.fedplt import FedPLTConfig
    cfg = FedPLTConfig(compression="topk", compress_backend="pallas")
    assert cfg.to_spec().compression.backend == "pallas"


def test_mixed_dtype_tree_falls_back_per_leaf():
    n = 4
    tree = {"a": jnp.ones((n, 40)),
            "b": jnp.ones((n, 24), jnp.bfloat16)}
    out = compress_increment(tree, _cfg("topk", backend="pallas"))
    per_leaf = compress_increment(tree, _cfg("topk", backend="xla"))
    jax.tree_util.tree_map(
        lambda a, b: np.testing.assert_array_equal(
            np.asarray(a, np.float32), np.asarray(b, np.float32)),
        out, per_leaf)
    assert out["b"].dtype == jnp.bfloat16


def test_compress_bench_perf_payload(monkeypatch):
    """The --json emitter's per-case payload (wall time, speedup,
    shapes) stays machine-readable: run the perf sweep on one tiny case
    and check the committed-baseline schema."""
    from benchmarks import compress_bench as cb

    # the engine-scale case the acceptance tracks is in the real sweep
    assert "engine_gemma2r" in {c[0] for c in cb._PERF_CASES}
    monkeypatch.setattr(cb, "_PERF_CASES", (("tiny", 2, (64, 30)),))
    rows, payload = cb._perf(quick=True)
    assert rows and len(payload) == 2 * len(sorted(PALLAS_COMPRESSORS))
    assert {p["backend"] for p in payload} == {"xla", "pallas"}
    for p in payload:
        assert p["kind"] == "perf" and p["case"] == "tiny"
        assert p["m_total"] == 94 and p["n_leaves"] == 2
        assert p["ms_per_call"] > 0.0 and p["speedup_vs_xla"] > 0.0
