"""All baseline algorithms behave as published on the paper's problem."""

import jax
import numpy as np
import pytest

from repro.core import baselines
from repro.core.problem import make_logreg_problem

KEY = jax.random.PRNGKey(1)


@pytest.fixture(scope="module")
def prob():
    return make_logreg_problem(n_agents=20, q=50, dim=5, seed=0)


EXACT = {
    "fedpd": dict(eta=1.0, gamma=0.1, n_epochs=5),
    "fedlin": dict(gamma=0.1, n_epochs=5),
    "scaffold": dict(gamma_l=0.1, n_epochs=5),
    "led": dict(gamma=0.1, n_epochs=5),
    "5gcs": dict(alpha=1.0, eta=1.0, n_epochs=5, participation=1.0),
}


@pytest.mark.parametrize("name", sorted(EXACT))
def test_exact_methods_converge(prob, name):
    algo = baselines.REGISTRY[name](prob, **EXACT[name])
    crit = np.asarray(algo.run(KEY, 400))
    assert crit[-1] < 1e-8, f"{name} final={crit[-1]}"


@pytest.mark.parametrize("name,kw,steps", [
    ("proxskip", dict(gamma=0.2, p_comm=0.2), 800),
    ("tamuna", dict(gamma=0.2, p_comm=0.2, participation=1.0), 800),
])
def test_probabilistic_lt_methods_converge(prob, name, kw, steps):
    algo = baselines.REGISTRY[name](prob, **kw)
    crit = np.asarray(algo.run(KEY, steps))
    assert crit[-1] < 1e-7, f"{name} final={crit[-1]}"


def test_fedavg_exhibits_client_drift(prob):
    """FedAvg with local training stalls above the exact threshold --
    the client-drift phenomenon motivating the paper (Sec. I)."""
    algo = baselines.REGISTRY["fedavg"](prob, gamma=0.1, n_epochs=5)
    crit = np.asarray(algo.run(KEY, 400))
    assert crit[-1] > 1e-4


def test_fedsplit_biased_under_inexact_prox(prob):
    """FedSplit (no warm start) stalls when the prox is solved inexactly
    -- the gap Fed-PLT's initialization closes (Sec. I-A)."""
    algo = baselines.REGISTRY["fedsplit"](prob, rho=1.0, n_epochs=5)
    crit = np.asarray(algo.run(KEY, 400))
    assert crit[-1] > 1e-6


def test_partial_participation_scaffold_5gcs(prob):
    for name, kw in [("scaffold", dict(gamma_l=0.1, n_epochs=5,
                                       participation=0.5)),
                     ("5gcs", dict(alpha=1.0, eta=1.0, n_epochs=5,
                                   participation=0.5))]:
        algo = baselines.REGISTRY[name](prob, **kw)
        crit = np.asarray(algo.run(KEY, 800))
        assert crit[-1] < 1e-6, f"{name} pp final={crit[-1]}"


def test_time_model_table2():
    """Per-round cost formulas match Table II."""
    prob = make_logreg_problem(n_agents=10, q=20, dim=3)
    tG, tC = 1.0, 10.0
    fedlin = baselines.REGISTRY["fedlin"](prob, n_epochs=5)
    fedpd = baselines.REGISTRY["fedpd"](prob, n_epochs=5)
    assert fedlin.time_per_round(tG, tC) == ((5 + 1) * tG + 2 * tC) * 10
    assert fedpd.time_per_round(tG, tC) == (5 * tG + tC) * 10
