"""Fed-PLT system behaviour: exact convergence, no client drift, partial
participation, composite problems, DP neighbourhood (paper Props. 1-4)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.fedplt import FedPLT, FedPLTConfig
from repro.core.problem import (make_logreg_problem,
                                make_quadratic_problem)
from repro.core.prox import prox_l1
from repro.core.solvers import SolverConfig


@pytest.fixture(scope="module")
def logreg():
    return make_logreg_problem(n_agents=20, q=50, dim=5, seed=0)


@pytest.fixture(scope="module")
def quad():
    return make_quadratic_problem(n_agents=8, dim=6, seed=1)


def run(problem, cfg, rounds=150, seed=0):
    algo = FedPLT(problem, cfg)
    state, crit = algo.run(jax.random.PRNGKey(seed), rounds)
    return algo, state, np.asarray(crit)


def test_exact_convergence_quadratic_closed_form(quad):
    cfg = FedPLTConfig(rho=1.0, solver=SolverConfig(name="gd", n_epochs=5))
    algo, state, crit = run(quad, cfg, 200)
    np.testing.assert_allclose(algo.x_bar(state), quad.solve(), atol=1e-4)


def test_exact_convergence_logreg(logreg):
    cfg = FedPLTConfig(rho=1.0, solver=SolverConfig(name="gd", n_epochs=5))
    algo, state, crit = run(logreg, cfg)
    assert crit[-1] < 1e-9
    np.testing.assert_allclose(algo.x_bar(state), logreg.solve(20000),
                               atol=1e-4)


def test_no_client_drift_large_ne(logreg):
    """Accuracy does not degrade as N_e grows (Sec. V-C2)."""
    for ne in (1, 5, 20):
        cfg = FedPLTConfig(rho=1.0,
                           solver=SolverConfig(name="gd", n_epochs=ne))
        _, _, crit = run(logreg, cfg, 200)
        assert crit[-1] < 1e-8, f"drift at N_e={ne}: {crit[-1]}"


def test_partial_participation_converges(logreg):
    cfg = FedPLTConfig(rho=1.0, participation=0.5,
                       solver=SolverConfig(name="gd", n_epochs=5))
    _, _, crit = run(logreg, cfg, 600)
    assert crit[-1] < 1e-8


def test_partial_participation_slower_than_full(logreg):
    """Table VI phenomenon: fewer active agents => slower convergence."""
    cfg_full = FedPLTConfig(rho=1.0, participation=1.0,
                            solver=SolverConfig(name="gd", n_epochs=5))
    cfg_half = FedPLTConfig(rho=1.0, participation=0.4,
                            solver=SolverConfig(name="gd", n_epochs=5))
    _, _, c_full = run(logreg, cfg_full, 120)
    _, _, c_half = run(logreg, cfg_half, 120)
    t_full = np.argmax(c_full < 1e-5) + 1
    t_half = np.argmax(c_half < 1e-5) + 1
    assert t_full < t_half


def test_accelerated_solver_converges(logreg):
    cfg = FedPLTConfig(rho=1.0,
                       solver=SolverConfig(name="agd", n_epochs=5))
    _, _, crit = run(logreg, cfg, 300)
    assert crit[-1] < 1e-8


def test_sgd_converges_to_neighbourhood(logreg):
    """Prop. 2: SGD converges to a variance-dependent neighbourhood that
    shrinks as the minibatch grows (nu smaller => tighter radius)."""
    tails = []
    for bs in (10, 45):
        cfg = FedPLTConfig(rho=1.0, batch_size=bs,
                           solver=SolverConfig(name="sgd", n_epochs=5))
        _, _, crit = run(logreg, cfg, 300)
        tails.append(np.mean(crit[-30:]))
    init = logreg.criterion(jnp.zeros((logreg.n_agents, logreg.dim)))
    assert tails[0] < 0.05 * float(init)   # converged to a neighbourhood
    assert tails[1] < tails[0]             # radius shrinks with variance


def test_noisy_gd_neighbourhood_scales_with_tau(logreg):
    errs = []
    for tau in (1e-4, 1e-2):
        cfg = FedPLTConfig(
            rho=1.0, solver=SolverConfig(name="noisy_gd", n_epochs=5,
                                         tau=tau))
        _, _, crit = run(logreg, cfg, 200)
        errs.append(np.mean(crit[-20:]))
    assert errs[0] < errs[1]  # Table VII: error grows with tau


def test_composite_l1_regularized(quad):
    """h = ||x||_1 at the coordinator: converges to the l1-regularized
    optimum (checked against proximal gradient oracle)."""
    cfg = FedPLTConfig(rho=0.5, prox_h="l1",
                       solver=SolverConfig(name="gd", n_epochs=10))
    algo, state, _ = run(quad, cfg, 400)
    # oracle: proximal gradient on F(x) = sum f_i + ||x||_1
    x = jnp.zeros(quad.dim)
    Lsum = quad.smoothness() * quad.n_agents
    for _ in range(20000):
        g = jnp.sum(quad.grads(jnp.broadcast_to(x, (quad.n_agents,
                                                    quad.dim))), axis=0)
        x = prox_l1(x - g / Lsum, 1.0 / Lsum)
    y_star = algo.prox_h(jnp.mean(state.z, axis=0),
                         cfg.rho / quad.n_agents)
    np.testing.assert_allclose(y_star, x, atol=2e-3)


def test_nonconvex_regularizer_runs():
    p = make_logreg_problem(n_agents=10, q=30, dim=4, nonconvex=True)
    cfg = FedPLTConfig(rho=1.0, L=5.0, mu=0.1,
                       solver=SolverConfig(name="gd", n_epochs=5,
                                           step_size=0.05))
    algo = FedPLT(p, cfg)
    _, crit = algo.run(jax.random.PRNGKey(0), 300)
    assert np.asarray(crit)[-1] < 1e-3  # converges in practice (Sec. VII)


def test_dp_init_draws_random_x0(logreg):
    cfg = FedPLTConfig(rho=1.0, dp_init=True,
                       solver=SolverConfig(name="noisy_gd", n_epochs=3,
                                           tau=0.1))
    algo = FedPLT(logreg, cfg)
    st = algo.init(jax.random.PRNGKey(0))
    assert float(jnp.std(st.x)) > 0.01
