"""Data pipeline, checkpointing, optimizers, sharding rules, HLO analyzer."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import restore_checkpoint, save_checkpoint
from repro.configs import ARCH_IDS, get_config
from repro.configs.base import SHAPES, InputShape
from repro.core.problem import dirichlet_partition
from repro.data.synthetic import make_batch_for, synthetic_lm_batch
from repro.fed import sharding
from repro.launch.hlo_analysis import analyze_text
from repro.models.model import build_model, input_specs, shape_supported
from repro.optim import adamw, apply_updates, momentum, sgd


# ---------------------------------------------------------------------------
# data
# ---------------------------------------------------------------------------

def test_synthetic_batch_shapes_and_range():
    b = synthetic_lm_batch(jax.random.PRNGKey(0), 100, 4, 16)
    assert b["tokens"].shape == (4, 16)
    assert int(b["tokens"].min()) >= 0 and int(b["tokens"].max()) < 100
    np.testing.assert_array_equal(b["labels"][:, :-1], b["tokens"][:, 1:])


def test_fed_batches_are_heterogeneous():
    cfg = get_config("gemma2-2b").reduced()
    shape = InputShape("t", 16, 8, "train")
    batch = make_batch_for(cfg, shape, n_agents=4)
    assert batch["tokens"].shape == (4, 2, 16)
    # different agents draw from skewed distributions
    assert not np.array_equal(batch["tokens"][0], batch["tokens"][3])


def test_dirichlet_partition_skews_labels():
    rng = np.random.default_rng(0)
    X = rng.normal(size=(1000, 3))
    y = rng.integers(0, 4, 1000)
    feats, labs = dirichlet_partition(X, y, n_agents=5, alpha=0.1, seed=1)
    assert feats.shape[0] == 5 and feats.shape[2] == 3
    # low alpha => at least one agent is label-skewed
    props = [np.mean(labs[i] == labs[i][0]) for i in range(5)]
    assert max(props) > 0.5


# ---------------------------------------------------------------------------
# checkpoint
# ---------------------------------------------------------------------------

def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(6.0).reshape(2, 3),
            "b": {"c": jnp.array([1, 2], jnp.int32)}}
    save_checkpoint(str(tmp_path / "ck"), tree, step=7)
    back = restore_checkpoint(str(tmp_path / "ck"), tree)
    np.testing.assert_allclose(back["a"], tree["a"])
    np.testing.assert_array_equal(back["b"]["c"], tree["b"]["c"])


def test_checkpoint_shape_mismatch_raises(tmp_path):
    tree = {"a": jnp.zeros((2, 3))}
    save_checkpoint(str(tmp_path / "ck"), tree)
    with pytest.raises(ValueError):
        restore_checkpoint(str(tmp_path / "ck"), {"a": jnp.zeros((3, 3))})


# ---------------------------------------------------------------------------
# optimizers
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("opt", [sgd(0.1), momentum(0.05), adamw(0.05)])
def test_optimizers_minimize_quadratic(opt):
    params = {"x": jnp.array([3.0, -2.0])}
    state = opt.init(params)
    for _ in range(300):
        grads = {"x": 2.0 * params["x"]}
        upd, state = opt.update(grads, state, params)
        params = apply_updates(params, upd)
    assert float(jnp.linalg.norm(params["x"])) < 1e-2


# ---------------------------------------------------------------------------
# sharding rules
# ---------------------------------------------------------------------------

AXES = {"data": 16, "model": 16}


def _check_tree(params, specs, reserve=0):
    flat_p = jax.tree_util.tree_leaves(params)
    flat_s = jax.tree_util.tree_leaves(
        specs, is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec))
    assert len(flat_p) == len(flat_s)
    for leaf, spec in zip(flat_p, flat_s):
        assert len(spec) <= leaf.ndim
        for dim, axis in zip(leaf.shape, tuple(spec)):
            if axis is None:
                continue
            size = AXES[axis] if isinstance(axis, str) else \
                int(np.prod([AXES[a] for a in axis]))
            assert dim % size == 0, (leaf.shape, spec)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_param_specs_divisible_all_archs(arch):
    cfg = get_config(arch)
    model = build_model(cfg)
    params = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    specs = sharding.param_specs(params, fsdp_axis="data",
                                 axis_sizes=AXES)
    _check_tree(params, specs)


@pytest.mark.parametrize("arch", ["gemma2-2b", "qwen2-moe-a2.7b"])
def test_cache_specs_divisible(arch):
    cfg = get_config(arch)
    for shape_id in ("decode_32k", "long_500k"):
        shape = SHAPES[shape_id]
        if not shape_supported(cfg, shape)[0]:
            continue
        from repro.models.model import cache_specs
        cache = cache_specs(cfg, shape)
        specs = sharding.cache_spec_tree(cache, AXES, data_axes=("data",))
        _check_tree(cache, specs)


def test_input_specs_cover_all_pairs():
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        for shape in SHAPES.values():
            if not shape_supported(cfg, shape)[0]:
                continue
            specs = input_specs(cfg, shape)
            assert specs, (arch, shape.name)


# ---------------------------------------------------------------------------
# HLO analyzer
# ---------------------------------------------------------------------------

def test_hlo_analyzer_scan_trip_count():
    def body(x, _):
        return x @ x, None

    def f(x):
        y, _ = jax.lax.scan(body, x, None, length=8)
        return y.sum()

    txt = jax.jit(f).lower(jnp.ones((64, 64))).compile().as_text()
    c = analyze_text(txt)
    assert c.flops >= 2 * 64 ** 3 * 8  # trip-count multiplied
    assert c.flops < 2 * 64 ** 3 * 8 * 1.5


def test_hlo_analyzer_collectives():
    mesh = jax.make_mesh((1,), ("d",))
    from jax.sharding import PartitionSpec as P

    # jax.shard_map only exists from 0.5; fall back to the experimental
    # home so the test runs on the pinned 0.4.x too
    shard_map = getattr(jax, "shard_map", None)
    if shard_map is None:
        from jax.experimental.shard_map import shard_map

    def f(x):
        return jax.lax.psum(x, "d")

    fn = jax.jit(
        shard_map(f, mesh=mesh, in_specs=P("d"), out_specs=P()))
    txt = fn.lower(jnp.ones((8, 128))).compile().as_text()
    c = analyze_text(txt)
    # single-device all-reduce may be optimized away; just assert parse ok
    assert c.bytes >= 0
