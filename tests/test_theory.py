"""Theory module: contraction factors, S matrix, Lemma 7, Corollary 1."""

import jax
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import theory
from repro.core.fedplt import FedPLT, FedPLTConfig
from repro.core.problem import make_quadratic_problem
from repro.core.solvers import SolverConfig


def test_zeta_minimized_near_rho_star():
    """zeta(rho) is minimized at rho = 1/sqrt(mu L) (PRS theory)."""
    mu, L = 0.5, 8.0
    rho_star = 1.0 / np.sqrt(mu * L)
    z_star = theory.zeta_prs(rho_star, mu, L)
    for rho in (0.01, 0.1, 10.0, 100.0):
        assert theory.zeta_prs(rho, mu, L) >= z_star - 1e-12


@given(st.floats(0.05, 1.0), st.floats(1.5, 50.0), st.floats(0.05, 20.0))
@settings(max_examples=60, deadline=None)
def test_zeta_chi_in_unit_interval(mu, L, rho):
    assert 0.0 <= theory.zeta_prs(rho, mu, L) < 1.0
    gamma = 2.0 / (mu + L + 2.0 / rho)
    assert 0.0 <= theory.chi_gd(gamma, mu + 1 / rho, L + 1 / rho) < 1.0


def test_lemma7_stabilizer_finds_stable_params():
    for mu, L in [(0.5, 4.0), (0.01, 100.0), (1.0, 1.5)]:
        res = theory.stabilize(mu, L)
        assert res.spectral_radius < 1.0, (mu, L)


def test_sigma_increases_as_participation_drops():
    s = 0.9
    sig = [theory.sigma(p, p, s) for p in (1.0, 0.7, 0.4, 0.1)]
    assert all(a < b for a, b in zip(sig, sig[1:]))
    assert sig[0] == pytest.approx(s)


def test_s_norm_bounds_empirical_rate():
    """||S|| from Prop. 1 upper-bounds the empirical contraction rate of
    the full Fed-PLT operator on a quadratic problem."""
    prob = make_quadratic_problem(n_agents=6, dim=4, seed=3)
    mu, L = prob.strong_convexity(), prob.smoothness()
    rho, ne = 1.0, 5
    scfg = SolverConfig(name="gd", n_epochs=ne)
    s_norm = theory.s_norm(scfg, mu, L, rho)
    algo = FedPLT(prob, FedPLTConfig(rho=rho, solver=scfg))
    state, crit = algo.run(jax.random.PRNGKey(0), 80)
    crit = np.asarray(crit)
    # empirical per-round criterion decay rate (criterion ~ dist^2)
    window = crit[10:60]
    emp_rate = np.exp(np.mean(np.diff(np.log(window + 1e-30)))) ** 0.5
    assert emp_rate <= s_norm + 0.05


def _stable_params(mu=0.5, L=4.0):
    res = theory.stabilize(mu, L)
    assert res.s_norm < 1.0
    return dict(mu=mu, L=L, rho=res.rho, gamma=res.gamma,
                n_epochs=res.n_epochs)


def test_corollary1_bound_monotone_in_tau():
    args = dict(K=100, dim=5, n_agents=10, r0=1.0, **_stable_params())
    b1 = theory.corollary1_bound(tau=1e-3, **args)
    b2 = theory.corollary1_bound(tau=1e-1, **args)
    assert b1 < b2 < float("inf")


def test_asymptotic_error_zero_noise():
    p = _stable_params()
    assert theory.asymptotic_error(p["mu"], p["L"], p["rho"], p["gamma"],
                                   p["n_epochs"], 0.0, 5, 10) == 0.0
