"""Mesh-sharded round engine suite (ROADMAP item 2).

Parity contract (see the MESH CONTRACT note in repro/fed/engine.py):

* KERNEL tier, bitwise: the partial-sum / presummed-downlink kernels ==
  the ref.py oracles on one shard's buffer (jit-vs-jit, like every
  other kernel suite -- eager refs diverge by FMA contraction, not by
  math).
* MESH-OF-1, bitwise: a (1, 1) mesh is the degenerate case of the one
  sharded code path -- trajectories equal the unsharded engine
  bit-for-bit on every state_layout x engine_backend x compressor
  combination.
* MULTI-DEVICE, fp32 rounding: an 8-way agent mesh reorders the
  cross-device psum, whose combine order is not host-reproducible --
  trajectories equal the 1-device run to float32 rounding (rtol=1e-5,
  atol=1e-6), not bitwise.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import prox as prox_lib
from repro.core.problem import make_logreg_problem
from repro.fed import engine
from repro.fed.api import (AgentGroupSpec, CompressionSpec, FedSpec,
                           PrivacySpec, build_trainer, spec_from_args)
from repro.kernels.round_edge import ops, ref

PROX_TABLE = [
    ("none", None),
    ("l1", prox_lib.prox_l1),
    ("weight_decay", prox_lib.make_prox("weight_decay", weight=0.1)),
    ("elastic_net", prox_lib.make_prox("elastic_net", l1=0.3, l2=0.7)),
]

multi_device = pytest.mark.skipif(
    len(jax.devices()) < 8,
    reason="needs 8 devices (XLA_FLAGS=--xla_force_host_platform_"
           "device_count=8)")


def _mesh(agents=1, model=1):
    devs = np.asarray(jax.devices()[:agents * model]).reshape(agents,
                                                              model)
    from jax.sharding import Mesh

    return Mesh(devs, ("agent", "model"))


def _stack(key, n, m, scale=1.0):
    return scale * jax.random.normal(key, (n, m))


def _assert_trees_equal(a, b):
    jax.tree_util.tree_map(
        lambda x, y: np.testing.assert_array_equal(np.asarray(x),
                                                   np.asarray(y)), a, b)


def _assert_trees_ulp_close(a, b):
    """Equality to float32 rounding (the multi-device bar)."""
    jax.tree_util.tree_map(
        lambda x, y: np.testing.assert_allclose(
            np.asarray(x), np.asarray(y), rtol=1e-5, atol=1e-6), a, b)


# ---------------------------------------------------------------------------
# Kernel tier: the sharded-edge kernels vs the ref.py oracles
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n,m", [(3, 7), (8, 300), (2, 1000)])
def test_uplink_partial_matches_ref(n, m):
    z = _stack(jax.random.PRNGKey(n * m), n, m)
    s = ops.round_uplink_partial(z)
    sr = jax.jit(ref.round_uplink_partial_ref)(z)
    assert s.shape == (1, m)
    np.testing.assert_array_equal(np.asarray(s), np.asarray(sr))


@pytest.mark.parametrize("n,m", [(3, 7), (6, 300), (4, 513)])
def test_downlink_presummed_matches_ref(n, m):
    key = jax.random.PRNGKey(n + m)
    x = _stack(key, n, m)
    w = _stack(jax.random.fold_in(key, 1), n, m)
    z = _stack(jax.random.fold_in(key, 2), n, m)
    y = _stack(jax.random.fold_in(key, 3), 1, m)
    u = jax.random.bernoulli(jax.random.fold_in(key, 4), 0.5,
                             (n,)).astype(jnp.float32)
    xn, zn = ops.round_downlink_presummed(x, w, z, y, u, damping=0.65)
    ref_jit = jax.jit(ref.round_downlink_presummed_ref,
                      static_argnames=("damping",))
    xr, zr = ref_jit(x, w, z, u, y, damping=0.65)
    np.testing.assert_array_equal(np.asarray(xn), np.asarray(xr))
    np.testing.assert_array_equal(np.asarray(zn), np.asarray(zr))


def test_partial_direct_matches_pallas_emulation():
    z = _stack(jax.random.PRNGKey(2), 5, 384)
    np.testing.assert_array_equal(
        np.asarray(ops.round_uplink_partial(z)),
        np.asarray(ops.round_uplink_partial(z, emulate=True)))
    y = jnp.mean(z, axis=0, keepdims=True)
    u = jnp.asarray([1.0, 0.0, 1.0, 1.0, 0.0])
    a = ops.round_downlink_presummed(z, z + 1.0, z, y, u, damping=0.5)
    b = ops.round_downlink_presummed(z, z + 1.0, z, y, u, damping=0.5,
                                     emulate=True)
    _assert_trees_equal(a, b)


@pytest.mark.parametrize("pname,prox", PROX_TABLE,
                         ids=[p[0] for p in PROX_TABLE])
@pytest.mark.parametrize("lagged", [False, True])
def test_sharded_ops_mesh_of_one_bitwise(pname, prox, lagged):
    """On a (1, 1) mesh the shard_map composites must equal the sharded
    oracles AND the unsharded fused kernels bit-for-bit -- one device is
    the degenerate case of the one sharded code path."""
    n, m = 6, 300
    key = jax.random.PRNGKey(7)
    z = _stack(key, n, m)
    t = z + 0.1 * _stack(jax.random.fold_in(key, 1), n, m) if lagged \
        else None
    mesh = _mesh(1, 1)
    y, v = ops.round_uplink_sharded(z, t, mesh=mesh, n_total=n,
                                    prox=prox, rho_eff=0.25)
    # EAGER oracle: the psum is a fusion barrier between the local sum
    # and the divide, so the sharded op reproduces the oracle's eager
    # op-by-op evaluation bitwise (a jitted oracle refolds sum/divide
    # across that boundary and drifts by 1 ulp)
    yr, vr = ref.round_uplink_sharded_ref(z, t, prox=prox, rho_eff=0.25)
    np.testing.assert_array_equal(np.asarray(y), np.asarray(yr))
    np.testing.assert_array_equal(np.asarray(v), np.asarray(vr))

    x = _stack(jax.random.fold_in(key, 2), n, m)
    w = _stack(jax.random.fold_in(key, 3), n, m)
    u = jax.random.bernoulli(jax.random.fold_in(key, 4), 0.5,
                             (n,)).astype(jnp.float32)
    xn, zn = ops.round_downlink_sharded(x, w, z, y, u, mesh=mesh,
                                        damping=0.65)
    dref = jax.jit(ref.round_downlink_presummed_ref,
                   static_argnames=("damping",))
    xr, zr = dref(x, w, z, u, y, damping=0.65)
    np.testing.assert_array_equal(np.asarray(xn), np.asarray(xr))
    np.testing.assert_array_equal(np.asarray(zn), np.asarray(zr))


@multi_device
def test_sharded_ops_multi_device_ulp_close():
    """Across 8 agent shards the psum's combine order is the device
    ring's, not the host's -- parity with the whole-buffer oracle is
    fp32-rounding, and the downlink (purely local rows) stays bitwise
    given the same y."""
    n, m = 32, 640
    key = jax.random.PRNGKey(3)
    z = _stack(key, n, m)
    mesh = _mesh(8, 1)
    prox = prox_lib.prox_l1
    y, v = ops.round_uplink_sharded(z, mesh=mesh, n_total=n, prox=prox,
                                    rho_eff=0.3)
    ref_jit = jax.jit(ref.round_uplink_sharded_ref,
                      static_argnames=("prox", "rho_eff"))
    yr, vr = ref_jit(z, prox=prox, rho_eff=0.3)
    _assert_trees_ulp_close((y, v), (yr, vr))

    x = _stack(jax.random.fold_in(key, 1), n, m)
    w = _stack(jax.random.fold_in(key, 2), n, m)
    u = jax.random.bernoulli(jax.random.fold_in(key, 3), 0.5,
                             (n,)).astype(jnp.float32)
    xn, zn = ops.round_downlink_sharded(x, w, z, y, u, mesh=mesh,
                                        damping=0.5)
    dref = jax.jit(ref.round_downlink_presummed_ref,
                   static_argnames=("damping",))
    _assert_trees_equal((xn, zn), dref(x, w, z, u, y, damping=0.5))


def test_sharded_edge_launch_count():
    """On the TPU schedule each shard's round edges are exactly TWO
    pallas launches: the partial-sum uplink and the presummed downlink
    (the psum itself is a collective, not a kernel)."""
    n, m = 8, 4096
    mesh = _mesh(1, 1)

    def count(jaxpr, name):
        total = 0
        for eqn in jaxpr.eqns:
            if eqn.primitive.name == name:
                total += 1
            for v in eqn.params.values():
                for vv in (v if isinstance(v, (list, tuple)) else [v]):
                    inner = getattr(vv, "jaxpr", None)
                    if inner is not None:
                        total += count(inner, name)
                    elif hasattr(vv, "eqns"):
                        total += count(vv, name)
        return total

    def tpu_edges(x, w, z, u):
        y, v = ops.round_uplink_sharded(z, mesh=mesh, n_total=n,
                                        prox=prox_lib.prox_l1,
                                        rho_eff=0.2, interpret=False)
        xn, zn = ops.round_downlink_sharded(x, w, z, y, u, mesh=mesh,
                                            damping=0.5,
                                            interpret=False)
        return v, xn, zn

    z = jnp.zeros((n, m))
    jaxpr = jax.make_jaxpr(tpu_edges)(z, z, z, jnp.zeros((n,)))
    assert count(jaxpr.jaxpr, "pallas_call") == 2


# ---------------------------------------------------------------------------
# Engine tier: a 1x1 mesh is the degenerate case of one code path
# ---------------------------------------------------------------------------

COMPRESSORS = [
    CompressionSpec("none"),
    CompressionSpec("topk", ratio=0.3, backend="xla"),
    CompressionSpec("int8", backend="pallas"),
]


def _dense_run(prob, spec, rounds=5):
    trainer = build_trainer(prob, spec)
    state, hist = trainer.run(jax.random.PRNGKey(1), rounds)
    return state, np.asarray(hist)


@pytest.mark.parametrize("layout", ["tree", "packed"])
@pytest.mark.parametrize("backend", ["xla", "pallas"])
@pytest.mark.parametrize("comp", COMPRESSORS,
                         ids=[c.name for c in COMPRESSORS])
def test_mesh_of_one_bitwise_matrix(layout, backend, comp):
    """Sharded (1x1 mesh) vs unsharded trajectories, bitwise, on every
    state_layout x engine_backend x compressor combination."""
    prob = make_logreg_problem(n_agents=6, q=20, dim=12, seed=0)
    kw = dict(state_layout=layout, engine_backend=backend,
              compression=comp, n_epochs=2, participation=0.7,
              damping=0.6)
    s0, h0 = _dense_run(prob, FedSpec(**kw))
    s1, h1 = _dense_run(prob, FedSpec(mesh_shape="1x1", **kw))
    _assert_trees_equal(jax.tree_util.tree_leaves(s0),
                        jax.tree_util.tree_leaves(s1))
    np.testing.assert_array_equal(h0, h1)


def test_mesh_of_one_bitwise_nonelementwise_prox():
    """A non-elementwise prox_h cannot fuse; under a mesh it runs the
    unsharded formula under GSPMD -- still bitwise at 1 device."""
    prob = make_logreg_problem(n_agents=4, q=20, dim=10, seed=0)
    kw = dict(state_layout="packed", engine_backend="pallas",
              prox_h="l2sq", n_epochs=2)
    s0, h0 = _dense_run(prob, FedSpec(**kw))
    s1, h1 = _dense_run(prob, FedSpec(agent_shards=1, mesh_shape="1x1",
                                      **kw))
    _assert_trees_equal(jax.tree_util.tree_leaves(s0),
                        jax.tree_util.tree_leaves(s1))
    np.testing.assert_array_equal(h0, h1)


@multi_device
@pytest.mark.parametrize("layout", ["tree", "packed"])
@pytest.mark.parametrize("backend", ["xla", "pallas"])
def test_eight_device_trajectory_ulp_close(layout, backend):
    """8 agent shards vs 1 device: equal to fp32 rounding (the psum
    reorders the coordinator reduction)."""
    prob = make_logreg_problem(n_agents=8, q=20, dim=12, seed=0)
    kw = dict(state_layout=layout, engine_backend=backend, n_epochs=2,
              damping=0.7)
    s0, h0 = _dense_run(prob, FedSpec(**kw))
    s8, h8 = _dense_run(prob, FedSpec(agent_shards=8, **kw))
    _assert_trees_ulp_close(jax.tree_util.tree_leaves(s0),
                            jax.tree_util.tree_leaves(s8))
    np.testing.assert_allclose(h0, h8, rtol=1e-4, atol=1e-7)


@multi_device
def test_eight_device_model_axis_trajectory():
    """A 4x2 mesh additionally shards the packed buffer's columns over
    'model' -- same fp32-rounding bar."""
    prob = make_logreg_problem(n_agents=8, q=20, dim=12, seed=0)
    kw = dict(state_layout="packed", engine_backend="pallas", n_epochs=2)
    s0, h0 = _dense_run(prob, FedSpec(**kw))
    s4, h4 = _dense_run(prob, FedSpec(mesh_shape="4x2", **kw))
    _assert_trees_ulp_close(jax.tree_util.tree_leaves(s0),
                            jax.tree_util.tree_leaves(s4))


@multi_device
def test_async_k0_sharded_matches_sync_sharded_bitwise():
    """max_staleness=0 async rounds == synchronous rounds bitwise per
    realization -- the contract must survive the mesh."""
    prob = make_logreg_problem(n_agents=8, q=20, dim=12, seed=0)
    kw = dict(state_layout="packed", engine_backend="pallas",
              agent_shards=8, participation=0.6, n_epochs=2)
    sync, _ = _dense_run(prob, FedSpec(**kw))
    stale, _ = _dense_run(prob, FedSpec(async_mode="stale",
                                        max_staleness=0, **kw))
    np.testing.assert_array_equal(np.asarray(sync.x),
                                  np.asarray(stale.x))
    np.testing.assert_array_equal(np.asarray(sync.z),
                                  np.asarray(stale.z))


@multi_device
def test_per_agent_privacy_tables_identical_under_mesh():
    """The Prop. 4 per-agent (eps_i, delta) table is a function of the
    spec, not the placement -- sharded and unsharded trainers must
    report identical budgets."""
    prob = make_logreg_problem(n_agents=8, q=20, dim=10, seed=0)
    kw = dict(n_epochs=2, privacy=PrivacySpec(tau=0.1, clip=1.0),
              agent_groups="4*gd:participation=0.5,4*gd")
    qs = list(range(10, 18))
    reps = []
    for extra in ({}, {"agent_shards": 8}):
        trainer = build_trainer(prob, FedSpec(**kw, **extra))
        reps.append(trainer.privacy_report(6, qs))
    a, b = reps
    assert a.adp_eps == b.adp_eps
    for ra, rb in zip(a.per_agent, b.per_agent):
        assert (ra.adp_eps, ra.eps_ceiling) == (rb.adp_eps,
                                                rb.eps_ceiling)


# ---------------------------------------------------------------------------
# Validation: actionable errors at spec and engine level
# ---------------------------------------------------------------------------

def test_spec_rejects_non_divisible_agents():
    with pytest.raises(ValueError, match="not divisible by"):
        FedSpec(n_agents=6, agent_shards=4).validate()


def test_spec_rejects_straddling_groups():
    with pytest.raises(ValueError, match="straddle"):
        FedSpec(n_agents=8, agent_shards=4,
                agent_groups=(AgentGroupSpec(size=3),
                              AgentGroupSpec(size=5))).validate()


def test_spec_rejects_malformed_mesh_shape():
    with pytest.raises(ValueError, match="AGENTSxMODEL"):
        FedSpec(mesh_shape="8").validate()
    with pytest.raises(ValueError, match="integers"):
        FedSpec(mesh_shape="ax1").validate()
    with pytest.raises(ValueError, match="disagrees"):
        FedSpec(agent_shards=2, mesh_shape="4x1").validate()


def test_spec_rejects_oversized_mesh():
    n = len(jax.devices())
    with pytest.raises(ValueError, match="device_count"):
        FedSpec(n_agents=2 * (n + 1),
                agent_shards=n + 1).validate().build_mesh()


def test_round_config_rejects_bad_shards():
    with pytest.raises(ValueError, match="agent_shards"):
        engine.RoundConfig(n_agents=4, agent_shards=0)
    with pytest.raises(ValueError, match="equal"):
        engine.RoundConfig(n_agents=6, agent_shards=4)


def test_validate_mesh_rejects_shard_mismatch():
    cfg = engine.RoundConfig(n_agents=8, agent_shards=8)
    with pytest.raises(ValueError, match="agent_shards=8"):
        engine.validate_mesh(cfg, _mesh(1, 1))


def test_validate_mesh_requires_agent_axis():
    from jax.sharding import Mesh

    mesh = Mesh(np.asarray(jax.devices()[:1]).reshape(1, 1),
                ("rows", "cols"))
    with pytest.raises(ValueError, match="'agent'"):
        engine.mesh_agent_shards(mesh)


@multi_device
def test_validate_mesh_rejects_straddling_solver_groups():
    solver = lambda v, k: v  # noqa: E731 -- never called by validation
    groups = (engine.SolverGroup(3, solver), engine.SolverGroup(5, solver))
    cfg = engine.RoundConfig(n_agents=8, agent_shards=4)
    with pytest.raises(ValueError, match="inside an agent shard"):
        engine.validate_mesh(cfg, _mesh(4, 1), groups)
    # aligned groups (and 1-row shards, where any cut aligns) pass
    ok = (engine.SolverGroup(4, solver), engine.SolverGroup(4, solver))
    engine.validate_mesh(cfg, _mesh(4, 1), ok)
    engine.validate_mesh(engine.RoundConfig(n_agents=8, agent_shards=8),
                         _mesh(8, 1), groups)


# ---------------------------------------------------------------------------
# CLI round trip
# ---------------------------------------------------------------------------

def test_cli_shard_flags_roundtrip():
    spec = spec_from_args(["--agent-shards", "2"])
    assert spec.agent_shards == 2 and spec.resolved_agent_shards() == 2
    spec = spec_from_args(["--mesh-shape", "2x1"])
    assert spec.mesh_axes() == (2, 1)
    assert spec_from_args([]).mesh_axes() is None
