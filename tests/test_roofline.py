"""Roofline machinery: analytic FLOPs model, HLO byte conventions,
collective pricing, trip multipliers."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import SHAPES, get_config
from repro.launch import roofline
from repro.launch.hlo_analysis import HloAnalyzer, analyze_text
from repro.models.model import build_model


# ---------------------------------------------------------------------------
# Analytic model
# ---------------------------------------------------------------------------

def test_active_params_match_eval_shape_dense():
    """For dense archs the analytic active-param count should be within a
    few % of the true parameter count (it IS the parameter count)."""
    for arch in ("phi4-mini-3.8b", "gemma2-2b", "nemotron-4-340b"):
        cfg = get_config(arch)
        model = build_model(cfg)
        true = sum(int(np.prod(l.shape)) for l in jax.tree_util.tree_leaves(
            jax.eval_shape(model.init, jax.random.PRNGKey(0))))
        approx = roofline.active_param_count(cfg)
        assert abs(approx - true) / true < 0.05, (arch, approx, true)


def test_moe_active_less_than_total():
    cfg = get_config("grok-1-314b")
    model = build_model(cfg)
    total = sum(int(np.prod(l.shape)) for l in jax.tree_util.tree_leaves(
        jax.eval_shape(model.init, jax.random.PRNGKey(0))))
    active = roofline.active_param_count(cfg)
    # top-2 of 8 experts => roughly a quarter of expert params active
    assert active < 0.55 * total


def test_model_flops_scaling():
    cfg = get_config("gemma2-2b")
    f_train = roofline.model_flops(cfg, SHAPES["train_4k"], "train")
    f_prefill = roofline.model_flops(cfg, SHAPES["prefill_32k"], "prefill")
    f_decode = roofline.model_flops(cfg, SHAPES["decode_32k"], "decode")
    assert f_train == pytest.approx(
        6 * roofline.active_param_count(cfg) * 256 * 4096)
    assert f_prefill == pytest.approx(f_train / 3.0)  # same tokens, 2ND
    assert f_decode < f_prefill / 1e3                 # one token per seq


# ---------------------------------------------------------------------------
# HLO analyzer conventions
# ---------------------------------------------------------------------------

def _hlo(f, *args):
    return jax.jit(f).lower(*args).compile().as_text()


def test_dynamic_slice_counts_slice_not_operand():
    """Scanning over a big xs array must not charge the whole array per
    iteration (the bug that inflated the mamba 'seq' iteration)."""
    big = jnp.ones((64, 256))

    def f(big):
        def body(acc, row):
            return acc + row.sum(), None

        acc, _ = jax.lax.scan(body, 0.0, big)
        return acc

    costs = analyze_text(_hlo(f, big))
    # traffic should be O(one pass over big) = 64KB-ish, far below
    # 64 iterations x full array (4MB)
    assert costs.bytes < 20 * big.size * 4


def test_trip_multiplier_exposed():
    def body(x, _):
        return x @ x, None

    def f(x):
        y, _ = jax.lax.scan(body, x, None, length=5)
        return y.sum()

    an = HloAnalyzer(_hlo(f, jnp.ones((32, 32))))
    mult = an.comp_multipliers()
    assert any(abs(m - 5.0) < 1e-6 for m in mult.values())


def test_collective_pricing_all_reduce_2x():
    text = """
ENTRY %main (p0: f32[128]) -> f32[128] {
  %p0 = f32[128]{0} parameter(0)
  ROOT %ar = f32[128]{0} all-reduce(%p0), to_apply=%add
}
"""
    costs = analyze_text(text)
    assert costs.coll_by_kind["all-reduce"] == 2 * 128 * 4


def test_top_collectives_sorted():
    text = """
ENTRY %main (p0: f32[128]) -> f32[128] {
  %p0 = f32[128]{0} parameter(0)
  %ag = f32[1024]{0} all-gather(%p0), dimensions={0}
  ROOT %ar = f32[128]{0} all-reduce(%p0), to_apply=%add
}
"""
    an = HloAnalyzer(text)
    tops = an.top_collectives(5)
    assert tops[0][1] == "all-gather"        # 4096B > 2x512B
    assert tops[0][0] >= tops[-1][0]
