"""Packed-resident state layout: the engine layout contract.

Packed-resident ``(N, M_total)`` trajectories must be BITWISE identical
to the tree-resident path per realization -- across both engine
backends, both front ends, heterogeneous groups, per-agent
participation, every registry compressor, and the two solver-stream
fallbacks (noisy_gd / clipped runs).  On top of parity: the zero
concatenate/gather property of a packed round's state path
(``engine.count_primitives``), checkpoint save -> load -> resume
equality, the compress ``auto`` backend heuristic, and the single-leaf
pack fast path.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.base import InputShape
from repro.core.problem import make_logreg_problem
from repro.core.solvers import SolverConfig
from repro.data.synthetic import make_batch_for
from repro.fed import compress as compress_lib
from repro.fed import engine, runtime
from repro.fed.api import (CompressionSpec, FedSpec, PrivacySpec,
                           build_trainer, spec_from_args)
from repro.fed.compress import (pack_leaves, packed_meta, resolve_backend,
                                unpack_leaves)
from repro.fed.solvers import (PACKED_DIRECT_SOLVERS,
                               make_packed_local_solver)
from repro.models.model import build_model

# ---------------------------------------------------------------------------
# Dense front end: packed == tree, bit for bit
# ---------------------------------------------------------------------------

N_AGENTS = 6
ROUNDS = 4


@pytest.fixture(scope="module")
def logreg():
    return make_logreg_problem(n_agents=N_AGENTS, q=25, dim=16, seed=0)


def _dense_pair(problem, **kw):
    """(tree_state, packed_state, tree_crit, packed_crit) after ROUNDS."""
    out = []
    for layout in ("tree", "packed"):
        tr = build_trainer(problem, FedSpec(state_layout=layout, **kw))
        state, crit = tr.run(jax.random.PRNGKey(3), ROUNDS)
        out += [state, np.asarray(crit)]
    return out


DENSE_CASES = [
    dict(gamma=0.05, weight_decay=0.01, damping=0.7),
    dict(gamma=0.05, participation=0.6),
    dict(gamma=0.05, compression=CompressionSpec(name="topk", ratio=0.5)),
    dict(gamma=0.05, compression=CompressionSpec(name="int8")),
    dict(gamma=0.05,
         compression=CompressionSpec(name="adaptive_topk", energy=0.8)),
    dict(gamma=0.05, agent_groups="3*gd,3*agd:n_epochs=2"),
    dict(gamma=0.05, privacy=PrivacySpec(tau=0.05, clip=1.0)),
]


@pytest.mark.parametrize("backend", engine.ENGINE_BACKENDS)
@pytest.mark.parametrize("kw", DENSE_CASES,
                         ids=lambda kw: next(iter(
                             kw.get("compression").name.split()
                             if kw.get("compression") else
                             [k for k in kw if k != "gamma"] or ["plain"])))
def test_dense_packed_matches_tree_bitwise(logreg, backend, kw):
    s_tree, c_tree, s_packed, c_packed = _dense_pair(
        logreg, engine_backend=backend, **kw)
    # dense single-leaf state: the packed buffer IS the (N, n) array
    np.testing.assert_array_equal(np.asarray(s_tree.x),
                                  np.asarray(s_packed.x))
    np.testing.assert_array_equal(np.asarray(s_tree.z),
                                  np.asarray(s_packed.z))
    if s_tree.t is not None:
        np.testing.assert_array_equal(np.asarray(s_tree.t),
                                      np.asarray(s_packed.t))
    np.testing.assert_array_equal(c_tree, c_packed)


# ---------------------------------------------------------------------------
# Model-scale front end: packed == tree, bit for bit
# ---------------------------------------------------------------------------

SHAPE = InputShape("t", 4, 4, "train")


@pytest.fixture(scope="module")
def tiny_model():
    cfg = get_config("gemma2-2b").reduced(n_layers=1, d_model=64, vocab=128)
    return cfg, build_model(cfg)


def _model_run(model, cfg, spec, n_rounds=2, n_agents=2):
    step = jax.jit(runtime.make_train_step(model, spec))
    state = runtime.init_state(model, jax.random.PRNGKey(0), spec)
    batch = make_batch_for(cfg, SHAPE, n_agents=n_agents)
    losses = []
    for i in range(n_rounds):
        state, m = step(state, batch, jax.random.PRNGKey(7))
        losses.append(float(m["loss"]))
    return state, losses


def _as_flat(x, meta=None):
    if meta is not None:
        x = unpack_leaves(x, meta)
    return np.concatenate([np.asarray(l).reshape(l.shape[0], -1)
                           for l in jax.tree_util.tree_leaves(x)], axis=1)


MODEL_CASES = [
    ("xla", dict(weight_decay=0.01)),
    ("pallas", dict(weight_decay=0.01)),
    ("pallas", dict(compression=CompressionSpec(name="int8"))),
    ("pallas", dict(compression=CompressionSpec(name="adaptive_topk",
                                                energy=0.8))),
    ("xla", dict(compression=CompressionSpec(name="topk", ratio=0.5),
                 participation=0.7)),
    ("pallas", dict(agent_groups="1*gd,1*agd:n_epochs=1")),
    # solver-stream fallbacks: per-leaf DP noise / clip reductions
    ("xla", dict(privacy=PrivacySpec(tau=0.05, clip=1.0))),
]


@pytest.mark.parametrize("backend,kw", MODEL_CASES,
                         ids=[f"{b}-{next(iter(k))}" for b, k in MODEL_CASES])
def test_model_packed_matches_tree_bitwise(tiny_model, backend, kw):
    cfg, model = tiny_model
    base = dict(n_agents=2, n_epochs=2, gamma=0.1, engine_backend=backend)
    spec_t = FedSpec(state_layout="tree", **base, **kw)
    spec_p = FedSpec(state_layout="packed", **base, **kw)
    s_t, l_t = _model_run(model, cfg, spec_t)
    s_p, l_p = _model_run(model, cfg, spec_p)
    meta = runtime.packed_layout(model, spec_p)
    np.testing.assert_array_equal(_as_flat(s_t.x), _as_flat(s_p.x, meta))
    np.testing.assert_array_equal(_as_flat(s_t.z), _as_flat(s_p.z, meta))
    if s_t.t is not None:
        np.testing.assert_array_equal(_as_flat(s_t.t),
                                      _as_flat(s_p.t, meta))
    assert l_t == l_p
    # API boundary: consensus unpacks to the same deployable model
    cons_t = runtime.consensus_model(s_t)
    cons_p = runtime.consensus_model(s_p, meta=meta)
    for a, b in zip(jax.tree_util.tree_leaves(cons_t),
                    jax.tree_util.tree_leaves(cons_p)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_model_packed_state_is_one_buffer(tiny_model):
    cfg, model = tiny_model
    spec = FedSpec(n_agents=2, n_epochs=1, gamma=0.1, state_layout="packed")
    state = runtime.init_state(model, jax.random.PRNGKey(0), spec)
    meta = runtime.packed_layout(model, spec)
    assert isinstance(state.x, jnp.ndarray)
    assert state.x.shape == (2, meta.width)
    # the round keeps the state resident: output is the same single buffer
    step = jax.jit(runtime.make_train_step(model, spec))
    batch = make_batch_for(cfg, SHAPE, n_agents=2)
    state, _ = step(state, batch, jax.random.PRNGKey(1))
    assert state.x.shape == (2, meta.width)


# ---------------------------------------------------------------------------
# Checkpoint: save -> load -> resume == uninterrupted, bit for bit
# ---------------------------------------------------------------------------

def test_packed_checkpoint_roundtrip_and_resume(tiny_model, tmp_path):
    from repro.checkpoint.io import (checkpoint_extra, checkpoint_step,
                                     packed_layout_manifest,
                                     restore_checkpoint, save_checkpoint)

    cfg, model = tiny_model
    spec = FedSpec(n_agents=2, n_epochs=1, gamma=0.1, state_layout="packed",
                   compression=CompressionSpec(name="topk", ratio=0.5))
    meta = runtime.packed_layout(model, spec)
    step = jax.jit(runtime.make_train_step(model, spec))
    batch = make_batch_for(cfg, SHAPE, n_agents=2)

    state = runtime.init_state(model, jax.random.PRNGKey(0), spec)
    state, _ = step(state, batch, jax.random.PRNGKey(1))

    path = str(tmp_path / "ckpt")
    save_checkpoint(path, state, step=1,
                    extra=packed_layout_manifest(meta))
    like = jax.tree_util.tree_map(
        lambda l: jax.ShapeDtypeStruct(l.shape, l.dtype), state)
    restored = restore_checkpoint(path, like)
    assert checkpoint_step(path) == 1

    # the manifest records the buffer geometry for restore validation
    extra = checkpoint_extra(path)
    assert extra["state_layout"] == "packed"
    assert extra["width"] == meta.width
    assert [tuple(s) for s in extra["segments"]] == list(meta.segments)

    # resume from the restored buffers == uninterrupted, bitwise
    s_cont, _ = step(state, batch, jax.random.PRNGKey(2))
    s_res, _ = step(restored, batch, jax.random.PRNGKey(2))
    np.testing.assert_array_equal(np.asarray(s_cont.x), np.asarray(s_res.x))
    np.testing.assert_array_equal(np.asarray(s_cont.z), np.asarray(s_res.z))
    np.testing.assert_array_equal(np.asarray(s_cont.t), np.asarray(s_res.t))


def test_checkpoint_extra_absent_is_none(tmp_path):
    from repro.checkpoint.io import checkpoint_extra, save_checkpoint

    path = str(tmp_path / "plain")
    save_checkpoint(path, {"a": jnp.zeros(3)}, step=0)
    assert checkpoint_extra(path) is None


# ---------------------------------------------------------------------------
# The zero-concatenate property: jaxpr op counts on the state path
# ---------------------------------------------------------------------------

def _ragged_tree(n=4):
    return {"a": jnp.ones((n, 3, 5)), "b": jnp.ones((n, 17)),
            "c": jnp.ones((n, 2, 2, 2))}


def _packed_round_jaxpr(backend, comp):
    tree = _ragged_tree()
    meta = packed_meta(tree)
    buf, _ = pack_leaves(tree)

    def fgrad(w, k):
        return jax.tree_util.tree_map(lambda l: 0.1 * l, w)

    scfg = SolverConfig(name="gd", n_epochs=2, step_size=0.1)
    spec = FedSpec(
        n_agents=4, engine_backend=backend, state_layout="packed",
        gamma=0.1, participation=0.9,
        compression=(CompressionSpec(name=comp, ratio=0.5)
                     if comp != "none" else CompressionSpec()))
    ecfg = spec.round_config()
    solver = make_packed_local_solver(scfg, fgrad, spec.rho, 0.1, 1.0,
                                      meta=meta)
    return jax.make_jaxpr(
        lambda x, z, t, k: engine.packed_round_step(
            ecfg, meta, x, z, t, k, solver))(
        buf, buf, buf, jax.random.PRNGKey(0)).jaxpr


@pytest.mark.parametrize("backend", engine.ENGINE_BACKENDS)
@pytest.mark.parametrize("comp", ["none", "topk", "int8"])
def test_packed_round_state_path_has_zero_concatenates(backend, comp):
    """The layout contract's headline property: a packed round contains
    ZERO concatenate ops -- state never leaves the resident buffer.  The
    only layout traffic left is the gradient oracle's static
    update-slice chain (values, not state): 3 leaves uncompressed, +3
    for the compressed per-segment write-back under xla."""
    counts = engine.count_primitives(
        _packed_round_jaxpr(backend, comp),
        ["concatenate", "dynamic_update_slice"])
    assert counts["concatenate"] == 0
    assert counts["dynamic_update_slice"] <= 6


@pytest.mark.parametrize("comp", ["none", "int8"])
def test_packed_round_state_path_has_zero_gathers(comp):
    # topk excluded: rank_select's index arithmetic gathers *values*
    counts = engine.count_primitives(
        _packed_round_jaxpr("pallas", comp), ["gather"])
    assert counts["gather"] == 0


def test_packed_removes_per_edge_repacking():
    """Under the pallas backend the tree layout pays a pack/unpack
    update-slice chain at every round edge; the packed layout pays only
    the oracle's (one pack of the gradient tree)."""
    tree = _ragged_tree()

    def fgrad(w, k):
        return jax.tree_util.tree_map(lambda l: 0.1 * l, w)

    scfg = SolverConfig(name="gd", n_epochs=2, step_size=0.1)
    spec = FedSpec(n_agents=4, engine_backend="pallas", gamma=0.1)
    ecfg = spec.round_config()
    solver = engine.make_local_solver(scfg, fgrad, spec.rho, 0.1, 1.0)
    tree_jaxpr = jax.make_jaxpr(
        lambda x, z, t, k: engine.round_step(ecfg, x, z, t, k, solver))(
        tree, tree, tree, jax.random.PRNGKey(0)).jaxpr
    n_tree = engine.count_primitives(
        tree_jaxpr, ["dynamic_update_slice"])["dynamic_update_slice"]
    n_packed = engine.count_primitives(
        _packed_round_jaxpr("pallas", "none"),
        ["dynamic_update_slice"])["dynamic_update_slice"]
    n_leaves = len(tree)
    assert n_packed == n_leaves          # the oracle's single pack
    assert n_tree >= 3 * n_leaves        # per-edge repacking


def test_count_primitives_descends_into_subjaxprs():
    def f(x):
        def body(c, _):
            return jnp.concatenate([c, c])[:4], None
        return jax.lax.scan(body, x, None, length=2)[0]

    # the concatenate lives only in the scan body's sub-jaxpr: a
    # non-descending counter would report 0
    jaxpr = jax.make_jaxpr(f)(jnp.ones(4)).jaxpr
    assert engine.count_primitives(jaxpr, ["concatenate"]) == {
        "concatenate": 1}


# ---------------------------------------------------------------------------
# Compress backend "auto"
# ---------------------------------------------------------------------------

def _ccfg(name, backend="auto", ratio=0.5):
    spec = FedSpec(n_agents=4, gamma=0.1,
                   compression=CompressionSpec(name=name, ratio=ratio,
                                               backend=backend))
    return spec.round_config()


def test_auto_backend_dispatch():
    # explicit backends pass through untouched
    assert resolve_backend(_ccfg("topk", "pallas")) == "pallas"
    assert resolve_backend(_ccfg("topk", "xla")) == "xla"
    # adaptive_topk: pallas always (one fused pass beats xla's two)
    assert resolve_backend(_ccfg("adaptive_topk")) == "pallas"
    # topk: xla always (lax.top_k wins at every measured size)
    assert resolve_backend(_ccfg("topk")) == "xla"
    # int8: pallas only pays off on wide buffers
    assert resolve_backend(_ccfg("int8"), m_total=1 << 15) == "pallas"
    assert resolve_backend(_ccfg("int8"), m_total=1 << 10) == "xla"
    assert resolve_backend(_ccfg("int8")) == "xla"  # unknown width
    # compressors without a kernel never route to pallas
    assert resolve_backend(_ccfg("none")) == "xla"


def test_auto_is_the_default_backend():
    assert CompressionSpec().backend == "auto"
    assert FedSpec(n_agents=2, gamma=0.1).validate()  # validates clean


@pytest.mark.parametrize("name", ["topk", "int8", "adaptive_topk"])
def test_auto_backend_is_bit_identical(name):
    """auto is a pure scheduling choice: both backends are bit-identical
    (PR 5 parity contract), so auto must match each of them."""
    key = jax.random.PRNGKey(0)
    dz = jax.random.normal(key, (4, 4096))
    # jit each, as the engine does (eager XLA codegen differs by a ULP
    # in the int8 scale on some shapes; see test_compress_kernels)
    outs = [jax.jit(lambda v, b=backend: compress_lib.compress_rows(
        v, _ccfg(name, b)))(dz) for backend in ("auto", "xla", "pallas")]
    np.testing.assert_array_equal(np.asarray(outs[0]), np.asarray(outs[1]))
    np.testing.assert_array_equal(np.asarray(outs[0]), np.asarray(outs[2]))


# ---------------------------------------------------------------------------
# pack_leaves fast path + PackedMeta
# ---------------------------------------------------------------------------

def test_single_leaf_pack_skips_padding_and_copies():
    x = jnp.arange(4 * 23, dtype=jnp.float32).reshape(4, 23)
    buf, meta = pack_leaves({"w": x})
    assert meta.width == 23                    # no lane alignment
    assert buf.shape == (4, 23)
    np.testing.assert_array_equal(np.asarray(buf), np.asarray(x))
    # zero-copy: no update-slice chain, no pad in the traced program
    jaxpr = jax.make_jaxpr(lambda t: pack_leaves(t)[0])({"w": x}).jaxpr
    counts = engine.count_primitives(
        jaxpr, ["dynamic_update_slice", "pad", "concatenate"])
    assert counts == {"dynamic_update_slice": 0, "pad": 0,
                      "concatenate": 0}
    # and the round trip is exact
    out = unpack_leaves(buf, meta)
    np.testing.assert_array_equal(np.asarray(out["w"]), np.asarray(x))


def test_multi_leaf_pack_still_lane_aligned():
    buf, meta = pack_leaves(_ragged_tree())
    assert meta.width % 128 == 0
    assert meta.width == buf.shape[1]
    assert meta.m_total == sum(b - a for a, b in meta.segments)


def test_packed_meta_is_static_and_hashable():
    meta1 = packed_meta(_ragged_tree())
    meta2 = packed_meta(jax.tree_util.tree_map(
        lambda l: jax.ShapeDtypeStruct(l.shape, l.dtype), _ragged_tree()))
    assert meta1 == meta2                      # shapes only, no values
    assert {meta1: "jit-static"}[meta2] == "jit-static"


def test_unpack_leaves_row_slice():
    """Group buffers (row slices of the resident buffer) unpack with the
    same meta -- run_solvers' heterogeneous path depends on this."""
    tree = _ragged_tree(n=5)
    buf, meta = pack_leaves(tree)
    part = unpack_leaves(buf[1:3], meta)
    for k in tree:
        np.testing.assert_array_equal(np.asarray(part[k]),
                                      np.asarray(tree[k][1:3]))


def test_packed_direct_solver_registry():
    """gd/agd/sgd run on the buffer; noisy_gd and clipped runs must NOT
    (per-leaf noise folds / clip norms would change bit streams)."""
    assert set(PACKED_DIRECT_SOLVERS) == {"gd", "agd", "sgd"}
    assert "noisy_gd" not in PACKED_DIRECT_SOLVERS


# ---------------------------------------------------------------------------
# Spec / CLI / sharding plumbing
# ---------------------------------------------------------------------------

def test_state_layout_cli_roundtrip():
    spec = spec_from_args(["--state-layout", "packed",
                           "--compress-backend", "auto"])
    assert spec.state_layout == "packed"
    assert spec.compression.backend == "auto"
    assert spec_from_args([]).state_layout == "tree"


def test_state_layout_validated():
    with pytest.raises(ValueError, match="state layout"):
        FedSpec(n_agents=2, gamma=0.1, state_layout="bogus").validate()
    with pytest.raises(ValueError):
        engine.RoundConfig(n_agents=2, state_layout="bogus")


def test_fed_state_specs_packed():
    from jax.sharding import PartitionSpec as P

    from repro.fed.sharding import fed_state_specs

    stacked = jax.tree_util.tree_map(
        lambda l: jax.ShapeDtypeStruct(l.shape, l.dtype), _ragged_tree())
    specs = fed_state_specs(stacked, agent_axis="data", fsdp_axis="model",
                            axis_sizes={"data": 2, "model": 2},
                            compressed=True, packed=True)
    # one buffer spec per state var: rows on the agent axis, columns on
    # the fsdp axis (width is lane-aligned, so 2 always divides)
    assert specs.x == P("data", "model")
    assert specs.z == specs.x and specs.t == specs.x
    assert specs.step == P()
    # non-divisible column axis falls back to replicated columns
    odd = {"w": jax.ShapeDtypeStruct((4, 23), jnp.float32)}
    specs_odd = fed_state_specs(odd, agent_axis="data", fsdp_axis="model",
                                axis_sizes={"data": 2, "model": 2},
                                packed=True)
    assert specs_odd.x == P("data", None)
