"""Local solvers approximate prox_{rho f}; contraction factors behave."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.solvers import (SolverConfig, clip_grad, local_train,
                                solver_contraction)

# quadratic f(w) = 0.5 w^T Q w + b w  =>  prox closed form
Q = jnp.array([[3.0, 0.4], [0.4, 1.2]])
B = jnp.array([0.3, -0.8])
MU, L = 1.1, 3.1  # eigenvalue bounds of Q


def fgrad(w, key):
    del key
    return Q @ w + B


def closed_prox(v, rho):
    return jnp.linalg.solve(jnp.eye(2) + rho * Q, v - rho * B)


@pytest.mark.parametrize("name,n", [("gd", 200), ("agd", 100),
                                    ("sgd", 200)])
def test_solver_converges_to_prox(name, n):
    v = jnp.array([1.0, 2.0])
    rho = 0.7
    cfg = SolverConfig(name=name, n_epochs=n)
    w = local_train(fgrad, jnp.zeros(2), v, rho, cfg,
                    jax.random.PRNGKey(0), MU, L)
    np.testing.assert_allclose(w, closed_prox(v, rho), atol=1e-4)


def test_noisy_gd_concentrates_near_prox():
    v = jnp.array([1.0, 2.0])
    rho = 0.7
    cfg = SolverConfig(name="noisy_gd", n_epochs=100, tau=0.01)
    ws = jax.vmap(lambda k: local_train(fgrad, jnp.zeros(2), v, rho, cfg,
                                        k, MU, L))(
        jax.random.split(jax.random.PRNGKey(0), 64))
    np.testing.assert_allclose(jnp.mean(ws, axis=0), closed_prox(v, rho),
                               atol=0.02)


def test_warm_start_beats_cold_start():
    """The paper's key initialization: starting at the previous x is
    closer after few epochs than cold start when x is near the target."""
    v = jnp.array([1.0, 2.0])
    rho = 0.7
    target = closed_prox(v, rho)
    cfg = SolverConfig(name="gd", n_epochs=2)
    near = target + 0.01
    w_warm = local_train(fgrad, near, v, rho, cfg, jax.random.PRNGKey(0),
                         MU, L)
    w_cold = local_train(fgrad, jnp.zeros(2), v, rho, cfg,
                         jax.random.PRNGKey(0), MU, L)
    assert (jnp.linalg.norm(w_warm - target)
            < jnp.linalg.norm(w_cold - target))


def test_contraction_decreases_with_epochs():
    rho = 1.0
    vals = [solver_contraction(SolverConfig(name="gd", n_epochs=n),
                               MU, L, rho) for n in (1, 2, 5, 10)]
    assert all(a > b for a, b in zip(vals, vals[1:]))
    assert 0 < vals[-1] < 1


def test_agd_contraction_eventually_beats_gd():
    """Prop. 3: chi(N_e) has the accelerated sqrt(kappa) exponent, so for
    N_e past the (1+kappa) burn-in it beats GD's chi^N_e."""
    rho = 10.0  # ill-conditioned d => acceleration wins
    mu, lsm = 0.01, 50.0
    gd = solver_contraction(SolverConfig(name="gd", n_epochs=300),
                            mu, lsm, rho)
    agd = solver_contraction(SolverConfig(name="agd", n_epochs=300),
                             mu, lsm, rho)
    assert agd < gd < 1.0


def test_clip_grad():
    g = jnp.array([3.0, 4.0])
    np.testing.assert_allclose(clip_grad(g, 5.0), g)
    np.testing.assert_allclose(jnp.linalg.norm(clip_grad(g, 1.0)), 1.0,
                               atol=1e-6)


def test_empirical_contraction_matches_bound():
    """|local_train(x) - local_train(y)| <= chi^Ne |x - y|."""
    v = jnp.array([0.5, -0.5])
    rho = 1.0
    cfg = SolverConfig(name="gd", n_epochs=3)
    chi_ne = solver_contraction(cfg, MU, L, rho)
    x, y = jnp.array([2.0, -1.0]), jnp.array([-1.0, 3.0])
    k = jax.random.PRNGKey(0)
    wx = local_train(fgrad, x, v, rho, cfg, k, MU, L)
    wy = local_train(fgrad, y, v, rho, cfg, k, MU, L)
    assert (jnp.linalg.norm(wx - wy)
            <= chi_ne * jnp.linalg.norm(x - y) + 1e-5)
