"""Per-agent heterogeneity: the solver registry, FedSpec.agent_groups
through both front ends, per-agent participation, and the per-agent
privacy table.

The safety contract that makes the feature cheap to adopt: a
*homogeneous* agent-group spec (one full-size group, knobs inherited)
is bit-identical to the legacy ungrouped path on every configuration
class (dense gd/agd, DP noise, compressed uplink, partial
participation)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.fedplt import FedPLT, FedPLTConfig
from repro.core.problem import make_quadratic_problem
from repro.core.solvers import SolverConfig
from repro.fed import runtime
from repro.fed.api import (AgentGroupSpec, CompressionSpec, FedSpec,
                           PrivacySpec, build_trainer, parse_agent_groups,
                           spec_from_args)
from repro.fed.solvers import (available_solvers, get_solver,
                               register_solver)


@pytest.fixture(scope="module")
def quad():
    return make_quadratic_problem(n_agents=5, dim=6, seed=3)


class QuadModel:
    def init(self, key):
        return {"x": jnp.zeros(6)}

    def loss_fn(self, params, batch, remat=False):
        x = params["x"]
        return 0.5 * x @ batch["Q"] @ x + batch["c"] @ x


def _quad_batch(quad):
    return {"Q": quad.Q, "c": quad.c}


# ---------------------------------------------------------------------------
# Solver registry
# ---------------------------------------------------------------------------

def test_solver_registry_has_builtins():
    for name in ("gd", "agd", "sgd", "noisy_gd"):
        assert name in available_solvers()


def test_unknown_solver_lists_registry():
    with pytest.raises(ValueError, match="unknown solver 'warp'.*"
                                         "registered:.*gd"):
        get_solver("warp")
    # ... and the same message surfaces from spec validation, both for
    # the top-level solver field and inside a group
    with pytest.raises(ValueError, match="registered:"):
        FedSpec(n_agents=2, gamma=0.1, agent_groups="2*warp").validate()


def test_registered_solver_usable_by_name(quad):
    """Extensibility proof mirroring the compressor registry: a solver
    registered here drives a group purely through FedSpec."""
    calls = []

    @register_solver("half_gd_test")
    def make_half_gd(scfg, fgrad, rho, mu, L, *, use_pallas, has_aux):
        from repro.core.solvers import local_train

        calls.append(1)
        half = SolverConfig(name="gd", n_epochs=scfg.n_epochs,
                            step_size=(scfg.step_size or 0.1) / 2.0)

        def solver(x, v, key):
            out = local_train(fgrad, x, v, rho, half, key, mu, L,
                              batched=True, has_aux=has_aux,
                              use_pallas=use_pallas)
            return out if has_aux else (out, None)

        return solver

    spec = FedSpec(n_agents=5, gamma=0.1, n_epochs=2,
                   agent_groups="3*gd,2*half_gd_test")
    trainer = build_trainer(QuadModel(), spec)
    state, hist = trainer.run(jax.random.PRNGKey(0), 5,
                              lambda i: _quad_batch(quad))
    assert calls, "registered solver factory was never dispatched"
    assert np.isfinite(hist[-1]["loss"])


def test_registered_solver_reaches_dense_front_end(quad):
    """A registry solver must also drive the dense (paper) path: the
    spec validates against the registry, so the trainer must dispatch
    through it rather than crash at trace time."""

    @register_solver("tiny_gd_test")
    def make_tiny_gd(scfg, fgrad, rho, mu, L, *, use_pallas, has_aux):
        from repro.core.solvers import local_train

        tiny = SolverConfig(name="gd", n_epochs=scfg.n_epochs,
                            step_size=0.05)

        def solver(x, v, key):
            out = local_train(fgrad, x, v, rho, tiny, key, mu, L,
                              batched=True, has_aux=has_aux,
                              use_pallas=use_pallas)
            return out if has_aux else (out, None)

        return solver

    spec = FedSpec(n_epochs=2, solver="tiny_gd_test")
    spec_g = FedSpec(n_epochs=2, agent_groups="3*gd,2*tiny_gd_test")
    for s in (spec, spec_g):
        state, crit = build_trainer(quad, s).run(jax.random.PRNGKey(0), 10)
        assert np.isfinite(np.asarray(crit)).all()


def test_custom_solver_with_tau_rejected():
    """Prop. 4 certifies NOISY local GD: a custom registered solver
    (which the accountant knows nothing about) must not silently earn a
    DP certificate just because tau was set."""
    register_solver("no_noise_test")(lambda *a, **k: None)
    with pytest.raises(ValueError, match="gd-type solver, not "
                                         "'no_noise_test'"):
        FedSpec(n_agents=2, gamma=0.1, solver="no_noise_test",
                privacy=PrivacySpec(tau=0.5)).validate()
    with pytest.raises(ValueError, match="gd-type solver"):
        FedSpec(n_agents=2, gamma=0.1,
                agent_groups="2*no_noise_test",
                privacy=PrivacySpec(tau=0.5)).validate()


def test_custom_solver_without_aux_trains_at_model_scale(quad):
    """A registry solver that returns aux=None (as the docstring
    permits) must not crash the model-scale loss metric: its agents
    drop out of the mean instead."""

    @register_solver("no_aux_gd_test")
    def make_no_aux_gd(scfg, fgrad, rho, mu, L, *, use_pallas, has_aux):
        from repro.core.solvers import local_train

        plain = SolverConfig(name="gd", n_epochs=scfg.n_epochs,
                             step_size=scfg.step_size)

        def solver(x, v, key):
            w = local_train(lambda w_, k: fgrad(w_, k)[0], x, v, rho,
                            plain, key, mu, L, batched=True)
            return w, None    # deliberately discards the loss trace

        return solver

    spec = FedSpec(n_agents=5, gamma=0.05, n_epochs=2,
                   agent_groups="3*gd,2*no_aux_gd_test")
    trainer = build_trainer(QuadModel(), spec)
    state, hist = trainer.run(jax.random.PRNGKey(0), 3,
                              lambda i: _quad_batch(quad))
    assert np.isfinite(hist[-1]["loss"])   # gd group still reports


def test_privacy_report_rejects_string_q(quad):
    trainer = build_trainer(quad, FedSpec(
        n_epochs=3, privacy=PrivacySpec(tau=0.1)))
    with pytest.raises(TypeError, match="not a string"):
        trainer.privacy_report(10, local_dataset_size="250")


def test_core_solvers_constant_matches_registry():
    """fedplt's dense fast path keys off CORE_SOLVERS; every core name
    must actually be registered (drift guard)."""
    from repro.fed.solvers import CORE_SOLVERS

    for name in CORE_SOLVERS:
        assert name in available_solvers()


def test_run_solvers_accepts_bare_solver_group():
    from repro.fed import engine

    x = {"w": jnp.arange(6.0).reshape(3, 2)}
    solver = lambda xs, vs, k: (jax.tree_util.tree_map(
        lambda l: l + 1.0, xs), None)
    w_bare, _ = engine.run_solvers(engine.SolverGroup(3, solver),
                                   x, x, jax.random.PRNGKey(0), 3)
    w_seq, _ = engine.run_solvers([engine.SolverGroup(3, solver)],
                                  x, x, jax.random.PRNGKey(0), 3)
    np.testing.assert_array_equal(np.asarray(w_bare["w"]),
                                  np.asarray(w_seq["w"]))


# ---------------------------------------------------------------------------
# Homogeneous agent_groups == legacy path, bit for bit (dense)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("cfg_kw,solver_kw,spec_kw", [
    (dict(), dict(name="gd"), dict()),
    (dict(), dict(name="agd"), dict(solver="agd")),
    (dict(), dict(name="noisy_gd", tau=0.05),
     dict(privacy=PrivacySpec(tau=0.05))),
    (dict(participation=0.6), dict(name="gd"), dict(participation=0.6)),
    (dict(participation=0.7, compression="topk", compress_ratio=0.5,
          damping=0.5), dict(name="gd"),
     dict(participation=0.7, damping=0.5,
          compression=CompressionSpec(name="topk", ratio=0.5))),
])
def test_single_homogeneous_group_bit_identical(quad, cfg_kw, solver_kw,
                                                spec_kw):
    cfg = FedPLTConfig(rho=1.0,
                       solver=SolverConfig(n_epochs=3, **solver_kw),
                       **cfg_kw)
    key = jax.random.PRNGKey(11)
    s_ref, c_ref = FedPLT(quad, cfg).run(key, 25)
    spec = FedSpec(rho=1.0, n_epochs=3,
                   agent_groups=(AgentGroupSpec(size=quad.n_agents),),
                   **spec_kw)
    s_new, c_new = build_trainer(quad, spec).run(key, 25)
    np.testing.assert_array_equal(np.asarray(c_ref), np.asarray(c_new))
    np.testing.assert_array_equal(np.asarray(s_ref.x), np.asarray(s_new.x))
    np.testing.assert_array_equal(np.asarray(s_ref.z), np.asarray(s_new.z))


def test_multi_group_homogeneous_matches_legacy_closely(quad):
    """Two groups with identical knobs are the same algorithm; only the
    batched-slice op scheduling may differ, so allclose not bit-equal."""
    cfg = FedPLTConfig(rho=1.0, solver=SolverConfig(name="gd", n_epochs=3))
    key = jax.random.PRNGKey(11)
    _, c_ref = FedPLT(quad, cfg).run(key, 20)
    _, c_new = build_trainer(
        quad, FedSpec(n_epochs=3, agent_groups="2,3")).run(key, 20)
    np.testing.assert_allclose(np.asarray(c_new), np.asarray(c_ref),
                               rtol=2e-3, atol=1e-9)


def test_single_homogeneous_group_bit_identical_model_scale(quad):
    """Model path: grouped spec with one inheriting group == ungrouped
    spec, same bits (the engine's single-group pass-through)."""
    batch = _quad_batch(quad)

    def run(spec):
        state = runtime.init_state(QuadModel(), jax.random.PRNGKey(0),
                                   spec)
        step = jax.jit(runtime.make_train_step(QuadModel(), spec))
        losses = []
        for i in range(4):
            state, m = step(state, batch, jax.random.PRNGKey(i))
            losses.append(float(m["loss"]))
        return losses, state

    base = dict(n_agents=5, gamma=0.05, n_epochs=3,
                privacy=PrivacySpec(tau=0.05, clip=1.0))
    l_ref, s_ref = run(FedSpec(**base))
    l_grp, s_grp = run(FedSpec(**base, agent_groups="5"))
    assert l_ref == l_grp
    np.testing.assert_array_equal(np.asarray(s_ref.x["x"]),
                                  np.asarray(s_grp.x["x"]))
    np.testing.assert_array_equal(np.asarray(s_ref.z["x"]),
                                  np.asarray(s_grp.z["x"]))


# ---------------------------------------------------------------------------
# Mixed groups end to end
# ---------------------------------------------------------------------------

def test_mixed_gd_agd_groups_dense_converges(quad):
    spec = FedSpec(n_epochs=3, agent_groups="3*gd,2*agd:n_epochs=2")
    state, crit = build_trainer(quad, spec).run(jax.random.PRNGKey(0), 40)
    crit = np.asarray(crit)
    assert np.isfinite(crit).all()
    assert crit[-1] < crit[0] * 1e-3  # still solves the problem


def test_mixed_groups_model_scale_build_trainer(quad):
    """Acceptance: a mixed gd/agd two-group spec runs end-to-end through
    build_trainer with per-group epochs/step sizes, and the consensus
    model still reaches the quadratic optimum."""
    spec = FedSpec(n_agents=5, gamma=0.05, n_epochs=3,
                   agent_groups="3*gd,2*agd:n_epochs=2:gamma=0.04")
    trainer = build_trainer(QuadModel(), spec)
    state, hist = trainer.run(jax.random.PRNGKey(0), 40,
                              lambda i: _quad_batch(quad))
    err = float(jnp.linalg.norm(trainer.consensus(state)["x"]
                                - quad.solve()))
    assert err < 1e-3
    assert np.isfinite(hist[-1]["loss"])


def test_per_group_participation_draws_per_agent(quad):
    """A (nearly-)zero-participation group freezes its agents' states
    while the p=1 group keeps moving."""
    spec = FedSpec(n_epochs=2,
                   agent_groups="2*gd:participation=1e-6,3*gd")
    trainer = build_trainer(quad, spec)
    state, _ = trainer.run(jax.random.PRNGKey(0), 10)
    x = np.asarray(state.x)
    assert np.abs(x[:2]).max() == 0.0      # init was zeros; never active
    assert np.abs(x[2:]).min() > 0.0


def test_engine_rejects_mismatched_group_sizes(quad):
    from repro.fed import engine

    cfg = engine.RoundConfig(n_agents=4)
    x = jnp.zeros((4, 2))
    dummy = engine.SolverGroup(3, lambda x, v, k: (x, None))
    with pytest.raises(ValueError, match="cover 3 agents"):
        engine.round_step(cfg, x, x, x, jax.random.PRNGKey(0), [dummy])


def test_round_config_participation_vector_length_checked():
    from repro.fed import engine

    with pytest.raises(ValueError, match="2 entries for n_agents=3"):
        engine.RoundConfig(n_agents=3, participation=(0.5, 1.0))


# ---------------------------------------------------------------------------
# Spec validation + CLI
# ---------------------------------------------------------------------------

def test_group_sizes_must_partition_agent_axis():
    with pytest.raises(ValueError, match="sizes sum to 3.*n_agents=4"):
        FedSpec(n_agents=4, gamma=0.1, agent_groups="2*gd,1*agd").validate()


def test_group_knobs_validated():
    with pytest.raises(ValueError, match=r"n_epochs.*\(agent group 1\)"):
        FedSpec(n_agents=4, gamma=0.1,
                agent_groups="2*gd,2*gd:n_epochs=0").validate()
    with pytest.raises(ValueError, match=r"participation.*group 0"):
        FedSpec(n_agents=2, gamma=0.1,
                agent_groups="2*gd:participation=1.5").validate()
    with pytest.raises(ValueError, match="agd momentum needs L > mu"):
        FedSpec(n_agents=2, agent_groups="2*agd:gamma=2.0").validate()
    with pytest.raises(ValueError, match="gd-type solver, not 'agd'"):
        FedSpec(n_agents=2, gamma=0.1, agent_groups="2*agd",
                privacy=PrivacySpec(tau=0.1)).validate()


def test_parse_agent_groups_grammar():
    assert parse_agent_groups("2*gd,1*agd:n_epochs=2:gamma=0.5") == (
        AgentGroupSpec(size=2, solver="gd"),
        AgentGroupSpec(size=1, solver="agd", n_epochs=2, gamma=0.5))
    assert parse_agent_groups("3") == (AgentGroupSpec(size=3),)
    with pytest.raises(ValueError, match="integer size"):
        parse_agent_groups("gd*2")
    with pytest.raises(ValueError, match="unknown agent-group option"):
        parse_agent_groups("2*gd:epochs=3")


def test_agent_groups_cli_roundtrip(quad):
    spec = spec_from_args(["--n-agents", "5", "--gamma", "0.05",
                           "--agent-groups", "3*gd,2*agd:n_epochs=1"])
    assert spec.agent_groups == (
        AgentGroupSpec(size=3, solver="gd"),
        AgentGroupSpec(size=2, solver="agd", n_epochs=1))
    spec.validate()
    # the parsed spec drives a real heterogeneous fed train step
    step = jax.jit(runtime.make_train_step(QuadModel(), spec))
    state = runtime.init_state(QuadModel(), jax.random.PRNGKey(0), spec)
    state, m = step(state, _quad_batch(quad), jax.random.PRNGKey(0))
    assert np.isfinite(m["loss"])


# ---------------------------------------------------------------------------
# Per-agent privacy accounting
# ---------------------------------------------------------------------------

def test_per_agent_eps_monotone_in_q(quad):
    """Prop. 4: eps_i shrinks as the local dataset grows (unclipped
    sensitivity convention, where the bound scales as 1/q_i^2)."""
    trainer = build_trainer(quad, FedSpec(
        n_epochs=3, privacy=PrivacySpec(tau=0.1)))
    qs = [10, 20, 40, 80, 160]
    rep = trainer.privacy_report(50, local_dataset_size=qs)
    eps = [a.adp_eps for a in rep.per_agent]
    assert all(a > b for a, b in zip(eps, eps[1:]))
    assert rep.adp_eps == max(eps)
    assert [a.q for a in rep.per_agent] == qs


def test_grouped_spec_reports_per_agent_table(quad):
    """A heterogeneous spec yields a per-agent table even with one
    scalar q: eps_i varies with each group's epoch count, and the
    headline eps is the max."""
    spec = FedSpec(n_agents=5, gamma=0.05, rho=1.0,
                   privacy=PrivacySpec(tau=0.1, clip=1.0),
                   agent_groups="3*gd:n_epochs=1,2*gd:n_epochs=50")
    trainer = build_trainer(QuadModel(), spec)
    rep = trainer.privacy_report(20, local_dataset_size=100)
    assert len(rep.per_agent) == 5
    eps = [a.adp_eps for a in rep.per_agent]
    # more local epochs -> closer to the ceiling -> strictly more eps
    assert eps[4] > eps[0]
    assert rep.adp_eps == pytest.approx(max(eps))
    # ... but never above the K*Ne->inf ceiling (the paper's headline)
    for a in rep.per_agent:
        assert a.adp_eps <= a.eps_ceiling + 1e-9


def test_homogeneous_scalar_report_unchanged(quad):
    """No groups + scalar q keeps the historical scalar report (no
    per-agent table materialized)."""
    trainer = build_trainer(quad, FedSpec(
        n_epochs=5, privacy=PrivacySpec(tau=0.05, clip=1.0)))
    rep = trainer.privacy_report(30, local_dataset_size=100)
    assert rep.per_agent is None
    assert np.isfinite(rep.adp_eps) and rep.adp_eps > 0


def test_per_agent_q_length_mismatch_raises(quad):
    trainer = build_trainer(quad, FedSpec(
        n_epochs=3, privacy=PrivacySpec(tau=0.1)))
    with pytest.raises(ValueError, match="3 entries for n_agents=5"):
        trainer.privacy_report(10, local_dataset_size=[10, 20, 30])
