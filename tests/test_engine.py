"""Unified round engine: the dense front end reproduces the pre-refactor
trajectory bit-for-bit; model-scale pytree rounds support compression,
agd, the fused kernel, and the DP accountant."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.base import InputShape
from repro.core.fedplt import FedPLT, FedPLTConfig
from repro.core.problem import make_quadratic_problem
from repro.core.solvers import SolverConfig, clip_grad, local_train
from repro.data.synthetic import make_batch_for
from repro.fed import engine, runtime
from repro.models.model import build_model


# ---------------------------------------------------------------------------
# Pre-refactor reference: the historical core/fedplt.py round, inlined
# ---------------------------------------------------------------------------

def _reference_run(problem, rho, n_epochs, participation, n_rounds, key,
                   damping=1.0, compression="none", compress_ratio=0.25,
                   tau=0.0):
    """Verbatim re-implementation of the dense round as it existed before
    the engine refactor (gd / noisy_gd solvers, prox_h = 0)."""
    N = problem.n_agents
    mu = jnp.float32(problem.strong_convexity())
    L = jnp.float32(problem.smoothness())
    # same f32 arithmetic chain as the traced per-agent moduli
    gamma = 2.0 / ((L + 1.0 / rho) + (mu + 1.0 / rho))
    inv_rho = 1.0 / rho
    noise_scale = jnp.sqrt(2.0 * gamma) * tau
    data = (problem.Q, problem.c)

    def local_gd(data_i, x_i, v_i, key_i):
        def body(w, k):
            g = jax.grad(lambda xx: problem.local_loss(data_i, xx))(w)
            new = w - gamma * (g + inv_rho * (w - v_i))
            if tau > 0.0:
                _, k_noise = jax.random.split(k)
                new = new + noise_scale * jax.random.normal(k_noise,
                                                            w.shape)
            return new, None

        w, _ = jax.lax.scan(body, x_i, jax.random.split(key_i, n_epochs))
        return w

    def compress(dz):
        if compression == "topk":
            k = max(1, int(compress_ratio * dz.shape[-1]))

            def topk_row(row):
                thresh = jnp.sort(jnp.abs(row))[-k]
                return jnp.where(jnp.abs(row) >= thresh, row, 0.0)

            return jax.vmap(topk_row)(dz)
        if compression == "int8":
            scale = jnp.max(jnp.abs(dz), axis=-1, keepdims=True) / 127.0
            scale = jnp.maximum(scale, 1e-12)
            q = jnp.round(dz / scale).astype(jnp.int8)
            return q.astype(dz.dtype) * scale
        return dz

    compressed = compression != "none"

    def round_ref(state, _):
        x, z, t, key = state
        key, k_part, k_solve = jax.random.split(key, 3)
        z_seen = t if compressed else z
        y = jnp.mean(z_seen, axis=0)
        v = 2.0 * y[None, :] - z
        solver_keys = jax.random.split(k_solve, N)
        w = jax.vmap(local_gd)(data, x, v, solver_keys)
        u = jax.random.bernoulli(k_part, participation,
                                 (N,)).astype(w.dtype)[:, None]
        x_new = u * w + (1.0 - u) * x
        z_upd = z + 2.0 * damping * (w - y[None, :])
        z_new = u * z_upd + (1.0 - u) * z
        if compressed:
            t_new = t + u * compress(z_new - t)
        else:
            t_new = z_new
        return (x_new, z_new, t_new, key), (x_new, z_new)

    _, k_state = jax.random.split(key)
    x0 = jnp.zeros((N, problem.dim))
    (_, _, _, _), traj = jax.lax.scan(round_ref, (x0, x0, x0, k_state),
                                      None, length=n_rounds)
    return traj


@pytest.mark.parametrize("kw", [
    dict(participation=1.0),
    dict(participation=0.6),
    dict(participation=0.7, compression="topk", compress_ratio=0.5,
         damping=0.5),
    dict(compression="int8"),
    dict(tau=0.05),   # DP noisy GD: same PRNG noise stream
])
def test_dense_round_matches_pre_refactor_bit_for_bit(kw):
    """core/fedplt.py through the engine == the historical implementation,
    exactly (same PRNG consumption, same op order, same bits)."""
    prob = make_quadratic_problem(n_agents=6, dim=5, seed=3)
    rho, ne, rounds = 1.0, 4, 30
    tau = kw.pop("tau", 0.0)
    solver = SolverConfig(name="noisy_gd" if tau > 0 else "gd",
                          n_epochs=ne, tau=tau)
    cfg = FedPLTConfig(rho=rho, solver=solver, **kw)
    algo = FedPLT(prob, cfg)
    key = jax.random.PRNGKey(7)

    state = algo.init(key)

    def body(s, _):
        s = algo._round_impl(s)
        return s, (s.x, s.z)

    _, (xs, zs) = jax.lax.scan(body, state, None, length=rounds)

    ref_xs, ref_zs = _reference_run(
        prob, rho, ne, kw.get("participation", 1.0), rounds, key,
        damping=kw.get("damping", 1.0),
        compression=kw.get("compression", "none"),
        compress_ratio=kw.get("compress_ratio", 0.25), tau=tau)

    np.testing.assert_array_equal(np.asarray(xs), np.asarray(ref_xs))
    np.testing.assert_array_equal(np.asarray(zs), np.asarray(ref_zs))


# ---------------------------------------------------------------------------
# Engine pieces on pytrees
# ---------------------------------------------------------------------------

def test_compress_increment_topk_keeps_k_per_leaf():
    cfg = engine.RoundConfig(n_agents=2, compression="topk",
                             compress_ratio=0.25)
    dz = {"a": jnp.arange(1.0, 17.0).reshape(2, 2, 4),
          "b": jnp.ones((2, 3))}
    out = engine.compress_increment(dz, cfg)
    # per agent, per leaf: ceil/floor(0.25 * m) kept, top magnitudes
    assert int(jnp.sum(out["a"][0] != 0)) == 2
    np.testing.assert_allclose(out["a"][1].reshape(-1)[-2:],
                               dz["a"][1].reshape(-1)[-2:])


def test_masked_mix_isolates_nonfinite_inactive_agents():
    """A diverged (NaN) local solve on a NON-participating agent must not
    poison its preserved state (jnp.where, not u*new + (1-u)*old)."""
    u = jnp.array([1.0, 0.0])
    new = {"w": jnp.array([[1.0, 2.0], [jnp.nan, jnp.inf]])}
    old = {"w": jnp.array([[9.0, 9.0], [3.0, 4.0]])}
    out = engine.masked_mix(u, new, old)
    np.testing.assert_array_equal(out["w"][0], [1.0, 2.0])
    np.testing.assert_array_equal(out["w"][1], [3.0, 4.0])  # finite kept


def test_clip_grad_batched_is_per_agent():
    g = {"w": jnp.array([[3.0, 4.0], [0.3, 0.4]]),
         "b": jnp.zeros((2, 1))}
    out = clip_grad(g, 1.0, batched=True)
    # agent 0 has norm 5 -> scaled to 1; agent 1 has norm 0.5 -> untouched
    np.testing.assert_allclose(out["w"][0], [0.6, 0.8], atol=1e-6)
    np.testing.assert_allclose(out["w"][1], [0.3, 0.4], atol=1e-6)


def test_local_train_pytree_matches_array():
    """A pytree of two halves steps exactly like the concatenated array."""
    Q = jnp.diag(jnp.array([2.0, 1.0, 3.0, 0.5]))
    c = jnp.array([0.1, -0.2, 0.3, 0.4])
    v = jnp.array([1.0, 2.0, -1.0, 0.5])
    cfg = SolverConfig(name="gd", n_epochs=7, step_size=0.1)
    key = jax.random.PRNGKey(0)

    w_arr = local_train(lambda w, k: Q @ w + c, jnp.zeros(4), v, 1.0, cfg,
                        key, 0.5, 3.0)

    def fgrad_tree(w, k):
        full = jnp.concatenate([w["lo"], w["hi"]])
        g = Q @ full + c
        return {"lo": g[:2], "hi": g[2:]}

    w_tree = local_train(fgrad_tree, {"lo": jnp.zeros(2), "hi": jnp.zeros(2)},
                         {"lo": v[:2], "hi": v[2:]}, 1.0, cfg, key, 0.5, 3.0)
    np.testing.assert_allclose(
        jnp.concatenate([w_tree["lo"], w_tree["hi"]]), w_arr, atol=1e-7)


# ---------------------------------------------------------------------------
# Model scale: compression, agd, fused kernel, privacy
# ---------------------------------------------------------------------------

SHAPE = InputShape("tiny", 32, 8, "train")


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("gemma2-2b").reduced()
    model = build_model(cfg)
    return cfg, model


def _losses(cfg, model, fcfg, rounds=6):
    state = runtime.init_state(model, jax.random.PRNGKey(0), fcfg)
    step = jax.jit(runtime.make_train_step(model, fcfg))
    batch = make_batch_for(cfg, SHAPE, n_agents=fcfg.n_agents)
    out = []
    for i in range(rounds):
        state, m = step(state, batch, jax.random.PRNGKey(i))
        out.append(float(m["loss"]))
    return out, state


@pytest.mark.parametrize("comp", ["topk", "int8"])
def test_compressed_pytree_round_converges(setup, comp):
    """Model-scale smoke: a compressed z-exchange still trains."""
    cfg, model = setup
    fcfg = runtime.FedConfig(n_agents=2, n_epochs=2, gamma=0.1,
                             compression=comp, compress_ratio=0.5)
    losses, state = _losses(cfg, model, fcfg)
    assert losses[-1] < losses[0]
    assert state.t is not None  # coordinator copy materialized
    # t lags z (error feedback residual is nonzero under top-k)
    if comp == "topk":
        lag = jax.tree_util.tree_reduce(
            lambda acc, p: acc + float(jnp.sum(jnp.abs(p[0] - p[1]))),
            jax.tree_util.tree_map(lambda a, b: jnp.stack(
                [a[0].ravel()[:64], b[0].ravel()[:64]]), state.z, state.t),
            0.0)
        assert lag > 0


def test_agd_solver_at_model_scale(setup):
    cfg, model = setup
    fcfg = runtime.FedConfig(n_agents=2, n_epochs=3, gamma=0.05,
                             solver="agd")
    losses, _ = _losses(cfg, model, fcfg)
    assert losses[-1] < losses[0]


def test_pallas_fused_step_matches_unfused(setup):
    cfg, model = setup
    base = runtime.FedConfig(n_agents=2, n_epochs=2, gamma=0.1)
    losses_ref, state_ref = _losses(cfg, model, base, rounds=2)
    fused = runtime.FedConfig(n_agents=2, n_epochs=2, gamma=0.1,
                              use_pallas_update=True)
    losses_fused, state_fused = _losses(cfg, model, fused, rounds=2)
    np.testing.assert_allclose(losses_ref, losses_fused, rtol=1e-4)
    x_ref = jax.tree_util.tree_leaves(state_ref.x)[0]
    x_fused = jax.tree_util.tree_leaves(state_fused.x)[0]
    np.testing.assert_allclose(np.asarray(x_ref), np.asarray(x_fused),
                               atol=1e-5)


def test_privacy_report_threads_from_config():
    fcfg = runtime.FedConfig(n_agents=4, rho=1.0, gamma=0.05, n_epochs=3,
                             tau=0.1, clip=1.0)
    rep = runtime.privacy_report(fcfg, n_rounds=50, local_dataset_size=100)
    assert np.isfinite(rep.adp_eps) and rep.adp_eps > 0
    assert rep.adp_eps <= rep.eps_ceiling + 1e-9
    with pytest.raises(ValueError):
        runtime.privacy_report(runtime.FedConfig(tau=0.0), 10, 10)


def test_fed_state_specs_structure():
    from jax.sharding import PartitionSpec as P

    from repro.fed import sharding

    params = {"wq": jnp.zeros((4, 8, 16)), "norm": jnp.zeros((4, 8))}
    spec = sharding.fed_state_specs(params, fsdp_axis=None,
                                    agent_axis="data",
                                    axis_sizes={"data": 4},
                                    compressed=True)
    assert isinstance(spec, runtime.FedState)
    assert spec.x == spec.z == spec.t
    assert spec.step == P()
    assert spec.x["wq"][0] == "data"  # leading agent axis
