"""Fused round-edge kernel suite.

Parity contract (two tiers -- see the note in repro/fed/engine.py):

* KERNEL tier, bitwise: the fused kernels == the ref.py oracles on the
  same ``(N, M)`` buffer (whole prox table, lagged/exact exchange,
  NaN'd solver results, non-block-aligned widths), and the multi-block
  grid == the single-program realization.
* ENGINE tier, 1-ULP: ``engine_backend="pallas"`` trajectories equal
  ``"xla"`` to float32 rounding (dense + model scale, per-agent
  participation, heterogeneous groups, compressed rounds).  Exact
  bitwise equality across backends is NOT a stable property of
  XLA:CPU: the algebraic simplifier refolds the coordinator chain's
  constants per consumer, per surrounding program, and per array shape
  -- the unfused xla backend's own ``run()`` (scan-fused criterion)
  and ``step()`` already disagree bitwise at some shapes, so no single
  kernel formulation can match the xla path in every context.  In
  practice most full-round configurations DO agree bit-for-bit (the
  kernels mirror the unfused path's per-consumer chain duplication),
  but tests assert only what is guaranteed."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import prox as prox_lib
from repro.core.problem import make_logreg_problem
from repro.fed import engine
from repro.fed.api import (CompressionSpec, FedSpec, PrivacySpec,
                           build_trainer, spec_from_args)
from repro.fed.compress import pack_coord, pack_leaves, unpack_coord
from repro.kernels.round_edge import ops, ref

# the full make_prox table as (name, bound callable) -- every entry is
# elementwise, so every entry must take the fused kernel path
PROX_TABLE = [
    ("none", None),
    ("zero", prox_lib.prox_zero),
    ("l1", prox_lib.prox_l1),
    ("l2sq", prox_lib.prox_l2sq),
    ("weight_decay", prox_lib.make_prox("weight_decay", weight=0.1)),
    ("elastic_net", prox_lib.make_prox("elastic_net", l1=0.3, l2=0.7)),
    ("box", prox_lib.make_prox("box", lo=-0.3, hi=0.5)),
    ("linf_ball", prox_lib.make_prox("linf_ball", radius=0.4)),
]


def _assert_trees_equal(a, b):
    jax.tree_util.tree_map(
        lambda x, y: np.testing.assert_array_equal(np.asarray(x),
                                                   np.asarray(y)), a, b)


def _assert_trees_ulp_close(a, b):
    """Equality to float32 rounding (the cross-backend engine bar)."""
    jax.tree_util.tree_map(
        lambda x, y: np.testing.assert_allclose(
            np.asarray(x), np.asarray(y), rtol=1e-5, atol=1e-6), a, b)


def _stack(key, n, m, scale=1.0):
    return scale * jax.random.normal(key, (n, m))


# ---------------------------------------------------------------------------
# Kernels vs ref.py oracles (jit-vs-jit, static prox/rho -- the form the
# engine runs; see test_compress_kernels for why eager parity is not
# the bar)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n,m", [(3, 7), (5, 300), (8, 128), (2, 1000),
                                 (32, 513)])
@pytest.mark.parametrize("pname,prox", PROX_TABLE,
                         ids=[p[0] for p in PROX_TABLE])
@pytest.mark.parametrize("lagged", [False, True])
def test_uplink_matches_ref(n, m, pname, prox, lagged):
    key = jax.random.PRNGKey(n * m)
    z = _stack(key, n, m)
    t = z + 0.1 * _stack(jax.random.fold_in(key, 1), n, m) if lagged \
        else None
    y, v = ops.round_uplink(z, t, prox=prox, rho_eff=0.25)
    ref_jit = jax.jit(ref.round_uplink_ref,
                      static_argnames=("prox", "rho_eff"))
    yr, vr = ref_jit(z, t, prox=prox, rho_eff=0.25)
    assert y.shape == (1, m) and v.shape == (n, m)
    np.testing.assert_array_equal(np.asarray(y), np.asarray(yr))
    np.testing.assert_array_equal(np.asarray(v), np.asarray(vr))


@pytest.mark.parametrize("n,m", [(3, 7), (5, 300), (32, 1000)])
@pytest.mark.parametrize("lagged", [False, True])
def test_downlink_matches_ref(n, m, lagged):
    key = jax.random.PRNGKey(n + m)
    x = _stack(key, n, m)
    w = _stack(jax.random.fold_in(key, 1), n, m)
    z = _stack(jax.random.fold_in(key, 2), n, m)
    t = z + 0.1 * _stack(jax.random.fold_in(key, 3), n, m) if lagged \
        else None
    u = jax.random.bernoulli(jax.random.fold_in(key, 4), 0.5,
                             (n,)).astype(jnp.float32)
    prox = prox_lib.make_prox("weight_decay", weight=0.2)
    xn, zn = ops.round_downlink(x, w, z, u, t, prox=prox, rho_eff=0.2,
                                damping=0.65)
    ref_jit = jax.jit(ref.round_downlink_ref,
                      static_argnames=("prox", "rho_eff", "damping"))
    xr, zr = ref_jit(x, w, z, u, t, prox=prox, rho_eff=0.2, damping=0.65)
    np.testing.assert_array_equal(np.asarray(xn), np.asarray(xr))
    np.testing.assert_array_equal(np.asarray(zn), np.asarray(zr))


@pytest.mark.parametrize("block_cols", [128, 256])
def test_multi_block_grid_matches_single_program(block_cols):
    """An explicit column block smaller than the width tiles the grid;
    the tiling must not change a single bit vs the one-program default
    (the TPU-shaped realization vs the interpret default)."""
    key = jax.random.PRNGKey(0)
    n, m = 6, 900                    # pads to the block, grid > 1
    z = _stack(key, n, m)
    x = _stack(jax.random.fold_in(key, 1), n, m)
    w = _stack(jax.random.fold_in(key, 2), n, m)
    u = jnp.asarray([1.0, 0.0, 1.0, 1.0, 0.0, 1.0])
    prox = prox_lib.prox_l1
    y1, v1 = ops.round_uplink(z, prox=prox, rho_eff=0.3)
    y2, v2 = ops.round_uplink(z, prox=prox, rho_eff=0.3,
                              block_cols=block_cols)
    np.testing.assert_array_equal(np.asarray(y1), np.asarray(y2))
    np.testing.assert_array_equal(np.asarray(v1), np.asarray(v2))
    x1, z1 = ops.round_downlink(x, w, z, u, prox=prox, rho_eff=0.3)
    x2, z2 = ops.round_downlink(x, w, z, u, prox=prox, rho_eff=0.3,
                                block_cols=block_cols)
    np.testing.assert_array_equal(np.asarray(x1), np.asarray(x2))
    np.testing.assert_array_equal(np.asarray(z1), np.asarray(z2))


def test_direct_realization_matches_pallas_emulation():
    """Interpret mode's single-program grid runs the kernel body
    DIRECTLY (no emulator block copies); forcing the pallas_call
    emulator over the same single block must give identical bits --
    the two realizations of the same kernel."""
    key = jax.random.PRNGKey(5)
    n, m = 7, 384
    z = _stack(key, n, m)
    x = _stack(jax.random.fold_in(key, 1), n, m)
    w = _stack(jax.random.fold_in(key, 2), n, m)
    u = jax.random.bernoulli(jax.random.fold_in(key, 3), 0.5,
                             (n,)).astype(jnp.float32)
    prox = prox_lib.make_prox("elastic_net", l1=0.2, l2=0.4)
    kw = dict(prox=prox, rho_eff=0.2)
    y1, v1 = ops.round_uplink(z, **kw)
    y2, v2 = ops.round_uplink(z, emulate=True, **kw)
    np.testing.assert_array_equal(np.asarray(y1), np.asarray(y2))
    np.testing.assert_array_equal(np.asarray(v1), np.asarray(v2))
    x1, z1 = ops.round_downlink(x, w, z, u, damping=0.5, **kw)
    x2, z2 = ops.round_downlink(x, w, z, u, damping=0.5, emulate=True,
                                **kw)
    np.testing.assert_array_equal(np.asarray(x1), np.asarray(x2))
    np.testing.assert_array_equal(np.asarray(z1), np.asarray(z2))


def test_downlink_nan_safe():
    """A diverged (NaN/Inf) local solve must not leak into agents that
    sat the round out -- the where-select semantics of masked_mix."""
    n, m = 4, 70
    x = jnp.ones((n, m))
    z = 2.0 * jnp.ones((n, m))
    w = jnp.full((n, m), jnp.nan)
    u = jnp.asarray([0.0, 1.0, 0.0, 1.0])
    xn, zn = ops.round_downlink(x, w, z, u)
    assert np.isfinite(np.asarray(xn[0])).all()
    assert np.isfinite(np.asarray(zn[2])).all()
    np.testing.assert_array_equal(np.asarray(xn[0]), np.asarray(x[0]))
    np.testing.assert_array_equal(np.asarray(zn[2]), np.asarray(z[2]))
    assert np.isnan(np.asarray(xn[1])).all()


def test_float64_and_bad_shapes_rejected():
    with pytest.raises(ValueError, match=r"\(N, M\)"):
        ops.round_uplink(jnp.ones((4,)))
    with pytest.raises(ValueError, match="must match z"):
        from repro.kernels.round_edge.kernel import round_uplink_2d
        round_uplink_2d(jnp.ones((2, 128)), jnp.ones((3, 128)))


# ---------------------------------------------------------------------------
# Engine edges: packed pallas == per-leaf XLA on ragged pytrees
# ---------------------------------------------------------------------------

def _ragged_tree(n=6, seed=3):
    key = jax.random.PRNGKey(seed)
    shapes = {"emb": (n, 37, 5), "w": {"a": (n, 130), "b": (n, 3)},
              "bias": (n, 1)}
    return jax.tree_util.tree_map(
        lambda s: jax.random.normal(jax.random.fold_in(key, s[-1]), s),
        shapes, is_leaf=lambda s: isinstance(s, tuple))


@pytest.mark.parametrize("pname,prox", PROX_TABLE,
                         ids=[p[0] for p in PROX_TABLE])
@pytest.mark.parametrize("lagged", [False, True])
def test_engine_edges_on_pytrees(pname, prox, lagged):
    """coordinator_edge + agent_edge under engine_backend="pallas" ==
    the per-leaf XLA path on a ragged multi-leaf pytree: the
    coordinator output ``y`` bitwise, everything else to fp32 rounding
    (the xla path's reflection/z-update chains refold shape-dependently
    -- see the module docstring)."""
    n = 6
    z = _ragged_tree(n, seed=1)
    x = _ragged_tree(n, seed=2)
    w = _ragged_tree(n, seed=4)
    z_seen = (jax.tree_util.tree_map(lambda l: 1.01 * l, z)
              if lagged else None)
    u = jnp.asarray([1.0, 0.0, 1.0, 1.0, 0.0, 1.0])

    def edges(cfg, zs):
        zs = z if zs is None else zs
        y, v = engine.coordinator_edge(cfg, z, zs, prox)
        xn, zn = engine.agent_edge(cfg, u, w, x, z, y, zs, prox)
        return y, v, xn, zn

    outs = {}
    for backend in ("xla", "pallas"):
        cfg = engine.RoundConfig(n_agents=n, rho=1.3, damping=0.6,
                                 engine_backend=backend)
        outs[backend] = jax.jit(lambda zs: edges(cfg, zs))(z_seen)
    _assert_trees_ulp_close(outs["xla"], outs["pallas"])


def test_fusible_prox_gating():
    assert engine.fusible_prox(None)
    for _, prox in PROX_TABLE:
        if prox is not None:
            assert engine.fusible_prox(prox), prox
    assert not engine.fusible_prox(lambda y, rho: y * 0.5)


def test_custom_prox_falls_back_to_xla():
    """An untagged (possibly non-elementwise) prox must take the XLA
    path under backend="pallas" -- output equal to the XLA backend's."""
    def custom(y, rho):
        # deliberately non-elementwise: couples coordinates
        return y - rho * jnp.mean(y, keepdims=True)

    n = 4
    z = _ragged_tree(n, seed=7)
    outs = {}
    for backend in ("xla", "pallas"):
        cfg = engine.RoundConfig(n_agents=n, engine_backend=backend)
        outs[backend] = jax.jit(
            lambda: engine.coordinator_edge(cfg, z, z, custom))()
    _assert_trees_equal(outs["xla"], outs["pallas"])


def test_mixed_dtype_tree_falls_back():
    n = 4
    tree = {"a": jnp.ones((n, 40)), "b": jnp.ones((n, 24), jnp.bfloat16)}
    for backend in ("xla", "pallas"):
        cfg = engine.RoundConfig(n_agents=n, engine_backend=backend)
        y, v = engine.coordinator_edge(cfg, tree, tree, None)
    assert y["b"].dtype == jnp.bfloat16


def test_pack_coord_roundtrip():
    tree = _ragged_tree(5)
    buf, meta = pack_leaves(tree)
    y = jax.tree_util.tree_map(lambda l: jnp.mean(l, axis=0), tree)
    y_buf = pack_coord(y, meta)
    assert y_buf.shape == (1, meta.width)
    _assert_trees_equal(unpack_coord(y_buf, meta), y)
    back = unpack_coord(pack_leaves(
        jax.tree_util.tree_map(lambda l: l[None], y))[0], meta)
    _assert_trees_equal(back, y)
    with pytest.raises(ValueError, match="does not match"):
        pack_coord(jax.tree_util.tree_map(lambda l: l[..., None], y),
                   meta)


# ---------------------------------------------------------------------------
# RoundConfig validation (incl. the participation-string regression)
# ---------------------------------------------------------------------------

def test_round_config_rejects_unknown_engine_backend():
    with pytest.raises(ValueError, match="engine backend"):
        engine.RoundConfig(n_agents=2, engine_backend="nope")


def test_participation_rejects_strings():
    """participation="0.5" is a __len__-bearing sequence of characters;
    it must fail loudly, not tuple-ize into per-character draws."""
    with pytest.raises(ValueError, match="string"):
        engine.RoundConfig(n_agents=2, participation="0.5")
    with pytest.raises(ValueError, match="string"):
        engine.RoundConfig(n_agents=2, participation=b"0.5")


def test_participation_rejects_non_numeric_sequences():
    with pytest.raises(ValueError, match="numbers"):
        engine.RoundConfig(n_agents=2, participation=("0.5", "a"))
    with pytest.raises(ValueError, match="numbers"):
        engine.RoundConfig(n_agents=2, participation=(0.5, None))


def test_participation_accepts_numeric_sequences():
    cfg = engine.RoundConfig(n_agents=3,
                             participation=np.asarray([0.5, 1.0, 0.25]))
    assert cfg.participation == (0.5, 1.0, 0.25)
    with pytest.raises(ValueError, match="2 entries"):
        engine.RoundConfig(n_agents=3, participation=(0.5, 1.0))


def test_participation_accepts_0d_array_scalars():
    """ndarray types carry __len__ even at 0-d, so a numpy/jax scalar
    must be recognized as the scalar it is, not misdiagnosed as a
    malformed per-agent sequence."""
    for p in (np.float32(0.5), np.asarray(0.5), jnp.float32(0.5)):
        cfg = engine.RoundConfig(n_agents=3, participation=p)
        assert cfg.participation == 0.5
        assert isinstance(cfg.participation, float)


# ---------------------------------------------------------------------------
# The backend knob end to end
# ---------------------------------------------------------------------------

def test_spec_validates_engine_backend():
    with pytest.raises(ValueError, match="engine backend"):
        FedSpec(n_agents=2, engine_backend="nope").validate()


def test_cli_engine_backend_roundtrip():
    spec = spec_from_args(["--engine-backend", "pallas"])
    assert spec.engine_backend == "pallas"
    assert spec.validate().round_config().engine_backend == "pallas"


def test_engine_backend_threads_through_shims():
    from repro.core.fedplt import FedPLTConfig
    from repro.fed.runtime import FedConfig

    cfg = FedPLTConfig(engine_backend="pallas")
    assert cfg.to_spec().engine_backend == "pallas"
    assert cfg.to_spec(n_agents=2).round_config().engine_backend == \
        "pallas"
    spec = cfg.to_spec()
    assert spec.to_dense_config().engine_backend == "pallas"
    fcfg = FedConfig(engine_backend="pallas")
    assert fcfg.to_spec().engine_backend == "pallas"


def test_backend_threads_to_dense_engine():
    prob = make_logreg_problem(n_agents=4, q=20, dim=10, seed=0)
    trainer = build_trainer(prob, FedSpec(engine_backend="pallas"))
    assert trainer.algo._ecfg.engine_backend == "pallas"


# ---------------------------------------------------------------------------
# Backend parity: full trajectories, round by round, to fp32 rounding
#
# Compared at ROUND granularity (the jitted step's RoundResult),
# iterated over a full trajectory.  The bar is 1-ULP-tight equality,
# not bitwise: XLA:CPU refolds the round body's constant chains per
# program context and per shape -- the xla backend's own run()
# (scan-fused criterion) and step() already disagree bitwise at some
# shapes -- so bitwise cross-backend equality is not a stable property
# of the platform (most configs do agree bit-for-bit in practice).
# ---------------------------------------------------------------------------

def _run_pair(prob, rounds=6, **kw):
    runs = []
    for backend in ("xla", "pallas"):
        spec = FedSpec(engine_backend=backend, **kw)
        trainer = build_trainer(prob, spec)
        state = trainer.init(jax.random.PRNGKey(0))
        crit = []
        for _ in range(rounds):
            state = trainer.step(state)
            crit.append(prob.criterion(state.x))
        t = state.t if state.t is not None else state.z
        runs.append((np.asarray(state.x), np.asarray(state.z),
                     np.asarray(t), np.asarray(state.y),
                     np.asarray(jnp.stack(crit))))
    return runs


@pytest.mark.parametrize("prox_h", ["zero", "l1", "l2sq", "elastic_net",
                                    "box", "linf_ball"])
def test_dense_trajectory_parity_prox_table(prox_h):
    prob = make_logreg_problem(n_agents=6, q=25, dim=16, seed=0)
    a, b = _run_pair(prob, rho=0.9, n_epochs=2, prox_h=prox_h)
    for x, y in zip(a, b):
        np.testing.assert_allclose(x, y, rtol=1e-5, atol=1e-6)


def test_dense_trajectory_parity_weight_decay():
    prob = make_logreg_problem(n_agents=6, q=25, dim=16, seed=0)
    a, b = _run_pair(prob, rho=0.8, n_epochs=2, weight_decay=0.1)
    for x, y in zip(a, b):
        np.testing.assert_allclose(x, y, rtol=1e-5, atol=1e-6)


def test_dense_trajectory_parity_participation_and_groups():
    """Per-agent participation vectors + heterogeneous SolverGroup
    partitions ride the fused edges bit-identically."""
    prob = make_logreg_problem(n_agents=6, q=25, dim=16, seed=0)
    a, b = _run_pair(
        prob, rho=1.0, n_epochs=2, damping=0.5,
        agent_groups="3*gd:participation=0.4,3*agd:n_epochs=1")
    for x, y in zip(a, b):
        np.testing.assert_allclose(x, y, rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("name,cbackend", [
    ("topk", "xla"), ("int8", "pallas")])
def test_dense_trajectory_parity_compressed(name, cbackend):
    """Compressed rounds (incl. the packed pallas compress backend --
    both fused kernel suites in one round) match across engine
    backends."""
    prob = make_logreg_problem(n_agents=6, q=25, dim=16, seed=0)
    a, b = _run_pair(
        prob, rho=1.0, n_epochs=1, damping=0.7,
        compression=CompressionSpec(name, ratio=0.3, energy=0.9,
                                    backend=cbackend))
    for x, y in zip(a, b):
        np.testing.assert_allclose(x, y, rtol=1e-5, atol=1e-6)


def test_dense_trajectory_adaptive_compressed_converges_equally():
    """adaptive_topk's per-agent k_i comes from an energy-cumsum
    threshold: a 1-ULP backend difference in the increment can flip
    WHICH coordinate is transmitted (a discrete selection), after which
    states differ macroscopically -- so the parity bar for adaptive
    compression is equal convergence, not state equality.  (topk/int8
    at these seeds never sit on a selection boundary and stay
    ULP-close; see test_dense_trajectory_parity_compressed.)"""
    prob = make_logreg_problem(n_agents=6, q=25, dim=16, seed=0)
    a, b = _run_pair(
        prob, rounds=8, rho=1.0, n_epochs=1, damping=0.7,
        compression=CompressionSpec("adaptive_topk", ratio=0.3,
                                    energy=0.9, backend="pallas"))
    crit_a, crit_b = a[-1], b[-1]
    assert crit_a[-1] < 0.1 * crit_a[0] and crit_b[-1] < 0.1 * crit_b[0]
    np.testing.assert_allclose(np.log10(crit_a), np.log10(crit_b),
                               atol=0.1)


def test_dense_trajectory_parity_noisy():
    prob = make_logreg_problem(n_agents=4, q=25, dim=12, seed=0)
    a, b = _run_pair(prob, rho=1.0, n_epochs=2,
                     privacy=PrivacySpec(tau=0.05, clip=1.0))
    for x, y in zip(a, b):
        np.testing.assert_allclose(x, y, rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("kw", [
    dict(n_epochs=1, weight_decay=0.05, participation=0.5, damping=0.5),
    dict(n_epochs=1, compression=CompressionSpec("topk", ratio=0.5)),
])
def test_model_trajectory_parity(kw):
    """The model-scale front end (ragged parameter pytree through
    fed/runtime.py) matches across engine backends to fp32 rounding."""
    from repro.configs import get_config
    from repro.configs.base import InputShape
    from repro.data.synthetic import make_batch_for
    from repro.models.model import build_model

    cfg = get_config("gemma2-2b").reduced(n_layers=1, d_model=64,
                                          vocab=128)
    model = build_model(cfg)
    shape = InputShape("t", 4, 4, "train")
    states = {}
    for backend in ("xla", "pallas"):
        spec = FedSpec(n_agents=2, gamma=0.1, engine_backend=backend,
                       **kw)
        trainer = build_trainer(model, spec)
        batch = make_batch_for(cfg, shape, n_agents=2)
        state = trainer.init(jax.random.PRNGKey(0))
        for i in range(2):
            state, _ = trainer.step(state, batch, jax.random.PRNGKey(i))
        states[backend] = state
    _assert_trees_ulp_close(states["xla"].x, states["pallas"].x)
    _assert_trees_ulp_close(states["xla"].z, states["pallas"].z)


def test_round_edge_launch_count():
    """On the TPU schedule (``interpret=False`` trace -- abstract eval
    only, safe on CPU) the fused round edges are exactly TWO pallas
    launches: one uplink, one downlink.  (The CPU default executes the
    same bodies directly when the grid is a single program, so the
    count is taken from the TPU-shaped trace.)"""
    n, m = 8, 4096
    z = jnp.zeros((n, m))
    u = jnp.zeros((n,))
    prox = prox_lib.prox_l1

    def count(jaxpr, name):
        total = 0
        for eqn in jaxpr.eqns:
            if eqn.primitive.name == name:
                total += 1
            for v in eqn.params.values():
                for vv in (v if isinstance(v, (list, tuple)) else [v]):
                    inner = getattr(vv, "jaxpr", None)
                    if inner is not None:
                        total += count(inner, name)
                    elif hasattr(vv, "eqns"):
                        total += count(vv, name)
        return total

    def tpu_edges(x, w, z, u):
        _, v = ops.round_uplink(z, prox=prox, rho_eff=0.2,
                                interpret=False)
        xn, zn = ops.round_downlink(x, w, z, u, prox=prox, rho_eff=0.2,
                                    interpret=False)
        return v, xn, zn

    jaxpr = jax.make_jaxpr(tpu_edges)(z, z, z, u)
    assert count(jaxpr.jaxpr, "pallas_call") == 2
