"""Prox library: closed forms, Moreau identity, Lemma 6, nonexpansiveness."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import prox as P

VECS = st.lists(st.floats(-10, 10), min_size=1, max_size=8)


def test_prox_l1_soft_threshold():
    y = jnp.array([3.0, -2.0, 0.5, 0.0])
    out = P.prox_l1(y, 1.0)
    np.testing.assert_allclose(out, [2.0, -1.0, 0.0, 0.0], atol=1e-7)


def test_prox_l2sq_shrinks():
    y = jnp.array([2.0, -4.0])
    np.testing.assert_allclose(P.prox_l2sq(y, 1.0), y / 2.0, atol=1e-7)


def test_prox_box_projects():
    y = jnp.array([2.0, -4.0, 0.3])
    np.testing.assert_allclose(P.prox_box(y, 0.7, -1, 1), [1, -1, 0.3],
                               atol=1e-7)


def test_prox_is_argmin():
    """prox_l1 satisfies the exact optimality condition
    (y - x)/rho in subdifferential of ||.||_1 at x."""
    y = jnp.array([1.5, -0.7, 3.0, 0.2])
    rho = 0.8
    x = P.prox_l1(y, rho)
    g = (y - x) / rho
    for xi, gi in zip(np.asarray(x), np.asarray(g)):
        if xi == 0.0:
            assert abs(gi) <= 1.0 + 1e-6
        else:
            assert gi == pytest.approx(np.sign(xi), abs=1e-6)


@given(VECS, VECS, st.floats(0.1, 10))
@settings(max_examples=50, deadline=None)
def test_prox_l1_nonexpansive(xs, ys, rho):
    n = min(len(xs), len(ys))
    x, y = jnp.array(xs[:n]), jnp.array(ys[:n])
    d_out = float(jnp.linalg.norm(P.prox_l1(x, rho) - P.prox_l1(y, rho)))
    d_in = float(jnp.linalg.norm(x - y))
    assert d_out <= d_in + 1e-5


@given(VECS, VECS, st.floats(0.1, 10))
@settings(max_examples=50, deadline=None)
def test_reflect_nonexpansive(xs, ys, rho):
    n = min(len(xs), len(ys))
    x, y = jnp.array(xs[:n]), jnp.array(ys[:n])
    refl = P.reflect(P.prox_l1)
    d_out = float(jnp.linalg.norm(refl(x, rho) - refl(y, rho)))
    d_in = float(jnp.linalg.norm(x - y))
    assert d_out <= d_in + 1e-5


@given(VECS, st.floats(0.2, 5))
@settings(max_examples=50, deadline=None)
def test_moreau_conjugate_of_l1_is_linf_projection(xs, rho):
    """f = ||.||_1  =>  f* = indicator of the l-inf ball, whose prox is
    the projection clip(y, -1, 1) for ANY rho -- analytic check of the
    Moreau-identity implementation."""
    x = jnp.array(xs)
    p_star = P.moreau_conjugate(P.prox_l1)(x, rho)
    np.testing.assert_allclose(p_star, jnp.clip(x, -1.0, 1.0), atol=1e-5)


def test_coordinator_prox_lemma6():
    """prox_{rho g} for g = consensus + h equals broadcast of
    prox_{rho h / N} at the average (Lemma 6)."""
    z = jnp.array([[1.0, 2.0], [3.0, -1.0], [-2.0, 5.0]])
    rho = 2.0
    y = P.coordinator_prox(z, rho, P.prox_l1)
    expect = P.prox_l1(jnp.mean(z, axis=0), rho / 3.0)
    np.testing.assert_allclose(y, expect, atol=1e-7)


def test_prox_of_smooth_matches_closed_form():
    """Approximate prox of a quadratic matches (I + rho Q)^-1 (y - rho b)."""
    Q = jnp.array([[2.0, 0.3], [0.3, 1.0]])
    b = jnp.array([0.5, -1.0])
    grad = lambda x: Q @ x + b
    y = jnp.array([1.0, 1.0])
    rho = 0.5
    out = P.prox_of_smooth(grad, y, rho, steps=2000, smoothness=3.0)
    expect = jnp.linalg.solve(jnp.eye(2) + rho * Q, y - rho * b)
    np.testing.assert_allclose(out, expect, atol=1e-4)
