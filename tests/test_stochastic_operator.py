"""Lemma 4 (stochastic Banach-Picard): statistical check of the bound

    E||x_k - xbar|| <= sqrt(pbar/punder) (zbar^k ||x_0 - xbar||
                       + (1 - zbar^k)/(1 - zbar) nu)

on a synthetic contractive operator with randomized coordinate updates
and additive noise -- the engine behind Prop. 2."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st


def _run_stoch_bp(key, T, xbar, p, nu_std, k_steps, x0):
    """x_{i,k+1} = T_i x_k + e_i if u_i ~ Ber(p) else x_{i,k}."""
    def step(x, k):
        ku, ke = jax.random.split(jax.random.fold_in(key, k))
        u = jax.random.bernoulli(ku, p, (x.shape[0],))
        e = nu_std * jax.random.normal(ke, x.shape)
        x_new = T @ x + e
        return jnp.where(u, x_new, x), None

    x, _ = jax.lax.scan(step, x0, jnp.arange(k_steps))
    return x


@given(st.integers(0, 1000), st.floats(0.3, 1.0), st.floats(0.0, 0.05))
@settings(max_examples=20, deadline=None)
def test_lemma4_bound_holds_statistically(seed, p, nu_std):
    n = 6
    rng = np.random.default_rng(seed)
    # zeta-contractive linear operator with fixed point xbar
    A = rng.normal(size=(n, n))
    A = 0.6 * A / np.linalg.norm(A, 2)          # zeta = 0.6
    zeta = float(np.linalg.norm(A, 2))
    b = rng.normal(size=n)
    xbar = np.linalg.solve(np.eye(n) - A, b)

    global T
    T = jnp.asarray(A)
    x0 = jnp.zeros(n)
    k_steps = 40
    keys = jax.random.split(jax.random.PRNGKey(seed), 64)

    def run(key):
        def step(x, k):
            ku, ke = jax.random.split(jax.random.fold_in(key, k))
            u = jax.random.bernoulli(ku, p, (n,))
            e = nu_std * jax.random.normal(ke, (n,))
            x_new = T @ x + jnp.asarray(b) + e
            return jnp.where(u, x_new, x), None

        x, _ = jax.lax.scan(step, x0, jnp.arange(k_steps))
        return jnp.linalg.norm(x - jnp.asarray(xbar))

    dists = jax.vmap(run)(keys)
    emp = float(jnp.mean(dists))

    # Lemma 4 bound
    zbar = np.sqrt(1 - p + p * zeta ** 2)
    nu = nu_std * np.sqrt(n * p)  # E||e|| <= nu_std sqrt(n); active w.p. p
    bound = (zbar ** k_steps * np.linalg.norm(x0 - xbar)
             + (1 - zbar ** k_steps) / (1 - zbar) * nu)
    # sqrt(pbar/punder) = 1 for uniform p
    assert emp <= bound * 1.15 + 1e-6, (emp, bound)
