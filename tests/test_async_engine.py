"""Bounded-staleness async rounds: the async-engine contract.

The headline contract: ``async_mode="stale"`` with ``max_staleness=0``
is BITWISE identical to the synchronous engine per realization -- under
both state layouts, both engine backends, and the registry compressors
-- and any recorded arrival schedule replays bit-for-bit through the
in-jit model (the broker only ever chooses the rows).  On top of that:
staleness counter semantics (stragglers keep training, forced arrival
at the bound), the arrival-schedule privacy composition, participation
/ arrival-mask edge cases, and the construction-time numeric validation
the async fields ride in on (damping / staleness).
"""

import argparse

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.fedplt import FedPLT, FedPLTConfig
from repro.core.problem import make_quadratic_problem
from repro.core.solvers import SolverConfig
from repro.fed import async_engine, engine, runtime
from repro.fed.api import (CompressionSpec, FedSpec, PrivacySpec,
                           add_spec_args, build_trainer,
                           effective_privacy_report, privacy_report,
                           spec_from_args)
from repro.fed.broker import ArrivalSchedule, IncrementBroker, replay
from repro.fed.engine import RoundConfig, StalenessConfig

N_AGENTS = 6
ROUNDS = 10


@pytest.fixture(scope="module")
def quad():
    return make_quadratic_problem(n_agents=N_AGENTS, dim=8, seed=3)


def _dense_pair(quad, **kw):
    base = dict(solver=SolverConfig(name="gd", n_epochs=3, step_size=0.05),
                participation=0.6, damping=0.7, **kw)
    sync = FedPLT(quad, FedPLTConfig(**base))
    asy = FedPLT(quad, FedPLTConfig(**base, async_mode="stale",
                                    max_staleness=0))
    return sync, asy


# ---------------------------------------------------------------------------
# max_staleness = 0 == the synchronous engine, bit for bit
# ---------------------------------------------------------------------------

DENSE_CASES = [
    dict(state_layout=layout, engine_backend=backend, compression=comp)
    for layout in ("tree", "packed")
    for backend in ("xla", "pallas")
    for comp in ("none", "topk", "int8")
]


@pytest.mark.parametrize(
    "kw", DENSE_CASES,
    ids=[f"{k['state_layout']}-{k['engine_backend']}-{k['compression']}"
         for k in DENSE_CASES])
def test_k0_bitwise_equals_sync_dense(quad, kw):
    sync, asy = _dense_pair(quad, **kw)
    key = jax.random.PRNGKey(42)
    s_state, s_crit = sync.run(key, ROUNDS)
    a_state, a_crit, sched = asy.run_recorded(key, ROUNDS)
    np.testing.assert_array_equal(np.asarray(s_state.x),
                                  np.asarray(a_state.x))
    np.testing.assert_array_equal(np.asarray(s_state.z),
                                  np.asarray(a_state.z))
    if s_state.t is not None:
        np.testing.assert_array_equal(np.asarray(s_state.t),
                                      np.asarray(a_state.t))
    np.testing.assert_array_equal(np.asarray(s_crit), np.asarray(a_crit))
    # at K = 0 the arrival mask IS the participation draw: partial
    assert 0 < np.asarray(sched).sum() < ROUNDS * N_AGENTS


class QuadModel:
    def init(self, key):
        return {"x": jnp.zeros(8)}

    def loss_fn(self, params, batch, remat=False):
        x = params["x"]
        return 0.5 * x @ batch["Q"] @ x + batch["c"] @ x


@pytest.mark.parametrize("layout,backend,comp", [
    ("tree", "xla", "none"),
    ("tree", "pallas", "topk"),
    ("packed", "xla", "int8"),
    ("packed", "pallas", "none"),
])
def test_k0_bitwise_equals_sync_model(quad, layout, backend, comp):
    model, batch = QuadModel(), {"Q": quad.Q, "c": quad.c}
    base = dict(n_agents=N_AGENTS, gamma=0.05, n_epochs=2,
                participation=0.7, state_layout=layout,
                engine_backend=backend,
                compression=CompressionSpec(name=comp))
    key = jax.random.PRNGKey(0)
    states = {}
    for tag, extra in (("sync", {}),
                       ("async", dict(async_mode="stale",
                                      max_staleness=0))):
        spec = FedSpec(**base, **extra)
        step = jax.jit(runtime.make_train_step(model, spec))
        state = runtime.init_state(model, key, spec)
        for i in range(4):
            state, m = step(state, batch, jax.random.PRNGKey(7))
        states[tag] = state
    for leaf_s, leaf_a in zip(
            jax.tree_util.tree_leaves((states["sync"].x,
                                       states["sync"].z)),
            jax.tree_util.tree_leaves((states["async"].x,
                                       states["async"].z))):
        np.testing.assert_array_equal(np.asarray(leaf_s),
                                      np.asarray(leaf_a))


# ---------------------------------------------------------------------------
# Recorded schedules replay bit-for-bit
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("layout,backend", [("tree", "xla"),
                                            ("packed", "pallas")])
def test_recorded_schedule_replays_bitwise(quad, layout, backend):
    algo = FedPLT(quad, FedPLTConfig(
        solver=SolverConfig(name="gd", n_epochs=3, step_size=0.05),
        participation=0.4, damping=0.7, async_mode="stale",
        max_staleness=3, state_layout=layout, engine_backend=backend))
    key = jax.random.PRNGKey(11)
    state, crit, sched = algo.run_recorded(key, 20)
    async_engine.validate_schedule(np.asarray(sched), 3)
    r_state, r_crit = algo.replay(key, sched)
    np.testing.assert_array_equal(np.asarray(state.x),
                                  np.asarray(r_state.x))
    np.testing.assert_array_equal(np.asarray(state.z),
                                  np.asarray(r_state.z))
    np.testing.assert_array_equal(np.asarray(state.staleness),
                                  np.asarray(r_state.staleness))
    np.testing.assert_array_equal(np.asarray(crit), np.asarray(r_crit))


def test_broker_run_replays_bitwise(quad):
    algo = FedPLT(quad, FedPLTConfig(
        solver=SolverConfig(name="gd", n_epochs=2, step_size=0.05),
        damping=0.7, async_mode="stale", max_staleness=2))
    key = jax.random.PRNGKey(5)
    step = lambda s, u: algo.round_with_arrival(s, u)[0]  # noqa: E731
    # agent 0 is a 10x straggler: it must be carried by the staleness
    # bound, arriving roughly every K+1 rounds
    broker = IncrementBroker(
        N_AGENTS, max_staleness=2, grace=0.003,
        latency_fn=lambda a, r: 0.01 if a == 0 else 0.001)
    final, sched = broker.run(step, algo.init(key), 12)
    assert sched.n_rounds == 12 and sched.n_agents == N_AGENTS
    sched.validate()
    arr, _ = sched.effective_counts()
    assert arr[0] < arr[1]          # the straggler arrived less often
    r_state = replay(step, algo.init(key), sched)
    np.testing.assert_array_equal(np.asarray(final.x),
                                  np.asarray(r_state.x))
    np.testing.assert_array_equal(np.asarray(final.z),
                                  np.asarray(r_state.z))


def test_broker_k0_is_the_synchronous_barrier(quad):
    algo = FedPLT(quad, FedPLTConfig(
        solver=SolverConfig(name="gd", n_epochs=2, step_size=0.05),
        async_mode="stale", max_staleness=0))
    step = lambda s, u: algo.round_with_arrival(s, u)[0]  # noqa: E731
    broker = IncrementBroker(N_AGENTS, max_staleness=0,
                             latency_fn=lambda a, r: 0.001)
    _, sched = broker.run(step, algo.init(jax.random.PRNGKey(0)), 5)
    # blocking on every dispatched agent: everyone arrives every round
    np.testing.assert_array_equal(sched.arrivals,
                                  np.ones((5, N_AGENTS), np.float32))


def test_schedule_save_load_roundtrip(tmp_path):
    sched = ArrivalSchedule(
        arrivals=np.asarray([[1, 0], [1, 1], [1, 1]], np.float32),
        max_staleness=1)
    path = tmp_path / "sched.json"
    sched.save(path)
    loaded = ArrivalSchedule.load(path)
    np.testing.assert_array_equal(sched.arrivals, loaded.arrivals)
    assert loaded.max_staleness == 1


# ---------------------------------------------------------------------------
# Staleness semantics on the raw in-jit model
# ---------------------------------------------------------------------------

def _async_cfg(n_agents=3, max_staleness=2, **kw):
    return RoundConfig(
        n_agents=n_agents, participation=1.0,
        staleness=StalenessConfig(mode="stale",
                                  max_staleness=max_staleness), **kw)


def _null_solver(x, v, key):
    # "training" that just returns the reflected target: makes the
    # round's algebra hand-checkable
    return v, None


def test_staleness_counters_and_forced_arrival():
    cfg = _async_cfg()
    N, dim = 3, 4
    x = z = t = jnp.zeros((N, dim))
    y_tag = async_engine.init_y_tag(z)
    s = async_engine.init_staleness(N)
    key = jax.random.PRNGKey(0)
    # round 0: agent 0 misses, others arrive
    r = async_engine.async_round_step(
        cfg, x, z, t, y_tag, s, key, _null_solver,
        arrival=jnp.asarray([0.0, 1.0, 1.0]))
    np.testing.assert_array_equal(np.asarray(r.staleness), [1, 0, 0])
    np.testing.assert_array_equal(np.asarray(r.u), [0, 1, 1])
    # the straggler kept its local progress (x <- w) but its z is frozen
    np.testing.assert_array_equal(np.asarray(r.z[0]), np.asarray(z[0]))
    # round 1: agent 0 misses again -> staleness 2 == K
    r2 = async_engine.async_round_step(
        cfg, r.x, r.z, r.t, r.y_tag, r.staleness, r.next_key,
        _null_solver, arrival=jnp.asarray([0.0, 1.0, 1.0]))
    np.testing.assert_array_equal(np.asarray(r2.staleness), [2, 0, 0])
    # round 2: the bound forces agent 0 in even though the row says 0
    r3 = async_engine.async_round_step(
        cfg, r2.x, r2.z, r2.t, r2.y_tag, r2.staleness, r2.next_key,
        _null_solver, arrival=jnp.asarray([0.0, 1.0, 1.0]))
    assert float(r3.u[0]) == 1.0
    np.testing.assert_array_equal(np.asarray(r3.staleness), [0, 0, 0])


def test_stale_increment_is_tagged_with_pulled_coordinator_point():
    # agent 0 pulls y at round 0, arrives at round 2: its z-update must
    # use the ROUND-0 y (its y_tag), not the round-2 y
    cfg = _async_cfg(max_staleness=2, damping=0.5)
    N, dim = 3, 2
    key = jax.random.PRNGKey(1)
    x = z = t = jnp.asarray(np.random.default_rng(0).normal(
        size=(N, dim)).astype(np.float32))
    y_tag = async_engine.init_y_tag(z)
    s = async_engine.init_staleness(N)
    rows = [jnp.asarray([0.0, 1.0, 1.0]), jnp.asarray([0.0, 1.0, 1.0]),
            jnp.asarray([1.0, 1.0, 1.0])]
    y0 = None
    for row in rows:
        r = async_engine.async_round_step(cfg, x, z, t, y_tag, s, key,
                                          _null_solver, arrival=row)
        if y0 is None:
            y0 = np.asarray(r.y)          # round-0 coordinator point
            z0_agent0 = np.asarray(z[0])
        x, z, t, y_tag, s, key = r.x, r.z, r.t, r.y_tag, r.staleness, \
            r.next_key
    # the tag the arrival used was the round-0 y...
    w_stale = 2.0 * y0 - z0_agent0        # null solver: w = v_stale
    expected = z0_agent0 + 2.0 * 0.5 * (w_stale - y0)
    np.testing.assert_allclose(np.asarray(r.z[0]), expected, rtol=1e-6)


def test_effective_counts_and_validation():
    # N=2, K=2: agent 0 arrives at staleness 2 (carries 3 rounds),
    # agent 1 arrives every round
    sched = np.asarray([[0, 1], [0, 1], [1, 1], [1, 1]], np.float32)
    arr, rel = async_engine.effective_counts(sched, 2)
    np.testing.assert_array_equal(arr, [2, 4])
    np.testing.assert_array_equal(rel, [4, 4])   # 3 + 1 vs 1*4
    async_engine.validate_schedule(sched, 2)
    with pytest.raises(ValueError, match="violates max_staleness"):
        async_engine.validate_schedule(sched, 1)
    with pytest.raises(ValueError, match="n_rounds, n_agents"):
        async_engine.effective_counts(np.ones(3), 1)


# ---------------------------------------------------------------------------
# Stale-aware privacy composition
# ---------------------------------------------------------------------------

def test_effective_privacy_reflects_released_rounds():
    spec = FedSpec(n_agents=2, gamma=0.05, n_epochs=5, rho=1.0,
                   privacy=PrivacySpec(tau=0.5, clip=1.0),
                   async_mode="stale", max_staleness=3)
    # agent 0 arrives at rounds 1, 5, 9, 13, 17: its first arrival is
    # only 1 round stale (2 released rounds) and its last 2 rounds of
    # work are still in flight at the end -- 18 released rounds vs the
    # full 20 for agent 1 (a stale arrival carries s+1 rounds, so mere
    # infrequency does NOT shrink the composition; unreleased work does)
    sched = np.zeros((20, 2), np.float32)
    sched[:, 1] = 1.0
    sched[1::4, 0] = 1.0
    rep = effective_privacy_report(spec, sched, 100)
    assert rep.per_agent is not None and len(rep.per_agent) == 2
    a0, a1 = rep.per_agent
    assert a0.arrivals == 5 and a1.arrivals == 20
    assert a0.K == 18 < a1.K == 20
    assert a0.adp_eps < a1.adp_eps   # fewer released rounds, smaller eps
    # and both are bounded by the nominal synchronous composition
    nominal = privacy_report(spec, 20, 100)
    assert rep.adp_eps <= nominal.adp_eps + 1e-12


def test_build_per_agent_accepts_per_agent_round_counts():
    from repro.core.privacy import PrivacyReport

    rep = PrivacyReport.build_per_agent(
        sensitivities=[100.0, 100.0], mu=1.0, tau=0.5, qs=[100, 100],
        gammas=[0.05, 0.05], K=20, n_epochs_seq=[5, 5], delta=1e-5,
        Ks=[5, 20], arrivals=[5, 20])
    a0, a1 = rep.per_agent
    assert (a0.K, a0.arrivals, a1.K, a1.arrivals) == (5, 5, 20, 20)
    assert a0.adp_eps < a1.adp_eps
    assert rep.adp_eps == a1.adp_eps   # headline = worst agent


# ---------------------------------------------------------------------------
# participation_mask / arrival_mask edge cases
# ---------------------------------------------------------------------------

def test_participation_mask_degenerate_rates():
    p = (0.0, 1.0, 0.5, 1.0, 0.0, 0.5)
    cfg = RoundConfig(n_agents=6, participation=p)
    key = jax.random.PRNGKey(0)
    draws = np.stack([np.asarray(engine.participation_mask(
        jax.random.fold_in(key, i), cfg)) for i in range(64)])
    assert draws.shape == (64, 6)
    np.testing.assert_array_equal(draws[:, 0], 0.0)   # p=0: never
    np.testing.assert_array_equal(draws[:, 4], 0.0)
    np.testing.assert_array_equal(draws[:, 1], 1.0)   # p=1: always
    np.testing.assert_array_equal(draws[:, 3], 1.0)
    assert 0 < draws[:, 2].sum() < 64                 # p=0.5: both


def test_participation_vector_length_mismatch_raises_before_tracing():
    with pytest.raises(ValueError, match="6 entries for n_agents=4"):
        RoundConfig(n_agents=4,
                    participation=(0.5, 0.5, 0.5, 0.5, 0.5, 0.5))


def test_arrival_mask_forces_at_the_bound():
    cfg = _async_cfg(n_agents=4, max_staleness=2)
    s = jnp.asarray([0, 1, 2, 2], jnp.int32)
    u = async_engine.arrival_mask(jax.random.PRNGKey(0), cfg, s,
                                  arrival=jnp.zeros(4))
    np.testing.assert_array_equal(np.asarray(u), [0, 0, 1, 1])


# ---------------------------------------------------------------------------
# Construction-time validation: damping + staleness fields
# ---------------------------------------------------------------------------

def test_string_damping_raises_at_construction():
    with pytest.raises(ValueError, match="damping must be a number"):
        RoundConfig(n_agents=4, damping="0.5")


def test_zero_d_array_damping_and_rho_accepted():
    cfg = RoundConfig(n_agents=4, damping=np.float64(0.5),
                      rho=jnp.asarray(2.0))
    assert cfg.damping == 0.5 and isinstance(cfg.damping, float)
    assert cfg.rho == 2.0 and isinstance(cfg.rho, float)


def test_staleness_config_validation():
    with pytest.raises(ValueError, match="unknown async mode"):
        StalenessConfig(mode="eventually")
    with pytest.raises(ValueError, match="max_staleness must be >= 0"):
        StalenessConfig(mode="stale", max_staleness=-1)
    with pytest.raises(ValueError, match="must be an integer"):
        StalenessConfig(mode="stale", max_staleness="3")
    with pytest.raises(ValueError, match="must be an integer"):
        StalenessConfig(mode="stale", max_staleness=1.5)
    # 0-d arrays are fine (configs built from parsed / loaded values)
    cfg = StalenessConfig(mode="stale", max_staleness=np.int64(4))
    assert cfg.max_staleness == 4 and isinstance(cfg.max_staleness, int)
    assert cfg.enabled and not StalenessConfig().enabled


def test_round_config_rejects_non_config_staleness():
    with pytest.raises(ValueError, match="StalenessConfig"):
        RoundConfig(n_agents=4, staleness="stale")


def test_spec_validate_catches_bad_async_fields():
    with pytest.raises(ValueError, match="unknown async mode"):
        FedSpec(n_agents=4, async_mode="later").validate()
    with pytest.raises(ValueError, match="max_staleness"):
        FedSpec(n_agents=4, async_mode="stale",
                max_staleness=-2).validate()


def test_sync_round_rejects_arrival_override(quad):
    algo = FedPLT(quad, FedPLTConfig(
        solver=SolverConfig(name="gd", n_epochs=1, step_size=0.05)))
    with pytest.raises(ValueError, match="require async_mode"):
        algo.round_with_arrival(algo.init(jax.random.PRNGKey(0)),
                                jnp.ones(N_AGENTS))


# ---------------------------------------------------------------------------
# Generated CLI
# ---------------------------------------------------------------------------

def test_async_cli_roundtrip(quad):
    spec = spec_from_args(["--async-mode", "stale",
                           "--max-staleness", "3",
                           "--participation", "0.5",
                           "--n-agents", str(N_AGENTS)])
    assert spec.async_mode == "stale" and spec.max_staleness == 3
    ecfg = build_trainer(quad, spec).algo._ecfg
    assert ecfg.staleness == StalenessConfig(mode="stale",
                                             max_staleness=3)
    # default stays synchronous
    assert spec_from_args([]).async_mode == "off"
    ap = argparse.ArgumentParser()
    add_spec_args(ap)
    with pytest.raises(SystemExit):   # argparse rejects unknown modes
        ap.parse_args(["--async-mode", "sometimes"])
