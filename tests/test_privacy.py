"""Privacy accountant: Prop. 4 bound shape, Lemma 5, calibration."""

import math

import pytest
from hypothesis import given, settings, strategies as st

from repro.core import privacy


ARGS = dict(sensitivity=1.0, mu=0.5, tau=0.1, q=250, gamma=0.1)


def test_eps_increases_with_rounds_but_bounded():
    eps = [privacy.rdp_epsilon(2.0, K=k, n_epochs=5, **ARGS)
           for k in (1, 10, 100, 10_000)]
    assert all(a < b for a, b in zip(eps, eps[1:]))
    ceiling = privacy.rdp_epsilon_limit(2.0, ARGS["sensitivity"],
                                        ARGS["mu"], ARGS["tau"], ARGS["q"])
    assert eps[-1] <= ceiling
    assert eps[-1] > 0.99 * ceiling  # saturates at the constant bound


def test_more_local_epochs_do_not_exceed_ceiling():
    """The paper's headline: N_e can be chosen freely for communication
    efficiency -- privacy stays under the same constant ceiling."""
    ceiling = privacy.rdp_epsilon_limit(2.0, ARGS["sensitivity"],
                                        ARGS["mu"], ARGS["tau"], ARGS["q"])
    for ne in (1, 5, 50, 500):
        eps = privacy.rdp_epsilon(2.0, K=1000, n_epochs=ne, **ARGS)
        assert eps <= ceiling + 1e-12


def test_eps_decreases_with_noise_and_data():
    e_small_tau = privacy.rdp_epsilon(2.0, K=100, n_epochs=5, **ARGS)
    e_big_tau = privacy.rdp_epsilon(
        2.0, K=100, n_epochs=5, **{**ARGS, "tau": 1.0})
    assert e_big_tau < e_small_tau
    e_big_q = privacy.rdp_epsilon(
        2.0, K=100, n_epochs=5, **{**ARGS, "q": 2500})
    assert e_big_q < e_small_tau


def test_rdp_to_adp_lemma5():
    assert privacy.rdp_to_adp(1.0, 2.0, 1e-5) == pytest.approx(
        1.0 + math.log(1e5), rel=1e-9)


def test_adp_optimizes_over_order():
    eps_fixed = privacy.rdp_to_adp(
        privacy.rdp_epsilon(2.0, K=100, n_epochs=5, **ARGS), 2.0, 1e-5)
    eps_best, lam = privacy.adp_epsilon(
        ARGS["sensitivity"], ARGS["mu"], ARGS["tau"], ARGS["q"],
        ARGS["gamma"], 100, 5, 1e-5)
    assert eps_best <= eps_fixed
    assert lam > 1.0


@given(st.floats(0.5, 20.0))
@settings(max_examples=20, deadline=None)
def test_calibration_inverse(target_eps):
    tau = privacy.calibrate_noise(target_eps, 1e-5, 1.0, 0.5, 250, 0.1,
                                  100, 5)
    eps, _ = privacy.adp_epsilon(1.0, 0.5, tau, 250, 0.1, 100, 5, 1e-5)
    assert eps <= target_eps * 1.01


def test_calibration_unreachable_target_raises():
    """Lemma 5 floors ADP eps at log(1/delta)/(lam-1) over the searched
    Renyi orders: a target below that floor must raise (stating the
    achievable eps), never silently return a tau that misses it."""
    with pytest.raises(ValueError, match="unreachable.*achievable "
                                         "eps=[0-9.e-]+"):
        privacy.calibrate_noise(1e-4, 1e-5, 1.0, 0.5, 250, 0.1, 100, 5)


def test_privacy_report():
    rep = privacy.PrivacyReport.build(1.0, 0.5, 0.1, 250, 0.1, 100, 5)
    assert rep.adp_eps > 0 and rep.eps_ceiling >= rep.adp_eps * 0.99
    assert rep.per_agent is None


def test_per_agent_report_max_and_rows():
    qs = [50, 100, 400]
    rep = privacy.PrivacyReport.build_per_agent(
        sensitivities=[1.0] * 3, mu=0.5, tau=0.1, qs=qs,
        gammas=[0.1] * 3, K=100, n_epochs_seq=[5, 5, 5])
    eps = [a.adp_eps for a in rep.per_agent]
    assert rep.adp_eps == max(eps)            # headline = worst agent
    assert eps[0] > eps[1] > eps[2]           # monotone in q_i
    assert rep.n_epochs == rep.per_agent[0].n_epochs
    assert rep.eps_ceiling >= rep.adp_eps
