"""Fault-tolerant federation runtime: the fault-injection contract.

Three layers under test, mirroring the broker/engine numerics-timing
split:

* :mod:`repro.fed.faults` -- seeded deterministic ``FaultPlan`` /
  ``FaultRecord`` artifacts (JSON round-trippable, NaN included);
* the in-jit fault overrides -- ``corrupt`` injection, the
  ``guard_increments`` uplink screen (a quarantined row IS a
  non-arrival), the survivor mean under ``live`` masks -- all BITWISE
  no-ops when disabled, on every layout x backend combo;
* the hardened :class:`repro.fed.broker.IncrementBroker` -- gate
  timeouts, retry/backoff, eviction, rejoin, and the bit-for-bit
  replay of faulty runs from ``(ArrivalSchedule, FaultRecord)``.

Plus the crash-safe checkpoint layer (atomic tmp-then-rename saves,
key-set validation, resume-bitwise) that rides in the same PR.
"""

import json
import math
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (find_latest_checkpoint, is_checkpoint,
                              restore_checkpoint, save_checkpoint)
from repro.core.fedplt import FedPLT, FedPLTConfig
from repro.core.problem import make_quadratic_problem
from repro.core.solvers import SolverConfig
from repro.fed import async_engine, engine, runtime
from repro.fed.api import (FedSpec, PrivacySpec, build_trainer,
                           effective_privacy_report, spec_from_args)
from repro.fed.broker import ArrivalSchedule, IncrementBroker, replay
from repro.fed.engine import RoundConfig
from repro.fed.faults import FaultEvent, FaultPlan, FaultRecord

N_AGENTS = 4

multi_device = pytest.mark.skipif(
    len(jax.devices()) < 8,
    reason="needs 8 devices (XLA_FLAGS=--xla_force_host_platform_"
           "device_count=8)")


@pytest.fixture(scope="module")
def quad():
    return make_quadratic_problem(n_agents=N_AGENTS, dim=8, seed=3)


def _algo(quad, **kw):
    base = dict(solver=SolverConfig(name="gd", n_epochs=2,
                                    step_size=0.05), damping=0.7)
    base.update(kw)
    return FedPLT(quad, FedPLTConfig(**base))


def _assert_state_equal(a, b, fields=("x", "z", "y")):
    for f in fields:
        va, vb = getattr(a, f), getattr(b, f)
        if va is None:
            assert vb is None
            continue
        np.testing.assert_array_equal(np.asarray(va), np.asarray(vb),
                                      err_msg=f"field {f}")


# ---------------------------------------------------------------------------
# FaultPlan / FaultRecord artifacts
# ---------------------------------------------------------------------------

def test_fault_event_validation():
    with pytest.raises(ValueError, match="unknown fault kind"):
        FaultEvent("explode", 0, 0)
    with pytest.raises(ValueError, match="agent must be >= 0"):
        FaultEvent("crash", -1, 0)
    with pytest.raises(ValueError, match="must exceed round"):
        FaultEvent("crash", 0, 3, until=3)
    with pytest.raises(ValueError, match="delay must be >= 0"):
        FaultEvent("stall", 0, 0, delay=-0.1)


def test_fault_plan_queries():
    plan = FaultPlan((FaultEvent("crash", 1, 2, until=5),
                      FaultEvent("drop", 0, 1),
                      FaultEvent("corrupt", 2, 3, value=float("nan")),
                      FaultEvent("stall", 3, 0, delay=0.25)))
    assert plan.needs_timeout()
    assert not plan.crashed(1, 1)
    assert plan.crashed(1, 2) and plan.crashed(1, 4)
    assert not plan.crashed(1, 5)          # until is exclusive
    assert plan.rejoins_at(5) == [1]
    assert plan.dropped(0, 1, attempt=0)
    assert not plan.dropped(0, 1, attempt=1)   # one drop eats one try
    assert math.isnan(plan.corrupt_value(2, 3))
    assert plan.corrupt_value(2, 4) is None
    assert plan.stall_delay(3, 0) == 0.25
    lat = plan.wrap_latency(lambda a, r: 0.1)
    assert lat(3, 0) == pytest.approx(0.35) and lat(3, 1) == 0.1
    with pytest.raises(ValueError, match="only 3 agents"):
        plan.check_agents(3)
    # a corrupt-only plan never loses work: no timeout needed
    assert not FaultPlan((FaultEvent("corrupt", 0, 0),)).needs_timeout()


def test_fault_plan_json_roundtrip_with_nan(tmp_path):
    plan = FaultPlan((FaultEvent("corrupt", 0, 1, value=float("nan")),
                      FaultEvent("corrupt", 1, 2, value=float("inf")),
                      FaultEvent("crash", 2, 0, until=4)),
                     n_agents=3, seed=7)
    path = tmp_path / "plan.json"
    plan.save(path)
    loaded = FaultPlan.load(path)
    assert loaded.n_agents == 3 and loaded.seed == 7
    assert math.isnan(loaded.corrupt_value(0, 1))
    assert math.isinf(loaded.corrupt_value(1, 2))
    assert loaded.crashed(2, 3) and not loaded.crashed(2, 4)


def test_fault_plan_generate_deterministic():
    kw = dict(p_crash=0.05, crash_length=2, p_drop=0.1, p_corrupt=0.1,
              p_stall=0.1)
    a = FaultPlan.generate(11, n_agents=8, n_rounds=20, **kw)
    b = FaultPlan.generate(11, n_agents=8, n_rounds=20, **kw)
    assert a.events == b.events and len(a.events) > 0
    c = FaultPlan.generate(12, n_agents=8, n_rounds=20, **kw)
    assert a.events != c.events
    # no new faults are scheduled for an agent while it is down
    for e in a.events:
        assert not any(o.kind == "crash" and o.agent == e.agent
                       and o is not e and o.round <= e.round
                       and (o.until is None or e.round < o.until)
                       for o in a.events)


# the satellite acceptance matrix: every byzantine JSON shape that must
# load, and every malformed one that must be rejected with the same
# error a directly-constructed event raises
_BYZ_JSON_ACCEPT = [
    # (payload, probe(event) -> bool)
    ({"kind": "sign_flip", "agent": 0, "round": 2},
     lambda e: e.byzantine_pair() == (-1.0, 0.0) and e.until is None),
    ({"kind": "sign_flip", "agent": 1, "round": 0, "until": 5},
     lambda e: e.active_at(4) and not e.active_at(5)),
    ({"kind": "scale", "agent": 2, "round": 1, "value": -5.0},
     lambda e: e.byzantine_pair() == (-5.0, 0.0)),
    ({"kind": "scale", "agent": 0, "round": 0, "value": 0.25,
      "until": 3},
     lambda e: e.byzantine_pair() == (0.25, 0.0)),
    ({"kind": "drift", "agent": 3, "round": 7, "value": 0.1},
     lambda e: e.byzantine_pair() == (1.0, 0.1)),
]

_BYZ_JSON_REJECT = [
    # (payload, error-pattern)
    ({"kind": "sign_flip", "agent": 0, "round": 0, "value": 2.0},
     "takes no value"),
    ({"kind": "scale", "agent": 0, "round": 0}, "finite nonzero"),
    ({"kind": "scale", "agent": 0, "round": 0, "value": 0.0},
     "finite nonzero"),
    ({"kind": "drift", "agent": 0, "round": 0,
      "value": float("inf")}, "finite value"),
    ({"kind": "sign_flip", "agent": 0, "round": 0, "delay": 0.5},
     "carry no delay"),
]


@pytest.mark.parametrize("payload,probe", _BYZ_JSON_ACCEPT)
def test_byzantine_json_accepts(payload, probe):
    e = FaultEvent.from_json(payload)
    assert e.byzantine
    assert probe(e)
    # the round trip is exact: dumping re-yields the canonical payload
    assert FaultEvent.from_json(e.to_json()) == e
    assert json.loads(json.dumps(e.to_json())) == e.to_json()


@pytest.mark.parametrize("payload,pattern", _BYZ_JSON_REJECT)
def test_byzantine_json_rejects(payload, pattern):
    with pytest.raises(ValueError, match=pattern):
        FaultEvent.from_json(payload)


def test_byzantine_plan_json_roundtrip_and_generate():
    plan = FaultPlan.generate(21, n_agents=8, n_rounds=10,
                              n_byzantine=3, byzantine_kind="scale",
                              byzantine_value=-2.0, byzantine_start=2)
    again = FaultPlan.generate(21, n_agents=8, n_rounds=10,
                               n_byzantine=3, byzantine_kind="scale",
                               byzantine_value=-2.0, byzantine_start=2)
    assert plan.events == again.events and plan.has_byzantine
    byz = [e for e in plan.events if e.byzantine]
    assert len(byz) == 3
    assert len({e.agent for e in byz}) == 3
    assert all(e.round == 2 and e.until is None for e in byz)
    loaded = FaultPlan.from_json(plan.to_json())
    assert loaded.events == plan.events
    with pytest.raises(ValueError, match="unknown byzantine kind"):
        FaultPlan.generate(0, n_agents=4, n_rounds=2, n_byzantine=1,
                           byzantine_kind="gaussian")
    with pytest.raises(ValueError, match="needs a byzantine_value"):
        FaultPlan.generate(0, n_agents=4, n_rounds=2, n_byzantine=1,
                           byzantine_kind="scale")
    with pytest.raises(ValueError, match="n_byzantine"):
        FaultPlan.generate(0, n_agents=2, n_rounds=2, n_byzantine=3)


def test_fault_plan_indexes_match_scan():
    """Satellite 1: corrupt_value / byzantine_at answer from the
    (agent, round) indexes built at construction; the pre-index linear
    scans are kept as the regression oracle."""
    rng = np.random.default_rng(4)
    events = []
    for _ in range(60):
        kind = rng.choice(["corrupt", "sign_flip", "scale", "drift"])
        agent, rnd = int(rng.integers(6)), int(rng.integers(12))
        until = None if rng.random() < 0.5 else rnd + int(
            rng.integers(1, 4))
        if kind == "corrupt":
            events.append(FaultEvent("corrupt", agent, rnd, until=until,
                                     value=float(rng.normal())))
        elif kind == "sign_flip":
            events.append(FaultEvent("sign_flip", agent, rnd,
                                     until=until))
        elif kind == "scale":
            events.append(FaultEvent("scale", agent, rnd, until=until,
                                     value=float(rng.normal()) or 1.0))
        else:
            events.append(FaultEvent("drift", agent, rnd, until=until,
                                     value=float(rng.normal())))
    plan = FaultPlan(tuple(events))
    for agent in range(6):
        for rnd in range(14):
            assert plan.corrupt_value(agent, rnd) == \
                plan._corrupt_value_scan(agent, rnd)
            assert plan.byzantine_at(agent, rnd) == \
                plan._byzantine_at_scan(agent, rnd)


def test_fault_record_live_index_matches_scan():
    """Satellite 1 (record side): live_row binary-searches cumulative
    snapshots; the linear scan stays as the oracle, including the
    out-of-round-order fallback."""
    rec = FaultRecord(n_agents=5)
    rec.note_eviction(1, 2)
    rec.note_eviction(3, 4)
    rec.note_rejoin(1, 6)
    rec.note_eviction(0, 9)
    for r in range(12):
        got = rec.live_row(r)
        want = rec._live_row_scan(r)
        if want is None:
            assert got is None
        else:
            np.testing.assert_array_equal(got, want)
    # a mutated record invalidates and rebuilds the cache
    rec.note_eviction(4, 10)
    np.testing.assert_array_equal(rec.live_row(11),
                                  rec._live_row_scan(11))
    # out-of-round-order events: the index detects it and falls back
    rec2 = FaultRecord(n_agents=3)
    rec2.note_eviction(0, 5)
    rec2.note_eviction(1, 2)           # earlier round appended later
    for r in range(8):
        got = rec2.live_row(r)
        want = rec2._live_row_scan(r)
        if want is None:
            assert got is None
        else:
            np.testing.assert_array_equal(got, want)


def test_fault_record_live_rows_and_json(tmp_path):
    rec = FaultRecord(n_agents=3)
    assert not rec.has_faults and rec.live_row(5) is None
    rec.note_eviction(1, 2)
    rec.note_rejoin(1, 4)
    rec.note_retry(1, 2, 1)
    rec.note_drop(0, 1)
    rec.note_error(2, 3, RuntimeError("boom"))
    rec.note_corrupt_row(2, np.asarray([0.0, 0.0, float("nan")]))
    assert rec.has_faults
    assert rec.live_row(1) is None      # before the first eviction
    np.testing.assert_array_equal(rec.live_row(2), [1.0, 0.0, 1.0])
    np.testing.assert_array_equal(rec.live_row(4), [1.0, 1.0, 1.0])
    lm = rec.live_matrix(5)
    np.testing.assert_array_equal(lm[:, 1], [1, 1, 0, 0, 1])
    path = tmp_path / "record.json"
    rec.save(path)
    loaded = FaultRecord.load(path)
    assert loaded.evictions == [(1, 2)] and loaded.rejoins == [(1, 4)]
    assert loaded.retries == [(1, 2, 1)] and loaded.drops == [(0, 1)]
    assert "boom" in loaded.errors[0][2]
    assert math.isnan(loaded.corrupt_row(2)[2])
    np.testing.assert_array_equal(loaded.live_row(3), rec.live_row(3))


# ---------------------------------------------------------------------------
# In-jit guards: bitwise no-op when clean, quarantine == non-arrival
# ---------------------------------------------------------------------------

GUARD_CASES = [
    dict(state_layout=layout, engine_backend=backend, compression=comp)
    for layout in ("tree", "packed")
    for backend in ("xla", "pallas")
    for comp in ("none", "topk")
]


@pytest.mark.parametrize(
    "kw", GUARD_CASES,
    ids=[f"{k['state_layout']}-{k['engine_backend']}-{k['compression']}"
         for k in GUARD_CASES])
def test_guards_on_clean_run_is_bitwise_noop(quad, kw):
    key = jax.random.PRNGKey(21)
    plain = _algo(quad, participation=0.6, **kw)
    guarded = _algo(quad, participation=0.6, guard_increments=True,
                    guard_norm_bound=1e6, **kw)
    s0, c0 = plain.run(key, 6)
    s1, c1 = guarded.run(key, 6)
    _assert_state_equal(s0, s1, fields=("x", "z", "t", "y"))
    np.testing.assert_array_equal(np.asarray(c0), np.asarray(c1))


@pytest.mark.parametrize("layout,backend", [
    ("tree", "xla"), ("tree", "pallas"),
    ("packed", "xla"), ("packed", "pallas")])
def test_quarantine_equals_non_arrival_sync(quad, layout, backend):
    # run A: everyone participates, agent 2's increment arrives as NaN,
    # the guard screens it; run B: agent 2 simply never participates.
    # The screened round must be bitwise the non-participation round.
    key = jax.random.PRNGKey(5)
    kw = dict(state_layout=layout, engine_backend=backend,
              guard_increments=True)
    a = _algo(quad, **kw)
    b = FedPLT(quad, FedPLTConfig(
        solver=SolverConfig(name="gd", n_epochs=2, step_size=0.05),
        damping=0.7, **kw), participation=(1.0, 1.0, 0.0, 1.0))
    corrupt = np.zeros(N_AGENTS, np.float32)
    corrupt[2] = np.nan
    sa, sb = a.init(key), b.init(key)
    for _ in range(3):
        sa, ua = a.round_with_faults(sa, None, jnp.asarray(corrupt), None)
        sb, ub = b.round_with_faults(sb, None, None, None)
        np.testing.assert_array_equal(np.asarray(ua), np.asarray(ub))
    _assert_state_equal(sa, sb)
    assert np.isfinite(np.asarray(sa.x)).all()


def test_quarantine_async_discards_poisoned_work(quad):
    # K > 0: a quarantined agent must NOT keep its poisoned local state
    # (keep &= ok), while a clean non-arriver DOES keep training -- so
    # (z, staleness) agree bitwise and only the straggler's x differs
    key = jax.random.PRNGKey(9)
    algo = _algo(quad, async_mode="stale", max_staleness=1,
                 guard_increments=True)
    corrupt = np.zeros(N_AGENTS, np.float32)
    corrupt[1] = np.inf
    ones = jnp.ones(N_AGENTS)
    miss = jnp.asarray([1.0, 0.0, 1.0, 1.0])
    sa = sb = algo.init(key)
    sa, ua = algo.round_with_faults(sa, ones, jnp.asarray(corrupt), None)
    sb, ub = algo.round_with_faults(sb, miss, None, None)
    np.testing.assert_array_equal(np.asarray(ua), np.asarray(ub))
    np.testing.assert_array_equal(np.asarray(sa.z), np.asarray(sb.z))
    np.testing.assert_array_equal(np.asarray(sa.staleness),
                                  np.asarray(sb.staleness))
    assert np.isfinite(np.asarray(sa.x)).all()   # poison discarded...
    np.testing.assert_array_equal(          # ...x pinned at its old value
        np.asarray(sa.x[1]), np.asarray(algo.init(key).x[1]))
    # the clean straggler kept its local progress instead
    assert not np.array_equal(np.asarray(sb.x[1]),
                              np.asarray(algo.init(key).x[1]))


def test_norm_bound_guard_vs_finiteness_only(quad):
    key = jax.random.PRNGKey(2)
    corrupt = np.zeros(N_AGENTS, np.float32)
    corrupt[0] = 1e4          # large but finite: norm-bound territory
    bounded = _algo(quad, guard_increments=True, guard_norm_bound=100.0)
    unbounded = _algo(quad, guard_increments=True)   # inf: finite-only
    dropped = FedPLT(quad, FedPLTConfig(
        solver=SolverConfig(name="gd", n_epochs=2, step_size=0.05),
        damping=0.7, guard_increments=True, guard_norm_bound=100.0),
        participation=(0.0, 1.0, 1.0, 1.0))
    s_b, _ = bounded.round_with_faults(bounded.init(key), None,
                                       jnp.asarray(corrupt), None)
    s_d, _ = dropped.round_with_faults(dropped.init(key), None, None,
                                       None)
    _assert_state_equal(s_b, s_d)       # over-norm row == non-arrival
    s_u, u_u = unbounded.round_with_faults(unbounded.init(key), None,
                                           jnp.asarray(corrupt), None)
    assert float(u_u[0]) == 1.0         # finite -> passes the inf bound
    assert float(jnp.max(jnp.abs(s_u.x))) > 1e2   # and poisons the state


def test_corrupt_without_guard_poisons_consensus(quad):
    algo = _algo(quad)
    corrupt = np.zeros(N_AGENTS, np.float32)
    corrupt[3] = np.nan
    s, u = algo.round_with_faults(algo.init(jax.random.PRNGKey(0)), None,
                                  jnp.asarray(corrupt), None)
    assert float(u[3]) == 1.0
    assert np.isnan(np.asarray(s.x[3])).any()
    assert np.isnan(np.asarray(algo.x_bar(s))).any()


def test_survivor_mean_input_algebra():
    cfg = RoundConfig(n_agents=4)
    z = jnp.asarray(np.random.default_rng(0).normal(size=(4, 5)),
                    jnp.float32)
    live = jnp.asarray([1.0, 0.0, 1.0, 1.0])
    scaled = engine.survivor_mean_input(cfg, z, live)
    np.testing.assert_allclose(
        np.asarray(jnp.mean(scaled, axis=0)),
        np.asarray(jnp.mean(z[jnp.asarray([0, 2, 3])], axis=0)),
        rtol=1e-6)
    assert engine.survivor_mean_input(cfg, z, None) is z


def test_live_mask_drops_evicted_agents_from_round(quad):
    algo = _algo(quad, async_mode="stale", max_staleness=1)
    live = jnp.asarray([1.0, 1.0, 0.0, 1.0])
    s, u = algo.round_with_faults(algo.init(jax.random.PRNGKey(4)),
                                  jnp.ones(N_AGENTS), None, live)
    assert float(u[2]) == 0.0           # forced arrival loses to death
    np.testing.assert_array_equal(np.asarray(s.staleness)[2], 0)
    # the dead agent's state is frozen
    np.testing.assert_array_equal(
        np.asarray(s.z[2]),
        np.asarray(algo.init(jax.random.PRNGKey(4)).z[2]))


@multi_device
@pytest.mark.parametrize("shards", [1, 8])
def test_quarantine_equals_non_arrival_sharded(shards):
    from jax.sharding import Mesh

    prob = make_quadratic_problem(n_agents=8, dim=8, seed=1)
    mesh = Mesh(np.asarray(jax.devices()[:shards]).reshape(shards, 1),
                ("agent", "model"))
    cfg = FedPLTConfig(
        solver=SolverConfig(name="gd", n_epochs=2, step_size=0.05),
        damping=0.7, state_layout="packed", engine_backend="pallas",
        guard_increments=True)
    a = FedPLT(prob, cfg, mesh=mesh)
    b = FedPLT(prob, cfg, mesh=mesh,
               participation=(1.0,) * 5 + (0.0,) + (1.0,) * 2)
    corrupt = np.zeros(8, np.float32)
    corrupt[5] = np.nan
    key = jax.random.PRNGKey(3)
    sa, _ = a.round_with_faults(a.init(key), None, jnp.asarray(corrupt),
                                None)
    sb, _ = b.round_with_faults(b.init(key), None, None, None)
    _assert_state_equal(sa, sb)
    assert np.isfinite(np.asarray(sa.x)).all()


# ---------------------------------------------------------------------------
# Broker fault tolerance: timeout -> retry -> evict -> replay
# ---------------------------------------------------------------------------

def _fault_step(algo):
    return lambda s, u, c, l: algo.round_with_faults(s, u, c, l)[0]


def test_broker_crash_eviction_completes_and_replays_bitwise(quad):
    # 2 of 64 agents crash mid-training: the run completes, evicts them
    # after the retry budget, and the (schedule, record) pair replays
    # the whole trajectory bit-for-bit
    prob = make_quadratic_problem(n_agents=64, dim=4, seed=0)
    algo = FedPLT(prob, FedPLTConfig(
        solver=SolverConfig(name="gd", n_epochs=1, step_size=0.05),
        damping=0.7, async_mode="stale", max_staleness=1))
    plan = FaultPlan((FaultEvent("crash", 3, 2),
                      FaultEvent("crash", 7, 2)))
    broker = IncrementBroker(
        64, max_staleness=1, latency_fn=lambda a, r: 0.0005,
        grace=0.005, gate_timeout=0.05, max_retries=1)
    step = _fault_step(algo)
    key = jax.random.PRNGKey(0)
    final, sched = broker.run(step, algo.init(key), 8, faults=plan)
    rec = broker.record
    assert sorted(a for a, _ in rec.evictions) == [3, 7]
    assert all(r >= 2 for _, r in rec.evictions)
    assert rec.retries                   # the budget was consumed first
    assert sched.live is not None
    first = rec.first_eviction_round()
    assert (sched.arrivals[first:, [3, 7]] == 0.0).all()
    assert (sched.live[first:, [3, 7]] == 0.0).all()
    assert (sched.live[:, [0, 1, 2]] == 1.0).all()
    assert np.isfinite(np.asarray(final.x)).all()
    r_state = replay(step, algo.init(key), sched, record=rec)
    _assert_state_equal(final, r_state, fields=("x", "z", "staleness"))


def test_broker_crash_window_rejoins(quad):
    algo = _algo(quad, async_mode="stale", max_staleness=0)
    plan = FaultPlan((FaultEvent("crash", 1, 1, until=3),))
    broker = IncrementBroker(
        N_AGENTS, max_staleness=0, latency_fn=lambda a, r: 0.001,
        gate_timeout=0.04, max_retries=1)
    step = _fault_step(algo)
    key = jax.random.PRNGKey(1)
    final, sched = broker.run(step, algo.init(key), 5, faults=plan)
    rec = broker.record
    assert rec.evictions == [(1, 1)]
    assert rec.rejoins == [(1, 3)]
    np.testing.assert_array_equal(sched.arrivals[:, 1], [1, 0, 0, 1, 1])
    np.testing.assert_array_equal(sched.live[:, 1], [1, 0, 0, 1, 1])
    r_state = replay(step, algo.init(key), sched, record=rec)
    _assert_state_equal(final, r_state, fields=("x", "z"))


def test_broker_drop_is_recovered_by_redispatch(quad):
    algo = _algo(quad, async_mode="stale", max_staleness=0)
    plan = FaultPlan((FaultEvent("drop", 0, 1),))
    broker = IncrementBroker(
        N_AGENTS, max_staleness=0, latency_fn=lambda a, r: 0.001,
        gate_timeout=0.05, max_retries=2)
    step = _fault_step(algo)
    key = jax.random.PRNGKey(2)
    final, sched = broker.run(step, algo.init(key), 4, faults=plan)
    rec = broker.record
    assert rec.drops == [(0, 1)]
    assert any(a == 0 and r == 1 for a, r, _n in rec.retries)
    assert not rec.evictions and sched.live is None
    # the redispatch got through: nobody missed a synchronous round
    np.testing.assert_array_equal(sched.arrivals,
                                  np.ones((4, N_AGENTS), np.float32))
    r_state = replay(step, algo.init(key), sched, record=rec)
    _assert_state_equal(final, r_state, fields=("x", "z"))


def test_broker_corrupt_plan_is_quarantined_and_replays(quad):
    algo = _algo(quad, async_mode="stale", max_staleness=1,
                 guard_increments=True)
    plan = FaultPlan((FaultEvent("corrupt", 2, 1, value=float("nan")),))
    broker = IncrementBroker(N_AGENTS, max_staleness=1,
                             latency_fn=lambda a, r: 0.001, grace=0.01)
    step = _fault_step(algo)
    key = jax.random.PRNGKey(3)
    final, sched = broker.run(step, algo.init(key), 4, faults=plan)
    rec = broker.record
    assert list(rec.corrupt_rows) == [1]
    assert math.isnan(rec.corrupt_rows[1][2])
    assert not rec.evictions
    assert np.isfinite(np.asarray(final.x)).all()    # guard held
    r_state = replay(step, algo.init(key), sched, record=rec)
    _assert_state_equal(final, r_state, fields=("x", "z", "staleness"))


def test_broker_requires_timeout_for_lossy_plans(quad):
    algo = _algo(quad, async_mode="stale", max_staleness=0)
    plan = FaultPlan((FaultEvent("crash", 0, 0),))
    broker = IncrementBroker(N_AGENTS, max_staleness=0)
    with pytest.raises(ValueError, match="needs a broker gate_timeout"):
        broker.run(_fault_step(algo), algo.init(jax.random.PRNGKey(0)),
                   2, faults=plan)


def test_broker_plan_agent_bounds_checked(quad):
    algo = _algo(quad, async_mode="stale", max_staleness=0)
    plan = FaultPlan((FaultEvent("corrupt", 9, 0),))
    broker = IncrementBroker(N_AGENTS, max_staleness=0)
    with pytest.raises(ValueError, match="only 4 agents"):
        broker.run(_fault_step(algo), algo.init(jax.random.PRNGKey(0)),
                   1, faults=plan)


def test_broker_legacy_round_fn_rejected_on_faulty_rows(quad):
    algo = _algo(quad, async_mode="stale", max_staleness=0)
    plan = FaultPlan((FaultEvent("corrupt", 0, 0, value=2.0),))
    broker = IncrementBroker(N_AGENTS, max_staleness=0,
                             latency_fn=lambda a, r: 0.001)
    step2 = lambda s, u: algo.round_with_arrival(s, u)[0]  # noqa: E731
    with pytest.raises(TypeError, match="4-arg form"):
        broker.run(step2, algo.init(jax.random.PRNGKey(0)), 2,
                   faults=plan)


def test_broker_raising_latency_fn_without_timeout_is_loud(quad):
    algo = _algo(quad, async_mode="stale", max_staleness=0)

    def bad_latency(a, r):
        if a == 1:
            raise OSError("link down")
        return 0.001

    broker = IncrementBroker(N_AGENTS, max_staleness=0,
                             latency_fn=bad_latency)
    step = lambda s, u: algo.round_with_arrival(s, u)[0]  # noqa: E731
    with pytest.raises(RuntimeError, match="agent 1 worker failed"):
        broker.run(step, algo.init(jax.random.PRNGKey(0)), 3)


def test_broker_raising_latency_fn_with_timeout_evicts(quad):
    algo = _algo(quad, async_mode="stale", max_staleness=0)

    def bad_latency(a, r):
        if a == 1:
            raise OSError("link down")
        return 0.001

    broker = IncrementBroker(N_AGENTS, max_staleness=0,
                             latency_fn=bad_latency, gate_timeout=0.2,
                             max_retries=0)
    final, sched = broker.run(
        _fault_step(algo), algo.init(jax.random.PRNGKey(0)), 3)
    rec = broker.record
    assert rec.evictions and rec.evictions[0][0] == 1
    assert rec.errors and "link down" in rec.errors[0][2]
    assert (sched.arrivals[:, 1] == 0.0).all() or \
        sched.arrivals[0, 1] == 0.0


def test_broker_evicting_everyone_raises(quad):
    algo = _algo(quad, async_mode="stale", max_staleness=0)
    plan = FaultPlan(tuple(FaultEvent("crash", a, 0)
                           for a in range(N_AGENTS)))
    broker = IncrementBroker(N_AGENTS, max_staleness=0,
                             latency_fn=lambda a, r: 0.001,
                             gate_timeout=0.02, max_retries=0)
    with pytest.raises(RuntimeError, match="no survivors"):
        broker.run(_fault_step(algo), algo.init(jax.random.PRNGKey(0)),
                   2, faults=plan)


# ---------------------------------------------------------------------------
# Broker edge cases (satellites): fresh buffers, grace drain, degenerate
# shapes
# ---------------------------------------------------------------------------

def test_broker_runs_do_not_share_buffers(quad):
    # regression: a straggler worker outliving its run's join timeout
    # must not be able to submit into a LATER run's buffer.  Agent 0's
    # only submission lands after run 1 returns; if the buffer were an
    # instance attribute, run 2's round 0 would consume it as a
    # perfectly-valid (agent 0, round 0) arrival.
    algo = _algo(quad, async_mode="stale", max_staleness=1)
    broker = IncrementBroker(
        N_AGENTS, max_staleness=1, grace=0.01, join_timeout=0.01,
        latency_fn=lambda a, r: 0.25 if a == 0 else 0.001)
    step = _fault_step(algo)
    key = jax.random.PRNGKey(0)
    _, sched1 = broker.run(step, algo.init(key), 1)
    _, sched2 = broker.run(step, algo.init(key), 1)
    np.testing.assert_array_equal(sched1.arrivals, sched2.arrivals)
    assert sched1.arrivals[0, 0] == 0.0


def test_broker_grace_drains_everything_ready(quad):
    algo = _algo(quad, async_mode="stale", max_staleness=2)
    broker = IncrementBroker(N_AGENTS, max_staleness=2, grace=0.05,
                             latency_fn=lambda a, r: 0.001)
    _, sched = broker.run(_fault_step(algo),
                          algo.init(jax.random.PRNGKey(0)), 3)
    # nobody is must-arrive before staleness 2, but the grace window is
    # long enough that every round drains every agent anyway
    np.testing.assert_array_equal(sched.arrivals,
                                  np.ones((3, N_AGENTS), np.float32))


def test_broker_zero_rounds(quad):
    algo = _algo(quad, async_mode="stale", max_staleness=1)
    state = algo.init(jax.random.PRNGKey(0))
    broker = IncrementBroker(N_AGENTS, max_staleness=1)
    out, sched = broker.run(_fault_step(algo), state, 0)
    assert out is state
    assert sched.arrivals.shape == (0, N_AGENTS)
    assert sched.live is None and broker.record.has_faults is False


def test_broker_single_agent_with_staleness():
    prob = make_quadratic_problem(n_agents=1, dim=4, seed=0)
    algo = FedPLT(prob, FedPLTConfig(
        solver=SolverConfig(name="gd", n_epochs=1, step_size=0.05),
        async_mode="stale", max_staleness=2))
    broker = IncrementBroker(1, max_staleness=2, grace=0.01,
                             latency_fn=lambda a, r: 0.001)
    final, sched = broker.run(_fault_step(algo),
                              algo.init(jax.random.PRNGKey(0)), 5)
    assert sched.arrivals.shape == (5, 1)
    sched.validate()
    assert sched.arrivals.sum() > 0
    assert np.isfinite(np.asarray(final.x)).all()


# ---------------------------------------------------------------------------
# ArrivalSchedule.load validation (satellite)
# ---------------------------------------------------------------------------

def _write(tmp_path, name, payload):
    p = tmp_path / name
    p.write_text(json.dumps(payload))
    return p


def test_schedule_load_rejects_malformed_files(tmp_path):
    ok = {"max_staleness": 1, "arrivals": [[1, 0], [1, 1]]}
    ArrivalSchedule.load(_write(tmp_path, "ok.json", ok))
    with pytest.raises(ValueError, match="need 'arrivals'"):
        ArrivalSchedule.load(_write(tmp_path, "a.json",
                                    {"arrivals": [[1]]}))
    with pytest.raises(ValueError, match="need 'arrivals'"):
        ArrivalSchedule.load(_write(tmp_path, "b.json", [[1, 0]]))
    with pytest.raises(ValueError, match="non-negative integer"):
        ArrivalSchedule.load(_write(
            tmp_path, "c.json", dict(ok, max_staleness=-1)))
    with pytest.raises(ValueError, match="non-negative integer"):
        ArrivalSchedule.load(_write(
            tmp_path, "d.json", dict(ok, max_staleness=1.5)))
    with pytest.raises(ValueError, match="non-negative integer"):
        ArrivalSchedule.load(_write(
            tmp_path, "e.json", dict(ok, max_staleness=True)))
    with pytest.raises(ValueError, match="must be 0 or 1"):
        ArrivalSchedule.load(_write(
            tmp_path, "f.json", dict(ok, arrivals=[[1, 2], [1, 1]])))
    with pytest.raises(ValueError, match="inconsistent lengths"):
        ArrivalSchedule.load(_write(
            tmp_path, "g.json", dict(ok, arrivals=[[1, 0], [1]])))
    with pytest.raises(ValueError, match="got shape"):
        ArrivalSchedule.load(_write(
            tmp_path, "h.json", dict(ok, arrivals=[1, 0, 1])))
    with pytest.raises(ValueError, match="does not match arrivals"):
        ArrivalSchedule.load(_write(
            tmp_path, "i.json", dict(ok, live=[[1, 1]])))
    with pytest.raises(ValueError, match="violates max_staleness"):
        ArrivalSchedule.load(_write(
            tmp_path, "j.json",
            {"max_staleness": 1, "arrivals": [[1, 0], [1, 0], [1, 0]]}))


def test_schedule_save_load_roundtrip_with_live(tmp_path):
    sched = ArrivalSchedule(
        arrivals=np.asarray([[1, 1], [1, 0], [1, 0]], np.float32),
        max_staleness=0,
        live=np.asarray([[1, 1], [1, 0], [1, 0]], np.float32))
    path = tmp_path / "sched.json"
    sched.save(path)
    loaded = ArrivalSchedule.load(path)
    np.testing.assert_array_equal(loaded.arrivals, sched.arrivals)
    np.testing.assert_array_equal(loaded.live, sched.live)


def test_validate_schedule_rejects_ghost_arrivals():
    arr = np.asarray([[1, 1], [1, 1]], np.float32)
    live = np.asarray([[1, 1], [1, 0]], np.float32)
    with pytest.raises(ValueError, match="while evicted"):
        async_engine.validate_schedule(arr, 0, live=live)


def test_effective_counts_exempt_dead_agents_but_keep_releases():
    # agent 0 releases 2 rounds, then is evicted: the charges for the
    # released work stay; the dead rounds neither arrive nor violate
    arr = np.asarray([[1, 1], [1, 1], [0, 1], [0, 1]], np.float32)
    live = np.asarray([[1, 1], [1, 1], [0, 1], [0, 1]], np.float32)
    arrivals, released = async_engine.effective_counts(arr, 0, live=live)
    np.testing.assert_array_equal(arrivals, [2, 4])
    np.testing.assert_array_equal(released, [2, 4])
    sched = ArrivalSchedule(arrivals=arr, max_staleness=0, live=live)
    a2, r2 = sched.validate().effective_counts()
    np.testing.assert_array_equal(a2, arrivals)
    np.testing.assert_array_equal(r2, released)


def test_evicted_agent_still_charged_in_privacy_report():
    spec = FedSpec(n_agents=2, gamma=0.05, n_epochs=3, rho=1.0,
                   privacy=PrivacySpec(tau=0.5, clip=1.0),
                   async_mode="stale", max_staleness=0)
    arr = np.asarray([[1, 1], [1, 1], [0, 1], [0, 1]], np.float32)
    rep = effective_privacy_report(spec, arr, 50)
    a0, a1 = rep.per_agent
    assert a0.K == 2 and a1.K == 4       # released rounds still charged
    assert 0 < a0.adp_eps < a1.adp_eps


# ---------------------------------------------------------------------------
# Spec / config plumbing for the guard knobs
# ---------------------------------------------------------------------------

def test_guard_knobs_thread_through_every_front_end(quad):
    spec = spec_from_args(["--guard-increments",
                           "--guard-norm-bound", "50.0",
                           "--n-agents", str(N_AGENTS)])
    assert spec.guard_increments and spec.guard_norm_bound == 50.0
    ecfg = build_trainer(quad, spec).algo._ecfg
    assert ecfg.guard_increments and ecfg.guard_norm_bound == 50.0
    # defaults stay off (and are bitwise no-ops -- tested above)
    assert not spec_from_args([]).guard_increments
    assert math.isinf(spec_from_args([]).guard_norm_bound)
    fcfg = runtime.FedConfig(guard_increments=True, guard_norm_bound=9.0)
    s2 = fcfg.to_spec()
    assert s2.guard_increments and s2.guard_norm_bound == 9.0


def test_guard_bound_validation():
    with pytest.raises(ValueError, match="guard_norm_bound"):
        FedSpec(n_agents=4, guard_norm_bound=0.0).validate()
    with pytest.raises(ValueError, match="guard_norm_bound"):
        RoundConfig(n_agents=4, guard_norm_bound=-1.0)
    with pytest.raises(ValueError, match="guard_norm_bound"):
        RoundConfig(n_agents=4, guard_norm_bound=float("nan"))
    cfg = RoundConfig(n_agents=4, guard_increments=1,
                      guard_norm_bound=np.float64(3.0))
    assert cfg.guard_increments is True
    assert cfg.guard_norm_bound == 3.0


# ---------------------------------------------------------------------------
# Crash-safe checkpoints
# ---------------------------------------------------------------------------

def _tree(val):
    return {"a": np.full((2, 3), val, np.float32),
            "b": {"c": np.full((4,), val + 1, np.float32)}}


def test_restore_lists_missing_and_extra_keys_together(tmp_path):
    path = str(tmp_path / "ck")
    save_checkpoint(path, _tree(1.0))
    bad_like = {"a": np.zeros((2, 3), np.float32),
                "d": np.zeros((4,), np.float32)}
    with pytest.raises(ValueError) as ei:
        restore_checkpoint(path, bad_like)
    msg = str(ei.value)
    assert "missing from checkpoint: d" in msg
    assert "unexpected in checkpoint: b/c" in msg


def test_save_checkpoint_failure_preserves_previous(tmp_path,
                                                    monkeypatch):
    path = str(tmp_path / "ck")
    save_checkpoint(path, _tree(1.0), step=1)
    assert is_checkpoint(path)

    def boom(*a, **kw):
        raise RuntimeError("disk full")

    monkeypatch.setattr(np, "savez", boom)
    with pytest.raises(RuntimeError, match="disk full"):
        save_checkpoint(path, _tree(2.0), step=2)
    monkeypatch.undo()
    # the old checkpoint is fully intact and no tmp debris is left
    assert is_checkpoint(path)
    got = restore_checkpoint(path, _tree(0.0))
    np.testing.assert_array_equal(got["a"], _tree(1.0)["a"])
    assert not [n for n in os.listdir(tmp_path) if ".ckpt-tmp-" in n]


def test_save_checkpoint_failure_on_fresh_path_leaves_nothing(
        tmp_path, monkeypatch):
    path = str(tmp_path / "fresh")

    def boom(*a, **kw):
        raise RuntimeError("disk full")

    monkeypatch.setattr(np, "savez", boom)
    with pytest.raises(RuntimeError):
        save_checkpoint(path, _tree(1.0))
    monkeypatch.undo()
    assert not os.path.exists(path)
    assert not [n for n in os.listdir(tmp_path) if ".ckpt-tmp-" in n]


def test_find_latest_checkpoint_skips_debris(tmp_path):
    root = str(tmp_path)
    assert find_latest_checkpoint(root) is None
    save_checkpoint(os.path.join(root, "step-000002"), _tree(1.0), step=2)
    save_checkpoint(os.path.join(root, "step-000010"), _tree(2.0),
                    step=10)
    os.makedirs(os.path.join(root, "step-000099.ckpt-tmp-x"))
    os.makedirs(os.path.join(root, "not-a-checkpoint"))
    latest = find_latest_checkpoint(root)
    assert latest is not None and latest.endswith("step-000010")
    # a direct checkpoint path is itself the answer
    assert find_latest_checkpoint(latest) == latest
    assert find_latest_checkpoint(str(tmp_path / "missing")) is None


class _TinyModel:
    def init(self, key):
        return {"w": jnp.zeros(6, jnp.float32)}

    def loss_fn(self, params, batch, remat=False):
        return 0.5 * jnp.sum((params["w"] - batch["target"]) ** 2)


def test_checkpoint_resume_is_bitwise(tmp_path):
    # 6 straight rounds == 3 rounds + atomic save + restore + 3 rounds,
    # bit for bit (per-round keys are fold_in-derived, as in the driver)
    model = _TinyModel()
    spec = FedSpec(n_agents=4, gamma=0.1, n_epochs=2, participation=0.7)
    step = jax.jit(runtime.make_train_step(model, spec))
    batch = {"target": jnp.broadcast_to(
        jnp.arange(6, dtype=jnp.float32), (4, 6))}
    key = jax.random.PRNGKey(8)

    state_a = runtime.init_state(model, key, spec)
    for i in range(6):
        state_a, _ = step(state_a, batch, jax.random.fold_in(key, i))

    state_b = runtime.init_state(model, key, spec)
    for i in range(3):
        state_b, _ = step(state_b, batch, jax.random.fold_in(key, i))
    path = str(tmp_path / "rounds" / "step-000003")
    save_checkpoint(path, state_b, step=3, extra={"round": 3})
    like = runtime.init_state(model, key, spec)
    resumed = restore_checkpoint(
        find_latest_checkpoint(str(tmp_path / "rounds")), like)
    for i in range(3, 6):
        resumed, _ = step(resumed, batch, jax.random.fold_in(key, i))

    for la, lb in zip(jax.tree_util.tree_leaves((state_a.x, state_a.z)),
                      jax.tree_util.tree_leaves((resumed.x, resumed.z))):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))
