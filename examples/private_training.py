"""Differentially-private federated training with Fed-PLT.

Walks the paper's privacy pipeline end to end:
  1. pick a target (eps, delta)-ADP budget,
  2. calibrate the noise variance tau (Prop. 4 inverted),
  3. train with noisy local GD,
  4. report the achieved accuracy and the privacy ceiling.

Run:  PYTHONPATH=src python examples/private_training.py
"""

import jax
import numpy as np

from repro.core import privacy, theory
from repro.core.problem import make_logreg_problem
from repro.fed.api import FedSpec, PrivacySpec, build_trainer


def main():
    problem = make_logreg_problem(n_agents=100, q=250, dim=5, seed=0)
    mu, L = problem.strong_convexity(), problem.smoothness()
    K, delta = 300, 1e-5
    # pick (rho, gamma, N_e) that make S contractive (Lemma 7 grid)
    stab = theory.stabilize(mu, L, n_epochs_grid=(5,))
    rho, gamma, n_epochs = stab.rho, stab.gamma, stab.n_epochs
    print(f"Lemma-7 stabilizer: rho={rho:.3f} gamma={gamma:.3f} "
          f"N_e={n_epochs} ||S||={stab.s_norm:.3f}")

    target_eps = 2.0
    tau = privacy.calibrate_noise(target_eps, delta, sensitivity=1.0,
                                  mu=mu, q=problem.q, gamma=gamma, K=K,
                                  n_epochs=n_epochs)
    print(f"target ({target_eps}, {delta})-ADP  =>  tau = {tau:.4f}")

    # the front door: tau > 0 upgrades the gd solver to DP noisy GD, and
    # the trainer reports its own (eps, delta) position
    trainer = build_trainer(problem, FedSpec(
        rho=rho, gamma=gamma, n_epochs=n_epochs,
        privacy=PrivacySpec(tau=tau, dp_init=True, delta=delta)))
    rep = trainer.privacy_report(K)
    print(f"achieved eps = {rep.adp_eps:.3f} at Renyi order "
          f"{rep.rdp_order:.1f}; ceiling as K*Ne->inf: "
          f"{rep.eps_ceiling:.3f}")

    # Prop. 4 is per-agent: with unequal local datasets the accountant
    # returns one (eps_i, delta) row per agent and the headline eps is
    # the max -- the small-data agents are the binding constraint
    qs = [50] * 5 + [problem.q] * (problem.n_agents - 5)
    rep_i = trainer.privacy_report(K, local_dataset_size=qs)
    print(f"heterogeneous q_i: worst-agent eps = {rep_i.adp_eps:.3f} "
          f"(q_i=50) vs {rep_i.per_agent[-1].adp_eps:.3f} "
          f"(q_i={problem.q})")

    state, crit = trainer.run(jax.random.PRNGKey(0), K)
    crit = np.asarray(crit)

    bound = theory.corollary1_bound(K, mu, L, rho, gamma, n_epochs, tau,
                                    problem.dim, problem.n_agents,
                                    r0=float(np.linalg.norm(state.x)))
    print(f"\nafter K={K} rounds: criterion = {crit[-1]:.3e}")
    print(f"asymptotic error bound (Cor. 1): {bound:.3e}")
    print(f"privacy does NOT degrade with more local epochs: the ceiling "
          f"above holds for ANY N_e (Prop. 4).")


if __name__ == "__main__":
    main()
