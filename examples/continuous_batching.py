"""Continuous batching: serve a stream of requests through fixed decode
slots (vLLM-style scheduling, simplified).

Per-sequence cache positions let each slot sit at a different depth:
while one request is still consuming its prompt (prefill-by-decode),
others are generating, and finished slots are freed (`reset_slots`) and
immediately refilled from the queue -- no global synchronization.

Run:  PYTHONPATH=src python examples/continuous_batching.py
"""

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models.decode import reset_slots
from repro.models.model import build_model


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma2-2b")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--max-prompt", type=int, default=12)
    ap.add_argument("--gen-len", type=int, default=10)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    model = build_model(cfg)
    rng = np.random.default_rng(0)
    params = model.init(jax.random.PRNGKey(1))

    # request queue: random-length prompts
    queue = [rng.integers(0, cfg.vocab,
                          rng.integers(3, args.max_prompt + 1)).tolist()
             for _ in range(args.requests)]

    B = args.slots
    cache_len = args.max_prompt + args.gen_len
    cache = model.init_cache(batch=B, cache_len=cache_len)
    decode = jax.jit(lambda p, c, t: model.decode_step(p, cache=c,
                                                       tokens=t))

    slot_req = [-1] * B          # request id per slot (-1 = free)
    slot_prompt: list[list] = [[] for _ in range(B)]   # remaining prompt
    slot_out: list[list] = [[] for _ in range(B)]
    done: dict[int, list] = {}
    next_req = 0
    steps = 0
    t0 = time.time()

    while len(done) < args.requests:
        # admit new requests into free slots
        for b in range(B):
            if slot_req[b] == -1 and next_req < args.requests:
                slot_req[b] = next_req
                slot_prompt[b] = list(queue[next_req])
                slot_out[b] = []
                next_req += 1

        # build the next token per slot: prompt token (teacher-forced) or
        # last generated token
        toks = []
        for b in range(B):
            if slot_req[b] == -1:
                toks.append(0)
            elif slot_prompt[b]:
                toks.append(slot_prompt[b].pop(0))
            else:
                toks.append(slot_out[b][-1])
        logits, cache = decode(params, cache,
                               jnp.asarray(toks, jnp.int32))
        steps += 1
        nxt = np.asarray(jnp.argmax(logits, axis=-1))

        # collect generations / retire finished slots
        finished = np.zeros(B, bool)
        for b in range(B):
            if slot_req[b] == -1:
                continue
            if not slot_prompt[b]:          # past the prompt: generating
                slot_out[b].append(int(nxt[b]))
            if len(slot_out[b]) >= args.gen_len:
                done[slot_req[b]] = slot_out[b]
                finished[b] = True
                slot_req[b] = -1
        if finished.any():
            cache = reset_slots(cache, jnp.asarray(finished))

    dt = time.time() - t0
    total_tokens = sum(len(v) for v in done.values())
    print(f"served {args.requests} requests through {B} slots in "
          f"{steps} decode steps ({dt:.1f}s, "
          f"{total_tokens/dt:.1f} gen tok/s)")
    naive_steps = sum(len(q) + args.gen_len for q in queue)
    print(f"continuous batching: {steps} steps vs {naive_steps} "
          f"sequential steps (x{naive_steps/steps:.1f} utilization)")
    for rid in sorted(done)[:3]:
        print(f"request {rid}: {done[rid][:8]}...")


if __name__ == "__main__":
    main()
