"""Quickstart: Fed-PLT on the paper's logistic-regression federation.

Reproduces the core claims in ~30 seconds on CPU:
  1. exact convergence with local training (no client drift),
  2. partial participation,
  3. comparison against FedAvg's drift plateau.

Everything goes through the front door: a :class:`repro.fed.api.FedSpec`
plus :func:`repro.fed.api.build_trainer` -- the same three lines drive
the dense paper problems here and model-scale training in
``examples/train_lm_federated.py``.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import jax
import numpy as np

from repro.core.baselines import make_fedavg
from repro.core.metrics import hitting_round
from repro.core.problem import make_logreg_problem
from repro.fed.api import FedSpec, build_trainer


def main():
    problem = make_logreg_problem(n_agents=100, q=250, dim=5, seed=0)
    print(f"problem: N={problem.n_agents} agents, n={problem.dim}, "
          f"mu={problem.strong_convexity():.2f}, "
          f"L={problem.smoothness():.2f}")

    # --- Fed-PLT, 5 local epochs, full participation ----------------------
    trainer = build_trainer(problem, FedSpec(rho=1.0, n_epochs=5))
    state, crit = trainer.run(jax.random.PRNGKey(0), 200)
    crit = np.asarray(crit)
    print(f"\nFed-PLT     : criterion {crit[-1]:.2e} after 200 rounds "
          f"(threshold hit at round {hitting_round(crit)})")

    # --- with partial participation (50% of agents per round) -----------
    trainer_pp = build_trainer(
        problem, FedSpec(rho=1.0, n_epochs=5, participation=0.5))
    _, crit_pp = trainer_pp.run(jax.random.PRNGKey(0), 400)
    crit_pp = np.asarray(crit_pp)
    print(f"Fed-PLT 50% : criterion {crit_pp[-1]:.2e} after 400 rounds "
          f"(hit at {hitting_round(crit_pp)})")

    # --- FedAvg drifts ---------------------------------------------------
    fedavg = make_fedavg(problem, gamma=0.1, n_epochs=5)
    crit_avg = np.asarray(fedavg.run(jax.random.PRNGKey(0), 400))
    print(f"FedAvg      : plateaus at {crit_avg[-1]:.2e} (client drift; "
          f"never reaches 1e-5)")

    x_bar = trainer.consensus(state)
    x_star = problem.solve()
    print(f"\n||x_bar - x*|| = {np.linalg.norm(x_bar - x_star):.2e} "
          f"(exact convergence, Prop. 2)")


if __name__ == "__main__":
    main()
