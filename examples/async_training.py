"""Asynchronous Fed-PLT with a straggler fleet.

Fed-PLT's convergence machinery never needs every agent at every round
(partial participation is already a Bernoulli mask); the async subsystem
generalizes that mask to a bounded-staleness ARRIVAL mask.  Slow agents
keep refining their local solve against the coordinator point they last
pulled and deliver the increment up to ``max_staleness`` rounds late --
the coordinator averages whatever has arrived and moves on.

This example drives the full stack:
  1. a host-side :class:`~repro.fed.broker.IncrementBroker` with a
     straggler group (5x the latency of the fast fleet),
  2. the realized arrival schedule and its bounded staleness,
  3. bit-for-bit replay of the recorded schedule (the broker decides
     only timing; every number comes from the in-jit model),
  4. the stale-aware per-agent privacy table: each agent is composed
     over the rounds of local work it actually RELEASED, not the
     nominal round count.

Run:  PYTHONPATH=src python examples/async_training.py
"""

import jax
import numpy as np

from repro.core.problem import make_logreg_problem
from repro.fed.api import FedSpec, PrivacySpec, build_trainer
from repro.fed.broker import IncrementBroker, replay

N_AGENTS = 8
STRAGGLERS = (0, 1)          # agents with 5x the fleet's latency
ROUNDS = 40
MAX_STALENESS = 3


def straggler_latency(agent: int, round_idx: int) -> float:
    """Simulated local-solve wall time (seconds)."""
    base = 0.002
    return base * (5.0 if agent in STRAGGLERS else 1.0)


def main():
    problem = make_logreg_problem(n_agents=N_AGENTS, q=200, dim=5,
                                  seed=0)
    spec = FedSpec(rho=5.0, gamma=0.05, n_epochs=5, damping=0.5,
                   participation=0.8,
                   async_mode="stale", max_staleness=MAX_STALENESS,
                   privacy=PrivacySpec(tau=0.3, clip=1.0, delta=1e-5))
    trainer = build_trainer(problem, spec)
    key = jax.random.PRNGKey(0)

    print(f"fleet: {N_AGENTS} agents, stragglers {STRAGGLERS} at 5x "
          f"latency, max_staleness={MAX_STALENESS}")

    # --- 1. broker run: threads supply the timing, jit the numerics ---
    broker = IncrementBroker(N_AGENTS, MAX_STALENESS,
                             latency_fn=straggler_latency, grace=0.003)
    step = lambda s, u: trainer.algo.round_with_arrival(s, u)[0]
    state, sched = broker.run(step, trainer.init(key), ROUNDS)

    # --- 2. the realized schedule ---------------------------------------
    arrivals, released = sched.effective_counts()
    print(f"\nrealized schedule over {sched.n_rounds} rounds "
          f"(bounded staleness verified):")
    for a in range(N_AGENTS):
        tag = " <- straggler" if a in STRAGGLERS else ""
        print(f"  agent {a}: arrivals={int(arrivals[a]):3d} "
              f"released_rounds={int(released[a]):3d}/{ROUNDS}{tag}")

    # --- 3. deterministic replay ----------------------------------------
    state2 = replay(step, trainer.init(key), sched)
    bitwise = all(
        (np.asarray(l1) == np.asarray(l2)).all()
        for l1, l2 in zip(jax.tree_util.tree_leaves(state),
                          jax.tree_util.tree_leaves(state2)))
    print(f"\nreplay of the recorded schedule is bit-identical: "
          f"{bitwise}")
    assert bitwise

    # --- 4. stale-aware privacy -----------------------------------------
    nominal = trainer.privacy_report(ROUNDS)
    rep = trainer.effective_privacy_report(sched.arrivals)
    print(f"\nnominal privacy (every agent charged all {ROUNDS} "
          f"rounds): ({nominal.adp_eps:.3f}, "
          f"{nominal.adp_delta:.0e})-ADP")
    print(f"effective privacy (realized arrival schedule): "
          f"({rep.adp_eps:.3f}, {rep.adp_delta:.0e})-ADP")
    for a in rep.per_agent:
        tag = " <- straggler" if a.agent in STRAGGLERS else ""
        print(f"  agent {a.agent}: arrivals={a.arrivals:3d} "
              f"released_rounds={a.K:3d}/{ROUNDS} "
              f"eps_i={a.adp_eps:.3f} (ceiling {a.eps_ceiling:.3f})"
              f"{tag}")
    print("\nnote: a stale arrival still carries every round of local "
          "work it accumulated, so only work discarded at the bound or "
          "still in flight at the end shrinks an agent's composition.")

    # --- 5. ...which is visible the moment a run stops mid-flight -------
    # compose over the first `cut` rounds only: whatever the stragglers
    # were still refining at that point was never transmitted, so it
    # charges nothing -- their effective eps drops below the fleet's
    cut = ROUNDS - 2
    rep_cut = trainer.effective_privacy_report(sched.arrivals[:cut])
    print(f"\nsame run audited at round {cut} (straggler work still "
          f"in flight charges nothing):")
    for a in rep_cut.per_agent:
        tag = " <- straggler" if a.agent in STRAGGLERS else ""
        print(f"  agent {a.agent}: released_rounds={a.K:3d}/{cut} "
              f"eps_i={a.adp_eps:.3f}{tag}")

    x_bar = trainer.consensus(state)
    print(f"\nconsensus reached: ||x_bar|| = "
          f"{float(np.linalg.norm(np.asarray(x_bar))):.4f}")


if __name__ == "__main__":
    main()
