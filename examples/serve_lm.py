"""Serve a small model with batched requests: prefill + decode with the
production cache semantics (ring buffers for local-attention layers,
recurrent state for SSM/hybrid layers).

Run:  PYTHONPATH=src python examples/serve_lm.py --arch recurrentgemma-2b
"""

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.launch.serve import generate
from repro.models.model import build_model


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma2-2b")
    ap.add_argument("--requests", type=int, default=8,
                    help="batched concurrent requests")
    ap.add_argument("--prompt-len", type=int, default=24)
    ap.add_argument("--gen-len", type=int, default=24)
    ap.add_argument("--temperature", type=float, default=0.8)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    model = build_model(cfg)
    key = jax.random.PRNGKey(0)
    params = model.init(key)

    # batched "requests": different prompts, served together
    prompts = jax.random.randint(
        key, (args.requests, args.prompt_len), 0, cfg.vocab, jnp.int32)
    t0 = time.time()
    out = generate(model, params, prompts, gen_len=args.gen_len,
                   cache_len=args.prompt_len + args.gen_len,
                   temperature=args.temperature, key=key)
    dt = time.time() - t0
    print(f"arch={args.arch} (reduced), {args.requests} requests, "
          f"{args.gen_len} tokens each")
    print(f"throughput: {args.requests * args.gen_len / dt:.1f} tok/s "
          f"(CPU)")
    for i in range(min(3, args.requests)):
        print(f"request {i}: {out[i][:10].tolist()}...")


if __name__ == "__main__":
    main()
