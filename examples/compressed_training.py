"""Compressed federated training (beyond-paper extension).

Fed-PLT already saves communication via local training (N_e) and partial
participation; this example stacks a third axis: compressing the z
uplink (int8 / top-k with lag-based error feedback) while keeping EXACT
convergence.

Run:  PYTHONPATH=src python examples/compressed_training.py
"""

import jax
import numpy as np

from repro.core.fedplt import FedPLT, FedPLTConfig
from repro.core.metrics import hitting_round
from repro.core.problem import make_logreg_problem
from repro.core.solvers import SolverConfig


def main():
    prob = make_logreg_problem(n_agents=100, q=250, dim=20, seed=0)
    gd5 = SolverConfig(name="gd", n_epochs=5)
    print(f"{'compressor':12s} {'rounds':>7s} {'final crit':>11s} "
          f"{'uplink vs exact':>16s}")
    k_exact = None
    for name, kw, bits in [
        ("exact", {}, 32.0),
        ("int8", dict(compression="int8"), 8.0),
        ("topk 25%", dict(compression="topk", compress_ratio=0.25), 8.0),
        ("topk 10%", dict(compression="topk", compress_ratio=0.1), 3.2),
    ]:
        cfg = FedPLTConfig(rho=1.0, solver=gd5, **kw)
        _, crit = FedPLT(prob, cfg).run(jax.random.PRNGKey(0), 600)
        crit = np.asarray(crit)
        k = hitting_round(crit)
        if k_exact is None:
            k_exact = k
        rel = (k * bits) / (k_exact * 32.0) if k else float("nan")
        print(f"{name:12s} {k!s:>7s} {crit[-1]:11.2e} "
              f"{rel:15.2f}x")
    print("\nall compressors converge EXACTLY (error feedback via the "
          "lagged coordinator copy); top-k 10% cuts uplink ~5x net.")


if __name__ == "__main__":
    main()
