"""Compressed federated training (beyond-paper extension).

Fed-PLT already saves communication via local training (N_e) and partial
participation; this example stacks a third axis: compressing the z
uplink (int8 / top-k / per-agent adaptive top-k with lag-based error
feedback) while keeping EXACT convergence.  Compressors are named
entries of the :mod:`repro.fed.compress` registry, so the sweep below is
driven entirely through :class:`repro.fed.api.CompressionSpec` -- a
compressor you register yourself joins it by name.

Run:  PYTHONPATH=src python examples/compressed_training.py
"""

import jax
import numpy as np

from repro.core.metrics import hitting_round
from repro.core.problem import make_logreg_problem
from repro.fed.api import CompressionSpec, FedSpec, build_trainer


def main():
    prob = make_logreg_problem(n_agents=100, q=250, dim=20, seed=0)
    print(f"{'compressor':15s} {'rounds':>7s} {'final crit':>11s} "
          f"{'uplink vs exact':>16s}")
    k_exact = None
    for name, comp, bits in [
        ("exact", CompressionSpec(), 32.0),
        ("int8", CompressionSpec(name="int8"), 8.0),
        ("topk 25%", CompressionSpec(name="topk", ratio=0.25), 8.0),
        ("topk 10%", CompressionSpec(name="topk", ratio=0.1), 3.2),
        ("adaptive", CompressionSpec(name="adaptive_topk", ratio=0.1,
                                     energy=0.9), 3.2),
        # same compressor through the fused packed-kernel backend
        # (--compress-backend pallas): bit-identical trajectory, the
        # whole pytree's uplink in one kernel launch
        ("adaptive/pallas", CompressionSpec(name="adaptive_topk",
                                            ratio=0.1, energy=0.9,
                                            backend="pallas"), 3.2),
    ]:
        spec = FedSpec(rho=1.0, n_epochs=5, compression=comp)
        _, crit = build_trainer(prob, spec).run(jax.random.PRNGKey(0), 600)
        crit = np.asarray(crit)
        k = hitting_round(crit)
        if k_exact is None:
            k_exact = k
        rel = (k * bits) / (k_exact * 32.0) if k else float("nan")
        print(f"{name:15s} {k!s:>7s} {crit[-1]:11.2e} "
              f"{rel:15.2f}x")
    print("\nall compressors converge EXACTLY (error feedback via the "
          "lagged coordinator copy); top-k 10% cuts uplink ~5x net, and "
          "adaptive top-k lets each agent pick its own k (the bits "
          "column shows its floor -- concentrated increments transmit "
          "less).")


if __name__ == "__main__":
    main()
