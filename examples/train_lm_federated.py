"""End-to-end driver: federated training of a ~100M-param transformer
with Fed-PLT for a few hundred rounds on CPU.

This is the 'train a ~100M model' end-to-end deliverable: a gemma2-family
model (vocab 8192, 4 layers, d_model 512 => ~97M params counting embeddings)
trained over 4 agents with non-IID synthetic streams, 3 local epochs per
round, partial participation, and optional DP noise.

Run:  PYTHONPATH=src python examples/train_lm_federated.py \
          [--rounds 300] [--tau 0.001]
"""

import argparse
import dataclasses
import time

import jax

from repro.configs import get_config
from repro.configs.base import InputShape
from repro.checkpoint import save_checkpoint
from repro.data.synthetic import fed_lm_batches
from repro.fed.api import (FedSpec, PrivacySpec, build_trainer,
                           parse_agent_groups)
from repro.models.model import build_model


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=300)
    ap.add_argument("--n-agents", type=int, default=4)
    ap.add_argument("--n-epochs", type=int, default=3)
    ap.add_argument("--tau", type=float, default=0.0)
    ap.add_argument("--participation", type=float, default=0.75)
    ap.add_argument("--agent-groups", type=parse_agent_groups,
                    default=None,
                    help="heterogeneous agent groups, e.g. "
                         "'2*agd,2*gd:n_epochs=1:participation=0.5' "
                         "(sizes must sum to --n-agents)")
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--checkpoint", default=None)
    args = ap.parse_args()

    # ~100M-param member of the gemma2 family:
    # embed 32768x768 = 25.2M + 8 layers x (attn 1.8M + geglu MLP 7.1M)
    cfg = dataclasses.replace(
        get_config("gemma2-2b"),
        n_layers=8, d_model=768, n_heads=12, n_kv_heads=6, head_dim=64,
        d_ff=3072, vocab=32768, window=64, dtype="float32",
    )
    model = build_model(cfg)
    n_params = sum(x.size for x in jax.tree_util.tree_leaves(
        jax.eval_shape(model.init, jax.random.PRNGKey(0))))
    print(f"model: gemma2-family, {n_params/1e6:.1f}M params")

    trainer = build_trainer(model, FedSpec(
        n_agents=args.n_agents, rho=1.0, gamma=0.1,
        n_epochs=args.n_epochs, participation=args.participation,
        agent_groups=args.agent_groups,
        privacy=PrivacySpec(tau=args.tau,
                            clip=1.0 if args.tau > 0 else None)))
    if args.tau > 0:
        rep = trainer.privacy_report(args.rounds,
                                     local_dataset_size=args.batch)
        print(f"privacy: ({rep.adp_eps:.3f}, {rep.adp_delta:.0e})-ADP "
              f"over {rep.K} rounds (ceiling {rep.eps_ceiling:.3f})")
        if rep.per_agent:   # heterogeneous groups: per-agent table
            for a in rep.per_agent:
                print(f"  agent {a.agent}: N_e={a.n_epochs} "
                      f"eps_i={a.adp_eps:.3f}")
    state = trainer.init(jax.random.PRNGKey(0))

    shape = InputShape("lm", args.seq_len, args.batch, "train")
    batches = fed_lm_batches(cfg, shape, args.n_agents)
    t0 = time.time()
    for i in range(args.rounds):
        state, metrics = trainer.step(state, next(batches),
                                      jax.random.PRNGKey(i))
        if i % 10 == 0 or i == args.rounds - 1:
            print(f"round {i:4d} loss={float(metrics['loss']):.4f} "
                  f"part={float(metrics['participation']):.2f} "
                  f"({time.time() - t0:.0f}s)")

    final = trainer.consensus(state)
    if args.checkpoint:
        save_checkpoint(args.checkpoint, final, step=args.rounds)
        print("checkpoint saved:", args.checkpoint)
    print("done.")


if __name__ == "__main__":
    main()
