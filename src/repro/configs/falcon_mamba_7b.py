"""falcon-mamba-7b [ssm] — attention-free Mamba-1. [arXiv:2410.05355]"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="falcon-mamba-7b",
    family="ssm",
    n_layers=64,
    d_model=4096,
    n_heads=1,          # unused (attention-free)
    n_kv_heads=1,
    d_ff=0,             # mamba block subsumes the FFN
    vocab=65_024,
    pattern=("ssm",),
    ssm_state=16,
    ssm_expand=2,
    conv_width=4,
    supports_long_ctx=True,   # O(1) state
    source="arXiv:2410.05355",
)
