"""The paper's own experimental set-up (Section VII): logistic regression,
N=100 agents, n=5 features, q_i=250 samples, eps=0.5."""

import dataclasses


@dataclasses.dataclass(frozen=True)
class LogRegConfig:
    n_agents: int = 100
    dim: int = 5
    q: int = 250
    eps: float = 0.5
    nonconvex: bool = False
    rho: float = 1.0
    n_epochs: int = 5
    t_G: float = 1.0
    t_C: float = 10.0
    n_rounds: int = 3000
    seed: int = 0


CONFIG = LogRegConfig()
LARGE = LogRegConfig(dim=100, t_G=20.0, t_C=200.0)
