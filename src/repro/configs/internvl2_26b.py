"""internvl2-26b [vlm] — InternLM2-20B language backbone; the InternViT
vision encoder + MLP projector are a STUB (input_specs supplies projected
patch embeddings).  [arXiv:2404.16821]"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-26b",
    family="vlm",
    n_layers=48,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    head_dim=128,
    d_ff=16384,
    vocab=92_553,
    pattern=("global",),
    activation="swiglu",
    frontend="vision",
    n_frontend_tokens=256,   # one image tile -> 256 projected patch tokens
    supports_long_ctx=False,
    source="arXiv:2404.16821",
)
