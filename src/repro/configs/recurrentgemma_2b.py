"""recurrentgemma-2b [hybrid] — RG-LRU + local attention, 2:1 pattern
(two recurrent blocks then one local-attention block). [arXiv:2402.19427]"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    n_layers=26,
    d_model=2560,
    n_heads=10,
    n_kv_heads=1,         # MQA in the attention blocks
    head_dim=256,
    d_ff=7680,
    vocab=256_000,
    pattern=("rec", "rec", "local"),
    window=2048,
    lru_width=2560,
    conv_width=4,
    activation="geglu",
    supports_long_ctx=True,   # recurrent state + local attention
    source="arXiv:2402.19427",
)
