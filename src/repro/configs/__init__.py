"""Architecture config registry: ``get_config("<arch-id>")``.

Assigned architectures (public-literature pool), one module each.
"""

from repro.configs.base import ModelConfig, InputShape, SHAPES  # noqa: F401

from repro.configs import (  # noqa: E402
    phi4_mini_3_8b,
    falcon_mamba_7b,
    whisper_small,
    gemma2_2b,
    qwen2_moe_a2_7b,
    grok_1_314b,
    recurrentgemma_2b,
    gemma3_12b,
    internvl2_26b,
    nemotron_4_340b,
    fedplt_logreg,
)

REGISTRY = {
    "phi4-mini-3.8b": phi4_mini_3_8b.CONFIG,
    "falcon-mamba-7b": falcon_mamba_7b.CONFIG,
    "whisper-small": whisper_small.CONFIG,
    "gemma2-2b": gemma2_2b.CONFIG,
    "qwen2-moe-a2.7b": qwen2_moe_a2_7b.CONFIG,
    "grok-1-314b": grok_1_314b.CONFIG,
    "recurrentgemma-2b": recurrentgemma_2b.CONFIG,
    "gemma3-12b": gemma3_12b.CONFIG,
    "internvl2-26b": internvl2_26b.CONFIG,
    "nemotron-4-340b": nemotron_4_340b.CONFIG,
}

ARCH_IDS = tuple(REGISTRY)


def get_config(arch_id: str) -> ModelConfig:
    try:
        return REGISTRY[arch_id]
    except KeyError:
        raise KeyError(
            f"unknown arch {arch_id!r}; available: {sorted(REGISTRY)}")


def get_shape(shape_id: str) -> InputShape:
    return SHAPES[shape_id]
