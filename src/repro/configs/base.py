"""Model/run configuration system.

``ModelConfig`` describes every assigned architecture; ``SHAPES`` holds the
four assigned input shapes.  Architectures register themselves in
``repro.configs`` (one module per arch, citing its source).
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                     # dense | ssm | hybrid | moe | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None  # default d_model // n_heads

    # --- attention structure -------------------------------------------
    # pattern is cycled over layers; entries: 'global' | 'local' | 'rec' | 'ssm'
    pattern: Tuple[str, ...] = ("global",)
    window: int = 4096              # sliding window for 'local' layers
    attn_softcap: Optional[float] = None
    final_softcap: Optional[float] = None
    rope_theta: float = 10_000.0
    causal: bool = True             # False for pure encoders

    # --- FFN -------------------------------------------------------------
    activation: str = "swiglu"      # swiglu | geglu | relu2 | gelu

    # --- MoE -------------------------------------------------------------
    n_experts: int = 0
    top_k: int = 0
    n_shared_experts: int = 0
    moe_d_ff: int = 0               # per-expert hidden dim
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01

    # --- SSM (mamba1) ------------------------------------------------------
    ssm_state: int = 16
    ssm_expand: int = 2
    conv_width: int = 4
    dt_rank: int = 0                # default ceil(d_model / 16)

    # --- RG-LRU (griffin/recurrentgemma) --------------------------------
    lru_width: Optional[int] = None

    # --- encoder-decoder --------------------------------------------------
    n_enc_layers: int = 0           # >0 => enc-dec (whisper)
    n_enc_tokens: int = 1500        # encoder sequence (audio frames)

    # --- multimodal frontend stub ----------------------------------------
    frontend: Optional[str] = None  # 'audio' | 'vision'
    n_frontend_tokens: int = 0      # patch/frame embeddings prepended

    # --- long-context decode -----------------------------------------------
    # 'global' layers switch to a windowed KV cache of this size for the
    # long_500k shape (sub-quadratic variant; see DESIGN.md).
    long_ctx_global_window: int = 32_768
    supports_long_ctx: bool = False

    # --- misc ---------------------------------------------------------------
    tie_embeddings: bool = True
    norm_eps: float = 1e-6
    dtype: str = "bfloat16"
    source: str = ""                # citation

    # --- performance knobs (beyond-paper optimizations; defaults preserve
    # the recorded baseline behaviour -- see EXPERIMENTS.md section Perf) ---
    ssm_fused_output: bool = False   # contract C inside the chunk scan
    ssm_scan_dtype: str = "float32"  # bf16 halves scan HBM traffic
    ssm_chunk: int = 128             # within-chunk assoc-scan span
    ssm_inner: str = "assoc"         # 'assoc' (log-depth) | 'seq'
    # 'seq' = sequential time scan with the C-contraction folded into the
    # step: HBM-traffic-equivalent stand-in for the lru_scan Pallas
    # kernel (1 read + 1 write per element); see EXPERIMENTS.md Perf.
    chunked_loss: int = 0            # >0: vocab-chunked CE (no full logits)
    attn_seq_shard: bool = False     # sequence-parallel full attention
    shard_residual: bool = False     # constrain residual stream to
    #   (batch->data, seq/d replicated) after every layer: stops GSPMD
    #   propagating the FSDP d-sharding of embed into activations
    attn_chunk: int = 1024           # KV-chunk span of online-softmax attn
    moe_buffer_shard: bool = False   # shard MoE capacity buffers (tokens)
    moe_grouped: bool = False        # per-batch-row dispatch (GSPMD-friendly
    #   vmapped scatter: batch is a pass-through dim, so token groups shard
    #   cleanly over 'data'; capacity enforced per row like MaxText)
    activation_batch_axes: tuple = ("data",)  # mesh axes of the batch dim

    # ------------------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def resolved_dt_rank(self) -> int:
        return self.dt_rank or max(1, -(-self.d_model // 16))

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def resolved_lru_width(self) -> int:
        return self.lru_width or self.d_model

    def layer_kinds(self) -> Tuple[str, ...]:
        """Per-layer temporal-mixing kind, cycling ``pattern``."""
        reps = -(-self.n_layers // len(self.pattern))
        return tuple((self.pattern * reps)[: self.n_layers])

    def reduced(self, n_layers: int = 2, d_model: int = 256,
                vocab: int = 512, n_experts: Optional[int] = None):
        """Smoke-test variant of the same family (<=2 layers, <=512 width)."""
        heads = max(1, min(self.n_heads, 4))
        kv = max(1, min(self.n_kv_heads, heads))
        kw = dict(
            n_layers=n_layers,
            d_model=d_model,
            n_heads=heads,
            n_kv_heads=kv,
            head_dim=d_model // heads,
            d_ff=2 * d_model if self.d_ff else 0,
            vocab=vocab,
            window=16,
            long_ctx_global_window=32,
            lru_width=d_model if self.lru_width else None,
            n_enc_layers=min(self.n_enc_layers, 2),
            n_enc_tokens=24 if self.n_enc_layers else self.n_enc_tokens,
            n_frontend_tokens=16 if self.frontend else 0,
            dtype="float32",
        )
        if self.n_experts:
            ne = n_experts if n_experts is not None else min(4, self.n_experts)
            kw.update(n_experts=ne, top_k=min(self.top_k, 2),
                      n_shared_experts=min(self.n_shared_experts, 1),
                      moe_d_ff=d_model)
        # keep a representative pattern but make sure it fits n_layers
        if len(self.pattern) > 1:
            kw["pattern"] = self.pattern[: max(2, len(self.pattern))]
        return dataclasses.replace(self, **kw)


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str            # 'train' | 'prefill' | 'decode'


SHAPES = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}
