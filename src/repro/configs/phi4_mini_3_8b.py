"""phi4-mini-3.8b [dense] — RoPE, SwiGLU, GQA. [arXiv:2412.08905]"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="phi4-mini-3.8b",
    family="dense",
    n_layers=32,
    d_model=3072,
    n_heads=24,
    n_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab=200_064,
    pattern=("global",),
    activation="swiglu",
    rope_theta=10_000.0,
    supports_long_ctx=False,    # pure full attention -> long_500k skipped
    source="arXiv:2412.08905",
)
