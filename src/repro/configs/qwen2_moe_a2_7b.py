"""qwen2-moe-a2.7b [moe] — 60 routed experts top-4 + 4 shared experts.
[hf:Qwen/Qwen1.5-MoE-A2.7B]"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-moe-a2.7b",
    family="moe",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    head_dim=128,
    d_ff=1408,            # per routed expert
    vocab=151_936,
    pattern=("global",),
    n_experts=60,
    top_k=4,
    n_shared_experts=4,   # shared-expert hidden = 4 * 1408 = 5632
    moe_d_ff=1408,
    activation="swiglu",
    supports_long_ctx=False,
    source="hf:Qwen/Qwen1.5-MoE-A2.7B",
)
