"""grok-1-314b [moe] — 8 experts, top-2 routing. [hf:xai-org/grok-1]"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="grok-1-314b",
    family="moe",
    n_layers=64,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    head_dim=128,
    d_ff=32768,           # per expert
    vocab=131_072,
    pattern=("global",),
    n_experts=8,
    top_k=2,
    n_shared_experts=0,
    moe_d_ff=32768,
    attn_softcap=30.0,    # grok uses attention logit capping
    final_softcap=30.0,
    activation="geglu",
    supports_long_ctx=False,
    source="hf:xai-org/grok-1",
)
