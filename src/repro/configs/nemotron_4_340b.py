"""nemotron-4-340b [dense] — GQA, squared-ReLU MLP. [arXiv:2402.16819]"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="nemotron-4-340b",
    family="dense",
    n_layers=96,
    d_model=18432,
    n_heads=96,
    n_kv_heads=8,
    head_dim=192,
    d_ff=73728,
    vocab=256_000,
    pattern=("global",),
    activation="relu2",
    tie_embeddings=False,
    supports_long_ctx=False,
    source="arXiv:2402.16819",
)
