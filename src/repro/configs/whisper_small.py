"""whisper-small [audio] — enc-dec transformer backbone; the
mel-spectrogram + conv feature extractor is a STUB (input_specs supplies
precomputed frame embeddings).  [arXiv:2212.04356]"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-small",
    family="encdec",
    n_layers=12,          # decoder layers
    n_enc_layers=12,      # encoder layers
    n_enc_tokens=1500,    # 30 s of audio at 50 Hz after the conv stub
    d_model=768,
    n_heads=12,
    n_kv_heads=12,
    head_dim=64,
    d_ff=3072,
    vocab=51_865,
    pattern=("global",),
    activation="gelu",
    frontend="audio",
    supports_long_ctx=False,
    source="arXiv:2212.04356",
)
