"""gemma2-2b [dense] — local+global alternating attention, logit softcaps.
[arXiv:2408.00118]"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma2-2b",
    family="dense",
    n_layers=26,
    d_model=2304,
    n_heads=8,
    n_kv_heads=4,
    head_dim=256,
    d_ff=9216,
    vocab=256_000,
    pattern=("local", "global"),   # alternating
    window=4096,
    attn_softcap=50.0,
    final_softcap=30.0,
    activation="geglu",
    supports_long_ctx=True,        # local layers + windowed-global variant
    long_ctx_global_window=32_768,
    source="arXiv:2408.00118",
)
