"""Pallas TPU kernels for the framework's compute hot spots.

Each kernel ships as <name>/kernel.py (pl.pallas_call + BlockSpec),
<name>/ops.py (jit'd public wrapper with interpret fallback) and
<name>/ref.py (pure-jnp oracle used by the allclose test sweeps).

  fedplt_update   -- the paper's fused local training step (elementwise,
                     3 reads 1 write, optional DP noise) -- the deployed
                     algorithm's per-parameter hot loop.
  compress        -- fused uplink-compression kernels (per-segment
                     magnitude-rank select for topk/adaptive_topk, int8
                     quantize-dequantize) over the packed agent-axis
                     buffer of repro.fed.compress.pack_leaves.
  round_edge      -- the Fed-PLT round's coordinator edges, fused over
                     the same packed buffer: agent-axis mean + prox_h +
                     reflection in one launch (uplink), Krasnosel'skii
                     z-update + participation selects in another
                     (downlink) -- repro.fed.engine's "pallas" backend.
  flash_attention -- blockwise online-softmax attention with GQA,
                     sliding window and logit softcap (model hot spot).
  lru_scan        -- chunked diagonal linear recurrence (RG-LRU / mamba
                     time mixing) with sequential cross-chunk carry.

This container is CPU-only: kernels are validated with interpret=True;
on TPU set interpret=False (the default resolves via repro.kernels.ON_TPU).
"""

import jax

ON_TPU = jax.default_backend() == "tpu"
