"""Blockwise flash attention for TPU (Pallas).

Online-softmax over KV blocks with an fp32 (m, l, acc) carry held in VMEM
scratch across the *sequential* innermost grid axis (the canonical TPU
flash pattern: the kv axis iterates fastest, so scratch persists per
(batch*head, q-block) cell).

Features needed by the zoo: GQA (kv head = q head // group), causal mask,
sliding window, logit softcap.  Block sizes are MXU-aligned (128).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_Q = 128
DEFAULT_BLOCK_K = 128
NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  scale, causal, window, cap, block_q, block_k, n_kv_blocks):
    qb = pl.program_id(1)
    kb = pl.program_id(2)

    @pl.when(kb == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32)          # (block_q, D)
    k = k_ref[0].astype(jnp.float32)          # (block_k, D)
    v = v_ref[0].astype(jnp.float32)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    if cap is not None:
        s = cap * jnp.tanh(s / cap)

    qpos = qb * block_q + jax.lax.broadcasted_iota(jnp.int32,
                                                   (block_q, block_k), 0)
    kpos = kb * block_k + jax.lax.broadcasted_iota(jnp.int32,
                                                   (block_q, block_k), 1)
    mask = jnp.ones((block_q, block_k), jnp.bool_)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]
    l_prev = l_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
    p = jnp.exp(s - m_new[:, None])
    corr = jnp.exp(m_prev - m_new)
    l_new = l_prev * corr + jnp.sum(p, axis=1)
    acc = acc_scr[...] * corr[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    m_scr[...] = m_new
    l_scr[...] = l_new
    acc_scr[...] = acc

    @pl.when(kb == n_kv_blocks - 1)
    def _finish():
        o_ref[0] = (acc_scr[...]
                    / jnp.maximum(l_scr[...], 1e-30)[:, None]
                    ).astype(o_ref.dtype)


def flash_attention_bhsd(q, k, v, *, causal=True, window=None, cap=None,
                         block_q=DEFAULT_BLOCK_Q, block_k=DEFAULT_BLOCK_K,
                         interpret: bool = True):
    """q: (BH, S, D); k, v: (BH, T, D) -- kv already GQA-expanded by index
    mapping in ops.py (no materialized repeat).  Returns (BH, S, D)."""
    BH, S, D = q.shape
    T = k.shape[1]
    block_q = min(block_q, S)
    block_k = min(block_k, T)
    n_kv_blocks = T // block_k
    grid = (BH, S // block_q, n_kv_blocks)

    kernel = functools.partial(
        _flash_kernel, scale=D ** -0.5, causal=causal, window=window,
        cap=cap, block_q=block_q, block_k=block_k, n_kv_blocks=n_kv_blocks)

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, D), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_k, D), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, block_k, D), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, D), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, S, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),      # m
            pltpu.VMEM((block_q,), jnp.float32),      # l
            pltpu.VMEM((block_q, D), jnp.float32),    # acc
        ],
        interpret=interpret,
    )(q, k, v)
