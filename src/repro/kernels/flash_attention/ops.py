"""Public flash-attention op: (B, S, H, D) layout + GQA head mapping."""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels import ON_TPU
from repro.kernels.flash_attention.kernel import flash_attention_bhsd


@partial(jax.jit, static_argnames=("causal", "window", "cap", "block_q",
                                   "block_k", "interpret"))
def flash_attention(q, k, v, *, causal=True, window=None, cap=None,
                    block_q=128, block_k=128,
                    interpret: bool | None = None):
    """q: (B, S, H, D); k, v: (B, T, Hkv, D).  Returns (B, S, H, D)."""
    if interpret is None:
        interpret = not ON_TPU
    B, S, H, D = q.shape
    T, Hkv = k.shape[1], k.shape[2]
    G = H // Hkv
    # fold (B, H) into one grid axis; GQA expands kv by repeat at the
    # (cheap) head level -- index-mapped, but jnp repeat here keeps the
    # kernel single-purpose; the repeat is on the small Hkv axis.
    qf = q.transpose(0, 2, 1, 3).reshape(B * H, S, D)
    kf = jnp.repeat(k.transpose(0, 2, 1, 3), G, axis=1).reshape(B * H, T, D)
    vf = jnp.repeat(v.transpose(0, 2, 1, 3), G, axis=1).reshape(B * H, T, D)
    o = flash_attention_bhsd(qf, kf, vf, causal=causal, window=window,
                             cap=cap, block_q=block_q, block_k=block_k,
                             interpret=interpret)
    return o.reshape(B, H, S, D).transpose(0, 2, 1, 3)
