"""Pure-jnp oracle: full-softmax attention with GQA/window/softcap."""

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def flash_attention_ref(q, k, v, *, causal=True, window=None, cap=None):
    """q: (B, S, H, D); k, v: (B, T, Hkv, D) -> (B, S, H, D)."""
    B, S, H, D = q.shape
    T, Hkv = k.shape[1], k.shape[2]
    G = H // Hkv
    qg = q.reshape(B, S, Hkv, G, D).astype(jnp.float32)
    s = jnp.einsum("bshgd,bthd->bhgst", qg, k.astype(jnp.float32))
    s = s * (D ** -0.5)
    if cap is not None:
        s = cap * jnp.tanh(s / cap)
    qpos = jnp.arange(S)[:, None]
    kpos = jnp.arange(T)[None, :]
    mask = jnp.ones((S, T), bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    s = jnp.where(mask, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgst,bthd->bshgd", p, v.astype(jnp.float32))
    return o.reshape(B, S, H, D).astype(q.dtype)
