"""Public chunked-LRU op with shape handling for the model layers."""

from __future__ import annotations

from functools import partial

import jax

from repro.kernels import ON_TPU
from repro.kernels.lru_scan.kernel import lru_scan_bsw


@partial(jax.jit, static_argnames=("chunk", "interpret"))
def lru_scan(a, b, *, chunk=128, interpret: bool | None = None):
    """Diagonal recurrence h_t = a_t h_{t-1} + b_t.

    a, b: (B, S, W) or (B, S, W, N) (mamba state dim folded into channels).
    """
    if interpret is None:
        interpret = not ON_TPU
    if a.ndim == 4:
        B, S, W, N = a.shape
        out = lru_scan_bsw(a.reshape(B, S, W * N), b.reshape(B, S, W * N),
                           chunk=chunk, interpret=interpret)
        return out.reshape(B, S, W, N)
    return lru_scan_bsw(a, b, chunk=chunk, interpret=interpret)
