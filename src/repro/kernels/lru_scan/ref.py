"""Pure-jnp oracle for the diagonal linear recurrence."""

import jax
import jax.numpy as jnp


def lru_scan_ref(a, b):
    """a, b: (B, S, W) -> h (B, S, W), h_t = a_t h_{t-1} + b_t, h_{-1}=0."""
    def combine(l, r):
        al, bl = l
        ar, br = r
        return al * ar, bl * ar + br

    a32, b32 = a.astype(jnp.float32), b.astype(jnp.float32)
    _, h = jax.lax.associative_scan(combine, (a32, b32), axis=1)
    return h.astype(a.dtype)
