"""Chunked diagonal linear-recurrence kernel (RG-LRU / mamba time mixing).

    h_t = a_t * h_{t-1} + b_t         (elementwise over the channel dim)

Grid: (B, n_chunks) with the chunk axis sequential; the running state h
persists in VMEM scratch across chunks.  Within a chunk the recurrence is
a log-depth associative scan over the time axis -- all in VMEM, one HBM
read of (a, b) and one write of h per element, which is the roofline for
this memory-bound op.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_CHUNK = 128


def _lru_kernel(a_ref, b_ref, h_out_ref, h_scr, *, chunk):
    cb = pl.program_id(1)

    @pl.when(cb == 0)
    def _init():
        h_scr[...] = jnp.zeros_like(h_scr)

    a = a_ref[0].astype(jnp.float32)     # (chunk, W)
    b = b_ref[0].astype(jnp.float32)

    def combine(l, r):
        al, bl = l
        ar, br = r
        return al * ar, bl * ar + br

    a_cum, b_cum = jax.lax.associative_scan(combine, (a, b), axis=0)
    h_all = a_cum * h_scr[...][None, :] + b_cum
    h_scr[...] = h_all[-1]
    h_out_ref[0] = h_all.astype(h_out_ref.dtype)


def lru_scan_bsw(a, b, *, chunk=DEFAULT_CHUNK, interpret: bool = True):
    """a, b: (B, S, W) -> h: (B, S, W) with h_t = a_t h_{t-1} + b_t."""
    B, S, W = a.shape
    chunk = min(chunk, S)
    grid = (B, S // chunk)
    kernel = functools.partial(_lru_kernel, chunk=chunk)
    spec = pl.BlockSpec((1, chunk, W), lambda i, j: (i, j, 0))
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[spec, spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((B, S, W), a.dtype),
        scratch_shapes=[pltpu.VMEM((W,), jnp.float32)],
        interpret=interpret,
    )(a, b)
