"""Pure-jnp oracles for the fused uplink-compression kernels.

Independent implementations (per-segment ``lax.top_k`` / double-sort /
plain quantize), mirroring the :mod:`repro.fed.compress` registry
compressors applied segment-by-segment -- the kernels must bit-match
these on tie-heavy, ragged, and non-block-aligned inputs.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def _segments_of(x, segments):
    return (((0, x.shape[1]),) if segments is None
            else tuple((int(a), int(b)) for a, b in segments))


def segment_ranks_ref(x, segments=None):
    """Stable descending-|x| ranks within each segment (int32)."""
    out = jnp.zeros(x.shape, jnp.int32)
    for s0, s1 in _segments_of(x, segments):
        order = jnp.argsort(-jnp.abs(x[:, s0:s1]), axis=-1, stable=True)
        m = s1 - s0
        rank = jnp.zeros((x.shape[0], m), jnp.int32).at[
            jnp.arange(x.shape[0])[:, None], order].set(
            jnp.arange(m, dtype=jnp.int32)[None, :])
        out = out.at[:, s0:s1].set(rank)
    return out


def rank_select_ref(x, segments=None, mode="topk", ratio=0.25,
                    energy=0.95):
    """Per-segment exact-k magnitude selection (ties by position)."""
    out = jnp.zeros_like(x)
    for s0, s1 in _segments_of(x, segments):
        seg = x[:, s0:s1]
        m = s1 - s0
        k_floor = max(1, int(ratio * m))
        if mode == "topk":
            def topk_row(row):
                _, idx = jax.lax.top_k(jnp.abs(row), k_floor)
                return jnp.zeros_like(row).at[idx].set(row[idx])

            res = jax.vmap(topk_row)(seg)
        elif mode == "adaptive_topk":
            def adaptive_row(row):
                e = jnp.square(jnp.abs(row))
                desc = jnp.sort(e)[::-1]
                cum = jnp.cumsum(desc)
                total = jnp.maximum(cum[-1], 1e-30)
                k = jnp.sum(cum < energy * total) + 1
                k = jnp.clip(k, k_floor, m)
                order = jnp.argsort(-jnp.abs(row))
                rank = jnp.zeros(m, jnp.int32).at[order].set(
                    jnp.arange(m, dtype=jnp.int32))
                return jnp.where(rank < k, row, 0.0)

            res = jax.vmap(adaptive_row)(seg)
        else:
            raise ValueError(f"unknown rank-select mode {mode!r}")
        out = out.at[:, s0:s1].set(res)
    return out


def int8_ref(x, segments=None):
    """Per-(agent, segment) symmetric int8 quantize-dequantize."""
    out = jnp.zeros_like(x)
    for s0, s1 in _segments_of(x, segments):
        seg = x[:, s0:s1]
        scale = jnp.max(jnp.abs(seg), axis=-1, keepdims=True) / 127.0
        scale = jnp.maximum(scale, 1e-12)
        q = jnp.round(seg / scale).astype(jnp.int8)
        out = out.at[:, s0:s1].set(q.astype(x.dtype) * scale)
    return out
