"""Fused uplink-compression kernels for the Fed-PLT z-exchange.

Two kernels over an agent-stacked ``(N, M)`` buffer whose columns are
partitioned into static *segments* (one segment per pytree leaf in the
packed path; a single segment in the per-leaf path):

  rank-select  -- ONE sort-equivalent pass per row computes the stable
                  descending-magnitude *rank* of every entry within its
                  segment, then keeps entries with ``rank < k``.  Ranks
                  (not a threshold) are required for exact-k semantics
                  on magnitude ties, and the same ranks serve both
                  ``topk`` (static per-segment k) and ``adaptive_topk``
                  (traced per-agent k_i from the energy cumsum of the
                  already-sorted magnitudes -- the XLA baseline's second
                  per-row sort disappears).
  int8         -- fused symmetric quantize-dequantize with one scale
                  per (agent, segment), i.e. per agent per leaf.

The select kernel always uses the COUNTING form of the rank select --
``rank < k`` rewritten as "strictly above the k-th magnitude, plus the
first k - #above positional ties", which needs only the SORTED
magnitudes, never a permutation: no dynamic gather or scatter anywhere
in the kernel (the Mosaic/TPU constraint).  Only how the sorted
magnitudes are obtained differs, and both give the IDENTICAL mask
(asserted bit-for-bit in tests):

  ``sort_impl="xla"``     -- one single-operand in-kernel ``lax.sort``
                             of the magnitude keys per segment;
                             executes under ``interpret=True`` (this
                             CPU container), where it is ~6x cheaper
                             than a stable key-value sort.
  ``sort_impl="bitonic"`` -- one compare-exchange network over the
                             whole padded buffer keyed by
                             (segment, -|x| bits), built from shuffles
                             and selects (the form a Mosaic/TPU
                             lowering needs, where ``lax.sort`` is
                             unavailable); O(M log^2 M).

(:func:`segment_ranks_2d` additionally materializes the int32 ranks by
inverting the sort permutation with a batched scatter -- an
introspection/test surface, interpret-oriented.)

All segment metadata (ids, starts, per-segment k) is static -- derived
from the packed treedef at trace time -- so it is baked into the kernel
as constants; only values and the adaptive k_i are traced.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

BLOCK_AGENTS = 8   # rows per grid program (the agent axis is small)

_I32_MAX = np.int32(np.iinfo(np.int32).max)


# ---------------------------------------------------------------------------
# Static segment metadata
# ---------------------------------------------------------------------------

def _check_segments(segments, width):
    segs = tuple((int(a), int(b)) for a, b in segments)
    prev = 0
    for s0, s1 in segs:
        if not 0 <= s0 < s1 <= width:
            raise ValueError(f"segment ({s0}, {s1}) out of range for "
                             f"width {width}")
        if s0 < prev:
            raise ValueError(f"segments must be sorted and disjoint, got "
                             f"{segs}")
        prev = s1
    return segs


def _column_intervals(segments, width):
    """Segments plus the uncovered gaps (padding), in column order.

    Every column belongs to exactly one contiguous interval; because the
    intervals are contiguous AND the sort's primary key is the interval
    id in column order, interval ``l`` occupies exactly the global
    sorted positions ``[start_l, stop_l)`` -- which is what turns one
    global sort into per-segment ranks by a constant subtraction.
    """
    intervals, cursor = [], 0
    for s0, s1 in segments:
        if cursor < s0:
            intervals.append((cursor, s0, False))
        intervals.append((s0, s1, True))
        cursor = s1
    if cursor < width:
        intervals.append((cursor, width, False))
    return intervals


def _segment_constants(segments, width):
    """(seg_id, seg_start) int32 column vectors, shape ``(1, width)``.

    Derived from the static segment tuple at trace time and handed to
    the kernels as (tiny) extra inputs -- Pallas kernels cannot capture
    array constants."""
    seg_id = np.empty((1, width), np.int32)
    seg_start = np.empty((1, width), np.int32)
    for i, (s0, s1, _) in enumerate(_column_intervals(segments, width)):
        seg_id[0, s0:s1] = i
        seg_start[0, s0:s1] = s0
    return seg_id, seg_start


# ---------------------------------------------------------------------------
# The one sort pass: stable descending-magnitude ranks within segments
# ---------------------------------------------------------------------------

def _magnitude_key(x):
    """int32 key monotone in |x| (IEEE bits of the non-negative |x|)."""
    mag = jnp.abs(x).astype(jnp.float32)
    return jax.lax.bitcast_convert_type(mag, jnp.int32)


def _lex_lt(a, b):
    """Strict lexicographic ``a < b`` over tuples of int32 arrays."""
    lt = jnp.zeros(a[0].shape, jnp.bool_)
    eq = jnp.ones(a[0].shape, jnp.bool_)
    for ai, bi in zip(a, b):
        lt = lt | (eq & (ai < bi))
        eq = eq & (ai == bi)
    return lt


def _pow2_pad(width):
    """(next power of two, columns to pad) for the bitonic network."""
    pow2 = 1 << max(1, (width - 1).bit_length())
    return pow2, pow2 - width


def _pad_cols(a, pad, fill):
    """Append ``pad`` columns of scalar ``fill`` to a (bm, n) int32
    array.  Padding must sort LAST: callers fill the primary key with
    ``_I32_MAX`` (a segment id beyond every real one)."""
    if not pad:
        return a
    return jnp.concatenate(
        [a, jnp.full((a.shape[0], pad), fill, jnp.int32)], axis=1)


def _xor_shuffle(a, j):
    """``a[..., i ^ j]`` for a power-of-two stride ``j``: XOR with j
    flips exactly one index bit, which is a static reshape + flip (no
    gather -- Pallas kernels cannot capture index constants and Mosaic
    has no general dynamic gather)."""
    n = a.shape[-1]
    v = a.reshape(a.shape[:-1] + (n // (2 * j), 2, j))
    return jnp.flip(v, axis=-2).reshape(a.shape)


def _bitonic_sort(arrs):
    """Ascending bitonic sort along the last axis (power-of-two length).

    ``arrs`` is a tuple of int32 arrays compared lexicographically; the
    key must be unique per element (we always include the position), so
    the network realizes exactly the stable order.  Compare-exchange
    partners and directions come from in-kernel iotas and static
    reshapes -- the Mosaic-lowerable form.
    """
    n = arrs[0].shape[-1]
    if n & (n - 1):
        raise ValueError(f"bitonic sort needs a power-of-two length, "
                         f"got {n}")
    idx = jax.lax.broadcasted_iota(jnp.int32, arrs[0].shape,
                                   arrs[0].ndim - 1)
    k = 2
    while k <= n:
        j = k // 2
        while j >= 1:
            parrs = tuple(_xor_shuffle(a, j) for a in arrs)
            ascending = (idx & k) == 0
            is_left = (idx & j) == 0    # i < i ^ j  <=>  bit j unset
            want_min = ascending == is_left
            lt = _lex_lt(arrs, parrs)
            take_partner = jnp.where(want_min, ~lt, lt)
            arrs = tuple(jnp.where(take_partner, pa, a)
                         for a, pa in zip(arrs, parrs))
            j //= 2
        k *= 2
    return arrs


def _segment_ranks(x, seg_id, seg_start, sort_impl):
    """(rank_within_segment, sorted_mag) for one ``(bm, M)`` block.

    One sort of the composite key (segment id, -|x| bits, position):
    stable descending-magnitude order within every segment at once.
    ``sorted_mag[:, start:stop]`` are segment ``(start, stop)``'s
    magnitudes in descending order (dtype of ``x``), so the adaptive
    energy cumsum needs no second sort.  ``seg_id`` / ``seg_start`` are
    the ``(1, width)`` column metadata rows from
    :func:`_segment_constants`.
    """
    bm, width = x.shape
    seg = jnp.broadcast_to(seg_id, x.shape)
    neg_mag = -_magnitude_key(x)
    pos = jax.lax.broadcasted_iota(jnp.int32, x.shape, 1)

    if sort_impl == "xla":
        _, neg_mag_s, pos_s = jax.lax.sort(
            (seg, neg_mag, pos), dimension=1, num_keys=2, is_stable=True)
    elif sort_impl == "bitonic":
        _, pad = _pow2_pad(width)
        seg_p = _pad_cols(seg, pad, _I32_MAX)
        neg_p = _pad_cols(neg_mag, pad, 0)
        pos_p = pos
        if pad:     # distinct positions for the padding columns too
            pos_p = jnp.concatenate(
                [pos, width + jax.lax.broadcasted_iota(
                    jnp.int32, (bm, pad), 1)], axis=1)
        _, neg_mag_s, pos_s = _bitonic_sort((seg_p, neg_p, pos_p))
        neg_mag_s, pos_s = neg_mag_s[:, :width], pos_s[:, :width]
    else:
        raise ValueError(f"unknown sort_impl {sort_impl!r} "
                         f"(known: 'xla', 'bitonic')")

    # invert the permutation: global sorted position of every column,
    # then subtract the (static) segment start -> rank within segment
    rows = jax.lax.broadcasted_iota(jnp.int32, x.shape, 0)
    rank = jnp.zeros(x.shape, jnp.int32).at[rows, pos_s].set(
        jax.lax.broadcasted_iota(jnp.int32, x.shape, 1))
    rank = rank - seg_start
    # recover |x| in sorted order from the key bits (exact for f32/bf16)
    sorted_mag = jax.lax.bitcast_convert_type(
        -neg_mag_s, jnp.float32).astype(x.dtype)
    return rank, sorted_mag


# ---------------------------------------------------------------------------
# Kernel bodies
# ---------------------------------------------------------------------------

def _seg_k(ratio, m):
    """The XLA compressors' k: ``max(1, int(ratio * m))`` (static)."""
    return max(1, int(ratio * m))


def _select_k(sorted_mag, mode, ratio, energy, m):
    """The per-(agent, segment) keep-count from the descending
    magnitudes: static for ``topk``; for ``adaptive_topk`` the traced
    k_i from the energy cumsum of the ALREADY-SORTED magnitudes -- the
    second sort of the XLA baseline is gone.  Arithmetic mirrors the
    registry compressor op-for-op so the traced k_i is bit-identical."""
    if mode == "topk":
        return _seg_k(ratio, m)            # static, same for every agent
    cum = jnp.cumsum(jnp.square(sorted_mag), axis=-1)
    total = jnp.maximum(cum[:, -1:], 1e-30)
    k = jnp.sum(cum < energy * total, axis=-1, keepdims=True) + 1
    return jnp.clip(k, _seg_k(ratio, m), m)


def _rank_select_kernel(x_ref, seg_ref, out_ref, *, segments, mode,
                        ratio, energy, sort_impl):
    """The COUNTING form of the rank select: from the per-segment
    descending magnitudes, the mask ``rank < k`` is equivalently "every
    entry STRICTLY above the k-th magnitude, plus the first
    ``k - #above`` entries TIED with it in position order" -- exactly
    the stable-rank tie discipline, with NO permutation inversion.  The
    TPU-shaped bitonic branch uses no dynamic gather/scatter anywhere
    (the Mosaic constraint); the interpret/CPU branch uses whatever
    XLA:CPU runs fastest (``top_k`` partial selection for static k, the
    counting mask after one single-operand sort for the traced adaptive
    k_i).  Every realization produces the bit-identical mask (asserted
    in tests)."""
    x = x_ref[...]
    bm, width = x.shape

    sorted_neg_full = None
    if sort_impl == "bitonic":
        # one compare-exchange network over the whole padded buffer
        # keyed by (segment, -|x| bits): ascending segment ids are the
        # column order, so segment l's descending magnitudes land
        # exactly in its own columns [s0, s1)
        seg = jnp.broadcast_to(seg_ref[...], x.shape)
        neg = -_magnitude_key(x)
        _, pad = _pow2_pad(width)
        _, sorted_neg_full = _bitonic_sort(
            (_pad_cols(seg, pad, _I32_MAX), _pad_cols(neg, pad, 0)))
    elif sort_impl != "xla":
        raise ValueError(f"unknown sort_impl {sort_impl!r} "
                         f"(known: 'xla', 'bitonic')")

    masks = []
    rows = jax.lax.broadcasted_iota(jnp.int32, x.shape, 0)
    for s0, s1, real in _column_intervals(segments, width):
        m = s1 - s0
        if not real:                       # padding: transmit nothing
            masks.append(jnp.zeros((bm, m), jnp.bool_))
            continue
        if sorted_neg_full is None and mode == "topk":
            # static k on CPU: top_k is a partial selection, cheaper
            # than any full sort (ties break by lowest index -- the
            # same discipline as the stable ranks)
            k = _seg_k(ratio, m)
            _, idx = jax.lax.top_k(jnp.abs(x[:, s0:s1]), k)
            masks.append(jnp.zeros((bm, m), jnp.bool_).at[
                rows[:, :k], idx].set(True))
            continue
        mag_key = _magnitude_key(x[:, s0:s1])
        if sorted_neg_full is not None:
            neg_s = sorted_neg_full[:, s0:s1]
        else:
            # one single-operand sort per segment: ~6x cheaper than a
            # stable key-value sort on XLA:CPU
            neg_s = jax.lax.sort(-mag_key, dimension=1, is_stable=False)
        sorted_mag = jax.lax.bitcast_convert_type(
            -neg_s, jnp.float32).astype(x.dtype)
        k = _select_k(sorted_mag, mode, ratio, energy, m)
        if mode == "topk":                 # static k: static slice
            kth = -neg_s[:, k - 1:k]       # k-th largest |x| key
        else:                              # traced per-agent k_i: a
            # masked reduction, not a gather (Mosaic-lowerable)
            pos = jax.lax.broadcasted_iota(jnp.int32, (bm, m), 1)
            kth = -jnp.sum(jnp.where(pos == k - 1, neg_s, 0),
                           axis=-1, keepdims=True)
        above = mag_key > kth
        tie = mag_key == kth
        n_above = jnp.sum(above, axis=-1, keepdims=True)
        tie_prefix = jnp.cumsum(tie.astype(jnp.int32), axis=-1)
        masks.append(above | (tie & (tie_prefix <= k - n_above)))
    mask = masks[0] if len(masks) == 1 else jnp.concatenate(masks, axis=1)
    out_ref[...] = jnp.where(mask, x, 0.0).astype(out_ref.dtype)


def _segment_ranks_kernel(x_ref, seg_ref, start_ref, rank_ref, *,
                          sort_impl):
    rank, _ = _segment_ranks(x_ref[...], seg_ref[...], start_ref[...],
                             sort_impl)
    rank_ref[...] = rank


def _int8_kernel(x_ref, out_ref, *, segments):
    """Fused symmetric int8 quantize-dequantize, one scale per
    (agent, segment) -- arithmetic mirrors the registry ``int8``
    compressor op-for-op per segment."""
    x = x_ref[...]
    width = x.shape[1]
    outs = []
    for s0, s1, real in _column_intervals(segments, width):
        if not real:
            outs.append(jnp.zeros((x.shape[0], s1 - s0), x.dtype))
            continue
        sl = x[:, s0:s1]
        scale = jnp.max(jnp.abs(sl), axis=-1, keepdims=True) / 127.0
        scale = jnp.maximum(scale, 1e-12)
        q = jnp.round(sl / scale).astype(jnp.int8)
        outs.append(q.astype(x.dtype) * scale)
    out = outs[0] if len(outs) == 1 else jnp.concatenate(outs, axis=1)
    out_ref[...] = out.astype(out_ref.dtype)


# ---------------------------------------------------------------------------
# pallas_call wrappers (2-D, rows padded to the block by ops.py)
# ---------------------------------------------------------------------------

def _row_blocked_call(kernel, x, out_dtype, block_agents, interpret,
                      meta_arrays=()):
    n, width = x.shape
    bm = min(block_agents, n)
    if n % bm:
        raise ValueError(f"row count {n} not a multiple of the agent "
                         f"block {bm} (ops.py pads)")
    spec = pl.BlockSpec((bm, width), lambda i: (i, 0))
    meta_spec = pl.BlockSpec((1, width), lambda i: (0, 0))
    return pl.pallas_call(
        kernel,
        grid=(n // bm,),
        in_specs=[spec] + [meta_spec] * len(meta_arrays),
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct(x.shape, out_dtype),
        interpret=interpret,
    )(x, *(jnp.asarray(a) for a in meta_arrays))


def rank_select_2d(x, *, segments, mode, ratio, energy, sort_impl,
                   block_agents=BLOCK_AGENTS, interpret=True):
    """Fused rank-select compressor on an ``(N, M)`` buffer."""
    if mode not in ("topk", "adaptive_topk"):
        raise ValueError(f"unknown rank-select mode {mode!r}")
    segments = _check_segments(segments, x.shape[1])
    seg_id, _ = _segment_constants(segments, x.shape[1])
    kernel = functools.partial(_rank_select_kernel, segments=segments,
                               mode=mode, ratio=ratio, energy=energy,
                               sort_impl=sort_impl)
    return _row_blocked_call(kernel, x, x.dtype, block_agents, interpret,
                             (seg_id,))


def segment_ranks_2d(x, *, segments, sort_impl,
                     block_agents=BLOCK_AGENTS, interpret=True):
    """Stable descending-|x| ranks within each segment (int32)."""
    segments = _check_segments(segments, x.shape[1])
    seg_id, seg_start = _segment_constants(segments, x.shape[1])
    kernel = functools.partial(_segment_ranks_kernel, sort_impl=sort_impl)
    return _row_blocked_call(kernel, x, jnp.int32, block_agents,
                             interpret, (seg_id, seg_start))


def int8_2d(x, *, segments, block_agents=BLOCK_AGENTS, interpret=True):
    """Fused per-(agent, segment) int8 quantize-dequantize."""
    segments = _check_segments(segments, x.shape[1])
    kernel = functools.partial(_int8_kernel, segments=segments)
    return _row_blocked_call(kernel, x, x.dtype, block_agents, interpret)
