"""Public fused uplink-compression ops.

Jitted wrappers over the :mod:`repro.kernels.compress.kernel` Pallas
kernels: pad the agent axis to the row block, dispatch, slice back.
``segments`` is the static tuple of ``(start, stop)`` column ranges (one
per packed pytree leaf; ``None`` means the whole buffer is one segment,
the per-leaf case).  Columns outside every segment are padding and come
back zero.

``interpret`` resolves via :data:`repro.kernels.ON_TPU` like the other
kernel suites; ``sort_impl`` defaults to the in-kernel ``lax.sort`` when
interpreting (this CPU container) and to the explicit bitonic network on
TPU, where ``lax.sort`` has no Mosaic lowering -- both produce the same
permutation (unique composite keys), asserted in the kernel tests.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels import ON_TPU
from repro.kernels.compress.kernel import (BLOCK_AGENTS, int8_2d,
                                           rank_select_2d, segment_ranks_2d)


def _resolve(x, segments, interpret, sort_impl):
    if x.ndim != 2:
        raise ValueError(f"compression ops take (N, M) buffers, got "
                         f"shape {x.shape}")
    if x.dtype == jnp.float64:
        raise ValueError("float64 buffers are not supported (the sort "
                         "key is the float32 magnitude bit pattern)")
    if interpret is None:
        interpret = not ON_TPU
    if sort_impl is None:
        sort_impl = "xla" if interpret else "bitonic"
    if segments is None:
        segments = ((0, x.shape[1]),)
    return tuple(tuple(s) for s in segments), interpret, sort_impl


def _pad_rows(x, block_agents):
    n = x.shape[0]
    bm = min(block_agents, n)
    pad = -n % bm
    if pad:
        x = jnp.concatenate(
            [x, jnp.zeros((pad, x.shape[1]), x.dtype)], axis=0)
    return x, n


@partial(jax.jit, static_argnames=("segments", "mode", "ratio", "energy",
                                   "interpret", "sort_impl",
                                   "block_agents"))
def rank_select(x, *, segments=None, mode="topk", ratio=0.25,
                energy=0.95, interpret=None, sort_impl=None,
                block_agents=BLOCK_AGENTS):
    """Fused magnitude-rank top-k selection.

    ``mode="topk"`` keeps the static ``max(1, int(ratio * m))`` largest-
    magnitude entries per (agent, segment); ``mode="adaptive_topk"``
    keeps the smallest per-agent k_i capturing an ``energy`` fraction of
    the segment's l2 energy (floored at the static k).  Ties break by
    position -- exactly k entries survive -- matching the registry
    compressors bit-for-bit.
    """
    segments, interpret, sort_impl = _resolve(x, segments, interpret,
                                              sort_impl)
    xp, n = _pad_rows(x, block_agents)
    out = rank_select_2d(xp, segments=segments, mode=mode, ratio=ratio,
                         energy=energy, sort_impl=sort_impl,
                         block_agents=block_agents, interpret=interpret)
    return out[:n]


@partial(jax.jit, static_argnames=("segments", "interpret", "sort_impl",
                                   "block_agents"))
def segment_ranks(x, *, segments=None, interpret=True, sort_impl=None,
                  block_agents=BLOCK_AGENTS):
    """Stable descending-|x| rank of every entry within its segment.

    An introspection/test surface: materializing ranks inverts the sort
    permutation with a batched scatter, which has no Mosaic lowering --
    so unlike the compressor ops this one defaults to ``interpret=True``
    everywhere (the compressors themselves use the scatter-free counting
    form and never need the rank array)."""
    segments, interpret, sort_impl = _resolve(x, segments, interpret,
                                              sort_impl)
    xp, n = _pad_rows(x, block_agents)
    out = segment_ranks_2d(xp, segments=segments, sort_impl=sort_impl,
                           block_agents=block_agents, interpret=interpret)
    return out[:n]


@partial(jax.jit, static_argnames=("segments", "interpret",
                                   "block_agents"))
def int8_quantize(x, *, segments=None, interpret=None,
                  block_agents=BLOCK_AGENTS):
    """Fused symmetric int8 quantize-dequantize, one scale per
    (agent, segment)."""
    segments, interpret, _ = _resolve(x, segments, interpret, "xla")
    xp, n = _pad_rows(x, block_agents)
    out = int8_2d(xp, segments=segments, block_agents=block_agents,
                  interpret=interpret)
    return out[:n]
