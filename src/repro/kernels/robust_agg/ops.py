"""Public robust-aggregation ops.

Jitted wrappers over the :mod:`repro.kernels.robust_agg.kernel` Pallas
kernel: pad the column axis to the block, dispatch the column-blocked
grid, slice back.  ``interpret`` resolves via
:data:`repro.kernels.ON_TPU` like the other kernel suites;
``sort_impl`` defaults to the in-kernel ``lax.sort`` when interpreting
(this CPU container) and to the bitonic network on TPU, where
``lax.sort`` has no Mosaic lowering -- both produce the bit-identical
aggregate (asserted in ``tests/test_robust.py``).
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels import ON_TPU
from repro.kernels.robust_agg.kernel import BLOCK_COLS, sort_aggregate_2d


def _resolve(x, interpret, sort_impl):
    if x.ndim != 2:
        raise ValueError(f"robust aggregates take (N, M) buffers, got "
                         f"shape {x.shape}")
    if x.dtype == jnp.float64:
        raise ValueError("float64 buffers are not supported (the sort "
                         "key is the float32 total-order bit pattern)")
    if interpret is None:
        interpret = not ON_TPU
    if sort_impl is None:
        sort_impl = "xla" if interpret else "bitonic"
    return interpret, sort_impl


@partial(jax.jit, static_argnames=("stat", "trim", "interpret",
                                   "sort_impl", "block_cols"))
def robust_aggregate(x, live=None, *, stat, trim=0, interpret=None,
                     sort_impl=None, block_cols=BLOCK_COLS):
    """Robust column aggregate of ``(N, M)`` -> ``(1, M)``.

    ``stat="trimmed_mean"`` drops the ``trim`` smallest and largest
    live values per column and averages the rest;
    ``stat="coord_median"`` takes the per-column median of the live
    values.  ``live`` is an optional ``(N,)`` (or ``(1, N)``) 0/1 row;
    dead agents are excluded from the order statistics entirely
    (survivor semantics, matching the engine's live masks).
    """
    interpret, sort_impl = _resolve(x, interpret, sort_impl)
    n, width = x.shape
    if live is None:
        lv = jnp.ones((1, n), jnp.float32)
    else:
        lv = jnp.asarray(live, jnp.float32).reshape(1, n)
    bc = min(block_cols, width)
    pad = -width % bc
    if pad:
        x = jnp.concatenate(
            [x, jnp.zeros((n, pad), x.dtype)], axis=1)
    out = sort_aggregate_2d(x, lv, stat=stat, trim=trim,
                            sort_impl=sort_impl, block_cols=bc,
                            interpret=interpret)
    return out[:, :width]
