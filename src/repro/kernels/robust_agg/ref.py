"""Reference oracle for the robust-aggregation kernels.

Plain-XLA order-statistic aggregates over an agent-stacked ``(N, M)``
buffer, arithmetic mirroring :mod:`repro.kernels.robust_agg.kernel`
op-for-op (transpose so the agent axis is last, one ``lax.sort`` of
``(dead, total-order key)``, the same masked-sum selection): the
kernel is asserted BITWISE against this oracle in
``tests/test_robust.py``.  This is also the aggregate the ``xla``
engine backend ships (:mod:`repro.fed.robust` registry entries).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.robust_agg.kernel import (ROBUST_STATS, _order_key,
                                             _order_val, _post_sort)


def robust_aggregate_ref(x, live=None, *, stat, trim=0):
    """Robust column aggregate of ``(N, M)`` -> ``(1, M)``.

    ``stat`` is ``"trimmed_mean"`` (drop the ``trim`` smallest and
    largest live values per column, average the rest) or
    ``"coord_median"``.  ``live`` is an optional ``(N,)`` 0/1 row:
    dead agents sort after every live value and the trim window /
    median index are taken against ``n_live`` (survivor semantics).
    """
    if stat not in ROBUST_STATS:
        raise ValueError(f"unknown robust stat {stat!r} "
                         f"(known: {', '.join(ROBUST_STATS)})")
    if x.ndim != 2:
        raise ValueError(f"robust aggregates take (N, M) buffers, got "
                         f"shape {x.shape}")
    n = x.shape[0]
    if live is None:
        lv = jnp.ones((1, n), jnp.float32)
    else:
        lv = jnp.asarray(live, jnp.float32).reshape(1, n)
    xt = x.T                                        # (M, N)
    dead = jnp.broadcast_to((lv == 0.0).astype(jnp.int32), xt.shape)
    _, key_s = jax.lax.sort((dead, _order_key(xt)), dimension=1,
                            num_keys=2, is_stable=False)
    val_s = _order_val(key_s)
    n_live = jnp.sum(lv.astype(jnp.int32), axis=-1, keepdims=True)
    pos = jax.lax.broadcasted_iota(jnp.int32, xt.shape, 1)
    out = _post_sort(val_s, pos, n_live, stat=stat, trim=int(trim))
    return out.T.astype(x.dtype)                    # (1, M)
