"""Column-wise sort-and-trim kernels for Byzantine-robust aggregation.

One kernel over the agent-stacked ``(N, M)`` buffer: per COLUMN (model
coordinate), sort the N agent values and reduce an order statistic --
``trimmed_mean`` (drop the ``f`` smallest and ``f`` largest, average
the rest) or ``coord_median``.  The robust coordinator step consumes
the ``(1, M)`` result in place of the plain agent mean
(:mod:`repro.fed.robust`).

The sort is the compress suite's machinery turned sideways: the block
is transposed in-kernel to ``(block_cols, N)`` so the agent axis is the
LAST axis, then sorted per row either by one in-kernel ``lax.sort``
(``sort_impl="xla"``, the interpret/CPU branch) or by the compress
suite's compare-exchange bitonic network (``sort_impl="bitonic"``, the
Mosaic/TPU branch -- no gather/scatter anywhere).  Both branches feed
the IDENTICAL post-sort arithmetic, so every realization produces the
bit-identical aggregate (asserted in ``tests/test_robust.py``).

Sort keys are int32 IEEE total-order keys (an involution of the f32
bit pattern), never raw floats: the order is total (NaN included, -0.0
before +0.0) and the sorted VALUES are recovered exactly by applying
the same involution to the sorted keys -- no carried permutation, no
stability requirement.

Liveness composes inside the order statistics, not by premultiplying:
an evicted agent's row gets the composite key ``(dead=1, *)`` and
sorts after every live row, so trim positions and the median index are
taken against ``n_live``, exactly the survivor-mean semantics.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.kernels.compress.kernel import (_I32_MAX, _bitonic_sort,
                                           _pad_cols, _pow2_pad)

BLOCK_COLS = 256   # columns per grid program (each sorts N values)

ROBUST_STATS = ("trimmed_mean", "coord_median")

_SIGN_MASK = np.int32(0x7FFFFFFF)


# ---------------------------------------------------------------------------
# IEEE total-order keys (involution: _order_key inverts itself)
# ---------------------------------------------------------------------------

def _order_key(x):
    """int32 key whose signed order is the IEEE total order of ``x``
    (f32): flip the low 31 bits of negative floats.  The map is an
    involution on the sign-preserved int32, so the sorted keys invert
    back to the sorted values exactly (:func:`_order_val`)."""
    b = jax.lax.bitcast_convert_type(x.astype(jnp.float32), jnp.int32)
    return b ^ ((b >> 31) & _SIGN_MASK)


def _order_val(key):
    """Exact inverse of :func:`_order_key` (same involution)."""
    b = key ^ ((key >> 31) & _SIGN_MASK)
    return jax.lax.bitcast_convert_type(b, jnp.float32)


# ---------------------------------------------------------------------------
# Shared post-sort arithmetic (the parity surface: ref.py mirrors this
# op-for-op, so kernel-vs-ref bitwise parity reduces to equal sorts)
# ---------------------------------------------------------------------------

def _pairwise_sum(v):
    """Balanced pairwise sum along the last axis -> ``(rows, 1)``.

    The reduction tree is explicit (static halving of a zero-padded
    power-of-two axis), so every backend and realization produces the
    bit-identical f32 sum -- ``jnp.sum``'s association is
    backend-dependent, which would break the kernel-vs-ref bitwise
    parity contract."""
    n = v.shape[-1]
    pow2 = 1 << max(0, (n - 1).bit_length())
    if pow2 != n:
        v = jnp.concatenate(
            [v, jnp.zeros(v.shape[:-1] + (pow2 - n,), v.dtype)],
            axis=-1)
    while v.shape[-1] > 1:
        k = v.shape[-1] // 2
        v = v[..., :k] + v[..., k:]
    return v


def _post_sort(val_s, pos, n_live, *, stat, trim):
    """Order-statistic reduction of per-row ascending values.

    ``val_s``/``pos`` are ``(rows, n)`` (values ascending, dead rows
    last); ``n_live`` is a ``(1, 1)`` int32.  Returns ``(rows, 1)``.
    Selection is by masked sum over positions -- no gather, the
    Mosaic-lowerable form (and exact: exactly one position matches).
    """
    if stat == "trimmed_mean":
        keep = (pos >= trim) & (pos < n_live - trim)
        denom = jnp.maximum(n_live - 2 * trim, 1).astype(val_s.dtype)
        # multiply by the explicit reciprocal instead of dividing: XLA
        # rewrites division BY A CONSTANT into this exact form, so a
        # literal division would round differently between a traced
        # live row (kernel operand) and a folded all-ones one (ref)
        return _pairwise_sum(jnp.where(keep, val_s, 0.0)) * (1.0 / denom)
    if stat == "coord_median":
        lo = (n_live - 1) // 2
        hi = n_live // 2
        v_lo = _pairwise_sum(jnp.where(pos == lo, val_s, 0.0))
        v_hi = _pairwise_sum(jnp.where(pos == hi, val_s, 0.0))
        # exact when n_live is odd: 0.5 * (v + v) == v in f32
        return 0.5 * (v_lo + v_hi)
    raise ValueError(f"unknown robust stat {stat!r} "
                     f"(known: {', '.join(ROBUST_STATS)})")


def _sorted_block(xt, dead, sort_impl):
    """Sort each row of ``(rows, n)`` by ``(dead, total-order key)``
    ascending; returns the values in sorted order (dead last)."""
    key = _order_key(xt)
    if sort_impl == "xla":
        _, key_s = jax.lax.sort((dead, key), dimension=xt.ndim - 1,
                                num_keys=2, is_stable=False)
    elif sort_impl == "bitonic":
        n = xt.shape[-1]
        _, pad = _pow2_pad(n)
        dead_s, key_s = _bitonic_sort((_pad_cols(dead, pad, _I32_MAX),
                                       _pad_cols(key, pad, 0)))
        key_s = key_s[:, :n]
    else:
        raise ValueError(f"unknown sort_impl {sort_impl!r} "
                         f"(known: 'xla', 'bitonic')")
    return _order_val(key_s)


# ---------------------------------------------------------------------------
# Kernel body + pallas_call wrapper
# ---------------------------------------------------------------------------

def _sort_agg_kernel(x_ref, live_ref, out_ref, *, stat, trim, sort_impl):
    x = x_ref[...]                       # (N, block_cols)
    lv = live_ref[...]                   # (1, N) float 0/1
    xt = x.T                             # (block_cols, N)
    dead = jnp.broadcast_to((lv == 0.0).astype(jnp.int32), xt.shape)
    val_s = _sorted_block(xt, dead, sort_impl)
    n_live = jnp.sum(lv.astype(jnp.int32), axis=-1, keepdims=True)
    pos = jax.lax.broadcasted_iota(jnp.int32, xt.shape, 1)
    out = _post_sort(val_s, pos, n_live, stat=stat, trim=trim)
    out_ref[...] = out.T.astype(out_ref.dtype)


def sort_aggregate_2d(x, live, *, stat, trim=0, sort_impl,
                      block_cols=BLOCK_COLS, interpret=True):
    """Robust column aggregate of an ``(N, M)`` buffer -> ``(1, M)``.

    ``live`` is a ``(1, N)`` 0/1 float row (all-ones = no evictions);
    ``M`` must be a multiple of ``block_cols`` (ops.py pads).
    """
    if stat not in ROBUST_STATS:
        raise ValueError(f"unknown robust stat {stat!r} "
                         f"(known: {', '.join(ROBUST_STATS)})")
    n, width = x.shape
    bc = min(block_cols, width)
    if width % bc:
        raise ValueError(f"column count {width} not a multiple of the "
                         f"column block {bc} (ops.py pads)")
    if live.shape != (1, n):
        raise ValueError(f"live row must be (1, {n}), got {live.shape}")
    kernel = functools.partial(_sort_agg_kernel, stat=stat,
                               trim=int(trim), sort_impl=sort_impl)
    return pl.pallas_call(
        kernel,
        grid=(width // bc,),
        in_specs=[pl.BlockSpec((n, bc), lambda i: (0, i)),
                  pl.BlockSpec((1, n), lambda i: (0, 0))],
        out_specs=pl.BlockSpec((1, bc), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, width), x.dtype),
        interpret=interpret,
    )(x, jnp.asarray(live))
