"""Public fused round-edge ops.

Jitted wrappers over the :mod:`repro.kernels.round_edge.kernel` Pallas
kernels: pad the column axis to the block, dispatch, slice back.  The
prox callable is a STATIC argument (it is traced into the kernel body),
so only the :func:`repro.core.prox.make_prox` table's elementwise
functions belong here -- the engine gates on their ``elementwise`` tag
and sends anything else down the XLA path.

``interpret`` resolves via :data:`repro.kernels.ON_TPU` like the other
kernel suites.  Padding columns are zeros; their outputs are sliced off
before returning, so a prox whose fixed point is nonzero at 0 (e.g. a
box with ``lo > 0``) cannot leak padding into real columns.

MESH-AWARE REALIZATIONS.  :func:`round_uplink_sharded` /
:func:`round_downlink_sharded` are the same two edges with the agent
axis behind ``shard_map`` on an ``(agent, model)`` mesh: each shard
reduces its local rows in-VMEM (:func:`round_uplink_partial`), ONE
``psum`` of the ``(1, M)`` partials crosses devices, and the chain
finishes (``/ N`` -> prox -> reflection) on coordinator-sized arrays --
``zbar`` never hits HBM at agent-stack size, sharded or not.  The
downlink consumes the replicated ``y`` with purely local per-row work
(:func:`round_downlink_presummed`), so a sharded round still launches
exactly TWO fused edge kernels per shard.  On a 1-device mesh the
results are bit-identical to the unsharded ops (asserted in tests): the
1-device mesh is the degenerate case of the one code path, not a
separate engine.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.kernels import ON_TPU
from repro.kernels.round_edge.kernel import (BLOCK_COLS,
                                             round_downlink_2d,
                                             round_downlink_presummed_2d,
                                             round_uplink_2d,
                                             round_uplink_partial_2d)


def _resolve(x, interpret):
    if x.ndim != 2:
        raise ValueError(f"round-edge ops take (N, M) buffers, got "
                         f"shape {x.shape}")
    return (not ON_TPU) if interpret is None else interpret


def _block_cols(m, block_cols, interpret):
    """Interpret mode defaults to ONE program spanning the whole width:
    the column block is a TPU VMEM-tiling concern, and the interpret
    emulator's per-program loop overhead would otherwise dominate the
    very traffic the fusion removes.  An explicit ``block_cols`` always
    wins (the multi-block grid is exercised in tests)."""
    if interpret and block_cols == BLOCK_COLS:
        return max(block_cols, m)
    return block_cols


def _pad_cols(x, block_cols):
    m = x.shape[1]
    bc = min(block_cols, m)
    pad = -m % bc
    if pad:
        x = jnp.concatenate(
            [x, jnp.zeros((x.shape[0], pad), x.dtype)], axis=1)
    return x, m


@partial(jax.jit, static_argnames=("prox", "rho_eff", "interpret",
                                   "block_cols", "emulate"))
def round_uplink(z, t=None, *, prox=None, rho_eff=1.0, interpret=None,
                 block_cols=BLOCK_COLS, emulate=False):
    """Fused ``y = prox(mean_i z_i, rho_eff)``, ``v = 2 y - z``.

    ``t`` (optional) is the coordinator's lagged copy of ``z`` under a
    compressed exchange: the mean/prox run over ``t``, the reflection
    over ``z``.  Returns ``(y, v)`` with ``y`` of shape ``(1, M)``.
    """
    interpret = _resolve(z, interpret)
    block_cols = _block_cols(z.shape[1], block_cols, interpret)
    zp, m = _pad_cols(z, block_cols)
    tp = None if t is None else _pad_cols(t, block_cols)[0]
    y, v = round_uplink_2d(zp, tp, prox_fn=prox, rho_eff=rho_eff,
                           block_cols=block_cols, interpret=interpret,
                           emulate=emulate)
    return y[:, :m], v[:, :m]


@partial(jax.jit, static_argnames=("prox", "rho_eff", "damping",
                                   "interpret", "block_cols", "emulate"))
def round_downlink(x, w, z, u, t=None, *, prox=None, rho_eff=1.0,
                   damping=1.0, interpret=None, block_cols=BLOCK_COLS,
                   emulate=False):
    """Fused ``z + 2*damping*(w - prox(mean z_seen, rho_eff))`` +
    participation selects of x and z.  ``u`` is the ``(N,)``
    participation draw (nonzero = active); ``t`` the lagged coordinator
    copy under a compressed exchange (None = exact; the coordinator
    chain is recomputed in-kernel either way -- see the kernel
    docstrings for why it is not an input).  Returns
    ``(x_new, z_new)``.
    """
    interpret = _resolve(x, interpret)
    block_cols = _block_cols(x.shape[1], block_cols, interpret)
    xp, m = _pad_cols(x, block_cols)
    wp, _ = _pad_cols(w, block_cols)
    zp, _ = _pad_cols(z, block_cols)
    tp = None if t is None else _pad_cols(t, block_cols)[0]
    x_new, z_new = round_downlink_2d(
        xp, wp, zp, tp, u=u.reshape(-1, 1), prox_fn=prox,
        rho_eff=rho_eff, damping=damping, block_cols=block_cols,
        interpret=interpret, emulate=emulate)
    return x_new[:, :m], z_new[:, :m]


@partial(jax.jit, static_argnames=("interpret", "block_cols", "emulate"))
def round_uplink_partial(z, *, interpret=None, block_cols=BLOCK_COLS,
                         emulate=False):
    """Local half of the sharded uplink: the ``(1, M)`` column sums of
    one shard's rows (one kernel launch; the psum happens outside)."""
    interpret = _resolve(z, interpret)
    block_cols = _block_cols(z.shape[1], block_cols, interpret)
    zp, m = _pad_cols(z, block_cols)
    s = round_uplink_partial_2d(zp, block_cols=block_cols,
                                interpret=interpret, emulate=emulate)
    return s[:, :m]


@partial(jax.jit, static_argnames=("damping", "interpret", "block_cols",
                                   "emulate"))
def round_downlink_presummed(x, w, z, y, u, *, damping=1.0,
                             interpret=None, block_cols=BLOCK_COLS,
                             emulate=False):
    """Sharded downlink: fused z-update + participation selects of one
    shard's rows, consuming the replicated coordinator point ``y``
    (shape ``(1, M)``) instead of recomputing the chain in-kernel."""
    interpret = _resolve(x, interpret)
    block_cols = _block_cols(x.shape[1], block_cols, interpret)
    xp, m = _pad_cols(x, block_cols)
    wp, _ = _pad_cols(w, block_cols)
    zp, _ = _pad_cols(z, block_cols)
    yp, _ = _pad_cols(y, block_cols)
    x_new, z_new = round_downlink_presummed_2d(
        xp, wp, zp, yp, u=u.reshape(-1, 1), damping=damping,
        block_cols=block_cols, interpret=interpret, emulate=emulate)
    return x_new[:, :m], z_new[:, :m]


def round_uplink_sharded(z, t=None, *, mesh, n_total, prox=None,
                         rho_eff=1.0, row_axis="agent", col_axis=None,
                         interpret=None, block_cols=BLOCK_COLS,
                         emulate=False):
    """Mesh-aware fused uplink: ``shard_map`` over ``mesh``'s agent
    axis, one partial-sum kernel launch per shard, one ``(1, M)`` psum,
    then ``y = prox(psum / n_total)`` and ``v = 2 y - z_local``.

    ``n_total`` is the GLOBAL agent count (the local row extent is
    ``n_total / shards``).  ``col_axis`` additionally shards columns
    (the caller guarantees divisibility).  Returns ``(y, v)`` with
    ``y`` replicated across the agent axis.
    """
    def _body(z_l, t_l=None):
        seen = z_l if t_l is None else t_l
        part = round_uplink_partial(seen, interpret=interpret,
                                    block_cols=block_cols,
                                    emulate=emulate)
        zbar = jax.lax.psum(part, row_axis) / n_total
        y = zbar if prox is None else prox(zbar, rho_eff)
        return y, 2.0 * y - z_l

    spec = P(row_axis, col_axis)
    in_specs = (spec,) if t is None else (spec, spec)
    f = shard_map(_body, mesh=mesh, in_specs=in_specs,
                  out_specs=(P(None, col_axis), spec), check_rep=False)
    return f(z) if t is None else f(z, t)


def round_downlink_sharded(x, w, z, y, u, *, mesh, damping=1.0,
                           row_axis="agent", col_axis=None,
                           interpret=None, block_cols=BLOCK_COLS,
                           emulate=False):
    """Mesh-aware fused downlink: one presummed-downlink kernel launch
    per shard, purely local (the replicated ``y`` carries the only
    cross-shard information).  ``u`` is the global ``(N,)``
    participation draw, sharded with the rows.  Returns
    ``(x_new, z_new)``.
    """
    def _body(x_l, w_l, z_l, y_l, u_l):
        return round_downlink_presummed(x_l, w_l, z_l, y_l, u_l,
                                        damping=damping,
                                        interpret=interpret,
                                        block_cols=block_cols,
                                        emulate=emulate)

    spec = P(row_axis, col_axis)
    f = shard_map(_body, mesh=mesh,
                  in_specs=(spec, spec, spec, P(None, col_axis),
                            P(row_axis)),
                  out_specs=(spec, spec), check_rep=False)
    return f(x, w, z, y, u.reshape(-1))
