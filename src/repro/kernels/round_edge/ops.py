"""Public fused round-edge ops.

Jitted wrappers over the :mod:`repro.kernels.round_edge.kernel` Pallas
kernels: pad the column axis to the block, dispatch, slice back.  The
prox callable is a STATIC argument (it is traced into the kernel body),
so only the :func:`repro.core.prox.make_prox` table's elementwise
functions belong here -- the engine gates on their ``elementwise`` tag
and sends anything else down the XLA path.

``interpret`` resolves via :data:`repro.kernels.ON_TPU` like the other
kernel suites.  Padding columns are zeros; their outputs are sliced off
before returning, so a prox whose fixed point is nonzero at 0 (e.g. a
box with ``lo > 0``) cannot leak padding into real columns.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels import ON_TPU
from repro.kernels.round_edge.kernel import (BLOCK_COLS, round_downlink_2d,
                                             round_uplink_2d)


def _resolve(x, interpret):
    if x.ndim != 2:
        raise ValueError(f"round-edge ops take (N, M) buffers, got "
                         f"shape {x.shape}")
    return (not ON_TPU) if interpret is None else interpret


def _block_cols(m, block_cols, interpret):
    """Interpret mode defaults to ONE program spanning the whole width:
    the column block is a TPU VMEM-tiling concern, and the interpret
    emulator's per-program loop overhead would otherwise dominate the
    very traffic the fusion removes.  An explicit ``block_cols`` always
    wins (the multi-block grid is exercised in tests)."""
    if interpret and block_cols == BLOCK_COLS:
        return max(block_cols, m)
    return block_cols


def _pad_cols(x, block_cols):
    m = x.shape[1]
    bc = min(block_cols, m)
    pad = -m % bc
    if pad:
        x = jnp.concatenate(
            [x, jnp.zeros((x.shape[0], pad), x.dtype)], axis=1)
    return x, m


@partial(jax.jit, static_argnames=("prox", "rho_eff", "interpret",
                                   "block_cols", "emulate"))
def round_uplink(z, t=None, *, prox=None, rho_eff=1.0, interpret=None,
                 block_cols=BLOCK_COLS, emulate=False):
    """Fused ``y = prox(mean_i z_i, rho_eff)``, ``v = 2 y - z``.

    ``t`` (optional) is the coordinator's lagged copy of ``z`` under a
    compressed exchange: the mean/prox run over ``t``, the reflection
    over ``z``.  Returns ``(y, v)`` with ``y`` of shape ``(1, M)``.
    """
    interpret = _resolve(z, interpret)
    block_cols = _block_cols(z.shape[1], block_cols, interpret)
    zp, m = _pad_cols(z, block_cols)
    tp = None if t is None else _pad_cols(t, block_cols)[0]
    y, v = round_uplink_2d(zp, tp, prox_fn=prox, rho_eff=rho_eff,
                           block_cols=block_cols, interpret=interpret,
                           emulate=emulate)
    return y[:, :m], v[:, :m]


@partial(jax.jit, static_argnames=("prox", "rho_eff", "damping",
                                   "interpret", "block_cols", "emulate"))
def round_downlink(x, w, z, u, t=None, *, prox=None, rho_eff=1.0,
                   damping=1.0, interpret=None, block_cols=BLOCK_COLS,
                   emulate=False):
    """Fused ``z + 2*damping*(w - prox(mean z_seen, rho_eff))`` +
    participation selects of x and z.  ``u`` is the ``(N,)``
    participation draw (nonzero = active); ``t`` the lagged coordinator
    copy under a compressed exchange (None = exact; the coordinator
    chain is recomputed in-kernel either way -- see the kernel
    docstrings for why it is not an input).  Returns
    ``(x_new, z_new)``.
    """
    interpret = _resolve(x, interpret)
    block_cols = _block_cols(x.shape[1], block_cols, interpret)
    xp, m = _pad_cols(x, block_cols)
    wp, _ = _pad_cols(w, block_cols)
    zp, _ = _pad_cols(z, block_cols)
    tp = None if t is None else _pad_cols(t, block_cols)[0]
    x_new, z_new = round_downlink_2d(
        xp, wp, zp, tp, u=u.reshape(-1, 1), prox_fn=prox,
        rho_eff=rho_eff, damping=damping, block_cols=block_cols,
        interpret=interpret, emulate=emulate)
    return x_new[:, :m], z_new[:, :m]
