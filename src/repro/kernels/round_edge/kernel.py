"""Fused Fed-PLT round-edge kernels: the coordinator edges of Algorithm 1.

Every Fed-PLT round opens and closes with memory-bound elementwise
traffic over the packed ``(N, M_total)`` agent buffer of
:func:`repro.fed.compress.pack_leaves`:

  uplink    -- ``y = prox_{rho h / N}(mean_i z_i)`` (Lemma 6) followed by
               the reflection ``v = 2 y - z``.  Unfused, XLA round-trips
               the full agent stack through HBM once for the mean, once
               for the prox, and once per leaf for the broadcasted
               reflection; fused, the agent-axis mean-reduce, the
               elementwise prox, and the reflection happen in-register
               per column tile -- ``zbar`` is never materialized in HBM
               and both ``y`` and ``v`` come out of ONE launch.
  downlink  -- the Krasnosel'skii update ``z + 2*damping*(w - y)`` and
               the Bernoulli-participation selects of BOTH state
               variables (``x`` from ``w``, ``z`` from the update), with
               the ``(N,)`` mask streamed once and the coordinator
               chain ``y`` recomputed in VMEM (not read back -- see
               :func:`_downlink_body`).  NaN-safe ``where`` semantics
               are preserved: a diverged local solve cannot leak into
               agents that sat the round out.

The whole :func:`repro.core.prox.make_prox` table (zero / l1 / l2sq /
weight_decay / elastic_net / box / linf_ball) is elementwise, so the
prox callable is traced straight into the kernel body (sign / abs /
clip / mul lower on Mosaic); custom non-elementwise proxes fall back to
the XLA path in the engine, never here.

Both kernels tile COLUMNS only: each grid program sees the full agent
axis (N is the small dimension), so the row mean is one in-kernel
sublane reduction with no cross-program accumulation, and the mean /
prox / reflect arithmetic is op-for-op the engine's per-leaf jnp chain
-- bit-identical to the ref.py oracles (asserted in tests), interpret
mode and TPU-shaped alike (no gather/scatter/iota anywhere; block row
dim is the logical N, which Mosaic masks).  Cross-backend parity of
whole jitted rounds is to fp32 rounding, not bitwise -- see the
parity-contract note in :mod:`repro.fed.engine`.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_COLS = 512   # lane-dim multiple of 128 (VREG alignment)


def _apply_prox(zbar, prox_fn, rho_eff):
    return zbar if prox_fn is None else prox_fn(zbar, rho_eff)


def _uplink_kernel(z_ref, y_ref, v_ref, *, prox_fn, rho_eff):
    """Exact exchange: the coordinator sees z itself -- one read.

    The mean->prox chain is written out ONCE PER OUTPUT, not shared:
    the unfused engine path hands XLA a ``y`` with two consumers (the
    output and the reflection), which the simplifier duplicates and
    then constant-folds / FMA-contracts differently per consumer (e.g.
    ``2*(sum*c)`` becomes a single fused ``sum*(2c) - z``).  Mirroring
    that duplication here puts the identical pattern in front of the
    same compiler, so both backends usually round identically (the
    contract is fp32-rounding equality -- see repro.fed.engine);
    computing ``v`` from the stored ``y`` would pin an intermediate the
    unfused path never materializes and systematically drift."""
    z = z_ref[...]
    y = _apply_prox(jnp.mean(z, axis=0, keepdims=True), prox_fn, rho_eff)
    y_ref[...] = y.astype(y_ref.dtype)
    y2 = _apply_prox(jnp.mean(z, axis=0, keepdims=True), prox_fn, rho_eff)
    v_ref[...] = (2.0 * y2 - z).astype(v_ref.dtype)


def _uplink_lagged_kernel(t_ref, z_ref, y_ref, v_ref, *, prox_fn,
                          rho_eff):
    """Compressed exchange: the coordinator averages its lagged copies
    t_i while the reflection still uses the agents' exact z_i.  Same
    per-output chain duplication as :func:`_uplink_kernel`."""
    t = t_ref[...]
    y = _apply_prox(jnp.mean(t, axis=0, keepdims=True), prox_fn, rho_eff)
    y_ref[...] = y.astype(y_ref.dtype)
    y2 = _apply_prox(jnp.mean(t, axis=0, keepdims=True), prox_fn, rho_eff)
    v_ref[...] = (2.0 * y2 - z_ref[...]).astype(v_ref.dtype)


def _downlink_body(x, w, z, z_seen, u, *, prox_fn, rho_eff, damping):
    """x/z updates of one column block.  The coordinator chain
    ``y = prox(mean(z_seen))`` is RECOMPUTED here rather than read from
    the uplink kernel's output: the unfused engine path never
    materializes ``y`` between the prox and the z-update, so XLA
    constant-folds / FMA-contracts the whole ``z + 2d*(w - prox(mean))``
    chain as one expression -- consuming a stored ``y`` would pin an
    intermediate rounding the XLA path doesn't have and systematically
    drift (see :func:`_uplink_kernel`).  The re-reduce is VMEM-local."""
    mask = u != 0                   # (N, 1), broadcast across columns
    x_new = jnp.where(mask, w, x)
    y = _apply_prox(jnp.mean(z_seen, axis=0, keepdims=True), prox_fn,
                    rho_eff)
    z_upd = z + 2.0 * damping * (w - y)
    return x_new, jnp.where(mask, z_upd, z)


def _downlink_kernel(x_ref, w_ref, z_ref, u_ref, x_out_ref, z_out_ref,
                     *, prox_fn, rho_eff, damping):
    """Exact exchange: the coordinator chain reruns over z itself."""
    z = z_ref[...]
    x_new, z_new = _downlink_body(x_ref[...], w_ref[...], z, z,
                                  u_ref[...], prox_fn=prox_fn,
                                  rho_eff=rho_eff, damping=damping)
    x_out_ref[...] = x_new.astype(x_out_ref.dtype)
    z_out_ref[...] = z_new.astype(z_out_ref.dtype)


def _downlink_lagged_kernel(x_ref, w_ref, z_ref, t_ref, u_ref,
                            x_out_ref, z_out_ref, *, prox_fn, rho_eff,
                            damping):
    """Compressed exchange: the coordinator chain reruns over the
    lagged copies t."""
    x_new, z_new = _downlink_body(x_ref[...], w_ref[...], z_ref[...],
                                  t_ref[...], u_ref[...],
                                  prox_fn=prox_fn, rho_eff=rho_eff,
                                  damping=damping)
    x_out_ref[...] = x_new.astype(x_out_ref.dtype)
    z_out_ref[...] = z_new.astype(z_out_ref.dtype)


def _partial_sum_kernel(z_ref, s_ref):
    """Sharded uplink, local half: the in-VMEM agent-axis reduce of ONE
    shard's rows.  Under ``shard_map`` each device owns a contiguous
    ``(N_local, M)`` row block; this kernel emits its ``(1, M)`` column
    sums, the engine ``psum``s those partials over the agent axis and
    finishes the chain (``/ N`` -> prox -> reflection) as
    coordinator-sized XLA ops.  The division by the GLOBAL agent count
    cannot happen here -- a shard only sees its own rows -- so unlike
    :func:`_uplink_kernel` the kernel is a pure sum: ``div(psum(sum),
    N)`` is bit-identical to the unsharded ``div(sum, N)`` on one shard
    (asserted in tests), which is what makes the 1-device mesh the
    degenerate case of the same code path."""
    s_ref[...] = jnp.sum(z_ref[...], axis=0,
                         keepdims=True).astype(s_ref.dtype)


def _downlink_presummed_kernel(x_ref, w_ref, z_ref, y_ref, u_ref,
                               x_out_ref, z_out_ref, *, damping):
    """Sharded downlink: purely local per-row work consuming the
    REPLICATED coordinator point ``y`` (1, M).  The unsharded
    :func:`_downlink_kernel` recomputes the coordinator chain in-VMEM
    instead, but a shard cannot -- the chain needs the cross-device
    mean -- so this kernel takes the uplink's ``y`` as an input, exactly
    like the engine's unfused xla path (``z + 2*damping*(w - y)`` with
    ``y`` broadcast), whose folding it must and does match bit-for-bit
    on a 1-device mesh (asserted in tests)."""
    mask = u_ref[...] != 0          # (N, 1), broadcast across columns
    x_new = jnp.where(mask, w_ref[...], x_ref[...])
    z = z_ref[...]
    z_upd = z + 2.0 * damping * (w_ref[...] - y_ref[...])
    x_out_ref[...] = x_new.astype(x_out_ref.dtype)
    z_out_ref[...] = jnp.where(mask, z_upd, z).astype(z_out_ref.dtype)


class _DirectRef:
    """Minimal Ref shim for running a kernel body directly (grid == 1,
    interpret mode): ``ref[...]`` reads the full-buffer block,
    ``ref[...] = v`` records the output."""

    def __init__(self, val=None, dtype=None):
        self.val = val
        self.dtype = dtype if dtype is not None else val.dtype

    def __getitem__(self, idx):
        return self.val

    def __setitem__(self, idx, v):
        self.val = v


def _direct(kernel, ins, out_shapes):
    """Run a kernel body once over full-buffer blocks.

    The interpret emulator copies every input and output block through
    ``dynamic_slice`` per program -- at engine-scale buffer widths
    those whole-buffer copies cost ~50x the fused arithmetic itself.
    With a single-program grid the body is just traced jnp on the full
    block, so interpret mode executes it directly; ``pallas_call``
    remains the path for real (multi-program) grids and for the TPU
    lowering, and is asserted bit-identical to this realization in
    tests (``emulate=True``)."""
    in_refs = [_DirectRef(a) for a in ins]
    out_refs = [_DirectRef(dtype=s.dtype) for s in out_shapes]
    kernel(*in_refs, *out_refs)
    return tuple(r.val for r in out_refs)


def round_uplink_2d(z, t=None, *, prox_fn=None, rho_eff=1.0,
                    block_cols=BLOCK_COLS, interpret=True,
                    emulate=False):
    """Fused coordinator prox + reflection on an ``(N, M)`` buffer.

    Returns ``(y, v)`` with ``y`` of shape ``(1, M)``.  ``t`` is the
    coordinator's lagged copy when the z-exchange is compressed (the
    mean runs over ``t``, the reflection over ``z``); None means the
    exchange is exact and ``z`` is read once.  ``M % block_cols == 0``
    (ops.py pads).
    """
    n, m = z.shape
    bc = min(block_cols, m)
    if m % bc:
        raise ValueError(f"column count {m} not a multiple of the "
                         f"column block {bc} (ops.py pads)")
    spec = pl.BlockSpec((n, bc), lambda j: (0, j))
    y_spec = pl.BlockSpec((1, bc), lambda j: (0, j))
    if t is None:
        kernel = functools.partial(_uplink_kernel, prox_fn=prox_fn,
                                   rho_eff=rho_eff)
        in_specs, args = [spec], (z,)
    else:
        if t.shape != z.shape or t.dtype != z.dtype:
            raise ValueError(f"t {t.shape}/{t.dtype} must match z "
                             f"{z.shape}/{z.dtype}")
        kernel = functools.partial(_uplink_lagged_kernel, prox_fn=prox_fn,
                                   rho_eff=rho_eff)
        in_specs, args = [spec, spec], (t, z)
    out_shape = (jax.ShapeDtypeStruct((1, m), z.dtype),
                 jax.ShapeDtypeStruct(z.shape, z.dtype))
    if interpret and bc == m and not emulate:
        return _direct(kernel, args, out_shape)
    return pl.pallas_call(
        kernel,
        grid=(m // bc,),
        in_specs=in_specs,
        out_specs=(y_spec, spec),
        out_shape=out_shape,
        interpret=interpret,
    )(*args)


def round_downlink_2d(x, w, z, t=None, *, u, prox_fn=None, rho_eff=1.0,
                      damping=1.0, block_cols=BLOCK_COLS,
                      interpret=True, emulate=False):
    """Fused Krasnosel'skii z-update + participation selects.

    ``x, w, z``: ``(N, M)``; ``u``: the ``(N, 1)`` participation draw
    (any dtype; nonzero = the agent was active); ``t`` is the
    coordinator's lagged copy of ``z`` under a compressed exchange
    (None = exact, the coordinator chain reruns over ``z``).  Returns
    ``(x_new, z_new)``.
    """
    n, m = x.shape
    bc = min(block_cols, m)
    if m % bc:
        raise ValueError(f"column count {m} not a multiple of the "
                         f"column block {bc} (ops.py pads)")
    checks = [("w", w, x.shape), ("z", z, x.shape), ("u", u, (n, 1))]
    if t is not None:
        checks.append(("t", t, x.shape))
    for name, a, shape in checks:
        if a.shape != shape:
            raise ValueError(f"{name} has shape {a.shape}, want {shape}")
    spec = pl.BlockSpec((n, bc), lambda j: (0, j))
    u_spec = pl.BlockSpec((n, 1), lambda j: (0, 0))
    if t is None:
        kernel = functools.partial(_downlink_kernel, prox_fn=prox_fn,
                                   rho_eff=rho_eff, damping=damping)
        in_specs = [spec, spec, spec, u_spec]
        args = (x, w, z, u)
    else:
        kernel = functools.partial(_downlink_lagged_kernel,
                                   prox_fn=prox_fn, rho_eff=rho_eff,
                                   damping=damping)
        in_specs = [spec, spec, spec, spec, u_spec]
        args = (x, w, z, t, u)
    out_shape = (jax.ShapeDtypeStruct(x.shape, x.dtype),
                 jax.ShapeDtypeStruct(z.shape, z.dtype))
    if interpret and bc == m and not emulate:
        return _direct(kernel, args, out_shape)
    return pl.pallas_call(
        kernel,
        grid=(m // bc,),
        in_specs=in_specs,
        out_specs=(spec, spec),
        out_shape=out_shape,
        interpret=interpret,
    )(*args)


def round_uplink_partial_2d(z, *, block_cols=BLOCK_COLS, interpret=True,
                            emulate=False):
    """Local half of the sharded uplink: the ``(1, M)`` column sums of
    one shard's ``(N_local, M)`` row block.  The caller (ops.py) runs
    this under ``shard_map``, psums the partials over the agent axis,
    and finishes ``/ N -> prox -> reflection`` on coordinator-sized
    arrays; ``zbar`` still never hits HBM at agent-stack size.
    ``M % block_cols == 0`` (ops.py pads).
    """
    n, m = z.shape
    bc = min(block_cols, m)
    if m % bc:
        raise ValueError(f"column count {m} not a multiple of the "
                         f"column block {bc} (ops.py pads)")
    spec = pl.BlockSpec((n, bc), lambda j: (0, j))
    s_spec = pl.BlockSpec((1, bc), lambda j: (0, j))
    out_shape = (jax.ShapeDtypeStruct((1, m), z.dtype),)
    if interpret and bc == m and not emulate:
        return _direct(_partial_sum_kernel, (z,), out_shape)[0]
    return pl.pallas_call(
        _partial_sum_kernel,
        grid=(m // bc,),
        in_specs=[spec],
        out_specs=(s_spec,),
        out_shape=out_shape,
        interpret=interpret,
    )(z)[0]


def round_downlink_presummed_2d(x, w, z, y, *, u, damping=1.0,
                                block_cols=BLOCK_COLS, interpret=True,
                                emulate=False):
    """Sharded downlink: z-update + participation selects of one
    shard's rows, consuming the replicated ``(1, M)`` coordinator point
    ``y`` from the sharded uplink (no in-kernel chain recompute -- a
    shard cannot reproduce the cross-device mean locally).  Returns
    ``(x_new, z_new)``.  ``M % block_cols == 0`` (ops.py pads).
    """
    n, m = x.shape
    bc = min(block_cols, m)
    if m % bc:
        raise ValueError(f"column count {m} not a multiple of the "
                         f"column block {bc} (ops.py pads)")
    for name, a, shape in [("w", w, x.shape), ("z", z, x.shape),
                           ("y", y, (1, m)), ("u", u, (n, 1))]:
        if a.shape != shape:
            raise ValueError(f"{name} has shape {a.shape}, want {shape}")
    spec = pl.BlockSpec((n, bc), lambda j: (0, j))
    y_spec = pl.BlockSpec((1, bc), lambda j: (0, j))
    u_spec = pl.BlockSpec((n, 1), lambda j: (0, 0))
    kernel = functools.partial(_downlink_presummed_kernel,
                               damping=damping)
    args = (x, w, z, y, u)
    out_shape = (jax.ShapeDtypeStruct(x.shape, x.dtype),
                 jax.ShapeDtypeStruct(z.shape, z.dtype))
    if interpret and bc == m and not emulate:
        return _direct(kernel, args, out_shape)
    return pl.pallas_call(
        kernel,
        grid=(m // bc,),
        in_specs=[spec, spec, spec, y_spec, u_spec],
        out_specs=(spec, spec),
        out_shape=out_shape,
        interpret=interpret,
    )(*args)
