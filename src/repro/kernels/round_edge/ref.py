"""Pure-jnp oracles for the fused round-edge kernels.

Independent implementations of the coordinator edges, written exactly
as :mod:`repro.fed.engine` computes them per leaf (mean -> prox ->
reflect; Krasnosel'skii update -> NaN-safe participation selects) --
the kernels must bit-match these on ragged, non-block-aligned, and
partially-participating inputs.
"""

from __future__ import annotations

import jax.numpy as jnp


def round_uplink_ref(z, t=None, prox=None, rho_eff=1.0):
    """``y = prox(mean_i z_i, rho_eff)``, ``v = 2 y - z`` on (N, M).

    Written with the ENGINE's exact shapes (axis-dropping mean, ``[None]``
    reflection broadcast): XLA's constant refolding of the shared
    coordinator chain is context-sensitive down to broadcast shapes, and
    the engine's per-leaf formulation is the contract the kernels must
    hit bit-for-bit."""
    zbar = jnp.mean(z if t is None else t, axis=0)
    y = zbar if prox is None else prox(zbar, rho_eff)
    return y[None], 2.0 * y[None] - z


def round_downlink_ref(x, w, z, u, t=None, prox=None, rho_eff=1.0,
                       damping=1.0):
    """Krasnosel'skii ``z + 2*damping*(w - y)`` + participation selects
    (``jnp.where``: an inactive agent's state is untouched even by a
    NaN local solve).  ``y`` is recomputed from the coordinator chain,
    exactly as the engine's unfused z-update consumes it."""
    mask = (u != 0).reshape(-1, 1)
    zbar = jnp.mean(z if t is None else t, axis=0)
    y = zbar if prox is None else prox(zbar, rho_eff)
    x_new = jnp.where(mask, w, x)
    z_new = jnp.where(mask, z + 2.0 * damping * (w - y[None]), z)
    return x_new, z_new


def round_uplink_partial_ref(z):
    """Local half of the sharded uplink: plain column sums of one
    shard's rows."""
    return jnp.sum(z, axis=0, keepdims=True)


def round_uplink_sharded_ref(z, t=None, prox=None, rho_eff=1.0,
                             n_total=None):
    """The SHARDED uplink formulation on a whole (N, M) buffer:
    sum -> divide by the global agent count -> prox -> reflection, with
    the reflection computed from the SHARED y (a shard consumes the
    replicated coordinator point; it cannot re-fold the chain per
    consumer the way the unsharded kernel mirrors).  ``n_total``
    defaults to N."""
    seen = z if t is None else t
    n = seen.shape[0] if n_total is None else n_total
    zbar = jnp.sum(seen, axis=0, keepdims=True) / n
    y = zbar if prox is None else prox(zbar, rho_eff)
    return y, 2.0 * y - z


def round_downlink_presummed_ref(x, w, z, u, y, damping=1.0):
    """Sharded downlink: the Krasnosel'skii update + participation
    selects consuming a REPLICATED coordinator point ``y`` of shape
    (1, M) -- no chain recompute (a shard cannot reproduce the
    cross-device mean locally)."""
    mask = (u != 0).reshape(-1, 1)
    x_new = jnp.where(mask, w, x)
    z_new = jnp.where(mask, z + 2.0 * damping * (w - y), z)
    return x_new, z_new
