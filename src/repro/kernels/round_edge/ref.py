"""Pure-jnp oracles for the fused round-edge kernels.

Independent implementations of the coordinator edges, written exactly
as :mod:`repro.fed.engine` computes them per leaf (mean -> prox ->
reflect; Krasnosel'skii update -> NaN-safe participation selects) --
the kernels must bit-match these on ragged, non-block-aligned, and
partially-participating inputs.
"""

from __future__ import annotations

import jax.numpy as jnp


def round_uplink_ref(z, t=None, prox=None, rho_eff=1.0):
    """``y = prox(mean_i z_i, rho_eff)``, ``v = 2 y - z`` on (N, M).

    Written with the ENGINE's exact shapes (axis-dropping mean, ``[None]``
    reflection broadcast): XLA's constant refolding of the shared
    coordinator chain is context-sensitive down to broadcast shapes, and
    the engine's per-leaf formulation is the contract the kernels must
    hit bit-for-bit."""
    zbar = jnp.mean(z if t is None else t, axis=0)
    y = zbar if prox is None else prox(zbar, rho_eff)
    return y[None], 2.0 * y[None] - z


def round_downlink_ref(x, w, z, u, t=None, prox=None, rho_eff=1.0,
                       damping=1.0):
    """Krasnosel'skii ``z + 2*damping*(w - y)`` + participation selects
    (``jnp.where``: an inactive agent's state is untouched even by a
    NaN local solve).  ``y`` is recomputed from the coordinator chain,
    exactly as the engine's unfused z-update consumes it."""
    mask = (u != 0).reshape(-1, 1)
    zbar = jnp.mean(z if t is None else t, axis=0)
    y = zbar if prox is None else prox(zbar, rho_eff)
    x_new = jnp.where(mask, w, x)
    z_new = jnp.where(mask, z + 2.0 * damping * (w - y[None]), z)
    return x_new, z_new
