"""Fused Fed-PLT local-step kernel.

    w_new = w - gamma * (g + inv_rho * (w - v)) [+ noise]

One fused pass: three HBM reads (w, g, v [, t]) and one write, vs. the
four extra round-trips XLA does unfused at billion-parameter scale.
Tiled (BLOCK_M, BLOCK_N) over a 2-D view of the flattened parameter
leaf; accumulation in fp32 regardless of the storage dtype.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_M = 256
BLOCK_N = 512   # lane-dim multiple of 128 (VREG / MXU alignment)


def _update_kernel(w_ref, g_ref, v_ref, w_out_ref, *, gamma, inv_rho):
    w = w_ref[...].astype(jnp.float32)
    g = g_ref[...].astype(jnp.float32)
    v = v_ref[...].astype(jnp.float32)
    out = w - gamma * (g + inv_rho * (w - v))
    w_out_ref[...] = out.astype(w_out_ref.dtype)


def _update_noise_kernel(w_ref, g_ref, v_ref, t_ref, w_out_ref, *,
                         gamma, inv_rho):
    w = w_ref[...].astype(jnp.float32)
    g = g_ref[...].astype(jnp.float32)
    v = v_ref[...].astype(jnp.float32)
    t = t_ref[...].astype(jnp.float32)
    out = w - gamma * (g + inv_rho * (w - v)) + t
    w_out_ref[...] = out.astype(w_out_ref.dtype)


def fedplt_update_2d(w, g, v, t=None, *, gamma: float, inv_rho: float,
                     interpret: bool = True):
    """2-D tiled fused update. w, g, v[, t]: (M, N) with M % BLOCK_M ==
    N % BLOCK_N == 0 (ops.py pads)."""
    M, N = w.shape
    bm, bn = min(BLOCK_M, M), min(BLOCK_N, N)
    grid = (M // bm, N // bn)
    spec = pl.BlockSpec((bm, bn), lambda i, j: (i, j))
    if t is None:
        kernel = functools.partial(_update_kernel, gamma=gamma,
                                   inv_rho=inv_rho)
        in_specs = [spec] * 3
        args = (w, g, v)
    else:
        kernel = functools.partial(_update_noise_kernel, gamma=gamma,
                                   inv_rho=inv_rho)
        in_specs = [spec] * 4
        args = (w, g, v, t)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct(w.shape, w.dtype),
        interpret=interpret,
    )(*args)
