"""Pure-jnp oracle for the fused Fed-PLT local step."""

import jax.numpy as jnp


def fedplt_update_ref(w, g, v, t=None, *, gamma: float, inv_rho: float):
    w32 = w.astype(jnp.float32)
    out = w32 - gamma * (g.astype(jnp.float32)
                         + inv_rho * (w32 - v.astype(jnp.float32)))
    if t is not None:
        out = out + t.astype(jnp.float32)
    return out.astype(w.dtype)
