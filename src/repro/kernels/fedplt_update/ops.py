"""Public fused Fed-PLT update op: arbitrary-shape leaves + pytrees."""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels import ON_TPU
from repro.kernels.fedplt_update.kernel import (BLOCK_M, BLOCK_N,
                                                fedplt_update_2d)


def _pad_to_2d(x):
    flat = x.reshape(-1)
    n = flat.shape[0]
    cols = BLOCK_N if n >= BLOCK_N else n
    rows = -(-n // cols)
    if rows > BLOCK_M and rows % BLOCK_M:
        rows += BLOCK_M - rows % BLOCK_M   # row-tile alignment
    pad = rows * cols - n
    if pad:
        flat = jnp.concatenate([flat, jnp.zeros((pad,), x.dtype)])
    return flat.reshape(rows, cols), n


@partial(jax.jit, static_argnames=("gamma", "inv_rho", "interpret"))
def fedplt_update(w, g, v, t=None, *, gamma: float, inv_rho: float,
                  interpret: bool | None = None):
    """Fused ``w - gamma (g + inv_rho (w - v)) [+ t]`` for one leaf."""
    if interpret is None:
        interpret = not ON_TPU
    w2, n = _pad_to_2d(w)
    g2, _ = _pad_to_2d(g.astype(w.dtype))
    v2, _ = _pad_to_2d(v.astype(w.dtype))
    t2 = None
    if t is not None:
        t2, _ = _pad_to_2d(t.astype(w.dtype))
    out = fedplt_update_2d(w2, g2, v2, t2, gamma=gamma, inv_rho=inv_rho,
                           interpret=interpret)
    return out.reshape(-1)[:n].reshape(w.shape)


def fedplt_update_tree(w_tree, g_tree, v_tree, *, gamma: float,
                       inv_rho: float, interpret: bool | None = None):
    """Apply the fused update leaf-wise across a parameter pytree."""
    return jax.tree_util.tree_map(
        lambda w, g, v: fedplt_update(w, g, v, gamma=gamma,
                                      inv_rho=inv_rho,
                                      interpret=interpret),
        w_tree, g_tree, v_tree)
