"""Data pipeline: synthetic streams, federated partitioning, loaders."""

from repro.data.synthetic import (  # noqa: F401
    synthetic_lm_batch,
    fed_lm_batches,
    make_batch_for,
)
