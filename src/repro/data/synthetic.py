"""Synthetic data pipeline.

Deterministic, PRNG-keyed token streams for LM training/serving, with
per-agent federation (each agent draws from a shifted distribution =
non-IID local data, mirroring the paper's heterogeneous-agents setting),
plus helpers that materialize a batch matching ``input_specs``.
"""

from __future__ import annotations

from typing import Iterator

import jax
import jax.numpy as jnp

from repro.configs.base import InputShape, ModelConfig
from repro.models import frontends


def synthetic_lm_batch(key, vocab: int, batch: int, seq_len: int,
                       skew: float = 0.0) -> dict:
    """Zipf-flavoured token stream; ``skew`` biases the distribution
    per-agent (non-IID)."""
    k1, k2 = jax.random.split(key)
    # piecewise: frequent head tokens + uniform tail, head shifted by skew
    head = jax.random.randint(k1, (batch, seq_len), 0,
                              max(2, int(vocab * 0.1)))
    tail = jax.random.randint(k2, (batch, seq_len), 0, vocab)
    coin = jax.random.bernoulli(key, 0.7 + 0.2 * jnp.tanh(skew),
                                (batch, seq_len))
    tokens = jnp.where(coin, (head + jnp.int32(skew * 100)) % vocab, tail)
    labels = jnp.roll(tokens, -1, axis=-1)
    return {"tokens": tokens, "labels": labels}


def make_batch_for(cfg: ModelConfig, shape: InputShape, key=None,
                   n_agents: int | None = None) -> dict:
    """A concrete batch matching ``input_specs(cfg, shape)['batch']``;
    with ``n_agents`` set, adds a leading agent axis (fed mode)."""
    if key is None:
        key = jax.random.PRNGKey(0)
    B, S = shape.global_batch, shape.seq_len

    def one(k, skew):
        s_text = S - (cfg.n_frontend_tokens if cfg.frontend == "vision"
                      else 0)
        b = B if n_agents is None else B // n_agents
        out = synthetic_lm_batch(k, cfg.vocab, b, s_text, skew)
        if cfg.n_enc_layers:
            out["enc_embeds"] = frontends.fake_audio_frames(k, cfg, b)
        if cfg.frontend == "vision":
            out["patch_embeds"] = frontends.fake_patch_embeds(k, cfg, b)
        if shape.kind != "train":
            out.pop("labels")
        return out

    if n_agents is None:
        return one(key, 0.0)
    ks = jax.random.split(key, n_agents)
    return jax.vmap(one)(ks, jnp.arange(n_agents, dtype=jnp.float32))


def fed_lm_batches(cfg: ModelConfig, shape: InputShape, n_agents: int,
                   seed: int = 0) -> Iterator[dict]:
    """Infinite iterator of per-agent-stacked training batches."""
    key = jax.random.PRNGKey(seed)
    step = 0
    while True:
        yield make_batch_for(cfg, shape, jax.random.fold_in(key, step),
                             n_agents=n_agents)
        step += 1
