"""Checkpointing: flattened npz leaves + JSON key manifest.

Host-side, framework-free (numpy) so checkpoints survive JAX upgrades;
restore re-shards onto the current mesh via device_put when given
shardings.

CRASH SAFETY.  :func:`save_checkpoint` is atomic at the directory
level: the checkpoint is assembled in a same-filesystem temporary
sibling (``<name>.ckpt-tmp-*``) -- leaves first, the manifest last,
fsync'd -- and only then renamed over the target.  A process killed at
ANY point therefore leaves either the previous complete checkpoint or
the new complete checkpoint at ``path``, never a torn mix; the worst
case is a leftover ``*.ckpt-tmp-*`` directory, which
:func:`find_latest_checkpoint` ignores.  The manifest doubles as the
commit record: :func:`is_checkpoint` treats a directory without a
parseable manifest + leaves file as not-a-checkpoint.
"""

from __future__ import annotations

import json
import os
import shutil
import tempfile

import jax
import numpy as np


def _flatten(tree):
    leaves = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in leaves:
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                       for k in path)
        out[key] = np.asarray(leaf)
    return out


def _fsync_dir(path: str) -> None:
    """Best-effort directory fsync (makes the rename durable; some
    filesystems don't support opening directories -- ignore those)."""
    try:
        fd = os.open(path, os.O_RDONLY)
    except OSError:
        return
    try:
        os.fsync(fd)
    except OSError:
        pass
    finally:
        os.close(fd)


def save_checkpoint(path: str, tree, step: int | None = None,
                    extra: dict | None = None):
    """``extra`` is an optional JSON-able dict stored in the manifest --
    e.g. the packed state layout (``packed_layout_manifest``) so a
    packed-resident run can validate its buffer geometry on restore.

    Atomic: assembled in a temporary sibling and renamed into place
    (see the module docstring); a kill mid-save never corrupts an
    existing checkpoint at ``path``.
    """
    path = path.rstrip(os.sep)
    parent = os.path.dirname(path) or "."
    os.makedirs(parent, exist_ok=True)
    base = os.path.basename(path)
    # same-directory tmp so the final rename never crosses a filesystem
    tmp = tempfile.mkdtemp(prefix=base + ".ckpt-tmp-", dir=parent)
    try:
        flat = _flatten(tree)
        np.savez(os.path.join(tmp, "leaves.npz"), **flat)
        treedef = jax.tree_util.tree_structure(tree)
        manifest = {"keys": sorted(flat), "step": step,
                    "treedef": str(treedef)}
        if extra is not None:
            manifest["extra"] = extra
        # the manifest is written LAST and fsync'd: its presence is the
        # commit record (is_checkpoint requires it to parse)
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
            f.flush()
            os.fsync(f.fileno())
        if os.path.exists(path):
            # swap dance: move the old checkpoint aside, promote the
            # new one, then drop the old; a failure mid-swap restores
            # the old checkpoint at ``path``
            trash = tempfile.mkdtemp(prefix=base + ".ckpt-tmp-old-",
                                     dir=parent)
            old = os.path.join(trash, "old")
            os.rename(path, old)
            try:
                os.rename(tmp, path)
            except OSError:
                os.rename(old, path)
                raise
            finally:
                shutil.rmtree(trash, ignore_errors=True)
        else:
            os.rename(tmp, path)
        _fsync_dir(parent)
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise


def restore_checkpoint(path: str, like, shardings=None):
    """Restore into the structure of ``like`` (a pytree of arrays or
    ShapeDtypeStructs).  Optionally device_put with ``shardings``.

    The stored key set is validated against ``like`` up front: missing
    and unexpected leaf keys are reported together in ONE ValueError,
    so a layout/model mismatch reads as a diff instead of a KeyError
    on whichever leaf happened to flatten first."""
    data = np.load(os.path.join(path, "leaves.npz"))
    flat_like = jax.tree_util.tree_flatten_with_path(like)
    want = {}
    for path_k, leaf in flat_like[0]:
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                       for k in path_k)
        want[key] = leaf
    have = set(data.files)
    missing = sorted(set(want) - have)
    extra_keys = sorted(have - set(want))
    if missing or extra_keys:
        parts = []
        if missing:
            parts.append("missing from checkpoint: "
                         + ", ".join(missing))
        if extra_keys:
            parts.append("unexpected in checkpoint: "
                         + ", ".join(extra_keys))
        raise ValueError(
            f"checkpoint at {path!r} does not match the restore "
            f"target ({'; '.join(parts)})")
    leaves = []
    for path_k, leaf in flat_like[0]:
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                       for k in path_k)
        arr = data[key]
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(f"shape mismatch for {key}: "
                             f"{arr.shape} vs {leaf.shape}")
        leaves.append(arr.astype(leaf.dtype))
    tree = jax.tree_util.tree_unflatten(flat_like[1], leaves)
    if shardings is not None:
        tree = jax.device_put(tree, shardings)
    return tree


def checkpoint_step(path: str) -> int | None:
    with open(os.path.join(path, "manifest.json")) as f:
        return json.load(f).get("step")


def checkpoint_extra(path: str) -> dict | None:
    """The manifest's ``extra`` dict (None for checkpoints written
    without one)."""
    with open(os.path.join(path, "manifest.json")) as f:
        return json.load(f).get("extra")


def is_checkpoint(path: str) -> bool:
    """True iff ``path`` holds a COMMITTED checkpoint: a parseable
    manifest plus the leaves file (a torn or in-flight tmp directory
    fails this)."""
    if not os.path.isdir(path):
        return False
    if not os.path.exists(os.path.join(path, "leaves.npz")):
        return False
    try:
        with open(os.path.join(path, "manifest.json")) as f:
            json.load(f)
    except (OSError, json.JSONDecodeError):
        return False
    return True


def find_latest_checkpoint(root: str) -> str | None:
    """The newest committed checkpoint directory under ``root``.

    "Newest" = highest manifest ``step`` (name as tie-break, so
    zero-padded ``step-%06d`` names order correctly even without
    steps).  In-flight / leftover ``*.ckpt-tmp-*`` directories and
    anything failing :func:`is_checkpoint` are skipped.  ``root``
    itself qualifies when it is directly a checkpoint."""
    if is_checkpoint(root):
        return root
    if not os.path.isdir(root):
        return None
    best = None
    for name in sorted(os.listdir(root)):
        if ".ckpt-tmp-" in name:
            continue
        cand = os.path.join(root, name)
        if not is_checkpoint(cand):
            continue
        step = checkpoint_step(cand)
        key = (step if step is not None else -1, name)
        if best is None or key > best[0]:
            best = (key, cand)
    return None if best is None else best[1]


def packed_layout_manifest(meta) -> dict:
    """JSON form of a :class:`repro.fed.compress.PackedMeta` for the
    checkpoint manifest: enough to verify on restore that a packed
    ``(N, width)`` state buffer was produced by the same model layout
    (the treedef itself is rebuilt from the model, not the manifest)."""
    return {"state_layout": "packed", "width": int(meta.width),
            "segments": [[int(a), int(b)] for a, b in meta.segments],
            "shapes": [list(map(int, s)) for s in meta.shapes]}
