"""Checkpointing: flattened npz leaves + JSON key manifest.

Host-side, framework-free (numpy) so checkpoints survive JAX upgrades;
restore re-shards onto the current mesh via device_put when given
shardings.
"""

from __future__ import annotations

import json
import os

import jax
import numpy as np


def _flatten(tree):
    leaves = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in leaves:
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                       for k in path)
        out[key] = np.asarray(leaf)
    return out


def save_checkpoint(path: str, tree, step: int | None = None,
                    extra: dict | None = None):
    """``extra`` is an optional JSON-able dict stored in the manifest --
    e.g. the packed state layout (``packed_layout_manifest``) so a
    packed-resident run can validate its buffer geometry on restore."""
    os.makedirs(path, exist_ok=True)
    flat = _flatten(tree)
    np.savez(os.path.join(path, "leaves.npz"), **flat)
    treedef = jax.tree_util.tree_structure(tree)
    manifest = {"keys": sorted(flat), "step": step,
                "treedef": str(treedef)}
    if extra is not None:
        manifest["extra"] = extra
    with open(os.path.join(path, "manifest.json"), "w") as f:
        json.dump(manifest, f)


def restore_checkpoint(path: str, like, shardings=None):
    """Restore into the structure of ``like`` (a pytree of arrays or
    ShapeDtypeStructs).  Optionally device_put with ``shardings``."""
    data = np.load(os.path.join(path, "leaves.npz"))
    flat_like = jax.tree_util.tree_flatten_with_path(like)
    leaves = []
    for path_k, leaf in flat_like[0]:
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                       for k in path_k)
        arr = data[key]
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(f"shape mismatch for {key}: "
                             f"{arr.shape} vs {leaf.shape}")
        leaves.append(arr.astype(leaf.dtype))
    tree = jax.tree_util.tree_unflatten(flat_like[1], leaves)
    if shardings is not None:
        tree = jax.device_put(tree, shardings)
    return tree


def checkpoint_step(path: str) -> int | None:
    with open(os.path.join(path, "manifest.json")) as f:
        return json.load(f).get("step")


def checkpoint_extra(path: str) -> dict | None:
    """The manifest's ``extra`` dict (None for checkpoints written
    without one)."""
    with open(os.path.join(path, "manifest.json")) as f:
        return json.load(f).get("extra")


def packed_layout_manifest(meta) -> dict:
    """JSON form of a :class:`repro.fed.compress.PackedMeta` for the
    checkpoint manifest: enough to verify on restore that a packed
    ``(N, width)`` state buffer was produced by the same model layout
    (the treedef itself is rebuilt from the model, not the manifest)."""
    return {"state_layout": "packed", "width": int(meta.width),
            "segments": [[int(a), int(b)] for a, b in meta.segments],
            "shapes": [list(map(int, s)) for s in meta.shapes]}
