"""Checkpointing: npz leaves + JSON treedef, shard-aware restore.

Saves are atomic (tmp-then-rename; see :mod:`repro.checkpoint.io`), so
a run killed mid-save never leaves a torn checkpoint behind.
"""

from repro.checkpoint.io import (  # noqa: F401
    checkpoint_extra,
    checkpoint_step,
    find_latest_checkpoint,
    is_checkpoint,
    restore_checkpoint,
    save_checkpoint,
)
