"""Checkpointing: npz leaves + JSON treedef, shard-aware restore."""

from repro.checkpoint.io import save_checkpoint, restore_checkpoint  # noqa: F401
