"""Trip-count-aware static analysis of post-SPMD HLO text.

XLA's ``compiled.cost_analysis()`` counts while-loop bodies ONCE (verified
empirically in this repo), which silently underestimates FLOPs/bytes of
scan-over-layers models by the layer count, and of Fed-PLT rounds by N_e.
This module re-derives the three roofline inputs from the HLO text itself,
multiplying every computation's cost by the product of the trip counts of
the while loops enclosing it:

  * flops            -- 2*M*N*K for dot ops (operand shapes resolved via
                        per-computation symbol tables), 1 flop/element for
                        elementwise ops inside fusions;
  * hbm bytes        -- operand + result bytes of top-level instructions
                        (HloCostAnalysis convention: fusion-internal
                        values don't touch HBM);
  * collective bytes -- per collective kind, bytes moved per device
                        (all-reduce counted 2x: ring reduce+broadcast).

All numbers are PER DEVICE (the SPMD module is per-device).
"""

from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.+?)\s+([\w\-]+)\((.*)$")
_COMP_NAME_RE = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\(")
_OPERAND_NAME_RE = re.compile(r"%([\w.\-]+)")
_ATTR_COMP_RE = {
    "body": re.compile(r"body=%?([\w.\-]+)"),
    "condition": re.compile(r"condition=%?([\w.\-]+)"),
    "calls": re.compile(r"calls=%?([\w.\-]+)"),
    "to_apply": re.compile(r"to_apply=%?([\w.\-]+)"),
    "branches": re.compile(r"branch_computations=\{([^}]*)\}"),
}
_CONST_RE = re.compile(r"constant\((-?\d+)\)")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")

_DTYPE_BYTES = {
    "pred": 1, "s2": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2,
    "u16": 2, "s32": 4, "u32": 4, "s64": 8, "u64": 8, "f8e4m3fn": 1,
    "f8e5m2": 1, "bf16": 2, "f16": 2, "f32": 4, "f64": 8, "c64": 8,
    "c128": 16, "token": 0, "opaque": 0,
}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")
_SKIP_BYTES_OPS = {
    "parameter", "constant", "get-tuple-element", "tuple", "bitcast",
    "while", "call", "conditional", "after-all", "partition-id",
    "replica-id", "iota",
}
_ELEMENTWISE_HINT = {
    "add", "subtract", "multiply", "divide", "power", "exponential",
    "exponential-minus-one", "log", "log-plus-one", "tanh", "rsqrt",
    "sqrt", "negate", "maximum", "minimum", "compare", "select", "and",
    "or", "not", "xor", "abs", "sign", "floor", "ceil", "round",
    "convert", "cosine", "sine", "atan2", "clamp", "logistic",
}


def _shape_info(type_str: str):
    """[(dtype, [dims...]), ...] for a (possibly tuple) type string."""
    out = []
    for dt, dims in _SHAPE_RE.findall(type_str):
        dlist = [int(x) for x in dims.split(",")] if dims else []
        out.append((dt, dlist))
    return out


def _bytes_of(type_str: str) -> int:
    total = 0
    for dt, dims in _shape_info(type_str):
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES.get(dt, 0)
    return total


def _elems_of(type_str: str) -> int:
    total = 0
    for _, dims in _shape_info(type_str):
        n = 1
        for d in dims:
            n *= d
        total += n
    return total


@dataclasses.dataclass
class Instr:
    name: str
    type_str: str
    op: str
    rest: str           # args + attrs (unsplit tail of the line)

    def operand_names(self):
        # strip attr section heuristically: operands come before '), '
        depth = 1
        for i, ch in enumerate(self.rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    return _OPERAND_NAME_RE.findall(self.rest[:i])
        return _OPERAND_NAME_RE.findall(self.rest)

    def attr(self, key):
        m = _ATTR_COMP_RE[key].search(self.rest)
        return m.group(1) if m else None


def _split_top_level(s: str, sep: str = ","):
    """Split at top-level separators (parens/brackets/braces respected)."""
    parts, depth, start = [], 0, 0
    for i, ch in enumerate(s):
        if ch in "([{":
            depth += 1
        elif ch in ")]}":
            depth -= 1
        elif ch == sep and depth == 0:
            parts.append(s[start:i])
            start = i + 1
    parts.append(s[start:])
    return parts


def _parse_comp_header(line: str):
    """-> (name, is_entry, {param: type}) or None."""
    s = line.strip()
    if not s.endswith("{") or "->" not in s:
        return None
    m = _COMP_NAME_RE.match(s)
    if not m:
        return None
    is_entry, name = bool(m.group(1)), m.group(2)
    # params: substring between the first '(' and its matching ')'
    i0 = s.index("(")
    depth, i1 = 0, len(s)
    for i in range(i0, len(s)):
        if s[i] == "(":
            depth += 1
        elif s[i] == ")":
            depth -= 1
            if depth == 0:
                i1 = i
                break
    params = {}
    inner = s[i0 + 1:i1]
    if inner.strip():
        for part in _split_top_level(inner):
            if ":" in part:
                pname, ptype = part.split(":", 1)
                params[pname.strip()] = ptype.strip()
    return name, is_entry, params


def parse_module(text: str):
    """-> (computations: {name: [Instr]}, symtab: {name: {instr: type}},
    entry name)."""
    comps: dict = {}
    symtab: dict = defaultdict(dict)
    entry = None
    cur = None
    for line in text.splitlines():
        if cur is None or line.rstrip().endswith("{"):
            header = _parse_comp_header(line)
            if header is not None:
                cur, is_entry, params = header[0], header[1], header[2]
                comps[cur] = []
                if is_entry:
                    entry = cur
                symtab[cur].update(params)
                continue
        if line.startswith("}"):
            cur = None
            continue
        if cur is None:
            continue
        mi = _INSTR_RE.match(line)
        if not mi:
            continue
        name, type_str, op, rest = mi.groups()
        comps[cur].append(Instr(name, type_str, op, rest))
        symtab[cur][name] = type_str
    return comps, symtab, entry


@dataclasses.dataclass
class Costs:
    flops: float = 0.0
    bytes: float = 0.0
    coll_bytes: float = 0.0
    coll_by_kind: dict = dataclasses.field(
        default_factory=lambda: defaultdict(float))
    coll_counts: dict = dataclasses.field(
        default_factory=lambda: defaultdict(float))

    def add(self, other: "Costs", mult: float):
        self.flops += other.flops * mult
        self.bytes += other.bytes * mult
        self.coll_bytes += other.coll_bytes * mult
        for k, v in other.coll_by_kind.items():
            self.coll_by_kind[k] += v * mult
        for k, v in other.coll_counts.items():
            self.coll_counts[k] += v * mult


class HloAnalyzer:
    def __init__(self, text: str):
        self.comps, self.symtab, self.entry = parse_module(text)
        self._memo: dict = {}
        self._slicing_memo: dict = {}

    # -- helpers ----------------------------------------------------------
    def _operand_type(self, comp, name):
        return self.symtab[comp].get(name)

    def trip_count(self, cond_comp: str) -> int:
        """Largest integer constant in the loop condition (XLA keeps the
        bound as a constant in counted loops).  Constants may appear as
        dedicated 'constant' instructions (operand was split off by the
        instruction regex) or inline."""
        best = 1
        for instr in self.comps.get(cond_comp, []):
            if instr.op == "constant":
                m = re.match(r"^\s*(-?\d+)\s*\)", instr.rest)
                if m:
                    best = max(best, int(m.group(1)))
            for c in _CONST_RE.findall(instr.type_str + " " + instr.rest):
                best = max(best, int(c))
        return best

    def _has_slicing(self, comp: str) -> bool:
        if comp not in self._slicing_memo:
            self._slicing_memo[comp] = any(
                i.op in ("dynamic-slice", "gather",
                         "dynamic-update-slice")
                for i in self.comps.get(comp, []))
        return self._slicing_memo[comp]

    def _dot_flops(self, comp, instr: Instr) -> float:
        out_elems = _elems_of(instr.type_str)
        m = _CONTRACT_RE.search(instr.rest)
        k = 1
        ops = instr.operand_names()
        if m and ops:
            lhs_t = self._operand_type(comp, ops[0])
            if lhs_t:
                shapes = _shape_info(lhs_t)
                if shapes:
                    dims = shapes[0][1]
                    for idx in (int(x) for x in m.group(1).split(",")
                                if x != ""):
                        if idx < len(dims):
                            k *= dims[idx]
        return 2.0 * out_elems * k

    # -- main walk ---------------------------------------------------------
    def comp_costs(self, comp: str) -> Costs:
        if comp in self._memo:
            return self._memo[comp]
        c = Costs()
        self._memo[comp] = c  # break cycles defensively
        for instr in self.comps.get(comp, []):
            op = instr.op
            if op == "while":
                body, cond = instr.attr("body"), instr.attr("condition")
                trips = self.trip_count(cond) if cond else 1
                if body:
                    c.add(self.comp_costs(body), trips)
                continue
            if op in ("call", "fusion", "conditional", "reduce",
                      "reduce-window", "scatter", "sort", "map",
                      "all-reduce", "reduce-scatter", "select-and-scatter",
                      "custom-call"):
                callee = instr.attr("calls") or instr.attr("to_apply")
                if callee and op in ("call", "fusion", "conditional"):
                    sub = self.comp_costs(callee)
                    # fusion internals contribute flops, not HBM bytes
                    c.flops += sub.flops
                    c.coll_bytes += sub.coll_bytes
                    for k, v in sub.coll_by_kind.items():
                        c.coll_by_kind[k] += v
                    for k, v in sub.coll_counts.items():
                        c.coll_counts[k] += v
            # flops
            if op in ("dot", "dot-general"):
                c.flops += self._dot_flops(comp, instr)
            elif op in _ELEMENTWISE_HINT:
                c.flops += _elems_of(instr.type_str)
            # collectives
            base = next((k for k in COLLECTIVES if op.startswith(k)), None)
            if base is not None and not op.endswith("-done"):
                out_b = _bytes_of(instr.type_str)
                opnd_b = sum(_bytes_of(self._operand_type(comp, o) or "")
                             for o in instr.operand_names())
                moved = max(out_b, opnd_b)
                if base == "all-reduce":
                    moved = 2 * out_b
                c.coll_bytes += moved
                c.coll_by_kind[base] += moved
                c.coll_counts[base] += 1
            # HBM bytes (top-level boundary convention, slicing-aware:
            # dynamic-slice/gather read only the slice, not the operand --
            # critical inside while loops where the full-operand convention
            # would charge the whole scan xs array once per iteration)
            if op not in _SKIP_BYTES_OPS:
                out_b = _bytes_of(instr.type_str)
                if op in ("dynamic-slice", "gather"):
                    c.bytes += 2 * out_b
                elif op == "dynamic-update-slice":
                    ops_ = instr.operand_names()
                    upd = _bytes_of(self._operand_type(comp, ops_[1]) or
                                    "") if len(ops_) > 1 else out_b
                    c.bytes += 2 * upd
                elif op == "scatter":
                    ops_ = instr.operand_names()
                    upd = _bytes_of(self._operand_type(comp, ops_[2]) or
                                    "") if len(ops_) > 2 else out_b
                    c.bytes += 3 * upd
                else:
                    b = out_b
                    slicing = False
                    callee = instr.attr("calls") or instr.attr("to_apply")
                    if op == "fusion" and callee:
                        slicing = self._has_slicing(callee)
                    for o in instr.operand_names():
                        t = self._operand_type(comp, o)
                        if not t:
                            continue
                        ob = _bytes_of(t)
                        if slicing and ob > 16 * max(out_b, 1):
                            ob = 2 * out_b  # operand is sliced, not read
                        b += ob
                    c.bytes += b
        self._memo[comp] = c
        return c

    def entry_costs(self) -> Costs:
        return self.comp_costs(self.entry)

    # -- diagnostics --------------------------------------------------------
    def comp_multipliers(self) -> dict:
        """Execution multiplier of every computation (product of enclosing
        while-loop trip counts along the call path from ENTRY)."""
        mult: dict = defaultdict(float)
        mult[self.entry] = 1.0
        order = [self.entry]
        seen = {self.entry}
        while order:
            comp = order.pop(0)
            for instr in self.comps.get(comp, []):
                subs = []
                if instr.op == "while":
                    body = instr.attr("body")
                    cond = instr.attr("condition")
                    trips = self.trip_count(cond) if cond else 1
                    if body:
                        subs.append((body, trips))
                else:
                    callee = instr.attr("calls") or instr.attr("to_apply")
                    if callee:
                        subs.append((callee, 1))
                for sub, m in subs:
                    mult[sub] += mult[comp] * m
                    if sub not in seen:
                        seen.add(sub)
                        order.append(sub)
        return dict(mult)

    def top_collectives(self, k: int = 10):
        """Largest collectives by bytes x execution multiplier -- the perf
        loop's 'profile': what to attack first."""
        mult = self.comp_multipliers()
        rows = []
        for comp, instrs in self.comps.items():
            m = mult.get(comp, 0.0)
            if m == 0.0:
                continue
            for instr in instrs:
                base = next((c for c in COLLECTIVES
                             if instr.op.startswith(c)), None)
                if base is None or instr.op.endswith("-done"):
                    continue
                out_b = _bytes_of(instr.type_str)
                total = (2 * out_b if base == "all-reduce" else out_b) * m
                rows.append((total, base, instr.type_str.strip()[:60],
                             f"x{m:.0f}", instr.name))
        rows.sort(reverse=True)
        return rows[:k]


def analyze_text(text: str) -> Costs:
    return HloAnalyzer(text).entry_costs()
