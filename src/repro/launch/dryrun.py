import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")

"""Multi-pod dry-run: prove the distribution config is coherent.

For every (architecture x input shape x mesh) combination, build the step
function with explicit in_shardings, ``.lower().compile()`` it against
ShapeDtypeStruct inputs (no allocation), and extract memory / cost /
collective analyses for the roofline table.

NOTE: the XLA_FLAGS line above MUST execute before any jax import -- jax
locks the device count on first init.  Do not set this flag globally.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch gemma2-2b \
      --shape train_4k [--multi-pod] [--mode fed|standard] [--out out.json]
  PYTHONPATH=src python -m repro.launch.dryrun --all --out results.json
"""

import argparse          # noqa: E402
import json              # noqa: E402
import time              # noqa: E402
from functools import partial  # noqa: E402

import jax               # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.configs import ARCH_IDS, SHAPES, get_config  # noqa: E402
from repro.fed import runtime, sharding  # noqa: E402
from repro.launch import roofline  # noqa: E402
from repro.launch.mesh import make_production_mesh, mesh_axis_sizes  # noqa: E402
from repro.models import model as model_lib  # noqa: E402
from repro.models.model import build_model  # noqa: E402
from repro.optim import sgd, apply_updates  # noqa: E402


# ---------------------------------------------------------------------------
# Case construction
# ---------------------------------------------------------------------------

def _ns(mesh, tree):
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), tree,
        is_leaf=lambda x: isinstance(x, P))


def build_case(arch: str, shape_name: str, mesh, mode: str = "fed",
               n_epochs: int = 4):
    """Returns (fn, arg_specs tuple, in_shardings tuple, meta dict)."""
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    ok, reason = model_lib.shape_supported(cfg, shape)
    if not ok:
        return None, None, None, {"skipped": reason}
    model = build_model(cfg)
    axes = mesh_axis_sizes(mesh)
    multi_pod = "pod" in axes
    batch_axes = ("pod", "data") if multi_pod else ("data",)
    meta = {"arch": arch, "shape": shape_name, "mode": mode,
            "mesh": dict(axes), "params": None}

    key_spec = jax.ShapeDtypeStruct((2,), jnp.uint32)
    import math
    params_shape = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    meta["params"] = sum(math.prod(l.shape)
                         for l in jax.tree_util.tree_leaves(params_shape))

    if shape.kind == "train" and mode == "fed":
        # one placement source: sharding.fed_axes / fed_state_specs /
        # fed_batch_specs (shared with build_trainer)
        agent_axis, fsdp_axis = sharding.fed_axes(axes)
        n_agents = axes[agent_axis]
        fcfg = runtime.FedConfig(n_agents=n_agents, n_epochs=n_epochs,
                                 tau=1e-3, participation=0.8)
        step = runtime.make_train_step(model, fcfg, use_remat=True)
        state_shape = jax.eval_shape(
            partial(runtime.init_state, model, fcfg=fcfg),
            jax.random.PRNGKey(0))
        state_spec = sharding.fed_state_specs(
            state_shape.x, fsdp_axis=fsdp_axis, agent_axis=agent_axis,
            axis_sizes=axes, compressed=fcfg.compression != "none")
        # batch: (A, B/A, S): per-agent batch shards over 'data' when the
        # agent axis is dedicated ('agent'/'pod'), else unsharded
        inner_axis = "data" if agent_axis != "data" else None
        batch_shape = jax.eval_shape(
            lambda: _fed_batch_specs(cfg, shape, n_agents))
        bspec = sharding.fed_batch_specs(batch_shape, agent_axis,
                                         inner_axis)
        fn = lambda state, batch, key: step(state, batch, key)
        args = (state_shape, batch_shape, key_spec)
        shardings_in = (_ns(mesh, state_spec), _ns(mesh, bspec),
                        NamedSharding(mesh, P()))
        meta["model_flops"] = roofline.model_flops(cfg, shape, "train") \
            * n_epochs
        return fn, args, shardings_in, meta

    if shape.kind == "train" and mode == "standard":
        opt = sgd(1e-2)
        pspec = sharding.param_specs(params_shape, fsdp_axis="data",
                                 axis_sizes=axes)

        def fn(params, batch, key):
            del key
            loss, grads = jax.value_and_grad(
                lambda p: model.loss_fn(p, batch=batch, remat=True))(params)
            upd, _ = opt.update(grads, (), params)
            return apply_updates(params, upd), loss

        batch_shape = model_lib.batch_specs(cfg, shape, with_labels=True)
        bspec = jax.tree_util.tree_map(
            lambda l: P(batch_axes, *([None] * (l.ndim - 1))), batch_shape)
        args = (params_shape, batch_shape, key_spec)
        shardings_in = (_ns(mesh, pspec), _ns(mesh, bspec),
                        NamedSharding(mesh, P()))
        meta["model_flops"] = roofline.model_flops(cfg, shape, "train")
        return fn, args, shardings_in, meta

    pspec = sharding.param_specs(params_shape, fsdp_axis="data",
                                 axis_sizes=axes)

    if shape.kind == "prefill":
        def fn(params, batch):
            return model.forward(params, batch=batch)[0]

        batch_shape = model_lib.batch_specs(cfg, shape, with_labels=False)
        bspec = jax.tree_util.tree_map(
            lambda l: P(batch_axes, *([None] * (l.ndim - 1))), batch_shape)
        args = (params_shape, batch_shape)
        shardings_in = (_ns(mesh, pspec), _ns(mesh, bspec))
        meta["model_flops"] = roofline.model_flops(cfg, shape, "prefill")
        return fn, args, shardings_in, meta

    # decode
    long_ctx = shape.name == "long_500k"
    cache_shape = model_lib.cache_specs(cfg, shape)
    cspec = sharding.cache_spec_tree(cache_shape, axes,
                                     data_axes=batch_axes)
    tok_shape = jax.ShapeDtypeStruct((shape.global_batch,), jnp.int32)
    data_size = 1
    for a in batch_axes:
        data_size *= axes.get(a, 1)
    tok_spec = P(batch_axes) if shape.global_batch % data_size == 0 \
        and data_size > 1 else P()

    def fn(params, cache, tokens):
        return model.decode_step(params, cache=cache, tokens=tokens,
                                 long_ctx=long_ctx)

    args = (params_shape, cache_shape, tok_shape)
    shardings_in = (_ns(mesh, pspec), _ns(mesh, cspec),
                    NamedSharding(mesh, tok_spec))
    meta["model_flops"] = roofline.model_flops(cfg, shape, "decode")
    return fn, args, shardings_in, meta


def _fed_batch_specs(cfg, shape, n_agents):
    base = model_lib.batch_specs(cfg, shape, with_labels=True)
    out = {}
    for k, v in base.items():
        out[k] = jax.ShapeDtypeStruct(
            (n_agents, v.shape[0] // n_agents) + v.shape[1:], v.dtype)
    return out


# ---------------------------------------------------------------------------
# Runner
# ---------------------------------------------------------------------------

def run_case(arch: str, shape_name: str, multi_pod: bool = False,
             mode: str = "fed", verbose: bool = True,
             mesh=None) -> dict:
    if mesh is None:
        mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = mesh.devices.size
    fn, args, shardings_in, meta = build_case(arch, shape_name, mesh,
                                              mode=mode)
    mesh_name = "2x16x16" if multi_pod else "16x16"
    result = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
              "mode": mode}
    if fn is None:
        result["status"] = "skipped"
        result["reason"] = meta["skipped"]
        if verbose:
            print(f"[dryrun] {arch} x {shape_name} x {mesh_name}: "
                  f"SKIPPED ({meta['skipped']})")
        return result

    t0 = time.time()
    try:
        with mesh:
            jitted = jax.jit(fn, in_shardings=shardings_in)
            lowered = jitted.lower(*args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower
            mem = compiled.memory_analysis()
            rl = roofline.analyze(compiled, compiled.as_text(),
                                  meta["model_flops"], n_dev)
    except Exception as e:  # noqa: BLE001 -- dry-run failures are bugs
        result["status"] = "FAILED"
        result["error"] = f"{type(e).__name__}: {e}"
        if verbose:
            print(f"[dryrun] {arch} x {shape_name} x {mesh_name}: FAILED "
                  f"{result['error']}")
        return result

    result.update({
        "status": "ok",
        "params": meta["params"],
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "roofline": rl.as_dict(),
    })
    for attr in ("temp_size_in_bytes", "argument_size_in_bytes",
                 "output_size_in_bytes", "generated_code_size_in_bytes"):
        if mem is not None and hasattr(mem, attr):
            result[f"mem_{attr}"] = int(getattr(mem, attr))
    if verbose:
        print(f"[dryrun] {arch} x {shape_name} x {mesh_name} [{mode}]: OK "
              f"compile={t_compile:.0f}s "
              f"compute={rl.compute_s:.3e}s memory={rl.memory_s:.3e}s "
              f"collective={rl.collective_s:.3e}s -> {rl.bottleneck}; "
              f"args/dev={result.get('mem_argument_size_in_bytes', 0)/1e9:.2f}GB "
              f"temp/dev={result.get('mem_temp_size_in_bytes', 0)/1e9:.2f}GB")
        print(f"          memory_analysis: {mem}")
        print(f"          cost_analysis: flops/dev={rl.flops:.3e} "
              f"bytes/dev={rl.hbm_bytes:.3e} "
              f"coll_bytes/dev={rl.coll_bytes:.3e} "
              f"counts={rl.coll_detail['counts']}")
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--mode", default="fed", choices=["fed", "standard"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    archs = ARCH_IDS if args.all or args.arch is None else [args.arch]
    shapes = list(SHAPES) if args.all or args.shape is None \
        else [args.shape]
    meshes = [False, True] if (args.both_meshes or args.all) \
        else [args.multi_pod]

    results = []
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                results.append(run_case(arch, shape, multi_pod=mp,
                                        mode=args.mode))
                if args.out:
                    with open(args.out, "w") as f:
                        json.dump(results, f, indent=1)
    n_ok = sum(r["status"] == "ok" for r in results)
    n_skip = sum(r["status"] == "skipped" for r in results)
    n_fail = sum(r["status"] == "FAILED" for r in results)
    print(f"[dryrun] done: {n_ok} ok, {n_skip} skipped, {n_fail} failed")
    return 1 if n_fail else 0


if __name__ == "__main__":
    raise SystemExit(main())
