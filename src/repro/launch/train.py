"""Training driver.

Runs Fed-PLT (default) or standard FSDP training of any assigned
architecture on the local devices (smoke/real) -- the multi-pod
configuration is exercised by dryrun.py.

Examples:
  PYTHONPATH=src python -m repro.launch.train --arch gemma2-2b --smoke \
      --steps 20 --mode fed
  PYTHONPATH=src python -m repro.launch.train --arch qwen2-moe-a2.7b \
      --smoke --steps 10 --mode standard --optimizer adamw
"""

from __future__ import annotations

import argparse
import os
import time

import jax
import numpy as np

from repro.checkpoint import (checkpoint_extra, find_latest_checkpoint,
                              save_checkpoint, restore_checkpoint)
from repro.configs import get_config
from repro.configs.base import InputShape
from repro.data.synthetic import make_batch_for
from repro.fed import api
from repro.models.model import build_model
from repro.optim import adamw, apply_updates, momentum, sgd


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--mode", default="fed", choices=["fed", "standard"])
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (2 layers, d_model 256)")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--local-dataset-size", type=int, default=None,
                    help="smallest local dataset size q_i for the "
                         "privacy report (default: per-agent batch)")
    ap.add_argument("--optimizer", default="sgd",
                    choices=["sgd", "momentum", "adamw"])
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--checkpoint", default=None)
    ap.add_argument("--checkpoint-every", type=int, default=0,
                    help="fed mode: save the round state to "
                         "<checkpoint>/rounds/step-NNNNNN every N rounds "
                         "(atomic tmp-then-rename saves; 0 = off)")
    ap.add_argument("--resume", action="store_true",
                    help="fed mode: resume from the latest committed "
                         "round checkpoint under <checkpoint>/rounds "
                         "(bit-for-bit: per-round keys are derived by "
                         "fold_in, so the continued run matches an "
                         "uninterrupted one)")
    # every fed knob is generated from the FedSpec fields -- new spec
    # fields / registered compressors become flags without edits here
    api.add_spec_args(ap)
    args = ap.parse_args()

    spec = api.spec_from_args(args)
    if args.mode == "fed":
        spec.validate()      # fail fast, before building the model
    if (args.checkpoint_every or args.resume) and not args.checkpoint:
        ap.error("--checkpoint-every/--resume require --checkpoint")
    if (args.checkpoint_every or args.resume) and args.mode != "fed":
        ap.error("--checkpoint-every/--resume are fed-mode only")

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.reduced()
    model = build_model(cfg)
    shape = InputShape("cli", args.seq_len, args.batch, "train")
    key = jax.random.PRNGKey(0)

    if args.mode == "fed":
        trainer = api.build_trainer(model, spec)
        # --agent-shards / --mesh-shape are generated spec flags; the
        # trainer builds the (agent, model) round mesh from them
        mesh = getattr(trainer, "mesh", None)
        if mesh is not None:
            sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
            print(f"mesh: {sizes} over {mesh.devices.size} devices "
                  f"(agent axis sharded)")
        if spec.privacy.tau > 0:
            # every DP run states its (eps, delta) position up front
            # make_batch_for splits the global batch across agents
            q = args.local_dataset_size or max(1, args.batch
                                               // spec.n_agents)
            rep = trainer.privacy_report(args.steps, q)
            caveat = "" if spec.privacy.clip is not None else \
                " (UNCLIPPED: per-sample sensitivity assumed 1.0 -- " \
                "pass --clip)"
            print(f"privacy: ({rep.adp_eps:.3f}, {rep.adp_delta:.0e})-ADP"
                  f" over K={rep.K} rounds x N_e={rep.n_epochs};"
                  f" ceiling as K*Ne->inf: eps={rep.eps_ceiling:.3f}"
                  f" at Renyi order {rep.rdp_order:.1f}{caveat}")
            if rep.per_agent:
                # heterogeneous run: the headline eps above is the max
                # over this per-agent (eps_i, delta) table (Prop. 4)
                for a in rep.per_agent:
                    print(f"  agent {a.agent:3d}: q_i={a.q} "
                          f"N_e={a.n_epochs} gamma={a.gamma:.4g} "
                          f"eps_i={a.adp_eps:.3f} "
                          f"(ceiling {a.eps_ceiling:.3f})")
        state = trainer.init(key)
        stale = spec.async_mode != "off"
        arrival_rows = []   # realized (N,) rows -> the run's schedule
        start_round = 0
        rounds_dir = (os.path.join(args.checkpoint, "rounds")
                      if args.checkpoint else None)
        if args.resume:
            latest = find_latest_checkpoint(rounds_dir)
            if latest is None:
                print(f"resume: no committed checkpoint under "
                      f"{rounds_dir} -- starting from round 0")
            else:
                shards = (trainer._state_shardings()
                          if mesh is not None else None)
                state = restore_checkpoint(latest, state, shards)
                meta_extra = checkpoint_extra(latest) or {}
                start_round = int(meta_extra.get("round", 0))
                arrival_rows = [np.asarray(r, np.float32)
                                for r in meta_extra.get("arrivals", [])]
                print(f"resumed from {latest} at round {start_round}")
        for i in range(start_round, args.steps):
            batch = make_batch_for(cfg, shape, jax.random.fold_in(key, i),
                                   n_agents=spec.n_agents)
            t0 = time.time()
            state, metrics = trainer.step(state, batch,
                                          jax.random.fold_in(key, i))
            extra = ""
            if stale:
                arrival_rows.append(np.asarray(metrics["arrivals"]))
                extra = f" stale={float(metrics['staleness']):.2f}"
            print(f"round {i:4d} loss={float(metrics['loss']):.4f} "
                  f"part={float(metrics['participation']):.2f}{extra} "
                  f"dt={time.time() - t0:.2f}s")
            if (args.checkpoint_every
                    and (i + 1) % args.checkpoint_every == 0):
                ck = os.path.join(rounds_dir, f"step-{i + 1:06d}")
                save_checkpoint(
                    ck, state, step=i + 1,
                    extra={"round": i + 1,
                           "arrivals": [np.asarray(r).tolist()
                                        for r in arrival_rows]})
                print(f"  checkpointed round {i + 1} -> {ck}")
        if stale and spec.privacy.tau > 0 and arrival_rows:
            # the nominal table above charged every agent the full K
            # rounds; recompose over the REALIZED arrival schedule --
            # each agent over the rounds of local work it released
            q = args.local_dataset_size or max(1, args.batch
                                               // spec.n_agents)
            rep = api.effective_privacy_report(
                spec, np.stack(arrival_rows), q)
            print(f"effective privacy (realized arrival schedule, "
                  f"max_staleness={spec.max_staleness}): "
                  f"({rep.adp_eps:.3f}, {rep.adp_delta:.0e})-ADP")
            for a in rep.per_agent:
                print(f"  agent {a.agent:3d}: arrivals={a.arrivals} "
                      f"released_rounds={a.K}/{rep.K} "
                      f"eps_i={a.adp_eps:.3f} "
                      f"(ceiling {a.eps_ceiling:.3f})")
        final = trainer.consensus(state)
    else:
        params = model.init(key)
        opt = {"sgd": sgd(args.lr), "momentum": momentum(args.lr),
               "adamw": adamw(args.lr)}[args.optimizer]
        opt_state = opt.init(params)

        @jax.jit
        def step(params, opt_state, batch):
            loss, grads = jax.value_and_grad(
                lambda p: model.loss_fn(p, batch=batch))(params)
            upd, opt_state = opt.update(grads, opt_state, params)
            return apply_updates(params, upd), opt_state, loss

        for i in range(args.steps):
            batch = make_batch_for(cfg, shape, jax.random.fold_in(key, i))
            t0 = time.time()
            params, opt_state, loss = step(params, opt_state, batch)
            print(f"step {i:4d} loss={float(loss):.4f} "
                  f"dt={time.time() - t0:.2f}s")
        final = params

    if args.checkpoint:
        target = args.checkpoint
        if args.mode == "fed" and (args.checkpoint_every or args.resume):
            # rolling round checkpoints live under <checkpoint>/rounds;
            # save_checkpoint atomically REPLACES its target directory,
            # so the consensus save gets a sibling entry instead of
            # clobbering the whole tree
            target = os.path.join(args.checkpoint, "consensus")
        save_checkpoint(target, final, step=args.steps)
        print(f"saved checkpoint to {target}")
    n = sum(x.size for x in jax.tree_util.tree_leaves(final))
    print(f"done: {args.arch} ({n/1e6:.2f}M params)")


if __name__ == "__main__":
    main()
