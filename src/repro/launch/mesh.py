"""Production meshes.

Defined as FUNCTIONS (not module-level constants) so importing this module
never touches jax device state; ``dryrun.py`` sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import to obtain placeholder devices.

  single-pod: (data=16, model=16)            -- 256 chips (v5e pod)
  multi-pod : (pod=2, data=16, model=16)     -- 512 chips
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_fed_mesh(n_agents: int = 4, *, multi_pod: bool = False):
    """Single-pod mesh with a DEDICATED agent axis: (agent, data, model).

    Beyond-paper optimization (EXPERIMENTS.md Perf, grok iteration): the
    default fed mapping uses the whole 'data' axis as the agent axis,
    which starves 2D-hungry layers (MoE capacity x ff) of a token axis
    and triggers GSPMD involuntary full rematerialization.  Splitting
    16 = n_agents x (16 / n_agents) restores it.
    """
    assert 16 % n_agents == 0
    if multi_pod:
        return jax.make_mesh((2 * n_agents, 16 // n_agents, 16),
                             ("agent", "data", "model"))
    return jax.make_mesh((n_agents, 16 // n_agents, 16),
                         ("agent", "data", "model"))


def make_host_mesh(model: int = 1):
    """Tiny mesh on the real local devices (tests / examples)."""
    n = len(jax.devices())
    return jax.make_mesh((n // model, model), ("data", "model"))


def mesh_axis_sizes(mesh) -> dict:
    return dict(zip(mesh.axis_names, mesh.devices.shape))
