"""Production meshes.

Defined as FUNCTIONS (not module-level constants) so importing this module
never touches jax device state; ``dryrun.py`` sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import to obtain placeholder devices.

  single-pod: (data=16, model=16)            -- 256 chips (v5e pod)
  multi-pod : (pod=2, data=16, model=16)     -- 512 chips
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_fed_mesh(n_agents: int = 4, *, multi_pod: bool = False):
    """Mesh with a DEDICATED agent axis: (agent, data, model).

    Beyond-paper optimization (EXPERIMENTS.md Perf, grok iteration): the
    default fed mapping uses the whole 'data' axis as the agent axis,
    which starves 2D-hungry layers (MoE capacity x ff) of a token axis
    and triggers GSPMD involuntary full rematerialization.  A dedicated
    agent axis restores it.

    Shapes are derived from the visible device count (multi-pod doubles
    the agent extent, mirroring the historical 512-chip layout): the
    remainder after the agent axis splits into the largest power-of-two
    'model' extent <= 16, with 'data' taking the rest.
    """
    if n_agents < 1:
        raise ValueError(f"n_agents must be >= 1, got {n_agents}")
    agents = 2 * n_agents if multi_pod else n_agents
    n_dev = len(jax.devices())
    if n_dev % agents != 0:
        raise ValueError(
            f"fed mesh needs the device count to be divisible by the "
            f"agent extent {agents} ({'2*' if multi_pod else ''}"
            f"n_agents), but {n_dev} devices are visible -- on CPU set "
            f"XLA_FLAGS=--xla_force_host_platform_device_count to a "
            f"multiple before importing jax")
    rest = n_dev // agents
    model = 1
    while model < 16 and rest % (model * 2) == 0:
        model *= 2
    data = rest // model
    return jax.make_mesh((agents, data, model),
                         ("agent", "data", "model"))


def make_host_mesh(model: int = 1):
    """Tiny mesh on the real local devices (tests / examples)."""
    n = len(jax.devices())
    return jax.make_mesh((n // model, model), ("data", "model"))


def mesh_axis_sizes(mesh) -> dict:
    return dict(zip(mesh.axis_names, mesh.devices.shape))
