"""Roofline analysis from compiled dry-run artifacts.

Three terms per (arch x shape x mesh), in seconds (per-device basis; the
SPMD module is per-device, so dividing the global quantities by `chips`
and using per-device HLO numbers coincide when the program is balanced):

    compute    = HLO_FLOPs_per_device / peak_FLOPs
    memory     = HLO_bytes_per_device / HBM_bw
    collective = collective_bytes_per_device / ICI_bw

``collective_bytes`` is NOT in cost_analysis: we parse the post-SPMD HLO
text and sum operand sizes of every all-gather / all-reduce /
reduce-scatter / all-to-all / collective-permute op.

Hardware model (TPU v5e): 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.
"""

from __future__ import annotations

import dataclasses
import re

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "bf16": 2, "f16": 2, "f32": 4, "f64": 8,
    "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# e.g.  "%ag = bf16[2,16,128]{2,1,0} all-gather(bf16[2,1,128]{2,1,0} %x), ..."
_OP_RE = re.compile(
    r"=\s*(?:\([^)]*\)|([a-z0-9]+)\[([0-9,]*)\][^ ]*)\s+([a-z0-9-]+)\(")
_OPERAND_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    if dtype not in _DTYPE_BYTES:
        return 0
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def collective_bytes(hlo_text: str) -> dict:
    """Sum of operand bytes per collective kind (per-device)."""
    out = {k: 0 for k in _COLLECTIVES}
    counts = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        stripped = line.strip()
        m = _OP_RE.search(stripped)
        if not m:
            continue
        op = m.group(3)
        # 'all-reduce-start' etc. normalize
        base = next((c for c in _COLLECTIVES if op.startswith(c)), None)
        if base is None:
            continue
        # operands: everything inside the call parens
        call = stripped[m.end():]
        depth, end = 1, 0
        for i, ch in enumerate(call):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    end = i
                    break
        operands = call[:end]
        b = sum(_shape_bytes(dt, dims)
                for dt, dims in _OPERAND_RE.findall(operands))
        out[base] += b
        counts[base] += 1
    out_total = sum(out.values())
    return {"per_kind": out, "counts": counts, "total": out_total}


@dataclasses.dataclass
class Roofline:
    flops: float                 # per-device (trip-count-aware)
    hbm_bytes: float             # per-device (trip-count-aware)
    coll_bytes: float            # per-device (trip-count-aware)
    coll_detail: dict
    compute_s: float
    memory_s: float
    collective_s: float
    bottleneck: str
    model_flops: float           # 6*N*D analytic (global)
    useful_ratio: float          # model_flops_per_device / hlo_flops
    xla_flops: float = 0.0       # raw cost_analysis (loop bodies x1)
    xla_bytes: float = 0.0

    def as_dict(self):
        return dataclasses.asdict(self)


def analyze(compiled, lowered_text: str | None, model_flops: float,
            n_devices: int) -> Roofline:
    """Derive the three terms.  FLOPs/bytes/collectives come from the
    trip-count-aware HLO analyzer (XLA's cost_analysis counts while-loop
    bodies once -- see hlo_analysis.py); raw XLA numbers are kept as a
    cross-check."""
    from repro.launch import hlo_analysis

    cost = compiled.cost_analysis()
    if isinstance(cost, list):   # older jax returns [dict]
        cost = cost[0]
    xla_flops = float(cost.get("flops", 0.0))
    xla_bytes = float(cost.get("bytes accessed", 0.0))
    text = lowered_text if lowered_text is not None else compiled.as_text()
    costs = hlo_analysis.analyze_text(text)
    flops = max(costs.flops, xla_flops)
    hbm = max(costs.bytes, xla_bytes)
    compute_s = flops / PEAK_FLOPS
    memory_s = hbm / HBM_BW
    collective_s = costs.coll_bytes / ICI_BW
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": collective_s}
    bottleneck = max(terms, key=terms.get)
    mf_dev = model_flops / max(n_devices, 1)
    return Roofline(
        flops=flops, hbm_bytes=hbm, coll_bytes=float(costs.coll_bytes),
        coll_detail={"per_kind": dict(costs.coll_by_kind),
                     "counts": dict(costs.coll_counts)},
        compute_s=compute_s, memory_s=memory_s,
        collective_s=collective_s, bottleneck=bottleneck,
        model_flops=model_flops,
        useful_ratio=(mf_dev / flops) if flops else 0.0,
        xla_flops=xla_flops, xla_bytes=xla_bytes)


# ---------------------------------------------------------------------------
# Analytic MODEL_FLOPS (6 N D for dense; 6 N_active D for MoE)
# ---------------------------------------------------------------------------

def active_param_count(cfg) -> int:
    """Parameters touched per token (routed experts counted top_k/E)."""
    d, ff, L = cfg.d_model, cfg.d_ff, cfg.n_layers
    hd = cfg.resolved_head_dim
    attn = d * hd * (cfg.n_heads * 2 + cfg.n_kv_heads * 2)
    gated = cfg.activation in ("swiglu", "geglu")
    per_ff = d * ff * (3 if gated else 2)
    total = 0
    kinds = cfg.layer_kinds()
    for kind in kinds:
        if kind == "ssm":
            d_in, n, r = cfg.d_inner, cfg.ssm_state, cfg.resolved_dt_rank
            total += d * 2 * d_in + d_in * (r + 2 * n) + r * d_in \
                + d_in * n + d_in * d
        elif kind == "rec":
            w = cfg.resolved_lru_width
            total += 2 * d * w + 2 * w * w + w * d + per_ff
        else:
            total += attn
            if cfg.n_experts:
                e_ff = cfg.moe_d_ff * (3 if gated else 2) * d
                total += cfg.top_k * e_ff \
                    + cfg.n_shared_experts * e_ff + d * cfg.n_experts
            else:
                total += per_ff
    if cfg.n_enc_layers:
        total += cfg.n_enc_layers * (attn + per_ff) \
            + cfg.n_layers * attn          # cross attention
    total += cfg.vocab * d * (1 if cfg.tie_embeddings else 2)
    return total


def model_flops(cfg, shape, mode: str) -> float:
    """6 N D (train), 2 N D (prefill/forward), 2 N per token (decode)."""
    n_active = active_param_count(cfg)
    if mode == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if mode == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    # decode: one token per sequence
    return 2.0 * n_active * shape.global_batch
