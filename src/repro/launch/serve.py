"""Serving driver: batched prefill (via scan-decode) + decode loop.

Small-scale runnable server loop exercising the same serve_step the
dry-run lowers at production shapes.

  PYTHONPATH=src python -m repro.launch.serve --arch gemma2-2b --smoke \
      --prompt-len 16 --gen-len 16 --batch 4
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models.model import build_model


def prefill_via_decode(model, params, cache, prompt):
    """Feed prompt tokens through decode_step via lax.scan (exact same
    cache semantics as serving; production prefill uses the parallel
    forward path)."""
    def body(cache, tok):
        logits, cache = model.decode_step(params, cache=cache, tokens=tok)
        return cache, logits

    cache, logits = jax.lax.scan(body, cache, prompt.T)  # scan over time
    return cache, logits[-1]


def generate(model, params, prompts, gen_len, cache_len, temperature=0.0,
             key=None):
    B = prompts.shape[0]
    cache = model.init_cache(batch=B, cache_len=cache_len)
    cache, logits = prefill_via_decode(model, params, cache, prompts)
    decode = jax.jit(lambda p, c, t: model.decode_step(p, cache=c,
                                                       tokens=t))
    toks = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    out = [toks]
    for i in range(gen_len - 1):
        logits, cache = decode(params, cache, toks)
        if temperature > 0 and key is not None:
            key, k = jax.random.split(key)
            toks = jax.random.categorical(k, logits / temperature)
            toks = toks.astype(jnp.int32)
        else:
            toks = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        out.append(toks)
    return jnp.stack(out, axis=1)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen-len", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.reduced()
    model = build_model(cfg)
    key = jax.random.PRNGKey(0)
    params = model.init(key)
    prompts = jax.random.randint(key, (args.batch, args.prompt_len), 0,
                                 cfg.vocab, jnp.int32)
    t0 = time.time()
    out = generate(model, params, prompts,
                   gen_len=args.gen_len,
                   cache_len=args.prompt_len + args.gen_len,
                   temperature=args.temperature, key=key)
    dt = time.time() - t0
    tps = args.batch * args.gen_len / dt
    print(f"generated {out.shape} tokens in {dt:.2f}s ({tps:.1f} tok/s)")
    print("sample:", out[0][:12].tolist())


if __name__ == "__main__":
    main()
