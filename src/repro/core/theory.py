"""Convergence theory of Fed-PLT (paper Section V).

Implements:
  * chi (Lemma 2) and zeta (Lemma 3) contraction factors,
  * the 2x2 matrix S of Proposition 1 (and S' of Proposition 3),
  * sigma = sqrt(1 - p + p ||S||^2) of Proposition 2,
  * the Lemma-7 stabilizing parameter search (cheap 2x2 grid search),
  * the Corollary-1 privacy/accuracy bound.
"""

from __future__ import annotations

import dataclasses
import itertools

import numpy as np

from repro.core.solvers import SolverConfig, solver_contraction


# ---------------------------------------------------------------------------
# Elementary contraction factors
# ---------------------------------------------------------------------------

def chi_gd(gamma: float, mu_d: float, L_d: float) -> float:
    """GD contraction factor (Lemma 2) on a mu_d-s.c., L_d-smooth function."""
    return max(abs(1.0 - gamma * mu_d), abs(1.0 - gamma * L_d))


def zeta_prs(rho: float, mu: float, L: float) -> float:
    """PRS contraction factor (Lemma 3)."""
    return max(abs((1.0 - rho * L) / (1.0 + rho * L)),
               abs((1.0 - rho * mu) / (1.0 + rho * mu)))


# ---------------------------------------------------------------------------
# Proposition 1 machinery
# ---------------------------------------------------------------------------

def s_matrix(chi_total: float, zeta: float, mu: float, rho: float) -> np.ndarray:
    """The matrix S of Proposition 1.

    ``chi_total`` is the contraction of the *whole* local-training map
    (chi^{N_e} for GD, chi(N_e) for AGD -- Proposition 3 uses the same
    template).
    """
    mu_d = mu + 1.0 / rho
    return np.array([
        [chi_total, (1.0 + chi_total) / mu_d],
        [2.0 * chi_total, zeta + 2.0 * chi_total / mu_d],
    ])


def s_norm(cfg_or_chi, mu: float, L: float, rho: float,
           solver: SolverConfig | None = None) -> float:
    """Spectral norm ||S|| -- upper bound on Fed-PLT's contraction rate."""
    if isinstance(cfg_or_chi, (int, float)):
        chi_total = float(cfg_or_chi)
    else:
        solver = cfg_or_chi
        chi_total = solver_contraction(solver, mu, L, rho)
    zeta = zeta_prs(rho, mu, L)
    S = s_matrix(chi_total, zeta, mu, rho)
    return float(np.linalg.norm(S, 2))


def sigma(p_min: float, p_max: float, s_nrm: float) -> float:
    """Stochastic rate of Proposition 2 (partial participation)."""
    del p_max
    return float(np.sqrt(max(0.0, 1.0 - p_min + p_min * s_nrm ** 2)))


def is_stable(cfg: SolverConfig, mu: float, L: float, rho: float) -> bool:
    """Spectral-radius stability of S (Prop. 1 requires a stable S)."""
    chi_total = solver_contraction(cfg, mu, L, rho)
    S = s_matrix(chi_total, zeta_prs(rho, mu, L), mu, rho)
    return bool(np.max(np.abs(np.linalg.eigvals(S))) < 1.0)


# ---------------------------------------------------------------------------
# Lemma 7: a stabilizing choice of parameters always exists -- find one
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class StabilizeResult:
    rho: float
    gamma: float
    n_epochs: int
    s_norm: float
    spectral_radius: float


def stabilize(mu: float, L: float, solver_name: str = "gd",
              n_epochs_grid=(1, 2, 5, 8, 10, 20),
              rho_grid=None, gamma_grid=None) -> StabilizeResult:
    """Grid search over (rho, gamma, N_e) minimizing spectral radius of S.

    S is 2x2 regardless of problem size (paper Section V-A), so this is
    computationally trivial -- exactly the tuning loop the paper suggests.
    """
    if rho_grid is None:
        rho_grid = np.geomspace(0.01, 100.0, 25)
    best = None
    for rho, ne in itertools.product(rho_grid, n_epochs_grid):
        mu_d, L_d = mu + 1.0 / rho, L + 1.0 / rho
        gammas = (gamma_grid if gamma_grid is not None
                  else [2.0 / (mu_d + L_d), 1.0 / L_d, 0.5 / L_d])
        for gamma in gammas:
            cfg = SolverConfig(name=solver_name, n_epochs=ne, step_size=gamma)
            chi_total = solver_contraction(cfg, mu, L, rho)
            S = s_matrix(chi_total, zeta_prs(rho, mu, L), mu, rho)
            sr = float(np.max(np.abs(np.linalg.eigvals(S))))
            nrm = float(np.linalg.norm(S, 2))
            if best is None or sr < best.spectral_radius:
                best = StabilizeResult(rho=float(rho), gamma=float(gamma),
                                       n_epochs=int(ne), s_norm=nrm,
                                       spectral_radius=sr)
    return best


# ---------------------------------------------------------------------------
# Corollary 1: accuracy under DP noise
# ---------------------------------------------------------------------------

def corollary1_bound(K: int, mu: float, L: float, rho: float, gamma: float,
                     n_epochs: int, tau: float, dim: int, n_agents: int,
                     r0: float) -> float:
    """Expected distance bound of Corollary 1 after K rounds.

    r0 = || [x_0 - x_bar; z_0 - z_bar] ||.
    """
    mu_d, L_d = mu + 1.0 / rho, L + 1.0 / rho
    chi = chi_gd(gamma, mu_d, L_d)
    chi_total = chi ** n_epochs
    S = s_matrix(chi_total, zeta_prs(rho, mu, L), mu, rho)
    nrm = float(np.linalg.norm(S, 2))
    geo = (1.0 - chi_total) / (1.0 - chi) if chi < 1.0 else float(n_epochs)
    noise = tau * np.sqrt(10.0 * dim * n_agents * gamma) * geo
    if nrm >= 1.0:
        return float("inf")
    return float(nrm ** K * r0 + (1.0 - nrm ** K) / (1.0 - nrm) * noise)


def asymptotic_error(mu: float, L: float, rho: float, gamma: float,
                     n_epochs: int, tau: float, dim: int,
                     n_agents: int) -> float:
    """K -> inf limit of Corollary 1 (the asymptotic error neighbourhood)."""
    return corollary1_bound(10 ** 9, mu, L, rho, gamma, n_epochs, tau,
                            dim, n_agents, r0=0.0)
