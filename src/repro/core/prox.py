"""Proximal and reflective operators (paper Definition 3).

All operators are pure jnp functions ``(y, rho) -> x`` with
``prox_{rho f}(y) = argmin_x f(x) + ||x - y||^2 / (2 rho)``.

The coordinator step of Fed-PLT (Lemma 6) is
``prox_{rho g}(z) = 1_N (x) prox_{rho h / N}(mean_i z_i)`` -- implemented in
:func:`coordinator_prox`.
"""

from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

ProxFn = Callable[[jnp.ndarray, float], jnp.ndarray]


def _elementwise(fn):
    """Tag a prox as elementwise: applied independently per coordinate
    with only static parameters.  The round engine reads this tag to
    decide whether the prox may be traced into the fused round-edge
    Pallas kernel (:mod:`repro.kernels.round_edge`); untagged custom
    callables always take the XLA path."""
    fn.elementwise = True
    return fn


def _pin_scale(c, like):
    """A shrinkage factor as an XLA-OPAQUE scalar of ``like``'s dtype.

    The algebraic simplifier folds adjacent multiplicative constants
    (the agent-mean's 1/N, step sizes, a scan-fused criterion) into the
    prox scale -- and whether it does depends on the surrounding
    program and even on array shapes, so the same prox would round
    differently in the unfused engine path, the fused round-edge
    kernel, and a scan body.  Hiding the scale behind an optimization
    barrier makes the shrinkage exactly ``round(y * c)`` in every
    context, which is what keeps ``engine_backend`` trajectories
    bit-identical."""
    return jax.lax.optimization_barrier(
        jnp.asarray(c, jnp.result_type(like)))


# ---------------------------------------------------------------------------
# Elementary proximal operators
# ---------------------------------------------------------------------------

@_elementwise
def prox_zero(y: jnp.ndarray, rho: float) -> jnp.ndarray:
    """prox of h = 0 (smooth problems): identity."""
    del rho
    return y


@_elementwise
def prox_l1(y: jnp.ndarray, rho: float) -> jnp.ndarray:
    """Soft-thresholding: prox of h(x) = ||x||_1."""
    return jnp.sign(y) * jnp.maximum(jnp.abs(y) - rho, 0.0)


@_elementwise
def prox_l2sq(y: jnp.ndarray, rho: float) -> jnp.ndarray:
    """prox of h(x) = ||x||^2 / 2: shrinkage.

    Multiplication by the PINNED reciprocal, NOT division: XLA rewrites
    division-by-constant into reciprocal multiplies fusion-dependently,
    and folds a bare multiplicative constant into its neighbors (see
    :func:`_pin_scale`) -- either would make the shrinkage's bits
    depend on the surrounding program and break the bitwise parity
    between the per-leaf and fused round-edge backends.  Same for every
    shrinking prox below."""
    return y * _pin_scale(1.0 / (1.0 + rho), y)


@_elementwise
def prox_weight_decay(y: jnp.ndarray, rho: float,
                      weight: float = 0.0) -> jnp.ndarray:
    """prox of h(x) = (weight/2) ||x||^2: shrinkage by 1/(1 + weight rho).

    The model-scale coordinator's weight decay -- registered here so the
    dense and model front ends share one ProxH convention (weight = 0 is
    the identity, i.e. h = 0)."""
    return y * _pin_scale(1.0 / (1.0 + weight * rho), y)


@_elementwise
def prox_elastic_net(y: jnp.ndarray, rho: float, l1: float = 1.0,
                     l2: float = 1.0) -> jnp.ndarray:
    """prox of h(x) = l1 ||x||_1 + (l2/2) ||x||^2."""
    return prox_l1(y, rho * l1) * _pin_scale(1.0 / (1.0 + rho * l2), y)


@_elementwise
def prox_box(y: jnp.ndarray, rho: float, lo: float = -1.0,
             hi: float = 1.0) -> jnp.ndarray:
    """prox of the indicator of a box = projection (rho-independent)."""
    del rho
    return jnp.clip(y, lo, hi)


@_elementwise
def prox_linf_ball(y: jnp.ndarray, rho: float, radius: float = 1.0):
    """Projection onto the l-inf ball."""
    del rho
    return jnp.clip(y, -radius, radius)


def make_prox(name: str, **kw) -> ProxFn:
    table = {
        "zero": prox_zero,
        "l1": prox_l1,
        "l2sq": prox_l2sq,
        "weight_decay": prox_weight_decay,
        "elastic_net": prox_elastic_net,
        "box": prox_box,
        "linf_ball": prox_linf_ball,
    }
    fn = table.get(name)
    if fn is None:
        raise ValueError(f"unknown prox {name!r}; registered: "
                         f"{', '.join(sorted(table))}")
    if kw:
        def bound(y, rho):
            return fn(y, rho, **kw)
        # binding static kwargs preserves elementwise-ness (the fused
        # round-edge kernel eligibility travels with the callable)
        bound.elementwise = getattr(fn, "elementwise", False)
        return bound
    return fn


# ---------------------------------------------------------------------------
# Derived operators
# ---------------------------------------------------------------------------

def reflect(prox: ProxFn) -> ProxFn:
    """Reflective operator refl_{rho f}(y) = 2 prox_{rho f}(y) - y.

    The reflection formula itself lives in the round engine
    (:func:`repro.fed.engine.reflect` -- the single source of the round
    topology); this combinator evaluates it on a single-agent stack.
    """

    def refl(y: jnp.ndarray, rho: float) -> jnp.ndarray:
        from repro.fed import engine

        return engine.reflect(prox(y, rho), y[None])[0]

    return refl


def moreau_conjugate(prox: ProxFn) -> ProxFn:
    """prox of the convex conjugate via the Moreau identity:

    ``prox_{rho f*}(y) = y - rho prox_{f / rho}(y / rho)``.
    """

    def prox_star(y: jnp.ndarray, rho: float) -> jnp.ndarray:
        return y - rho * prox(y / rho, 1.0 / rho)

    return prox_star


def prox_of_smooth(grad_fn, y: jnp.ndarray, rho: float, steps: int = 50,
                   step_size: float | None = None,
                   smoothness: float = 1.0) -> jnp.ndarray:
    """Approximate prox of a smooth f by gradient descent on
    ``d(x) = f(x) + ||x - y||^2 / (2 rho)`` (used when h is not proximable;
    the induced error is the additive noise allowed by Prop. 2)."""
    if step_size is None:
        step_size = 1.0 / (smoothness + 1.0 / rho)

    def body(x, _):
        g = grad_fn(x) + (x - y) / rho
        return x - step_size * g, None

    x, _ = jax.lax.scan(body, y, None, length=steps)
    return x


# ---------------------------------------------------------------------------
# Fed-PLT coordinator step (paper Lemma 6)
# ---------------------------------------------------------------------------

def coordinator_prox(z: jnp.ndarray, rho: float, prox_h: ProxFn) -> jnp.ndarray:
    """``y = prox_{rho h / N}(mean_i z_i)`` for stacked ``z`` of shape (N, n).

    Returns the (single, shared) coordinator model y of shape (n,).
    Back-compat re-export: the implementation is
    :func:`repro.fed.engine.coordinator_prox` (the single source of the
    round topology), of which the dense array is the single-leaf case.
    """
    from repro.fed import engine

    return engine.coordinator_prox(
        z, engine.RoundConfig(n_agents=z.shape[0], rho=rho), prox_h)
