"""Fed-PLT -- Algorithm 1 of the paper, vectorized over agents.

This is the paper-faithful *dense* front end: local states are a single
``(N, n)`` array, i.e. the single-leaf case of the unified round engine
in :mod:`repro.fed.engine`, which owns the round topology (coordinator
prox -> reflection -> warm-started local solver -> Bernoulli
participation -> optional compressed z-exchange).  This class only
supplies the per-agent gradient oracles, curvature moduli, and the
``lax.scan`` training loop that records the paper's convergence
criterion.
"""

from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core import prox as prox_lib
from repro.core.solvers import SolverConfig, local_train
from repro.fed import engine


class FedPLTState(NamedTuple):
    x: jnp.ndarray      # (N, n) local models
    z: jnp.ndarray      # (N, n) auxiliary (PRS) variables
    y: jnp.ndarray      # (n,)  coordinator model (last broadcast)
    key: jax.Array
    k: jnp.ndarray      # round counter
    # coordinator's copy of each z_i; lags z by the never-transmitted
    # residual when the exchange is compressed.  None when uncompressed:
    # the coordinator then sees z exactly and a separate copy would just
    # double z-memory.
    t: Optional[jnp.ndarray] = None
    # bounded-staleness async rounds only (None when synchronous):
    # per-agent pulled coordinator point and staleness counters (the
    # carry of repro.fed.async_engine.async_round_step)
    y_tag: Optional[jnp.ndarray] = None     # (N, n)
    staleness: Optional[jnp.ndarray] = None  # (N,) int32


@dataclasses.dataclass(frozen=True)
class FedPLTConfig:
    rho: float = 1.0
    solver: SolverConfig = dataclasses.field(default_factory=SolverConfig)
    participation: float = 1.0        # p (uniform across agents)
    prox_h: str = "zero"              # coordinator regularizer
    batch_size: Optional[int] = None  # for sgd oracle
    # curvature moduli of the f_i; None -> taken from the problem
    mu: Optional[float] = None
    L: Optional[float] = None
    dp_init: bool = False             # x0 ~ N(0, 2 tau^2/mu I)  (Prop. 4)
    # Remark 1 (uncoordinated solvers): per-agent step sizes tuned to the
    # LOCAL moduli (mu_i, L_i) instead of the global (min mu_i, max L_i)
    uncoordinated: bool = False
    # beyond-paper: compressed z-exchange with lag-based error feedback
    # (see repro.fed.compress for the registry)
    compression: str = "none"         # compressor registry name
    compress_ratio: float = 0.25      # top-k fraction kept
    compress_energy: float = 0.95     # adaptive_topk per-agent target
    compress_backend: str = "xla"     # "auto" | "xla" per-leaf | "pallas"
    engine_backend: str = "xla"       # round edges: "xla" | "pallas" fused
    # round-to-round state representation: "tree" | "packed" resident
    # buffer (engine layout contract; dense states are single-leaf, so
    # the packed form of an (N, n) stack is the same array -- the knob
    # switches the round arithmetic to the whole-buffer packed path)
    state_layout: str = "tree"
    # Krasnosel'skii relaxation: z <- z + 2*damping*(x - y).  damping = 1
    # is the paper's PRS; damping = 1/2 is Douglas-Rachford -- needed to
    # stabilize aggressively compressed exchanges (see tests)
    damping: float = 1.0
    # bounded-staleness async rounds ("stale"): the participation draw
    # becomes an arrival draw and stragglers keep training against their
    # stale reflection up to max_staleness rounds (repro.fed.async_engine;
    # max_staleness=0 reproduces the synchronous engine bitwise)
    async_mode: str = "off"
    max_staleness: int = 0
    # in-jit increment guards (fault tolerance): screen each agent's
    # local-solve row at the uplink -- non-finite / over-norm rows
    # become non-arrivals instead of poisoning the consensus mean
    guard_increments: bool = False
    guard_norm_bound: float = float("inf")
    # coordinator aggregation (repro.fed.robust registry): "mean" keeps
    # the historical uplink bitwise; robust statistics bound what
    # finite byzantine increments can do (param: trimmed_mean's trim
    # count f, norm_clip_mean's clip radius)
    aggregator: str = "mean"
    aggregator_param: float = 0.0

    def to_spec(self, n_agents: Optional[int] = None):
        """The equivalent :class:`repro.fed.api.FedSpec` (the front-door
        config); ``build_trainer(problem, cfg.to_spec())`` reproduces
        ``FedPLT(problem, cfg)`` bit-for-bit."""
        from repro.fed import api

        s = self.solver
        # the legacy dense solvers only read tau under name="noisy_gd"
        # (a gd config with tau set ran noiseless); drop the ignored tau
        # so the spec's tau>0 -> noisy_gd upgrade cannot change behavior
        tau = s.tau if s.name == "noisy_gd" else 0.0
        return api.FedSpec(
            n_agents=n_agents, rho=self.rho,
            participation=self.participation, damping=self.damping,
            solver=s.name, n_epochs=s.n_epochs, gamma=s.step_size,
            mu=self.mu, L=self.L, batch_size=self.batch_size,
            uncoordinated=self.uncoordinated, prox_h=self.prox_h,
            privacy=api.PrivacySpec(tau=tau, clip=s.clip,
                                    dp_init=self.dp_init),
            compression=api.CompressionSpec(
                name=self.compression, ratio=self.compress_ratio,
                energy=self.compress_energy,
                backend=self.compress_backend),
            engine_backend=self.engine_backend,
            state_layout=self.state_layout,
            async_mode=self.async_mode,
            max_staleness=self.max_staleness,
            guard_increments=self.guard_increments,
            guard_norm_bound=self.guard_norm_bound,
            aggregator=self.aggregator,
            aggregator_param=self.aggregator_param)


class FedPLT:
    """Paper-faithful Fed-PLT on a vectorized federated problem.

    ``prox_h`` overrides the coordinator regularizer resolved from
    ``config.prox_h`` (used by the front door to supply registry proxes
    with bound kwargs, e.g. weight decay).

    ``solver_groups`` partitions the agent axis into heterogeneous
    groups: a sequence of ``(size, SolverConfig)`` pairs (sizes summing
    to ``n_agents``), each group running its own solver/epochs/step on
    its contiguous slice (the paper's "agents choose their local
    solver").  None -- or one full-size group equal to ``config.solver``
    -- reproduces the homogeneous trajectory bit-for-bit.

    ``participation`` optionally overrides ``config.participation`` with
    a per-agent ``(N,)`` tuple of Bernoulli rates.

    ``mesh`` (an ``(agent, model)`` :class:`jax.sharding.Mesh`, e.g.
    from :meth:`repro.fed.api.FedSpec.build_mesh`) shards the agent
    axis of every round across the mesh per the engine's mesh contract;
    a 1-device mesh reproduces the unsharded trajectory bitwise."""

    def __init__(self, problem, config: FedPLTConfig, prox_h=None,
                 solver_groups=None, participation=None, mesh=None):
        self.problem = problem
        self.cfg = config
        self.mesh = mesh
        self.mu = config.mu if config.mu is not None else problem.strong_convexity()
        self.L = config.L if config.L is not None else problem.smoothness()
        if self.mu <= 0:  # nonconvex / merely-convex: fall back to 1/rho curvature
            self.mu = 0.0
        if config.uncoordinated and hasattr(problem,
                                            "per_agent_smoothness"):
            self.mu_i = problem.per_agent_strong_convexity()
            self.L_i = problem.per_agent_smoothness()
        else:
            N = problem.n_agents
            self.mu_i = jnp.full((N,), self.mu)
            self.L_i = jnp.full((N,), self.L)
        self.prox_h = (prox_h if prox_h is not None
                       else prox_lib.make_prox(config.prox_h))
        self._ecfg = engine.RoundConfig(
            n_agents=problem.n_agents, rho=config.rho,
            participation=(participation if participation is not None
                           else config.participation),
            damping=config.damping,
            compression=config.compression,
            compress_ratio=config.compress_ratio,
            compress_energy=config.compress_energy,
            compress_backend=config.compress_backend,
            engine_backend=config.engine_backend,
            state_layout=config.state_layout,
            staleness=engine.StalenessConfig(
                mode=config.async_mode,
                max_staleness=config.max_staleness),
            agent_shards=engine.mesh_agent_shards(mesh),
            guard_increments=config.guard_increments,
            guard_norm_bound=config.guard_norm_bound,
            aggregator=config.aggregator,
            aggregator_param=config.aggregator_param)
        # packed layout: the dense state is single-leaf, so its resident
        # (N, n) buffer IS the stacked array (pack_leaves fast path, no
        # lane padding) -- the meta is pure shape arithmetic and the
        # historical solvers consume the buffer unchanged
        self._meta = None
        if config.state_layout == "packed":
            from repro.fed import compress as compress_lib
            self._meta = compress_lib.packed_meta(jax.ShapeDtypeStruct(
                (problem.n_agents, problem.dim), jnp.float32))
        if solver_groups is None:
            # the homogeneous path is the single full-size group; a
            # [0:N] slice is a no-op, so this is bit-identical to the
            # historical dedicated path (asserted in tests/test_api.py)
            self._solvers = self._make_group_solver(
                0, problem.n_agents, config.solver)
        else:
            sizes = [s for s, _ in solver_groups]
            if sum(sizes) != problem.n_agents:
                raise ValueError(
                    f"solver_groups sizes sum to {sum(sizes)}, problem "
                    f"has n_agents={problem.n_agents}")
            self._solvers, start = [], 0
            for size, scfg in solver_groups:
                self._solvers.append(engine.SolverGroup(
                    size, self._make_group_solver(start, size, scfg)))
                start += size
            self._solvers = tuple(self._solvers)
        self._round = jax.jit(self._round_impl)
        self._round_arrival = jax.jit(self._round_core)

    # ------------------------------------------------------------------
    def init(self, key: jax.Array) -> FedPLTState:
        N, n = self.problem.n_agents, self.problem.dim
        k_init, k_state = jax.random.split(key)
        if self.cfg.dp_init and self.cfg.solver.tau > 0 and self.mu > 0:
            std = jnp.sqrt(2.0 * self.cfg.solver.tau ** 2 / self.mu)
            x0 = std * jax.random.normal(k_init, (N, n))
        else:
            x0 = jnp.zeros((N, n))
        # t (the coordinator's copy) is only materialized when the
        # exchange is compressed; uncompressed it would double z-memory
        stale = self._ecfg.staleness.enabled
        return FedPLTState(x=x0, z=x0, y=jnp.zeros(n), key=k_state,
                           k=jnp.zeros((), jnp.int32),
                           t=x0 if self._ecfg.compressed else None,
                           y_tag=jnp.zeros((N, n)) if stale else None,
                           staleness=(jnp.zeros((N,), jnp.int32)
                                      if stale else None))

    # ------------------------------------------------------------------
    def _fgrad(self, data, w, key, scfg=None):
        """Per-agent gradient oracle (full or minibatch)."""
        scfg = scfg if scfg is not None else self.cfg.solver
        if scfg.name == "sgd" and self.cfg.batch_size is not None:
            q = data[0].shape[0]
            idx = jax.random.randint(key, (self.cfg.batch_size,), 0, q)
            return self.problem.minibatch_grad(data, w, idx)
        return jax.grad(lambda xx: self.problem.local_loss(data, xx))(w)

    def _agent_data(self):
        # Problems expose stacked per-agent arrays; assemble the leaf tuple.
        if hasattr(self.problem, "A"):
            return (self.problem.A, self.problem.b)
        return (self.problem.Q, self.problem.c)

    # ------------------------------------------------------------------
    def _make_group_solver(self, start: int, size: int,
                           scfg: SolverConfig):
        """Engine LocalSolver for agents ``[start, start+size)`` running
        their own ``scfg``.

        Core solvers keep the historical per-agent vmap + key split over
        ``local_train`` with (possibly per-agent, Remark 1) curvature
        moduli -- restricted to the group's slice of the data and
        moduli, so the single full-size group IS the homogeneous path,
        bit for bit.  Any other name is a :mod:`repro.fed.solvers`
        registry entry and is built through its factory on a stacked
        gradient oracle (the same batched contract the model path uses),
        so registered custom solvers are reachable from the dense front
        end too."""
        stop = start + size
        from repro.fed import solvers as solver_registry

        if scfg.name not in solver_registry.CORE_SOLVERS:

            def fgrad_stacked(w_stack, key):
                data_g = tuple(a[start:stop] for a in self._agent_data())
                keys = jax.random.split(key, size)
                return jax.vmap(
                    lambda d, w, k: self._fgrad(d, w, k, scfg))(
                        data_g, w_stack, keys)

            return solver_registry.make_local_solver(
                scfg, fgrad_stacked, self.cfg.rho, self.mu, self.L)

        def solver(x_g, v_g, k_solve):
            solver_keys = jax.random.split(k_solve, size)
            data_g = tuple(a[start:stop] for a in self._agent_data())

            def one_agent(data_i, x_i, v_i, key_i, mu_i, L_i):
                fgrad = lambda w, k: self._fgrad(data_i, w, k, scfg)
                return local_train(fgrad, x_i, v_i, self.cfg.rho, scfg,
                                   key_i, mu_i, L_i)

            w = jax.vmap(one_agent)(data_g, x_g, v_g, solver_keys,
                                    self.mu_i[start:stop],
                                    self.L_i[start:stop])
            return w, None

        return solver

    def _round_core(self, state: FedPLTState, arrival=None,
                    corrupt=None, live=None):
        """One round; returns ``(next_state, u)`` with ``u`` the round's
        realized (N,) participation / arrival mask.  ``arrival``
        (async mode only) substitutes a recorded schedule row for the
        Bernoulli draw -- the broker replay path.  ``corrupt`` / ``live``
        are broker-realized fault rows (corruption injection / eviction
        masks; see :func:`repro.fed.engine.round_step`) and work in both
        synchrony modes."""
        compressed = self._ecfg.compressed
        t = state.t if compressed else state.z
        if self._ecfg.staleness.enabled:
            from repro.fed import async_engine

            step = (async_engine.packed_async_round_step
                    if self._meta is not None
                    else async_engine.async_round_step)
            extra = (self._meta,) if self._meta is not None else ()
            res = step(self._ecfg, *extra, state.x, state.z, t,
                       state.y_tag, state.staleness, state.key,
                       self._solvers, prox_h=self.prox_h,
                       arrival=arrival, mesh=self.mesh,
                       corrupt=corrupt, live=live)
            y = res.y.reshape(-1) if self._meta is not None else res.y
            return FedPLTState(x=res.x, z=res.z, y=y, key=res.next_key,
                               k=state.k + 1,
                               t=res.t if compressed else None,
                               y_tag=res.y_tag,
                               staleness=res.staleness), res.u
        if arrival is not None:
            raise ValueError("arrival schedules require async_mode="
                             "'stale' (synchronous rounds draw "
                             "participation internally)")
        if self._meta is not None:
            res = engine.packed_round_step(
                self._ecfg, self._meta, state.x, state.z, t, state.key,
                self._solvers, prox_h=self.prox_h, mesh=self.mesh,
                corrupt=corrupt, live=live)
            y = res.y.reshape(-1)   # (1, n) coordinator buffer -> (n,)
        else:
            res = engine.round_step(self._ecfg, state.x, state.z, t,
                                    state.key, self._solvers,
                                    prox_h=self.prox_h, mesh=self.mesh,
                                    corrupt=corrupt, live=live)
            y = res.y
        return FedPLTState(x=res.x, z=res.z, y=y, key=res.next_key,
                           k=state.k + 1,
                           t=res.t if compressed else None), res.u

    def _round_impl(self, state: FedPLTState) -> FedPLTState:
        return self._round_core(state)[0]

    # ------------------------------------------------------------------
    def round(self, state: FedPLTState) -> FedPLTState:
        return self._round(state)

    def round_with_arrival(self, state: FedPLTState, arrival=None):
        """One jitted round returning ``(next_state, u)``; ``arrival``
        optionally replaces the arrival draw with a recorded (N,) 0/1
        row (async mode) -- the broker's numerics entry point."""
        return self._round_arrival(state, arrival)

    def round_with_faults(self, state: FedPLTState, arrival=None,
                          corrupt=None, live=None):
        """One jitted round returning ``(next_state, u)`` with the full
        broker override set: ``arrival`` (recorded schedule row, async
        mode), ``corrupt`` (per-agent corruption multipliers applied to
        the solver output) and ``live`` (0/1 eviction mask; the
        coordinator averages over survivors).  The fault-capable broker
        entry point -- e.g.
        ``lambda s, u, c, l: algo.round_with_faults(s, u, c, l)[0]``.
        All-None reproduces :meth:`round_with_arrival` bitwise."""
        return self._round_arrival(state, arrival, corrupt, live)

    def run(self, key: jax.Array, n_rounds: int):
        """Run ``n_rounds`` rounds; returns (final_state, criterion_history).

        criterion_history[k] = || sum_i grad f_i(x_bar_k) ||^2 *after* round k.
        """
        state, crit, _ = self.run_recorded(key, n_rounds)
        return state, crit

    def run_recorded(self, key: jax.Array, n_rounds: int):
        """:meth:`run` that also returns the realized ``(n_rounds, N)``
        arrival schedule (the stacked per-round masks -- feed it to
        :func:`repro.fed.api.effective_privacy_report` or replay it with
        :meth:`replay`)."""
        state = self.init(key)

        def body(s, _):
            s, u = self._round_core(s)
            return s, (self.problem.criterion(s.x), u)

        state, (crit, sched) = jax.lax.scan(body, state, None,
                                            length=n_rounds)
        return state, crit, sched

    def replay(self, key: jax.Array, schedule):
        """Re-run a recorded ``(n_rounds, N)`` arrival schedule through
        the in-jit async model; returns (final_state, criterion_history)
        bit-identical to the run that recorded it (same init key)."""
        if not self._ecfg.staleness.enabled:
            raise ValueError("replay requires async_mode='stale'")
        schedule = jnp.asarray(schedule, jnp.float32)
        state = self.init(key)

        def body(s, row):
            s, _ = self._round_core(s, row)
            return s, self.problem.criterion(s.x)

        state, crit = jax.lax.scan(body, state, schedule)
        return state, crit

    # convenience -------------------------------------------------------
    def x_bar(self, state: FedPLTState) -> jnp.ndarray:
        return jnp.mean(state.x, axis=0)
