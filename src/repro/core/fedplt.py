"""Fed-PLT -- Algorithm 1 of the paper, vectorized over agents.

One round:
  coordinator:  y = prox_{rho h / N}( mean_i z_i )            (Lemma 6)
  agents i active (u_i ~ Ber(p_i)):
      v_i   = 2 y - z_i
      x_i   <- N_e epochs of the local solver on
               d_i(w) = f_i(w) + ||w - v_i||^2/(2 rho),  warm start x_i
      z_i   <- z_i + 2 (x_i - y)
  agents inactive: state unchanged.

The whole round is one jitted function; the training loop is a
``lax.scan`` that also records the paper's convergence criterion.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core import prox as prox_lib
from repro.core.solvers import SolverConfig, local_train


class FedPLTState(NamedTuple):
    x: jnp.ndarray      # (N, n) local models
    z: jnp.ndarray      # (N, n) auxiliary (PRS) variables
    y: jnp.ndarray      # (n,)  coordinator model (last broadcast)
    key: jax.Array
    k: jnp.ndarray      # round counter
    # compressed-communication state (zeros when compression == 'none'):
    t: jnp.ndarray = None    # (N, n) coordinator's copy of each z_i
    e: jnp.ndarray = None    # (N, n) error-feedback memory


@dataclasses.dataclass(frozen=True)
class FedPLTConfig:
    rho: float = 1.0
    solver: SolverConfig = dataclasses.field(default_factory=SolverConfig)
    participation: float = 1.0        # p (uniform across agents)
    prox_h: str = "zero"              # coordinator regularizer
    batch_size: Optional[int] = None  # for sgd oracle
    # curvature moduli of the f_i; None -> taken from the problem
    mu: Optional[float] = None
    L: Optional[float] = None
    dp_init: bool = False             # x0 ~ N(0, 2 tau^2/mu I)  (Prop. 4)
    # Remark 1 (uncoordinated solvers): per-agent step sizes tuned to the
    # LOCAL moduli (mu_i, L_i) instead of the global (min mu_i, max L_i)
    uncoordinated: bool = False
    # beyond-paper: compressed z-exchange with error feedback (the paper
    # cites quantized-DP work [25]-[27] as complementary; we implement
    # increment compression: agents transmit C(dz + e), coordinator
    # averages the transmitted copies)
    compression: str = "none"         # none | topk | int8
    compress_ratio: float = 0.25      # top-k fraction kept
    # Krasnosel'skii relaxation: z <- z + 2*damping*(x - y).  damping = 1
    # is the paper's PRS; damping = 1/2 is Douglas-Rachford -- needed to
    # stabilize aggressively compressed exchanges (see tests)
    damping: float = 1.0


class FedPLT:
    """Paper-faithful Fed-PLT on a vectorized federated problem."""

    def __init__(self, problem, config: FedPLTConfig):
        self.problem = problem
        self.cfg = config
        self.mu = config.mu if config.mu is not None else problem.strong_convexity()
        self.L = config.L if config.L is not None else problem.smoothness()
        if self.mu <= 0:  # nonconvex / merely-convex: fall back to 1/rho curvature
            self.mu = 0.0
        if config.uncoordinated and hasattr(problem,
                                            "per_agent_smoothness"):
            self.mu_i = problem.per_agent_strong_convexity()
            self.L_i = problem.per_agent_smoothness()
        else:
            N = problem.n_agents
            self.mu_i = jnp.full((N,), self.mu)
            self.L_i = jnp.full((N,), self.L)
        self.prox_h = prox_lib.make_prox(config.prox_h)
        self._round = jax.jit(self._round_impl)

    # ------------------------------------------------------------------
    def init(self, key: jax.Array) -> FedPLTState:
        N, n = self.problem.n_agents, self.problem.dim
        k_init, k_state = jax.random.split(key)
        if self.cfg.dp_init and self.cfg.solver.tau > 0 and self.mu > 0:
            std = jnp.sqrt(2.0 * self.cfg.solver.tau ** 2 / self.mu)
            x0 = std * jax.random.normal(k_init, (N, n))
        else:
            x0 = jnp.zeros((N, n))
        return FedPLTState(x=x0, z=x0, y=jnp.zeros(n), key=k_state,
                           k=jnp.zeros((), jnp.int32),
                           t=x0, e=jnp.zeros((N, n)))

    # ------------------------------------------------------------------
    def _fgrad(self, data, w, key):
        """Per-agent gradient oracle (full or minibatch)."""
        if self.cfg.solver.name == "sgd" and self.cfg.batch_size is not None:
            q = data[0].shape[0]
            idx = jax.random.randint(key, (self.cfg.batch_size,), 0, q)
            return self.problem.minibatch_grad(data, w, idx)
        return jax.grad(lambda xx: self.problem.local_loss(data, xx))(w)

    def _agent_data(self):
        # Problems expose stacked per-agent arrays; assemble the leaf tuple.
        if hasattr(self.problem, "A"):
            return (self.problem.A, self.problem.b)
        return (self.problem.Q, self.problem.c)

    # ------------------------------------------------------------------
    def _compress(self, dz: jnp.ndarray) -> jnp.ndarray:
        """Per-agent increment compressor (beyond-paper)."""
        if self.cfg.compression == "topk":
            k = max(1, int(self.cfg.compress_ratio * dz.shape[-1]))

            def topk_row(row):
                thresh = jnp.sort(jnp.abs(row))[-k]
                return jnp.where(jnp.abs(row) >= thresh, row, 0.0)

            return jax.vmap(topk_row)(dz)
        if self.cfg.compression == "int8":
            scale = jnp.max(jnp.abs(dz), axis=-1, keepdims=True) / 127.0
            scale = jnp.maximum(scale, 1e-12)
            q = jnp.round(dz / scale).astype(jnp.int8)
            return q.astype(dz.dtype) * scale
        return dz

    def _round_impl(self, state: FedPLTState) -> FedPLTState:
        cfg = self.cfg
        key, k_part, k_solve = jax.random.split(state.key, 3)
        compressed = cfg.compression != "none"

        # -- coordinator: averages the *transmitted* copies when the
        # exchange is compressed (t_i), else the exact z_i (Lemma 6) ----
        z_seen = state.t if compressed else state.z
        y = prox_lib.coordinator_prox(z_seen, cfg.rho, self.prox_h)

        # -- agents ---------------------------------------------------------
        v = 2.0 * y[None, :] - state.z
        solver_keys = jax.random.split(k_solve, self.problem.n_agents)

        def one_agent(data_i, x_i, v_i, key_i, mu_i, L_i):
            fgrad = lambda w, k: self._fgrad(data_i, w, k)
            return local_train(fgrad, x_i, v_i, cfg.rho, cfg.solver,
                               key_i, mu_i, L_i)

        data = self._agent_data()
        w = jax.vmap(one_agent)(data, state.x, v, solver_keys,
                                self.mu_i, self.L_i)

        # -- partial participation ---------------------------------------
        u = jax.random.bernoulli(
            k_part, cfg.participation,
            (self.problem.n_agents,)).astype(w.dtype)[:, None]
        x_new = u * w + (1.0 - u) * state.x
        z_upd = state.z + 2.0 * cfg.damping * (w - y[None, :])
        z_new = u * z_upd + (1.0 - u) * state.z

        # -- compressed uplink -------------------------------------------
        # t lags z by exactly the never-transmitted residual, so
        # compressing (z_new - t) IS error feedback (adding a separate
        # error memory would double-count the residual and diverge).
        if compressed:
            q = self._compress(z_new - state.t)
            t_new = state.t + u * q          # coordinator copy advances
            e_new = state.e
        else:
            t_new, e_new = z_new, state.e

        return FedPLTState(x=x_new, z=z_new, y=y, key=key,
                           k=state.k + 1, t=t_new, e=e_new)

    # ------------------------------------------------------------------
    def round(self, state: FedPLTState) -> FedPLTState:
        return self._round(state)

    def run(self, key: jax.Array, n_rounds: int):
        """Run ``n_rounds`` rounds; returns (final_state, criterion_history).

        criterion_history[k] = || sum_i grad f_i(x_bar_k) ||^2 *after* round k.
        """
        state = self.init(key)

        def body(s, _):
            s = self._round_impl(s)
            return s, self.problem.criterion(s.x)

        state, crit = jax.lax.scan(body, state, None, length=n_rounds)
        return state, crit

    # convenience -------------------------------------------------------
    def x_bar(self, state: FedPLTState) -> jnp.ndarray:
        return jnp.mean(state.x, axis=0)
