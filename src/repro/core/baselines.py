"""Baseline federated algorithms compared against Fed-PLT (paper Sec. I-A).

All baselines share the interface

    algo = make_<name>(problem, **hyperparams)
    crit_history = algo.run(key, n_rounds)        # (n_rounds,) criterion

with the paper's criterion ``|| sum_i grad f_i(x_bar) ||^2`` recorded after
every communication round, and a ``time_per_round(t_G, t_C)`` implementing
the Table-II accounting.

Implementation provenance (documented deviations):
  * FedAvg        -- McMahan et al. (reference point, not in the tables).
  * FedSplit [34] -- PRS without warm start (inner GD initialized at the
                     reflected point, *not* at the previous local model).
  * FedPD  [35]   -- augmented-Lagrangian form, warm-started inner GD.
  * FedLin [36]   -- two communications per round (gradient sync + model).
  * SCAFFOLD      -- option-II control variates.
  * ProxSkip [19] -- a.k.a. Scaffnew; probabilistic communication.
  * TAMUNA [37]   -- implemented in its LT+PP form without compression
                     (the paper's tables use exactly this regime:
                     geometric local epochs, client sampling).
  * LED    [38]   -- implemented in its equivalent control-variate server
                     form (drift-corrected local GD with zero-mean duals);
                     same fixed points, see docstring.
  * 5GCS   [14]   -- RandProx/Point-SAGA form: sampled clients approximate
                     prox_{alpha f_i} with any local solver, dual table on
                     the server.
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# Shared helpers
# ---------------------------------------------------------------------------

def _local_gd(problem, data_i, w0, n_epochs, gamma, correction=None):
    """n_epochs of  w -= gamma * (grad f_i(w) + correction)."""

    def body(w, _):
        g = jax.grad(lambda xx: problem.local_loss(data_i, xx))(w)
        if correction is not None:
            g = g + correction
        return w - gamma * g, None

    w, _ = jax.lax.scan(body, w0, None, length=n_epochs)
    return w


def _local_gd_fn(problem, data_i, w0, n_epochs, gamma, grad_mod):
    """n_epochs of  w -= gamma * grad_mod(grad f_i(w), w)."""

    def body(w, _):
        g = jax.grad(lambda xx: problem.local_loss(data_i, xx))(w)
        return w - gamma * grad_mod(g, w), None

    w, _ = jax.lax.scan(body, w0, None, length=n_epochs)
    return w


def _agent_data(problem):
    if hasattr(problem, "A"):
        return (problem.A, problem.b)
    return (problem.Q, problem.c)


def _masked_mean(w, u, fallback):
    """Mean over active agents (u in {0,1}); falls back when none active."""
    cnt = jnp.sum(u)
    m = jnp.sum(w * u[:, None], axis=0) / jnp.maximum(cnt, 1.0)
    return jnp.where(cnt > 0, m, fallback)


@dataclasses.dataclass
class Algorithm:
    name: str
    run: Callable  # (key, n_rounds) -> (n_rounds,) criterion history
    time_per_round: Callable  # (t_G, t_C) -> float
    comms_per_round: float = 1.0


# ---------------------------------------------------------------------------
# FedAvg
# ---------------------------------------------------------------------------

def make_fedavg(problem, gamma=0.1, n_epochs=5, participation=1.0):
    N = problem.n_agents
    data = _agent_data(problem)

    def run(key, n_rounds):
        x0 = jnp.zeros(problem.dim)

        def round_fn(carry, k):
            x_bar, = carry
            w = jax.vmap(lambda d0, d1: _local_gd(
                problem, (d0, d1), x_bar, n_epochs, gamma))(*data)
            u = jax.random.bernoulli(k, participation, (N,)).astype(w.dtype)
            x_new = _masked_mean(w, u, x_bar)
            crit = problem.criterion(x_new)
            return (x_new,), crit

        _, crit = jax.lax.scan(round_fn, (x0,),
                               jax.random.split(key, n_rounds))
        return crit

    return Algorithm(
        "fedavg", jax.jit(run, static_argnums=1),
        lambda tG, tC, N_=N: (n_epochs * tG + tC) * N_ * participation)


# ---------------------------------------------------------------------------
# FedSplit [34] -- PRS without the warm-start initialization
# ---------------------------------------------------------------------------

def make_fedsplit(problem, rho=1.0, gamma=None, n_epochs=5):
    N = problem.n_agents
    data = _agent_data(problem)
    mu, L = problem.strong_convexity(), problem.smoothness()
    if gamma is None:
        gamma = 2.0 / (mu + L + 2.0 / rho)
    inv_rho = 1.0 / rho

    def run(key, n_rounds):
        del key
        z0 = jnp.zeros((N, problem.dim))

        def round_fn(z, _):
            x_bar = jnp.mean(z, axis=0)
            v = 2.0 * x_bar[None, :] - z

            def solve(d0, d1, v_i):
                # cold start at the reflected point (FedSplit's choice)
                return _local_gd_fn(problem, (d0, d1), v_i, n_epochs, gamma,
                                    lambda g, w: g + inv_rho * (w - v_i))

            w = jax.vmap(solve)(*data, v)
            z_new = z + 2.0 * (w - x_bar[None, :])
            return z_new, problem.criterion(w)

        _, crit = jax.lax.scan(round_fn, z0, None, length=n_rounds)
        return crit

    return Algorithm(
        "fedsplit", jax.jit(run, static_argnums=1),
        lambda tG, tC, N_=N: (n_epochs * tG + tC) * N_)


# ---------------------------------------------------------------------------
# FedPD [35]
# ---------------------------------------------------------------------------

def make_fedpd(problem, eta=1.0, gamma=0.05, n_epochs=5):
    N = problem.n_agents
    data = _agent_data(problem)
    inv_eta = 1.0 / eta

    def run(key, n_rounds):
        del key
        x0 = jnp.zeros((N, problem.dim))
        lam0 = jnp.zeros((N, problem.dim))
        xbar0 = jnp.zeros(problem.dim)

        def round_fn(carry, _):
            x, lam, x_bar = carry

            def solve(d0, d1, x_i, lam_i):
                return _local_gd_fn(
                    problem, (d0, d1), x_i, n_epochs, gamma,
                    lambda g, w: g + lam_i + inv_eta * (w - x_bar))

            x_new = jax.vmap(solve)(*data, x, lam)
            lam_new = lam + inv_eta * (x_new - x_bar[None, :])
            x_bar_new = jnp.mean(x_new + eta * lam_new, axis=0)
            return (x_new, lam_new, x_bar_new), problem.criterion(x_new)

        _, crit = jax.lax.scan(round_fn, (x0, lam0, xbar0), None,
                               length=n_rounds)
        return crit

    return Algorithm(
        "fedpd", jax.jit(run, static_argnums=1),
        lambda tG, tC, N_=N: (n_epochs * tG + tC) * N_)


# ---------------------------------------------------------------------------
# FedLin [36]
# ---------------------------------------------------------------------------

def make_fedlin(problem, gamma=0.05, n_epochs=5):
    N = problem.n_agents
    data = _agent_data(problem)

    def run(key, n_rounds):
        del key
        x0 = jnp.zeros(problem.dim)

        def round_fn(x_bar, _):
            # communication 1: gradient sync
            g_at_xbar = problem.grads(
                jnp.broadcast_to(x_bar, (N, problem.dim)))
            g_mean = jnp.mean(g_at_xbar, axis=0)

            def solve(d0, d1, g_i):
                return _local_gd_fn(
                    problem, (d0, d1), x_bar, n_epochs, gamma,
                    lambda g, w: g - g_i + g_mean)

            w = jax.vmap(solve)(*data, g_at_xbar)
            # communication 2: model sync
            x_new = jnp.mean(w, axis=0)
            return x_new, problem.criterion(x_new)

        _, crit = jax.lax.scan(round_fn, x0, None, length=n_rounds)
        return crit

    return Algorithm(
        "fedlin", jax.jit(run, static_argnums=1),
        lambda tG, tC, N_=N: ((n_epochs + 1) * tG + 2 * tC) * N_,
        comms_per_round=2.0)


# ---------------------------------------------------------------------------
# SCAFFOLD
# ---------------------------------------------------------------------------

def make_scaffold(problem, gamma_l=0.05, gamma_g=1.0, n_epochs=5,
                  participation=1.0):
    N = problem.n_agents
    data = _agent_data(problem)

    def run(key, n_rounds):
        x0 = jnp.zeros(problem.dim)
        c0 = jnp.zeros(problem.dim)
        ci0 = jnp.zeros((N, problem.dim))

        def round_fn(carry, k):
            x_bar, c, c_i = carry

            def solve(d0, d1, ci_):
                return _local_gd_fn(
                    problem, (d0, d1), x_bar, n_epochs, gamma_l,
                    lambda g, w: g - ci_ + c)

            w = jax.vmap(solve)(*data, c_i)
            c_i_plus = c_i - c + (x_bar[None, :] - w) / (n_epochs * gamma_l)
            u = jax.random.bernoulli(k, participation, (N,)).astype(w.dtype)
            dx = _masked_mean(w - x_bar[None, :], u, jnp.zeros(problem.dim))
            dc = _masked_mean(c_i_plus - c_i, u, jnp.zeros(problem.dim))
            frac = jnp.sum(u) / N
            x_new = x_bar + gamma_g * dx
            c_new = c + frac * dc
            c_i_new = u[:, None] * c_i_plus + (1 - u[:, None]) * c_i
            return (x_new, c_new, c_i_new), problem.criterion(x_new)

        _, crit = jax.lax.scan(round_fn, (x0, c0, ci0),
                               jax.random.split(key, n_rounds))
        return crit

    return Algorithm(
        "scaffold", jax.jit(run, static_argnums=1),
        lambda tG, tC, N_=N: (n_epochs * tG + tC) * N_ * participation)


# ---------------------------------------------------------------------------
# ProxSkip / Scaffnew [19]
# ---------------------------------------------------------------------------

def make_proxskip(problem, gamma=0.05, p_comm=0.2):
    """One *gradient step* per iteration; communication w.p. p_comm.

    To compare on equal rounds, run() treats 1/p_comm iterations as one
    nominal 'round' so histories align with N_e = 1/p_comm local epochs.
    """
    N = problem.n_agents

    def run(key, n_rounds):
        steps = n_rounds  # caller scales
        x0 = jnp.zeros((N, problem.dim))
        h0 = jnp.zeros((N, problem.dim))

        def step_fn(carry, k):
            x, h = carry
            g = problem.grads(x)
            x_hat = x - gamma * (g - h)
            theta = jax.random.bernoulli(k, p_comm)
            x_comm = jnp.broadcast_to(jnp.mean(x_hat, axis=0),
                                      x_hat.shape)
            x_new = jnp.where(theta, x_comm, x_hat)
            h_new = jnp.where(theta, h + (p_comm / gamma) * (x_new - x_hat),
                              h)
            return (x_new, h_new), problem.criterion(x_new)

        _, crit = jax.lax.scan(step_fn, (x0, h0),
                               jax.random.split(key, steps))
        return crit

    return Algorithm(
        "proxskip", jax.jit(run, static_argnums=1),
        lambda tG, tC, N_=N: (tG + p_comm * tC) * N_)


# ---------------------------------------------------------------------------
# TAMUNA [37] -- LT + PP form (no compression)
# ---------------------------------------------------------------------------

def make_tamuna(problem, gamma=0.05, p_comm=0.2, participation=1.0):
    """Scaffnew-style probabilistic communication + client sampling.

    The number of local epochs between communications is Geom(p_comm)
    (mean 1/p_comm = N_e), matching the paper's comparison protocol.
    """
    N = problem.n_agents

    def run(key, n_steps):
        x0 = jnp.zeros((N, problem.dim))
        h0 = jnp.zeros((N, problem.dim))

        def step_fn(carry, k):
            x, h = carry
            k_comm, k_part = jax.random.split(k)
            g = problem.grads(x)
            x_hat = x - gamma * (g - h)
            theta = jax.random.bernoulli(k_comm, p_comm)
            u = jax.random.bernoulli(k_part, participation,
                                     (N,)).astype(x.dtype)
            x_mean = _masked_mean(x_hat, u, jnp.mean(x_hat, axis=0))
            active = (u[:, None] > 0)
            x_comm = jnp.where(active, jnp.broadcast_to(x_mean, x_hat.shape),
                               x_hat)
            x_new = jnp.where(theta, x_comm, x_hat)
            # inactive agents have x_new == x_hat, so their h is unchanged
            h_new = jnp.where(theta,
                              h + (p_comm / gamma) * (x_new - x_hat), h)
            return (x_new, h_new), problem.criterion(x_new)

        _, crit = jax.lax.scan(step_fn, (x0, h0),
                               jax.random.split(key, n_steps))
        return crit

    return Algorithm(
        "tamuna", jax.jit(run, static_argnums=1),
        lambda tG, tC, N_=N: (tG + p_comm * tC) * N_ * participation)


# ---------------------------------------------------------------------------
# LED [38] -- control-variate server form
# ---------------------------------------------------------------------------

def make_led(problem, gamma=0.05, n_epochs=5, beta=1.0):
    """Local Exact-Diffusion, implemented in its equivalent control-variate
    server form: agents run drift-corrected local GD

        w <- w - gamma (grad f_i(w) - y_i),      w^0 = x_bar,

    and the zero-mean duals track y_i -> grad f_i(x*):

        y_i <- y_i + beta/(gamma N_e) (x_bar_new - w_i^{N_e}).

    Fixed points coincide with the exact optimum (sum_i y_i = 0 is
    preserved, so w_i = x_bar for all i forces sum_i grad f_i(x_bar) = 0).
    """
    N = problem.n_agents
    data = _agent_data(problem)

    def run(key, n_rounds):
        del key
        x0 = jnp.zeros(problem.dim)
        y0 = jnp.zeros((N, problem.dim))

        def round_fn(carry, _):
            x_bar, y = carry

            def solve(d0, d1, y_i):
                return _local_gd_fn(problem, (d0, d1), x_bar, n_epochs,
                                    gamma, lambda g, w: g - y_i)

            w = jax.vmap(solve)(*data, y)
            x_new = jnp.mean(w, axis=0)
            y_new = y + beta / (gamma * n_epochs) * (x_new[None, :] - w)
            return (x_new, y_new), problem.criterion(x_new)

        _, crit = jax.lax.scan(round_fn, (x0, y0), None, length=n_rounds)
        return crit

    return Algorithm(
        "led", jax.jit(run, static_argnums=1),
        lambda tG, tC, N_=N: (n_epochs * tG + tC) * N_)


# ---------------------------------------------------------------------------
# 5GCS [14] -- RandProx / Point-SAGA form with client sampling
# ---------------------------------------------------------------------------

def make_5gcs(problem, alpha=1.0, eta=0.5, n_epochs=5, participation=0.5,
              solver: str = "gd"):
    """Sampled clients approximately solve prox_{alpha f_i}(x + alpha u_i)
    with N_e local epochs (any solver satisfying a descent condition --
    here GD or AGD); the server keeps a dual table u_i (N+3 variables).
    """
    N = problem.n_agents
    data = _agent_data(problem)
    mu, L = problem.strong_convexity(), problem.smoothness()
    mu_d, L_d = mu + 1.0 / alpha, L + 1.0 / alpha
    gamma = 2.0 / (mu_d + L_d)
    inv_alpha = 1.0 / alpha

    def run(key, n_rounds):
        x0 = jnp.zeros(problem.dim)
        u0 = jnp.zeros((N, problem.dim))
        w0 = jnp.zeros((N, problem.dim))  # client-side warm starts

        def round_fn(carry, k):
            x, u, w_prev = carry
            sel = jax.random.bernoulli(k, participation, (N,)).astype(
                x.dtype)

            def solve(d0, d1, u_i, w_i):
                v_i = x + alpha * u_i
                if solver == "agd":
                    beta = ((jnp.sqrt(L_d) - jnp.sqrt(mu_d))
                            / (jnp.sqrt(L_d) + jnp.sqrt(mu_d)))

                    def body(c, _):
                        w, up = c
                        grd = jax.grad(lambda xx: problem.local_loss(
                            (d0, d1), xx))(w) + inv_alpha * (w - v_i)
                        un = w - grd / L_d
                        return (un + beta * (un - up), un), None

                    (w, _), _ = jax.lax.scan(body, (w_i, w_i), None,
                                             length=n_epochs)
                    return w
                return _local_gd_fn(
                    problem, (d0, d1), w_i, n_epochs, gamma,
                    lambda g, w: g + inv_alpha * (w - v_i))

            w_hat = jax.vmap(solve)(*data, u, w_prev)
            g_new = inv_alpha * (x[None, :] + alpha * u - w_hat)
            u_new = sel[:, None] * g_new + (1 - sel[:, None]) * u
            w_new = sel[:, None] * w_hat + (1 - sel[:, None]) * w_prev
            x_new = x - eta * alpha * jnp.mean(u_new, axis=0)
            return (x_new, u_new, w_new), problem.criterion(x_new)

        _, crit = jax.lax.scan(round_fn, (x0, u0, w0),
                               jax.random.split(key, n_rounds))
        return crit

    return Algorithm(
        "5gcs", jax.jit(run, static_argnums=1),
        lambda tG, tC, N_=N: (n_epochs * tG + tC) * N_ * participation)


REGISTRY = {
    "fedavg": make_fedavg,
    "fedsplit": make_fedsplit,
    "fedpd": make_fedpd,
    "fedlin": make_fedlin,
    "scaffold": make_scaffold,
    "proxskip": make_proxskip,
    "tamuna": make_tamuna,
    "led": make_led,
    "5gcs": make_5gcs,
}
