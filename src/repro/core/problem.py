"""Federated empirical-risk-minimization problems (paper Section VII).

A problem bundles per-agent datasets and exposes vectorized local losses and
gradients.  Data layout: leading axis = agent, i.e. features ``A`` has shape
``(N, q, n)`` and labels ``b`` shape ``(N, q)``.

The paper's experiment: logistic regression with N=100 agents, n=5 features,
q_i=250 samples, regularization ``eps * r(x)`` with
``r(x) = ||x||^2/2`` (convex) or ``r(x) = sum_j x_j^2/(1+x_j^2)``
(nonconvex), eps = 0.5.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


# ---------------------------------------------------------------------------
# Regularizers
# ---------------------------------------------------------------------------

def reg_l2sq(x: jnp.ndarray) -> jnp.ndarray:
    return 0.5 * jnp.sum(x * x)


def reg_nonconvex(x: jnp.ndarray) -> jnp.ndarray:
    """The paper's nonconvex regularizer: sum_j x_j^2 / (1 + x_j^2)."""
    return jnp.sum(x * x / (1.0 + x * x))


# ---------------------------------------------------------------------------
# Logistic regression
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class LogRegProblem:
    """l2/nonconvex-regularized logistic regression, one dataset per agent.

    ``f_i(x) = (1/q_i) sum_h log(1 + exp(-b_ih <a_ih, x>)) + eps * r(x)``
    """

    A: jnp.ndarray          # (N, q, n)
    b: jnp.ndarray          # (N, q) in {-1, +1}
    eps: float = 0.5
    nonconvex: bool = False

    # -- basic shapes ------------------------------------------------------
    @property
    def n_agents(self) -> int:
        return self.A.shape[0]

    @property
    def q(self) -> int:
        return self.A.shape[1]

    @property
    def dim(self) -> int:
        return self.A.shape[2]

    # -- losses ------------------------------------------------------------
    def _reg(self, x: jnp.ndarray) -> jnp.ndarray:
        return reg_nonconvex(x) if self.nonconvex else reg_l2sq(x)

    def local_loss(self, i_data: tuple[jnp.ndarray, jnp.ndarray],
                   x: jnp.ndarray) -> jnp.ndarray:
        """Loss of one agent given its (A_i, b_i)."""
        A_i, b_i = i_data
        logits = A_i @ x * b_i
        return jnp.mean(jnp.log1p(jnp.exp(-logits))) + self.eps * self._reg(x)

    def losses(self, x_stack: jnp.ndarray) -> jnp.ndarray:
        """Per-agent losses for stacked models ``x_stack`` of shape (N, n)."""
        return jax.vmap(lambda A_i, b_i, x: self.local_loss((A_i, b_i), x))(
            self.A, self.b, x_stack)

    def local_grad(self, i_data, x):
        return jax.grad(lambda xx: self.local_loss(i_data, xx))(x)

    def grads(self, x_stack: jnp.ndarray) -> jnp.ndarray:
        """Per-agent gradients, stacked (N, n); x_stack may be (N, n) or (n,)."""
        if x_stack.ndim == 1:
            x_stack = jnp.broadcast_to(x_stack, (self.n_agents,) + x_stack.shape)
        return jax.vmap(lambda A_i, b_i, x: self.local_grad((A_i, b_i), x))(
            self.A, self.b, x_stack)

    def minibatch_grad(self, i_data, x, idx):
        """Stochastic gradient on rows ``idx`` of one agent's dataset."""
        A_i, b_i = i_data
        return jax.grad(
            lambda xx: self.local_loss((A_i[idx], b_i[idx]), xx))(x)

    # -- the paper's convergence criterion ----------------------------------
    def criterion(self, x_stack: jnp.ndarray) -> jnp.ndarray:
        """``|| sum_i grad f_i(x_bar) ||^2`` with ``x_bar = mean_i x_i``."""
        x_bar = jnp.mean(x_stack, axis=0) if x_stack.ndim > 1 else x_stack
        g = self.grads(jnp.broadcast_to(x_bar, (self.n_agents, self.dim)))
        return jnp.sum(jnp.sum(g, axis=0) ** 2)

    # -- curvature estimates -------------------------------------------------
    def smoothness(self) -> float:
        """Upper bound on the smoothness modulus of every f_i."""
        # logistic: Hessian <= A^T A / (4 q); reg adds eps (l2sq) or 2*eps.
        lams = []
        A = np.asarray(self.A)
        for i in range(self.n_agents):
            s = np.linalg.norm(A[i], ord=2)
            lams.append(s * s / (4.0 * self.q))
        reg_smooth = 2.0 * self.eps if self.nonconvex else self.eps
        return float(np.max(lams) + reg_smooth)

    def strong_convexity(self) -> float:
        """Strong-convexity modulus (convex case: eps from the l2 reg)."""
        if self.nonconvex:
            return 0.0
        return float(self.eps)

    # -- Remark 1: per-agent moduli for uncoordinated local solvers -------
    def per_agent_smoothness(self) -> jnp.ndarray:
        A = np.asarray(self.A)
        lams = [np.linalg.norm(A[i], ord=2) ** 2 / (4.0 * self.q)
                for i in range(self.n_agents)]
        reg = 2.0 * self.eps if self.nonconvex else self.eps
        return jnp.asarray(np.array(lams) + reg)

    def per_agent_strong_convexity(self) -> jnp.ndarray:
        mu = 0.0 if self.nonconvex else self.eps
        return jnp.full((self.n_agents,), mu)

    # -- oracle solution -----------------------------------------------------
    def solve(self, iters: int = 20_000) -> jnp.ndarray:
        """High-accuracy solution of ``min_x sum_i f_i(x)`` by full GD
        (used as the oracle x-bar in tests)."""
        L = self.smoothness() * self.n_agents
        step = 1.0 / L

        def total_grad(x):
            return jnp.sum(self.grads(
                jnp.broadcast_to(x, (self.n_agents, self.dim))), axis=0)

        def body(x, _):
            return x - step * total_grad(x), None

        x, _ = jax.lax.scan(body, jnp.zeros(self.dim), None, length=iters)
        return x


def make_logreg_problem(key=None, n_agents: int = 100, q: int = 250,
                        dim: int = 5, eps: float = 0.5,
                        nonconvex: bool = False,
                        heterogeneity: float = 1.0,
                        seed: int = 0) -> LogRegProblem:
    """Random logistic-regression federation (paper Section VII set-up).

    ``heterogeneity`` shifts each agent's feature distribution by an
    agent-specific offset, producing non-IID local data.
    """
    if key is None:
        key = jax.random.PRNGKey(seed)
    k1, k2, k3, k4 = jax.random.split(key, 4)
    ground_truth = jax.random.normal(k1, (dim,))
    offsets = heterogeneity * jax.random.normal(k2, (n_agents, 1, dim))
    A = jax.random.normal(k3, (n_agents, q, dim)) + offsets
    logits = jnp.einsum("nqd,d->nq", A, ground_truth)
    noise = 0.5 * jax.random.normal(k4, (n_agents, q))
    b = jnp.where(logits + noise > 0, 1.0, -1.0)
    # balance roughly 50/50 by construction (random gt, centered features)
    return LogRegProblem(A=A, b=b, eps=eps, nonconvex=nonconvex)


def dirichlet_partition(features: np.ndarray, labels: np.ndarray,
                        n_agents: int, alpha: float = 0.5,
                        seed: int = 0) -> tuple[np.ndarray, np.ndarray]:
    """Non-IID label-skew partitioner (Dirichlet over label proportions).

    Returns per-agent stacked arrays trimmed to equal size
    ``(N, q_min, n)`` / ``(N, q_min)`` so they vectorize.
    """
    rng = np.random.default_rng(seed)
    classes = np.unique(labels)
    agent_rows: list[list[int]] = [[] for _ in range(n_agents)]
    for c in classes:
        rows = np.flatnonzero(labels == c)
        rng.shuffle(rows)
        props = rng.dirichlet(alpha * np.ones(n_agents))
        counts = np.floor(props * len(rows)).astype(int)
        counts[-1] = len(rows) - counts[:-1].sum()
        start = 0
        for i, cnt in enumerate(counts):
            agent_rows[i].extend(rows[start:start + cnt])
            start += cnt
    q_min = max(1, min(len(r) for r in agent_rows))
    feats = np.stack([features[r[:q_min]] for r in agent_rows])
    labs = np.stack([labels[r[:q_min]] for r in agent_rows])
    return feats, labs


# ---------------------------------------------------------------------------
# Quadratic problems (closed-form optimum; used by tests/property checks)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class QuadraticProblem:
    """``f_i(x) = x^T Q_i x / 2 + c_i^T x`` with SPD ``Q_i``; the federated
    optimum is available in closed form."""

    Q: jnp.ndarray    # (N, n, n), SPD
    c: jnp.ndarray    # (N, n)

    @property
    def n_agents(self):
        return self.Q.shape[0]

    @property
    def dim(self):
        return self.Q.shape[-1]

    def local_loss(self, i_data, x):
        Q_i, c_i = i_data
        return 0.5 * x @ Q_i @ x + c_i @ x

    def losses(self, x_stack):
        return jax.vmap(lambda Q_i, c_i, x: self.local_loss((Q_i, c_i), x))(
            self.Q, self.c, x_stack)

    def grads(self, x_stack):
        if x_stack.ndim == 1:
            x_stack = jnp.broadcast_to(x_stack, (self.n_agents,) + x_stack.shape)
        return jnp.einsum("nij,nj->ni", self.Q, x_stack) + self.c

    def minibatch_grad(self, i_data, x, idx):
        del idx
        Q_i, c_i = i_data
        return Q_i @ x + c_i

    def criterion(self, x_stack):
        x_bar = jnp.mean(x_stack, axis=0) if x_stack.ndim > 1 else x_stack
        g = jnp.sum(self.grads(
            jnp.broadcast_to(x_bar, (self.n_agents, self.dim))), axis=0)
        return jnp.sum(g ** 2)

    def solve(self):
        return jnp.linalg.solve(jnp.sum(self.Q, axis=0),
                                -jnp.sum(self.c, axis=0))

    def smoothness(self):
        return float(jnp.max(jax.vmap(
            lambda Q: jnp.linalg.eigvalsh(Q)[-1])(self.Q)))

    def strong_convexity(self):
        return float(jnp.min(jax.vmap(
            lambda Q: jnp.linalg.eigvalsh(Q)[0])(self.Q)))


def make_quadratic_problem(key=None, n_agents: int = 10, dim: int = 8,
                           cond: float = 10.0, seed: int = 0):
    if key is None:
        key = jax.random.PRNGKey(seed)
    k1, k2 = jax.random.split(key)
    eigs = jnp.linspace(1.0, cond, dim)

    def one(k):
        H = jax.random.normal(k, (dim, dim))
        Qmat, _ = jnp.linalg.qr(H)
        return (Qmat * eigs) @ Qmat.T

    Q = jax.vmap(one)(jax.random.split(k1, n_agents))
    c = jax.random.normal(k2, (n_agents, dim))
    return QuadraticProblem(Q=Q, c=c)
