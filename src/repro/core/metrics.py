"""Convergence metrics and the paper's (t_G, t_C) time model (Sec. VII).

The paper measures "computational time to reach
|| sum_i grad f_i(x_bar) ||^2 <= 1e-5" with per-round costs from Table II,
e.g. Fed-PLT costs ``(N_e t_G + t_C) N`` per round.
"""

from __future__ import annotations

import dataclasses

import numpy as np

THRESHOLD = 1e-5


def hitting_round(crit_history: np.ndarray,
                  threshold: float = THRESHOLD) -> int | None:
    """First round index (1-based) whose criterion is below threshold."""
    hit = np.flatnonzero(np.asarray(crit_history) <= threshold)
    return int(hit[0]) + 1 if hit.size else None


def time_to_converge(crit_history, time_per_round, t_G=1.0, t_C=10.0,
                     threshold: float = THRESHOLD,
                     steps_per_round: int = 1) -> float | None:
    """Paper metric: rounds-to-threshold x per-round cost.

    ``steps_per_round`` converts per-*step* histories (ProxSkip/TAMUNA
    record every gradient step) into nominal rounds.
    """
    k = hitting_round(crit_history, threshold)
    if k is None:
        return None
    return (k / steps_per_round) * time_per_round(t_G, t_C) * steps_per_round


@dataclasses.dataclass
class RunResult:
    name: str
    rounds: int | None
    comp_time: float | None
    final_crit: float

    def row(self):
        return (self.name,
                "-" if self.rounds is None else self.rounds,
                "-" if self.comp_time is None else f"{self.comp_time:.4g}",
                f"{self.final_crit:.3e}")


def evaluate(name, crit_history, time_per_round, t_G=1.0, t_C=10.0,
             threshold=THRESHOLD) -> RunResult:
    crit = np.asarray(crit_history)
    k = hitting_round(crit, threshold)
    t = None if k is None else k * time_per_round(t_G, t_C)
    return RunResult(name=name, rounds=k, comp_time=t,
                     final_crit=float(crit[-1]))
