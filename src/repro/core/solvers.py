"""Local training solvers (paper Section IV-B), pytree-general.

Every solver approximates the local proximal update

    x_{i,k+1} ~= prox_{rho f_i}(v_i) = argmin_w d_i(w),
    d_i(w) = f_i(w) + ||w - v_i||^2 / (2 rho)

by ``N_e`` epochs, **warm-started at the previous local state** (the
initialization that makes Fed-PLT contractive, Section V-C1).

States, reflections, and gradients are arbitrary pytrees -- a bare
``jnp.ndarray`` (the dense convex experiments, per-agent under ``vmap``)
is the single-leaf case; model-parameter pytrees whose leaves carry a
leading agent axis (``batched=True``) are the model-scale case used by
:mod:`repro.fed.engine`.

A solver is driven by a stochastic gradient oracle
``fgrad(w, key) -> grad f_i(w)`` (deterministic solvers ignore ``key``;
with ``has_aux`` the oracle returns ``(grad, aux)`` and the stacked
per-epoch aux is returned alongside the iterate).

Solvers:
  * ``gd``        -- gradient descent, Eq. (11)
  * ``agd``       -- accelerated (Nesterov) GD with constant momentum, Eq. (12)
  * ``sgd``       -- minibatch SGD (oracle supplies the minibatch gradient)
  * ``noisy_gd``  -- DP noisy GD, Eq. (13):  w += -gamma grad d + t,
                     t ~ sqrt(2 gamma) N(0, tau^2 I)

``use_pallas=True`` routes the inner update through the fused
``fedplt_update`` Pallas kernel (3 HBM reads + 1 write per leaf instead
of XLA's unfused round-trips) whenever the step size is a static float.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

GradOracle = Callable[[Any, jax.Array], Any]

tree_map = jax.tree_util.tree_map


@dataclasses.dataclass(frozen=True)
class SolverConfig:
    name: str = "gd"                  # gd | agd | sgd | noisy_gd
    n_epochs: int = 5                 # N_e
    step_size: Optional[float] = None  # gamma; None -> optimal for moduli
    tau: float = 0.0                  # DP noise std (noisy_gd)
    clip: Optional[float] = None      # clip threshold C for grads (DP)

    def resolve_step_size(self, mu_d: float, L_d: float) -> float:
        """gamma* = 2/(L_d + mu_d) minimizes the GD contraction factor
        chi = max(|1 - gamma mu_d|, |1 - gamma L_d|) (Lemma 2)."""
        if self.step_size is not None:
            return self.step_size
        return 2.0 / (L_d + mu_d)


def grad_norm(g: Any, *, batched: bool = False) -> jnp.ndarray:
    """l2 norm across all leaves; per-agent (over the leading axis) when
    ``batched``."""
    leaves = jax.tree_util.tree_leaves(g)
    if batched:
        sq = sum(jnp.sum(jnp.square(l.astype(jnp.float32)).reshape(
            l.shape[0], -1), axis=-1) for l in leaves)
    else:
        sq = sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                 for l in leaves)
    return jnp.sqrt(sq)


def clip_grad(g: Any, clip: Optional[float], *,
              batched: bool = False) -> Any:
    """Norm clipping ``g * min(1, C / ||g||)`` (paper Assumption 3 remark).

    The norm is over the whole gradient pytree -- per agent when
    ``batched`` (leaves carry a leading agent axis).
    """
    if clip is None:
        return g
    nrm = grad_norm(g, batched=batched)
    factor = jnp.minimum(1.0, clip / jnp.maximum(nrm, 1e-12))

    def scale(l):
        f = factor.reshape((-1,) + (1,) * (l.ndim - 1)) if batched \
            else factor
        return l * f.astype(l.dtype)

    return tree_map(scale, g)


def _leaf_noise(w: Any, key: jax.Array, scale) -> Any:
    """Per-leaf Gaussian noise tree (fp32), one folded key per leaf.

    A single-leaf tree (the dense front end) draws straight from ``key``
    -- the exact PRNG stream of the pre-refactor implementation, so
    seeded DP experiments reproduce bit-for-bit."""
    leaves, treedef = jax.tree_util.tree_flatten(w)
    if len(leaves) == 1:
        noise = [scale * jax.random.normal(key, leaves[0].shape,
                                           jnp.float32)]
    else:
        noise = [scale * jax.random.normal(jax.random.fold_in(key, i),
                                           l.shape, jnp.float32)
                 for i, l in enumerate(leaves)]
    return jax.tree_util.tree_unflatten(treedef, noise)


def local_train(fgrad: GradOracle, w0: Any, v: Any, rho: float,
                cfg: SolverConfig, key: jax.Array, mu, L, *,
                batched: bool = False, has_aux: bool = False,
                use_pallas: bool = False):
    """Run ``cfg.n_epochs`` epochs of the chosen solver on d(w).

    ``mu``/``L`` are strong convexity / smoothness of f_i; d adds 1/rho to
    both.  Returns ``w_{N_e}`` (and the stacked per-epoch oracle aux when
    ``has_aux``).
    """
    mu_d, L_d = mu + 1.0 / rho, L + 1.0 / rho
    gamma = cfg.resolve_step_size(mu_d, L_d)
    inv_rho = 1.0 / rho
    # the fused kernel needs a static step size (pallas_call specializes
    # on it); traced moduli (vmapped per-agent gamma) fall back to XLA
    fused = use_pallas and isinstance(gamma, float) and cfg.name != "agd"

    def dgrad(w, k):
        out = fgrad(w, k)
        g, aux = out if has_aux else (out, None)
        return clip_grad(g, cfg.clip, batched=batched), aux

    def step_leaf(wl, gl, vl, tl):
        """w - gamma (g + inv_rho (w - v)) [+ t], fp32 accumulation."""
        if fused:
            from repro.kernels.fedplt_update.ops import fedplt_update
            return fedplt_update(wl, gl, vl, t=tl, gamma=gamma,
                                 inv_rho=inv_rho)
        new = (wl.astype(jnp.float32)
               - gamma * (gl.astype(jnp.float32)
                          + inv_rho * (wl.astype(jnp.float32)
                                       - vl.astype(jnp.float32))))
        if tl is not None:
            new = new + tl
        return new.astype(wl.dtype)

    def tree_step(w, g, noise):
        if noise is None:
            return tree_map(lambda wl, gl, vl: step_leaf(wl, gl, vl, None),
                            w, g, v)
        return tree_map(step_leaf, w, g, v, noise)

    keys = jax.random.split(key, cfg.n_epochs)

    if cfg.name in ("gd", "sgd"):
        def body(w, k):
            g, aux = dgrad(w, k)
            return tree_step(w, g, None), aux

        w, aux = jax.lax.scan(body, w0, keys)
        return (w, aux) if has_aux else w

    if cfg.name == "noisy_gd":
        noise_scale = jnp.sqrt(2.0 * gamma) * cfg.tau

        def body(w, k):
            k_batch, k_noise = jax.random.split(k)
            g, aux = dgrad(w, k_batch)
            return tree_step(w, g, _leaf_noise(w, k_noise, noise_scale)), aux

        w, aux = jax.lax.scan(body, w0, keys)
        return (w, aux) if has_aux else w

    if cfg.name == "agd":
        # Eq. (12): constant step 1/L_d, constant momentum beta.
        beta = ((jnp.sqrt(L_d) - jnp.sqrt(mu_d))
                / (jnp.sqrt(L_d) + jnp.sqrt(mu_d)))

        def body(carry, k):
            w, u_prev = carry
            g, aux = dgrad(w, k)
            u = tree_map(
                lambda wl, gl, vl: (wl.astype(jnp.float32)
                                    - (gl.astype(jnp.float32)
                                       + inv_rho * (wl.astype(jnp.float32)
                                                    - vl.astype(jnp.float32)))
                                    / L_d).astype(wl.dtype),
                w, g, v)
            w_next = tree_map(
                lambda ul, upl: (ul.astype(jnp.float32)
                                 + beta * (ul.astype(jnp.float32)
                                           - upl.astype(jnp.float32))
                                 ).astype(ul.dtype),
                u, u_prev)
            return (w_next, u), aux

        (w, _), aux = jax.lax.scan(body, (w0, w0), keys)
        return (w, aux) if has_aux else w

    raise ValueError(f"unknown solver {cfg.name!r}")


def solver_contraction(cfg: SolverConfig, mu: float, L: float,
                       rho: float) -> float:
    """Contraction factor of the *whole* local training map
    (chi^{N_e} for GD-type, chi(N_e) of Prop. 3 for AGD)."""
    mu_d, L_d = mu + 1.0 / rho, L + 1.0 / rho
    if cfg.name in ("gd", "sgd", "noisy_gd"):
        gamma = cfg.resolve_step_size(mu_d, L_d)
        chi = max(abs(1.0 - gamma * mu_d), abs(1.0 - gamma * L_d))
        return float(chi ** cfg.n_epochs)
    if cfg.name == "agd":
        kappa = L_d / mu_d
        return float((1.0 + kappa) * (1.0 - (1.0 / kappa) ** 0.5) ** cfg.n_epochs)
    raise ValueError(cfg.name)
