"""Local training solvers (paper Section IV-B).

Every solver approximates the local proximal update

    x_{i,k+1} ~= prox_{rho f_i}(v_i) = argmin_w d_i(w),
    d_i(w) = f_i(w) + ||w - v_i||^2 / (2 rho)

by ``N_e`` epochs, **warm-started at the previous local state** (the
initialization that makes Fed-PLT contractive, Section V-C1).

A solver is driven by a per-agent stochastic gradient oracle
``fgrad(w, key) -> grad f_i(w)`` (deterministic solvers ignore ``key``).

Solvers:
  * ``gd``        -- gradient descent, Eq. (11)
  * ``agd``       -- accelerated (Nesterov) GD with constant momentum, Eq. (12)
  * ``sgd``       -- minibatch SGD (oracle supplies the minibatch gradient)
  * ``noisy_gd``  -- DP noisy GD, Eq. (13):  w += -gamma grad d + t,
                     t ~ sqrt(2 gamma) N(0, tau^2 I)
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp

GradOracle = Callable[[jnp.ndarray, jax.Array], jnp.ndarray]


@dataclasses.dataclass(frozen=True)
class SolverConfig:
    name: str = "gd"                  # gd | agd | sgd | noisy_gd
    n_epochs: int = 5                 # N_e
    step_size: Optional[float] = None  # gamma; None -> optimal for moduli
    tau: float = 0.0                  # DP noise std (noisy_gd)
    clip: Optional[float] = None      # clip threshold L for grads (DP)

    def resolve_step_size(self, mu_d: float, L_d: float) -> float:
        """gamma* = 2/(L_d + mu_d) minimizes the GD contraction factor
        chi = max(|1 - gamma mu_d|, |1 - gamma L_d|) (Lemma 2)."""
        if self.step_size is not None:
            return self.step_size
        return 2.0 / (L_d + mu_d)


def clip_grad(g: jnp.ndarray, clip: Optional[float]) -> jnp.ndarray:
    """Norm clipping ``g * min(1, C / ||g||)`` (paper Assumption 3 remark)."""
    if clip is None:
        return g
    nrm = jnp.linalg.norm(g)
    return g * jnp.minimum(1.0, clip / jnp.maximum(nrm, 1e-12))


def local_train(fgrad: GradOracle, w0: jnp.ndarray, v: jnp.ndarray,
                rho: float, cfg: SolverConfig, key: jax.Array,
                mu: float, L: float) -> jnp.ndarray:
    """Run ``cfg.n_epochs`` epochs of the chosen solver on d(w).

    ``mu``/``L`` are strong convexity / smoothness of f_i; d adds 1/rho to
    both.  Returns w_{N_e}.
    """
    mu_d, L_d = mu + 1.0 / rho, L + 1.0 / rho
    gamma = cfg.resolve_step_size(mu_d, L_d)
    inv_rho = 1.0 / rho

    def dgrad(w, k):
        return clip_grad(fgrad(w, k), cfg.clip) + inv_rho * (w - v)

    keys = jax.random.split(key, cfg.n_epochs)

    if cfg.name in ("gd", "sgd"):
        def body(w, k):
            return w - gamma * dgrad(w, k), None

        w, _ = jax.lax.scan(body, w0, keys)
        return w

    if cfg.name == "noisy_gd":
        noise_scale = jnp.sqrt(2.0 * gamma) * cfg.tau

        def body(w, k):
            k_batch, k_noise = jax.random.split(k)
            t = noise_scale * jax.random.normal(k_noise, w.shape)
            return w - gamma * dgrad(w, k_batch) + t, None

        w, _ = jax.lax.scan(body, w0, keys)
        return w

    if cfg.name == "agd":
        # Eq. (12): constant step 1/L_d, constant momentum beta.
        beta = ((jnp.sqrt(L_d) - jnp.sqrt(mu_d))
                / (jnp.sqrt(L_d) + jnp.sqrt(mu_d)))

        def body(carry, k):
            w, u_prev = carry
            u = w - dgrad(w, k) / L_d
            w_next = u + beta * (u - u_prev)
            return (w_next, u), None

        (w, _), _ = jax.lax.scan(body, (w0, w0), keys)
        return w

    raise ValueError(f"unknown solver {cfg.name!r}")


def solver_contraction(cfg: SolverConfig, mu: float, L: float,
                       rho: float) -> float:
    """Contraction factor of the *whole* local training map
    (chi^{N_e} for GD-type, chi(N_e) of Prop. 3 for AGD)."""
    mu_d, L_d = mu + 1.0 / rho, L + 1.0 / rho
    if cfg.name in ("gd", "sgd", "noisy_gd"):
        gamma = cfg.resolve_step_size(mu_d, L_d)
        chi = max(abs(1.0 - gamma * mu_d), abs(1.0 - gamma * L_d))
        return float(chi ** cfg.n_epochs)
    if cfg.name == "agd":
        kappa = L_d / mu_d
        return float((1.0 + kappa) * (1.0 - (1.0 / kappa) ** 0.5) ** cfg.n_epochs)
    raise ValueError(cfg.name)
