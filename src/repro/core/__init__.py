"""Core Fed-PLT library: the paper's contribution as composable JAX modules.

Layout
------
problem.py   -- federated ERM problems (logistic regression, quadratics)
prox.py      -- proximal / reflective operator library
solvers.py   -- local training solvers: GD, accelerated GD, SGD, noisy GD
fedplt.py    -- Algorithm 1 (Fed-PLT): PRS-based federated learning
theory.py    -- contraction constants, S matrix, Lemma 7 stabilizer, Cor. 1
privacy.py   -- RDP/ADP accountant (Prop. 4, Lemma 5), noise calibration
baselines.py -- FedAvg, FedSplit, FedPD, FedLin, SCAFFOLD, ProxSkip,
                TAMUNA, LED, 5GCS
metrics.py   -- convergence criteria and the paper's (t_G, t_C) time model
"""
