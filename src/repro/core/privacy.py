"""Differential-privacy accountant for Fed-PLT (paper Section VI).

Implements:
  * Proposition 4: (lambda, eps)-RDP of Fed-PLT with noisy GD local
    training,

        eps_i <= lambda L^2 / (mu tau^2 q_i^2) * (1 - exp(-mu gamma K N_e / 2))

    -- crucially *bounded* as K N_e -> inf (local training does not blow up
    the privacy budget).
  * Lemma 5: RDP -> approximate DP conversion, with optimization over the
    Renyi order lambda.
  * Noise calibration: smallest tau meeting a target (eps, delta)-ADP.
"""

from __future__ import annotations

import dataclasses
import math

import numpy as np


def rdp_epsilon(lam: float, sensitivity: float, mu: float, tau: float,
                q: int, gamma: float, K: int, n_epochs: int) -> float:
    """Proposition 4 worst-case RDP bound (lam = Renyi order > 1).

    ``sensitivity`` is L of Assumption 3 (gradient sensitivity * q_i),
    ``mu`` the strong-convexity modulus (lambda underbar), ``q`` the
    smallest local dataset size.
    """
    if lam <= 1.0:
        raise ValueError("Renyi order must be > 1")
    if tau <= 0.0:
        return float("inf")
    cap = lam * sensitivity ** 2 / (mu * tau ** 2 * q ** 2)
    return float(cap * (1.0 - math.exp(-mu * gamma * K * n_epochs / 2.0)))


def rdp_epsilon_limit(lam: float, sensitivity: float, mu: float, tau: float,
                      q: int) -> float:
    """K N_e -> infinity privacy ceiling (the paper's headline bound)."""
    if tau <= 0.0:
        return float("inf")
    return float(lam * sensitivity ** 2 / (mu * tau ** 2 * q ** 2))


def rdp_to_adp(eps_rdp: float, lam: float, delta: float) -> float:
    """Lemma 5: (lam, eps)-RDP  =>  (eps + log(1/delta)/(lam-1), delta)-ADP."""
    return float(eps_rdp + math.log(1.0 / delta) / (lam - 1.0))


def adp_epsilon(sensitivity: float, mu: float, tau: float, q: int,
                gamma: float, K: int, n_epochs: int, delta: float,
                lam_grid=None) -> tuple[float, float]:
    """Best ADP epsilon over a grid of Renyi orders; returns (eps, lam*)."""
    if lam_grid is None:
        lam_grid = np.concatenate([np.linspace(1.01, 2, 25),
                                   np.linspace(2, 64, 200),
                                   np.geomspace(64, 4096, 60)])
    best_eps, best_lam = float("inf"), None
    for lam in lam_grid:
        e = rdp_to_adp(
            rdp_epsilon(lam, sensitivity, mu, tau, q, gamma, K, n_epochs),
            lam, delta)
        if e < best_eps:
            best_eps, best_lam = e, float(lam)
    return best_eps, best_lam


def calibrate_noise(target_eps: float, delta: float, sensitivity: float,
                    mu: float, q: int, gamma: float, K: int,
                    n_epochs: int, tol: float = 1e-6) -> float:
    """Smallest tau such that Fed-PLT is (target_eps, delta)-ADP
    (bisection; eps is monotone decreasing in tau).

    Raises ValueError when the target is unreachable by noise alone:
    the Lemma-5 RDP->ADP conversion floors the ADP eps at
    ``log(1/delta) / (lam_max - 1)`` over the searched Renyi orders, so
    a target below that floor cannot be met no matter how large tau is
    -- returning the bracket top silently would hand the caller a tau
    that does NOT meet the budget it asked for.
    """
    lo, hi = 1e-8, 1e6
    for _ in range(200):
        mid = math.sqrt(lo * hi)
        eps, _ = adp_epsilon(sensitivity, mu, mid, q, gamma, K, n_epochs,
                             delta)
        if eps > target_eps:
            lo = mid
        else:
            hi = mid
        if hi / lo < 1.0 + tol:
            break
    achieved, _ = adp_epsilon(sensitivity, mu, hi, q, gamma, K, n_epochs,
                              delta)
    if not achieved <= target_eps * (1.0 + 10.0 * tol):
        raise ValueError(
            f"target eps={target_eps:.4g} is unreachable by noise "
            f"calibration: best achievable eps={achieved:.4g} at "
            f"tau={hi:.3g} (Lemma 5 floors ADP eps at "
            f"log(1/delta)/(lambda-1) over the searched Renyi orders)")
    return hi


@dataclasses.dataclass(frozen=True)
class AgentPrivacy:
    """One agent's row of the per-agent (eps_i, delta) table (Prop. 4 is
    a per-agent bound: eps_i depends on q_i, gamma_i, and N_e,i)."""
    agent: int
    q: int
    n_epochs: int
    gamma: float
    adp_eps: float
    rdp_order: float
    eps_ceiling: float
    # Async (bounded-staleness) runs compose over the agent's REALIZED
    # schedule: K is its effective round count (rounds of local epochs
    # actually released; None = the report's nominal K) and arrivals how
    # many increments it transmitted.  Synchronous reports leave both
    # None.
    K: int = None
    arrivals: int = None


@dataclasses.dataclass(frozen=True)
class PrivacyReport:
    """Summary of the privacy position of one Fed-PLT configuration.

    ``per_agent`` is None for a homogeneous run (every agent shares the
    scalar fields); for heterogeneous runs it carries one
    :class:`AgentPrivacy` row per agent and the scalar ``adp_eps`` /
    ``eps_ceiling`` are the MAX over agents (the budget the deployment
    as a whole must honor), with ``n_epochs`` / ``rdp_*`` taken from
    that worst-off agent.
    """
    tau: float
    K: int
    n_epochs: int
    rdp_eps: float
    rdp_order: float
    adp_eps: float
    adp_delta: float
    eps_ceiling: float       # K*Ne -> inf limit at the same order
    per_agent: tuple = None  # tuple[AgentPrivacy, ...] | None

    @staticmethod
    def build(sensitivity, mu, tau, q, gamma, K, n_epochs,
              delta=1e-5) -> "PrivacyReport":
        eps, lam = adp_epsilon(sensitivity, mu, tau, q, gamma, K, n_epochs,
                               delta)
        return PrivacyReport(
            tau=tau, K=K, n_epochs=n_epochs,
            rdp_eps=rdp_epsilon(lam, sensitivity, mu, tau, q, gamma, K,
                                n_epochs),
            rdp_order=lam,
            adp_eps=eps, adp_delta=delta,
            eps_ceiling=rdp_to_adp(
                rdp_epsilon_limit(lam, sensitivity, mu, tau, q), lam, delta),
        )

    @staticmethod
    def build_per_agent(sensitivities, mu, tau, qs, gammas, K,
                        n_epochs_seq, delta=1e-5, Ks=None,
                        arrivals=None) -> "PrivacyReport":
        """Per-agent Prop. 4 accounting: one (eps_i, delta) row per
        agent, each with its own sensitivity / q_i / gamma_i / N_e,i and
        its own optimized Renyi order.  The headline eps is the max over
        agents.

        ``Ks`` (optional) gives each agent its own EFFECTIVE round count
        -- under bounded-staleness async rounds, the rounds of local
        epochs agent i actually released (derived from the realized
        arrival schedule by ``repro.fed.async_engine.effective_counts``;
        the K * N_e product of Prop. 4 then reflects released
        information only).  ``arrivals`` (optional) annotates each row
        with the agent's increment count; both default to the
        synchronous reading where every agent composes over the nominal
        ``K`` rounds."""
        effective = Ks is not None
        if Ks is None:
            Ks = [K] * len(qs)
        if arrivals is None:
            arrivals = [None] * len(qs)
        rows = []
        for i, (s, q, gamma, ne, ki, ai) in enumerate(
                zip(sensitivities, qs, gammas, n_epochs_seq, Ks,
                    arrivals)):
            eps, lam = adp_epsilon(s, mu, tau, q, gamma, ki, ne, delta)
            rows.append(AgentPrivacy(
                agent=i, q=q, n_epochs=ne, gamma=gamma, adp_eps=eps,
                rdp_order=lam,
                eps_ceiling=rdp_to_adp(
                    rdp_epsilon_limit(lam, s, mu, tau, q), lam, delta),
                K=ki if effective else None, arrivals=ai))
        worst = max(rows, key=lambda r: r.adp_eps)
        worst_K = worst.K if worst.K is not None else K
        return PrivacyReport(
            tau=tau, K=K, n_epochs=worst.n_epochs,
            rdp_eps=rdp_epsilon(worst.rdp_order,
                                sensitivities[worst.agent], mu, tau,
                                worst.q, worst.gamma, worst_K,
                                worst.n_epochs),
            rdp_order=worst.rdp_order,
            adp_eps=worst.adp_eps, adp_delta=delta,
            eps_ceiling=max(r.eps_ceiling for r in rows),
            per_agent=tuple(rows))
