"""Differential-privacy accountant for Fed-PLT (paper Section VI).

Implements:
  * Proposition 4: (lambda, eps)-RDP of Fed-PLT with noisy GD local
    training,

        eps_i <= lambda L^2 / (mu tau^2 q_i^2) * (1 - exp(-mu gamma K N_e / 2))

    -- crucially *bounded* as K N_e -> inf (local training does not blow up
    the privacy budget).
  * Lemma 5: RDP -> approximate DP conversion, with optimization over the
    Renyi order lambda.
  * Noise calibration: smallest tau meeting a target (eps, delta)-ADP.
"""

from __future__ import annotations

import dataclasses
import math

import numpy as np


def rdp_epsilon(lam: float, sensitivity: float, mu: float, tau: float,
                q: int, gamma: float, K: int, n_epochs: int) -> float:
    """Proposition 4 worst-case RDP bound (lam = Renyi order > 1).

    ``sensitivity`` is L of Assumption 3 (gradient sensitivity * q_i),
    ``mu`` the strong-convexity modulus (lambda underbar), ``q`` the
    smallest local dataset size.
    """
    if lam <= 1.0:
        raise ValueError("Renyi order must be > 1")
    if tau <= 0.0:
        return float("inf")
    cap = lam * sensitivity ** 2 / (mu * tau ** 2 * q ** 2)
    return float(cap * (1.0 - math.exp(-mu * gamma * K * n_epochs / 2.0)))


def rdp_epsilon_limit(lam: float, sensitivity: float, mu: float, tau: float,
                      q: int) -> float:
    """K N_e -> infinity privacy ceiling (the paper's headline bound)."""
    if tau <= 0.0:
        return float("inf")
    return float(lam * sensitivity ** 2 / (mu * tau ** 2 * q ** 2))


def rdp_to_adp(eps_rdp: float, lam: float, delta: float) -> float:
    """Lemma 5: (lam, eps)-RDP  =>  (eps + log(1/delta)/(lam-1), delta)-ADP."""
    return float(eps_rdp + math.log(1.0 / delta) / (lam - 1.0))


def adp_epsilon(sensitivity: float, mu: float, tau: float, q: int,
                gamma: float, K: int, n_epochs: int, delta: float,
                lam_grid=None) -> tuple[float, float]:
    """Best ADP epsilon over a grid of Renyi orders; returns (eps, lam*)."""
    if lam_grid is None:
        lam_grid = np.concatenate([np.linspace(1.01, 2, 25),
                                   np.linspace(2, 64, 200),
                                   np.geomspace(64, 4096, 60)])
    best_eps, best_lam = float("inf"), None
    for lam in lam_grid:
        e = rdp_to_adp(
            rdp_epsilon(lam, sensitivity, mu, tau, q, gamma, K, n_epochs),
            lam, delta)
        if e < best_eps:
            best_eps, best_lam = e, float(lam)
    return best_eps, best_lam


def calibrate_noise(target_eps: float, delta: float, sensitivity: float,
                    mu: float, q: int, gamma: float, K: int,
                    n_epochs: int, tol: float = 1e-6) -> float:
    """Smallest tau such that Fed-PLT is (target_eps, delta)-ADP
    (bisection; eps is monotone decreasing in tau)."""
    lo, hi = 1e-8, 1e6
    for _ in range(200):
        mid = math.sqrt(lo * hi)
        eps, _ = adp_epsilon(sensitivity, mu, mid, q, gamma, K, n_epochs,
                             delta)
        if eps > target_eps:
            lo = mid
        else:
            hi = mid
        if hi / lo < 1.0 + tol:
            break
    return hi


@dataclasses.dataclass(frozen=True)
class PrivacyReport:
    """Summary of the privacy position of one Fed-PLT configuration."""
    tau: float
    K: int
    n_epochs: int
    rdp_eps: float
    rdp_order: float
    adp_eps: float
    adp_delta: float
    eps_ceiling: float       # K*Ne -> inf limit at the same order

    @staticmethod
    def build(sensitivity, mu, tau, q, gamma, K, n_epochs,
              delta=1e-5) -> "PrivacyReport":
        eps, lam = adp_epsilon(sensitivity, mu, tau, q, gamma, K, n_epochs,
                               delta)
        return PrivacyReport(
            tau=tau, K=K, n_epochs=n_epochs,
            rdp_eps=rdp_epsilon(lam, sensitivity, mu, tau, q, gamma, K,
                                n_epochs),
            rdp_order=lam,
            adp_eps=eps, adp_delta=delta,
            eps_ceiling=rdp_to_adp(
                rdp_epsilon_limit(lam, sensitivity, mu, tau, q), lam, delta),
        )
