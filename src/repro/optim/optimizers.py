"""Minimal optimizer library (standard, non-federated training mode).

Optax-style triples: ``init(params) -> state``, ``update(grads, state,
params) -> (updates, state)``, plus ``apply_updates``.
"""

from __future__ import annotations

from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp


class Optimizer(NamedTuple):
    init: Callable[[Any], Any]
    update: Callable[..., tuple]


def apply_updates(params, updates):
    return jax.tree_util.tree_map(
        lambda p, u: (p.astype(jnp.float32) + u).astype(p.dtype),
        params, updates)


def sgd(lr: float) -> Optimizer:
    def init(params):
        return ()

    def update(grads, state, params=None):
        return jax.tree_util.tree_map(
            lambda g: -lr * g.astype(jnp.float32), grads), state

    return Optimizer(init, update)


def momentum(lr: float, beta: float = 0.9) -> Optimizer:
    def init(params):
        return jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)

    def update(grads, m, params=None):
        m = jax.tree_util.tree_map(
            lambda mm, g: beta * mm + g.astype(jnp.float32), m, grads)
        return jax.tree_util.tree_map(lambda mm: -lr * mm, m), m

    return Optimizer(init, update)


def adamw(lr: float, b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
          weight_decay: float = 0.0) -> Optimizer:
    class State(NamedTuple):
        mu: Any
        nu: Any
        t: jnp.ndarray

    def init(params):
        z = lambda: jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
        return State(mu=z(), nu=z(), t=jnp.zeros((), jnp.int32))

    def update(grads, state, params):
        t = state.t + 1
        mu = jax.tree_util.tree_map(
            lambda m, g: b1 * m + (1 - b1) * g.astype(jnp.float32),
            state.mu, grads)
        nu = jax.tree_util.tree_map(
            lambda v, g: b2 * v + (1 - b2) * jnp.square(
                g.astype(jnp.float32)), state.nu, grads)
        mu_hat = jax.tree_util.tree_map(lambda m: m / (1 - b1 ** t), mu)
        nu_hat = jax.tree_util.tree_map(lambda v: v / (1 - b2 ** t), nu)
        upd = jax.tree_util.tree_map(
            lambda m, v, p: -lr * (m / (jnp.sqrt(v) + eps)
                                   + weight_decay * p.astype(jnp.float32)),
            mu_hat, nu_hat, params)
        return upd, State(mu=mu, nu=nu, t=t)

    return Optimizer(init, update)
