"""Optimizers for standard (non-federated) training mode."""

from repro.optim.optimizers import sgd, momentum, adamw, apply_updates  # noqa: F401
