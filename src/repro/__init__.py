"""repro -- Fed-PLT (Federated Private Local Training) in JAX.

A production-grade, multi-pod JAX framework reproducing and extending

    "Enhancing Privacy in Federated Learning through Local Training"
    N. Bastianello, C. Liu, K. H. Johansson (2024).
"""

__version__ = "1.0.0"
