"""The public Model bundle + input_specs for every (arch x shape) pair."""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.configs.base import InputShape, ModelConfig
from repro.models import decode as decode_lib
from repro.models import transformer as tfm


@dataclasses.dataclass(frozen=True)
class Model:
    config: ModelConfig
    init: Callable[[jax.Array], Any]
    loss_fn: Callable[..., jnp.ndarray]          # (params, batch)
    forward: Callable[..., tuple]                # (params, batch) -> logits
    init_cache: Callable[..., Any]               # (batch, cache_len, long)
    decode_step: Callable[..., tuple]            # (params, cache, tokens)

    def param_count(self, params) -> int:
        return sum(x.size for x in jax.tree_util.tree_leaves(params))


def build_model(cfg: ModelConfig) -> Model:
    return Model(
        config=cfg,
        init=partial(tfm.init_params, cfg=cfg),
        loss_fn=partial(tfm.loss_fn, cfg=cfg),
        forward=partial(tfm.forward, cfg=cfg),
        init_cache=partial(decode_lib.init_cache, cfg),
        decode_step=partial(decode_lib.decode_step, cfg=cfg),
    )


# ---------------------------------------------------------------------------
# input_specs: ShapeDtypeStruct stand-ins for every model input
# ---------------------------------------------------------------------------

def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, jnp.dtype(dtype))


def batch_specs(cfg: ModelConfig, shape: InputShape,
                with_labels: bool) -> dict:
    """Specs of the data batch for train/prefill modes."""
    B, S = shape.global_batch, shape.seq_len
    dtype = cfg.dtype
    specs = {}
    if cfg.n_enc_layers:                     # enc-dec (whisper)
        specs["enc_embeds"] = _sds((B, cfg.n_enc_tokens, cfg.d_model), dtype)
        specs["tokens"] = _sds((B, S), "int32")
    elif cfg.frontend == "vision":
        n_front = cfg.n_frontend_tokens
        specs["patch_embeds"] = _sds((B, n_front, cfg.d_model), dtype)
        specs["tokens"] = _sds((B, S - n_front), "int32")
    else:
        specs["tokens"] = _sds((B, S), "int32")
    if with_labels:
        label_len = specs["tokens"].shape[1]
        specs["labels"] = _sds((B, label_len), "int32")
    return specs


def cache_specs(cfg: ModelConfig, shape: InputShape) -> Any:
    long_ctx = shape.name == "long_500k"
    return jax.eval_shape(
        lambda: decode_lib.init_cache(cfg, shape.global_batch,
                                      shape.seq_len, long_ctx))


def input_specs(cfg: ModelConfig, shape: InputShape) -> dict:
    """All inputs of the step lowered for this shape (params excluded)."""
    if shape.kind == "train":
        return {"batch": batch_specs(cfg, shape, with_labels=True)}
    if shape.kind == "prefill":
        return {"batch": batch_specs(cfg, shape, with_labels=False)}
    # decode
    return {
        "cache": cache_specs(cfg, shape),
        "tokens": _sds((shape.global_batch,), "int32"),
    }


def shape_supported(cfg: ModelConfig, shape: InputShape) -> tuple[bool, str]:
    """Whether (arch, shape) is runnable; (False, reason) records the skip."""
    if shape.name == "long_500k" and not cfg.supports_long_ctx:
        return False, ("pure full-attention architecture: long_500k "
                       "requires sub-quadratic attention (DESIGN.md skip)")
    return True, ""
