"""Elementary layers shared across the zoo (pure-jnp, shard-friendly)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# Normalization
# ---------------------------------------------------------------------------

def rms_norm(x: jnp.ndarray, weight: jnp.ndarray,
             eps: float = 1e-6) -> jnp.ndarray:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * (1.0 + weight.astype(jnp.float32))).astype(dtype)


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------

def rope_frequencies(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2,
                                       dtype=jnp.float32) / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray,
               theta: float) -> jnp.ndarray:
    """x: (..., S, H, D); positions: (S,) or broadcastable to x[..., :, 0, 0]."""
    freqs = rope_frequencies(x.shape[-1], theta)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, D/2)
    cos = jnp.cos(angles)[..., None, :]  # (..., S, 1, D/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Activations / MLPs
# ---------------------------------------------------------------------------

def squared_relu(x):
    r = jax.nn.relu(x)
    return r * r


_GATED = {"swiglu": jax.nn.silu, "geglu": jax.nn.gelu}
_PLAIN = {"relu2": squared_relu, "gelu": jax.nn.gelu}


def init_mlp(key, d_model: int, d_ff: int, activation: str, dtype):
    k1, k2 = jax.random.split(key)
    width = 2 * d_ff if activation in _GATED else d_ff
    s_in = d_model ** -0.5
    s_out = d_ff ** -0.5
    return {
        "wi": (s_in * jax.random.normal(k1, (d_model, width))).astype(dtype),
        "wo": (s_out * jax.random.normal(k2, (d_ff, d_model))).astype(dtype),
    }


def mlp(params, x: jnp.ndarray, activation: str) -> jnp.ndarray:
    h = x @ params["wi"]
    if activation in _GATED:
        gate, up = jnp.split(h, 2, axis=-1)
        h = _GATED[activation](gate) * up
    else:
        h = _PLAIN[activation](h)
    return h @ params["wo"]


def mlp_flops(d_model: int, d_ff: int, activation: str, n_tokens: int) -> float:
    width = 2 * d_ff if activation in _GATED else d_ff
    return 2.0 * n_tokens * d_model * (width + d_ff)


# ---------------------------------------------------------------------------
# Misc
# ---------------------------------------------------------------------------

def softcap(x: jnp.ndarray, cap: float | None) -> jnp.ndarray:
    if cap is None:
        return x
    return cap * jnp.tanh(x / cap)


def causal_conv1d(x: jnp.ndarray, w: jnp.ndarray,
                  b: jnp.ndarray | None = None) -> jnp.ndarray:
    """Depthwise causal temporal conv. x: (B, S, C); w: (K, C)."""
    K = w.shape[0]
    pad = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    out = jnp.zeros_like(x)
    for k in range(K):  # K is tiny (4): unrolled taps, no conv primitive
        out = out + pad[:, k:k + x.shape[1], :] * w[k]
    if b is not None:
        out = out + b
    return out


def conv1d_step(conv_state: jnp.ndarray, x_t: jnp.ndarray, w: jnp.ndarray,
                b: jnp.ndarray | None = None):
    """Single decode step of causal_conv1d.

    conv_state: (B, K-1, C) past inputs; x_t: (B, C). Returns (y_t, new_state).
    """
    window = jnp.concatenate([conv_state, x_t[:, None, :]], axis=1)  # (B,K,C)
    y = jnp.einsum("bkc,kc->bc", window, w)
    if b is not None:
        y = y + b
    return y, window[:, 1:, :]


def chunked_cross_entropy(x: jnp.ndarray, head: jnp.ndarray,
                          labels: jnp.ndarray, chunk: int,
                          cap: float | None = None) -> jnp.ndarray:
    """Token CE without materializing the (B, S, V) logits: lax.scan over
    vocab chunks with an online logsumexp (beyond-paper memory
    optimization; see EXPERIMENTS.md section Perf)."""
    V = head.shape[1]
    if V % chunk:
        chunk = V
    n_chunks = V // chunk
    hc = head.reshape(head.shape[0], n_chunks, chunk).transpose(1, 0, 2)

    def body(carry, inp):
        m, l, gold = carry
        h_c, c_idx = inp
        logits = (x @ h_c).astype(jnp.float32)       # (B, S, chunk)
        logits = softcap(logits, cap)
        m_new = jnp.maximum(m, jnp.max(logits, axis=-1))
        l_new = l * jnp.exp(m - m_new) + jnp.sum(
            jnp.exp(logits - m_new[..., None]), axis=-1)
        local = labels - c_idx * chunk
        valid = (local >= 0) & (local < chunk)
        picked = jnp.take_along_axis(
            logits, jnp.clip(local, 0, chunk - 1)[..., None],
            axis=-1)[..., 0]
        gold_new = jnp.where(valid, picked, gold)
        return (m_new, l_new, gold_new), None

    B, S = labels.shape
    m0 = jnp.full((B, S), -1e30, jnp.float32)
    l0 = jnp.zeros((B, S), jnp.float32)
    g0 = jnp.zeros((B, S), jnp.float32)
    (m, l, gold), _ = jax.lax.scan(body, (m0, l0, g0),
                                   (hc, jnp.arange(n_chunks)))
    nll = m + jnp.log(l) - gold
    return jnp.mean(nll)


def cross_entropy(logits: jnp.ndarray, labels: jnp.ndarray,
                  mask: jnp.ndarray | None = None) -> jnp.ndarray:
    """Mean token cross-entropy; logits promoted to f32."""
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None],
                               axis=-1).squeeze(-1)
    nll = logz - gold
    if mask is not None:
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)
