"""Stage-based transformer assembly for all 10 architectures.

A model is a list of *stages*; each stage is a repeating unit of layer
kinds (e.g. gemma3's ``('local',)*5 + ('global',)``) scanned ``n_units``
times with stacked parameters.  Heterogeneous patterns therefore compile
to O(len(pattern)) HLO regardless of depth (nemotron's 96 layers lower as
one scanned unit), which keeps CPU dry-run compiles tractable and is the
production pattern for TPU (same as MaxText).

Layer kinds:
  'global' -- full (causal) attention + FFN/MoE
  'local'  -- sliding-window attention + FFN/MoE
  'ssm'    -- mamba-1 block (no separate FFN)
  'rec'    -- RG-LRU block + FFN
  'enc'    -- non-causal attention + FFN (whisper encoder)
  'xdec'   -- causal self-attn + cross-attn + FFN (whisper decoder)
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn_lib
from repro.models import moe as moe_lib
from repro.models import rglru as rglru_lib
from repro.models import ssm as ssm_lib
from repro.models.layers import (apply_rope, cross_entropy, init_mlp, mlp,
                                 rms_norm, softcap)

Params = Any


# ---------------------------------------------------------------------------
# Stage structure
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Stage:
    unit: tuple            # layer kinds within the repeating unit
    n_units: int
    cross: bool = False    # decoder-with-cross-attention stage


def build_stages(cfg: ModelConfig) -> list[Stage]:
    stages = []
    if cfg.n_enc_layers:
        stages.append(Stage(unit=("enc",), n_units=cfg.n_enc_layers))
        stages.append(Stage(unit=("xdec",), n_units=cfg.n_layers,
                            cross=True))
        return stages
    unit = tuple(cfg.pattern)
    n_full, rem = divmod(cfg.n_layers, len(unit))
    if n_full:
        stages.append(Stage(unit=unit, n_units=n_full))
    if rem:
        stages.append(Stage(unit=unit[:rem], n_units=1))
    return stages


# ---------------------------------------------------------------------------
# Parameter init
# ---------------------------------------------------------------------------

def _init_layer(key, kind: str, cfg: ModelConfig, dtype):
    d = cfg.d_model
    p = {"ln1": jnp.zeros((d,), dtype)}
    ks = jax.random.split(key, 4)
    if kind == "ssm":
        p["mamba"] = ssm_lib.init_mamba(ks[0], cfg, dtype)
        return p
    if kind == "rec":
        p["rec"] = rglru_lib.init_rglru_block(ks[0], cfg, dtype)
    else:
        p["attn"] = attn_lib.init_attn(ks[0], d, cfg.n_heads,
                                       cfg.n_kv_heads,
                                       cfg.resolved_head_dim, dtype)
        if kind == "xdec":
            p["ln_x"] = jnp.zeros((d,), dtype)
            p["xattn"] = attn_lib.init_attn(ks[1], d, cfg.n_heads,
                                            cfg.n_kv_heads,
                                            cfg.resolved_head_dim, dtype)
    p["ln2"] = jnp.zeros((d,), dtype)
    if cfg.n_experts and kind in ("global", "local"):
        p["moe"] = moe_lib.init_moe(ks[2], cfg, dtype)
    else:
        p["mlp"] = init_mlp(ks[2], d, cfg.d_ff, cfg.activation, dtype)
    return p


def init_params(key, cfg: ModelConfig) -> Params:
    dtype = jnp.dtype(cfg.dtype)
    stages = build_stages(cfg)
    keys = jax.random.split(key, len(stages) + 2)
    params: dict = {"stages": []}
    for stage, k in zip(stages, keys[:-2]):
        def unit_init(kk):
            uks = jax.random.split(kk, len(stage.unit))
            return {str(i): _init_layer(uk, kind, cfg, dtype)
                    for i, (kind, uk) in enumerate(zip(stage.unit, uks))}

        params["stages"].append(
            jax.vmap(unit_init)(jax.random.split(k, stage.n_units)))
    params["embed"] = (cfg.d_model ** -0.5 * jax.random.normal(
        keys[-2], (cfg.vocab, cfg.d_model))).astype(dtype)
    params["final_norm"] = jnp.zeros((cfg.d_model,), dtype)
    if not cfg.tie_embeddings:
        params["lm_head"] = (cfg.d_model ** -0.5 * jax.random.normal(
            keys[-1], (cfg.d_model, cfg.vocab))).astype(dtype)
    return params


# ---------------------------------------------------------------------------
# Full-sequence forward (train / prefill)
# ---------------------------------------------------------------------------

def _attention_full(p, x, kind, cfg: ModelConfig, positions, enc_out=None):
    H, Hkv, D = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    q, k, v = attn_lib.qkv(p, x, n_heads=H, n_kv_heads=Hkv, head_dim=D)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    if cfg.attn_seq_shard:
        # sequence-parallel attention (beyond-paper, EXPERIMENTS.md Perf):
        # shard queries over 'model' along S, replicate the (small GQA)
        # kv along 'model' -- kills the per-chunk partial-sum all-reduces
        # GSPMD inserts when n_kv_heads < |model| (e.g. phi4: 8 kv heads
        # on a 16-way axis).
        from jax.sharding import PartitionSpec as Pspec
        wsc = jax.lax.with_sharding_constraint
        b_ax = tuple(cfg.activation_batch_axes) or None
        q = wsc(q, Pspec(b_ax, "model", None, None))
        k = wsc(k, Pspec(b_ax, None, None, None))
        v = wsc(v, Pspec(b_ax, None, None, None))
    if kind == "local":
        o = attn_lib.attn_block_local(q, k, v, window=cfg.window,
                                      cap=cfg.attn_softcap)
    elif kind == "enc":
        o = attn_lib.attn_chunked(q, k, v, causal=False,
                                  cap=cfg.attn_softcap,
                                  chunk=cfg.attn_chunk)
    else:
        o = attn_lib.attn_chunked(q, k, v, causal=cfg.causal,
                                  cap=cfg.attn_softcap,
                                  chunk=cfg.attn_chunk)
    return o @ p["wo"]


def _cross_attention_full(p, x, enc_out, cfg: ModelConfig):
    H, Hkv, D = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    B, S, _ = x.shape
    T = enc_out.shape[1]
    q = (x @ p["wq"]).reshape(B, S, H, D)
    k = (enc_out @ p["wk"]).reshape(B, T, Hkv, D)
    v = (enc_out @ p["wv"]).reshape(B, T, Hkv, D)
    o = attn_lib.attn_chunked(q, k, v, causal=False, cap=cfg.attn_softcap)
    return o @ p["wo"]


def _constrain_residual(x, cfg):
    if not cfg.shard_residual:
        return x
    from jax.sharding import PartitionSpec as Pspec
    b_ax = tuple(cfg.activation_batch_axes) or None
    return jax.lax.with_sharding_constraint(
        x, Pspec(b_ax, *([None] * (x.ndim - 1))))


def _layer_forward(p, x, kind, cfg, positions, aux, enc_out=None):
    eps = cfg.norm_eps
    x = _constrain_residual(x, cfg)
    if kind == "ssm":
        return x + ssm_lib.mamba_forward(
            p["mamba"], rms_norm(x, p["ln1"], eps), cfg), aux
    if kind == "rec":
        x = x + rglru_lib.rglru_forward(
            p["rec"], rms_norm(x, p["ln1"], eps), cfg)
    else:
        x = x + _attention_full(p["attn"], rms_norm(x, p["ln1"], eps),
                                kind, cfg, positions)
        if kind == "xdec":
            x = x + _cross_attention_full(
                p["xattn"], rms_norm(x, p["ln_x"], eps), enc_out, cfg)
    h = rms_norm(x, p["ln2"], eps)
    if "moe" in p:
        out, a = moe_lib.moe_ffn(p["moe"], h, cfg)
        return x + out, aux + a
    return x + mlp(p["mlp"], h, cfg.activation), aux


def _stage_forward(stage_params, stage: Stage, x, cfg, positions,
                   aux, enc_out=None, remat=False):
    def unit_body(carry, unit_params):
        x, aux = carry
        for i, kind in enumerate(stage.unit):
            x, aux = _layer_forward(unit_params[str(i)], x, kind, cfg,
                                    positions, aux, enc_out)
        return (x, aux), None

    body = jax.checkpoint(unit_body) if remat else unit_body
    (x, aux), _ = jax.lax.scan(body, (x, aux), stage_params)
    return x, aux


def forward_hidden(params, cfg: ModelConfig, batch: dict, remat=False):
    """Full-sequence forward up to the final norm -> (hidden, aux_loss).

    batch keys: 'tokens' (B, S_text); optional 'patch_embeds'
    (B, n_front, d) for VLM/audio-prepend; optional 'enc_embeds'
    (B, n_enc_tokens, d) for enc-dec.
    """
    stages = build_stages(cfg)
    scale = jnp.asarray(cfg.d_model ** 0.5, params["embed"].dtype)
    x = params["embed"][batch["tokens"]] * scale
    if cfg.frontend and cfg.frontend != "audio":
        x = jnp.concatenate(
            [batch["patch_embeds"].astype(x.dtype), x], axis=1)
    positions = jnp.arange(x.shape[1])
    aux = jnp.zeros((), jnp.float32)

    enc_out = None
    stage_idx = 0
    if cfg.n_enc_layers:
        enc_x = batch["enc_embeds"].astype(x.dtype)
        enc_pos = jnp.arange(enc_x.shape[1])
        enc_out, aux = _stage_forward(params["stages"][0], stages[0],
                                      enc_x, cfg, enc_pos, aux, remat=remat)
        enc_out = rms_norm(enc_out, jnp.zeros_like(enc_out[0, 0]),
                           cfg.norm_eps)
        stage_idx = 1

    for sp, stage in zip(params["stages"][stage_idx:], stages[stage_idx:]):
        x, aux = _stage_forward(sp, stage, x, cfg, positions, aux,
                                enc_out, remat=remat)

    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    return x, aux


def _head(params, cfg):
    return params["embed"].T if cfg.tie_embeddings else params["lm_head"]


def forward(params, cfg: ModelConfig, batch: dict, remat=False):
    """Full-sequence forward -> (logits, aux_loss)."""
    x, aux = forward_hidden(params, cfg, batch, remat=remat)
    logits = softcap(x @ _head(params, cfg), cfg.final_softcap)
    return logits, aux


def loss_fn(params, cfg: ModelConfig, batch: dict, remat=False):
    x, aux = forward_hidden(params, cfg, batch, remat=remat)
    if cfg.frontend and cfg.frontend != "audio":
        x = x[:, batch["patch_embeds"].shape[1]:, :]
    if cfg.chunked_loss:
        from repro.models.layers import chunked_cross_entropy
        loss = chunked_cross_entropy(x, _head(params, cfg),
                                     batch["labels"], cfg.chunked_loss,
                                     cap=cfg.final_softcap)
    else:
        logits = softcap(x @ _head(params, cfg), cfg.final_softcap)
        loss = cross_entropy(logits, batch["labels"])
    return loss + cfg.router_aux_weight * aux
