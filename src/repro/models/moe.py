"""Mixture-of-Experts layer (qwen2-moe: 60 routed top-4 + 4 shared;
grok-1: 8 routed top-2).

Dispatch is sort-free scatter/gather with a capacity buffer:

    token -> top_k experts -> rank-within-expert -> (E, C+1, d) buffer
    (overflow rides in the spill slot C and is dropped)

This avoids the (T, E, C) one-hot dispatch tensor entirely (O(T k) scatter
instead), which is what makes grok-scale MoE lowerable at 1M tokens.
Experts are sharded over the ``model`` mesh axis (expert parallelism); the
scatter/gather lowers to all-to-all style collectives under GSPMD.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import init_mlp, mlp


def init_moe(key, cfg, dtype):
    k_router, k_experts, k_shared = jax.random.split(key, 3)
    s = cfg.d_model ** -0.5
    params = {
        "router": (s * jax.random.normal(
            k_router, (cfg.d_model, cfg.n_experts))).astype(jnp.float32),
        "experts": jax.vmap(
            lambda k: init_mlp(k, cfg.d_model, cfg.moe_d_ff,
                               cfg.activation, dtype)
        )(jax.random.split(k_experts, cfg.n_experts)),
    }
    if cfg.n_shared_experts:
        params["shared"] = init_mlp(
            k_shared, cfg.d_model, cfg.n_shared_experts * cfg.moe_d_ff,
            cfg.activation, dtype)
    return params


def moe_ffn_grouped(params, x, cfg):
    """Per-batch-row dispatch (cfg.moe_grouped): every scatter/gather is
    vmapped over the batch row, so the row dim is a pass-through scatter
    dimension and GSPMD shards the whole MoE path over 'data' without the
    involuntary full rematerialization the flat dispatch triggers.
    Capacity is enforced per row (C_row = cf * S * k / E), as in MaxText.
    """
    B, S, d = x.shape
    E, K = cfg.n_experts, cfg.top_k
    logits = (x.reshape(B * S, d).astype(jnp.float32)
              @ params["router"]).reshape(B, S, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, K)            # (B, S, K)
    gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)

    me = jnp.mean(probs.reshape(-1, E), axis=0)
    ce = jnp.mean(jnp.sum(jax.nn.one_hot(expert_idx, E), axis=2)
                  .reshape(-1, E), axis=0)
    aux = E * jnp.sum(me * ce)

    capacity = int(cfg.capacity_factor * S * K / E) + 1

    def row_dispatch(x_row, eidx_row, gate_row):
        fe = eidx_row.reshape(-1)                              # (S*K,)
        ft = jnp.repeat(jnp.arange(S), K)
        fg = gate_row.reshape(-1)
        order = jnp.argsort(fe, stable=True)
        se = fe[order]
        starts = jnp.searchsorted(se, jnp.arange(E), side="left")
        rank_sorted = jnp.arange(S * K) - starts[se]
        rank = jnp.zeros(S * K, jnp.int32).at[order].set(
            rank_sorted.astype(jnp.int32))
        slot = jnp.minimum(rank, capacity)
        buf = jnp.zeros((E, capacity + 1, d), x_row.dtype)
        buf = buf.at[fe, slot].add(x_row[ft])
        return buf, (fe, ft, fg, rank, slot)

    buf, meta = jax.vmap(row_dispatch)(x, expert_idx, gate_vals)

    # keep (E, B, C, d) -- merging B into C would destroy the 'data'
    # sharding of the batch dim (EXPERIMENTS.md Perf, grok iteration 4)
    h = buf[:, :, :capacity].transpose(1, 0, 2, 3)             # (E,B,C,d)
    out = jax.vmap(lambda p, hh: mlp(p, hh, cfg.activation))(
        params["experts"], h)
    out = out.transpose(1, 0, 2, 3)                            # (B,E,C,d)
    out = jnp.concatenate(
        [out, jnp.zeros((B, E, 1, d), out.dtype)], axis=2)
    if cfg.shard_residual:
        # keep the combine-gather operand batch-sharded / d-replicated,
        # else the expert wo FSDP d-sharding forces a full remat of the
        # data-dependent gather (grok iteration 6)
        from jax.sharding import PartitionSpec as Pspec
        tok_axes = tuple(cfg.activation_batch_axes) or None
        out = jax.lax.with_sharding_constraint(
            out, Pspec(tok_axes, None, None, None))

    def row_combine(out_row, m):
        fe, ft, fg, rank, slot = m
        gathered = out_row[fe, slot]
        dropped = (rank >= capacity)[:, None]
        contrib = jnp.where(dropped, 0.0, fg[:, None]) * gathered
        return jnp.zeros((S, d), out_row.dtype).at[ft].add(
            contrib.astype(out_row.dtype))

    y = jax.vmap(row_combine)(out, meta)
    if cfg.n_shared_experts:
        y = y + mlp(params["shared"], x, cfg.activation)
    return y, aux


def moe_ffn(params, x, cfg):
    """x: (B, S, d) -> (out (B, S, d), aux_loss scalar)."""
    if cfg.moe_grouped and x.shape[1] > 1:
        return moe_ffn_grouped(params, x, cfg)
    B, S, d = x.shape
    T = B * S
    E, K = cfg.n_experts, cfg.top_k
    xt = x.reshape(T, d)

    logits = (xt.astype(jnp.float32) @ params["router"])      # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, K)            # (T, K)
    gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)

    # --- load-balancing auxiliary loss (Switch-style) -------------------
    me = jnp.mean(probs, axis=0)                               # (E,)
    ce = jnp.mean(
        jnp.sum(jax.nn.one_hot(expert_idx, E), axis=1), axis=0)
    aux = E * jnp.sum(me * ce)

    # --- capacity-buffer dispatch ----------------------------------------
    capacity = int(cfg.capacity_factor * T * K / E) + 1
    flat_expert = expert_idx.reshape(-1)                       # (T*K,)
    flat_token = jnp.repeat(jnp.arange(T), K)
    flat_gate = gate_vals.reshape(-1)

    # rank of each (token, k) within its expert, by sorted order
    order = jnp.argsort(flat_expert, stable=True)
    sorted_expert = flat_expert[order]
    # start offset of each expert within the sorted list
    starts = jnp.searchsorted(sorted_expert, jnp.arange(E), side="left")
    rank_sorted = jnp.arange(T * K) - starts[sorted_expert]
    rank = jnp.zeros(T * K, jnp.int32).at[order].set(
        rank_sorted.astype(jnp.int32))
    slot = jnp.minimum(rank, capacity)                         # spill -> C

    buf = jnp.zeros((E, capacity + 1, d), x.dtype)
    buf = buf.at[flat_expert, slot].add(xt[flat_token])
    if cfg.moe_buffer_shard:
        # shard the capacity buffers along tokens-in-expert over the free
        # batch axes (E itself is often not divisible by the model axis,
        # e.g. grok's 8 experts on a 16-way axis) -- gives the expert
        # matmul its second sharding dim (tokens x ff) and prevents GSPMD
        # involuntary full rematerialization of the (E, C, ff) hidden.
        from jax.sharding import PartitionSpec as Pspec
        tok_axes = tuple(cfg.activation_batch_axes)
        if tok_axes:
            buf = jax.lax.with_sharding_constraint(
                buf, Pspec(None, tok_axes, None))

    # --- expert compute (vmapped over E; sharded over 'model') ----------
    out_buf = jax.vmap(lambda p, h: mlp(p, h, cfg.activation))(
        params["experts"], buf[:, :capacity])
    out_buf = jnp.concatenate(
        [out_buf, jnp.zeros((E, 1, d), out_buf.dtype)], axis=1)

    # --- combine -----------------------------------------------------------
    gathered = out_buf[flat_expert, slot]                      # (T*K, d)
    dropped = (rank >= capacity)[:, None]
    contrib = jnp.where(dropped, 0.0, flat_gate[:, None]) * gathered
    out = jnp.zeros((T, d), x.dtype).at[flat_token].add(
        contrib.astype(x.dtype))

    if cfg.n_shared_experts:
        out = out + mlp(params["shared"], xt, cfg.activation)

    return out.reshape(B, S, d), aux
