"""Model zoo: the 10 assigned architectures as composable JAX modules.

``repro.models.model.build_model(config)`` returns a :class:`Model` bundle
with ``init / loss_fn / prefill / init_cache / decode_step``.
"""

from repro.models.model import Model, build_model  # noqa: F401
