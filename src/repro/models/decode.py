"""Single-token decode path (serve_step) with KV / recurrent caches.

``init_cache`` builds the cache pytree for a (config, batch, cache_len)
triple; ``decode_step`` consumes one token per sequence and returns next
logits + updated cache.  Layer caches:

  'global' -- KV cache of length cache_len (or a ring buffer of
              ``cfg.long_ctx_global_window`` in long-context mode: the
              sub-quadratic windowed-global variant, see DESIGN.md)
  'local'  -- ring-buffer KV cache of length min(window, cache_len)
  'ssm'    -- (conv_state, h) mamba recurrent state
  'rec'    -- (conv_state, h) RG-LRU recurrent state
  'xdec'   -- self-attn KV cache + precomputed cross-attention K/V
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn_lib
from repro.models import rglru as rglru_lib
from repro.models import ssm as ssm_lib
from repro.models.layers import mlp, rms_norm, softcap
from repro.models.transformer import build_stages
from repro.models import moe as moe_lib


# ---------------------------------------------------------------------------
# Cache construction
# ---------------------------------------------------------------------------

def _layer_cache(kind: str, cfg: ModelConfig, batch: int, cache_len: int,
                 long_ctx: bool, dtype):
    Hkv, D = cfg.n_kv_heads, cfg.resolved_head_dim
    if kind == "ssm":
        return ssm_lib.init_mamba_cache(batch, cfg, dtype)
    if kind == "rec":
        return rglru_lib.init_rglru_cache(batch, cfg, dtype)
    if kind == "local":
        length = min(cfg.window, cache_len)
        return attn_lib.init_kv_cache(batch, length, Hkv, D, dtype)
    # global / xdec self-attention
    length = (min(cfg.long_ctx_global_window, cache_len) if long_ctx
              else cache_len)
    c = attn_lib.init_kv_cache(batch, length, Hkv, D, dtype)
    if kind == "xdec":
        c["xk"] = jnp.zeros((batch, cfg.n_enc_tokens, Hkv, D), dtype)
        c["xv"] = jnp.zeros((batch, cfg.n_enc_tokens, Hkv, D), dtype)
    return c


def init_cache(cfg: ModelConfig, batch: int, cache_len: int,
               long_ctx: bool = False):
    dtype = jnp.dtype(cfg.dtype)
    stages = build_stages(cfg)
    caches = []
    for stage in stages:
        if stage.unit == ("enc",):
            continue  # encoder has no decode-time state

        def unit_cache(_):
            return {str(i): _layer_cache(kind, cfg, batch, cache_len,
                                         long_ctx, dtype)
                    for i, kind in enumerate(stage.unit)}

        caches.append(jax.vmap(unit_cache)(jnp.arange(stage.n_units)))
    # per-sequence positions (continuous batching: sequences may differ)
    return {"stages": caches, "pos": jnp.zeros((batch,), jnp.int32)}


def fill_cross_cache(params, cfg: ModelConfig, cache, enc_out):
    """Populate the decoder's cross-attention K/V from encoder output
    (run once per request before decoding; enc-dec archs only)."""
    assert cfg.n_enc_layers, "cross cache only exists for enc-dec models"
    Hkv, D = cfg.n_kv_heads, cfg.resolved_head_dim
    B, T, _ = enc_out.shape
    dec_params = params["stages"][1]           # the ('xdec',) stage

    def per_unit(p_unit):
        xa = p_unit["0"]["xattn"]
        xk = (enc_out @ xa["wk"]).reshape(B, T, Hkv, D)
        xv = (enc_out @ xa["wv"]).reshape(B, T, Hkv, D)
        return xk, xv

    xk, xv = jax.vmap(per_unit)(dec_params)    # (U, B, T, Hkv, D)
    new_stage = dict(cache["stages"][0])
    inner = dict(new_stage["0"])
    inner["xk"], inner["xv"] = xk, xv
    new_stage["0"] = inner
    return {"stages": [new_stage] + cache["stages"][1:],
            "pos": cache["pos"]}


def reset_slots(cache, done_mask: jnp.ndarray):
    """Free finished sequences' slots (continuous batching): zero their
    positions and invalidate their KV rows.  done_mask: (B,) bool."""
    def reset_leaf(path, leaf):
        names = [str(getattr(k, "key", getattr(k, "idx", k)))
                 for k in path]
        name = names[-1] if names else ""
        if name == "pos" and leaf.ndim == 1:
            return jnp.where(done_mask, 0, leaf)          # top-level pos
        if name == "pos":                                 # (U, B, C)
            return jnp.where(done_mask[None, :, None], -1, leaf)
        if name in ("h", "conv"):                         # recurrent state
            mask = done_mask.reshape((1, -1) + (1,) * (leaf.ndim - 2))
            return jnp.where(mask, 0, leaf)
        return leaf

    return jax.tree_util.tree_map_with_path(reset_leaf, cache)


# ---------------------------------------------------------------------------
# Decode step
# ---------------------------------------------------------------------------

def _decode_cross_attn(p, x_t, xk, xv, cfg):
    H, Hkv, D = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    B = x_t.shape[0]
    G = H // Hkv
    q = (x_t @ p["wq"]).reshape(B, 1, Hkv, G, D)
    s = jnp.einsum("bshgd,bthd->bhgst", q, xk,
                   preferred_element_type=jnp.float32) * (D ** -0.5)
    s = softcap(s, cfg.attn_softcap)
    pr = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgst,bthd->bshgd", pr.astype(xv.dtype), xv)
    return o.reshape(B, -1) @ p["wo"]


def _layer_decode(p, c, kind, cfg: ModelConfig, x_t, pos, long_ctx):
    eps = cfg.norm_eps
    if kind == "ssm":
        out, c_new = ssm_lib.mamba_step(p["mamba"],
                                        rms_norm(x_t, p["ln1"], eps),
                                        c, cfg)
        return x_t + out, c_new
    c_new = dict(c)
    if kind == "rec":
        out, cr = rglru_lib.rglru_step(p["rec"],
                                       rms_norm(x_t, p["ln1"], eps), c, cfg)
        x_t = x_t + out
        c_new = cr
    else:
        if kind == "local":
            window, ring = cfg.window, True
        elif long_ctx:
            window, ring = cfg.long_ctx_global_window, True
        else:
            window, ring = None, False
        out, kv_new = attn_lib.decode_attn(
            p["attn"], rms_norm(x_t, p["ln1"], eps),
            {k: c[k] for k in ("k", "v", "pos")},
            n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
            head_dim=cfg.resolved_head_dim, rope_theta=cfg.rope_theta,
            pos=pos, window=window, cap=cfg.attn_softcap, ring=ring)
        x_t = x_t + out @ p["attn"]["wo"]
        c_new.update(kv_new)
        if kind == "xdec":
            x_t = x_t + _decode_cross_attn(
                p["xattn"], rms_norm(x_t, p["ln_x"], eps),
                c["xk"], c["xv"], cfg)
    h = rms_norm(x_t, p["ln2"], eps)
    if "moe" in p:
        out, _ = moe_lib.moe_ffn(p["moe"], h[:, None, :], cfg)
        x_t = x_t + out[:, 0, :]
    else:
        x_t = x_t + mlp(p["mlp"], h, cfg.activation)
    return x_t, c_new


def decode_step(params, cfg: ModelConfig, cache, tokens: jnp.ndarray,
                long_ctx: bool = False):
    """tokens: (B,) int32 -> (logits (B, V), new cache).

    cache['pos'] is per-sequence (B,), so batched requests may sit at
    different depths (continuous batching)."""
    stages = [s for s in build_stages(cfg) if s.unit != ("enc",)]
    stage_params = params["stages"][1:] if cfg.n_enc_layers else \
        params["stages"]
    pos = cache["pos"]
    scale = jnp.asarray(cfg.d_model ** 0.5, params["embed"].dtype)
    x_t = params["embed"][tokens] * scale

    new_stage_caches = []
    for sp, sc, stage in zip(stage_params, cache["stages"], stages):
        def unit_body(x_t, inp):
            up, uc = inp
            uc_new = {}
            for i, kind in enumerate(stage.unit):
                x_t, uc_new[str(i)] = _layer_decode(
                    up[str(i)], uc[str(i)], kind, cfg, x_t, pos, long_ctx)
            return x_t, uc_new

        x_t, sc_new = jax.lax.scan(unit_body, x_t, (sp, sc))
        new_stage_caches.append(sc_new)

    x_t = rms_norm(x_t, params["final_norm"], cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = softcap(x_t @ head, cfg.final_softcap)
    return logits, {"stages": new_stage_caches, "pos": pos + 1}
