"""Attention: GQA, sliding-window, logit softcap, KV caches.

Two full-sequence implementations:

* ``attn_reference`` -- materializes (B, H, S, T) scores.  Oracle/tests.
* ``attn_chunked``   -- online-softmax over KV chunks (lax.scan), the
  production XLA path: peak memory O(S * chunk) instead of O(S^2).
  (The Pallas flash kernel in ``repro.kernels.flash_attention`` is the
  TPU-target version of the same algorithm.)

Local (sliding-window) layers additionally use ``attn_block_local``:
exact sliding-window attention computed block-diagonally (each block of
size W attends to itself + the previous block), cost O(S * 2W).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import apply_rope, softcap

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# Projections
# ---------------------------------------------------------------------------

def init_attn(key, d_model: int, n_heads: int, n_kv_heads: int,
              head_dim: int, dtype):
    ks = jax.random.split(key, 4)
    s = d_model ** -0.5
    so = (n_heads * head_dim) ** -0.5
    return {
        "wq": (s * jax.random.normal(ks[0], (d_model, n_heads * head_dim))
               ).astype(dtype),
        "wk": (s * jax.random.normal(ks[1], (d_model, n_kv_heads * head_dim))
               ).astype(dtype),
        "wv": (s * jax.random.normal(ks[2], (d_model, n_kv_heads * head_dim))
               ).astype(dtype),
        "wo": (so * jax.random.normal(ks[3], (n_heads * head_dim, d_model))
               ).astype(dtype),
    }


def qkv(params, x, *, n_heads, n_kv_heads, head_dim):
    B, S, _ = x.shape
    q = (x @ params["wq"]).reshape(B, S, n_heads, head_dim)
    k = (x @ params["wk"]).reshape(B, S, n_kv_heads, head_dim)
    v = (x @ params["wv"]).reshape(B, S, n_kv_heads, head_dim)
    return q, k, v


# ---------------------------------------------------------------------------
# Full-sequence attention
# ---------------------------------------------------------------------------

def _gqa_scores(q, k, scale, cap):
    """q: (B,S,Hkv,G,D), k: (B,T,Hkv,D) -> (B,Hkv,G,S,T) fp32 scores."""
    s = jnp.einsum("bshgd,bthd->bhgst", q, k,
                   preferred_element_type=jnp.float32) * scale
    return softcap(s, cap)


def _gqa_out(probs, v):
    """probs: (B,Hkv,G,S,T), v: (B,T,Hkv,D) -> (B,S,Hkv*G*D)."""
    o = jnp.einsum("bhgst,bthd->bshgd", probs.astype(v.dtype), v)
    B, S = o.shape[:2]
    return o.reshape(B, S, -1)


def attn_reference(q, k, v, *, causal=True, window=None, cap=None,
                   q_offset=0):
    """Oracle attention. q: (B,S,H,D); k,v: (B,T,Hkv,D)."""
    B, S, H, D = q.shape
    T, Hkv = k.shape[1], k.shape[2]
    G = H // Hkv
    qg = q.reshape(B, S, Hkv, G, D)
    s = _gqa_scores(qg, k, D ** -0.5, cap)
    qpos = jnp.arange(S) + q_offset
    kpos = jnp.arange(T)
    mask = jnp.ones((S, T), bool)
    if causal:
        mask &= kpos[None, :] <= qpos[:, None]
    if window is not None:
        mask &= kpos[None, :] > qpos[:, None] - window
    s = jnp.where(mask, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return _gqa_out(p, v)


def attn_chunked(q, k, v, *, causal=True, window=None, cap=None,
                 q_offset=0, chunk=1024):
    """Online-softmax attention, scanning KV chunks (production XLA path)."""
    B, S, H, D = q.shape
    T, Hkv = k.shape[1], k.shape[2]
    if T % chunk:
        chunk = T  # degenerate: single chunk
    n_chunks = T // chunk
    G = H // Hkv
    qg = q.reshape(B, S, Hkv, G, D)
    scale = D ** -0.5
    qpos = jnp.arange(S) + q_offset

    kc = k.reshape(B, n_chunks, chunk, Hkv, D).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(B, n_chunks, chunk, Hkv, D).transpose(1, 0, 2, 3, 4)

    def body(carry, inputs):
        m, l, acc = carry
        (k_c, v_c, c_idx) = inputs
        s = _gqa_scores(qg, k_c, scale, cap)  # (B,Hkv,G,S,chunk)
        kpos = c_idx * chunk + jnp.arange(chunk)
        mask = jnp.ones((S, chunk), bool)
        if causal:
            mask &= kpos[None, :] <= qpos[:, None]
        if window is not None:
            mask &= kpos[None, :] > qpos[:, None] - window
        s = jnp.where(mask, s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        corr = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[..., None])
        l_new = l * corr + jnp.sum(p, axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bhgst,bthd->bhgsd", p.astype(v_c.dtype), v_c
        ).astype(jnp.float32)
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, Hkv, G, S), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, Hkv, G, S), jnp.float32)
    a0 = jnp.zeros((B, Hkv, G, S, D), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0),
                                  (kc, vc, jnp.arange(n_chunks)))
    o = acc / jnp.maximum(l, 1e-30)[..., None]
    o = o.transpose(0, 3, 1, 2, 4).reshape(B, S, H * D)  # (B,S,Hkv,G,D)->
    return o.astype(q.dtype)


def attn_block_local(q, k, v, *, window, cap=None):
    """Exact causal sliding-window attention in O(S * 2W).

    Requires S % W == 0.  Each query block (size W) attends to [itself +
    previous block] with the exact causal+window mask.
    """
    B, S, H, D = q.shape
    Hkv = k.shape[2]
    W = window
    if S % W or S == W:
        return attn_reference(q, k, v, causal=True, window=window, cap=cap)
    nb = S // W
    G = H // Hkv
    qb = q.reshape(B, nb, W, Hkv, G, D)
    kb = k.reshape(B, nb, W, Hkv, D)
    vb = v.reshape(B, nb, W, Hkv, D)
    k_prev = jnp.concatenate([jnp.zeros_like(kb[:, :1]), kb[:, :-1]], axis=1)
    v_prev = jnp.concatenate([jnp.zeros_like(vb[:, :1]), vb[:, :-1]], axis=1)
    k2 = jnp.concatenate([k_prev, kb], axis=2)   # (B,nb,2W,Hkv,D)
    v2 = jnp.concatenate([v_prev, vb], axis=2)
    s = jnp.einsum("bnshgd,bnthd->bnhgst", qb, k2,
                   preferred_element_type=jnp.float32) * (D ** -0.5)
    s = softcap(s, cap)
    qpos = jnp.arange(W)[:, None]           # position within block
    kpos = jnp.arange(2 * W)[None, :] - W    # relative to block start
    mask = (kpos <= qpos) & (kpos > qpos - W)
    first = jnp.arange(nb) == 0              # block 0 has no prev block
    prev_valid = jnp.where(first[:, None, None], kpos[None] >= 0, True)
    mask = mask[None, :, :] & prev_valid
    s = jnp.where(mask[:, None, None, :, :][None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bnhgst,bnthd->bnshgd", p.astype(v2.dtype), v2)
    return o.reshape(B, S, H * D)


# ---------------------------------------------------------------------------
# Decode-step attention over a cache
# ---------------------------------------------------------------------------

def init_kv_cache(batch: int, cache_len: int, n_kv_heads: int, head_dim: int,
                  dtype) -> dict:
    return {
        "k": jnp.zeros((batch, cache_len, n_kv_heads, head_dim), dtype),
        "v": jnp.zeros((batch, cache_len, n_kv_heads, head_dim), dtype),
        # absolute position held in each slot, PER SEQUENCE; -1 = empty
        # (per-sequence positions enable continuous batching)
        "pos": jnp.full((batch, cache_len), -1, jnp.int32),
    }


def decode_attn(params, x_t, cache, *, n_heads, n_kv_heads, head_dim,
                rope_theta, pos, window=None, cap=None, ring=False,
                rope=True):
    """One-token attention against a KV cache.

    x_t: (B, d); pos: (B,) int32 per-sequence positions (sequences may be
    at different depths -- continuous batching).  ``ring=True`` means the
    cache is a ring buffer of size ``cache_len`` (windowed layers).
    Returns (out (B, d_attn), new_cache).
    """
    B = x_t.shape[0]
    pos = jnp.broadcast_to(pos, (B,))
    q = (x_t @ params["wq"]).reshape(B, 1, n_heads, head_dim)
    k_t = (x_t @ params["wk"]).reshape(B, 1, n_kv_heads, head_dim)
    v_t = (x_t @ params["wv"]).reshape(B, 1, n_kv_heads, head_dim)
    if rope:
        posv = pos[:, None]                     # (B, 1)
        q = apply_rope(q, posv, rope_theta)
        k_t = apply_rope(k_t, posv, rope_theta)

    C = cache["k"].shape[1]
    slot = jnp.mod(pos, C) if ring else jnp.clip(pos, 0, C - 1)  # (B,)

    def upd_row(cache_row, new_row, s):
        return jax.lax.dynamic_update_slice(cache_row, new_row, (s, 0, 0))

    k = jax.vmap(upd_row)(cache["k"], k_t, slot)
    v = jax.vmap(upd_row)(cache["v"], v_t, slot)
    posarr = jax.vmap(
        lambda row, p, s: jax.lax.dynamic_update_slice(row, p[None], (s,))
    )(cache["pos"], pos, slot)                  # (B, C)

    G = n_heads // n_kv_heads
    qg = q.reshape(B, 1, n_kv_heads, G, head_dim)
    s = _gqa_scores(qg, k, head_dim ** -0.5, cap)  # (B,Hkv,G,1,C)
    valid = (posarr >= 0) & (posarr <= pos[:, None])
    if window is not None:
        valid &= posarr > (pos[:, None] - window)
    s = jnp.where(valid[:, None, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = _gqa_out(p, v)[:, 0, :]
    return o, {"k": k, "v": v, "pos": posarr}
