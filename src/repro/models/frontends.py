"""Modality frontend STUBS (per the brief's carve-out).

The audio conv/mel feature extractor (whisper) and the ViT+projector
(internvl2) are not implemented; instead these helpers generate
correctly-shaped embeddings -- the exact tensors ``input_specs`` describes
-- so smoke tests and examples can exercise the transformer backbone.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig


def fake_audio_frames(key, cfg: ModelConfig, batch: int) -> jnp.ndarray:
    """Stub of log-mel + conv frontend output: (B, n_enc_tokens, d)."""
    return 0.02 * jax.random.normal(
        key, (batch, cfg.n_enc_tokens, cfg.d_model), jnp.dtype(cfg.dtype))


def fake_patch_embeds(key, cfg: ModelConfig, batch: int) -> jnp.ndarray:
    """Stub of ViT + MLP-projector output: (B, n_frontend_tokens, d)."""
    return 0.02 * jax.random.normal(
        key, (batch, cfg.n_frontend_tokens, cfg.d_model),
        jnp.dtype(cfg.dtype))
