"""Mamba-1 selective SSM block (falcon-mamba).

Diagonal selective state space:

    dt_t  = softplus(dt_proj(x_proj_dt(u_t)))                (B, S, d_in)
    B_t,C_t = x_proj(u_t)                                    (B, S, n)
    A     = -exp(A_log)                                      (d_in, n)
    h_t   = exp(dt_t A) h_{t-1} + dt_t B_t u_t
    y_t   = <h_t, C_t> + D u_t

Sequence mixing runs as a *chunked* scan: within a chunk, an associative
scan in VMEM-sized pieces; across chunks, a sequential lax.scan carry.
This bounds the materialized state to (B, Q, d_in, n) per chunk instead of
(B, S, d_in, n) — the TPU-native adaptation of the CUDA selective-scan
kernel (see also kernels/lru_scan for the Pallas version of the same
chunking idea).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import causal_conv1d, conv1d_step


def init_mamba(key, cfg, dtype):
    d, d_in, n = cfg.d_model, cfg.d_inner, cfg.ssm_state
    dt_rank = cfg.resolved_dt_rank
    ks = jax.random.split(key, 7)
    s = d ** -0.5
    si = d_in ** -0.5
    return {
        "in_proj": (s * jax.random.normal(ks[0], (d, 2 * d_in))).astype(dtype),
        "conv_w": (0.5 * jax.random.normal(
            ks[1], (cfg.conv_width, d_in))).astype(dtype),
        "conv_b": jnp.zeros((d_in,), dtype),
        "x_proj": (si * jax.random.normal(
            ks[2], (d_in, dt_rank + 2 * n))).astype(dtype),
        "dt_proj": (dt_rank ** -0.5 * jax.random.normal(
            ks[3], (dt_rank, d_in))).astype(dtype),
        "dt_bias": jnp.log(jnp.exp(
            jnp.linspace(1e-3, 0.1, d_in)) - 1.0).astype(jnp.float32),
        "A_log": jnp.log(jnp.broadcast_to(
            jnp.arange(1, n + 1, dtype=jnp.float32), (d_in, n))),
        "D": jnp.ones((d_in,), jnp.float32),
        "out_proj": (si * jax.random.normal(ks[6], (d_in, d))).astype(dtype),
    }


def _ssm_coeffs(params, u):
    """u: (B, S, d_in) post-conv activations -> (a, bx, C) scan coeffs."""
    n = params["A_log"].shape[1]
    dt_rank = params["dt_proj"].shape[0]
    proj = u @ params["x_proj"]                                # (B,S,r+2n)
    dt_in, Bc, Cc = jnp.split(proj, [dt_rank, dt_rank + n], axis=-1)
    dt = jax.nn.softplus(
        (dt_in @ params["dt_proj"]).astype(jnp.float32)
        + params["dt_bias"])                                   # (B,S,d_in)
    A = -jnp.exp(params["A_log"])                              # (d_in, n)
    a = jnp.exp(dt[..., None] * A)                             # (B,S,d_in,n)
    bx = (dt * u.astype(jnp.float32))[..., None] * \
        Bc.astype(jnp.float32)[..., None, :]                   # (B,S,d_in,n)
    return a, bx, Cc.astype(jnp.float32)


def ssm_scan_chunked(a, bx, h0, chunk: int = 128):
    """Sequence scan of h_t = a_t h_{t-1} + bx_t, chunked over time.

    a, bx: (B, S, d_in, n); h0: (B, d_in, n).  Returns (h_all (B,S,d_in,n),
    h_last).  Within-chunk: associative scan; across chunks: lax.scan.
    """
    B, S, d_in, n = a.shape
    if S % chunk:
        chunk = S
    nc = S // chunk
    a_c = a.reshape(B, nc, chunk, d_in, n).transpose(1, 0, 2, 3, 4)
    b_c = bx.reshape(B, nc, chunk, d_in, n).transpose(1, 0, 2, 3, 4)

    def combine(l, r):
        al, bl = l
        ar, br = r
        return al * ar, bl * ar + br

    def chunk_body(h, inp):
        a_i, b_i = inp                                         # (B,chunk,...)
        a_cum, b_cum = jax.lax.associative_scan(combine, (a_i, b_i), axis=1)
        h_all = a_cum * h[:, None] + b_cum
        return h_all[:, -1], h_all

    h_last, h_chunks = jax.lax.scan(chunk_body, h0, (a_c, b_c))
    h_all = h_chunks.transpose(1, 0, 2, 3, 4).reshape(B, S, d_in, n)
    return h_all, h_last


def ssm_mix_seq(params, u, scan_dtype) -> jnp.ndarray:
    """Sequential time scan with the C-contraction folded into the step,
    so the (B, S, d_in, n) state NEVER materializes: per step we read
    (a_t, b_t), update h in place, and emit y_t = <h, C_t> of size
    (B, d_in).  This is the XLA stand-in for the lru_scan Pallas kernel's
    VMEM-resident chunked scan (identical HBM traffic: one read of the
    coefficients + one running state)."""
    a, bx, Cc = _ssm_coeffs(params, u)
    a = a.astype(scan_dtype)
    bx = bx.astype(scan_dtype)

    def step(h, inp):
        a_t, b_t, c_t = inp
        h = a_t.astype(jnp.float32) * h + b_t.astype(jnp.float32)
        y_t = jnp.einsum("bdn,bn->bd", h, c_t)
        return h, y_t

    B_, S, d_in, n = a.shape
    h0 = jnp.zeros((B_, d_in, n), jnp.float32)
    _, y = jax.lax.scan(
        step, h0,
        (a.transpose(1, 0, 2, 3), bx.transpose(1, 0, 2, 3),
         Cc.transpose(1, 0, 2)))
    y = y.transpose(1, 0, 2)
    return y + params["D"] * u.astype(jnp.float32)


def ssm_mix_fused(params, u, chunk: int, scan_dtype) -> jnp.ndarray:
    """Optimized sequence mixing: coefficients computed AND the C
    contraction applied inside the chunk body, so only (B, S, d_in)
    tensors cross scan boundaries (the (B, S, d_in, n) state never
    materializes at full sequence length).  Optionally runs the scan in
    bf16 with an fp32 cross-chunk carry.  See EXPERIMENTS.md section Perf
    (falcon-mamba iteration log)."""
    B_, S, d_in = u.shape
    if S % chunk:
        chunk = S
    nc = S // chunk
    u_c = u.reshape(B_, nc, chunk, d_in).transpose(1, 0, 2, 3)

    def combine(l, r):
        al, bl = l
        ar, br = r
        return al * ar, bl * ar + br

    def chunk_body(h, u_i):
        a, bx, Cc = _ssm_coeffs(params, u_i)
        a = a.astype(scan_dtype)
        bx = bx.astype(scan_dtype)
        a_cum, b_cum = jax.lax.associative_scan(combine, (a, bx), axis=1)
        h_all = (a_cum.astype(jnp.float32) * h[:, None]
                 + b_cum.astype(jnp.float32))
        y_i = jnp.einsum("bsdn,bsn->bsd", h_all, Cc)
        y_i = y_i + params["D"] * u_i.astype(jnp.float32)
        return h_all[:, -1], y_i

    h0 = jnp.zeros((B_, d_in, params["A_log"].shape[1]), jnp.float32)
    _, y_chunks = jax.lax.scan(chunk_body, h0, u_c)
    return y_chunks.transpose(1, 0, 2, 3).reshape(B_, S, d_in)


def mamba_forward(params, x, cfg, chunk: int | None = None):
    """Full-sequence mamba block. x: (B, S, d) -> (B, S, d)."""
    chunk = chunk or cfg.ssm_chunk
    u, z = jnp.split(x @ params["in_proj"], 2, axis=-1)        # (B,S,d_in)
    u = causal_conv1d(u, params["conv_w"], params["conv_b"])
    u = jax.nn.silu(u)
    if cfg.ssm_fused_output and cfg.ssm_inner == "seq":
        y = ssm_mix_seq(params, u, jnp.dtype(cfg.ssm_scan_dtype))
    elif cfg.ssm_fused_output:
        y = ssm_mix_fused(params, u, chunk,
                          jnp.dtype(cfg.ssm_scan_dtype))
    else:
        a, bx, Cc = _ssm_coeffs(params, u)
        B_, S, d_in, n = a.shape
        h0 = jnp.zeros((B_, d_in, n), jnp.float32)
        h_all, _ = ssm_scan_chunked(a, bx, h0, chunk)
        y = jnp.einsum("bsdn,bsn->bsd", h_all, Cc)
        y = y + params["D"] * u.astype(jnp.float32)
    y = y.astype(x.dtype) * jax.nn.silu(z)
    return y @ params["out_proj"]


def init_mamba_cache(batch, cfg, dtype):
    return {
        "conv": jnp.zeros((batch, cfg.conv_width - 1, cfg.d_inner), dtype),
        "h": jnp.zeros((batch, cfg.d_inner, cfg.ssm_state), jnp.float32),
    }


def mamba_step(params, x_t, cache, cfg):
    """One decode step. x_t: (B, d) -> (y (B, d), new_cache)."""
    u, z = jnp.split(x_t @ params["in_proj"], 2, axis=-1)      # (B, d_in)
    u, conv_state = conv1d_step(cache["conv"], u, params["conv_w"],
                                params["conv_b"])
    u = jax.nn.silu(u)
    a, bx, Cc = _ssm_coeffs(params, u[:, None, :])
    h = a[:, 0] * cache["h"] + bx[:, 0]                        # (B,d_in,n)
    y = jnp.einsum("bdn,bn->bd", h, Cc[:, 0])
    y = y + params["D"] * u.astype(jnp.float32)
    y = y.astype(x_t.dtype) * jax.nn.silu(z)
    return y @ params["out_proj"], {"conv": conv_state, "h": h}
