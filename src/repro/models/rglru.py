"""RG-LRU recurrent block (recurrentgemma / Griffin, arXiv:2402.19427).

Real-Gated Linear Recurrent Unit:

    r_t = sigmoid(W_a x_t)          (recurrence gate)
    i_t = sigmoid(W_x x_t)          (input gate)
    a_t = exp(-c * softplus(Lambda) * r_t)
    h_t = a_t . h_{t-1} + sqrt(1 - a_t^2) . (i_t . x_t)

wrapped in the Griffin recurrent block:

    branch1 = conv1d(W_1 x) -> RG-LRU
    branch2 = gelu(W_2 x)
    out     = W_o (branch1 . branch2)

Sequence mixing reuses the chunked diagonal scan from ssm.py (state dim =
lru_width, no extra d_state factor).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import causal_conv1d, conv1d_step
from repro.models.ssm import ssm_scan_chunked

_C = 8.0  # Griffin's constant


def init_rglru_block(key, cfg, dtype):
    d, w = cfg.d_model, cfg.resolved_lru_width
    ks = jax.random.split(key, 6)
    s = d ** -0.5
    sw = w ** -0.5
    return {
        "w_branch1": (s * jax.random.normal(ks[0], (d, w))).astype(dtype),
        "w_branch2": (s * jax.random.normal(ks[1], (d, w))).astype(dtype),
        "conv_w": (0.5 * jax.random.normal(
            ks[2], (cfg.conv_width, w))).astype(dtype),
        "conv_b": jnp.zeros((w,), dtype),
        "w_a": (sw * jax.random.normal(ks[3], (w, w))).astype(dtype),
        "w_x": (sw * jax.random.normal(ks[4], (w, w))).astype(dtype),
        # Lambda init so that a ~ Uniform(0.9, 0.999)^c at r=1 (Griffin A.2)
        "lam": jnp.log(jnp.expm1(
            -jnp.log(jnp.linspace(0.9, 0.999, w)) / _C)).astype(jnp.float32),
        "w_out": (sw * jax.random.normal(ks[5], (w, d))).astype(dtype),
    }


def _gates(params, u):
    """u: (..., w) -> (a, gated_input) in fp32."""
    r = jax.nn.sigmoid((u @ params["w_a"]).astype(jnp.float32))
    i = jax.nn.sigmoid((u @ params["w_x"]).astype(jnp.float32))
    log_a = -_C * jax.nn.softplus(params["lam"]) * r
    a = jnp.exp(log_a)
    beta = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12))
    return a, beta * i * u.astype(jnp.float32)


def rglru_forward(params, x, cfg, chunk: int = 256):
    """Full-sequence Griffin recurrent block. x: (B, S, d)."""
    u = x @ params["w_branch1"]                                 # (B,S,w)
    u = causal_conv1d(u, params["conv_w"], params["conv_b"])
    a, bx = _gates(params, u)
    B, S, w = a.shape
    h0 = jnp.zeros((B, w), jnp.float32)
    # reuse the chunked diagonal scan with a trailing singleton state dim
    h_all, _ = ssm_scan_chunked(a[..., None], bx[..., None], h0[..., None],
                                chunk)
    h = h_all[..., 0].astype(x.dtype)                           # (B,S,w)
    gate = jax.nn.gelu(x @ params["w_branch2"])
    return (h * gate) @ params["w_out"]


def init_rglru_cache(batch, cfg, dtype):
    w = cfg.resolved_lru_width
    return {
        "conv": jnp.zeros((batch, cfg.conv_width - 1, w), dtype),
        "h": jnp.zeros((batch, w), jnp.float32),
    }


def rglru_step(params, x_t, cache, cfg):
    """One decode step. x_t: (B, d)."""
    u = x_t @ params["w_branch1"]
    u, conv_state = conv1d_step(cache["conv"], u, params["conv_w"],
                                params["conv_b"])
    a, bx = _gates(params, u)
    h = a * cache["h"] + bx
    gate = jax.nn.gelu(x_t @ params["w_branch2"])
    out = (h.astype(x_t.dtype) * gate) @ params["w_out"]
    return out, {"conv": conv_state, "h": h}
