"""One front door for Fed-PLT: ``FedSpec`` + ``build_trainer``.

The historical configs (``FedPLTConfig`` for the dense paper
experiments, ``FedConfig`` for model scale, plus the engine's
``RoundConfig`` and the solvers' ``SolverConfig``) redeclared
overlapping knobs and validated them in three different places.
``FedSpec`` is the single composable spec:

    round topology   -- n_agents / rho / participation / damping
    local solver     -- solver / n_epochs / gamma / (mu, L)
    privacy          -- :class:`PrivacySpec` (tau, clip, delta, dp_init)
    uplink           -- :class:`CompressionSpec` (registry name + knobs)
    coordinator h    -- prox_h registry name (+ weight_decay shorthand)

with ONE :meth:`FedSpec.validate` owning every cross-field check, and
:func:`build_trainer` dispatching to either front end behind one handle:

    >>> spec = FedSpec(n_agents=4, gamma=0.1, n_epochs=3)
    >>> trainer = build_trainer(problem_or_model, spec)
    >>> state, history = trainer.run(jax.random.PRNGKey(0), 100)

Both legacy configs now expose ``.to_spec()`` and stay bit-compatible:
``build_trainer(problem, cfg.to_spec())`` reproduces
``FedPLT(problem, cfg)`` trajectories exactly.

The CLI in :mod:`repro.launch.train` is *generated* from the spec's
dataclass fields (:func:`add_spec_args` / :func:`spec_from_args`), so a
new knob added here -- or a new compressor registered in
:mod:`repro.fed.compress` -- shows up as a flag without touching the
driver.
"""

from __future__ import annotations

import argparse
import dataclasses
from typing import Any, Optional, Sequence, Union

import jax

from repro.core import prox as prox_lib
from repro.core.solvers import SolverConfig
from repro.fed import engine
from repro.fed.compress import (COMPRESS_BACKENDS, available_compressors,
                                get_compressor)
from repro.fed.robust import available_aggregators, validate_aggregator
from repro.fed.solvers import get_solver


def _upgrade_solver(name: str, tau: float) -> str:
    """tau > 0 turns the gd-type solvers into DP noisy GD.

    Any other solver -- agd, or a custom registry entry -- is REJECTED
    under tau > 0: the Prop. 4 accountant certifies noisy local GD
    specifically, and a solver that injects no noise must never receive
    an (eps, delta) certificate just because tau was set."""
    if tau > 0.0:
        if name in ("gd", "sgd"):
            return "noisy_gd"
        if name != "noisy_gd":
            raise ValueError("DP noise (tau > 0) requires a gd-type "
                             f"solver, not {name!r}")
    return name


def _cli(flag=None, help="", arg_type=None, choices=None, default=None,
         expose=True):
    """Field metadata driving the generated argparse flags.

    ``default`` overrides the dataclass default on the CLI only (the CLI
    must pick concrete values where the spec allows None/derived).
    """
    return {"cli": {"flag": flag, "help": help, "type": arg_type,
                    "choices": choices, "default": default,
                    "expose": expose}}


# ---------------------------------------------------------------------------
# Component specs
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class PrivacySpec:
    """DP knobs (paper Section VI)."""

    tau: float = dataclasses.field(default=0.0, metadata=_cli(
        help="DP noise std (tau > 0 turns gd-type solvers into noisy GD)"))
    clip: Optional[float] = dataclasses.field(default=None, metadata=_cli(
        arg_type=float,
        help="per-agent gradient clip threshold C (DP sensitivity)"))
    delta: float = dataclasses.field(default=1e-5, metadata=_cli(
        help="ADP delta for the privacy report"))
    dp_init: bool = dataclasses.field(default=False, metadata=_cli(
        expose=False))   # x0 ~ N(0, 2 tau^2/mu I) (Prop. 4, dense path)


@dataclasses.dataclass(frozen=True)
class CompressionSpec:
    """z-uplink compression; ``name`` is a :mod:`repro.fed.compress`
    registry entry, so registered compressors are reachable by name from
    every front end (and the generated CLI) without engine changes."""

    name: str = dataclasses.field(default="none", metadata=_cli(
        flag="--compression", help="z-uplink compressor (registry name)"))
    ratio: float = dataclasses.field(default=0.25, metadata=_cli(
        flag="--compress-ratio",
        help="top-k fraction kept (floor for adaptive_topk)"))
    energy: float = dataclasses.field(default=0.95, metadata=_cli(
        flag="--compress-energy",
        help="adaptive_topk per-agent energy target"))
    # "pallas": pack all leaves into one (N, M_total) buffer and run the
    # fused repro.kernels.compress kernels once per round (bit-identical
    # to the per-leaf "xla" path; compressors without a kernel fall
    # back).  "auto" (default) picks per case from the committed
    # benchmark heuristics (repro.fed.compress.resolve_backend) -- a
    # pure scheduling choice, since both backends are bit-identical.
    backend: str = dataclasses.field(default="auto", metadata=_cli(
        flag="--compress-backend", choices=["auto", "xla", "pallas"],
        help="uplink compressor backend (auto picks per case; pallas = "
             "fused packed kernels)"))


@dataclasses.dataclass(frozen=True)
class AgentGroupSpec:
    """One contiguous group of agents with its own local-training recipe.

    ``None`` fields inherit the top-level :class:`FedSpec` value, so a
    group only states what makes it *different*.  Groups partition the
    agent axis in order: the first group owns agents ``[0, size)``, the
    next ``[size, size + size')``, and so on; the engine runs each
    group's registered solver on its slice and re-stitches the stacked
    pytree (:func:`repro.fed.engine.run_solvers`).
    """

    size: int
    solver: Optional[str] = None         # repro.fed.solvers registry name
    n_epochs: Optional[int] = None       # N_e of this group
    gamma: Optional[float] = None        # local step size of this group
    participation: Optional[float] = None  # Bernoulli p of this group


def parse_agent_groups(text: str) -> tuple[AgentGroupSpec, ...]:
    """Parse the CLI grammar for ``--agent-groups``.

    Comma-separated groups, each ``SIZE[*SOLVER][:key=value]...`` with
    keys ``n_epochs`` / ``gamma`` / ``participation``; omitted pieces
    inherit the top-level spec.  Examples::

        2*gd,2*agd
        3*gd:participation=0.5,1*agd:n_epochs=1:gamma=0.02
    """
    groups = []
    for part in text.split(","):
        part = part.strip()
        if not part:
            raise ValueError(f"empty agent group in {text!r}")
        head, *opts = part.split(":")
        if "*" in head:
            size_s, solver = head.split("*", 1)
            solver = solver.strip() or None
        else:
            size_s, solver = head, None
        try:
            size = int(size_s)
        except ValueError:
            raise ValueError(
                f"agent group {part!r} must start with an integer size "
                f"(grammar: SIZE[*SOLVER][:key=value]...)") from None
        kw = {}
        for opt in opts:
            k, sep, val = opt.partition("=")
            k = k.strip()
            if not sep or k not in ("n_epochs", "gamma", "participation"):
                raise ValueError(
                    f"unknown agent-group option {opt!r} in {part!r} "
                    f"(known: n_epochs=, gamma=, participation=)")
            kw[k] = int(val) if k == "n_epochs" else float(val)
        groups.append(AgentGroupSpec(size=size, solver=solver, **kw))
    return tuple(groups)


# ---------------------------------------------------------------------------
# The spec
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class FedSpec:
    """Composable Fed-PLT specification -- the one front-door config."""

    # -- round topology --------------------------------------------------
    n_agents: Optional[int] = dataclasses.field(default=None, metadata=_cli(
        arg_type=int, default=4,
        help="number of agents (dense path: taken from the problem)"))
    rho: float = dataclasses.field(default=1.0, metadata=_cli(
        help="proximal penalty rho of Algorithm 1"))
    participation: float = dataclasses.field(default=1.0, metadata=_cli(
        help="per-agent Bernoulli participation probability p"))
    damping: float = dataclasses.field(default=1.0, metadata=_cli(
        help="Krasnosel'skii relaxation (1 = PRS, 0.5 = Douglas-Rachford)"))
    # -- local solver ----------------------------------------------------
    solver: str = dataclasses.field(default="gd", metadata=_cli(
        choices=["gd", "agd", "sgd"],
        help="local solver (tau > 0 upgrades gd-type to noisy_gd)"))
    # NOTE: the generated CLI default must equal the field default (one
    # FedSpec() regardless of the front end) -- asserted in tests.
    n_epochs: int = dataclasses.field(default=5, metadata=_cli(
        help="local epochs N_e per round"))
    gamma: Optional[float] = dataclasses.field(default=None, metadata=_cli(
        arg_type=float, default=0.05,
        help="local step size (None: optimal 2/(L_d + mu_d) from moduli; "
             "required at model scale)"))
    mu: Optional[float] = dataclasses.field(default=None,
                                            metadata=_cli(expose=False))
    L: Optional[float] = dataclasses.field(default=None,
                                           metadata=_cli(expose=False))
    batch_size: Optional[int] = dataclasses.field(
        default=None, metadata=_cli(expose=False))  # dense sgd minibatch
    uncoordinated: bool = dataclasses.field(
        default=False, metadata=_cli(expose=False))  # Remark 1 (dense)
    # -- heterogeneous agent groups -------------------------------------
    # None = every agent runs the top-level solver/n_epochs/gamma/
    # participation (the historical homogeneous path, bit-identical).
    # A tuple of AgentGroupSpec partitions the agent axis into groups,
    # each with its own registered solver and knobs.
    agent_groups: Optional[tuple[AgentGroupSpec, ...]] = dataclasses.field(
        default=None, metadata=_cli(
            arg_type=parse_agent_groups,
            help="heterogeneous agent groups, e.g. "
                 "'2*gd,2*agd:n_epochs=1:gamma=0.02' (sizes must sum to "
                 "n-agents; omitted knobs inherit the top-level spec)"))
    # -- coordinator regularizer h --------------------------------------
    prox_h: str = dataclasses.field(default="zero",
                                    metadata=_cli(expose=False))
    weight_decay: float = dataclasses.field(default=0.0, metadata=_cli(
        help="coordinator l2 regularizer h (prox_h='weight_decay')"))
    # -- composed specs --------------------------------------------------
    privacy: PrivacySpec = dataclasses.field(default_factory=PrivacySpec)
    compression: CompressionSpec = dataclasses.field(
        default_factory=CompressionSpec)
    # -- execution -------------------------------------------------------
    use_pallas: bool = dataclasses.field(default=False, metadata=_cli(
        flag="--use-pallas-update",
        help="fused fedplt_update kernel for the local step"))
    # "pallas": run the round's coordinator edges (prox + reflect;
    # z-update + participation selects) as the two fused
    # repro.kernels.round_edge launches on the packed (N, M_total)
    # buffer (fp32-rounding-identical to the per-leaf "xla" path --
    # parity contract in repro.fed.engine; custom non-elementwise
    # proxes and mixed-dtype trees fall back per edge)
    engine_backend: str = dataclasses.field(default="xla", metadata=_cli(
        flag="--engine-backend", choices=["xla", "pallas"],
        help="round-edge backend (pallas = fused packed kernels)"))
    # "packed": carry the federated state (x, z, t) as one resident
    # (N, M_total) buffer per variable across rounds -- packed once at
    # init, unpacked only at the API boundary (consensus / metrics /
    # checkpoints).  Bitwise-identical trajectories to "tree" per
    # realization (layout contract in repro.fed.engine).
    state_layout: str = dataclasses.field(default="tree", metadata=_cli(
        flag="--state-layout", choices=["tree", "packed"],
        help="round-to-round state representation (packed = one "
             "resident agent-axis buffer, zero per-round pack/unpack)"))
    # "stale": bounded-staleness async rounds -- the participation draw
    # becomes an ARRIVAL draw, non-arrived agents keep training against
    # their stale reflection, and an agent is forced to arrive when its
    # work is max_staleness rounds old.  max_staleness=0 reproduces the
    # synchronous engine bitwise per realization (contract in
    # repro.fed.async_engine).
    async_mode: str = dataclasses.field(default="off", metadata=_cli(
        flag="--async-mode", choices=["off", "stale"],
        help="async round mode (stale = bounded-staleness arrivals; "
             "off = bulk-synchronous rounds)"))
    max_staleness: int = dataclasses.field(default=0, metadata=_cli(
        flag="--max-staleness", arg_type=int,
        help="staleness bound K: an agent holding K-round-old work is "
             "forced to arrive (0 = synchronous semantics)"))
    # in-jit increment guards (fault tolerance): screen every agent's
    # uplink row for non-finite values (and, when guard_norm_bound is
    # finite, for norm above the bound) and convert a failing row into a
    # NON-ARRIVAL this round -- a bitwise no-op when every row is clean.
    guard_increments: bool = dataclasses.field(default=False, metadata=_cli(
        flag="--guard-increments",
        help="screen agent increments in-jit: a non-finite (or "
             "over-norm) uplink row becomes a non-arrival this round"))
    guard_norm_bound: float = dataclasses.field(
        default=float("inf"), metadata=_cli(
            flag="--guard-norm-bound", arg_type=float,
            help="l2 norm bound for --guard-increments (inf = "
                 "finiteness-only screen)"))
    # coordinator aggregation (repro.fed.robust registry): "mean" keeps
    # the historical uplink bitwise; trimmed_mean / coord_median /
    # norm_clip_mean replace it with a robust statistic of the live
    # rows, bounding what finite guard-evading byzantine increments
    # can do to the consensus
    aggregator: str = dataclasses.field(default="mean", metadata=_cli(
        flag="--aggregator",
        help="coordinator aggregator (repro.fed.robust registry name; "
             "mean = the historical uplink)"))
    aggregator_param: float = dataclasses.field(
        default=0.0, metadata=_cli(
            flag="--aggregator-param", arg_type=float,
            help="aggregator parameter: trim count f for trimmed_mean, "
                 "clip radius for norm_clip_mean"))
    # sharded rounds (engine mesh contract): shard the agent axis of
    # every per-agent carrier across this many devices.  1 = unsharded;
    # a 1-device mesh reproduces the unsharded trajectory bitwise.
    agent_shards: int = dataclasses.field(default=1, metadata=_cli(
        flag="--agent-shards", arg_type=int,
        help="shard the round's agent axis across this many devices "
             "(n-agents must divide evenly; 1 = unsharded)"))
    # explicit (agent, model) mesh extents as "AxM", e.g. "8x1"; None
    # derives (agent_shards, 1).  The model axis additionally shards
    # the packed buffer's columns when it divides the width.
    mesh_shape: Optional[str] = dataclasses.field(default=None, metadata=_cli(
        flag="--mesh-shape", arg_type=str,
        help="explicit AGENTSxMODEL device mesh, e.g. '8x1' "
             "(default: agent-shards x 1)"))

    def __post_init__(self):
        groups = self.agent_groups
        if groups is not None:
            if isinstance(groups, str):
                groups = parse_agent_groups(groups)
            object.__setattr__(self, "agent_groups", tuple(groups))

    # ------------------------------------------------------------------
    # Resolution
    # ------------------------------------------------------------------
    def solver_name(self) -> str:
        """tau > 0 turns the gd-type solvers into DP noisy GD."""
        return _upgrade_solver(self.solver, self.privacy.tau)

    def solver_config(self) -> SolverConfig:
        return SolverConfig(name=self.solver_name(),
                            n_epochs=self.n_epochs, step_size=self.gamma,
                            tau=self.privacy.tau, clip=self.privacy.clip)

    def resolved_groups(self) -> Optional[tuple[AgentGroupSpec, ...]]:
        """``agent_groups`` with every None field filled from the
        top-level spec (None when the spec is homogeneous)."""
        if self.agent_groups is None:
            return None
        return tuple(AgentGroupSpec(
            size=g.size,
            solver=g.solver if g.solver is not None else self.solver,
            n_epochs=(g.n_epochs if g.n_epochs is not None
                      else self.n_epochs),
            gamma=g.gamma if g.gamma is not None else self.gamma,
            participation=(g.participation if g.participation is not None
                           else self.participation))
            for g in self.agent_groups)

    def group_solver_configs(self) -> Optional[tuple[SolverConfig, ...]]:
        """Per-group :class:`SolverConfig` (tau>0 upgrades gd-type
        groups to noisy GD, exactly like the homogeneous path)."""
        groups = self.resolved_groups()
        if groups is None:
            return None
        return tuple(SolverConfig(
            name=_upgrade_solver(g.solver, self.privacy.tau),
            n_epochs=g.n_epochs, step_size=g.gamma,
            tau=self.privacy.tau, clip=self.privacy.clip)
            for g in groups)

    def participation_schedule(self) -> Union[float, tuple[float, ...]]:
        """Engine participation: the scalar p, or the per-agent (N,)
        tuple expanded from the groups when any group deviates."""
        groups = self.resolved_groups()
        if groups is None or all(
                g.participation == self.participation for g in groups):
            return self.participation
        out: list[float] = []
        for g in groups:
            out.extend([float(g.participation)] * g.size)
        return tuple(out)

    def round_config(self) -> engine.RoundConfig:
        if self.n_agents is None:
            raise ValueError("FedSpec.n_agents is unresolved (the dense "
                             "path fills it from the problem; set it "
                             "explicitly at model scale)")
        return engine.RoundConfig(
            n_agents=self.n_agents, rho=self.rho,
            participation=self.participation_schedule(),
            damping=self.damping,
            compression=self.compression.name,
            compress_ratio=self.compression.ratio,
            compress_energy=self.compression.energy,
            compress_backend=self.compression.backend,
            engine_backend=self.engine_backend,
            state_layout=self.state_layout,
            staleness=self.staleness_config(),
            agent_shards=self.resolved_agent_shards(),
            guard_increments=self.guard_increments,
            guard_norm_bound=self.guard_norm_bound,
            aggregator=self.aggregator,
            aggregator_param=self.aggregator_param)

    def staleness_config(self) -> engine.StalenessConfig:
        """The engine :class:`repro.fed.engine.StalenessConfig` this
        spec denotes (validates mode / bound on construction)."""
        return engine.StalenessConfig(mode=self.async_mode,
                                      max_staleness=self.max_staleness)

    def mesh_axes(self) -> Optional[tuple[int, int]]:
        """The ``(agent, model)`` mesh extents this spec denotes, or
        None when the run is unsharded.  ``mesh_shape`` wins when set
        (and must agree with a non-default ``agent_shards``)."""
        if self.mesh_shape is None:
            if self.agent_shards == 1:
                return None
            return (self.agent_shards, 1)
        parts = self.mesh_shape.lower().split("x")
        if len(parts) != 2:
            raise ValueError(
                f"mesh_shape must be 'AGENTSxMODEL' (e.g. '8x1'), got "
                f"{self.mesh_shape!r}")
        try:
            a, m = (int(p) for p in parts)
        except ValueError:
            raise ValueError(
                f"mesh_shape extents must be integers, got "
                f"{self.mesh_shape!r}") from None
        if a < 1 or m < 1:
            raise ValueError(f"mesh_shape extents must be >= 1, got "
                             f"{self.mesh_shape!r}")
        if self.agent_shards != 1 and self.agent_shards != a:
            raise ValueError(
                f"agent_shards={self.agent_shards} disagrees with "
                f"mesh_shape={self.mesh_shape!r} (agent extent {a}); "
                f"set one, or make them agree")
        return (a, m)

    def resolved_agent_shards(self) -> int:
        """The agent-axis device count the engine must validate against
        (1 when unsharded)."""
        axes = self.mesh_axes()
        return 1 if axes is None else axes[0]

    def build_mesh(self):
        """The ``jax.sharding.Mesh`` this spec denotes, or None when
        unsharded.  Raises with the host-device escape hatch named when
        the platform has too few devices."""
        axes = self.mesh_axes()
        if axes is None:
            return None
        import numpy as np
        from jax.sharding import Mesh

        a, m = axes
        devices = jax.devices()
        if len(devices) < a * m:
            raise ValueError(
                f"mesh of {a}x{m} needs {a * m} devices, but only "
                f"{len(devices)} are visible -- on CPU set "
                f"XLA_FLAGS=--xla_force_host_platform_device_count="
                f"{a * m} before importing jax")
        return Mesh(np.asarray(devices[:a * m]).reshape(a, m),
                    ("agent", "model"))

    def moduli_for(self, gamma: Optional[float]) \
            -> tuple[float, Optional[float]]:
        """(mu, L) of the local f_i given a group's step size.  Explicit
        values win; with ``gamma`` set (model scale) an unknown L is
        derived as 1/gamma - 1/rho so that agd's 1/L_d step equals
        gamma; with neither (dense path) L stays None and the problem's
        own moduli are used."""
        mu = self.mu if self.mu is not None else 0.0
        if self.L is not None:
            return mu, self.L
        if gamma is None:
            return mu, None
        return mu, 1.0 / gamma - 1.0 / self.rho

    def moduli(self) -> tuple[float, Optional[float]]:
        """(mu, L) of the local f_i for momentum resolution (top-level
        gamma; see :meth:`moduli_for`)."""
        return self.moduli_for(self.gamma)

    def resolve_prox_h(self) -> engine.ProxH:
        """Engine ProxH of the coordinator regularizer h; None when h = 0.
        Every name -- including the model path's weight decay -- comes
        from the one :func:`repro.core.prox.make_prox` registry."""
        if self.weight_decay != 0.0:
            return prox_lib.make_prox("weight_decay",
                                      weight=self.weight_decay)
        if self.prox_h == "zero":
            return None
        return prox_lib.make_prox(self.prox_h)

    # ------------------------------------------------------------------
    # Validation: the single home of every cross-field check
    # ------------------------------------------------------------------
    def validate(self) -> "FedSpec":
        """Raise ValueError on any inconsistent combination; returns self
        so call sites can chain ``spec.validate()``."""
        if self.n_agents is not None and self.n_agents < 1:
            raise ValueError("n_agents must be >= 1")
        if self.rho <= 0.0:
            raise ValueError("rho must be positive")
        if not 0.0 < self.participation <= 1.0:
            raise ValueError("participation must be in (0, 1]")
        if not 0.0 < self.damping <= 1.0:
            raise ValueError("damping must be in (0, 1]")
        if self.n_epochs < 1:
            raise ValueError("n_epochs must be >= 1")
        if self.gamma is not None and self.gamma <= 0.0:
            raise ValueError("gamma must be positive")
        p = self.privacy
        if p.tau < 0.0:
            raise ValueError("tau must be >= 0")
        if p.clip is not None and p.clip <= 0.0:
            raise ValueError("clip must be positive (clip=0 zeroes every "
                             "gradient; use None to disable clipping)")
        if not 0.0 < p.delta < 1.0:
            raise ValueError("delta must be in (0, 1)")
        name = self.solver_name()   # raises for agd + tau > 0
        get_solver(name)            # unknown-solver registry error
        get_compressor(self.compression.name)  # unknown-compressor error
        if not 0.0 < self.compression.ratio <= 1.0:
            raise ValueError("compress ratio must be in (0, 1]")
        if not 0.0 < self.compression.energy <= 1.0:
            raise ValueError("compress energy must be in (0, 1]")
        if self.compression.backend not in COMPRESS_BACKENDS:
            raise ValueError(
                f"unknown compress backend {self.compression.backend!r}; "
                f"known: {', '.join(COMPRESS_BACKENDS)}")
        if self.engine_backend not in engine.ENGINE_BACKENDS:
            raise ValueError(
                f"unknown engine backend {self.engine_backend!r}; "
                f"known: {', '.join(engine.ENGINE_BACKENDS)}")
        if self.state_layout not in engine.ENGINE_LAYOUTS:
            raise ValueError(
                f"unknown state layout {self.state_layout!r}; "
                f"known: {', '.join(engine.ENGINE_LAYOUTS)}")
        self.staleness_config()     # bad mode / bound -> ValueError
        if not self.guard_norm_bound > 0.0:   # also rejects NaN
            raise ValueError("guard_norm_bound must be positive (use "
                             "inf for a finiteness-only screen)")
        validate_aggregator(self.aggregator, self.aggregator_param,
                            self.n_agents)
        if self.weight_decay < 0.0:
            raise ValueError("weight_decay must be >= 0")
        if self.weight_decay != 0.0 and self.prox_h not in (
                "zero", "weight_decay"):
            raise ValueError("weight_decay and a non-trivial prox_h are "
                             "mutually exclusive (one coordinator h)")
        self.resolve_prox_h()       # unknown prox name -> KeyError
        if name == "agd":
            self._check_agd_moduli(self.gamma)
        self._validate_groups()
        self._validate_mesh()
        return self

    def _check_agd_moduli(self, gamma: Optional[float],
                          where: str = "") -> None:
        mu, L = self.moduli_for(gamma)
        if L is not None and L <= mu:
            if self.L is not None:
                raise ValueError(f"agd momentum needs L > mu (got "
                                 f"L={L:.4g}, mu={mu:.4g}){where}")
            raise ValueError(
                f"agd momentum needs L > mu; derived L={L:.4g} from "
                f"gamma={gamma} (needs gamma < rho/(1 + mu*rho) "
                f"= {self.rho / (1.0 + mu * self.rho):.4g}) -- pass "
                f"an explicit L in the spec{where}")

    def _validate_groups(self) -> None:
        groups = self.resolved_groups()
        if groups is None:
            return
        if not groups:
            raise ValueError("agent_groups must have at least one group "
                             "(use None for the homogeneous path)")
        for i, g in enumerate(groups):
            where = f" (agent group {i})"
            if g.size < 1:
                raise ValueError(f"agent group sizes must be >= 1, got "
                                 f"{g.size}{where}")
            gname = _upgrade_solver(g.solver, self.privacy.tau)
            get_solver(gname)   # unknown-solver registry error
            if g.n_epochs < 1:
                raise ValueError(f"n_epochs must be >= 1{where}")
            if g.gamma is not None and g.gamma <= 0.0:
                raise ValueError(f"gamma must be positive{where}")
            if not 0.0 < g.participation <= 1.0:
                raise ValueError(
                    f"participation must be in (0, 1]{where}")
            if gname == "agd":
                self._check_agd_moduli(g.gamma, where)
        total = sum(g.size for g in groups)
        if self.n_agents is not None and total != self.n_agents:
            raise ValueError(
                f"agent_groups sizes sum to {total}, but "
                f"n_agents={self.n_agents} -- groups must partition the "
                f"agent axis")

    def _validate_mesh(self) -> None:
        if self.agent_shards < 1:
            raise ValueError(f"agent_shards must be >= 1, got "
                             f"{self.agent_shards}")
        shards = self.resolved_agent_shards()  # parses/checks mesh_shape
        if shards == 1:
            return
        if self.n_agents is not None and self.n_agents % shards != 0:
            raise ValueError(
                f"n_agents={self.n_agents} is not divisible by "
                f"agent_shards={shards} -- every device must own the "
                f"same number of agent rows (pad n_agents or change the "
                f"shard count)")
        groups = self.resolved_groups()
        if groups is not None and self.n_agents is not None:
            rows = self.n_agents // shards
            edge = 0
            for i, g in enumerate(groups[:-1]):
                edge += g.size
                if edge % rows != 0:
                    raise ValueError(
                        f"agent group {i} ends at row {edge}, which is "
                        f"not a multiple of the shard size {rows} "
                        f"(n_agents={self.n_agents} / agent_shards="
                        f"{shards}) -- a solver group may not straddle "
                        f"a device boundary; re-cut the groups or "
                        f"change the shard count")

    # ------------------------------------------------------------------
    # Legacy-config bridge (kept bit-compatible)
    # ------------------------------------------------------------------
    def to_dense_config(self):
        """The :class:`repro.core.fedplt.FedPLTConfig` this spec denotes
        (inverse of ``FedPLTConfig.to_spec``, used by the dense trainer
        so trajectories stay bit-identical to the legacy front end)."""
        from repro.core.fedplt import FedPLTConfig

        return FedPLTConfig(
            rho=self.rho,
            solver=self.solver_config(),
            participation=self.participation,
            prox_h=self.prox_h,
            batch_size=self.batch_size,
            mu=self.mu, L=self.L,
            dp_init=self.privacy.dp_init,
            uncoordinated=self.uncoordinated,
            compression=self.compression.name,
            compress_ratio=self.compression.ratio,
            compress_energy=self.compression.energy,
            compress_backend=self.compression.backend,
            engine_backend=self.engine_backend,
            state_layout=self.state_layout,
            damping=self.damping,
            async_mode=self.async_mode,
            max_staleness=self.max_staleness,
            guard_increments=self.guard_increments,
            guard_norm_bound=self.guard_norm_bound,
            aggregator=self.aggregator,
            aggregator_param=self.aggregator_param)


def as_spec(cfg: Any) -> FedSpec:
    """Normalize a FedSpec / FedPLTConfig / FedConfig to a FedSpec."""
    if isinstance(cfg, FedSpec):
        return cfg
    to_spec = getattr(cfg, "to_spec", None)
    if to_spec is None:
        raise TypeError(f"cannot interpret {type(cfg).__name__} as a "
                        f"FedSpec (no .to_spec())")
    return to_spec()


# ---------------------------------------------------------------------------
# Privacy accounting from the spec
# ---------------------------------------------------------------------------

def _resolve_gamma(spec: "FedSpec", gamma: Optional[float]) -> float:
    """A concrete step size for the accountant: the configured gamma, or
    the optimal 2/(L_d + mu_d) derived from explicit moduli."""
    if gamma is not None:
        return gamma
    m, L = spec.moduli()
    if L is None:
        raise ValueError("privacy_report needs gamma (or explicit "
                         "moduli to derive it)")
    return spec.solver_config().resolve_step_size(
        m + 1.0 / spec.rho, L + 1.0 / spec.rho)


def privacy_report(spec: Any, n_rounds: int,
                   local_dataset_size: Union[int, Sequence[int]],
                   delta: Optional[float] = None, *,
                   mu: Optional[float] = None):
    """Position a DP run on the paper's (eps, delta) map (Prop. 4 +
    Lemma 5 via :mod:`repro.core.privacy`).

    Proposition 4 is a PER-AGENT statement: eps_i depends on agent i's
    dataset size q_i and local epoch count.  ``local_dataset_size`` may
    therefore be one int (every agent) or a per-agent sequence; with
    per-agent sizes or a heterogeneous ``spec.agent_groups`` the report
    carries the full per-agent (eps_i, delta) table
    (``report.per_agent``) and its headline ``adp_eps`` is the max over
    agents -- the budget the deployment as a whole must honor.  A
    homogeneous spec with one scalar q returns the historical scalar
    report unchanged.

    ``mu`` is the strong-convexity modulus the accountant charges
    against: the caller's problem modulus on the dense path, and by
    default the curvature the algorithm optimizes against at model scale
    (the proximal term gives d_i strong convexity >= weight_decay +
    1/rho, valid even for nonconvex local losses).

    Sensitivity convention: ``core.privacy`` expects the paper's
    Assumption-3 L (a PER-SAMPLE gradient bound; the bound divides by
    q^2).  The runtime clips the per-agent MEAN gradient at C, so
    swapping one of q samples can move the clipped gradient by up to 2C
    -- the per-sample-equivalent bound is L = C * q_i.  An unclipped run
    assumes per-sample bound L = 1.0 and a loud caveat is on the caller.
    """
    from repro.core.privacy import PrivacyReport

    spec = as_spec(spec).validate()
    p = spec.privacy
    if p.tau <= 0.0:
        raise ValueError("privacy_report requires tau > 0")
    mu_eff = mu if mu is not None else spec.weight_decay + 1.0 / spec.rho
    if mu_eff <= 0.0:
        raise ValueError("privacy accounting requires a strongly convex "
                         "local objective (mu > 0)")
    delta_eff = delta if delta is not None else p.delta
    groups = spec.resolved_groups()

    if isinstance(local_dataset_size, (str, bytes)):
        raise TypeError("local_dataset_size must be an int or a "
                        "sequence of per-agent ints, not a string")
    try:                     # a per-agent sequence of q_i?
        qs = [int(q) for q in local_dataset_size]
    except TypeError:        # scalar (python or numpy int): every agent
        qs = None

    if groups is None and qs is None:
        # homogeneous spec, one q: the historical scalar report
        gamma = _resolve_gamma(spec, spec.gamma)
        sensitivity = (p.clip * local_dataset_size
                       if p.clip is not None else 1.0)
        return PrivacyReport.build(
            sensitivity=sensitivity, mu=mu_eff, tau=p.tau,
            q=local_dataset_size, gamma=gamma, K=n_rounds,
            n_epochs=spec.n_epochs, delta=delta_eff)

    # per-agent accounting: expand groups / q_i to one row per agent
    qs, gammas, epochs, sensitivities = _per_agent_inputs(spec, qs,
                                                          local_dataset_size)
    return PrivacyReport.build_per_agent(
        sensitivities=sensitivities, mu=mu_eff, tau=p.tau, qs=qs,
        gammas=gammas, K=n_rounds, n_epochs_seq=epochs, delta=delta_eff)


def _per_agent_inputs(spec: "FedSpec", qs, local_dataset_size):
    """Expand a validated spec + dataset size(s) to one accounting row
    per agent: ``(qs, gammas, epochs, sensitivities)``, each length N."""
    if spec.n_agents is None:
        raise ValueError("per-agent privacy_report needs a resolved "
                         "n_agents")
    N = spec.n_agents
    if qs is None:
        qs = [int(local_dataset_size)] * N
    if len(qs) != N:
        raise ValueError(f"local_dataset_size has {len(qs)} entries for "
                         f"n_agents={N}")
    groups = spec.resolved_groups()
    if groups is None:
        gammas = [_resolve_gamma(spec, spec.gamma)] * N
        epochs = [spec.n_epochs] * N
    else:
        gammas, epochs = [], []
        for g in groups:
            gammas.extend([_resolve_gamma(spec, g.gamma)] * g.size)
            epochs.extend([g.n_epochs] * g.size)
    clip = spec.privacy.clip
    sensitivities = [clip * q if clip is not None else 1.0 for q in qs]
    return qs, gammas, epochs, sensitivities


def effective_privacy_report(spec: Any, schedule,
                             local_dataset_size: Union[int, Sequence[int]],
                             delta: Optional[float] = None, *,
                             mu: Optional[float] = None):
    """Per-agent privacy report under a REALIZED async arrival schedule.

    ``schedule`` is the ``(n_rounds, n_agents)`` 0/1 arrival record of a
    bounded-staleness run (stacked per-round arrival masks -- a broker's
    ``ArrivalSchedule.arrivals`` or the stacked ``u`` of the in-jit
    model).  Staleness changes the DP *composition*, not the mechanism:
    agent i released ``arrivals_i`` increments carrying
    ``released_rounds_i`` rounds of local epochs (an increment ``s``
    rounds stale carries ``s + 1`` rounds; work discarded at the bound
    was never transmitted and charges nothing).  The report therefore
    composes agent i over ``K_i = released_rounds_i`` effective rounds
    instead of the nominal round count -- always the per-agent table,
    even for a homogeneous spec, because realized schedules are
    per-agent by nature.
    """
    from repro.core.privacy import PrivacyReport
    from repro.fed.async_engine import effective_counts

    spec = as_spec(spec).validate()
    p = spec.privacy
    if p.tau <= 0.0:
        raise ValueError("effective_privacy_report requires tau > 0")
    mu_eff = mu if mu is not None else spec.weight_decay + 1.0 / spec.rho
    if mu_eff <= 0.0:
        raise ValueError("privacy accounting requires a strongly convex "
                         "local objective (mu > 0)")
    delta_eff = delta if delta is not None else p.delta

    if isinstance(local_dataset_size, (str, bytes)):
        raise TypeError("local_dataset_size must be an int or a "
                        "sequence of per-agent ints, not a string")
    try:
        qs = [int(q) for q in local_dataset_size]
    except TypeError:
        qs = None
    qs, gammas, epochs, sensitivities = _per_agent_inputs(spec, qs,
                                                          local_dataset_size)
    import numpy as _np
    sched = _np.asarray(schedule)
    if sched.ndim != 2 or sched.shape[1] != spec.n_agents:
        raise ValueError(f"schedule must be (n_rounds, n_agents="
                         f"{spec.n_agents}), got shape {sched.shape}")
    arrivals, released = effective_counts(sched, spec.max_staleness)
    return PrivacyReport.build_per_agent(
        sensitivities=sensitivities, mu=mu_eff, tau=p.tau, qs=qs,
        gammas=gammas, K=int(sched.shape[0]), n_epochs_seq=epochs,
        delta=delta_eff, Ks=[int(k) for k in released],
        arrivals=[int(a) for a in arrivals])


# ---------------------------------------------------------------------------
# The trainer handle
# ---------------------------------------------------------------------------

class FedTrainer:
    """Uniform handle over both Fed-PLT front ends.

    ``init / step / run / consensus / privacy_report`` mean the same
    thing on the dense paper problems and at model scale; only ``step``
    / ``run`` arity differs (model-scale rounds consume a batch).
    """

    spec: FedSpec

    def init(self, key: jax.Array):
        raise NotImplementedError

    def step(self, state, *args):
        raise NotImplementedError

    def run(self, key: jax.Array, n_rounds: int, *args):
        raise NotImplementedError

    def consensus(self, state):
        raise NotImplementedError

    def privacy_report(self, n_rounds: int,
                       local_dataset_size=None,
                       delta: Optional[float] = None):
        raise NotImplementedError


class DenseTrainer(FedTrainer):
    """:class:`repro.core.fedplt.FedPLT` behind the FedTrainer handle --
    trajectories are bit-identical to the legacy front end."""

    def __init__(self, problem, spec: FedSpec):
        if spec.n_agents not in (None, problem.n_agents):
            raise ValueError(f"spec.n_agents={spec.n_agents} != "
                             f"problem.n_agents={problem.n_agents}")
        self.spec = dataclasses.replace(spec, n_agents=problem.n_agents)
        # the spec with the problem's actual curvature filled in --
        # validation and privacy accounting both need the real moduli
        self._resolved = dataclasses.replace(
            self.spec,
            mu=spec.mu if spec.mu is not None
            else float(problem.strong_convexity()),
            L=spec.L if spec.L is not None
            else float(problem.smoothness())).validate()
        from repro.core.fedplt import FedPLT

        prox_override = (self.spec.resolve_prox_h()
                         if self.spec.weight_decay != 0.0 else None)
        groups = self._resolved.resolved_groups()
        solver_groups = None
        if groups is not None:
            solver_groups = tuple(
                (g.size, scfg) for g, scfg in zip(
                    groups, self._resolved.group_solver_configs()))
        part = self._resolved.participation_schedule()
        self.problem = problem
        self.algo = FedPLT(problem, self.spec.to_dense_config(),
                           prox_h=prox_override,
                           solver_groups=solver_groups,
                           participation=part if isinstance(part, tuple)
                           else None,
                           mesh=self._resolved.build_mesh())

    def init(self, key: jax.Array):
        return self.algo.init(key)

    def step(self, state):
        """One Fed-PLT round (jitted)."""
        return self.algo.round(state)

    def run(self, key: jax.Array, n_rounds: int):
        """Run from a fresh init; returns (state, criterion_history)."""
        return self.algo.run(key, n_rounds)

    def run_recorded(self, key: jax.Array, n_rounds: int):
        """:meth:`run` that also returns the realized ``(n_rounds, N)``
        arrival schedule (feed it to :meth:`effective_privacy_report`
        or :meth:`replay`)."""
        return self.algo.run_recorded(key, n_rounds)

    def replay(self, key: jax.Array, schedule):
        """Re-run a recorded arrival schedule through the in-jit async
        model (bit-identical to the run that recorded it)."""
        return self.algo.replay(key, schedule)

    def round_with_faults(self, state, arrival=None, corrupt=None,
                          live=None):
        """One round under broker-supplied fault overrides: ``arrival``
        (N,) 0/1 row, ``corrupt`` (N,) per-agent corruption multipliers
        (0 = clean), ``live`` (N,) 0/1 survivor mask.  All None
        reproduces :meth:`step` bitwise."""
        return self.algo.round_with_faults(state, arrival, corrupt, live)

    def consensus(self, state):
        return self.algo.x_bar(state)

    def privacy_report(self, n_rounds: int,
                       local_dataset_size=None,
                       delta: Optional[float] = None):
        """``local_dataset_size`` may be one int or a per-agent sequence
        of q_i (defaults to the problem's uniform q)."""
        q = (local_dataset_size if local_dataset_size is not None
             else self.problem.q)
        return privacy_report(self._resolved, n_rounds, q, delta,
                              mu=self.algo.mu if self.algo.mu > 0
                              else None)

    def effective_privacy_report(self, schedule,
                                 local_dataset_size=None,
                                 delta: Optional[float] = None):
        """Per-agent report under a realized async arrival schedule
        (see :func:`repro.fed.api.effective_privacy_report`)."""
        q = (local_dataset_size if local_dataset_size is not None
             else self.problem.q)
        return effective_privacy_report(
            self._resolved, schedule, q, delta,
            mu=self.algo.mu if self.algo.mu > 0 else None)


class ModelTrainer(FedTrainer):
    """:mod:`repro.fed.runtime` behind the FedTrainer handle."""

    def __init__(self, model, spec: FedSpec, use_remat: bool = True):
        if spec.n_agents is None:
            raise ValueError("FedSpec.n_agents is required at model scale")
        if spec.gamma is None:
            raise ValueError("FedSpec.gamma is required at model scale "
                             "(the local moduli are unknown)")
        from repro.fed import runtime

        self.spec = spec.validate()
        self.model = model
        self._runtime = runtime
        # packed layout: the one static buffer meta of the run, needed
        # for the API-boundary unpack (consensus / checkpoint targets)
        self.packed_meta = (runtime.packed_layout(model, self.spec)
                            if self.spec.state_layout == "packed"
                            else None)
        # sharded rounds: the (agent, model) mesh of the run; the round
        # engine wraps the edges in shard_map on it and init places the
        # state by repro.fed.sharding.fed_state_specs (the one placement
        # source, shared with the dry-run compiler)
        self.mesh = self.spec.build_mesh()
        self._step = jax.jit(
            runtime.make_train_step(model, spec, use_remat=use_remat))

    def _state_shardings(self):
        from repro.fed import sharding

        axes = dict(zip(self.mesh.axis_names, self.mesh.devices.shape))
        agent_axis, fsdp_axis = sharding.fed_axes(axes)
        shapes = jax.eval_shape(self.model.init, jax.random.PRNGKey(0))
        stacked = jax.tree_util.tree_map(
            lambda s: jax.ShapeDtypeStruct(
                (self.spec.n_agents,) + s.shape, s.dtype), shapes)
        specs = sharding.fed_state_specs(
            stacked, fsdp_axis=fsdp_axis, agent_axis=agent_axis,
            axis_sizes=axes,
            compressed=self.spec.compression.name != "none",
            packed=self.spec.state_layout == "packed",
            stale=self.spec.staleness_config().enabled)
        return sharding.shardings(self.mesh, specs)

    def init(self, key: jax.Array):
        state = self._runtime.init_state(self.model, key, self.spec)
        if self.mesh is None:
            return state
        return jax.device_put(state, self._state_shardings())

    def step(self, state, batch, key: jax.Array, arrival=None,
             corrupt=None, live=None):
        """One jitted Fed-PLT round on an agent-stacked batch.
        ``arrival`` (async mode) replaces the arrival draw with a
        recorded (N,) 0/1 schedule row -- broker numerics / replay.
        ``corrupt`` / ``live`` are the broker's fault overrides (see
        :mod:`repro.fed.broker`): per-agent corruption multipliers and
        the survivor mask after evictions."""
        return self._step(state, batch, key, arrival, corrupt, live)

    def run(self, key: jax.Array, n_rounds: int, batches):
        """Run from a fresh init.  ``batches`` is either a callable
        ``i -> batch`` or an iterable of per-round batches; returns
        ``(state, metrics_history)``.  Scalar metrics come back as
        floats; vector metrics (the async mode's per-agent ``arrivals``
        row) as numpy arrays."""
        import numpy as np

        state = self.init(key)
        if callable(batches):
            get = batches
        else:
            it = iter(batches)
            get = lambda i: next(it)  # noqa: E731
        history = []
        for i in range(n_rounds):
            state, m = self.step(state, get(i), jax.random.fold_in(key, i))
            history.append({
                k: float(v) if getattr(v, "ndim", 0) == 0 else np.asarray(v)
                for k, v in m.items()})
        return state, history

    def consensus(self, state):
        return self._runtime.consensus_model(state, meta=self.packed_meta)

    def privacy_report(self, n_rounds: int,
                       local_dataset_size=None,
                       delta: Optional[float] = None):
        """``local_dataset_size`` may be one int or a per-agent sequence
        of q_i."""
        if local_dataset_size is None:
            raise ValueError("model-scale privacy_report needs the local "
                             "dataset size q_i")
        return privacy_report(self.spec, n_rounds, local_dataset_size,
                              delta)


def build_trainer(problem_or_model, spec: Any) -> FedTrainer:
    """The front door: a unified trainer over both Fed-PLT paths.

    Dense convex problems (``local_loss`` + ``n_agents``; see
    :mod:`repro.core.problem`) get the paper-faithful ``FedPLT`` engine
    front end; model objects (``init`` + ``loss_fn``; see
    :mod:`repro.models.model`) get the model-scale runtime.  ``spec``
    may be a :class:`FedSpec` or any legacy config with ``.to_spec()``.
    """
    spec = as_spec(spec)
    if hasattr(problem_or_model, "local_loss") and \
            hasattr(problem_or_model, "n_agents"):
        return DenseTrainer(problem_or_model, spec)
    if hasattr(problem_or_model, "loss_fn") and \
            hasattr(problem_or_model, "init"):
        return ModelTrainer(problem_or_model, spec)
    raise TypeError(
        f"cannot build a trainer for {type(problem_or_model).__name__}: "
        f"expected a dense problem (local_loss/n_agents) or a model "
        f"(init/loss_fn)")


# ---------------------------------------------------------------------------
# CLI generation: argparse flags derived from the spec fields
# ---------------------------------------------------------------------------

def _cli_entries():
    """(owner, field, flag, dest, argparse-kwargs) for every exposed
    spec field, derived from the dataclass metadata -- the CLI cannot
    drift from the spec because it is generated from it."""
    out = []
    for owner in ("spec", "privacy", "compression"):
        cls = {"spec": FedSpec, "privacy": PrivacySpec,
               "compression": CompressionSpec}[owner]
        for f in dataclasses.fields(cls):
            if dataclasses.is_dataclass(f.type) or f.name in (
                    "privacy", "compression"):
                continue
            meta = f.metadata.get("cli")
            if meta is None or not meta["expose"]:
                continue
            flag = meta["flag"] or "--" + f.name.replace("_", "-")
            dest = flag.lstrip("-").replace("-", "_")
            default = (meta["default"] if meta["default"] is not None
                       else f.default)
            kwargs = dict(default=default, help=meta["help"])
            if f.type in ("bool", bool):
                kwargs["action"] = "store_true"
            else:
                kwargs["type"] = meta["type"] or type(default)
                if meta["choices"]:
                    kwargs["choices"] = meta["choices"]
            if f.name == "name" and owner == "compression":
                kwargs["choices"] = available_compressors()
            if f.name == "aggregator" and owner == "spec":
                kwargs["choices"] = available_aggregators()
            out.append((owner, f.name, flag, dest, kwargs))
    return out


def add_spec_args(ap: argparse.ArgumentParser) -> argparse.ArgumentParser:
    """Add one flag per exposed :class:`FedSpec` field (fed mode)."""
    for _, _, flag, _, kwargs in _cli_entries():
        ap.add_argument(flag, **kwargs)
    return ap


def spec_from_args(args) -> FedSpec:
    """Build a :class:`FedSpec` from parsed args (or an argv list).

    Accepts either the ``argparse.Namespace`` of a parser that went
    through :func:`add_spec_args`, or a raw argv list, e.g.
    ``spec_from_args(["--tau", "0.1", "--solver", "gd"])``.
    """
    if not isinstance(args, argparse.Namespace):
        ap = argparse.ArgumentParser(prog="fedspec")
        add_spec_args(ap)
        args = ap.parse_args(list(args))
    buckets = {"spec": {}, "privacy": {}, "compression": {}}
    for owner, name, _, dest, _ in _cli_entries():
        buckets[owner][name] = getattr(args, dest)
    return FedSpec(privacy=PrivacySpec(**buckets["privacy"]),
                   compression=CompressionSpec(**buckets["compression"]),
                   **buckets["spec"])
