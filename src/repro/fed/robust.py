"""Byzantine-robust coordinator aggregation (ROADMAP fault-tolerance leg).

The Fed-PLT coordinator step is ``y = prox_{rho h/N}(mean_i z_i)`` -- a
mean with BREAKDOWN POINT ZERO: the in-jit increment guards quarantine
non-finite or over-norm rows, but one adversarial agent submitting a
finite, in-norm-bound, sign-flipped increment still steers the
consensus arbitrarily.  This module supplies the missing layer: a
registry of robust aggregators that replace the plain agent mean at the
uplink, selected by ``RoundConfig.aggregator`` / ``FedSpec.aggregator``.

Registry (mirrors :func:`repro.fed.compress.register_compressor` /
:func:`repro.fed.solvers.register_solver`): an aggregator is
``fn(z, live, *, param, colmask=None, model_axis=None) -> (1, M)``
over the agent-stacked ``(N, M)`` buffer.  ``live`` is the broker's 0/1
eviction row (None = everyone live): dead rows are EXCLUDED from the
order statistics, matching the survivor-mean semantics of
:func:`repro.fed.engine.survivor_mean_input`.  ``colmask`` marks real
(non-lane-padding) columns for aggregators whose arithmetic couples
columns (``norm_clip_mean`` row norms); per-column order statistics
ignore it.  ``model_axis`` is the mesh axis name to ``psum`` row-norm
partials over when the column axis is itself sharded.

Built-ins:

* ``mean`` -- the bitwise-identical default.  The engine never routes
  it through this module: :func:`repro.fed.engine.robust_seen` resolves
  ``"mean"`` (and ``trimmed_mean`` with ``f = 0``) to the historical
  :func:`survivor_mean_input` path, so clean configurations keep the
  exact pre-robustness graph.
* ``trimmed_mean`` -- drop the ``f = int(param)`` smallest and largest
  live values per column, average the rest.  Tolerates ``f`` byzantine
  agents (breakdown ``f < N/2`` enforced at validation).
* ``coord_median`` -- per-column median of the live values
  (``trimmed_mean`` at maximal trim; breakdown 1/2).
* ``norm_clip_mean`` -- centered clipping: rows are recentered at the
  coordinate-wise median, clipped to l2 radius ``param``, and averaged.
  Bounds any single agent's pull by ``param / n_live`` while keeping
  full mean efficiency for in-radius honest rows.

HOW THE ENGINE CONSUMES THE AGGREGATE: the robust statistic is folded
in as a ``z_seen`` INPUT TRANSFORM -- the ``(1, M)`` aggregate is
broadcast back to ``(N, M)`` and handed to the unchanged round edges,
whose fixed mean-over-N of N identical rows reproduces the aggregate
(to f32 rounding; exactly when N is a power of two).  One transform
point therefore composes with every layout x backend x compressor x
mesh combination and with the fused downlink, which recomputes the
coordinator chain from the SAME broadcast buffer -- no kernel learns a
second code path.  The reflection ``v = 2 y - z`` still reads the
original ``z``.

MESH CONTRACT extension: order statistics need the FULL agent column,
so the sharded packed path all-gathers the per-shard row blocks on the
``agent`` axis before aggregating -- ``(N/shards, M_local)`` rows move
per device per round, versus the mean's single ``(1, M)`` psum.  That
cost is the price of a breakdown point (documented in ROADMAP);
``mean`` keeps the single-psum uplink untouched.  A 1-device mesh is
bitwise identical to the unsharded path (the gather of one shard is
the identity).

Backends: ``trimmed_mean`` and ``coord_median`` have a Pallas
column-wise sort-and-trim kernel (:mod:`repro.kernels.robust_agg`)
used under ``engine_backend="pallas"``; the XLA oracle
(:func:`repro.kernels.robust_agg.ref.robust_aggregate_ref`) is
BITWISE-identical (parity contract, asserted in tests), so backends
never fork trajectories at the aggregate.  ``norm_clip_mean`` is
XLA-only (its clip is a dense row-wise rescale, already one fused
elementwise chain).

Robust aggregation interacts with privacy accounting in one direction
only: it can SAVE a run from a poisoned consensus, but it never
refunds epsilon -- DP guarantees come from the local noise mechanism
(Prop. 4) and are unaffected by how the coordinator combines the
submitted increments.
"""

from __future__ import annotations

import math
from typing import Callable, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.fed import compress as compress_lib
from repro.kernels.robust_agg.ref import robust_aggregate_ref

tree_map = jax.tree_util.tree_map

# Aggregators with a Pallas sort-and-trim kernel (others always run the
# XLA registry implementation, whatever the engine backend)
PALLAS_AGGREGATORS = frozenset({"trimmed_mean", "coord_median"})

# fn(z, live, *, param, colmask=None, model_axis=None) -> (1, M)
Aggregator = Callable[..., jnp.ndarray]

_AGGREGATORS: Dict[str, Aggregator] = {}


def register_aggregator(name: str):
    """Register an aggregator under ``name`` (decorator), making it
    reachable from every front end via ``FedSpec.aggregator``."""

    def deco(fn: Aggregator) -> Aggregator:
        _AGGREGATORS[name] = fn
        return fn

    return deco


def get_aggregator(name: str) -> Aggregator:
    try:
        return _AGGREGATORS[name]
    except KeyError:
        raise ValueError(
            f"unknown aggregator {name!r}; registered: "
            f"{', '.join(sorted(_AGGREGATORS))}") from None


def available_aggregators():
    return sorted(_AGGREGATORS)


def validate_aggregator(name: str, param, n_agents: Optional[int] = None
                        ) -> float:
    """Construction-time screening of an (aggregator, param) pair;
    returns the normalized float param.  One home for the rules, called
    by ``FedSpec.validate()`` and ``RoundConfig.__post_init__`` alike:

    * ``trimmed_mean``: ``param`` is the trim count ``f`` -- a
      non-negative integer with ``2 f < n_agents`` (something must
      survive the trim; ``f`` is also the byzantine tolerance).
    * ``norm_clip_mean``: ``param`` is the clip radius -- finite, > 0.
    * ``mean`` / ``coord_median``: no parameter (``param`` ignored).
    """
    get_aggregator(name)   # fail fast on unknown names
    try:
        p = float(param)
    except (TypeError, ValueError):
        raise ValueError(
            f"aggregator_param must be a number, got {param!r}") from None
    if name == "trimmed_mean":
        if not (math.isfinite(p) and p >= 0 and p == int(p)):
            raise ValueError(
                f"trimmed_mean takes a non-negative integer trim count "
                f"f as aggregator_param, got {param!r}")
        if n_agents is not None and 2 * int(p) >= n_agents:
            raise ValueError(
                f"trimmed_mean with f={int(p)} trims 2f={2 * int(p)} of "
                f"n_agents={n_agents} rows: need 2f < N so at least one "
                f"row survives the trim")
    elif name == "norm_clip_mean":
        if not (math.isfinite(p) and p > 0):
            raise ValueError(
                f"norm_clip_mean takes a finite positive clip radius as "
                f"aggregator_param, got {param!r}")
    return p


# ---------------------------------------------------------------------------
# Shared pieces
# ---------------------------------------------------------------------------

def _live_row(live, n: int) -> jnp.ndarray:
    """Canonical ``(1, N)`` float 0/1 live row (None = all live)."""
    if live is None:
        return jnp.ones((1, n), jnp.float32)
    return jnp.asarray(live, jnp.float32).reshape(1, n)


def _mean_live(rows: jnp.ndarray, lv: jnp.ndarray) -> jnp.ndarray:
    """Mean over live rows -> ``(1, M)`` (``lv`` is ``(1, N)``)."""
    n_live = jnp.maximum(jnp.sum(lv), 1.0)
    return jnp.sum(rows * lv.T, axis=0, keepdims=True) / n_live


# ---------------------------------------------------------------------------
# Built-in aggregators
# ---------------------------------------------------------------------------

@register_aggregator("mean")
def _mean(z, live, *, param, colmask=None, model_axis=None):
    """Survivor mean -- the registry form of the engine default (the
    engine itself short-circuits to :func:`survivor_mean_input`)."""
    return _mean_live(z, _live_row(live, z.shape[0]))


@register_aggregator("trimmed_mean")
def _trimmed_mean(z, live, *, param, colmask=None, model_axis=None):
    return robust_aggregate_ref(z, live, stat="trimmed_mean",
                                trim=int(param))


@register_aggregator("coord_median")
def _coord_median(z, live, *, param, colmask=None, model_axis=None):
    return robust_aggregate_ref(z, live, stat="coord_median")


@register_aggregator("norm_clip_mean")
def _norm_clip_mean(z, live, *, param, colmask=None, model_axis=None):
    """Centered clipping: recenter at the coordinate-wise median, clip
    each live row's residual to l2 radius ``param``, average.  The
    residual norm is taken over REAL columns only (``colmask``): lane
    padding may have drifted in the resident packed layout, and must
    not perturb real-column results (layout parity)."""
    lv = _live_row(live, z.shape[0])
    center = robust_aggregate_ref(z, live, stat="coord_median")
    r = z - center
    if colmask is not None:
        r = r * colmask.astype(r.dtype)
    partial = jnp.sum(jnp.square(r.astype(jnp.float32)), axis=1,
                      keepdims=True)
    if model_axis is not None:
        partial = jax.lax.psum(partial, model_axis)
    norms = jnp.sqrt(partial)
    scale = jnp.minimum(1.0, param / jnp.maximum(norms, 1e-12))
    return center + _mean_live(r * scale.astype(r.dtype), lv)


# ---------------------------------------------------------------------------
# Dispatch: one (N, M) buffer -> (1, M) aggregate
# ---------------------------------------------------------------------------

def aggregate_rows(z: jnp.ndarray, live, *, name: str, param: float,
                   colmask=None, backend: str = "xla",
                   model_axis: Optional[str] = None) -> jnp.ndarray:
    """Aggregate the agent-stacked ``(N, M)`` buffer to ``(1, M)``.

    ``backend="pallas"`` routes :data:`PALLAS_AGGREGATORS` through the
    :mod:`repro.kernels.robust_agg` sort-and-trim kernel (bitwise equal
    to the registry oracle -- parity contract); everything else, and
    every aggregator without a kernel, runs the registry entry."""
    if backend == "pallas" and name in PALLAS_AGGREGATORS \
            and model_axis is None:
        from repro.kernels.robust_agg import ops as robust_ops

        return robust_ops.robust_aggregate(
            z, live, stat=name,
            trim=int(param) if name == "trimmed_mean" else 0)
    return get_aggregator(name)(z, live, param=param, colmask=colmask,
                                model_axis=model_axis)


def _segment_colmask(meta) -> Optional[np.ndarray]:
    """``(1, width)`` bool mask of real (in-segment) columns, or None
    when the packing has no lane padding."""
    mask = np.zeros((1, meta.width), bool)
    for a, b in meta.segments:
        mask[0, a:b] = True
    return None if mask.all() else mask


# ---------------------------------------------------------------------------
# Engine entry points: the z_seen input transforms
# ---------------------------------------------------------------------------

def robust_seen_packed(z_seen: jnp.ndarray, live, *, name: str,
                       param: float, meta, backend: str,
                       mesh=None, col_axis: Optional[str] = None
                       ) -> jnp.ndarray:
    """Robust ``z_seen`` transform on the resident packed buffer:
    aggregate the live rows, broadcast back to ``(N, width)``.

    With a ``mesh`` the transform runs under ``shard_map``: each agent
    shard all-gathers the full agent column (the mesh-contract cost of
    an order statistic), aggregates locally via the XLA oracle (bitwise
    equal to the kernel -- parity contract), and writes its own row
    block of the broadcast.  ``col_axis`` names the mesh axis sharding
    the column dimension (None = replicated columns)."""
    n, width = z_seen.shape
    lv = _live_row(live, n)
    colmask = _segment_colmask(meta)
    if mesh is None:
        agg = aggregate_rows(z_seen, lv, name=name, param=param,
                             colmask=None if colmask is None
                             else jnp.asarray(colmask),
                             backend=backend)
        return jnp.broadcast_to(agg, z_seen.shape)

    cmask = np.ones((1, width), bool) if colmask is None else colmask

    def body(z_l, lv_l, cm_l):
        z_full = jax.lax.all_gather(z_l, "agent", axis=0, tiled=True)
        agg = aggregate_rows(z_full, lv_l, name=name, param=param,
                             colmask=cm_l, backend="xla",
                             model_axis=col_axis)
        return jnp.broadcast_to(agg, z_l.shape)

    spec = P("agent", col_axis)
    f = shard_map(body, mesh=mesh,
                  in_specs=(spec, P(), P(None, col_axis)),
                  out_specs=spec, check_rep=False)
    return f(z_seen, lv, jnp.asarray(cmask))


def robust_seen_tree(z_seen, live, *, name: str, param: float,
                     backend: str):
    """Robust ``z_seen`` transform on agent-stacked pytrees: pack the
    leaves (fresh pack -- padding columns are exact zeros), aggregate,
    broadcast, unpack.  Real-column arithmetic is identical to the
    packed-resident path, so tree and packed trajectories stay
    bitwise-aligned per realization (layout contract)."""
    buf, meta = compress_lib.pack_leaves(z_seen)
    out = robust_seen_packed(buf, live, name=name, param=param,
                             meta=meta, backend=backend)
    return compress_lib.unpack_leaves(out, meta)
