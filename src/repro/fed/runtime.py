"""Fed-PLT at model scale: the paper's Algorithm 1 on parameter pytrees.

The paper's agents become mesh slices: per-agent states (x_i, z_i) are the
model parameter pytree stacked on a leading agent axis (sharded over
'data' on a single pod, over 'pod' across pods).  One jitted
``train_step`` is one Fed-PLT round, delegated to the unified round
engine (:mod:`repro.fed.engine`):

  1. coordinator:  y = prox_h( mean_A z )        -- ONE agent-axis
     all-reduce per round (vs one per step for FedAvg-style DP training:
     this is the paper's communication saving, mapped to the inter-slice
     link);
  2. N_e local epochs of the chosen solver (gd / agd / sgd / noisy_gd,
     :mod:`repro.core.solvers` generalized to pytrees) -- no agent-axis
     collectives inside; the fused update is the fedplt_update Pallas
     kernel on TPU;
  3. masked participation update of (x, z), optionally with topk/int8
     increment compression of the z uplink (lag-based error feedback).

The gradient grad f_i is computed on the agent's local batch, vmapped over
the agent axis; within an agent, activations shard over 'model' (+'data'
in multi-pod fed mode).
"""

from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core.solvers import SolverConfig
from repro.fed import engine
from repro.models.model import Model


class FedState(NamedTuple):
    x: Any              # pytree, leaves (A, ...)
    z: Any              # pytree, leaves (A, ...)
    step: jnp.ndarray
    # coordinator's copy of z -- only materialized when the z-exchange is
    # compressed (None otherwise: at model scale t doubles state memory)
    t: Any = None


@dataclasses.dataclass(frozen=True)
class FedConfig:
    n_agents: int = 16
    rho: float = 1.0
    gamma: float = 0.05
    n_epochs: int = 5
    participation: float = 1.0
    tau: float = 0.0                 # DP noise std (forces noisy local GD)
    clip: Optional[float] = None     # per-agent gradient clipping
    weight_decay: float = 0.0        # coordinator prox: l2 regularizer h
    use_pallas_update: bool = False  # fused fedplt_update kernel for the
    #   local step (interpret-mode on CPU; real kernel on TPU)
    solver: str = "gd"               # gd | agd | sgd (tau>0 -> noisy_gd)
    # curvature moduli of the local losses; 0 -> derived from gamma so
    # that agd's 1/L_d step equals gamma
    mu: float = 0.0
    L: float = 0.0
    compression: str = "none"        # none | topk | int8 (z uplink)
    compress_ratio: float = 0.25
    damping: float = 1.0             # Krasnosel'skii relaxation

    def solver_name(self) -> str:
        """tau > 0 turns the gd-type solvers into DP noisy GD."""
        if self.tau > 0.0:
            if self.solver == "agd":
                raise ValueError("DP noise (tau > 0) requires a gd-type "
                                 "solver, not 'agd'")
            return "noisy_gd"
        return self.solver

    def solver_config(self) -> SolverConfig:
        return SolverConfig(name=self.solver_name(),
                            n_epochs=self.n_epochs, step_size=self.gamma,
                            tau=self.tau, clip=self.clip)

    def moduli(self) -> tuple[float, float]:
        """(mu, L) of the local f_i for momentum resolution.  gd-type
        solvers step with the configured gamma regardless; when L is
        unknown we pick L_d = 1/gamma so that agd's 1/L_d step also
        equals gamma.  That inversion needs gamma < rho/(1 + mu*rho);
        agd with a larger gamma must pass L explicitly (enforced in
        :func:`make_train_step`)."""
        if self.L > 0.0:
            return self.mu, self.L
        return self.mu, 1.0 / self.gamma - 1.0 / self.rho

    def round_config(self) -> engine.RoundConfig:
        return engine.RoundConfig(
            n_agents=self.n_agents, rho=self.rho,
            participation=self.participation, damping=self.damping,
            compression=self.compression,
            compress_ratio=self.compress_ratio)


def init_state(model: Model, key: jax.Array, fcfg: FedConfig) -> FedState:
    params = model.init(key)
    stacked = jax.tree_util.tree_map(
        lambda p: jnp.broadcast_to(p, (fcfg.n_agents,) + p.shape), params)
    t = stacked if fcfg.compression != "none" else None
    return FedState(x=stacked, z=stacked, step=jnp.zeros((), jnp.int32),
                    t=t)


def _prox_h(fcfg: FedConfig):
    """Leaf-wise engine ProxH of h = (wd/2)||.||^2 (Lemma 6); None when
    weight_decay = 0 (smooth problems, h = 0).  The engine calls it with
    rho_eff = rho / N."""
    if fcfg.weight_decay == 0.0:
        return None
    return lambda yl, rho_eff: yl / (1.0 + fcfg.weight_decay * rho_eff)


def _coordinator_prox(zbar, fcfg: FedConfig):
    """Apply the coordinator prox to an agent-mean pytree (convenience /
    test hook; delegates to the same :func:`_prox_h` the engine uses)."""
    prox = _prox_h(fcfg)
    if prox is None:
        return zbar
    rho_eff = fcfg.rho / fcfg.n_agents
    return jax.tree_util.tree_map(lambda t: prox(t, rho_eff), zbar)


def make_train_step(model: Model, fcfg: FedConfig, use_remat: bool = True):
    """Returns ``step(state, batch, key) -> (state, metrics)``.

    ``batch`` leaves carry a leading agent axis: tokens (A, b, S), etc.
    """
    scfg = fcfg.solver_config()
    ecfg = fcfg.round_config()
    prox_h = _prox_h(fcfg)
    mu, L = fcfg.moduli()
    if fcfg.clip is not None and fcfg.clip <= 0.0:
        raise ValueError("FedConfig.clip must be positive (clip=0 zeroes "
                         "every gradient; use None to disable clipping)")
    if scfg.name == "agd" and L <= mu:
        raise ValueError(
            f"agd momentum needs L > mu; derived L={L:.4g} from "
            f"gamma={fcfg.gamma} (needs gamma < rho/(1 + mu*rho) = "
            f"{fcfg.rho / (1.0 + fcfg.mu * fcfg.rho):.4g}) -- pass an "
            f"explicit L in FedConfig")

    def per_agent_loss(params_i, batch_i):
        return model.loss_fn(params_i, batch=batch_i, remat=use_remat)

    grad_fn = jax.value_and_grad(per_agent_loss)

    def train_step(state: FedState, batch, key: jax.Array):
        rkey = jax.random.fold_in(key, state.step)

        def fgrad(w, k):
            del k  # the local batch is fixed within a round
            losses, g = jax.vmap(grad_fn)(w, batch)
            return g, losses

        local_solver = engine.make_local_solver(
            scfg, fgrad, fcfg.rho, mu, L,
            use_pallas=fcfg.use_pallas_update, has_aux=True)

        t = state.t if ecfg.compressed else state.z
        res = engine.round_step(ecfg, state.x, state.z, t, rkey,
                                local_solver, prox_h=prox_h)

        metrics = {
            "loss": jnp.mean(res.aux[-1]),   # (N_e, A) per-epoch losses
            "participation": jnp.mean(res.u.astype(jnp.float32)),
        }
        new_state = FedState(x=res.x, z=res.z, step=state.step + 1,
                             t=res.t if ecfg.compressed else None)
        return new_state, metrics

    return train_step


def consensus_model(state: FedState):
    """The deployable model: the coordinator average of the agent states."""
    return jax.tree_util.tree_map(lambda x: jnp.mean(x, axis=0), state.x)


def privacy_report(fcfg: FedConfig, n_rounds: int, local_dataset_size: int,
                   delta: float = 1e-5):
    """Position a DP training run on the paper's (eps, delta) map
    (Prop. 4 + Lemma 5 via :mod:`repro.core.privacy`).

    At model scale the local losses are nonconvex, so we account with the
    curvature the algorithm actually optimizes against: the proximal term
    gives d_i strong convexity >= weight_decay + 1/rho.

    Sensitivity convention: ``core.privacy`` expects the paper's
    Assumption-3 L (a PER-SAMPLE gradient bound; the bound divides by
    q^2).  The runtime clips the per-agent MEAN gradient at C, so
    swapping one of q samples can move the clipped gradient by up to 2C
    -- the per-sample-equivalent bound is L = C * q.  An unclipped run
    assumes per-sample bound L = 1.0 and a loud caveat is on the caller.
    """
    from repro.core.privacy import PrivacyReport

    if fcfg.tau <= 0.0:
        raise ValueError("privacy_report requires tau > 0")
    if fcfg.clip is not None and fcfg.clip <= 0.0:
        raise ValueError("clip must be positive (clip=0 zeroes every "
                         "gradient)")
    mu_eff = fcfg.weight_decay + 1.0 / fcfg.rho
    sensitivity = (fcfg.clip * local_dataset_size
                   if fcfg.clip is not None else 1.0)
    return PrivacyReport.build(
        sensitivity=sensitivity, mu=mu_eff, tau=fcfg.tau,
        q=local_dataset_size, gamma=fcfg.gamma, K=n_rounds,
        n_epochs=fcfg.n_epochs, delta=delta)
