"""Fed-PLT at model scale: the paper's Algorithm 1 on parameter pytrees.

The paper's agents become mesh slices: per-agent states (x_i, z_i) are the
model parameter pytree stacked on a leading agent axis (sharded over
'data' on a single pod, over 'pod' across pods).  One jitted
``train_step`` is one Fed-PLT round, delegated to the unified round
engine (:mod:`repro.fed.engine`):

  1. coordinator:  y = prox_h( mean_A z )        -- ONE agent-axis
     all-reduce per round (vs one per step for FedAvg-style DP training:
     this is the paper's communication saving, mapped to the inter-slice
     link);
  2. N_e local epochs of the chosen solver (gd / agd / sgd / noisy_gd,
     :mod:`repro.core.solvers` generalized to pytrees) -- no agent-axis
     collectives inside; the fused update is the fedplt_update Pallas
     kernel on TPU;
  3. masked participation update of (x, z), optionally with topk/int8
     increment compression of the z uplink (lag-based error feedback).

The gradient grad f_i is computed on the agent's local batch, vmapped over
the agent axis; within an agent, activations shard over 'model' (+'data'
in multi-pod fed mode).
"""

from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.fed import engine
from repro.fed.api import FedSpec, as_spec
from repro.fed.api import privacy_report as _spec_privacy_report
from repro.models.model import Model


class FedState(NamedTuple):
    """Per-agent federated state.

    Tree layout (default): ``x``/``z``/``t`` are parameter pytrees with
    leaves ``(A, ...)``.  Packed layout (``spec.state_layout ==
    "packed"``, engine layout contract): each is ONE resident
    ``(A, width)`` buffer laid out by the static
    :func:`packed_layout` meta -- packed once in :func:`init_state`,
    unpacked only at the API boundary (:func:`consensus_model`,
    checkpoint restore targets)."""

    x: Any              # pytree, leaves (A, ...) -- or (A, width) buffer
    z: Any              # pytree, leaves (A, ...) -- or (A, width) buffer
    step: jnp.ndarray
    # coordinator's copy of z -- only materialized when the z-exchange is
    # compressed (None otherwise: at model scale t doubles state memory)
    t: Any = None
    # bounded-staleness async rounds only (None when synchronous): the
    # per-agent pulled coordinator point and staleness counters carried
    # by repro.fed.async_engine
    y_tag: Any = None
    staleness: Any = None   # (A,) int32


@dataclasses.dataclass(frozen=True)
class FedConfig:
    """Thin legacy shim over :class:`repro.fed.api.FedSpec`.

    Kept for existing call sites; every runtime entry point normalizes
    it via :meth:`to_spec`, and all validation lives in
    ``FedSpec.validate`` -- prefer constructing a ``FedSpec`` (or
    ``api.build_trainer``) directly in new code.
    """

    n_agents: int = 16
    rho: float = 1.0
    gamma: float = 0.05
    n_epochs: int = 5
    participation: float = 1.0
    tau: float = 0.0                 # DP noise std (forces noisy local GD)
    clip: Optional[float] = None     # per-agent gradient clipping
    weight_decay: float = 0.0        # coordinator prox: l2 regularizer h
    use_pallas_update: bool = False  # fused fedplt_update kernel for the
    #   local step (interpret-mode on CPU; real kernel on TPU)
    solver: str = "gd"               # gd | agd | sgd (tau>0 -> noisy_gd)
    # curvature moduli of the local losses; 0 -> derived from gamma so
    # that agd's 1/L_d step equals gamma
    mu: float = 0.0
    L: float = 0.0
    compression: str = "none"        # z-uplink compressor registry name
    compress_ratio: float = 0.25
    compress_backend: str = "xla"    # "auto" | "xla" per-leaf | "pallas"
    engine_backend: str = "xla"      # round edges: "xla" | "pallas" fused
    state_layout: str = "tree"       # "tree" | "packed" resident buffer
    damping: float = 1.0             # Krasnosel'skii relaxation
    async_mode: str = "off"          # "off" | "stale" bounded staleness
    max_staleness: int = 0           # K: forced arrival bound
    guard_increments: bool = False   # in-jit finite/norm screen on uplinks
    guard_norm_bound: float = float("inf")  # inf = finiteness-only screen
    aggregator: str = "mean"         # repro.fed.robust registry name
    aggregator_param: float = 0.0    # trim count f / clip radius

    def to_spec(self) -> FedSpec:
        from repro.fed.api import CompressionSpec, PrivacySpec

        return FedSpec(
            n_agents=self.n_agents, rho=self.rho,
            participation=self.participation, damping=self.damping,
            solver=self.solver, n_epochs=self.n_epochs, gamma=self.gamma,
            mu=self.mu if self.mu != 0.0 else None,
            L=self.L if self.L > 0.0 else None,
            weight_decay=self.weight_decay,
            privacy=PrivacySpec(tau=self.tau, clip=self.clip),
            compression=CompressionSpec(name=self.compression,
                                        ratio=self.compress_ratio,
                                        backend=self.compress_backend),
            engine_backend=self.engine_backend,
            state_layout=self.state_layout,
            use_pallas=self.use_pallas_update,
            async_mode=self.async_mode,
            max_staleness=self.max_staleness,
            guard_increments=self.guard_increments,
            guard_norm_bound=self.guard_norm_bound,
            aggregator=self.aggregator,
            aggregator_param=self.aggregator_param)


def packed_layout(model: Model, fcfg):
    """The static :class:`repro.fed.compress.PackedMeta` of a model's
    agent-stacked state -- pure shape arithmetic over
    ``jax.eval_shape(model.init)``, so no parameters are materialized.
    One meta serves the whole run (init, every round, the API
    boundary)."""
    from repro.fed import compress as compress_lib

    spec = as_spec(fcfg)
    shapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    stacked = jax.tree_util.tree_map(
        lambda s: jax.ShapeDtypeStruct((spec.n_agents,) + s.shape,
                                       s.dtype), shapes)
    return compress_lib.packed_meta(stacked)


def init_state(model: Model, key: jax.Array, fcfg) -> FedState:
    """``fcfg`` may be a legacy :class:`FedConfig` or a ``FedSpec``.

    Under the packed layout the broadcast parameter stack is packed
    ONCE here -- the round loop never packs again."""
    spec = as_spec(fcfg)
    params = model.init(key)
    stacked = jax.tree_util.tree_map(
        lambda p: jnp.broadcast_to(p, (spec.n_agents,) + p.shape), params)
    if spec.state_layout == "packed":
        from repro.fed.compress import pack_leaves

        stacked = pack_leaves(stacked)[0]
    t = stacked if spec.compression.name != "none" else None
    stale = spec.staleness_config().enabled
    return FedState(x=stacked, z=stacked, step=jnp.zeros((), jnp.int32),
                    t=t,
                    y_tag=(jax.tree_util.tree_map(jnp.zeros_like, stacked)
                           if stale else None),
                    staleness=(jnp.zeros((spec.n_agents,), jnp.int32)
                               if stale else None))


def _coordinator_prox(zbar, fcfg):
    """Apply the coordinator prox to an agent-mean pytree (convenience /
    test hook; delegates to the same registry ProxH the engine uses)."""
    spec = as_spec(fcfg)
    prox = spec.resolve_prox_h()
    if prox is None:
        return zbar
    rho_eff = spec.rho / spec.n_agents
    return jax.tree_util.tree_map(lambda t: prox(t, rho_eff), zbar)


def make_train_step(model: Model, fcfg, use_remat: bool = True):
    """Returns ``step(state, batch, key) -> (state, metrics)``.

    ``fcfg`` may be a legacy :class:`FedConfig` or a ``FedSpec``;
    ``batch`` leaves carry a leading agent axis: tokens (A, b, S), etc.
    """
    spec = as_spec(fcfg).validate()   # the ONE validation site
    scfg = spec.solver_config()
    ecfg = spec.round_config()
    mesh = spec.build_mesh()          # None = unsharded rounds
    prox_h = spec.resolve_prox_h()
    mu, L = spec.moduli()
    groups = spec.resolved_groups()
    group_cfgs = spec.group_solver_configs()
    # packed layout: one static meta; solvers are built on the resident
    # buffer (gd/agd/sgd run directly on it, the gradient oracle
    # unpacking inside the jit -- see repro.fed.solvers)
    meta = (packed_layout(model, spec)
            if spec.state_layout == "packed" else None)
    if meta is not None:
        from repro.fed.solvers import make_packed_local_solver

        def make_solver(cfg_s, fgrad, mu_s, L_s):
            return make_packed_local_solver(
                cfg_s, fgrad, spec.rho, mu_s, L_s, meta=meta,
                use_pallas=spec.use_pallas, has_aux=True)
    else:
        def make_solver(cfg_s, fgrad, mu_s, L_s):
            return engine.make_local_solver(
                cfg_s, fgrad, spec.rho, mu_s, L_s,
                use_pallas=spec.use_pallas, has_aux=True)

    def per_agent_loss(params_i, batch_i):
        return model.loss_fn(params_i, batch=batch_i, remat=use_remat)

    grad_fn = jax.value_and_grad(per_agent_loss)

    def train_step(state: FedState, batch, key: jax.Array, arrival=None,
                   corrupt=None, live=None):
        rkey = jax.random.fold_in(key, state.step)

        def fgrad_for(batch_slice):
            def fgrad(w, k):
                del k  # the local batch is fixed within a round
                losses, g = jax.vmap(grad_fn)(w, batch_slice)
                return g, losses
            return fgrad

        if groups is None:
            local_solver = make_solver(scfg, fgrad_for(batch), mu, L)
        else:
            # heterogeneous groups: each contiguous agent slice gets its
            # own registered solver over its slice of the batch, with
            # moduli derived from the group's own step size
            local_solver, start = [], 0
            for g, gscfg in zip(groups, group_cfgs):
                stop = start + g.size
                batch_g = jax.tree_util.tree_map(
                    lambda b, lo=start, hi=stop: b[lo:hi], batch)
                mu_g, L_g = spec.moduli_for(gscfg.step_size)
                local_solver.append(engine.SolverGroup(
                    g.size, make_solver(gscfg, fgrad_for(batch_g),
                                        mu_g, L_g)))
                start = stop
            local_solver = tuple(local_solver)

        t = state.t if ecfg.compressed else state.z
        if ecfg.staleness.enabled:
            from repro.fed import async_engine

            if meta is not None:
                res = async_engine.packed_async_round_step(
                    ecfg, meta, state.x, state.z, t, state.y_tag,
                    state.staleness, rkey, local_solver, prox_h=prox_h,
                    arrival=arrival, mesh=mesh, corrupt=corrupt,
                    live=live)
            else:
                res = async_engine.async_round_step(
                    ecfg, state.x, state.z, t, state.y_tag,
                    state.staleness, rkey, local_solver, prox_h=prox_h,
                    arrival=arrival, mesh=mesh, corrupt=corrupt,
                    live=live)
        elif arrival is not None:
            raise ValueError("arrival schedules require async_mode="
                             "'stale' (synchronous rounds draw "
                             "participation internally)")
        elif meta is not None:
            res = engine.packed_round_step(ecfg, meta, state.x, state.z,
                                           t, rkey, local_solver,
                                           prox_h=prox_h, mesh=mesh,
                                           corrupt=corrupt, live=live)
        else:
            res = engine.round_step(ecfg, state.x, state.z, t, rkey,
                                    local_solver, prox_h=prox_h,
                                    mesh=mesh, corrupt=corrupt,
                                    live=live)

        # aux is the (N_e, A) per-epoch loss stack when homogeneous, a
        # tuple of per-group (N_e_g, size_g) stacks when grouped (epoch
        # counts may differ per group).  A custom registry solver may
        # return aux=None -- its agents drop out of the metric (NaN when
        # nobody reports) rather than crashing the round.
        if groups is None or len(local_solver) == 1:
            lasts = [] if res.aux is None else [res.aux[-1]]
        else:
            lasts = [a[-1] for a in (res.aux or ()) if a is not None]
        loss = (jnp.mean(jnp.concatenate(lasts)) if lasts
                else jnp.asarray(jnp.nan, jnp.float32))
        metrics = {
            "loss": loss,
            "participation": jnp.mean(res.u.astype(jnp.float32)),
        }
        if ecfg.staleness.enabled:
            # the realized (A,) arrival row -- stack over rounds to get
            # the schedule effective_privacy_report composes over
            metrics["arrivals"] = res.u
            metrics["staleness"] = jnp.mean(
                res.staleness.astype(jnp.float32))
            new_state = FedState(x=res.x, z=res.z, step=state.step + 1,
                                 t=res.t if ecfg.compressed else None,
                                 y_tag=res.y_tag,
                                 staleness=res.staleness)
        else:
            new_state = FedState(x=res.x, z=res.z, step=state.step + 1,
                                 t=res.t if ecfg.compressed else None)
        return new_state, metrics

    return train_step


def consensus_model(state: FedState, meta=None):
    """The deployable model: the coordinator average of the agent states.

    ``meta`` is required for a packed-layout state (the API-boundary
    unpack of the layout contract); the tree layout ignores it."""
    x = state.x
    if meta is not None:
        from repro.fed.compress import unpack_leaves

        x = unpack_leaves(x, meta)
    return jax.tree_util.tree_map(lambda l: jnp.mean(l, axis=0), x)


def privacy_report(fcfg, n_rounds: int, local_dataset_size: int,
                   delta: float = 1e-5):
    """Position a DP training run on the paper's (eps, delta) map.

    Thin delegate to :func:`repro.fed.api.privacy_report` (one
    accountant for both front ends); at model scale the local losses are
    nonconvex, so it accounts with the curvature the algorithm actually
    optimizes against (the proximal term gives d_i strong convexity
    >= weight_decay + 1/rho).  See the api docstring for the
    sensitivity convention.
    """
    return _spec_privacy_report(as_spec(fcfg), n_rounds,
                                local_dataset_size, delta)
