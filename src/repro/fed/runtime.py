"""Fed-PLT at model scale: the paper's Algorithm 1 on parameter pytrees.

The paper's agents become mesh slices: per-agent states (x_i, z_i) are the
model parameter pytree stacked on a leading agent axis (sharded over
'data' on a single pod, over 'pod' across pods).  One jitted
``train_step`` is one Fed-PLT round:

  1. coordinator:  y = prox_h( mean_A z )        -- ONE agent-axis
     all-reduce per round (vs one per step for FedAvg-style DP training:
     this is the paper's communication saving, mapped to the inter-slice
     link);
  2. N_e local epochs of  w <- w - gamma (grad f_i(w) + (w - v_i)/rho) + t,
     t ~ sqrt(2 gamma) N(0, tau^2)  -- no agent-axis collectives inside
     (``lax.scan``; the fused update is the fedplt_update Pallas kernel on
     TPU);
  3. masked participation update of (x, z).

The gradient grad f_i is computed on the agent's local batch, vmapped over
the agent axis; within an agent, activations shard over 'model' (+'data'
in multi-pod fed mode).
"""

from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.models.model import Model


class FedState(NamedTuple):
    x: Any              # pytree, leaves (A, ...)
    z: Any              # pytree, leaves (A, ...)
    step: jnp.ndarray


@dataclasses.dataclass(frozen=True)
class FedConfig:
    n_agents: int = 16
    rho: float = 1.0
    gamma: float = 0.05
    n_epochs: int = 5
    participation: float = 1.0
    tau: float = 0.0                 # DP noise std (noisy local GD)
    clip: Optional[float] = None     # per-agent gradient clipping
    weight_decay: float = 0.0        # coordinator prox: l2 regularizer h
    use_pallas_update: bool = False  # fused fedplt_update kernel for the
    #   local step (interpret-mode on CPU; real kernel on TPU)


def init_state(model: Model, key: jax.Array, fcfg: FedConfig) -> FedState:
    params = model.init(key)
    stacked = jax.tree_util.tree_map(
        lambda p: jnp.broadcast_to(p, (fcfg.n_agents,) + p.shape), params)
    return FedState(x=stacked, z=stacked, step=jnp.zeros((), jnp.int32))


def _coordinator_prox(zbar, fcfg: FedConfig):
    """prox of h = (wd/2)||.||^2 at the coordinator (Lemma 6); identity
    when weight_decay = 0 (smooth problems, h = 0)."""
    if fcfg.weight_decay == 0.0:
        return zbar
    shrink = 1.0 / (1.0 + fcfg.rho * fcfg.weight_decay / fcfg.n_agents)
    return jax.tree_util.tree_map(lambda t: t * shrink, zbar)


def _clip_tree(g, clip):
    if clip is None:
        return g
    leaves = jax.tree_util.tree_leaves(g)
    nrm = jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                       for l in leaves))
    factor = jnp.minimum(1.0, clip / jnp.maximum(nrm, 1e-12))
    return jax.tree_util.tree_map(lambda l: l * factor.astype(l.dtype), g)


def make_train_step(model: Model, fcfg: FedConfig, use_remat: bool = True):
    """Returns ``step(state, batch, key) -> (state, metrics)``.

    ``batch`` leaves carry a leading agent axis: tokens (A, b, S), etc.
    """

    def per_agent_loss(params_i, batch_i):
        return model.loss_fn(params_i, batch=batch_i, remat=use_remat)

    grad_fn = jax.value_and_grad(per_agent_loss)

    def train_step(state: FedState, batch, key: jax.Array):
        A = fcfg.n_agents
        k_part, k_noise = jax.random.split(jax.random.fold_in(key,
                                                              state.step))

        # ---- coordinator: ONE cross-agent collective per round ---------
        zbar = jax.tree_util.tree_map(lambda z: jnp.mean(z, axis=0),
                                      state.z)
        y = _coordinator_prox(zbar, fcfg)
        v = jax.tree_util.tree_map(lambda yy, zz: 2.0 * yy[None] - zz,
                                   y, state.z)

        # ---- local training: N_e epochs, no cross-agent collectives ----
        inv_rho = 1.0 / fcfg.rho
        noise_scale = jnp.sqrt(2.0 * fcfg.gamma) * fcfg.tau

        def local_epoch(w, epoch_key):
            losses, g = jax.vmap(grad_fn)(w, batch)
            if fcfg.clip is not None:
                g = jax.vmap(lambda gi: _clip_tree(gi, fcfg.clip))(g)

            def upd(w_l, g_l, v_l, path_seed):
                noise = None
                if fcfg.tau > 0.0:
                    nk = jax.random.fold_in(epoch_key, path_seed)
                    noise = noise_scale * jax.random.normal(
                        nk, w_l.shape, jnp.float32)
                if fcfg.use_pallas_update:
                    # fused Pallas kernel: 3 reads + 1 write, fp32 accum
                    from repro.kernels.fedplt_update.ops import \
                        fedplt_update
                    new = fedplt_update(
                        w_l, g_l.astype(w_l.dtype), v_l.astype(w_l.dtype),
                        t=None if noise is None else
                        noise.astype(w_l.dtype),
                        gamma=fcfg.gamma, inv_rho=inv_rho)
                    return new
                new = w_l - fcfg.gamma * (
                    g_l.astype(jnp.float32)
                    + inv_rho * (w_l.astype(jnp.float32)
                                 - v_l.astype(jnp.float32)))
                if noise is not None:
                    new = new + noise
                return new.astype(w_l.dtype)

            leaves, treedef = jax.tree_util.tree_flatten(w)
            g_leaves = treedef.flatten_up_to(g)
            v_leaves = treedef.flatten_up_to(v)
            new_leaves = [upd(wl, gl, vl, i) for i, (wl, gl, vl)
                          in enumerate(zip(leaves, g_leaves, v_leaves))]
            return (jax.tree_util.tree_unflatten(treedef, new_leaves),
                    jnp.mean(losses))

        w, epoch_losses = jax.lax.scan(
            local_epoch, state.x, jax.random.split(k_noise, fcfg.n_epochs))

        # ---- partial participation -------------------------------------
        u = jax.random.bernoulli(k_part, fcfg.participation, (A,))

        def mix(new, old):
            mask = u.reshape((A,) + (1,) * (new.ndim - 1))
            return jnp.where(mask, new, old)

        x_new = jax.tree_util.tree_map(mix, w, state.x)
        z_new = jax.tree_util.tree_map(
            lambda z_l, w_l, y_l: mix(z_l + 2.0 * (w_l - y_l[None]), z_l),
            state.z, w, y)

        metrics = {
            "loss": epoch_losses[-1],
            "participation": jnp.mean(u.astype(jnp.float32)),
        }
        return FedState(x=x_new, z=z_new, step=state.step + 1), metrics

    return train_step


def consensus_model(state: FedState):
    """The deployable model: the coordinator average of the agent states."""
    return jax.tree_util.tree_map(lambda x: jnp.mean(x, axis=0), state.x)
