"""Pluggable per-agent local-solver registry for the Fed-PLT engine.

The paper's flexibility claim -- "agents have the flexibility to choose
from various local training solvers" -- is a *per-agent* statement, so
solver dispatch mirrors the uplink-compressor registry
(:func:`repro.fed.compress.register_compressor`): a name maps to a
*factory* that builds an :data:`repro.fed.engine.LocalSolver` from a
:class:`repro.core.solvers.SolverConfig` plus the gradient oracle, and
every front end (``FedSpec``, the legacy shims, the generated train CLI)
reaches registered solvers by name.  Heterogeneous deployments assign a
different registered solver (and epochs / step size) to each agent
group; see ``FedSpec.agent_groups``.

New solvers plug in through :func:`register_solver`::

    @register_solver("signum")
    def make_signum(scfg, fgrad, rho, mu, L, *, use_pallas, has_aux):
        def solver(x, v, key):
            ...  # n_epochs sign-GD steps on d_i, warm-started at x
            return w, aux  # aux = the oracle's aux when has_aux,
        return solver      #       else None

The factory receives ``(scfg, fgrad, rho, mu, L)`` and keyword-only
``use_pallas`` / ``has_aux``; the returned solver must be warm-started
at its first argument and respect the engine's ``(x, v, key) ->
(w, aux)`` contract (leaves carry a leading agent axis).  With
``has_aux`` the oracle returns ``(grad, aux)`` and the solver should
return the stacked per-epoch aux (the model runtime reads it as the
per-agent loss trace); returning ``aux=None`` instead is tolerated --
the run still trains, the solver's agents just drop out of the loss
metric.
"""

from __future__ import annotations

from typing import Any, Callable, Dict, Tuple

# (x_stack, v_stack, key) -> (w_stack, aux) -- see repro.fed.engine
LocalSolver = Callable[[Any, Any, Any], Tuple[Any, Any]]
# (solver_cfg, fgrad, rho, mu, L, *, use_pallas, has_aux) -> LocalSolver
SolverFactory = Callable[..., LocalSolver]

_REGISTRY: Dict[str, SolverFactory] = {}


def register_solver(name: str) -> Callable[[SolverFactory], SolverFactory]:
    """Decorator registering a local-solver factory under ``name``."""

    def deco(fn: SolverFactory) -> SolverFactory:
        _REGISTRY[name] = fn
        return fn

    return deco


def get_solver(name: str) -> SolverFactory:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown solver {name!r}; registered: "
            f"{', '.join(available_solvers())}") from None


def available_solvers() -> list[str]:
    return sorted(_REGISTRY)


def make_local_solver(solver_cfg, fgrad, rho: float, mu: float = 0.0,
                      L: float = 0.0, *, use_pallas: bool = False,
                      has_aux: bool = False) -> LocalSolver:
    """Build the :data:`LocalSolver` registered under ``solver_cfg.name``.

    ``fgrad(w_stack, key)`` returns the per-agent gradient pytree (leaves
    ``(N, ...)``); with ``has_aux`` it returns ``(grads, aux)``.
    """
    factory = get_solver(solver_cfg.name)
    return factory(solver_cfg, fgrad, rho, mu, L, use_pallas=use_pallas,
                   has_aux=has_aux)


# ---------------------------------------------------------------------------
# Built-in solvers: the paper's gd / agd / sgd / noisy_gd, all served by
# core/solvers.local_train (which dispatches internally on scfg.name)
# ---------------------------------------------------------------------------

# The names served by core/solvers.local_train.  The dense front end
# (core/fedplt.py) keeps its historical per-agent vmap for exactly
# these; anything else registered here gets the stacked-oracle factory
# path.  One constant, imported there -- the lists must not drift.
CORE_SOLVERS = ("gd", "agd", "sgd", "noisy_gd")

# Core solvers whose update is purely elementwise on the state: under
# the packed layout they run core/solvers.local_train DIRECTLY on the
# resident (N, width) buffer, bit-identical per column to the per-leaf
# tree path.  noisy_gd is excluded -- its per-leaf noise folds the key
# per leaf, so a single buffer would change the DP noise stream -- and
# clipped runs are excluded at call time (the clip norm reduces per
# leaf before summing across the tree; one buffer would reorder it).
PACKED_DIRECT_SOLVERS = ("gd", "agd", "sgd")


def _core_local_train(scfg, fgrad, rho, mu, L, *, use_pallas, has_aux):
    from repro.core.solvers import local_train

    def solver(x, v, key):
        out = local_train(fgrad, x, v, rho, scfg, key, mu, L,
                          batched=True, has_aux=has_aux,
                          use_pallas=use_pallas)
        if has_aux:
            return out
        return out, None

    return solver


for _name in CORE_SOLVERS:
    register_solver(_name)(_core_local_train)
del _name


# ---------------------------------------------------------------------------
# Packed-layout adapters (repro.fed.engine layout contract): solvers for
# the resident (N, width) buffer
# ---------------------------------------------------------------------------

def wrap_packed_solver(solver: LocalSolver, meta) -> LocalSolver:
    """Adapt a tree-form :data:`LocalSolver` to the packed layout:
    unpack the resident buffers, run the solver on the tree, pack the
    result -- all inside the round's jit.  The exact-bits fallback for
    solvers whose internals depend on the leaf decomposition."""
    from repro.fed.compress import pack_leaves, unpack_leaves

    def packed(x_buf, v_buf, key):
        w, aux = solver(unpack_leaves(x_buf, meta),
                        unpack_leaves(v_buf, meta), key)
        return pack_leaves(w)[0], aux

    return packed


def make_packed_local_solver(solver_cfg, fgrad, rho: float,
                             mu: float = 0.0, L: float = 0.0, *, meta,
                             use_pallas: bool = False,
                             has_aux: bool = False) -> LocalSolver:
    """Build a :data:`LocalSolver` operating on the resident packed
    buffer (``meta`` is its static :class:`~repro.fed.compress.PackedMeta`).

    :data:`PACKED_DIRECT_SOLVERS` with no clipping run
    ``core/solvers.local_train`` directly on the ``(N, width)`` buffer
    -- the update is elementwise, so every column computes exactly what
    the per-leaf path computes -- with the gradient oracle wrapped as
    unpack-inside-jit (``unpack_leaves -> fgrad -> pack_leaves``): the
    state path itself carries zero pack/unpack; the only remaining
    layout traffic is the oracle's slice/update-slice chain on gradient
    values.  ``noisy_gd`` and clipped configurations instead fall back
    to :func:`wrap_packed_solver` around the registered tree solver,
    preserving their exact PRNG/reduction streams (see
    :data:`PACKED_DIRECT_SOLVERS`)."""
    from repro.fed.compress import pack_leaves, unpack_leaves

    direct = (solver_cfg.name in PACKED_DIRECT_SOLVERS
              and solver_cfg.clip is None)
    if not direct:
        return wrap_packed_solver(
            make_local_solver(solver_cfg, fgrad, rho, mu, L,
                              use_pallas=use_pallas, has_aux=has_aux),
            meta)

    from repro.core.solvers import local_train

    def fgrad_buf(w_buf, key):
        out = fgrad(unpack_leaves(w_buf, meta), key)
        if has_aux:
            g, aux = out
            return pack_leaves(g)[0], aux
        return pack_leaves(out)[0]

    def solver(x_buf, v_buf, key):
        out = local_train(fgrad_buf, x_buf, v_buf, rho, solver_cfg, key,
                          mu, L, batched=True, has_aux=has_aux,
                          use_pallas=use_pallas)
        if has_aux:
            return out
        return out, None

    return solver
