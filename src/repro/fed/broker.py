"""Host-side bounded-staleness broker: Layer 2 of the async subsystem.

:mod:`repro.fed.async_engine` models staleness as a deterministic pure
function inside jit -- given an arrival schedule, the numerics are
fixed.  This module supplies the *scheduler*: agent workers running on
threads, a bounded-staleness increment buffer between them and the
coordinator, and a round loop that drains the buffer, realizes which
agents arrive when, and feeds each realized arrival row to the in-jit
model.  The division of labor is strict:

* the broker decides only TIMING (who arrives at which round gate);
* every number flows through the in-jit model via the ``arrival=``
  override -- the broker never touches state.

Because of that split, a broker run is replayable bit-for-bit: record
its :class:`ArrivalSchedule`, then push the same rows through the same
in-jit step from the same init (:func:`replay`) -- asserted in
``tests/test_async_engine.py``.

ROUND PROTOCOL (:meth:`IncrementBroker.run`):

1. Every fresh agent (no pending work) is dispatched this round's
   assignment; its worker thread "trains" for its simulated latency and
   submits the increment to the buffer.
2. At the round gate the coordinator BLOCKS on must-arrive agents --
   those whose pending work is ``max_staleness`` rounds old (with
   ``max_staleness = 0`` that is every dispatched agent: the broker
   degenerates to the synchronous barrier).
3. It then grace-drains the buffer: increments that happen to be ready
   arrive too; everyone else ages one round.
4. The realized 0/1 row is fed to ``round_fn(state, row)`` -- the
   in-jit async round -- and recorded.

The recorded schedule always satisfies the staleness bound by
construction (validated on exit against
:func:`repro.fed.async_engine.validate_schedule`).
"""

from __future__ import annotations

import dataclasses
import json
import queue
import threading
import time
from typing import Any, Callable, List, Optional, Tuple

import numpy as np

from repro.fed import async_engine


@dataclasses.dataclass(frozen=True)
class ArrivalSchedule:
    """A realized async run: one 0/1 row per round, one column per
    agent, plus the staleness bound it was realized under."""

    arrivals: np.ndarray        # (n_rounds, n_agents) float32 in {0, 1}
    max_staleness: int

    def __post_init__(self):
        arr = np.asarray(self.arrivals, np.float32)
        if arr.ndim != 2:
            raise ValueError(f"arrivals must be (n_rounds, n_agents), "
                             f"got shape {arr.shape}")
        object.__setattr__(self, "arrivals", arr)

    @property
    def n_rounds(self) -> int:
        return self.arrivals.shape[0]

    @property
    def n_agents(self) -> int:
        return self.arrivals.shape[1]

    def validate(self) -> "ArrivalSchedule":
        """Raise ValueError if any agent's pending work outlives the
        bound; returns self for chaining."""
        async_engine.validate_schedule(self.arrivals, self.max_staleness)
        return self

    def effective_counts(self) -> Tuple[np.ndarray, np.ndarray]:
        """Per-agent ``(arrivals, released_rounds)`` -- the composition
        inputs of the stale-aware privacy report (see
        :func:`repro.fed.async_engine.effective_counts`)."""
        return async_engine.effective_counts(self.arrivals,
                                             self.max_staleness)

    # -- persistence (json keeps schedules diffable and dependency-free)
    def save(self, path) -> None:
        with open(path, "w") as fh:
            json.dump({"max_staleness": int(self.max_staleness),
                       "arrivals": self.arrivals.astype(int).tolist()},
                      fh)

    @staticmethod
    def load(path) -> "ArrivalSchedule":
        with open(path) as fh:
            d = json.load(fh)
        return ArrivalSchedule(
            arrivals=np.asarray(d["arrivals"], np.float32),
            max_staleness=int(d["max_staleness"]))


class AgentWorker(threading.Thread):
    """One agent's training loop on its own thread.

    The worker consumes round assignments from its inbox, simulates the
    local solve for ``latency_fn(agent, round) -> seconds`` of wall
    time, and submits ``(agent, round)`` to the broker's buffer.  The
    actual solver runs inside the coordinator's jitted round (the
    numerics split above) -- the thread realizes only the *duration*."""

    def __init__(self, agent: int,
                 latency_fn: Callable[[int, int], float],
                 buffer: "queue.Queue"):
        super().__init__(daemon=True, name=f"fed-agent-{agent}")
        self.agent = agent
        self._latency_fn = latency_fn
        self._buffer = buffer
        self.inbox: "queue.Queue" = queue.Queue()

    def run(self) -> None:
        while True:
            item = self.inbox.get()
            if item is None:            # shutdown sentinel
                return
            round_idx = item
            delay = float(self._latency_fn(self.agent, round_idx))
            if delay > 0.0:
                time.sleep(delay)
            self._buffer.put((self.agent, round_idx))


class IncrementBroker:
    """Bounded-staleness buffer + round-gate coordinator driver.

    ``latency_fn(agent, round) -> seconds`` shapes the traffic (default:
    a deterministic pseudo-random few-millisecond jitter so runs finish
    fast but schedules are nontrivial).  Straggler fleets are one
    lambda away -- see ``examples/async_training.py``.
    """

    def __init__(self, n_agents: int, max_staleness: int,
                 latency_fn: Optional[Callable[[int, int], float]] = None,
                 grace: float = 0.0, seed: int = 0):
        if n_agents < 1:
            raise ValueError("n_agents must be >= 1")
        if max_staleness < 0:
            raise ValueError("max_staleness must be >= 0")
        self.n_agents = n_agents
        self.max_staleness = max_staleness
        self.grace = float(grace)
        if latency_fn is None:
            rng = np.random.default_rng(seed)
            # pre-drawn jitter table keeps the default deterministic per
            # seed without sharing an rng across threads
            table = rng.uniform(0.0, 0.004, size=(n_agents, 64))
            latency_fn = lambda a, r: float(table[a, r % 64])  # noqa: E731
        self._latency_fn = latency_fn
        self._buffer: "queue.Queue" = queue.Queue()

    # ------------------------------------------------------------------
    def run(self, round_fn: Callable[[Any, np.ndarray], Any], state: Any,
            n_rounds: int) -> Tuple[Any, ArrivalSchedule]:
        """Drive ``n_rounds`` async rounds; returns
        ``(final_state, schedule)``.

        ``round_fn(state, arrival_row) -> state`` is the in-jit numerics
        -- e.g. ``lambda s, u: algo.round_with_arrival(s, u)[0]`` on the
        dense front end, or a model-scale closure over
        ``trainer.step(..., arrival=u)``."""
        K = self.max_staleness
        workers = [AgentWorker(a, self._latency_fn, self._buffer)
                   for a in range(self.n_agents)]
        for w in workers:
            w.start()
        pending_age = np.full(self.n_agents, -1, np.int64)  # -1 = fresh
        ready = np.zeros(self.n_agents, bool)   # submitted, not applied
        rows: List[np.ndarray] = []
        try:
            for r in range(n_rounds):
                # 1. dispatch this round's work to every fresh agent
                for a in range(self.n_agents):
                    if pending_age[a] < 0:
                        workers[a].inbox.put(r)
                        pending_age[a] = 0

                # 2. block on must-arrive agents (work K rounds old);
                # K = 0 blocks on every dispatched agent -- the
                # synchronous barrier
                must = (pending_age >= K) & ~ready
                while must.any():
                    agent, _ = self._buffer.get()
                    ready[agent] = True
                    must[agent] = False

                # 3. grace-drain whatever else is already in the buffer
                deadline = time.monotonic() + self.grace
                while True:
                    try:
                        timeout = deadline - time.monotonic()
                        agent, _ = self._buffer.get(
                            timeout=max(timeout, 0.0))
                        ready[agent] = True
                    except queue.Empty:
                        break

                # 4. realize the row, feed the in-jit model, age misses
                u = ready.astype(np.float32)
                rows.append(u)
                state = round_fn(state, u)
                pending_age[ready] = -1
                pending_age[pending_age >= 0] += 1
                ready[:] = False
        finally:
            for w in workers:
                w.inbox.put(None)
            for w in workers:
                w.join(timeout=5.0)
        schedule = ArrivalSchedule(arrivals=np.stack(rows),
                                   max_staleness=K).validate()
        return state, schedule


def replay(round_fn: Callable[[Any, np.ndarray], Any], state: Any,
           schedule: ArrivalSchedule) -> Any:
    """Push a recorded schedule's rows through the in-jit model from
    ``state``; with the same init this reproduces the broker run's
    trajectory bit-for-bit (the broker only ever chose the rows)."""
    for row in np.asarray(schedule.arrivals, np.float32):
        state = round_fn(state, row)
    return state
