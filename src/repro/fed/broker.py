"""Host-side bounded-staleness broker: Layer 2 of the async subsystem.

:mod:`repro.fed.async_engine` models staleness as a deterministic pure
function inside jit -- given an arrival schedule, the numerics are
fixed.  This module supplies the *scheduler*: agent workers running on
threads, a bounded-staleness increment buffer between them and the
coordinator, and a round loop that drains the buffer, realizes which
agents arrive when, and feeds each realized arrival row to the in-jit
model.  The division of labor is strict:

* the broker decides only TIMING and LIVENESS (who arrives at which
  round gate, who is evicted/rejoined, which recorded fault rows apply);
* every number flows through the in-jit model via the ``arrival=`` /
  ``corrupt=`` / ``live=`` overrides -- the broker never touches state.

Because of that split, a broker run is replayable bit-for-bit: record
its :class:`ArrivalSchedule` (and, for faulty runs, the
:class:`repro.fed.faults.FaultRecord` left on ``broker.record``), then
push the same rows through the same in-jit step from the same init
(:func:`replay`) -- asserted in ``tests/test_async_engine.py`` and
``tests/test_faults.py``.

ROUND PROTOCOL (:meth:`IncrementBroker.run`):

0. REJOIN: an evicted agent whose :class:`~repro.fed.faults.FaultPlan`
   crash window ends this round re-enters the fleet fresh (recorded in
   the FaultRecord); it is dispatched against the CURRENT reflection at
   step 1 like any fresh agent (its staleness counter was pinned at 0
   in-jit while it was dead).
1. DISPATCH: every live fresh agent (no pending work) is handed this
   round's assignment; its worker thread "trains" for its simulated
   latency and submits the increment to the per-run buffer.  Work
   dispatched to a plan-crashed agent silently disappears -- that is
   the fault being injected.
2. GATE: the coordinator blocks on must-arrive agents -- those whose
   pending work is ``max_staleness`` rounds old (``max_staleness = 0``:
   every dispatched agent; the synchronous barrier).  With a
   ``gate_timeout``, a gate that expires marks a RETRY for each missing
   agent: its original round assignment is redispatched and the wait
   window grows by ``retry_backoff**attempt`` (exponential backoff).
   An agent that exhausts ``max_retries`` is EVICTED: it leaves the
   arrival rows, the keep branch, and the coordinator mean (the in-jit
   ``live`` row) until a plan rejoin.  Evicting the last live agent
   raises -- there is no one left to average.
   A worker whose ``latency_fn`` raises submits the error instead of
   dying silently: without a ``gate_timeout`` the run fails loudly with
   that error; with one, the error burns a retry like a timeout.
   A plan-dropped submission is discarded at the gate (lost in
   transit); the timeout machinery redispatches it.
3. GRACE-DRAIN: increments that happen to be ready arrive too;
   everyone else ages one round.  The same stale-duplicate filter
   applies (only a submission matching the agent's current dispatch is
   accepted -- redispatch races cannot double-arrive).
4. REALIZE: the 0/1 arrival row (live agents only), this round's
   ``corrupt`` row (from plan ``corrupt`` events, recorded in the
   FaultRecord), and the ``live`` row (``None`` until the first
   eviction -- the clean graph is retraced exactly) are fed to
   ``round_fn(state, row[, corrupt, live])`` and recorded.

The recorded schedule always satisfies the staleness bound by
construction (validated on exit against
:func:`repro.fed.async_engine.validate_schedule`, with the record's
live matrix exempting evicted agents).
"""

from __future__ import annotations

import dataclasses
import inspect
import json
import queue
import threading
import time
from typing import Any, Callable, List, Optional, Tuple

import numpy as np

from repro.fed import async_engine
from repro.fed import faults as faults_lib


@dataclasses.dataclass(frozen=True)
class ArrivalSchedule:
    """A realized async run: one 0/1 row per round, one column per
    agent, plus the staleness bound it was realized under.  Faulty runs
    additionally carry ``live``, the ``(n_rounds, n_agents)`` 0/1
    liveness matrix (None = no evictions)."""

    arrivals: np.ndarray        # (n_rounds, n_agents) float32 in {0, 1}
    max_staleness: int
    live: Optional[np.ndarray] = None   # (n_rounds, n_agents) or None

    def __post_init__(self):
        arr = np.asarray(self.arrivals, np.float32)
        if arr.ndim != 2:
            raise ValueError(f"arrivals must be (n_rounds, n_agents), "
                             f"got shape {arr.shape}")
        object.__setattr__(self, "arrivals", arr)
        if self.live is not None:
            lv = np.asarray(self.live, np.float32)
            if lv.shape != arr.shape:
                raise ValueError(
                    f"live matrix shape {lv.shape} does not match "
                    f"arrivals shape {arr.shape}")
            object.__setattr__(self, "live", lv)

    @property
    def n_rounds(self) -> int:
        return self.arrivals.shape[0]

    @property
    def n_agents(self) -> int:
        return self.arrivals.shape[1]

    def validate(self) -> "ArrivalSchedule":
        """Raise ValueError if any agent's pending work outlives the
        bound (evicted agents exempt while dead); returns self for
        chaining."""
        async_engine.validate_schedule(self.arrivals, self.max_staleness,
                                       live=self.live)
        return self

    def effective_counts(self) -> Tuple[np.ndarray, np.ndarray]:
        """Per-agent ``(arrivals, released_rounds)`` -- the composition
        inputs of the stale-aware privacy report (see
        :func:`repro.fed.async_engine.effective_counts`).  An evicted
        agent keeps the charges for every round it RELEASED before the
        eviction -- that information left the agent."""
        return async_engine.effective_counts(self.arrivals,
                                             self.max_staleness,
                                             live=self.live)

    # -- persistence (json keeps schedules diffable and dependency-free)
    def save(self, path) -> None:
        d = {"max_staleness": int(self.max_staleness),
             "arrivals": self.arrivals.astype(int).tolist()}
        if self.live is not None:
            d["live"] = self.live.astype(int).tolist()
        with open(path, "w") as fh:
            json.dump(d, fh)

    @staticmethod
    def load(path) -> "ArrivalSchedule":
        """Load and VALIDATE a saved schedule: malformed JSON -- values
        outside {0, 1}, ragged/mis-shaped rows, a non-integer or
        negative ``max_staleness``, a bound the rows violate -- raises
        ValueError here instead of flowing into the jitted round."""
        with open(path) as fh:
            d = json.load(fh)
        if not isinstance(d, dict) or "arrivals" not in d \
                or "max_staleness" not in d:
            raise ValueError(
                f"{path}: not an ArrivalSchedule (need 'arrivals' and "
                f"'max_staleness' keys)")
        k = d["max_staleness"]
        if isinstance(k, bool) or not isinstance(k, int) or k < 0:
            raise ValueError(
                f"{path}: max_staleness must be a non-negative integer "
                f"round count, got {k!r}")
        arr = _load_binary_matrix(path, "arrivals", d["arrivals"])
        lv = None
        if d.get("live") is not None:
            lv = _load_binary_matrix(path, "live", d["live"])
            if lv.shape != arr.shape:
                raise ValueError(
                    f"{path}: live matrix shape {lv.shape} does not "
                    f"match arrivals shape {arr.shape}")
        return ArrivalSchedule(arrivals=arr, max_staleness=k,
                               live=lv).validate()


def _load_binary_matrix(path, name: str, raw) -> np.ndarray:
    """Parse a JSON (n_rounds, n_agents) matrix of {0, 1} entries with
    clear errors (ragged rows, wrong rank, non-binary values)."""
    try:
        arr = np.asarray(raw, np.float32)
    except (TypeError, ValueError):
        raise ValueError(
            f"{path}: {name} must be a rectangular (n_rounds, n_agents) "
            f"matrix -- rows have inconsistent lengths or non-numeric "
            f"entries") from None
    if arr.ndim != 2:
        raise ValueError(
            f"{path}: {name} must be (n_rounds, n_agents), got shape "
            f"{arr.shape}")
    if not np.isin(arr, (0.0, 1.0)).all():
        bad = arr[~np.isin(arr, (0.0, 1.0))]
        raise ValueError(
            f"{path}: {name} entries must be 0 or 1, found "
            f"{bad.ravel()[:4].tolist()}")
    return arr


class AgentWorker(threading.Thread):
    """One agent's training loop on its own thread.

    The worker consumes round assignments from its inbox, simulates the
    local solve for ``latency_fn(agent, round) -> seconds`` of wall
    time, and submits ``(agent, round, error)`` to the broker's buffer
    (``error`` is None on success; a raising ``latency_fn`` is
    SUBMITTED, not swallowed, so the gate can surface it).  The actual
    solver runs inside the coordinator's jitted round (the numerics
    split above) -- the thread realizes only the *duration*."""

    def __init__(self, agent: int,
                 latency_fn: Callable[[int, int], float],
                 buffer: "queue.Queue"):
        super().__init__(daemon=True, name=f"fed-agent-{agent}")
        self.agent = agent
        self._latency_fn = latency_fn
        self._buffer = buffer
        self.inbox: "queue.Queue" = queue.Queue()

    def run(self) -> None:
        while True:
            item = self.inbox.get()
            if item is None:            # shutdown sentinel
                return
            round_idx = item
            try:
                delay = float(self._latency_fn(self.agent, round_idx))
                if delay > 0.0:
                    time.sleep(delay)
            except Exception as err:    # surfaced at the round gate
                self._buffer.put((self.agent, round_idx, err))
                continue
            self._buffer.put((self.agent, round_idx, None))


def _accepts_faults(round_fn) -> bool:
    """Whether ``round_fn`` takes the ``(state, u, corrupt, live)``
    fault-capable signature (vs the legacy 2-arg ``(state, u)``)."""
    try:
        params = inspect.signature(round_fn).parameters.values()
    except (TypeError, ValueError):
        return False
    n = 0
    for p in params:
        if p.kind == inspect.Parameter.VAR_POSITIONAL:
            return True
        if p.kind in (inspect.Parameter.POSITIONAL_ONLY,
                      inspect.Parameter.POSITIONAL_OR_KEYWORD):
            n += 1
    return n >= 4


class IncrementBroker:
    """Bounded-staleness buffer + round-gate coordinator driver.

    ``latency_fn(agent, round) -> seconds`` shapes the traffic (default:
    a deterministic pseudo-random few-millisecond jitter so runs finish
    fast but schedules are nontrivial).  Straggler fleets are one
    lambda away -- see ``examples/async_training.py``.

    Fault tolerance (the ROUND PROTOCOL above): ``gate_timeout`` bounds
    each round gate's wait (None -- the historical default -- blocks
    forever and is rejected when a :class:`~repro.fed.faults.FaultPlan`
    can lose work); a missing agent is retried up to ``max_retries``
    times with the window growing by ``retry_backoff`` per attempt,
    then evicted.  After each :meth:`run` the realized
    :class:`~repro.fed.faults.FaultRecord` is left on ``self.record``.
    """

    def __init__(self, n_agents: int, max_staleness: int,
                 latency_fn: Optional[Callable[[int, int], float]] = None,
                 grace: float = 0.0, seed: int = 0,
                 gate_timeout: Optional[float] = None,
                 max_retries: int = 2, retry_backoff: float = 2.0,
                 join_timeout: float = 5.0):
        if n_agents < 1:
            raise ValueError("n_agents must be >= 1")
        if max_staleness < 0:
            raise ValueError("max_staleness must be >= 0")
        if gate_timeout is not None and not gate_timeout > 0:
            raise ValueError("gate_timeout must be positive seconds "
                             "(None = block forever)")
        if max_retries < 0:
            raise ValueError("max_retries must be >= 0")
        if retry_backoff < 1.0:
            raise ValueError("retry_backoff must be >= 1")
        self.n_agents = n_agents
        self.max_staleness = max_staleness
        self.grace = float(grace)
        self.gate_timeout = gate_timeout
        self.max_retries = int(max_retries)
        self.retry_backoff = float(retry_backoff)
        self.join_timeout = float(join_timeout)
        self.record: Optional[faults_lib.FaultRecord] = None
        if latency_fn is None:
            rng = np.random.default_rng(seed)
            # pre-drawn jitter table keeps the default deterministic per
            # seed without sharing an rng across threads
            table = rng.uniform(0.0, 0.004, size=(n_agents, 64))
            latency_fn = lambda a, r: float(table[a, r % 64])  # noqa: E731
        self._latency_fn = latency_fn

    # ------------------------------------------------------------------
    def run(self, round_fn: Callable[..., Any], state: Any,
            n_rounds: int,
            faults: Optional[faults_lib.FaultPlan] = None
            ) -> Tuple[Any, ArrivalSchedule]:
        """Drive ``n_rounds`` async rounds; returns
        ``(final_state, schedule)``.

        ``round_fn(state, arrival_row) -> state`` is the in-jit numerics
        -- e.g. ``lambda s, u: algo.round_with_arrival(s, u)[0]`` on the
        dense front end, or a model-scale closure over
        ``trainer.step(..., arrival=u)``.  For faulty runs pass the
        4-arg form ``round_fn(state, u, corrupt, live)`` (e.g. over
        ``algo.round_with_faults``); the realized
        :class:`~repro.fed.faults.FaultRecord` is left on
        ``self.record``."""
        K = self.max_staleness
        N = self.n_agents
        plan = faults
        if plan is not None:
            plan.check_agents(N)
            if self.gate_timeout is None and plan.needs_timeout():
                raise ValueError(
                    "a FaultPlan with crash/drop events needs a broker "
                    "gate_timeout: without one the round gate would "
                    "block forever on work that never arrives")
        latency = self._latency_fn
        if plan is not None:
            latency = plan.wrap_latency(latency)
        # a FRESH buffer per run: a straggler worker from a previous
        # run() that outlived its join timeout can only submit into its
        # own (abandoned) queue, never into this one
        buffer: "queue.Queue" = queue.Queue()
        workers = [AgentWorker(a, latency, buffer) for a in range(N)]
        for w in workers:
            w.start()
        pending_age = np.full(N, -1, np.int64)      # -1 = fresh
        dispatch_round = np.full(N, -1, np.int64)   # round of pending work
        attempts = np.zeros(N, np.int64)            # failed deliveries
        ready = np.zeros(N, bool)     # submitted, not applied
        live = np.ones(N, bool)
        accepts_faults = _accepts_faults(round_fn)
        record = faults_lib.FaultRecord(n_agents=N)
        self.record = record
        rows: List[np.ndarray] = []
        live_rows: List[np.ndarray] = []

        def dispatch(a: int, assigned_round: int, now_round: int) -> None:
            # work sent to a plan-crashed agent vanishes: nothing enters
            # the worker inbox, so the gate timeout machinery engages
            if plan is None or not plan.crashed(a, now_round):
                workers[a].inbox.put(int(assigned_round))

        def retry_or_evict(a: int, r: int) -> None:
            attempts[a] += 1
            if attempts[a] > self.max_retries:
                live[a] = False
                ready[a] = False
                pending_age[a] = -1
                record.note_eviction(a, r)
            else:
                record.note_retry(a, int(dispatch_round[a]),
                                  int(attempts[a]))
                dispatch(a, int(dispatch_round[a]), r)

        def consume(item, r: int) -> None:
            a, rnd, err = item
            if (not live[a] or pending_age[a] < 0
                    or rnd != dispatch_round[a] or ready[a]):
                return   # stale duplicate / evicted straggler
            if err is not None:
                record.note_error(a, int(rnd), err)
                if self.gate_timeout is None:
                    raise RuntimeError(
                        f"agent {a} worker failed in round {int(rnd)}: "
                        f"{err!r}") from err
                retry_or_evict(a, r)
                return
            if plan is not None and plan.dropped(a, int(rnd),
                                                 int(attempts[a])):
                record.note_drop(a, int(rnd))
                return   # lost in transit; the gate redispatches
            ready[a] = True

        try:
            for r in range(n_rounds):
                # 0. rejoins: a revived agent re-enters the fleet fresh
                if plan is not None:
                    for a in plan.rejoins_at(r):
                        if not live[a]:
                            live[a] = True
                            pending_age[a] = -1
                            ready[a] = False
                            record.note_rejoin(a, r)

                # 1. dispatch this round's work to every live fresh agent
                for a in range(N):
                    if live[a] and pending_age[a] < 0:
                        pending_age[a] = 0
                        dispatch_round[a] = r
                        attempts[a] = 0
                        dispatch(a, r, r)

                # 2. gate on must-arrive agents (work K rounds old);
                # K = 0 blocks on every dispatched agent -- the
                # synchronous barrier.  With a gate_timeout, expiry
                # retries (backoff) then evicts the missing agents
                gate_start = time.monotonic()
                while True:
                    must = live & (pending_age >= K) & ~ready
                    if not must.any():
                        break
                    if self.gate_timeout is None:
                        consume(buffer.get(), r)
                        continue
                    window = self.gate_timeout * (
                        self.retry_backoff ** int(attempts[must].max()))
                    remain = gate_start + window - time.monotonic()
                    item = None
                    if remain > 0:
                        try:
                            item = buffer.get(timeout=remain)
                        except queue.Empty:
                            pass
                    if item is not None:
                        consume(item, r)
                        continue
                    for a in np.nonzero(must)[0]:
                        retry_or_evict(int(a), r)
                    if not live.any():
                        raise RuntimeError(
                            f"round {r}: every agent exceeded the retry "
                            f"budget and was evicted -- no survivors to "
                            f"average")
                    gate_start = time.monotonic()   # new attempt window

                # 3. grace-drain whatever else is already in the buffer
                deadline = time.monotonic() + self.grace
                while True:
                    try:
                        timeout = deadline - time.monotonic()
                        item = buffer.get(timeout=max(timeout, 0.0))
                    except queue.Empty:
                        break
                    consume(item, r)

                # 4. realize the rows, feed the in-jit model, age misses
                u = (ready & live).astype(np.float32)
                corrupt = None
                if plan is not None:
                    # plans with byzantine events realize (N, 2)
                    # [mult, add] pairs; legacy plans keep the (N,)
                    # multiplicative rows so their recordings replay on
                    # the exact historical jitted graph
                    byz = plan.has_byzantine
                    crow = (np.zeros((N, 2), np.float32) if byz
                            else np.zeros(N, np.float32))
                    hit = False
                    for a in np.nonzero(ready & live)[0]:
                        rnd = int(dispatch_round[a])
                        val = plan.corrupt_value(int(a), rnd)
                        if val is not None:
                            crow[a] = (val, 0.0) if byz else val
                            hit = True
                        if byz:
                            pair = plan.byzantine_at(int(a), rnd)
                            if pair is not None:
                                crow[a] = pair
                                hit = True
                    if hit:
                        corrupt = crow
                        record.note_corrupt_row(r, crow)
                live_arg = (live.astype(np.float32)
                            if record.evictions else None)
                if accepts_faults:
                    state = round_fn(state, u, corrupt, live_arg)
                elif corrupt is not None or live_arg is not None:
                    raise TypeError(
                        "this run produced fault rows (corrupt/evicted "
                        "agents) but round_fn only takes (state, u) -- "
                        "pass the 4-arg form, e.g. lambda s, u, c, l: "
                        "algo.round_with_faults(s, u, c, l)[0]")
                else:
                    state = round_fn(state, u)
                rows.append(u)
                live_rows.append(live.astype(np.float32))
                pending_age[ready] = -1
                pending_age[pending_age >= 0] += 1
                ready[:] = False
        finally:
            for w in workers:
                w.inbox.put(None)
            for w in workers:
                w.join(timeout=self.join_timeout)
        arrivals = (np.stack(rows) if rows
                    else np.zeros((0, N), np.float32))
        lv = None
        if record.evictions:
            lv = (np.stack(live_rows) if live_rows
                  else np.zeros((0, N), np.float32))
        schedule = ArrivalSchedule(arrivals=arrivals, max_staleness=K,
                                   live=lv).validate()
        return state, schedule


def replay(round_fn: Callable[..., Any], state: Any,
           schedule: ArrivalSchedule,
           record: Optional[faults_lib.FaultRecord] = None) -> Any:
    """Push a recorded schedule's rows through the in-jit model from
    ``state``; with the same init this reproduces the broker run's
    trajectory bit-for-bit (the broker only ever chose the rows).

    For a faulty run pass the broker's :class:`FaultRecord` and the
    4-arg ``round_fn(state, u, corrupt, live)``: each round replays the
    exact ``corrupt`` and ``live`` rows the original run realized
    (``live`` stays None before the first eviction, retracing the same
    jitted graphs)."""
    for r, row in enumerate(np.asarray(schedule.arrivals, np.float32)):
        if record is None:
            state = round_fn(state, row)
        else:
            state = round_fn(state, row, record.corrupt_row(r),
                             record.live_row(r))
    return state
