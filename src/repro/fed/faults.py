"""Deterministic fault injection for the federation runtime.

The broker/engine split (fed/broker.py's ROUND PROTOCOL) makes TIMING a
recorded, replayable input to the jitted round.  This module extends the
same contract to FAILURE: a seeded :class:`FaultPlan` decides, ahead of
time, which agents crash, stall, drop their uplink, or corrupt their
increment -- and a :class:`FaultRecord` captures what the broker actually
did about it (retries, evictions, rejoins, quarantined rows), so that

    ``broker.run(step, state, R, faults=plan)``  and
    ``broker.replay(step, state, schedule, record=broker.record)``

produce bitwise-identical trajectories.  Nothing in this module touches
jax: plans and records are plain host-side data, JSON round-trippable
(NaN corrupt values included), and cheap to query per (agent, round).

Fault kinds
-----------
``crash``    agent is dead for rounds ``[round, until)`` (``until=None``
             = forever): dispatched work silently disappears, so the
             broker's gate timeout -> retry -> evict machinery engages.
``drop``     the agent does the work but the uplink for ``round`` is
             lost in transit on its first attempt; the broker's
             redispatch recovers it.
``corrupt``  the increment for ``round`` arrives multiplied by
             ``value`` per row (NaN/Inf poison it outright, a huge
             finite value trips the norm guard).  Applied IN-JIT by
             ``engine.apply_corruption`` from the broker-realized row,
             keeping numerics out of the host threads.
``stall``    transient slowdown: ``delay`` seconds are added to the
             worker's latency for ``round``.

Byzantine kinds (adversarial, guard-evading -- the increments stay
finite and in-norm, so only a robust aggregator stops them; see
:mod:`repro.fed.robust`).  All three are WINDOWED like ``crash``:
active for rounds ``[round, until)``, ``until=None`` = forever.

``sign_flip``  the agent submits ``-w`` -- the classic consensus
               -steering attack (no ``value``).
``scale``      the agent submits ``value * w`` (``value`` finite and
               nonzero; huge values belong to ``corrupt`` + the norm
               guard, this kind models in-bound distortion).
``drift``      the agent submits ``w + value`` (``value`` finite): a
               constant pull toward an attacker-chosen direction.

Byzantine corruptions are realized by the broker as ``(N, 2)``
``[mult, add]`` rows consumed by ``engine.apply_corruption`` and
recorded in the :class:`FaultRecord` -- replay is bit-for-bit, same as
the multiplicative ``corrupt`` kind.  Plans WITHOUT byzantine events
keep realizing the historical ``(N,)`` rows, so old recordings replay
on the exact same jitted graph.
"""

from __future__ import annotations

import bisect
import dataclasses
import json
import math
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

BYZANTINE_KINDS = ("sign_flip", "scale", "drift")

FAULT_KINDS = ("crash", "drop", "corrupt", "stall") + BYZANTINE_KINDS

# THE no-value sentinel: every valueless event must carry this exact
# object so dataclass equality (which can only see NaN == NaN through
# the identity shortcut) treats regenerated / reloaded plans as equal
_NAN = float("nan")


@dataclasses.dataclass(frozen=True)
class FaultEvent:
    """One scheduled fault: ``kind`` hitting ``agent`` at ``round``."""

    kind: str
    agent: int
    round: int
    until: Optional[int] = None    # crash/byzantine: first round clear
    value: float = _NAN            # corrupt/scale/drift parameter
    delay: float = 0.0             # stall only: extra latency (seconds)

    def __post_init__(self):
        if self.kind not in FAULT_KINDS:
            raise ValueError(
                f"unknown fault kind {self.kind!r} (one of {FAULT_KINDS})")
        if self.agent < 0:
            raise ValueError(f"agent must be >= 0, got {self.agent}")
        if self.round < 0:
            raise ValueError(f"round must be >= 0, got {self.round}")
        if self.until is not None and self.until <= self.round:
            raise ValueError(
                f"crash until={self.until} must exceed round={self.round}")
        if self.delay < 0:
            raise ValueError(f"delay must be >= 0, got {self.delay}")
        if self.kind in BYZANTINE_KINDS:
            if self.delay:
                raise ValueError(
                    f"{self.kind} events carry no delay (that is what "
                    f"'stall' models), got delay={self.delay}")
            if self.kind == "sign_flip":
                if not math.isnan(self.value):
                    raise ValueError(
                        f"sign_flip takes no value (the multiplier IS "
                        f"-1), got value={self.value}")
            elif self.kind == "scale":
                if not (math.isfinite(self.value) and self.value != 0.0):
                    raise ValueError(
                        f"scale needs a finite nonzero value (non-finite "
                        f"poison is the 'corrupt' kind), got "
                        f"value={self.value}")
            elif not math.isfinite(self.value):    # drift
                raise ValueError(
                    f"drift needs a finite value, got value={self.value}")

    @property
    def byzantine(self) -> bool:
        return self.kind in BYZANTINE_KINDS

    def byzantine_pair(self) -> Tuple[float, float]:
        """The ``(mult, add)`` row this event realizes
        (:func:`repro.fed.engine.apply_corruption` semantics)."""
        if self.kind == "sign_flip":
            return (-1.0, 0.0)
        if self.kind == "scale":
            return (float(self.value), 0.0)
        if self.kind == "drift":
            return (1.0, float(self.value))
        raise ValueError(f"{self.kind!r} is not a byzantine kind")

    def active_at(self, round: int) -> bool:
        """Whether this (windowed) event is live at ``round``."""
        return (self.round <= round
                and (self.until is None or round < self.until))

    def to_json(self) -> dict:
        d = {"kind": self.kind, "agent": int(self.agent),
             "round": int(self.round)}
        if self.until is not None:
            d["until"] = int(self.until)
        if self.kind in ("corrupt", "scale", "drift"):
            d["value"] = float(self.value)
        if self.kind == "stall":
            d["delay"] = float(self.delay)
        return d

    @staticmethod
    def from_json(d: dict) -> "FaultEvent":
        v = d.get("value")
        return FaultEvent(kind=d["kind"], agent=int(d["agent"]),
                          round=int(d["round"]),
                          until=(None if d.get("until") is None
                                 else int(d["until"])),
                          value=(_NAN if v is None or (
                              isinstance(v, float) and math.isnan(v))
                              else float(v)),
                          delay=float(d.get("delay", 0.0)))


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """A seeded, deterministic set of fault events.

    Like ``ArrivalSchedule`` this is an ARTIFACT: generate it once
    (:meth:`generate`), save it next to the run, and any later process
    can reload it and reproduce the exact same failure pattern.  The
    queries below are what the broker consults each round.
    """

    events: Tuple[FaultEvent, ...]
    n_agents: Optional[int] = None   # validated bound when given
    seed: Optional[int] = None       # provenance only

    def __post_init__(self):
        evs = tuple(e if isinstance(e, FaultEvent) else FaultEvent(**e)
                    for e in self.events)
        object.__setattr__(self, "events", evs)
        if self.n_agents is not None:
            self.check_agents(int(self.n_agents))
        # (agent, round) indexes, built once: the broker queries per
        # agent per round per attempt from its hot loop, and a linear
        # scan over a many-round generated plan is O(events) per query.
        # First matching event wins, exactly like the scans these
        # replace (regression-tested against them in tests).
        corrupt_index: Dict[Tuple[int, int], float] = {}
        byz_index: Dict[int, List[FaultEvent]] = {}
        for e in evs:
            if e.kind == "corrupt":
                corrupt_index.setdefault((e.agent, e.round),
                                         float(e.value))
            elif e.kind in BYZANTINE_KINDS:
                byz_index.setdefault(e.agent, []).append(e)
        object.__setattr__(self, "_corrupt_index", corrupt_index)
        object.__setattr__(self, "_byz_index", byz_index)

    # -- broker-facing queries ------------------------------------------
    def check_agents(self, n_agents: int) -> None:
        bad = [e for e in self.events if e.agent >= n_agents]
        if bad:
            raise ValueError(
                f"fault plan targets agents {sorted({e.agent for e in bad})} "
                f"but the fleet has only {n_agents} agents")

    def needs_timeout(self) -> bool:
        """True when the plan can make dispatched work vanish -- such a
        plan needs a broker ``gate_timeout`` or the round gate would
        block forever."""
        return any(e.kind in ("crash", "drop") for e in self.events)

    def crashed(self, agent: int, round: int) -> bool:
        return any(e.kind == "crash" and e.agent == agent
                   and e.round <= round
                   and (e.until is None or round < e.until)
                   for e in self.events)

    def rejoins_at(self, round: int) -> List[int]:
        """Agents whose crash window ends exactly at ``round``."""
        return sorted({e.agent for e in self.events
                       if e.kind == "crash" and e.until == round})

    def dropped(self, agent: int, round: int, attempt: int) -> bool:
        """Whether delivery ``attempt`` (0-based) of this round's uplink
        is lost.  Each matching drop event eats one attempt, so the
        broker's redispatch eventually gets through."""
        n = sum(1 for e in self.events if e.kind == "drop"
                and e.agent == agent and e.round == round)
        return attempt < n

    def corrupt_value(self, agent: int, round: int) -> Optional[float]:
        return self._corrupt_index.get((agent, round))

    def _corrupt_value_scan(self, agent: int, round: int
                            ) -> Optional[float]:
        """The pre-index linear scan, kept as the regression oracle for
        :meth:`corrupt_value` (asserted equal in tests)."""
        for e in self.events:
            if (e.kind == "corrupt" and e.agent == agent
                    and e.round == round):
                return float(e.value)
        return None

    def byzantine_at(self, agent: int, round: int
                     ) -> Optional[Tuple[float, float]]:
        """The ``(mult, add)`` pair of the first byzantine event whose
        window covers ``(agent, round)``, or None -- the broker realizes
        this into the ``(N, 2)`` corruption row."""
        for e in self._byz_index.get(agent, ()):
            if e.active_at(round):
                return e.byzantine_pair()
        return None

    def _byzantine_at_scan(self, agent: int, round: int
                           ) -> Optional[Tuple[float, float]]:
        """Linear-scan regression oracle for :meth:`byzantine_at`."""
        for e in self.events:
            if (e.kind in BYZANTINE_KINDS and e.agent == agent
                    and e.active_at(round)):
                return e.byzantine_pair()
        return None

    @property
    def has_byzantine(self) -> bool:
        """Whether any byzantine event is scheduled: gates the broker's
        corruption-row encoding -- plans without byzantine events keep
        the historical ``(N,)`` rows so old recordings replay bitwise."""
        return bool(self._byz_index)

    def stall_delay(self, agent: int, round: int) -> float:
        return sum(e.delay for e in self.events if e.kind == "stall"
                   and e.agent == agent and e.round == round)

    def wrap_latency(self, latency_fn: Callable[[int, int], float]
                     ) -> Callable[[int, int], float]:
        """Latency function with the plan's stalls folded in."""
        def fn(agent: int, round: int) -> float:
            return float(latency_fn(agent, round)) + self.stall_delay(
                agent, round)
        return fn

    # -- construction / persistence -------------------------------------
    @staticmethod
    def generate(seed: int, n_agents: int, n_rounds: int, *,
                 p_crash: float = 0.0, crash_length: Optional[int] = None,
                 p_drop: float = 0.0, p_corrupt: float = 0.0,
                 corrupt_value: float = _NAN,
                 p_stall: float = 0.0,
                 stall_delay: float = 0.05,
                 n_byzantine: int = 0,
                 byzantine_kind: str = "sign_flip",
                 byzantine_value: Optional[float] = None,
                 byzantine_start: int = 0) -> "FaultPlan":
        """Draw a plan from a seeded rng -- same (seed, shape, probs)
        always yields the same events.

        ``n_byzantine`` picks that many distinct agents (from the same
        rng, so the pick is seeded too) and schedules one PERSISTENT
        ``byzantine_kind`` event per agent starting at
        ``byzantine_start``; ``byzantine_value`` is required for
        ``scale``/``drift``.  ``n_byzantine=0`` (the default) draws
        nothing extra, keeping legacy plans bit-identical."""
        rng = np.random.default_rng(seed)
        events: List[FaultEvent] = []
        if n_byzantine:
            if byzantine_kind not in BYZANTINE_KINDS:
                raise ValueError(
                    f"unknown byzantine kind {byzantine_kind!r} "
                    f"(one of {BYZANTINE_KINDS})")
            if byzantine_kind != "sign_flip" and byzantine_value is None:
                raise ValueError(
                    f"{byzantine_kind} needs a byzantine_value")
            if int(n_byzantine) > n_agents:
                raise ValueError(
                    f"n_byzantine={n_byzantine} exceeds "
                    f"n_agents={n_agents}")
            picked = rng.choice(n_agents, size=int(n_byzantine),
                                replace=False)
            for a in sorted(int(a) for a in picked):
                events.append(FaultEvent(
                    byzantine_kind, a, int(byzantine_start),
                    value=(_NAN if byzantine_value is None
                           else float(byzantine_value))))
        crashed_until = np.zeros(n_agents, np.int64)   # rounds < this: dead
        for r in range(n_rounds):
            for a in range(n_agents):
                if r < crashed_until[a]:
                    continue    # already down -- no new faults while dead
                if p_crash and rng.random() < p_crash:
                    until = (None if crash_length is None
                             else min(r + int(crash_length), n_rounds))
                    events.append(FaultEvent("crash", a, r, until=until))
                    crashed_until[a] = n_rounds if until is None else until
                    continue
                if p_drop and rng.random() < p_drop:
                    events.append(FaultEvent("drop", a, r))
                if p_corrupt and rng.random() < p_corrupt:
                    events.append(FaultEvent("corrupt", a, r,
                                             value=corrupt_value))
                if p_stall and rng.random() < p_stall:
                    events.append(FaultEvent("stall", a, r,
                                             delay=stall_delay))
        return FaultPlan(tuple(events), n_agents=n_agents, seed=seed)

    def to_json(self) -> dict:
        return {"events": [e.to_json() for e in self.events],
                "n_agents": self.n_agents, "seed": self.seed}

    @staticmethod
    def from_json(d: dict) -> "FaultPlan":
        return FaultPlan(tuple(FaultEvent.from_json(e)
                               for e in d["events"]),
                         n_agents=d.get("n_agents"), seed=d.get("seed"))

    def save(self, path: str) -> None:
        with open(path, "w") as fh:
            json.dump(self.to_json(), fh)   # allow_nan: corrupt values

    @staticmethod
    def load(path: str) -> "FaultPlan":
        with open(path) as fh:
            return FaultPlan.from_json(json.load(fh))


@dataclasses.dataclass
class FaultRecord:
    """What the broker actually DID during a faulty run.

    The record is the second half of the replay contract: the
    ``ArrivalSchedule`` pins the arrival rows, the record pins the
    per-round ``corrupt`` and ``live`` rows the jitted round consumed
    (plus the retry/drop/error bookkeeping for inspection).  ``events``
    is one chronological list of ``(round, agent, "evict"|"rejoin")``
    entries so a rejoin-then-re-evict within one run stays ordered.
    """

    n_agents: int
    events: List[Tuple[int, int, str]] = dataclasses.field(
        default_factory=list)
    retries: List[Tuple[int, int, int]] = dataclasses.field(
        default_factory=list)    # (agent, round, attempt)
    drops: List[Tuple[int, int]] = dataclasses.field(
        default_factory=list)    # (agent, round)
    errors: List[Tuple[int, int, str]] = dataclasses.field(
        default_factory=list)    # (agent, round, repr(exc))
    corrupt_rows: dict = dataclasses.field(
        default_factory=dict)    # {round: [value] * n_agents}

    # -- broker hooks ----------------------------------------------------
    def note_eviction(self, agent: int, round: int) -> None:
        self.events.append((int(round), int(agent), "evict"))

    def note_rejoin(self, agent: int, round: int) -> None:
        self.events.append((int(round), int(agent), "rejoin"))

    def note_retry(self, agent: int, round: int, attempt: int) -> None:
        self.retries.append((int(agent), int(round), int(attempt)))

    def note_drop(self, agent: int, round: int) -> None:
        self.drops.append((int(agent), int(round)))

    def note_error(self, agent: int, round: int, err: BaseException) -> None:
        self.errors.append((int(agent), int(round), repr(err)))

    def note_corrupt_row(self, round: int, row: np.ndarray) -> None:
        row = np.asarray(row)
        if row.ndim == 2:      # byzantine (N, 2) [mult, add] pairs
            self.corrupt_rows[int(round)] = [
                [float(m), float(ad)] for m, ad in row]
        else:
            self.corrupt_rows[int(round)] = [float(v) for v in row]

    # -- replay queries --------------------------------------------------
    @property
    def evictions(self) -> List[Tuple[int, int]]:
        return [(a, r) for (r, a, k) in self.events if k == "evict"]

    @property
    def rejoins(self) -> List[Tuple[int, int]]:
        return [(a, r) for (r, a, k) in self.events if k == "rejoin"]

    @property
    def has_faults(self) -> bool:
        return bool(self.events or self.corrupt_rows)

    def first_eviction_round(self) -> Optional[int]:
        rounds = [r for (r, _a, k) in self.events if k == "evict"]
        return min(rounds) if rounds else None

    def live_row(self, round: int) -> Optional[np.ndarray]:
        """The (N,) live row the broker passed for ``round`` -- None
        before the first eviction (the broker passes None until then, so
        replay must too to retrace the exact same jitted graph).

        Replay queries this once per round; the naive form rescans the
        whole event list each time, so the rows are computed once as
        per-event snapshots (lazily, rebuilt whenever events grew) and
        answered by binary search -- regression-tested against
        :meth:`_live_row_scan`."""
        rounds, snaps, first = self._live_index()
        if first is None or round < first:
            return None
        if rounds is None:            # out-of-order events: exact scan
            return self._live_row_scan(round)
        idx = bisect.bisect_right(rounds, round)
        return snaps[idx - 1].copy() if idx else None

    def _live_row_scan(self, round: int) -> Optional[np.ndarray]:
        """The pre-index linear scan (regression oracle)."""
        first = self.first_eviction_round()
        if first is None or round < first:
            return None
        row = np.ones(self.n_agents, np.float32)
        for (r, a, kind) in self.events:
            if r <= round:
                row[a] = 0.0 if kind == "evict" else 1.0
        return row

    def _live_index(self):
        """Lazy ``(event rounds, cumulative row snapshots, first evict
        round)``, keyed on ``len(events)`` (the record only appends).
        ``rounds`` comes back None when events arrived out of round
        order (hand-built records) -- callers then fall back to the
        scan, which applies events in LIST order like the original."""
        cached = getattr(self, "_live_cache", None)
        if cached is not None and cached[0] == len(self.events):
            return cached[1], cached[2], cached[3]
        first = self.first_eviction_round()
        rounds: Optional[List[int]] = []
        snaps: List[np.ndarray] = []
        row = np.ones(self.n_agents, np.float32)
        prev = None
        for (r, a, kind) in self.events:
            if prev is not None and r < prev:
                rounds, snaps = None, []
                break
            prev = r
            row = row.copy()
            row[a] = 0.0 if kind == "evict" else 1.0
            rounds.append(r)
            snaps.append(row)
        self._live_cache = (len(self.events), rounds, snaps, first)
        return rounds, snaps, first

    def live_matrix(self, n_rounds: int) -> np.ndarray:
        """(n_rounds, N) 0/1 liveness, for schedule validation."""
        lm = np.ones((n_rounds, self.n_agents), np.float32)
        for (r, a, kind) in self.events:
            if r < n_rounds:
                lm[r:, a] = 0.0 if kind == "evict" else 1.0
        return lm

    def corrupt_row(self, round: int) -> Optional[np.ndarray]:
        row = self.corrupt_rows.get(int(round))
        return None if row is None else np.asarray(row, np.float32)

    # -- persistence -----------------------------------------------------
    def to_json(self) -> dict:
        return {"n_agents": int(self.n_agents),
                "events": [list(e) for e in self.events],
                "retries": [list(e) for e in self.retries],
                "drops": [list(e) for e in self.drops],
                "errors": [list(e) for e in self.errors],
                "corrupt_rows": {str(r): row for r, row
                                 in self.corrupt_rows.items()}}

    @staticmethod
    def from_json(d: dict) -> "FaultRecord":
        rec = FaultRecord(n_agents=int(d["n_agents"]))
        rec.events = [(int(r), int(a), str(k)) for r, a, k in d["events"]]
        rec.retries = [(int(a), int(r), int(n)) for a, r, n in d["retries"]]
        rec.drops = [(int(a), int(r)) for a, r in d["drops"]]
        rec.errors = [(int(a), int(r), str(m)) for a, r, m in d["errors"]]

        def parse_row(row):
            if row and isinstance(row[0], (list, tuple)):
                return [[float(m), float(ad)] for m, ad in row]
            return [float(v) for v in row]

        rec.corrupt_rows = {int(r): parse_row(row)
                            for r, row in d["corrupt_rows"].items()}
        return rec

    def save(self, path: str) -> None:
        with open(path, "w") as fh:
            json.dump(self.to_json(), fh)

    @staticmethod
    def load(path: str) -> "FaultRecord":
        with open(path) as fh:
            return FaultRecord.from_json(json.load(fh))
