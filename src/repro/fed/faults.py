"""Deterministic fault injection for the federation runtime.

The broker/engine split (fed/broker.py's ROUND PROTOCOL) makes TIMING a
recorded, replayable input to the jitted round.  This module extends the
same contract to FAILURE: a seeded :class:`FaultPlan` decides, ahead of
time, which agents crash, stall, drop their uplink, or corrupt their
increment -- and a :class:`FaultRecord` captures what the broker actually
did about it (retries, evictions, rejoins, quarantined rows), so that

    ``broker.run(step, state, R, faults=plan)``  and
    ``broker.replay(step, state, schedule, record=broker.record)``

produce bitwise-identical trajectories.  Nothing in this module touches
jax: plans and records are plain host-side data, JSON round-trippable
(NaN corrupt values included), and cheap to query per (agent, round).

Fault kinds
-----------
``crash``    agent is dead for rounds ``[round, until)`` (``until=None``
             = forever): dispatched work silently disappears, so the
             broker's gate timeout -> retry -> evict machinery engages.
``drop``     the agent does the work but the uplink for ``round`` is
             lost in transit on its first attempt; the broker's
             redispatch recovers it.
``corrupt``  the increment for ``round`` arrives multiplied by
             ``value`` per row (NaN/Inf poison it outright, a huge
             finite value trips the norm guard).  Applied IN-JIT by
             ``engine.apply_corruption`` from the broker-realized row,
             keeping numerics out of the host threads.
``stall``    transient slowdown: ``delay`` seconds are added to the
             worker's latency for ``round``.
"""

from __future__ import annotations

import dataclasses
import json
from typing import Callable, List, Optional, Tuple

import numpy as np

FAULT_KINDS = ("crash", "drop", "corrupt", "stall")


@dataclasses.dataclass(frozen=True)
class FaultEvent:
    """One scheduled fault: ``kind`` hitting ``agent`` at ``round``."""

    kind: str
    agent: int
    round: int
    until: Optional[int] = None    # crash only: first round alive again
    value: float = float("nan")    # corrupt only: per-row multiplier
    delay: float = 0.0             # stall only: extra latency (seconds)

    def __post_init__(self):
        if self.kind not in FAULT_KINDS:
            raise ValueError(
                f"unknown fault kind {self.kind!r} (one of {FAULT_KINDS})")
        if self.agent < 0:
            raise ValueError(f"agent must be >= 0, got {self.agent}")
        if self.round < 0:
            raise ValueError(f"round must be >= 0, got {self.round}")
        if self.until is not None and self.until <= self.round:
            raise ValueError(
                f"crash until={self.until} must exceed round={self.round}")
        if self.delay < 0:
            raise ValueError(f"delay must be >= 0, got {self.delay}")

    def to_json(self) -> dict:
        d = {"kind": self.kind, "agent": int(self.agent),
             "round": int(self.round)}
        if self.until is not None:
            d["until"] = int(self.until)
        if self.kind == "corrupt":
            d["value"] = float(self.value)
        if self.kind == "stall":
            d["delay"] = float(self.delay)
        return d

    @staticmethod
    def from_json(d: dict) -> "FaultEvent":
        return FaultEvent(kind=d["kind"], agent=int(d["agent"]),
                          round=int(d["round"]),
                          until=(None if d.get("until") is None
                                 else int(d["until"])),
                          value=float(d.get("value", float("nan"))),
                          delay=float(d.get("delay", 0.0)))


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """A seeded, deterministic set of fault events.

    Like ``ArrivalSchedule`` this is an ARTIFACT: generate it once
    (:meth:`generate`), save it next to the run, and any later process
    can reload it and reproduce the exact same failure pattern.  The
    queries below are what the broker consults each round.
    """

    events: Tuple[FaultEvent, ...]
    n_agents: Optional[int] = None   # validated bound when given
    seed: Optional[int] = None       # provenance only

    def __post_init__(self):
        evs = tuple(e if isinstance(e, FaultEvent) else FaultEvent(**e)
                    for e in self.events)
        object.__setattr__(self, "events", evs)
        if self.n_agents is not None:
            self.check_agents(int(self.n_agents))

    # -- broker-facing queries ------------------------------------------
    def check_agents(self, n_agents: int) -> None:
        bad = [e for e in self.events if e.agent >= n_agents]
        if bad:
            raise ValueError(
                f"fault plan targets agents {sorted({e.agent for e in bad})} "
                f"but the fleet has only {n_agents} agents")

    def needs_timeout(self) -> bool:
        """True when the plan can make dispatched work vanish -- such a
        plan needs a broker ``gate_timeout`` or the round gate would
        block forever."""
        return any(e.kind in ("crash", "drop") for e in self.events)

    def crashed(self, agent: int, round: int) -> bool:
        return any(e.kind == "crash" and e.agent == agent
                   and e.round <= round
                   and (e.until is None or round < e.until)
                   for e in self.events)

    def rejoins_at(self, round: int) -> List[int]:
        """Agents whose crash window ends exactly at ``round``."""
        return sorted({e.agent for e in self.events
                       if e.kind == "crash" and e.until == round})

    def dropped(self, agent: int, round: int, attempt: int) -> bool:
        """Whether delivery ``attempt`` (0-based) of this round's uplink
        is lost.  Each matching drop event eats one attempt, so the
        broker's redispatch eventually gets through."""
        n = sum(1 for e in self.events if e.kind == "drop"
                and e.agent == agent and e.round == round)
        return attempt < n

    def corrupt_value(self, agent: int, round: int) -> Optional[float]:
        for e in self.events:
            if (e.kind == "corrupt" and e.agent == agent
                    and e.round == round):
                return float(e.value)
        return None

    def stall_delay(self, agent: int, round: int) -> float:
        return sum(e.delay for e in self.events if e.kind == "stall"
                   and e.agent == agent and e.round == round)

    def wrap_latency(self, latency_fn: Callable[[int, int], float]
                     ) -> Callable[[int, int], float]:
        """Latency function with the plan's stalls folded in."""
        def fn(agent: int, round: int) -> float:
            return float(latency_fn(agent, round)) + self.stall_delay(
                agent, round)
        return fn

    # -- construction / persistence -------------------------------------
    @staticmethod
    def generate(seed: int, n_agents: int, n_rounds: int, *,
                 p_crash: float = 0.0, crash_length: Optional[int] = None,
                 p_drop: float = 0.0, p_corrupt: float = 0.0,
                 corrupt_value: float = float("nan"),
                 p_stall: float = 0.0,
                 stall_delay: float = 0.05) -> "FaultPlan":
        """Draw a plan from a seeded rng -- same (seed, shape, probs)
        always yields the same events."""
        rng = np.random.default_rng(seed)
        events: List[FaultEvent] = []
        crashed_until = np.zeros(n_agents, np.int64)   # rounds < this: dead
        for r in range(n_rounds):
            for a in range(n_agents):
                if r < crashed_until[a]:
                    continue    # already down -- no new faults while dead
                if p_crash and rng.random() < p_crash:
                    until = (None if crash_length is None
                             else min(r + int(crash_length), n_rounds))
                    events.append(FaultEvent("crash", a, r, until=until))
                    crashed_until[a] = n_rounds if until is None else until
                    continue
                if p_drop and rng.random() < p_drop:
                    events.append(FaultEvent("drop", a, r))
                if p_corrupt and rng.random() < p_corrupt:
                    events.append(FaultEvent("corrupt", a, r,
                                             value=corrupt_value))
                if p_stall and rng.random() < p_stall:
                    events.append(FaultEvent("stall", a, r,
                                             delay=stall_delay))
        return FaultPlan(tuple(events), n_agents=n_agents, seed=seed)

    def to_json(self) -> dict:
        return {"events": [e.to_json() for e in self.events],
                "n_agents": self.n_agents, "seed": self.seed}

    @staticmethod
    def from_json(d: dict) -> "FaultPlan":
        return FaultPlan(tuple(FaultEvent.from_json(e)
                               for e in d["events"]),
                         n_agents=d.get("n_agents"), seed=d.get("seed"))

    def save(self, path: str) -> None:
        with open(path, "w") as fh:
            json.dump(self.to_json(), fh)   # allow_nan: corrupt values

    @staticmethod
    def load(path: str) -> "FaultPlan":
        with open(path) as fh:
            return FaultPlan.from_json(json.load(fh))


@dataclasses.dataclass
class FaultRecord:
    """What the broker actually DID during a faulty run.

    The record is the second half of the replay contract: the
    ``ArrivalSchedule`` pins the arrival rows, the record pins the
    per-round ``corrupt`` and ``live`` rows the jitted round consumed
    (plus the retry/drop/error bookkeeping for inspection).  ``events``
    is one chronological list of ``(round, agent, "evict"|"rejoin")``
    entries so a rejoin-then-re-evict within one run stays ordered.
    """

    n_agents: int
    events: List[Tuple[int, int, str]] = dataclasses.field(
        default_factory=list)
    retries: List[Tuple[int, int, int]] = dataclasses.field(
        default_factory=list)    # (agent, round, attempt)
    drops: List[Tuple[int, int]] = dataclasses.field(
        default_factory=list)    # (agent, round)
    errors: List[Tuple[int, int, str]] = dataclasses.field(
        default_factory=list)    # (agent, round, repr(exc))
    corrupt_rows: dict = dataclasses.field(
        default_factory=dict)    # {round: [value] * n_agents}

    # -- broker hooks ----------------------------------------------------
    def note_eviction(self, agent: int, round: int) -> None:
        self.events.append((int(round), int(agent), "evict"))

    def note_rejoin(self, agent: int, round: int) -> None:
        self.events.append((int(round), int(agent), "rejoin"))

    def note_retry(self, agent: int, round: int, attempt: int) -> None:
        self.retries.append((int(agent), int(round), int(attempt)))

    def note_drop(self, agent: int, round: int) -> None:
        self.drops.append((int(agent), int(round)))

    def note_error(self, agent: int, round: int, err: BaseException) -> None:
        self.errors.append((int(agent), int(round), repr(err)))

    def note_corrupt_row(self, round: int, row: np.ndarray) -> None:
        self.corrupt_rows[int(round)] = [float(v) for v in row]

    # -- replay queries --------------------------------------------------
    @property
    def evictions(self) -> List[Tuple[int, int]]:
        return [(a, r) for (r, a, k) in self.events if k == "evict"]

    @property
    def rejoins(self) -> List[Tuple[int, int]]:
        return [(a, r) for (r, a, k) in self.events if k == "rejoin"]

    @property
    def has_faults(self) -> bool:
        return bool(self.events or self.corrupt_rows)

    def first_eviction_round(self) -> Optional[int]:
        rounds = [r for (r, _a, k) in self.events if k == "evict"]
        return min(rounds) if rounds else None

    def live_row(self, round: int) -> Optional[np.ndarray]:
        """The (N,) live row the broker passed for ``round`` -- None
        before the first eviction (the broker passes None until then, so
        replay must too to retrace the exact same jitted graph)."""
        first = self.first_eviction_round()
        if first is None or round < first:
            return None
        row = np.ones(self.n_agents, np.float32)
        for (r, a, kind) in self.events:
            if r <= round:
                row[a] = 0.0 if kind == "evict" else 1.0
        return row

    def live_matrix(self, n_rounds: int) -> np.ndarray:
        """(n_rounds, N) 0/1 liveness, for schedule validation."""
        lm = np.ones((n_rounds, self.n_agents), np.float32)
        for (r, a, kind) in self.events:
            if r < n_rounds:
                lm[r:, a] = 0.0 if kind == "evict" else 1.0
        return lm

    def corrupt_row(self, round: int) -> Optional[np.ndarray]:
        row = self.corrupt_rows.get(int(round))
        return None if row is None else np.asarray(row, np.float32)

    # -- persistence -----------------------------------------------------
    def to_json(self) -> dict:
        return {"n_agents": int(self.n_agents),
                "events": [list(e) for e in self.events],
                "retries": [list(e) for e in self.retries],
                "drops": [list(e) for e in self.drops],
                "errors": [list(e) for e in self.errors],
                "corrupt_rows": {str(r): row for r, row
                                 in self.corrupt_rows.items()}}

    @staticmethod
    def from_json(d: dict) -> "FaultRecord":
        rec = FaultRecord(n_agents=int(d["n_agents"]))
        rec.events = [(int(r), int(a), str(k)) for r, a, k in d["events"]]
        rec.retries = [(int(a), int(r), int(n)) for a, r, n in d["retries"]]
        rec.drops = [(int(a), int(r)) for a, r in d["drops"]]
        rec.errors = [(int(a), int(r), str(m)) for a, r, m in d["errors"]]
        rec.corrupt_rows = {int(r): [float(v) for v in row]
                            for r, row in d["corrupt_rows"].items()}
        return rec

    def save(self, path: str) -> None:
        with open(path, "w") as fh:
            json.dump(self.to_json(), fh)

    @staticmethod
    def load(path: str) -> "FaultRecord":
        with open(path) as fh:
            return FaultRecord.from_json(json.load(fh))
