"""The single Fed-PLT round engine: Algorithm 1 on agent-stacked pytrees.

Every leaf of the state pytrees carries a leading agent axis ``(N, ...)``;
a dense ``(N, n)`` array (the convex experiments in :mod:`repro.core`) is
just the single-leaf case, a stacked model parameter pytree
(:mod:`repro.fed.runtime`) the general one.  One round:

  coordinator:  y = prox_{rho h / N}( mean_i z_i )            (Lemma 6)
  agents i active (u_i ~ Ber(p_i)):
      v_i   = 2 y - z_i                                       (reflection)
      x_i   <- N_e epochs of the local solver on
               d_i(w) = f_i(w) + ||w - v_i||^2/(2 rho),  warm start x_i
      z_i   <- z_i + 2 * damping * (x_i - y)
  agents inactive: state unchanged.

The local solver is pluggable (:data:`LocalSolver`, built by name from
the :mod:`repro.fed.solvers` registry): adapters supply the gradient
oracle / per-agent vmap; the *round topology* -- coordinator prox,
reflection, participation masking, and the compressed z-exchange --
lives only here, so ``core/fedplt.py`` and ``fed/runtime.py`` cannot
diverge again.  Agents need not be uniform: ``round_step`` accepts a
partition of the agent axis into :class:`SolverGroup` slices (each with
its own solver/epochs/step size, see :func:`run_solvers`) and
``participation`` may be a per-agent vector -- the paper's "agents
choose their local training solver" and per-agent Prop. 4 accounting,
at engine level.

Compressed uplink (beyond-paper): agents transmit the compressed
increment ``C(z_new - t)`` and the coordinator's copy ``t`` advances by
exactly what was transmitted.  ``t`` therefore lags ``z`` by the
never-transmitted residual, which *is* error feedback (an explicit error
memory would double-count the residual and diverge).

Round-edge backends: ``RoundConfig.engine_backend`` selects how the
round's memory-bound coordinator edges execute -- ``"xla"`` (default)
is the historical per-leaf ``tree_map`` path; ``"pallas"`` packs the
agent stack into one ``(N, M_total)`` buffer and runs the two fused
:mod:`repro.kernels.round_edge` kernels (mean + prox + reflection;
z-update + participation selects), collapsing the coordinator edge to
TWO launches.  Parity contract: the kernels are bit-identical to the
per-leaf edge formulas as materialized values (asserted against the
ref oracles across the whole prox table), and cross-backend
trajectories agree to float32 rounding.  Exact bitwise equality of
whole jitted rounds is NOT promised: XLA refolds the coordinator
chain's constants per consumer/program/shape -- the xla backend's own
``run()`` and ``step()`` already differ bitwise at some shapes -- so
the kernels mirror the unfused path's typical compilation (chain
duplication per consumer, pinned prox scales in ``core/prox.py``),
which makes most full-round configurations agree bit-for-bit in
practice.

State layouts -- the LAYOUT CONTRACT (ROADMAP item 1):
``RoundConfig.state_layout`` selects the round-to-round representation
of the federated state ``(x, z, t)``:

* ``"tree"`` (default): agent-stacked pytrees, the historical layout.
  Every packed-backend feature (fused edges, packed compress) pays a
  ``pack_leaves``/``unpack_leaves`` round-trip per use.
* ``"packed"``: ONE resident ``(N, M_total)`` buffer per state
  variable plus one static :class:`repro.fed.compress.PackedMeta`,
  packed once at ``init``.  Every round-to-round transition -- both
  fused round-edge kernels, the compressed z-exchange, participation
  selects, the Krasnosel'skii update, and (for gd/agd/sgd) the local
  solver itself -- runs directly on the buffer
  (:func:`packed_round_step`); the tree form is reconstructed only at
  the API boundary (consensus, metrics, checkpointing) and inside the
  gradient oracle (``unpack -> fgrad -> pack``, traced into the same
  jit).  A packed pallas round therefore contains ZERO concatenate /
  gather ops on the state path (asserted in tests via
  :func:`count_primitives`); the remaining layout traffic is the
  oracle's static slice/update-slice chain, which touches gradient
  values, not state.

  Parity: packed-resident trajectories are BITWISE identical to the
  tree-resident path per realization, under both engine backends and
  every registry compressor (asserted in tests).  The packed edges
  compute the same per-column arithmetic the per-leaf path computes
  (columns are independent; the agent-axis mean reduces in the same
  order), the PRNG key schedule is unchanged, and the two
  solver-stream exceptions fall back to unpack-around-the-solver
  rather than forking bits: ``noisy_gd`` (its per-leaf noise draws
  fold the key per leaf -- a single buffer would change the DP noise
  stream) and clipped runs (the clip norm reduces per leaf before
  summing -- one buffer would reorder the reduction).

  Padding columns (multi-leaf trees are lane-aligned) are dead state:
  they start at zero, may drift under an elementwise prox whose fixed
  point at 0 is nonzero, and are never unpacked; the compress paths
  zero out-of-segment columns under both backends, so the coordinator
  copy ``t``'s padding never advances.  ``jnp.where`` masking keeps
  them NaN-safe.

SHARDED ROUNDS -- the MESH CONTRACT (ROADMAP item 2): passing a
``mesh`` (an ``(agent, model)`` :class:`jax.sharding.Mesh`) to
:func:`round_step` / :func:`packed_round_step` (and the async variants)
runs the round's EDGES under ``shard_map``, with each device owning a
contiguous ``n_agents / agent_shards`` row block of every per-agent
carrier -- state buffers/leaves, the participation draw, and (async)
the staleness counters, ``y_tag``, and arrival rows all shard together
on the agent axis.  The uplink's agent mean becomes: an in-VMEM local
row reduce per shard (one fused kernel launch under the pallas
backend), ONE ``(1, width)`` cross-device ``psum`` of the partials,
then ``/ N -> prox -> reflection`` at coordinator size -- ``zbar``
still never materializes at agent-stack size.  The downlink consumes
the replicated coordinator point with purely local per-row work (the
second launch), so a sharded pallas round still runs exactly TWO fused
edge launches PER SHARD.  Everything between the edges (local solvers,
compression, masks, the key schedule) is row-wise or
coordinator-sized and runs under GSPMD unchanged, which is what keeps
the parity contract: a 1-DEVICE MESH IS BITWISE-IDENTICAL to the
unsharded engine on every layout x backend x compressor combo
(asserted in tests) -- the degenerate case of one code path, not a
separate engine -- while multi-device trajectories agree with
single-device to fp32 rounding only (cross-device psum reduction order
is not bitwise-stable, measured at one ulp in practice).
Solver groups must land shard-aligned (group boundaries at multiples
of the shard row block) or the round step raises before tracing;
a non-elementwise custom prox falls back to the unsharded edge formula
(GSPMD still shards the arithmetic, there is just no per-shard kernel).
MESH CONTRACT extension (robust aggregation): an order-statistic
aggregator (``RoundConfig.aggregator`` != "mean") needs the FULL agent
column, so the packed sharded uplink is preceded by an all-gather of
the per-shard row blocks on the agent axis
(:func:`repro.fed.robust.robust_seen_packed`) -- ``(N/shards, width)``
rows move per device per round, the documented price of a nonzero
breakdown point.  ``mean`` keeps the single-psum uplink untouched, and
a 1-device mesh remains bitwise identical to the unsharded engine
(the gather of one shard is the identity).  Tree-layout robust rounds
under a mesh compute the aggregate globally (GSPMD inserts the
collectives) before the sharded edges run.
"""

from __future__ import annotations

import dataclasses
from typing import (Any, Callable, Dict, NamedTuple, Optional, Sequence,
                    Tuple, Union)

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.fed import compress as compress_lib
from repro.fed.compress import compress_increment, get_compressor

tree_map = jax.tree_util.tree_map

# round-edge execution backends: "xla" = per-leaf tree_map ops;
# "pallas" = the fused repro.kernels.round_edge kernels on the packed
# (N, M_total) buffer -- ONE launch per edge (parity contract above)
ENGINE_BACKENDS = ("xla", "pallas")

# round-to-round state representations (layout contract above):
# "tree" = agent-stacked pytrees; "packed" = one resident (N, M_total)
# buffer per state variable + a static PackedMeta
ENGINE_LAYOUTS = ("tree", "packed")

# round synchrony modes: "off" = the bulk-synchronous round above;
# "stale" = the bounded-staleness async model (arrival mask + per-agent
# staleness counters; semantics in repro.fed.async_engine)
ASYNC_MODES = ("off", "stale")


def _numeric_scalar(name: str, value):
    """Normalize a config scalar to ``float`` with a clear construction
    error: strings (which ``float()`` would happily parse -- hiding the
    type bug until deep inside jit) and non-numerics raise ValueError;
    0-d numpy/jax arrays are accepted and unwrapped."""
    if isinstance(value, (str, bytes)):
        raise ValueError(
            f"{name} must be a number, got the string {value!r}")
    if getattr(value, "ndim", None) == 0:   # 0-d numpy/jax scalar
        return float(value)
    try:
        return float(value)
    except (TypeError, ValueError):
        raise ValueError(
            f"{name} must be a number, got {value!r}") from None


def _int_scalar(name: str, value) -> int:
    """Like :func:`_numeric_scalar` but for integer knobs: accepts ints
    and 0-d integer arrays, rejects strings, floats with a fractional
    part, and non-numerics -- at construction, not inside jit."""
    if isinstance(value, (str, bytes)):
        raise ValueError(
            f"{name} must be an integer, got the string {value!r}")
    if isinstance(value, bool):
        raise ValueError(f"{name} must be an integer, got {value!r}")
    if getattr(value, "ndim", None) == 0:
        value = value.item()
    try:
        as_int = int(value)
    except (TypeError, ValueError):
        raise ValueError(
            f"{name} must be an integer, got {value!r}") from None
    if as_int != value:
        raise ValueError(f"{name} must be an integer, got {value!r}")
    return as_int


@dataclasses.dataclass(frozen=True)
class StalenessConfig:
    """Bounded-staleness async-round knobs (ROADMAP item 3).

    ``mode="stale"`` turns the round's Bernoulli participation draw into
    an *arrival* draw: agents that arrive submit their increment (tagged
    with the coordinator point it was computed against) and pull a fresh
    reflection next round; agents that do not arrive KEEP TRAINING
    against their stale reflection, aging a per-agent staleness counter.
    ``max_staleness`` is the hard bound K: an agent holding work K
    rounds old is forced to arrive.  K = 0 permits no stale work at all
    -- a miss discards the round's local work, which is exactly the
    synchronous engine (bitwise per realization; contract in
    :mod:`repro.fed.async_engine`).
    """

    mode: str = "off"            # "off" | "stale"
    max_staleness: int = 0       # K: forced arrival at staleness K

    def __post_init__(self):
        if self.mode not in ASYNC_MODES:
            raise ValueError(
                f"unknown async mode {self.mode!r}; "
                f"known: {', '.join(ASYNC_MODES)}")
        k = _int_scalar("max_staleness", self.max_staleness)
        if k < 0:
            raise ValueError(f"max_staleness must be >= 0, got {k}")
        object.__setattr__(self, "max_staleness", k)

    @property
    def enabled(self) -> bool:
        return self.mode != "off"

# (x_stack, v_stack, key) -> (w_stack, aux); aux may be None.  The solver
# must be warm-started at x_stack (Section V-C1) -- the engine passes the
# previous local states as the first argument.
LocalSolver = Callable[[Any, Any, jax.Array], Tuple[Any, Any]]


class SolverGroup(NamedTuple):
    """A contiguous slice of the agent axis running its own local solver.

    ``round_step`` accepts a sequence of groups instead of one
    :data:`LocalSolver`: the stacked pytrees are partitioned along the
    agent axis (group g owns agents ``[sum(sizes[:g]), sum(sizes[:g+1]))``),
    each group's solver runs on its slice (vmapped within the group by
    whoever built it), and the results are re-stitched by concatenation.
    A single group is dispatched exactly like a bare solver (same key,
    no slicing), so a homogeneous "grouped" round is bit-identical to
    the historical path.
    """

    size: int
    solver: LocalSolver


# A round's solver assignment: one solver for every agent, or a
# partition of the agent axis into heterogeneous groups.
SolverAssignment = Union[LocalSolver, Sequence[SolverGroup]]

# Leaf-wise proximal operator of the coordinator regularizer h:
# (zbar, rho_eff) -> y, applied to the agent-mean tree with
# rho_eff = rho / N (Lemma 6).  None means h = 0 (identity).
ProxH = Optional[Callable[[Any, float], Any]]


@dataclasses.dataclass(frozen=True)
class RoundConfig:
    """Round-topology knobs shared by every Fed-PLT front end."""

    n_agents: int
    rho: float = 1.0
    # p: one scalar shared by every agent, or an (n_agents,)-tuple of
    # per-agent probabilities (Prop. 4 / heterogeneous deployments)
    participation: Union[float, Tuple[float, ...]] = 1.0
    # Krasnosel'skii relaxation: z <- z + 2*damping*(x - y).  damping = 1
    # is the paper's PRS; damping = 1/2 is Douglas-Rachford -- needed to
    # stabilize aggressively compressed exchanges.
    damping: float = 1.0
    # compressor name in the repro.fed.compress registry
    # (none | topk | int8 | adaptive_topk | anything registered)
    compression: str = "none"
    compress_ratio: float = 0.25      # top-k fraction kept (floor for adaptive)
    compress_energy: float = 0.95     # adaptive_topk per-agent energy target
    # "xla" = per-leaf registry compressors; "pallas" = packed agent-axis
    # buffer through the fused repro.kernels.compress kernels (one launch
    # per round, bit-identical output; non-accelerated compressors fall
    # back to the per-leaf path)
    compress_backend: str = "xla"
    # "xla" = per-leaf tree_map round edges; "pallas" = the fused
    # repro.kernels.round_edge kernels on the packed buffer (coordinator
    # prox + reflect in one launch, z-update + participation selects in
    # another; parity contract in the module docstring.  Non-elementwise
    # custom proxes and mixed-dtype trees fall back per edge)
    engine_backend: str = "xla"
    # "tree" = agent-stacked pytrees round to round; "packed" = one
    # resident (N, M_total) buffer per state variable (layout contract
    # in the module docstring; front ends dispatch on this to
    # packed_round_step and convert at the API boundary only)
    state_layout: str = "tree"
    # bounded-staleness async rounds: mode "off" keeps this config a
    # synchronous round; "stale" generalizes the participation draw to
    # an arrival mask with per-agent staleness counters (front ends
    # dispatch to repro.fed.async_engine when enabled)
    staleness: StalenessConfig = dataclasses.field(
        default_factory=StalenessConfig)
    # number of contiguous row blocks the agent axis is sharded into
    # when a mesh is passed to the round step (mesh contract in the
    # module docstring); 1 = unsharded.  Every shard owns
    # n_agents/agent_shards agents, so N must divide evenly
    agent_shards: int = 1
    # in-jit increment guards (fault tolerance): when enabled, each
    # agent row of the local-solve result is screened at the uplink --
    # a non-finite row (NaN/Inf), or one whose l2 norm exceeds
    # guard_norm_bound, is converted into a NON-ARRIVAL (u_i -> 0, the
    # quarantine row), so one corrupt increment cannot poison the
    # consensus mean.  With every row clean the guard multiplies u by
    # an all-ones mask: trajectories are bitwise unchanged
    guard_increments: bool = False
    guard_norm_bound: float = float("inf")   # inf = finiteness-only screen
    # coordinator aggregator (repro.fed.robust registry): "mean" keeps
    # the historical uplink bitwise; "trimmed_mean" (param = trim count
    # f), "coord_median", and "norm_clip_mean" (param = clip radius)
    # replace the agent mean with a robust statistic of the live rows
    # -- finite, guard-evading byzantine increments bounded by the
    # aggregator's breakdown point instead of steering the consensus
    aggregator: str = "mean"
    aggregator_param: float = 0.0

    def __post_init__(self):
        get_compressor(self.compression)  # fail fast on unknown names
        from repro.fed import robust as robust_lib
        object.__setattr__(
            self, "aggregator_param",
            robust_lib.validate_aggregator(
                self.aggregator, self.aggregator_param, self.n_agents))
        if self.compress_backend not in compress_lib.COMPRESS_BACKENDS:
            raise ValueError(
                f"unknown compress backend {self.compress_backend!r}; "
                f"known: {', '.join(compress_lib.COMPRESS_BACKENDS)}")
        if self.engine_backend not in ENGINE_BACKENDS:
            raise ValueError(
                f"unknown engine backend {self.engine_backend!r}; "
                f"known: {', '.join(ENGINE_BACKENDS)}")
        if self.state_layout not in ENGINE_LAYOUTS:
            raise ValueError(
                f"unknown state layout {self.state_layout!r}; "
                f"known: {', '.join(ENGINE_LAYOUTS)}")
        # damping gets the same construction-time screening as
        # participation below: a string "0.5" parses as a valid float,
        # so without this it would only blow up (or worse, silently
        # trace) deep inside the jitted round
        object.__setattr__(self, "damping",
                           _numeric_scalar("damping", self.damping))
        object.__setattr__(self, "rho", _numeric_scalar("rho", self.rho))
        shards = _int_scalar("agent_shards", self.agent_shards)
        if shards < 1:
            raise ValueError(f"agent_shards must be >= 1, got {shards}")
        object.__setattr__(self, "agent_shards", shards)
        if self.n_agents % shards:
            raise ValueError(
                f"n_agents={self.n_agents} is not divisible by "
                f"agent_shards={shards}: every shard owns an equal "
                f"contiguous row block of the agent axis -- choose "
                f"n_agents a multiple of the shard count (or reduce "
                f"agent_shards)")
        object.__setattr__(self, "guard_increments",
                           bool(self.guard_increments))
        bound = _numeric_scalar("guard_norm_bound", self.guard_norm_bound)
        if not bound > 0.0:   # rejects 0, negatives, and NaN
            raise ValueError(
                f"guard_norm_bound must be > 0 (inf disables the norm "
                f"screen), got {bound}")
        object.__setattr__(self, "guard_norm_bound", bound)
        if self.staleness is None:
            object.__setattr__(self, "staleness", StalenessConfig())
        elif not isinstance(self.staleness, StalenessConfig):
            raise ValueError(
                f"staleness must be a StalenessConfig, got "
                f"{self.staleness!r}")
        p = self.participation
        if isinstance(p, (str, bytes)):
            # a string is a __len__-bearing sequence of characters:
            # without this guard participation="0.5" would silently
            # tuple-ize into per-character draws (or crash later)
            raise ValueError(
                f"participation must be a probability or a per-agent "
                f"sequence of probabilities, got the string {p!r}")
        if getattr(p, "ndim", None) == 0:
            # a 0-d numpy/jax scalar: ndarray types carry __len__ (it
            # raises when called), so without this it would be
            # misdiagnosed as a malformed per-agent sequence
            object.__setattr__(self, "participation", float(p))
        elif isinstance(p, (list, tuple)) or hasattr(p, "__len__"):
            try:
                p = tuple(float(x) for x in p)
            except (TypeError, ValueError):
                raise ValueError(
                    f"per-agent participation must contain numbers, "
                    f"got {self.participation!r}") from None
            object.__setattr__(self, "participation", p)
            if len(p) != self.n_agents:
                raise ValueError(
                    f"per-agent participation has {len(p)} entries for "
                    f"n_agents={self.n_agents}")

    @property
    def compressed(self) -> bool:
        return self.compression != "none"

    @property
    def robust_aggregator(self) -> Optional[str]:
        """The aggregator name when the uplink is actually robust, else
        None: ``"mean"`` -- and ``"trimmed_mean"`` at ``f = 0``, which
        IS the mean -- resolve to the historical
        :func:`survivor_mean_input` path, keeping clean configurations
        bitwise identical to the pre-robustness engine."""
        if self.aggregator == "mean":
            return None
        if (self.aggregator == "trimmed_mean"
                and int(self.aggregator_param) == 0):
            return None
        return self.aggregator


class RoundResult(NamedTuple):
    x: Any               # pytree, leaves (N, ...)
    z: Any               # pytree, leaves (N, ...)
    t: Any               # coordinator's copy of z (== z when uncompressed)
    y: Any               # pytree, coordinator model (no agent axis)
    next_key: jax.Array  # carried PRNG state
    u: jnp.ndarray       # (N,) participation draw of this round
    aux: Any             # whatever the local solver returned


# ---------------------------------------------------------------------------
# Round pieces
# ---------------------------------------------------------------------------

def agent_mean(z: Any) -> Any:
    """Mean over the leading agent axis, leaf-wise."""
    return tree_map(lambda zl: jnp.mean(zl, axis=0), z)


def coordinator_prox(z: Any, cfg: RoundConfig, prox_h: ProxH = None) -> Any:
    """``y = prox_{rho h / N}(mean_i z_i)`` on pytrees (Lemma 6)."""
    zbar = agent_mean(z)
    if prox_h is None:
        return zbar
    rho_eff = cfg.rho / cfg.n_agents
    return tree_map(lambda zl: prox_h(zl, rho_eff), zbar)


def reflect(y: Any, z: Any) -> Any:
    """``v = 2 y - z`` with y broadcast across the agent axis."""
    return tree_map(lambda yl, zl: 2.0 * yl[None] - zl, y, z)


def participation_mask(key: jax.Array, cfg: RoundConfig) -> jnp.ndarray:
    """One Bernoulli(p_i) draw per agent, as a float (N,) vector.

    Scalar ``cfg.participation`` reproduces the historical uniform draw
    bit-for-bit; an ``(N,)`` tuple draws each agent at its own rate from
    the same key (one uniform per agent either way)."""
    p = cfg.participation
    if isinstance(p, tuple):
        p = jnp.asarray(p, jnp.float32)
    return jax.random.bernoulli(
        key, p, (cfg.n_agents,)).astype(jnp.float32)


def masked_mix(u: jnp.ndarray, new: Any, old: Any) -> Any:
    """Select ``new`` where the agent participated, ``old`` otherwise,
    leaf-wise.  ``jnp.where`` (not ``u*new + (1-u)*old``) so a diverged
    local solve (NaN/Inf) cannot leak into agents that sat the round
    out; for finite values the two are bit-identical with u in {0, 1}."""
    mask = u != 0

    def mix(nl, ol):
        return jnp.where(mask.reshape((-1,) + (1,) * (nl.ndim - 1)),
                         nl, ol)

    return tree_map(mix, new, old)


# ---------------------------------------------------------------------------
# Fault tolerance: corruption injection, in-jit increment guards, and the
# survivor mean (live masks).  All three are BITWISE NO-OPS when disabled
# (corrupt=None / guards off / live=None) -- the fault-free graph is the
# historical graph, which is what keeps clean trajectories replayable
# against recordings made before this layer existed.
# ---------------------------------------------------------------------------

def apply_corruption(w: Any, corrupt) -> Any:
    """Inject a recorded corruption row into the solver output.

    ``corrupt`` is the broker-realized corruption, in one of two forms:

    * an ``(N,)`` row (the historical encoding): agent ``i``'s row of
      every leaf is multiplied by ``corrupt[i]`` wherever the entry is
      non-zero-or-NaN (NaN multipliers poison the row to NaN, Inf to
      Inf, a huge finite value trips the norm guard); zero entries
      leave the row untouched.
    * an ``(N, 2)`` ``[mult, add]`` pair per agent (the byzantine
      encoding): flagged rows -- any row whose pair is not ``(0, 0)``
      -- become ``w * mult + add``, which expresses the guard-evading
      attacks (``sign_flip`` = ``(-1, 0)``, ``scale(v)`` = ``(v, 0)``,
      ``drift(v)`` = ``(1, v)``) as well as every legacy multiplicative
      corruption (``(v, 0)``).

    ``None`` returns ``w`` unchanged.  This is the numerics half of a
    ``FaultPlan`` corruption event: the broker only RECORDS the rows
    (timing side), the jitted round applies them here, so replaying the
    rows reproduces the corruption bit-for-bit.  Plans without
    byzantine events keep realizing the ``(N,)`` form, so their
    recordings replay on the exact historical graph."""
    if corrupt is None:
        return w
    c = jnp.asarray(corrupt, jnp.float32)
    if c.ndim == 2:
        mult, add = c[:, 0], c[:, 1]
        # NaN != 0 is True: NaN entries flag the row (poison semantics)
        flagged = (mult != 0.0) | (add != 0.0)

        def poison(l):
            shape = (-1,) + (1,) * (l.ndim - 1)
            return jnp.where(
                flagged.reshape(shape),
                l * mult.astype(l.dtype).reshape(shape)
                + add.astype(l.dtype).reshape(shape), l)

        return tree_map(poison, w)
    c = c.reshape(-1)
    flagged = c != 0.0        # NaN != 0 is True: NaN rows are flagged

    def poison(l):
        shape = (-1,) + (1,) * (l.ndim - 1)
        return jnp.where(flagged.reshape(shape),
                         l * c.astype(l.dtype).reshape(shape), l)

    return tree_map(poison, w)


def _row_sq_norms(w: Any, meta=None) -> jnp.ndarray:
    """Per-agent squared l2 norm over the non-agent axes, in float32.
    For a resident packed buffer pass ``meta``: lane-padding columns are
    zeroed BEFORE squaring (NaN * 0 is NaN -- masking after the square
    would let drifted padding state trip the guard)."""
    leaves = jax.tree_util.tree_leaves(w)
    if meta is not None and len(leaves) == 1:
        buf = leaves[0]
        mask = np.zeros((buf.shape[-1],), bool)
        for a, b in meta.segments:
            mask[a:b] = True
        vals = buf if mask.all() else jnp.where(
            jnp.asarray(mask)[None, :], buf, 0.0)
        return jnp.sum(jnp.square(vals.astype(jnp.float32)), axis=1)
    total = None
    for l in leaves:
        sq = jnp.sum(jnp.square(l.astype(jnp.float32)),
                     axis=tuple(range(1, l.ndim)))
        total = sq if total is None else total + sq
    return total


def increment_guard(cfg: RoundConfig, w: Any, u: jnp.ndarray, meta=None
                    ) -> Tuple[jnp.ndarray, Optional[jnp.ndarray]]:
    """The in-jit uplink screen: returns ``(u_guarded, ok)`` where
    ``ok`` is the per-agent ``(N,)`` bool clean mask (``None`` when
    guards are off).  A corrupt row -- non-finite, or l2 norm above
    ``cfg.guard_norm_bound`` -- becomes a NON-ARRIVAL: ``u_i -> 0``,
    exactly as if the agent had not arrived, and the NaN-safe
    ``jnp.where`` selects downstream keep the poison out of
    ``(x, z, t)``.  With every row clean ``u * ok`` multiplies by ones,
    so guarded clean rounds are bitwise identical to unguarded ones."""
    if not cfg.guard_increments:
        return u, None
    sq = _row_sq_norms(w, meta)
    ok = jnp.isfinite(sq)
    if np.isfinite(cfg.guard_norm_bound):
        ok = ok & (sq <= jnp.float32(cfg.guard_norm_bound) ** 2)
    return u * ok.astype(u.dtype), ok


def survivor_mean_input(cfg: RoundConfig, z_seen: Any, live) -> Any:
    """Fold an eviction ``live`` row into the coordinator's input so the
    engine's fixed mean-over-N becomes the mean over SURVIVORS:
    ``z * live * (N / n_live)`` sums to ``sum_live(z)`` and the edges
    divide by N downstream, i.e. ``mean_live(z)``; dead rows contribute
    exact zeros.  Premultiplying here -- rather than teaching every
    uplink a second mask -- is what makes survivor averaging work on
    every layout x backend x mesh combo without touching a kernel: the
    scaled buffer is simply not ``z``, so the lagged ``z_seen`` path
    engages everywhere (including the fused downlink, which recomputes
    the coordinator chain from the SAME scaled input).  ``live=None``
    returns ``z_seen`` unchanged -- the historical graph."""
    if live is None:
        return z_seen
    lv = jnp.asarray(live, jnp.float32).reshape(-1)
    scale = lv * (cfg.n_agents / jnp.sum(lv))
    return tree_map(
        lambda l: l * scale.astype(l.dtype).reshape(
            (-1,) + (1,) * (l.ndim - 1)),
        z_seen)


def robust_seen(cfg: RoundConfig, z_seen: Any, live, meta=None,
                mesh=None) -> Any:
    """The uplink's aggregation input transform -- THE one place the
    coordinator's reduction is shaped.  ``aggregator="mean"`` (and
    ``trimmed_mean`` at ``f = 0``) calls :func:`survivor_mean_input`
    exactly: clean configurations keep the historical graph bitwise
    (including the ``z_seen is z`` object-identity the lagged-path
    dispatch keys on).  A robust aggregator computes its ``(1, M)``
    statistic over the LIVE rows and broadcasts it back across the
    agent axis, so the unchanged edges' fixed mean-over-N reproduces
    the robust ``y`` -- one transform, every layout x backend x
    compressor x mesh combo (rationale in :mod:`repro.fed.robust`).

    ``meta`` marks the packed form (``z_seen`` a resident ``(N, width)``
    buffer); without it ``z_seen`` is an agent-stacked pytree."""
    name = cfg.robust_aggregator
    if name is None:
        return survivor_mean_input(cfg, z_seen, live)
    from repro.fed import robust as robust_lib

    if meta is not None:
        col = None if mesh is None else _mesh_col_axis(
            mesh, z_seen.shape[1])
        return robust_lib.robust_seen_packed(
            z_seen, live, name=name, param=cfg.aggregator_param,
            meta=meta, backend=cfg.engine_backend, mesh=mesh,
            col_axis=col)
    return robust_lib.robust_seen_tree(
        z_seen, live, name=name, param=cfg.aggregator_param,
        backend=cfg.engine_backend)


def live_mask_rows(u: jnp.ndarray, live) -> jnp.ndarray:
    """Zero the arrival/participation row of evicted agents (``live``
    an ``(N,)`` 0/1 row; None = everyone live, returned unchanged)."""
    if live is None:
        return u
    return u * jnp.asarray(live, u.dtype).reshape(-1)


# ---------------------------------------------------------------------------
# Round edges: the coordinator-side memory-bound passes, with a fused
# packed-buffer backend
# ---------------------------------------------------------------------------

def fusible_prox(prox_h: ProxH) -> bool:
    """Whether ``prox_h`` may be traced into the fused uplink kernel:
    h = 0, or a :func:`repro.core.prox.make_prox` table entry (every one
    is elementwise and carries the ``elementwise`` tag).  Untagged
    custom callables take the XLA path."""
    return prox_h is None or getattr(prox_h, "elementwise", False)


def _uniform_stack(*trees) -> bool:
    """True when every leaf of every tree shares one (agent count,
    dtype) -- the precondition for packing them into one buffer (the
    same rule :func:`repro.fed.compress.compress_increment` uses)."""
    leaves = [l for t in trees for l in jax.tree_util.tree_leaves(t)]
    return len({(l.shape[0], jnp.result_type(l)) for l in leaves}) == 1


# ---------------------------------------------------------------------------
# Mesh plumbing (the mesh contract in the module docstring)
# ---------------------------------------------------------------------------

def mesh_agent_shards(mesh) -> int:
    """The extent of ``mesh``'s agent axis (1 when ``mesh`` is None)."""
    if mesh is None:
        return 1
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    if "agent" not in sizes:
        raise ValueError(
            f"sharded rounds need a mesh with an 'agent' axis, got "
            f"axes {tuple(mesh.axis_names)}")
    return int(sizes["agent"])


def _mesh_col_axis(mesh, width: int) -> Optional[str]:
    """The mesh axis that additionally shards the packed column axis:
    ``"model"`` when the mesh has one whose extent divides the buffer
    width, else None (columns replicated within each agent shard)."""
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    m = int(sizes.get("model", 0))
    return "model" if m > 1 and width % m == 0 else None


def validate_mesh(cfg: RoundConfig, mesh,
                  local_solver: SolverAssignment = None) -> None:
    """Trace-time screening of a sharded round: the mesh's agent axis
    must evenly partition the agent axis, agree with
    ``cfg.agent_shards`` when that was pinned, and every solver-group
    boundary must land on a shard boundary (group slicing happens on
    the host; a group straddling shards would silently gather rows
    across devices every round)."""
    shards = mesh_agent_shards(mesh)
    if cfg.n_agents % shards:
        raise ValueError(
            f"n_agents={cfg.n_agents} is not divisible by the mesh's "
            f"agent axis ({shards} shards): every shard owns an equal "
            f"contiguous row block -- choose n_agents a multiple of "
            f"the shard count or shrink the mesh")
    if cfg.agent_shards > 1 and cfg.agent_shards != shards:
        raise ValueError(
            f"RoundConfig.agent_shards={cfg.agent_shards} but the mesh "
            f"has {shards} agent shards: drop one of the two or make "
            f"them agree")
    if (shards > 1 and local_solver is not None
            and not callable(local_solver)
            and not isinstance(local_solver, SolverGroup)):
        rows = cfg.n_agents // shards
        start = 0
        for g_idx, grp in enumerate(tuple(local_solver)[:-1]):
            start += grp.size
            if start % rows:
                raise ValueError(
                    f"solver group {g_idx} ends at agent {start}, "
                    f"inside an agent shard: with {shards} shards of "
                    f"{rows} agents each, group boundaries must be "
                    f"multiples of {rows} -- resize the groups or "
                    f"change the shard count")


def _row_specs(tree):
    """Per-leaf ``P('agent', None, ...)`` specs for agent-stacked
    pytrees (rank-matched, columns replicated)."""
    return tree_map(
        lambda l: P(*(("agent",) + (None,) * (l.ndim - 1))), tree)


def _rep_specs(tree):
    """Per-leaf fully-replicated specs (coordinator pytrees carry no
    agent axis)."""
    return tree_map(lambda l: P(*((None,) * l.ndim)), tree)


def _uplink_sharded_xla(cfg: RoundConfig, z: jnp.ndarray,
                        z_seen: jnp.ndarray, prox_h: ProxH, mesh,
                        col: Optional[str]) \
        -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Sharded packed uplink, xla backend: local column sums per shard,
    one psum of the ``(1, width)`` partials, then the coordinator-sized
    chain and the local reflection -- the same formulation the fused
    sharded kernel realizes (bitwise on a 1-device mesh: ``div(psum(
    sum), N)`` == ``div(sum, N)`` and the reflection reads the shared
    ``y``, exactly like the unsharded xla edge)."""
    n = cfg.n_agents
    rho_eff = cfg.rho / cfg.n_agents
    lagged = z_seen is not z

    def body(z_l, *rest):
        seen = rest[0] if rest else z_l
        part = jnp.sum(seen, axis=0, keepdims=True)
        zbar = jax.lax.psum(part, "agent") / n
        y = zbar if prox_h is None else prox_h(zbar, rho_eff)
        return y, 2.0 * y - z_l

    spec = P("agent", col)
    f = shard_map(body, mesh=mesh,
                  in_specs=(spec, spec) if lagged else (spec,),
                  out_specs=(P(None, col), spec), check_rep=False)
    return f(z, z_seen) if lagged else f(z)


def _downlink_sharded_xla(cfg: RoundConfig, u: jnp.ndarray,
                          w: jnp.ndarray, x: jnp.ndarray,
                          z: jnp.ndarray, y: jnp.ndarray, mesh,
                          col: Optional[str]) \
        -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Sharded packed downlink, xla backend: purely local per-row work
    consuming the replicated coordinator point (op-for-op the unsharded
    xla edge, which already consumes ``y``)."""
    def body(u_l, w_l, x_l, z_l, y_l):
        mask = (u_l != 0).reshape(-1, 1)
        x_new = jnp.where(mask, w_l, x_l)
        z_upd = z_l + 2.0 * cfg.damping * (w_l - y_l)
        return x_new, jnp.where(mask, z_upd, z_l)

    spec = P("agent", col)
    f = shard_map(body, mesh=mesh,
                  in_specs=(P("agent"), spec, spec, spec, P(None, col)),
                  out_specs=(spec, spec), check_rep=False)
    return f(u.reshape(-1), w, x, z, y)


def _tree_uplink_sharded(cfg: RoundConfig, z: Any, z_seen: Any,
                         prox_h: ProxH, mesh) -> Tuple[Any, Any]:
    """Sharded uplink on agent-stacked pytrees: per-leaf local sums,
    one psum per leaf, chain at coordinator size.  The ``y`` leaves are
    COMPLETE after the agent-axis reduction, so ANY per-leaf prox --
    including non-elementwise customs the packed paths must refuse --
    is applied here unchanged."""
    n = cfg.n_agents
    rho_eff = cfg.rho / cfg.n_agents
    lagged = z_seen is not z

    def body(z_t, *rest):
        seen = rest[0] if rest else z_t
        zbar = tree_map(
            lambda sl: jax.lax.psum(jnp.sum(sl, axis=0), "agent") / n,
            seen)
        y = (zbar if prox_h is None
             else tree_map(lambda l: prox_h(l, rho_eff), zbar))
        v = tree_map(lambda yl, zl: 2.0 * yl[None] - zl, y, z_t)
        return y, v

    rows = _row_specs(z)
    y_specs = tree_map(lambda l: P(*((None,) * (l.ndim - 1))), z)
    f = shard_map(body, mesh=mesh,
                  in_specs=(rows, _row_specs(z_seen)) if lagged
                  else (rows,),
                  out_specs=(y_specs, rows), check_rep=False)
    return f(z, z_seen) if lagged else f(z)


def _tree_downlink_sharded(cfg: RoundConfig, u: jnp.ndarray, w: Any,
                           x: Any, z: Any, y: Any,
                           mesh) -> Tuple[Any, Any]:
    """Sharded downlink on agent-stacked pytrees: the Krasnosel'skii
    update + NaN-safe participation selects per row block, consuming
    the replicated coordinator tree."""
    def body(u_l, w_t, x_t, z_t, y_t):
        mask = u_l != 0

        def mix(nl, ol):
            return jnp.where(
                mask.reshape((-1,) + (1,) * (nl.ndim - 1)), nl, ol)

        x_new = tree_map(mix, w_t, x_t)
        z_upd = tree_map(
            lambda zl, wl, yl: zl + 2.0 * cfg.damping * (wl - yl[None]),
            z_t, w_t, y_t)
        return x_new, tree_map(mix, z_upd, z_t)

    f = shard_map(body, mesh=mesh,
                  in_specs=(P("agent"), _row_specs(w), _row_specs(x),
                            _row_specs(z), _rep_specs(y)),
                  out_specs=(_row_specs(x), _row_specs(z)),
                  check_rep=False)
    return f(u.reshape(-1), w, x, z, y)


def coordinator_edge(cfg: RoundConfig, z: Any, z_seen: Any,
                     prox_h: ProxH = None, mesh=None) -> Tuple[Any, Any]:
    """The round's uplink edge: ``y = prox_{rho h/N}(mean_i z_seen_i)``
    and the reflection ``v = 2 y - z`` (``z_seen`` is the coordinator's
    lagged copy ``t`` under a compressed exchange, ``z`` itself
    otherwise).

    Under ``cfg.engine_backend == "pallas"`` (uniform stack, fusible
    prox) the leaves are packed into one ``(N, M_total)`` buffer and the
    agent-axis mean-reduce, the elementwise prox, and the reflected
    broadcast run as ONE :mod:`repro.kernels.round_edge` launch --
    ``zbar`` never materializes in HBM (parity contract: module
    docstring).  With a ``mesh`` the same edge runs under ``shard_map``
    (mesh contract: module docstring)."""
    if (cfg.engine_backend == "pallas" and fusible_prox(prox_h)
            and _uniform_stack(z, z_seen)):
        from repro.kernels.round_edge import ops as edge_ops

        buf_z, meta = compress_lib.pack_leaves(z)
        buf_t = (None if z_seen is z
                 else compress_lib.pack_leaves(z_seen)[0])
        if mesh is not None:
            y_buf, v_buf = edge_ops.round_uplink_sharded(
                buf_z, buf_t, mesh=mesh, n_total=cfg.n_agents,
                prox=prox_h, rho_eff=cfg.rho / cfg.n_agents,
                col_axis=_mesh_col_axis(mesh, buf_z.shape[1]))
        else:
            y_buf, v_buf = edge_ops.round_uplink(
                buf_z, buf_t, prox=prox_h,
                rho_eff=cfg.rho / cfg.n_agents)
        return (compress_lib.unpack_coord(y_buf, meta),
                compress_lib.unpack_leaves(v_buf, meta))
    if mesh is not None:
        return _tree_uplink_sharded(cfg, z, z_seen, prox_h, mesh)
    y = coordinator_prox(z_seen, cfg, prox_h)
    return y, reflect(y, z)


def agent_edge(cfg: RoundConfig, u: jnp.ndarray, w: Any, x: Any, z: Any,
               y: Any, z_seen: Any = None,
               prox_h: ProxH = None, mesh=None) -> Tuple[Any, Any]:
    """The round's downlink edge: the Krasnosel'skii update
    ``z + 2*damping*(w - y)`` and the participation selects of both
    state variables (``x`` from the solver result ``w``, ``z`` from the
    update), returning ``(x_new, z_new)``.

    Under ``cfg.engine_backend == "pallas"`` (uniform stack, fusible
    prox) both updates run as ONE fused :mod:`repro.kernels.round_edge`
    launch on the packed buffer, the mask streamed as an ``(N,)``
    vector -- ``jnp.where`` semantics preserved, so a diverged (NaN)
    local solve still cannot leak into agents that sat the round out.
    The kernel recomputes the coordinator chain from ``z_seen`` (the
    same source :func:`coordinator_edge` read) instead of consuming
    ``y``: the unfused path never materializes ``y`` between the prox
    and the z-update, and parity wants the compiler handed the same
    expression (see the kernel docstrings; contract in the module
    docstring).
    """
    if z_seen is None:
        z_seen = z
    if (cfg.engine_backend == "pallas" and fusible_prox(prox_h)
            and _uniform_stack(x, w, z, z_seen)):
        from repro.kernels.round_edge import ops as edge_ops

        x_buf, meta = compress_lib.pack_leaves(x)
        w_buf = compress_lib.pack_leaves(w)[0]
        z_buf = compress_lib.pack_leaves(z)[0]
        if mesh is not None:
            y_buf = compress_lib.pack_coord(y, meta)
            xb, zb = edge_ops.round_downlink_sharded(
                x_buf, w_buf, z_buf, y_buf, u, mesh=mesh,
                damping=cfg.damping,
                col_axis=_mesh_col_axis(mesh, x_buf.shape[1]))
        else:
            t_buf = (None if z_seen is z
                     else compress_lib.pack_leaves(z_seen)[0])
            xb, zb = edge_ops.round_downlink(
                x_buf, w_buf, z_buf, u, t_buf, prox=prox_h,
                rho_eff=cfg.rho / cfg.n_agents, damping=cfg.damping)
        return (compress_lib.unpack_leaves(xb, meta),
                compress_lib.unpack_leaves(zb, meta))
    if mesh is not None:
        return _tree_downlink_sharded(cfg, u, w, x, z, y, mesh)
    x_new = masked_mix(u, w, x)
    z_upd = tree_map(
        lambda zl, wl, yl: zl + 2.0 * cfg.damping * (wl - yl[None]),
        z, w, y)
    return x_new, masked_mix(u, z_upd, z)


# ---------------------------------------------------------------------------
# Packed-resident round edges: the same arithmetic on the resident
# (N, M_total) buffer -- no pack/unpack anywhere (layout contract in the
# module docstring)
# ---------------------------------------------------------------------------

def coordinator_edge_packed(cfg: RoundConfig, z: jnp.ndarray,
                            z_seen: jnp.ndarray, meta,
                            prox_h: ProxH = None, mesh=None) \
        -> Tuple[jnp.ndarray, jnp.ndarray]:
    """:func:`coordinator_edge` on resident ``(N, width)`` buffers:
    returns ``(y, v)`` with ``y`` the ``(1, width)`` coordinator buffer.

    The pallas backend hands the buffers straight to the fused kernel
    (the tree path's pack step vanishes); the xla backend computes the
    identical per-column arithmetic with whole-buffer ops.  A
    non-elementwise custom prox is the one case that must see the tree:
    it is applied through ``unpack_coord``/``pack_coord`` on the
    ``(1, width)`` mean -- coordinator-sized traffic, not agent-stack
    traffic."""
    rho_eff = cfg.rho / cfg.n_agents
    if mesh is not None and fusible_prox(prox_h):
        col = _mesh_col_axis(mesh, z.shape[1])
        if cfg.engine_backend == "pallas":
            from repro.kernels.round_edge import ops as edge_ops

            return edge_ops.round_uplink_sharded(
                z, None if z_seen is z else z_seen, mesh=mesh,
                n_total=cfg.n_agents, prox=prox_h, rho_eff=rho_eff,
                col_axis=col)
        return _uplink_sharded_xla(cfg, z, z_seen, prox_h, mesh, col)
    # a non-elementwise custom prox under a mesh falls through to the
    # unsharded formula: the prox sees the coordinator-sized tree and
    # GSPMD shards the agent-stack arithmetic (mesh contract)
    if cfg.engine_backend == "pallas" and fusible_prox(prox_h):
        from repro.kernels.round_edge import ops as edge_ops

        return edge_ops.round_uplink(
            z, None if z_seen is z else z_seen, prox=prox_h,
            rho_eff=rho_eff)
    zbar = jnp.mean(z_seen, axis=0, keepdims=True)
    if prox_h is None:
        y = zbar
    elif getattr(prox_h, "elementwise", False):
        y = prox_h(zbar, rho_eff)
    else:
        y = compress_lib.pack_coord(
            tree_map(lambda l: prox_h(l, rho_eff),
                     compress_lib.unpack_coord(zbar, meta)), meta)
    return y, 2.0 * y - z


def agent_edge_packed(cfg: RoundConfig, u: jnp.ndarray, w: jnp.ndarray,
                      x: jnp.ndarray, z: jnp.ndarray, y: jnp.ndarray,
                      z_seen: jnp.ndarray,
                      prox_h: ProxH = None, mesh=None) \
        -> Tuple[jnp.ndarray, jnp.ndarray]:
    """:func:`agent_edge` on resident ``(N, width)`` buffers (``y`` is
    the ``(1, width)`` coordinator buffer): Krasnosel'skii update +
    participation selects, ``jnp.where`` semantics preserved so a
    diverged (NaN) local solve cannot leak into inactive agents."""
    if mesh is not None and fusible_prox(prox_h):
        col = _mesh_col_axis(mesh, z.shape[1])
        if cfg.engine_backend == "pallas":
            from repro.kernels.round_edge import ops as edge_ops

            return edge_ops.round_downlink_sharded(
                x, w, z, y, u, mesh=mesh, damping=cfg.damping,
                col_axis=col)
        return _downlink_sharded_xla(cfg, u, w, x, z, y, mesh, col)
    if cfg.engine_backend == "pallas" and fusible_prox(prox_h):
        from repro.kernels.round_edge import ops as edge_ops

        return edge_ops.round_downlink(
            x, w, z, u, None if z_seen is z else z_seen, prox=prox_h,
            rho_eff=cfg.rho / cfg.n_agents, damping=cfg.damping)
    mask = (u != 0).reshape(-1, 1)
    x_new = jnp.where(mask, w, x)
    z_upd = z + 2.0 * cfg.damping * (w - y)
    return x_new, jnp.where(mask, z_upd, z)


def packed_round_step(cfg: RoundConfig, meta, x: jnp.ndarray,
                      z: jnp.ndarray, t: jnp.ndarray, key: jax.Array,
                      local_solver: SolverAssignment,
                      prox_h: ProxH = None, mesh=None,
                      corrupt=None, live=None) -> RoundResult:
    """One Fed-PLT round on the RESIDENT packed state: ``x``/``z``/``t``
    are ``(N, width)`` buffers laid out by ``meta`` (a static
    :class:`repro.fed.compress.PackedMeta`), and the returned
    :class:`RoundResult` carries buffers too (``y`` is ``(1, width)``).

    Mirrors :func:`round_step` exactly -- same 3-way key split, same
    edge formulas, same compressed-uplink ``t + u * q`` -- so packed
    and tree trajectories are bitwise identical per realization
    (asserted in tests).  ``local_solver`` must consume buffers: build
    it with :func:`repro.fed.solvers.make_packed_local_solver` (or wrap
    a tree solver with :func:`repro.fed.solvers.wrap_packed_solver`).
    :func:`run_solvers` works unchanged -- a buffer is a pytree, group
    slicing is row slicing.

    ``corrupt`` / ``live`` are broker-realized fault rows (see
    :func:`round_step`); ``None`` for both keeps the historical graph.
    """
    if mesh is not None:
        validate_mesh(cfg, mesh, local_solver)
    key, k_part, k_solve = jax.random.split(key, 3)

    z_seen = t if cfg.compressed else z
    z_seen = robust_seen(cfg, z_seen, live, meta, mesh)
    y, v = coordinator_edge_packed(cfg, z, z_seen, meta, prox_h, mesh)

    w, aux = run_solvers(local_solver, x, v, k_solve, cfg.n_agents)
    w = apply_corruption(w, corrupt)

    u = live_mask_rows(participation_mask(k_part, cfg), live)
    u, _ok = increment_guard(cfg, w, u, meta)
    x_new, z_new = agent_edge_packed(cfg, u, w, x, z, y, z_seen, prox_h,
                                     mesh)

    if cfg.compressed:
        q = compress_lib.compress_increment_packed(z_new - t, meta, cfg)
        t_new = t + u.astype(q.dtype).reshape(-1, 1) * q
    else:
        t_new = z_new

    return RoundResult(x=x_new, z=z_new, t=t_new, y=y, next_key=key,
                       u=u, aux=aux)


def count_primitives(jaxpr, names: Sequence[str]) -> Dict[str, int]:
    """Occurrences of each primitive in ``jaxpr`` (a ``ClosedJaxpr``'s
    ``.jaxpr`` or any inner jaxpr), descending into sub-jaxprs (scan /
    cond / pjit bodies).  The layout contract's measurement tool: tests,
    the engine benchmark, and the CI smoke all assert the packed pallas
    round's state path through it (zero ``concatenate`` / ``gather``)."""
    counts = {n: 0 for n in names}
    _count_into(jaxpr, counts)
    return counts


def _count_into(jaxpr, counts) -> None:
    for eqn in jaxpr.eqns:
        if eqn.primitive.name in counts:
            counts[eqn.primitive.name] += 1
        for v in eqn.params.values():
            for vv in (v if isinstance(v, (list, tuple)) else [v]):
                inner = getattr(vv, "jaxpr", None)
                if inner is not None:
                    _count_into(inner, counts)
                elif hasattr(vv, "eqns"):
                    _count_into(vv, counts)


# ---------------------------------------------------------------------------
# Heterogeneous agent groups
# ---------------------------------------------------------------------------

def _slice_agents(tree: Any, start: int, stop: int) -> Any:
    return tree_map(lambda l: l[start:stop], tree)


def run_solvers(local_solver: SolverAssignment, x: Any, v: Any,
                key: jax.Array, n_agents: int) -> Tuple[Any, Any]:
    """Dispatch the round's solver assignment on the reflected states.

    A bare :data:`LocalSolver` (or a single :class:`SolverGroup`) is
    called on the full stack with ``key`` unchanged -- bit-identical to
    the historical homogeneous path.  Multiple groups partition the
    agent axis contiguously: group ``g`` solves its slice under
    ``fold_in(key, g)`` and the per-group results are re-stitched by
    concatenation.  ``aux`` is the solver's aux unchanged when
    homogeneous, else the tuple of per-group auxes (None when every
    group returned None) -- per-group epoch counts may differ, so the
    engine cannot stack them.
    """
    if isinstance(local_solver, SolverGroup):   # bare group, not a seq
        local_solver = (local_solver,)
    if callable(local_solver):
        return local_solver(x, v, key)
    groups = tuple(local_solver)
    sizes = [g.size for g in groups]
    if sum(sizes) != n_agents:
        raise ValueError(f"solver groups cover {sum(sizes)} agents, "
                         f"round has n_agents={n_agents}")
    if len(groups) == 1:
        return groups[0].solver(x, v, key)
    ws, auxs = [], []
    start = 0
    for g_idx, grp in enumerate(groups):
        stop = start + grp.size
        w_g, aux_g = grp.solver(_slice_agents(x, start, stop),
                                _slice_agents(v, start, stop),
                                jax.random.fold_in(key, g_idx))
        ws.append(w_g)
        auxs.append(aux_g)
        start = stop
    w = tree_map(lambda *ls: jnp.concatenate(ls, axis=0), *ws)
    aux = None if all(a is None for a in auxs) else tuple(auxs)
    return w, aux


# ---------------------------------------------------------------------------
# Compressed z-exchange: the compressor itself lives in the
# repro.fed.compress registry; `compress_increment` is re-exported above
# so front ends keep one import site.
# ---------------------------------------------------------------------------
# One round
# ---------------------------------------------------------------------------

def round_step(cfg: RoundConfig, x: Any, z: Any, t: Any, key: jax.Array,
               local_solver: SolverAssignment,
               prox_h: ProxH = None, mesh=None,
               corrupt=None, live=None) -> RoundResult:
    """One Fed-PLT round on agent-stacked pytrees.

    ``t`` is the coordinator's copy of ``z`` (pass ``z`` itself when the
    exchange is uncompressed).  Consumes ``key`` exactly like the
    historical implementations: split 3 ways (carry, participation,
    solver).  ``local_solver`` is one solver for every agent or a
    sequence of :class:`SolverGroup` partitioning the agent axis (see
    :func:`run_solvers`).

    ``corrupt`` / ``live`` are broker-realized fault rows: ``corrupt``
    multiplies flagged agents' solver output (:func:`apply_corruption`,
    screened by :func:`increment_guard` when enabled), ``live`` drops
    evicted agents from both the participation draw and the coordinator
    mean (:func:`survivor_mean_input`).  ``None`` for both keeps the
    historical graph bitwise.
    """
    if mesh is not None:
        validate_mesh(cfg, mesh, local_solver)
    key, k_part, k_solve = jax.random.split(key, 3)

    # -- coordinator edge: prox of the mean of the *transmitted* copies
    # when the exchange is compressed (t_i), else the exact z_i (Lemma
    # 6), fused with the reflection; evictions rescale the input so the
    # mean runs over survivors only, and a robust aggregator replaces
    # the mean with its statistic of the live rows --------------------
    z_seen = t if cfg.compressed else z
    z_seen = robust_seen(cfg, z_seen, live, mesh=mesh)
    y, v = coordinator_edge(cfg, z, z_seen, prox_h, mesh)

    # -- agents: warm-started local training on the reflected states ----
    w, aux = run_solvers(local_solver, x, v, k_solve, cfg.n_agents)
    w = apply_corruption(w, corrupt)

    # -- agent edge: Krasnosel'skii z-update + partial participation ----
    u = live_mask_rows(participation_mask(k_part, cfg), live)
    u, _ok = increment_guard(cfg, w, u)
    x_new, z_new = agent_edge(cfg, u, w, x, z, y, z_seen, prox_h, mesh)

    # -- compressed uplink: t advances by the transmitted increment ------
    if cfg.compressed:
        q = compress_increment(tree_map(jnp.subtract, z_new, t), cfg)
        # arithmetic (u*q) masking, not jnp.where: an inactive agent's
        # increment is computed from its own finite old state so there is
        # no NaN hazard here, and the historical `t + u*q` lets XLA
        # contract the int8 dequant-multiply + add into one FMA --
        # keeping compressed trajectories bit-identical to pre-refactor
        t_new = tree_map(
            lambda tl, ql: tl + u.astype(ql.dtype).reshape(
                (-1,) + (1,) * (ql.ndim - 1)) * ql,
            t, q)
    else:
        t_new = z_new

    return RoundResult(x=x_new, z=z_new, t=t_new, y=y, next_key=key, u=u,
                       aux=aux)


# ---------------------------------------------------------------------------
# Default local solver: core/solvers.py generalized to stacked pytrees
# ---------------------------------------------------------------------------

def make_local_solver(solver_cfg, fgrad, rho: float, mu: float = 0.0,
                      L: float = 0.0, *, use_pallas: bool = False,
                      has_aux: bool = False) -> LocalSolver:
    """Build a :data:`LocalSolver` from a stacked gradient oracle.

    ``fgrad(w_stack, key)`` returns the per-agent gradient pytree (leaves
    (N, ...)); with ``has_aux`` it returns ``(grads, aux)``.  Solver
    choice, step size, DP noise, and per-agent clipping all come from
    ``solver_cfg`` (a :class:`repro.core.solvers.SolverConfig`);
    dispatch goes through the :mod:`repro.fed.solvers` registry, so a
    solver registered there is reachable by name from every front end.
    The fused ``fedplt_update`` Pallas kernel is used for the inner step
    when ``use_pallas`` and the step size is static.
    """
    from repro.fed.solvers import make_local_solver as _make

    return _make(solver_cfg, fgrad, rho, mu, L, use_pallas=use_pallas,
                 has_aux=has_aux)
