"""The single Fed-PLT round engine: Algorithm 1 on agent-stacked pytrees.

Every leaf of the state pytrees carries a leading agent axis ``(N, ...)``;
a dense ``(N, n)`` array (the convex experiments in :mod:`repro.core`) is
just the single-leaf case, a stacked model parameter pytree
(:mod:`repro.fed.runtime`) the general one.  One round:

  coordinator:  y = prox_{rho h / N}( mean_i z_i )            (Lemma 6)
  agents i active (u_i ~ Ber(p_i)):
      v_i   = 2 y - z_i                                       (reflection)
      x_i   <- N_e epochs of the local solver on
               d_i(w) = f_i(w) + ||w - v_i||^2/(2 rho),  warm start x_i
      z_i   <- z_i + 2 * damping * (x_i - y)
  agents inactive: state unchanged.

The local solver is pluggable (:data:`LocalSolver`, built by name from
the :mod:`repro.fed.solvers` registry): adapters supply the gradient
oracle / per-agent vmap; the *round topology* -- coordinator prox,
reflection, participation masking, and the compressed z-exchange --
lives only here, so ``core/fedplt.py`` and ``fed/runtime.py`` cannot
diverge again.  Agents need not be uniform: ``round_step`` accepts a
partition of the agent axis into :class:`SolverGroup` slices (each with
its own solver/epochs/step size, see :func:`run_solvers`) and
``participation`` may be a per-agent vector -- the paper's "agents
choose their local training solver" and per-agent Prop. 4 accounting,
at engine level.

Compressed uplink (beyond-paper): agents transmit the compressed
increment ``C(z_new - t)`` and the coordinator's copy ``t`` advances by
exactly what was transmitted.  ``t`` therefore lags ``z`` by the
never-transmitted residual, which *is* error feedback (an explicit error
memory would double-count the residual and diverge).

Round-edge backends: ``RoundConfig.engine_backend`` selects how the
round's memory-bound coordinator edges execute -- ``"xla"`` (default)
is the historical per-leaf ``tree_map`` path; ``"pallas"`` packs the
agent stack into one ``(N, M_total)`` buffer and runs the two fused
:mod:`repro.kernels.round_edge` kernels (mean + prox + reflection;
z-update + participation selects), collapsing the coordinator edge to
TWO launches.  Parity contract: the kernels are bit-identical to the
per-leaf edge formulas as materialized values (asserted against the
ref oracles across the whole prox table), and cross-backend
trajectories agree to float32 rounding.  Exact bitwise equality of
whole jitted rounds is NOT promised: XLA refolds the coordinator
chain's constants per consumer/program/shape -- the xla backend's own
``run()`` and ``step()`` already differ bitwise at some shapes -- so
the kernels mirror the unfused path's typical compilation (chain
duplication per consumer, pinned prox scales in ``core/prox.py``),
which makes most full-round configurations agree bit-for-bit in
practice.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp

from repro.fed import compress as compress_lib
from repro.fed.compress import compress_increment, get_compressor

tree_map = jax.tree_util.tree_map

# round-edge execution backends: "xla" = per-leaf tree_map ops;
# "pallas" = the fused repro.kernels.round_edge kernels on the packed
# (N, M_total) buffer -- ONE launch per edge (parity contract above)
ENGINE_BACKENDS = ("xla", "pallas")

# (x_stack, v_stack, key) -> (w_stack, aux); aux may be None.  The solver
# must be warm-started at x_stack (Section V-C1) -- the engine passes the
# previous local states as the first argument.
LocalSolver = Callable[[Any, Any, jax.Array], Tuple[Any, Any]]


class SolverGroup(NamedTuple):
    """A contiguous slice of the agent axis running its own local solver.

    ``round_step`` accepts a sequence of groups instead of one
    :data:`LocalSolver`: the stacked pytrees are partitioned along the
    agent axis (group g owns agents ``[sum(sizes[:g]), sum(sizes[:g+1]))``),
    each group's solver runs on its slice (vmapped within the group by
    whoever built it), and the results are re-stitched by concatenation.
    A single group is dispatched exactly like a bare solver (same key,
    no slicing), so a homogeneous "grouped" round is bit-identical to
    the historical path.
    """

    size: int
    solver: LocalSolver


# A round's solver assignment: one solver for every agent, or a
# partition of the agent axis into heterogeneous groups.
SolverAssignment = Union[LocalSolver, Sequence[SolverGroup]]

# Leaf-wise proximal operator of the coordinator regularizer h:
# (zbar, rho_eff) -> y, applied to the agent-mean tree with
# rho_eff = rho / N (Lemma 6).  None means h = 0 (identity).
ProxH = Optional[Callable[[Any, float], Any]]


@dataclasses.dataclass(frozen=True)
class RoundConfig:
    """Round-topology knobs shared by every Fed-PLT front end."""

    n_agents: int
    rho: float = 1.0
    # p: one scalar shared by every agent, or an (n_agents,)-tuple of
    # per-agent probabilities (Prop. 4 / heterogeneous deployments)
    participation: Union[float, Tuple[float, ...]] = 1.0
    # Krasnosel'skii relaxation: z <- z + 2*damping*(x - y).  damping = 1
    # is the paper's PRS; damping = 1/2 is Douglas-Rachford -- needed to
    # stabilize aggressively compressed exchanges.
    damping: float = 1.0
    # compressor name in the repro.fed.compress registry
    # (none | topk | int8 | adaptive_topk | anything registered)
    compression: str = "none"
    compress_ratio: float = 0.25      # top-k fraction kept (floor for adaptive)
    compress_energy: float = 0.95     # adaptive_topk per-agent energy target
    # "xla" = per-leaf registry compressors; "pallas" = packed agent-axis
    # buffer through the fused repro.kernels.compress kernels (one launch
    # per round, bit-identical output; non-accelerated compressors fall
    # back to the per-leaf path)
    compress_backend: str = "xla"
    # "xla" = per-leaf tree_map round edges; "pallas" = the fused
    # repro.kernels.round_edge kernels on the packed buffer (coordinator
    # prox + reflect in one launch, z-update + participation selects in
    # another; parity contract in the module docstring.  Non-elementwise
    # custom proxes and mixed-dtype trees fall back per edge)
    engine_backend: str = "xla"

    def __post_init__(self):
        get_compressor(self.compression)  # fail fast on unknown names
        if self.compress_backend not in compress_lib.COMPRESS_BACKENDS:
            raise ValueError(
                f"unknown compress backend {self.compress_backend!r}; "
                f"known: {', '.join(compress_lib.COMPRESS_BACKENDS)}")
        if self.engine_backend not in ENGINE_BACKENDS:
            raise ValueError(
                f"unknown engine backend {self.engine_backend!r}; "
                f"known: {', '.join(ENGINE_BACKENDS)}")
        p = self.participation
        if isinstance(p, (str, bytes)):
            # a string is a __len__-bearing sequence of characters:
            # without this guard participation="0.5" would silently
            # tuple-ize into per-character draws (or crash later)
            raise ValueError(
                f"participation must be a probability or a per-agent "
                f"sequence of probabilities, got the string {p!r}")
        if getattr(p, "ndim", None) == 0:
            # a 0-d numpy/jax scalar: ndarray types carry __len__ (it
            # raises when called), so without this it would be
            # misdiagnosed as a malformed per-agent sequence
            object.__setattr__(self, "participation", float(p))
        elif isinstance(p, (list, tuple)) or hasattr(p, "__len__"):
            try:
                p = tuple(float(x) for x in p)
            except (TypeError, ValueError):
                raise ValueError(
                    f"per-agent participation must contain numbers, "
                    f"got {self.participation!r}") from None
            object.__setattr__(self, "participation", p)
            if len(p) != self.n_agents:
                raise ValueError(
                    f"per-agent participation has {len(p)} entries for "
                    f"n_agents={self.n_agents}")

    @property
    def compressed(self) -> bool:
        return self.compression != "none"


class RoundResult(NamedTuple):
    x: Any               # pytree, leaves (N, ...)
    z: Any               # pytree, leaves (N, ...)
    t: Any               # coordinator's copy of z (== z when uncompressed)
    y: Any               # pytree, coordinator model (no agent axis)
    next_key: jax.Array  # carried PRNG state
    u: jnp.ndarray       # (N,) participation draw of this round
    aux: Any             # whatever the local solver returned


# ---------------------------------------------------------------------------
# Round pieces
# ---------------------------------------------------------------------------

def agent_mean(z: Any) -> Any:
    """Mean over the leading agent axis, leaf-wise."""
    return tree_map(lambda zl: jnp.mean(zl, axis=0), z)


def coordinator_prox(z: Any, cfg: RoundConfig, prox_h: ProxH = None) -> Any:
    """``y = prox_{rho h / N}(mean_i z_i)`` on pytrees (Lemma 6)."""
    zbar = agent_mean(z)
    if prox_h is None:
        return zbar
    rho_eff = cfg.rho / cfg.n_agents
    return tree_map(lambda zl: prox_h(zl, rho_eff), zbar)


def reflect(y: Any, z: Any) -> Any:
    """``v = 2 y - z`` with y broadcast across the agent axis."""
    return tree_map(lambda yl, zl: 2.0 * yl[None] - zl, y, z)


def participation_mask(key: jax.Array, cfg: RoundConfig) -> jnp.ndarray:
    """One Bernoulli(p_i) draw per agent, as a float (N,) vector.

    Scalar ``cfg.participation`` reproduces the historical uniform draw
    bit-for-bit; an ``(N,)`` tuple draws each agent at its own rate from
    the same key (one uniform per agent either way)."""
    p = cfg.participation
    if isinstance(p, tuple):
        p = jnp.asarray(p, jnp.float32)
    return jax.random.bernoulli(
        key, p, (cfg.n_agents,)).astype(jnp.float32)


def masked_mix(u: jnp.ndarray, new: Any, old: Any) -> Any:
    """Select ``new`` where the agent participated, ``old`` otherwise,
    leaf-wise.  ``jnp.where`` (not ``u*new + (1-u)*old``) so a diverged
    local solve (NaN/Inf) cannot leak into agents that sat the round
    out; for finite values the two are bit-identical with u in {0, 1}."""
    mask = u != 0

    def mix(nl, ol):
        return jnp.where(mask.reshape((-1,) + (1,) * (nl.ndim - 1)),
                         nl, ol)

    return tree_map(mix, new, old)


# ---------------------------------------------------------------------------
# Round edges: the coordinator-side memory-bound passes, with a fused
# packed-buffer backend
# ---------------------------------------------------------------------------

def fusible_prox(prox_h: ProxH) -> bool:
    """Whether ``prox_h`` may be traced into the fused uplink kernel:
    h = 0, or a :func:`repro.core.prox.make_prox` table entry (every one
    is elementwise and carries the ``elementwise`` tag).  Untagged
    custom callables take the XLA path."""
    return prox_h is None or getattr(prox_h, "elementwise", False)


def _uniform_stack(*trees) -> bool:
    """True when every leaf of every tree shares one (agent count,
    dtype) -- the precondition for packing them into one buffer (the
    same rule :func:`repro.fed.compress.compress_increment` uses)."""
    leaves = [l for t in trees for l in jax.tree_util.tree_leaves(t)]
    return len({(l.shape[0], jnp.result_type(l)) for l in leaves}) == 1


def coordinator_edge(cfg: RoundConfig, z: Any, z_seen: Any,
                     prox_h: ProxH = None) -> Tuple[Any, Any]:
    """The round's uplink edge: ``y = prox_{rho h/N}(mean_i z_seen_i)``
    and the reflection ``v = 2 y - z`` (``z_seen`` is the coordinator's
    lagged copy ``t`` under a compressed exchange, ``z`` itself
    otherwise).

    Under ``cfg.engine_backend == "pallas"`` (uniform stack, fusible
    prox) the leaves are packed into one ``(N, M_total)`` buffer and the
    agent-axis mean-reduce, the elementwise prox, and the reflected
    broadcast run as ONE :mod:`repro.kernels.round_edge` launch --
    ``zbar`` never materializes in HBM (parity contract: module
    docstring)."""
    if (cfg.engine_backend == "pallas" and fusible_prox(prox_h)
            and _uniform_stack(z, z_seen)):
        from repro.kernels.round_edge import ops as edge_ops

        buf_z, meta = compress_lib.pack_leaves(z)
        buf_t = (None if z_seen is z
                 else compress_lib.pack_leaves(z_seen)[0])
        y_buf, v_buf = edge_ops.round_uplink(
            buf_z, buf_t, prox=prox_h, rho_eff=cfg.rho / cfg.n_agents)
        return (compress_lib.unpack_coord(y_buf, meta),
                compress_lib.unpack_leaves(v_buf, meta))
    y = coordinator_prox(z_seen, cfg, prox_h)
    return y, reflect(y, z)


def agent_edge(cfg: RoundConfig, u: jnp.ndarray, w: Any, x: Any, z: Any,
               y: Any, z_seen: Any = None,
               prox_h: ProxH = None) -> Tuple[Any, Any]:
    """The round's downlink edge: the Krasnosel'skii update
    ``z + 2*damping*(w - y)`` and the participation selects of both
    state variables (``x`` from the solver result ``w``, ``z`` from the
    update), returning ``(x_new, z_new)``.

    Under ``cfg.engine_backend == "pallas"`` (uniform stack, fusible
    prox) both updates run as ONE fused :mod:`repro.kernels.round_edge`
    launch on the packed buffer, the mask streamed as an ``(N,)``
    vector -- ``jnp.where`` semantics preserved, so a diverged (NaN)
    local solve still cannot leak into agents that sat the round out.
    The kernel recomputes the coordinator chain from ``z_seen`` (the
    same source :func:`coordinator_edge` read) instead of consuming
    ``y``: the unfused path never materializes ``y`` between the prox
    and the z-update, and parity wants the compiler handed the same
    expression (see the kernel docstrings; contract in the module
    docstring).
    """
    if z_seen is None:
        z_seen = z
    if (cfg.engine_backend == "pallas" and fusible_prox(prox_h)
            and _uniform_stack(x, w, z, z_seen)):
        from repro.kernels.round_edge import ops as edge_ops

        x_buf, meta = compress_lib.pack_leaves(x)
        w_buf = compress_lib.pack_leaves(w)[0]
        z_buf = compress_lib.pack_leaves(z)[0]
        t_buf = (None if z_seen is z
                 else compress_lib.pack_leaves(z_seen)[0])
        xb, zb = edge_ops.round_downlink(
            x_buf, w_buf, z_buf, u, t_buf, prox=prox_h,
            rho_eff=cfg.rho / cfg.n_agents, damping=cfg.damping)
        return (compress_lib.unpack_leaves(xb, meta),
                compress_lib.unpack_leaves(zb, meta))
    x_new = masked_mix(u, w, x)
    z_upd = tree_map(
        lambda zl, wl, yl: zl + 2.0 * cfg.damping * (wl - yl[None]),
        z, w, y)
    return x_new, masked_mix(u, z_upd, z)


# ---------------------------------------------------------------------------
# Heterogeneous agent groups
# ---------------------------------------------------------------------------

def _slice_agents(tree: Any, start: int, stop: int) -> Any:
    return tree_map(lambda l: l[start:stop], tree)


def run_solvers(local_solver: SolverAssignment, x: Any, v: Any,
                key: jax.Array, n_agents: int) -> Tuple[Any, Any]:
    """Dispatch the round's solver assignment on the reflected states.

    A bare :data:`LocalSolver` (or a single :class:`SolverGroup`) is
    called on the full stack with ``key`` unchanged -- bit-identical to
    the historical homogeneous path.  Multiple groups partition the
    agent axis contiguously: group ``g`` solves its slice under
    ``fold_in(key, g)`` and the per-group results are re-stitched by
    concatenation.  ``aux`` is the solver's aux unchanged when
    homogeneous, else the tuple of per-group auxes (None when every
    group returned None) -- per-group epoch counts may differ, so the
    engine cannot stack them.
    """
    if isinstance(local_solver, SolverGroup):   # bare group, not a seq
        local_solver = (local_solver,)
    if callable(local_solver):
        return local_solver(x, v, key)
    groups = tuple(local_solver)
    sizes = [g.size for g in groups]
    if sum(sizes) != n_agents:
        raise ValueError(f"solver groups cover {sum(sizes)} agents, "
                         f"round has n_agents={n_agents}")
    if len(groups) == 1:
        return groups[0].solver(x, v, key)
    ws, auxs = [], []
    start = 0
    for g_idx, grp in enumerate(groups):
        stop = start + grp.size
        w_g, aux_g = grp.solver(_slice_agents(x, start, stop),
                                _slice_agents(v, start, stop),
                                jax.random.fold_in(key, g_idx))
        ws.append(w_g)
        auxs.append(aux_g)
        start = stop
    w = tree_map(lambda *ls: jnp.concatenate(ls, axis=0), *ws)
    aux = None if all(a is None for a in auxs) else tuple(auxs)
    return w, aux


# ---------------------------------------------------------------------------
# Compressed z-exchange: the compressor itself lives in the
# repro.fed.compress registry; `compress_increment` is re-exported above
# so front ends keep one import site.
# ---------------------------------------------------------------------------
# One round
# ---------------------------------------------------------------------------

def round_step(cfg: RoundConfig, x: Any, z: Any, t: Any, key: jax.Array,
               local_solver: SolverAssignment,
               prox_h: ProxH = None) -> RoundResult:
    """One Fed-PLT round on agent-stacked pytrees.

    ``t`` is the coordinator's copy of ``z`` (pass ``z`` itself when the
    exchange is uncompressed).  Consumes ``key`` exactly like the
    historical implementations: split 3 ways (carry, participation,
    solver).  ``local_solver`` is one solver for every agent or a
    sequence of :class:`SolverGroup` partitioning the agent axis (see
    :func:`run_solvers`).
    """
    key, k_part, k_solve = jax.random.split(key, 3)

    # -- coordinator edge: prox of the mean of the *transmitted* copies
    # when the exchange is compressed (t_i), else the exact z_i (Lemma
    # 6), fused with the reflection ------------------------------------
    z_seen = t if cfg.compressed else z
    y, v = coordinator_edge(cfg, z, z_seen, prox_h)

    # -- agents: warm-started local training on the reflected states ----
    w, aux = run_solvers(local_solver, x, v, k_solve, cfg.n_agents)

    # -- agent edge: Krasnosel'skii z-update + partial participation ----
    u = participation_mask(k_part, cfg)
    x_new, z_new = agent_edge(cfg, u, w, x, z, y, z_seen, prox_h)

    # -- compressed uplink: t advances by the transmitted increment ------
    if cfg.compressed:
        q = compress_increment(tree_map(jnp.subtract, z_new, t), cfg)
        # arithmetic (u*q) masking, not jnp.where: an inactive agent's
        # increment is computed from its own finite old state so there is
        # no NaN hazard here, and the historical `t + u*q` lets XLA
        # contract the int8 dequant-multiply + add into one FMA --
        # keeping compressed trajectories bit-identical to pre-refactor
        t_new = tree_map(
            lambda tl, ql: tl + u.astype(ql.dtype).reshape(
                (-1,) + (1,) * (ql.ndim - 1)) * ql,
            t, q)
    else:
        t_new = z_new

    return RoundResult(x=x_new, z=z_new, t=t_new, y=y, next_key=key, u=u,
                       aux=aux)


# ---------------------------------------------------------------------------
# Default local solver: core/solvers.py generalized to stacked pytrees
# ---------------------------------------------------------------------------

def make_local_solver(solver_cfg, fgrad, rho: float, mu: float = 0.0,
                      L: float = 0.0, *, use_pallas: bool = False,
                      has_aux: bool = False) -> LocalSolver:
    """Build a :data:`LocalSolver` from a stacked gradient oracle.

    ``fgrad(w_stack, key)`` returns the per-agent gradient pytree (leaves
    (N, ...)); with ``has_aux`` it returns ``(grads, aux)``.  Solver
    choice, step size, DP noise, and per-agent clipping all come from
    ``solver_cfg`` (a :class:`repro.core.solvers.SolverConfig`);
    dispatch goes through the :mod:`repro.fed.solvers` registry, so a
    solver registered there is reachable by name from every front end.
    The fused ``fedplt_update`` Pallas kernel is used for the inner step
    when ``use_pallas`` and the step size is static.
    """
    from repro.fed.solvers import make_local_solver as _make

    return _make(solver_cfg, fgrad, rho, mu, L, use_pallas=use_pallas,
                 has_aux=has_aux)
