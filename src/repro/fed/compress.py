"""Pluggable per-agent uplink compressors for the Fed-PLT z-exchange.

A compressor maps the flattened per-leaf increment ``dz`` of shape
``(N, m)`` (one row per agent) to the values actually transmitted; the
round engine (:mod:`repro.fed.engine`) advances the coordinator's lagged
copy ``t`` by exactly what was transmitted, so the never-transmitted
residual is the error-feedback memory.  Top-k / int8 scales are per
agent per leaf -- what an actual uplink would quantize.

New compressors plug in through :func:`register_compressor`::

    @register_compressor("sign")
    def compress_sign(dz, cfg):
        scale = jnp.mean(jnp.abs(dz), axis=-1, keepdims=True)
        return jnp.sign(dz) * scale

and are immediately reachable from every front end (``FedSpec``,
``FedPLTConfig``, ``FedConfig``, the train CLI) by name -- the engine
dispatches through this registry, never through hard-coded branches.

The registered function receives the :class:`repro.fed.engine.RoundConfig`
(duck-typed: it only reads ``compress_ratio`` / ``compress_energy``) and
must preserve shape and dtype.

Backends: the registry functions are the XLA reference path.  With
``cfg.compress_backend == "pallas"`` the accelerated compressors
(:data:`PALLAS_COMPRESSORS`) instead run the fused
:mod:`repro.kernels.compress` kernels, and ``compress_increment`` packs
ALL pytree leaves into one ``(N, M_total)`` buffer
(:func:`pack_leaves`) so the whole round's uplink is ONE kernel launch
with segment-aware per-(agent, leaf) scales -- bit-identical to the
per-leaf XLA path (asserted in tests).  Compressors without a kernel
(custom registry entries, ``none``) fall back to the per-leaf XLA path
under either backend.

``"auto"`` (the :class:`repro.fed.api.CompressionSpec` default) picks
per call from the committed BENCH_compress.json evidence
(:func:`resolve_backend`): the fused kernel always wins for
``adaptive_topk`` (it replaces two XLA sorts per leaf with one counting
pass), always loses for static ``topk`` on this container (XLA's
``top_k`` beats the full sort), and pays off for ``int8`` only on wide
buffers where the scale reduction amortizes the launch.  Both backends
are bit-identical, so auto-dispatch is a pure scheduling choice --
trajectories do not depend on it.
"""

from __future__ import annotations

from typing import Any, Callable, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

# (dz_rows (N, m), round_cfg) -> transmitted rows (N, m)
CompressFn = Callable[[jnp.ndarray, Any], jnp.ndarray]

_REGISTRY: Dict[str, CompressFn] = {}

COMPRESS_BACKENDS = ("auto", "xla", "pallas")
# registry names with a fused kernel implementation
PALLAS_COMPRESSORS = frozenset({"topk", "adaptive_topk", "int8"})

# column alignment of the packed buffer (TPU lane width)
_LANE = 128

# auto-dispatch: int8's fused kernel only amortizes its launch on wide
# buffers (BENCH_compress.json: 0.29x at m=256, 1.1-1.3x at m >= 65536)
_AUTO_INT8_MIN_COLS = 16384


def register_compressor(name: str) -> Callable[[CompressFn], CompressFn]:
    """Decorator registering a per-agent row compressor under ``name``."""

    def deco(fn: CompressFn) -> CompressFn:
        _REGISTRY[name] = fn
        return fn

    return deco


def get_compressor(name: str) -> CompressFn:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown compressor {name!r}; registered: "
            f"{', '.join(available_compressors())}") from None


def available_compressors() -> list[str]:
    return sorted(_REGISTRY)


def _backend_of(cfg) -> str:
    backend = getattr(cfg, "compress_backend", "xla")
    if backend not in COMPRESS_BACKENDS:
        raise ValueError(f"unknown compress backend {backend!r}; known: "
                         f"{', '.join(COMPRESS_BACKENDS)}")
    return backend


def resolve_backend(cfg, m_total=None) -> str:
    """Resolve ``cfg.compress_backend`` to a concrete ``"xla"`` /
    ``"pallas"`` for this ``(n_agents, m_total, compressor)`` case.

    Explicit backends pass through.  ``"auto"`` encodes the committed
    BENCH_compress.json evidence: ``adaptive_topk`` always takes the
    fused kernel (4-9x: one counting pass vs two XLA sorts per leaf),
    static ``topk`` always takes XLA (``lax.top_k`` beats a full sort at
    every measured shape), and ``int8`` takes the kernel only at
    ``m_total >= _AUTO_INT8_MIN_COLS`` where the per-(agent, segment)
    scale reduction amortizes the launch.  Both backends are
    bit-identical, so this is purely a scheduling decision.
    """
    backend = _backend_of(cfg)
    if backend != "auto":
        return backend
    name = cfg.compression
    if name not in PALLAS_COMPRESSORS:
        return "xla"          # no kernel: only the registry path exists
    if name == "adaptive_topk":
        return "pallas"
    if name == "int8" and m_total is not None \
            and m_total >= _AUTO_INT8_MIN_COLS:
        return "pallas"
    return "xla"


def _use_pallas(cfg, m_total=None) -> bool:
    return (resolve_backend(cfg, m_total) == "pallas"
            and cfg.compression in PALLAS_COMPRESSORS)


def _pallas_rows(dz: jnp.ndarray, cfg, segments=None) -> jnp.ndarray:
    """The fused-kernel compressor on an (N, m) buffer (optionally with
    per-leaf column segments)."""
    from repro.kernels.compress import ops

    name = cfg.compression
    if name == "int8":
        return ops.int8_quantize(dz, segments=segments)
    return ops.rank_select(dz, segments=segments, mode=name,
                           ratio=cfg.compress_ratio,
                           energy=cfg.compress_energy)


def compress_rows(dz: jnp.ndarray, cfg) -> jnp.ndarray:
    """Dispatch the configured compressor on a flattened (N, m) increment."""
    if _use_pallas(cfg, dz.shape[1]):
        return _pallas_rows(dz, cfg)
    return get_compressor(cfg.compression)(dz, cfg)


# ---------------------------------------------------------------------------
# Leaf packing: the whole pytree as one (N, M_total) buffer
# ---------------------------------------------------------------------------

class PackedMeta(NamedTuple):
    """Static layout of a packed agent-stacked pytree: everything needed
    to invert :func:`pack_leaves` and to hand the kernels their static
    per-leaf column segments.  Hashable (tuples + a treedef), so it can
    ride through ``jit`` closures and static arguments unchanged -- the
    packed-resident engine keeps ONE meta for the whole run."""

    treedef: Any
    shapes: Tuple[Tuple[int, ...], ...]      # per-leaf (N, ...) shapes
    segments: Tuple[Tuple[int, int], ...]    # per-leaf (start, stop) cols
    width: int                               # padded column count

    @property
    def m_total(self) -> int:
        """Data columns (excluding lane padding) -- the auto-dispatch
        shape signal."""
        return self.segments[-1][1]


def packed_meta(tree: Any) -> PackedMeta:
    """The :class:`PackedMeta` that :func:`pack_leaves` would record for
    ``tree`` -- pure shape arithmetic, so ``tree`` may hold
    ``ShapeDtypeStruct`` leaves (e.g. from ``jax.eval_shape``): the
    packed-resident front ends derive their static layout without ever
    materializing a tree-form state."""
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    if not leaves:
        raise ValueError("packed_meta: empty pytree")
    n = leaves[0].shape[0]
    dtype = jnp.result_type(leaves[0])
    for l in leaves:
        if l.shape[0] != n or jnp.result_type(l) != dtype:
            raise ValueError(
                "pack_leaves needs a uniform agent axis and dtype, got "
                f"{[(tuple(x.shape), str(jnp.result_type(x))) for x in leaves]}")
    segments, start = [], 0
    for l in leaves:
        m = 1
        for d in l.shape[1:]:
            m *= d
        segments.append((start, start + m))
        start += m
    # single leaf: the flattened leaf IS the buffer, no lane padding --
    # the kernel wrappers pad to their block internally, and skipping
    # the pad keeps the dense (N, n) front end's packed form identical
    # to its tree form (zero-copy residency)
    width = start if len(leaves) == 1 else -(-start // _LANE) * _LANE
    return PackedMeta(treedef=treedef,
                      shapes=tuple(tuple(l.shape) for l in leaves),
                      segments=tuple(segments), width=width)


def pack_leaves(tree: Any) -> Tuple[jnp.ndarray, PackedMeta]:
    """Flatten every ``(N, ...)`` leaf and concatenate along columns into
    one ``(N, M_total)`` buffer (padded to the TPU lane width), recording
    per-leaf segment offsets.  All leaves must share the agent axis and
    dtype (the uplink buffer is one wire format).

    Fast path: a single-leaf tree (the dense front end) skips the copy
    chain entirely -- the flattened leaf is returned as the buffer, a
    pure reshape (and the identity for an already-2D array)."""
    meta = packed_meta(tree)
    leaves = jax.tree_util.tree_leaves(tree)
    n = leaves[0].shape[0]
    flat = [l.reshape(n, -1) for l in leaves]
    if len(flat) == 1:
        return flat[0], meta
    # write each leaf into a preallocated buffer: XLA:CPU compiles
    # a many-operand concatenate as a chain of whole-buffer copies
    # (O(leaves x M_total) traffic -- ~20x slower at a 200-leaf
    # engine-scale tree), while consecutive dynamic_update_slice
    # ops alias in place under jit
    buf = jnp.zeros((n, meta.width), leaves[0].dtype)
    for f, (s0, _) in zip(flat, meta.segments):
        buf = jax.lax.dynamic_update_slice(buf, f, (0, s0))
    return buf, meta


def unpack_leaves(buf: jnp.ndarray, meta: PackedMeta) -> Any:
    """Invert :func:`pack_leaves` (padding columns are dropped).

    The agent count is taken from ``buf``, not ``meta``, so a row-sliced
    buffer (a heterogeneous solver group's agents) unpacks with the same
    meta."""
    n = buf.shape[0]
    leaves = [buf[:, s0:s1].reshape((n,) + shape[1:])
              for (s0, s1), shape in zip(meta.segments, meta.shapes)]
    return jax.tree_util.tree_unflatten(meta.treedef, leaves)


def pack_coord(tree: Any, meta: PackedMeta) -> jnp.ndarray:
    """Pack a COORDINATOR pytree (the agent-axis-free ``y``, leaves
    shaped like the agent leaves minus the leading axis) into a
    ``(1, width)`` buffer aligned with ``meta``'s column segments --
    the form the fused round-edge kernels stream ``y`` in."""
    leaves = jax.tree_util.tree_leaves(tree)
    if len(leaves) != len(meta.shapes):
        raise ValueError(f"coordinator tree has {len(leaves)} leaves, "
                         f"meta has {len(meta.shapes)}")
    flat = []
    for leaf, shape in zip(leaves, meta.shapes):
        if tuple(leaf.shape) != tuple(shape[1:]):
            raise ValueError(f"coordinator leaf {tuple(leaf.shape)} does "
                             f"not match agent leaf {tuple(shape)}")
        flat.append(leaf.reshape(1, -1))
    if len(flat) == 1 and meta.width == flat[0].shape[1]:
        return flat[0]
    buf = jnp.zeros((1, meta.width), flat[0].dtype)
    for f, (s0, _) in zip(flat, meta.segments):
        buf = jax.lax.dynamic_update_slice(buf, f, (0, s0))
    return buf


def unpack_coord(buf: jnp.ndarray, meta: PackedMeta) -> Any:
    """Invert :func:`pack_coord`: a ``(1, width)`` coordinator buffer
    back to the agent-axis-free pytree."""
    leaves = [buf[:, s0:s1].reshape(shape[1:])
              for (s0, s1), shape in zip(meta.segments, meta.shapes)]
    return jax.tree_util.tree_unflatten(meta.treedef, leaves)


def _tree_m_total(leaves) -> int:
    total = 0
    for l in leaves:
        m = 1
        for d in l.shape[1:]:
            m *= d
        total += m
    return total


def compress_increment(dz: Any, cfg) -> Any:
    """Apply the configured compressor to a stacked increment pytree
    (top-k / int8 scales are per agent per leaf, which is what an actual
    uplink would quantize).

    XLA backend: leaf-wise, each leaf flattened to (N, m) -- one sort
    launch per leaf.  Pallas backend (accelerated compressors only):
    leaves are packed into one (N, M_total) buffer and the fused
    segment-aware kernel runs ONCE per round; bit-identical output."""
    leaves = jax.tree_util.tree_leaves(dz)
    if _use_pallas(cfg, _tree_m_total(leaves)):
        uniform = len({(l.shape[0], jnp.result_type(l)) for l in leaves}) == 1
        if uniform:
            buf, meta = pack_leaves(dz)
            return unpack_leaves(_pallas_rows(buf, cfg, meta.segments),
                                 meta)
        # mixed-dtype trees have no single wire format: per-leaf kernels
        return jax.tree_util.tree_map(
            lambda l: _pallas_rows(l.reshape(l.shape[0], -1),
                                   cfg).reshape(l.shape), dz)
    fn = get_compressor(cfg.compression)

    def leaf(l):
        return fn(l.reshape(l.shape[0], -1), cfg).reshape(l.shape)

    return jax.tree_util.tree_map(leaf, dz)


def compress_increment_packed(dz_buf: jnp.ndarray, meta: PackedMeta,
                              cfg) -> jnp.ndarray:
    """The configured compressor on a RESIDENT packed ``(N, width)``
    increment -- the packed-resident engine's uplink: no pack/unpack at
    all.

    Pallas-resolved backends run the fused segment-aware kernel directly
    on the buffer.  The XLA path runs the registry function per column
    segment (each segment is exactly one flattened leaf, so scales stay
    per (agent, leaf) and the output is bit-identical to the tree path)
    and writes the results into a zero buffer -- out-of-segment padding
    columns therefore come back zero under BOTH backends (the kernels
    zero them too), which keeps the coordinator copy ``t``'s padding
    static across rounds."""
    if _use_pallas(cfg, meta.m_total):
        return _pallas_rows(dz_buf, cfg, meta.segments)
    fn = get_compressor(cfg.compression)
    if len(meta.segments) == 1 and meta.width == meta.m_total:
        return fn(dz_buf, cfg)     # single leaf: the buffer IS the leaf
    out = jnp.zeros_like(dz_buf)
    for s0, s1 in meta.segments:
        out = jax.lax.dynamic_update_slice(
            out, fn(jax.lax.slice_in_dim(dz_buf, s0, s1, axis=1), cfg),
            (0, s0))
    return out


# ---------------------------------------------------------------------------
# Built-in compressors
# ---------------------------------------------------------------------------

@register_compressor("none")
def compress_none(dz: jnp.ndarray, cfg) -> jnp.ndarray:
    """Exact exchange: transmit the full-precision increment."""
    del cfg
    return dz


@register_compressor("topk")
def compress_topk(dz: jnp.ndarray, cfg) -> jnp.ndarray:
    """Keep the ``compress_ratio`` fraction of largest-magnitude entries
    per agent (same k for every agent).

    Exactly k entries survive: selection is by top-k *index* (ties
    broken by position), not by thresholding ``|row| >= |row|_(k)`` --
    a threshold transmits every tied coordinate (an all-constant row
    would transmit ALL of them), silently blowing the bandwidth budget
    the ratio promises."""
    k = max(1, int(cfg.compress_ratio * dz.shape[-1]))

    def topk_row(row):
        _, idx = jax.lax.top_k(jnp.abs(row), k)
        return jnp.zeros_like(row).at[idx].set(row[idx])

    return jax.vmap(topk_row)(dz)


@register_compressor("int8")
def compress_int8(dz: jnp.ndarray, cfg) -> jnp.ndarray:
    """Symmetric per-agent int8 quantization (scale = max|dz| / 127)."""
    del cfg
    scale = jnp.max(jnp.abs(dz), axis=-1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.round(dz / scale).astype(jnp.int8)
    return q.astype(dz.dtype) * scale


@register_compressor("adaptive_topk")
def compress_adaptive_topk(dz: jnp.ndarray, cfg) -> jnp.ndarray:
    """Per-agent ADAPTIVE-ratio top-k (ROADMAP follow-up): each agent
    keeps the smallest k_i whose top coordinates capture a
    ``compress_energy`` fraction of its increment's l2 energy, floored at
    ``compress_ratio * m``.  Agents with concentrated increments (a few
    hot coordinates -- e.g. embedding rows they actually touched)
    transmit far fewer values than agents with diffuse updates, instead
    of everyone paying one global worst-case k."""
    m = dz.shape[-1]
    k_floor = max(1, int(cfg.compress_ratio * m))

    def row_fn(row):
        energy = jnp.square(jnp.abs(row))
        desc = jnp.sort(energy)[::-1]
        cum = jnp.cumsum(desc)
        total = jnp.maximum(cum[-1], 1e-30)
        # smallest prefix capturing the energy target, never below the floor
        k = jnp.sum(cum < cfg.compress_energy * total) + 1
        k = jnp.clip(k, k_floor, m)
        # exactly-k selection by magnitude *rank* (stable argsort breaks
        # ties by position); k is traced here, so jax.lax.top_k (static
        # k only) is not an option and thresholding would transmit every
        # tied coordinate
        order = jnp.argsort(-jnp.abs(row))
        rank = jnp.zeros(m, jnp.int32).at[order].set(
            jnp.arange(m, dtype=jnp.int32))
        return jnp.where(rank < k, row, 0.0)

    return jax.vmap(row_fn)(dz)
