"""Sharding rules: parameter/activation PartitionSpecs for the meshes.

Scheme (GSPMD logical axes):
  * tensor-parallel dim (heads / ffn-hidden / experts / vocab) -> 'model'
  * the other weight dim -> 'data' (FSDP) in standard mode, or replicated
    within an agent slice in fed mode (the agent axis owns 'data'/'pod')
  * batch -> 'data' (+ 'pod'); fed mode: leading agent axis -> agent_axis
  * stacked-unit leading dim (scan over layers) -> replicated

Rules are path-based so they survive arbitrary pytree nesting.
"""

from __future__ import annotations

from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


# name fragments that identify the tensor-parallel dim of each weight
_RULES = [
    # (leaf-name, spec WITHOUT the stacked-unit axis), fsdp axis slot = 'F'
    # embed: vocab on 'model' only -- FSDP on d would make the token
    # gather d-sharded and GSPMD fully rematerializes it (grok iter5)
    ("embed", ("model", None)),
    ("lm_head", ("F", "model")),
    ("wq", ("F", "model")),
    ("wk", ("F", "model")),
    ("wv", ("F", "model")),
    ("wo", ("model", "F")),
    ("wi", ("F", "model")),
    ("router", ("F", None)),
    ("in_proj", ("F", "model")),
    ("conv_w", (None, "model")),
    ("conv_b", ("model",)),
    ("x_proj", ("model", None)),
    ("dt_proj", (None, "model")),
    ("dt_bias", ("model",)),
    ("A_log", ("model", None)),
    ("D", ("model",)),
    ("out_proj", ("model", "F")),
    ("w_branch1", ("F", "model")),
    ("w_branch2", ("F", "model")),
    ("w_a", (None, "model")),
    ("w_x", (None, "model")),
    ("lam", ("model",)),
    ("w_out", ("model", "F")),
]
_EXPERT_PREFIX = "experts"      # adds a leading 'model' expert axis


def _axis_size(axis, axis_sizes):
    if axis is None:
        return 1
    if isinstance(axis, (tuple, list)):
        n = 1
        for a in axis:
            n *= axis_sizes.get(a, 1)
        return n
    return axis_sizes.get(axis, 1)


def _sanitize(base, shape, axis_sizes):
    """Drop axes whose size does not divide the dim (pjit requires exact
    divisibility for explicit in_shardings)."""
    if axis_sizes is None:
        return base
    out = []
    for dim, axis in zip(shape, base):
        out.append(axis if dim % _axis_size(axis, axis_sizes) == 0
                   else None)
    return out


def _leaf_spec(path, leaf, fsdp: Optional[str], reserve_leading: int = 0,
               axis_sizes: Optional[dict] = None):
    names = [getattr(k, "key", getattr(k, "name", "")) for k in path]
    leaf_name = names[-1] if names else ""
    expert = _EXPERT_PREFIX in names
    base = None
    for frag, spec in _RULES:
        if leaf_name == frag:
            base = list(spec)
            break
    if base is None:
        base = []  # norms & misc: replicated
    # substitute FSDP slot
    base = [fsdp if a == "F" else a for a in base]
    ndim = leaf.ndim - reserve_leading
    shape = leaf.shape[reserve_leading:]
    if expert and base:
        # expert-parallel: leading E axis takes 'model' when divisible;
        # otherwise keep plain TP on the inner dims
        e_dim = shape[max(0, ndim - len(base) - 1)]
        if axis_sizes is None or e_dim % _axis_size("model",
                                                    axis_sizes) == 0:
            base = [fsdp if a == "model" else a for a in base]
            base = ["model"] + base
    # pad leading axes (stacked units / extra nesting) with None
    while len(base) < ndim:
        base = [None] + base
    base = base[:max(ndim, 0)]
    base = _sanitize(base, shape, axis_sizes)
    return P(*base)


def param_specs(params, *, fsdp_axis: Optional[str] = "data",
                agent_axis: Optional[str] = None,
                axis_sizes: Optional[dict] = None):
    """PartitionSpec pytree for a parameter pytree.

    ``agent_axis``: if set, leaves are assumed to carry a leading stacked
    agent dimension sharded over that mesh axis (fed mode).
    ``axis_sizes``: mesh axis sizes; dims not divisible by their assigned
    axis size fall back to replicated (pjit requires divisibility).
    """
    def spec(path, leaf):
        if agent_axis is not None:
            s = _leaf_spec(path, leaf, fsdp_axis, reserve_leading=1,
                           axis_sizes=axis_sizes)
            return P(agent_axis, *s)
        return _leaf_spec(path, leaf, fsdp_axis, axis_sizes=axis_sizes)

    return jax.tree_util.tree_map_with_path(spec, params)


def fed_axes(axis_sizes) -> tuple[Optional[str], Optional[str]]:
    """``(agent_axis, fsdp_axis)`` of a mesh for fed mode -- the ONE
    axis-picking rule every placement site shares: a dedicated 'agent'
    axis (make_fed_mesh / the engine's round mesh) wins, then a
    multi-pod 'pod' axis, else the agent stack rides 'data' and FSDP is
    off (one axis cannot carry both)."""
    if "agent" in axis_sizes:
        return "agent", "data" if "data" in axis_sizes else None
    if "pod" in axis_sizes:
        return "pod", "data" if "data" in axis_sizes else None
    if "data" in axis_sizes:
        return "data", None
    return None, None


def fed_row_spec(agent_axis: Optional[str]) -> P:
    """Spec for a per-agent ``(N,)`` round row -- arrival masks,
    staleness counters, the broker's corrupt / live fault rows: one
    scalar per agent, sharded on the agent axis alone.  The one spec
    every (N,) round input shares, so fault overrides placed by callers
    agree with the engine's shard_map edges."""
    return P(agent_axis)


def fed_batch_specs(batch, agent_axis: Optional[str],
                    inner_axis: Optional[str] = None):
    """Specs for an agent-stacked batch ``(A, per_agent_batch, ...)``:
    agents over ``agent_axis``, the per-agent batch dim over
    ``inner_axis`` (only meaningful when the agent axis is dedicated)."""
    return jax.tree_util.tree_map(
        lambda l: P(agent_axis, *((inner_axis,) + (None,) * (l.ndim - 2)
                                  if l.ndim >= 2 else ())), batch)


def fed_state_specs(stacked_params, *, fsdp_axis: Optional[str] = "data",
                    agent_axis: Optional[str] = None,
                    axis_sizes: Optional[dict] = None,
                    compressed: bool = False,
                    packed: bool = False,
                    stale: bool = False):
    """PartitionSpec pytree for a :class:`repro.fed.runtime.FedState` --
    the single placement source for fed-mode state (build_trainer, the
    dry-run compiler, checkpoint restore targets).

    ``stacked_params``: the agent-stacked parameter pytree (or its
    ShapeDtypeStructs) -- x, z, and (when ``compressed``) the
    coordinator copy t all share its layout; the step counter is
    replicated.

    ``packed``: specs for the packed resident layout instead (engine
    layout contract) -- each state variable is ONE ``(A, width)``
    buffer: rows shard over ``agent_axis``, columns over ``fsdp_axis``
    when that axis exists on the mesh and the lane-aligned width
    divides its extent (per-leaf path rules do not apply to a buffer).

    ``stale``: bounded-staleness async carriers -- the pulled
    coordinator point ``y_tag`` shards like z; the per-agent
    ``staleness`` counters shard on the agent axis alone.
    """
    from repro.fed.runtime import FedState

    if packed:
        from repro.fed.compress import packed_meta

        width = packed_meta(stacked_params).width
        # columns ride the FSDP axis when the mesh has one, else the
        # tensor axis (the engine's round mesh is (agent, model)); an
        # axis must EXIST on the mesh and divide the width to qualify
        col = None
        for cand in (fsdp_axis, "model"):
            if (cand is not None and axis_sizes is not None
                    and cand in axis_sizes
                    and width % _axis_size(cand, axis_sizes) == 0):
                col = cand
                break
        pspec = P(agent_axis, col)
    else:
        pspec = param_specs(stacked_params, fsdp_axis=fsdp_axis,
                            agent_axis=agent_axis, axis_sizes=axis_sizes)
    return FedState(x=pspec, z=pspec, step=P(),
                    t=pspec if compressed else None,
                    y_tag=pspec if stale else None,
                    staleness=fed_row_spec(agent_axis) if stale else None)


def shardings(mesh: Mesh, spec_tree):
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P))


# ---------------------------------------------------------------------------
# Activation / batch specs
# ---------------------------------------------------------------------------

def batch_spec(batch_axes=("pod", "data"), agent_axis=None):
    """Spec for data batches: leading batch dim over data axes (or agent
    axis first in fed mode: (A, per_agent_batch, ...))."""
    axes = tuple(a for a in batch_axes if a)
    if agent_axis is not None:
        return lambda leaf: P(agent_axis, None) if leaf.ndim == 2 \
            else P(agent_axis, *([None] * (leaf.ndim - 1)))
    return lambda leaf: P(axes, *([None] * (leaf.ndim - 1)))


def tree_batch_specs(batch, batch_axes=("pod", "data"), agent_axis=None):
    fn = batch_spec(batch_axes, agent_axis)
    return jax.tree_util.tree_map(fn, batch)


def cache_spec_tree(cache, axis_sizes: dict, data_axes=("data",),
                    seq_axis: Optional[str] = "model"):
    """KV/recurrent cache specs with divisibility-aware placement.

    k/v/xk/xv (U, B, C, Hkv, D): batch -> data axes (if divisible),
    sequence C -> ``seq_axis`` (if divisible).  Recurrent states shard the
    channel dim over ``seq_axis``.  'pos' index arrays are replicated.
    """
    data_axes = tuple(a for a in data_axes if a)
    data_size = 1
    for a in data_axes:
        data_size *= axis_sizes.get(a, 1)
    seq_size = axis_sizes.get(seq_axis, 1) if seq_axis else 1

    def div(n, k):
        return k > 1 and n % k == 0

    def spec(path, leaf):
        names = [str(getattr(k, "key", getattr(k, "name", "")))
                 for k in path]
        name = names[-1] if names else ""
        if name == "pos" or leaf.ndim <= 1:
            return P(*([None] * leaf.ndim))
        batch_axis = data_axes if div(leaf.shape[1], data_size) else None
        if name in ("k", "v", "xk", "xv"):
            s = [None, batch_axis] + [None] * (leaf.ndim - 2)
            if seq_axis and div(leaf.shape[2], seq_size):
                s[2] = seq_axis
            return P(*s)
        if name == "h":       # (U, B, d_in[, n]) or (U, B, w)
            s = [None, batch_axis] + [None] * (leaf.ndim - 2)
            if seq_axis and div(leaf.shape[2], seq_size):
                s[2] = seq_axis
            return P(*s)
        if name == "conv":    # (U, B, K-1, C)
            s = [None, batch_axis, None] + [None] * (leaf.ndim - 3)
            if seq_axis and leaf.ndim >= 4 and div(leaf.shape[3], seq_size):
                s[3] = seq_axis
            return P(*s)
        return P(*([None] * leaf.ndim))

    return jax.tree_util.tree_map_with_path(spec, cache)
