"""Large-scale federated runtime: Fed-PLT over TPU meshes."""
