"""Large-scale federated runtime: Fed-PLT over TPU meshes.

The front door is :mod:`repro.fed.api`:

    from repro.fed import FedSpec, build_trainer
    trainer = build_trainer(problem_or_model, FedSpec(...))

re-exported here for convenience; the round engine, compressor
registry, runtime, and sharding rules live in the submodules.
"""

from repro.fed.api import (CompressionSpec, FedSpec, FedTrainer,
                           PrivacySpec, build_trainer, spec_from_args)

__all__ = ["CompressionSpec", "FedSpec", "FedTrainer", "PrivacySpec",
           "build_trainer", "spec_from_args"]
