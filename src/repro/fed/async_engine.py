"""Bounded-staleness async rounds: the deterministic in-jit model.

The engine's :func:`repro.fed.engine.round_step` is bulk-synchronous:
every agent trains against THIS round's reflection and the coordinator
averages whoever the participation draw selected.  Production
coordinators are not synchronous -- agents return increments late, and
the coordinator applies them as they arrive.  This module generalizes
the round to that regime while staying a deterministic pure function
inside jit, so async behavior is replayable and testable bit-for-bit
(the host-side realization of *when* arrivals happen lives in
:mod:`repro.fed.broker`; this module owns all the numerics).

THE STALENESS CONTRACT
======================

Two per-agent state variables ride next to ``(x, z, t)``:

* ``y_tag`` -- the coordinator point agent i's current local work was
  computed against (the ``y`` it "pulled"; leaves carry the agent axis).
* ``staleness`` -- ``(N,)`` int32: how many rounds old that work is.
  ``0`` means the agent starts fresh work this round.

One async round (:func:`async_round_step`):

1. Coordinator edge exactly as the synchronous engine: ``y_r`` and the
   fresh reflection ``v_r`` from the same
   :func:`~repro.fed.engine.coordinator_edge` (both backends, both
   layouts -- the fused uplink kernel path is unchanged).
2. Training target: fresh agents (``staleness == 0``) take ``v_r`` and
   record ``y_tag <- y_r``; stale agents keep training against their
   stale reflection ``2 * y_tag - z`` (``z_i`` is unchanged while an
   agent is stale, so this reproduces the reflection it originally
   pulled).  Every agent runs the local solver warm-started at its
   current ``x`` -- a stale agent therefore accumulates MORE local
   epochs against the same proximal target, the paper's central lever.
3. Arrival mask: the Bernoulli participation draw (same key slot as the
   synchronous round), OR-ed with the hard bound -- an agent whose work
   is ``max_staleness`` rounds old is FORCED to arrive.  A recorded
   schedule may be substituted for the draw (``arrival=``), which is
   how :mod:`repro.fed.broker` replays realized schedules bit-for-bit.
4. Arrived agents: the synchronous downlink edge applies
   ``z += 2*damping*(w - y)`` and the selects of ``(x, z)`` with the
   arrival mask streamed exactly like the participation mask (the fused
   downlink kernel path is unchanged); arrived agents whose work was
   STALE are then corrected to use their tagged coordinator point:
   ``z_i <- z_i + 2*damping*(w_i - y_tag_i)`` -- the increment is
   applied against the round it was computed in, not the current one.
5. Non-arrived agents below the bound keep their local progress
   (``x <- w``) and age (``staleness += 1``).  At ``max_staleness = 0``
   no stale work may exist, so a miss discards the round's local work
   -- which is EXACTLY the synchronous engine's inactive-agent
   semantics.

PARITY CONTRACT: with ``max_staleness = 0`` the async round is BITWISE
identical to :func:`repro.fed.engine.round_step` /
:func:`~repro.fed.engine.packed_round_step` per realization, under both
state layouts, both engine backends, and every registry compressor: the
key is split the same 3 ways, the arrival draw is the participation
draw from the same key slot (the forcing term is identically zero when
``staleness`` is identically zero), and every staleness select reduces
to an elementwise pass-through of the synchronous values (asserted in
``tests/test_async_engine.py``).

Privacy: staleness changes the *composition*, not the mechanism -- an
agent that arrived ``a_i`` times released ``(s+1)`` rounds of local
epochs per arrival (work discarded at the bound was never transmitted
and charges nothing).  :func:`effective_counts` derives those per-agent
effective round counts from a recorded arrival schedule;
``repro.fed.api.effective_privacy_report`` feeds them to the per-agent
Prop. 4 accountant.
"""

from __future__ import annotations

from typing import Any, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.fed import compress as compress_lib
from repro.fed import engine
from repro.fed.engine import (ASYNC_MODES, ProxH,  # noqa: F401  (re-export)
                              RoundConfig, SolverAssignment,
                              StalenessConfig)

tree_map = jax.tree_util.tree_map


class AsyncRoundResult(NamedTuple):
    """:class:`repro.fed.engine.RoundResult` plus the staleness carry."""

    x: Any               # pytree / buffer, agent axis leading
    z: Any
    t: Any               # coordinator's copy (== z when uncompressed)
    y: Any               # coordinator model of THIS round
    y_tag: Any           # per-agent pulled coordinator point (agent axis)
    staleness: jnp.ndarray   # (N,) int32 age of each agent's work
    next_key: jax.Array
    u: jnp.ndarray       # (N,) realized arrival mask of this round
    aux: Any


# ---------------------------------------------------------------------------
# State initialization
# ---------------------------------------------------------------------------

def init_staleness(n_agents: int) -> jnp.ndarray:
    """Round-0 counters: every agent starts fresh."""
    return jnp.zeros((n_agents,), jnp.int32)


def init_y_tag(z: Any) -> Any:
    """Round-0 tags: zeros shaped like the agent-stacked state.  The
    value is never read -- a fresh agent (staleness 0) overwrites its
    tag with this round's ``y`` before anything consumes it."""
    return tree_map(jnp.zeros_like, z)


# ---------------------------------------------------------------------------
# Round pieces
# ---------------------------------------------------------------------------

def _vec(mask: jnp.ndarray, leaf: jnp.ndarray) -> jnp.ndarray:
    """Reshape an (N,) mask for broadcast against an agent-axis leaf."""
    return mask.reshape((-1,) + (1,) * (leaf.ndim - 1))


def _select(mask: jnp.ndarray, new: Any, old: Any) -> Any:
    """``jnp.where`` on trees with an (N,) bool mask (NaN-safe select,
    same semantics as :func:`repro.fed.engine.masked_mix`)."""
    return tree_map(lambda nl, ol: jnp.where(_vec(mask, nl), nl, ol),
                    new, old)


def forced_arrivals(staleness: jnp.ndarray, max_staleness: int) \
        -> jnp.ndarray:
    """The hard bound: an agent holding work ``max_staleness`` rounds
    old must arrive.  Fresh agents (staleness 0) are never forced --
    at K = 0 a miss discards instead (the synchronous semantics), so
    the forcing term is identically zero there and the arrival mask is
    the participation draw bit-for-bit."""
    return (staleness >= max_staleness) & (staleness > 0)


def arrival_mask(key: jax.Array, cfg: RoundConfig,
                 staleness: jnp.ndarray,
                 arrival: Optional[jnp.ndarray] = None,
                 live=None) -> jnp.ndarray:
    """The round's realized (N,) float arrival mask: the Bernoulli
    participation draw (or an externally realized schedule row --
    broker runs and replays) OR-ed with the forced arrivals.  An
    eviction ``live`` row zeroes dead agents AFTER the forcing term --
    an evicted agent neither draws nor is forced in."""
    if arrival is None:
        draw = engine.participation_mask(key, cfg)
    else:
        draw = jnp.asarray(arrival, jnp.float32).reshape(-1)
    forced = forced_arrivals(staleness, cfg.staleness.max_staleness)
    return engine.live_mask_rows(
        jnp.maximum(draw, forced.astype(jnp.float32)), live)


def _advance_staleness(staleness: jnp.ndarray, u: jnp.ndarray,
                       max_staleness: int, live=None) -> jnp.ndarray:
    """Arrivals reset to 0; pending work below the bound ages by one;
    a miss AT the bound (only reachable at K = 0, where the bound
    forces every stale agent in) stays -- its work was discarded.
    Evicted agents (``live`` row 0) are pinned at 0: their pending work
    is abandoned, and a later rejoin starts them fresh."""
    aged = jnp.where(staleness < max_staleness, staleness + 1, staleness)
    out = jnp.where(u != 0, jnp.zeros_like(staleness), aged)
    if live is not None:
        out = jnp.where(jnp.asarray(live).reshape(-1) != 0, out,
                        jnp.zeros_like(out))
    return out


# ---------------------------------------------------------------------------
# One async round, tree layout
# ---------------------------------------------------------------------------

def async_round_step(cfg: RoundConfig, x: Any, z: Any, t: Any,
                     y_tag: Any, staleness: jnp.ndarray, key: jax.Array,
                     local_solver: SolverAssignment,
                     prox_h: ProxH = None,
                     arrival: Optional[jnp.ndarray] = None,
                     mesh=None, corrupt=None, live=None) -> AsyncRoundResult:
    """One bounded-staleness round on agent-stacked pytrees (module
    contract above).  Mirrors :func:`repro.fed.engine.round_step`'s key
    schedule and edge formulas exactly; ``arrival`` optionally replaces
    the Bernoulli draw with a realized schedule row (broker replay).
    With a ``mesh`` the edges run under ``shard_map`` and every async
    carrier (``y_tag``, ``staleness``, the arrival rows) shards on the
    agent axis with the state; the staleness selects between the edges
    are per-row elementwise, so GSPMD shards them transparently (mesh
    contract in :mod:`repro.fed.engine`).

    ``corrupt`` / ``live`` are broker-realized fault rows (see
    :func:`repro.fed.engine.round_step`): corrupted increments are
    screened by the guard into non-arrivals AND excluded from the keep
    branch (poisoned local progress is discarded, not carried); evicted
    agents leave the coordinator mean, the arrival draw, and the keep
    branch until a rejoin."""
    if mesh is not None:
        engine.validate_mesh(cfg, mesh, local_solver)
    key, k_part, k_solve = jax.random.split(key, 3)

    # -- coordinator edge: identical to the synchronous round (with the
    # survivor rescale when agents were evicted, and the robust
    # aggregate when one is configured) ---------------------------------
    z_seen = t if cfg.compressed else z
    z_seen = engine.robust_seen(cfg, z_seen, live, mesh=mesh)
    y, v_fresh = engine.coordinator_edge(cfg, z, z_seen, prox_h, mesh)

    # -- training targets: fresh agents pull this round's reflection,
    # stale agents reproduce the one they pulled (z_i unchanged while
    # stale, so 2*y_tag - z IS that reflection) -------------------------
    fresh = staleness == 0
    v_stale = tree_map(lambda ytl, zl: 2.0 * ytl - zl, y_tag, z)
    v_eff = _select(fresh, v_fresh, v_stale)
    y_tag_new = tree_map(
        lambda yl, ytl: jnp.where(_vec(fresh, ytl), yl[None], ytl),
        y, y_tag)

    # -- every agent trains, warm-started at its current x --------------
    w, aux = engine.run_solvers(local_solver, x, v_eff, k_solve,
                                cfg.n_agents)
    w = engine.apply_corruption(w, corrupt)

    # -- arrivals: the participation draw + the hard staleness bound,
    # screened by the increment guard (a corrupt row is a non-arrival) --
    u = arrival_mask(k_part, cfg, staleness, arrival, live)
    u, ok = engine.increment_guard(cfg, w, u)

    # -- synchronous downlink edge with the arrival mask streamed like
    # the participation mask (fused kernel path unchanged) --------------
    x_upd, z_upd = engine.agent_edge(cfg, u, w, x, z, y, z_seen, prox_h,
                                     mesh)

    # -- stale arrivals: the increment is tagged with the coordinator
    # point it was computed against, not this round's -------------------
    arrived = u != 0
    stale_arrival = arrived & (~fresh)
    z_tagged = tree_map(
        lambda zl, wl, ytl: zl + 2.0 * cfg.damping * (wl - ytl),
        z, w, y_tag)
    z_new = _select(stale_arrival, z_tagged, z_upd)

    # -- stragglers below the bound keep their local progress; a
    # quarantined (corrupt) or evicted agent must NOT -- keeping a
    # poisoned w would carry the corruption into the next round ---------
    keep = (~arrived) & (staleness < cfg.staleness.max_staleness)
    if live is not None:
        keep = keep & (jnp.asarray(live).reshape(-1) != 0)
    if ok is not None:
        keep = keep & ok
    x_new = _select(keep, w, x_upd)

    s_new = _advance_staleness(staleness, u, cfg.staleness.max_staleness,
                               live)

    # -- compressed uplink: only arrived increments are transmitted -----
    if cfg.compressed:
        q = engine.compress_increment(
            tree_map(jnp.subtract, z_new, t), cfg)
        t_new = tree_map(
            lambda tl, ql: tl + _vec(u.astype(ql.dtype), ql) * ql, t, q)
    else:
        t_new = z_new

    return AsyncRoundResult(x=x_new, z=z_new, t=t_new, y=y,
                            y_tag=y_tag_new, staleness=s_new,
                            next_key=key, u=u, aux=aux)


# ---------------------------------------------------------------------------
# One async round, packed-resident layout
# ---------------------------------------------------------------------------

def packed_async_round_step(cfg: RoundConfig, meta, x: jnp.ndarray,
                            z: jnp.ndarray, t: jnp.ndarray,
                            y_tag: jnp.ndarray, staleness: jnp.ndarray,
                            key: jax.Array,
                            local_solver: SolverAssignment,
                            prox_h: ProxH = None,
                            arrival: Optional[jnp.ndarray] = None,
                            mesh=None, corrupt=None,
                            live=None) -> AsyncRoundResult:
    """:func:`async_round_step` on the RESIDENT ``(N, width)`` buffers
    (engine layout contract): ``y_tag`` is an ``(N, width)`` buffer and
    ``y`` comes back ``(1, width)``.  Same arithmetic per column, so
    packed async trajectories are bitwise identical to the tree path
    per realization, exactly like the synchronous engine.  ``mesh``
    shards the edges and every async carrier on the agent axis (mesh
    contract in :mod:`repro.fed.engine`)."""
    if mesh is not None:
        engine.validate_mesh(cfg, mesh, local_solver)
    key, k_part, k_solve = jax.random.split(key, 3)

    z_seen = t if cfg.compressed else z
    z_seen = engine.robust_seen(cfg, z_seen, live, meta, mesh)
    y, v_fresh = engine.coordinator_edge_packed(cfg, z, z_seen, meta,
                                                prox_h, mesh)

    fresh_col = (staleness == 0).reshape(-1, 1)
    v_eff = jnp.where(fresh_col, v_fresh, 2.0 * y_tag - z)
    y_tag_new = jnp.where(fresh_col, y, y_tag)   # (1, w) broadcasts

    w, aux = engine.run_solvers(local_solver, x, v_eff, k_solve,
                                cfg.n_agents)
    w = engine.apply_corruption(w, corrupt)

    u = arrival_mask(k_part, cfg, staleness, arrival, live)
    u, ok = engine.increment_guard(cfg, w, u, meta)

    x_upd, z_upd = engine.agent_edge_packed(cfg, u, w, x, z, y, z_seen,
                                            prox_h, mesh)

    arrived = u != 0
    stale_arrival = (arrived & ~fresh_col.reshape(-1)).reshape(-1, 1)
    z_tagged = z + 2.0 * cfg.damping * (w - y_tag)
    z_new = jnp.where(stale_arrival, z_tagged, z_upd)

    keep = (~arrived) & (staleness < cfg.staleness.max_staleness)
    if live is not None:
        keep = keep & (jnp.asarray(live).reshape(-1) != 0)
    if ok is not None:
        keep = keep & ok
    x_new = jnp.where(keep.reshape(-1, 1), w, x_upd)

    s_new = _advance_staleness(staleness, u, cfg.staleness.max_staleness,
                               live)

    if cfg.compressed:
        q = compress_lib.compress_increment_packed(z_new - t, meta, cfg)
        t_new = t + u.astype(q.dtype).reshape(-1, 1) * q
    else:
        t_new = z_new

    return AsyncRoundResult(x=x_new, z=z_new, t=t_new, y=y,
                            y_tag=y_tag_new, staleness=s_new,
                            next_key=key, u=u, aux=aux)


# ---------------------------------------------------------------------------
# Schedule analysis: the staleness semantics replayed on the host, for
# privacy composition (and broker-schedule validation)
# ---------------------------------------------------------------------------

def effective_counts(schedule, max_staleness: int, live=None) \
        -> Tuple[np.ndarray, np.ndarray]:
    """Per-agent effective composition of a realized arrival schedule.

    ``schedule`` is the ``(R, N)`` 0/1 arrival record (one row per
    round, e.g. stacked ``AsyncRoundResult.u``).  Returns
    ``(arrivals, released_rounds)`` int64 ``(N,)`` vectors:

    * ``arrivals[i]`` -- how many increments agent i released (its
      effective participation count);
    * ``released_rounds[i]`` -- how many ROUNDS of local training those
      increments carried (an increment ``s`` rounds stale carries
      ``s + 1`` rounds of epochs).  Work discarded at the K = 0 bound
      was never transmitted and charges nothing -- DP composes over
      released information only.

    This replays :func:`_advance_staleness` on the host, so the counts
    agree with what the in-jit model realized.  ``live`` (an optional
    ``(R, N)`` 0/1 liveness matrix from a faulty run's ``FaultRecord``)
    pins evicted agents' counters at 0 the same way the in-jit model
    does; released-round charges from BEFORE an eviction are kept --
    that information left the agent, so DP must still pay for it."""
    sched = np.asarray(schedule)
    if sched.ndim != 2:
        raise ValueError(f"schedule must be (n_rounds, n_agents), got "
                         f"shape {sched.shape}")
    lv = _check_live(live, sched.shape)
    r_rounds, n = sched.shape
    s = np.zeros(n, np.int64)
    arrivals = np.zeros(n, np.int64)
    released = np.zeros(n, np.int64)
    for r in range(r_rounds):
        u = sched[r] != 0
        if lv is not None:
            u = u & (lv[r] != 0)
        arrivals += u
        released += np.where(u, s + 1, 0)
        s = np.where(u, 0,
                     np.where(s < max_staleness, s + 1, s))
        if lv is not None:
            s = np.where(lv[r] != 0, s, 0)
    return arrivals, released


def _check_live(live, shape) -> Optional[np.ndarray]:
    if live is None:
        return None
    lv = np.asarray(live)
    if lv.shape != tuple(shape):
        raise ValueError(f"live matrix shape {lv.shape} does not match "
                         f"schedule shape {tuple(shape)}")
    return lv


def validate_schedule(schedule, max_staleness: int, live=None) -> None:
    """Raise ValueError when a schedule violates the hard bound: an
    agent may never hold work more than ``max_staleness`` rounds old
    when increments are pending (the in-jit model would force such an
    arrival; a recorded schedule claiming otherwise is corrupt).  With
    a ``live`` matrix (faulty runs), evicted agents are exempt from the
    bound while dead -- their pending work was abandoned, not held --
    but an arrival from a dead agent is itself a violation."""
    sched = np.asarray(schedule)
    if sched.ndim != 2:
        raise ValueError(f"schedule must be (n_rounds, n_agents), got "
                         f"shape {sched.shape}")
    lv = _check_live(live, sched.shape)
    n = sched.shape[1]
    s = np.zeros(n, np.int64)
    for r, row in enumerate(sched):
        u = row != 0
        alive = np.ones(n, bool) if lv is None else (lv[r] != 0)
        ghost = u & ~alive
        if ghost.any():
            raise ValueError(
                f"schedule is inconsistent with the live matrix: agents "
                f"{np.nonzero(ghost)[0].tolist()} arrive in round {r} "
                f"while evicted")
        over = (~u) & (s >= max_staleness) & (s > 0) & alive
        if over.any():
            raise ValueError(
                f"schedule violates max_staleness={max_staleness}: "
                f"agents {np.nonzero(over)[0].tolist()} miss round {r} "
                f"while holding work {int(s[over].max())} rounds old")
        s = np.where(u, 0, np.where(s < max_staleness, s + 1, s))
        s = np.where(alive, s, 0)
